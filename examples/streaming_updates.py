"""End-to-end serving driver: the paper's §5.2 scenario — a live index
absorbing a 1%-per-epoch update stream (SPACEV-like skew) while serving
queries, with the Updater→Local-Rebuilder feed-forward pipeline.

    PYTHONPATH=src python examples/streaming_updates.py [--epochs 10]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import spfresh
from repro.core import LireConfig
from repro.data import UpdateWorkload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--n", type=int, default=6000)
    args = ap.parse_args()

    wl = UpdateWorkload.spacev(n=args.n, dim=16, rate=0.01, seed=0)
    spec = spfresh.ServiceSpec(
        index=spfresh.IndexSpec(config=LireConfig(
            dim=16, block_size=8, max_blocks_per_posting=8, num_blocks=16384,
            num_postings_cap=2048, num_vectors_cap=131072,
            split_limit=48, merge_limit=6, reassign_range=8, replica_count=2,
            nprobe=8,
        )),
        serve=spfresh.ServeSpec(search_k=10, fg_bg_ratio=2),
        maintenance=spfresh.MaintenanceSpec(maintain_budget=16),
    )
    vecs, _ = wl.live_vectors()
    service = spfresh.open(spec, vectors=vecs)
    engine = service.engine
    print(f"day | recall@10 | search p99 (ms) | postings | splits | reassigned")
    for day in range(args.epochs):
        del_vids, ins_vecs, ins_vids = wl.epoch()
        engine.delete(del_vids.astype(np.int32))
        engine.insert(ins_vecs, ins_vids.astype(np.int32))

        queries, gt = wl.queries(64)
        _, got = engine.search(queries)
        hits = sum(
            len(set(g.tolist()) & set(o.tolist())) for g, o in zip(gt, got)
        )
        recall = hits / (len(queries) * 10)
        lat = engine.latency_percentiles("search")
        st = engine.stats()
        print(
            f"{day:3d} | {recall:9.3f} | {lat.get('p99_ms', 0):15.2f} | "
            f"{st['n_postings']:8d} | {st['n_splits']:6d} | "
            f"{st['n_reassigned']:10d}"
        )
    engine.drain()
    print("final stats:", engine.stats())


if __name__ == "__main__":
    main()
