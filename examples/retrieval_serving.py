"""The paper's technique as a framework feature: two-tower retrieval served
by the SPFresh index (the `retrieval_cand` cell) with streaming catalog
churn — vs the brute-force GEMM baseline.  The second half attaches the
batched ServeEngine pipeline in front of the corpus: lookups and churn
flow through the micro-batched queue, background maintenance is
policy-scheduled, and the engine's report shows latency percentiles,
padding waste, and maintenance throughput.

    PYTHONPATH=src python examples/retrieval_serving.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.types import LireConfig
from repro.models import recsys as R
from repro.serve.retrieval import IndexedRetriever


def main() -> None:
    model_cfg = R.TwoTowerConfig(
        n_items=20000, n_user_fields=4, user_vocab_per_field=1000,
        embed_dim=32, tower_dims=(64, 16),
    )
    params = R.twotower_init(jax.random.PRNGKey(0), model_cfg)
    index_cfg = LireConfig(
        dim=16, block_size=16, max_blocks_per_posting=8, num_blocks=16384,
        num_postings_cap=2048, num_vectors_cap=262144,
        split_limit=96, merge_limit=12, reassign_range=8, replica_count=2,
        nprobe=16,
    )

    retriever = IndexedRetriever(params, model_cfg, index_cfg)
    catalog = np.arange(15000)
    t0 = time.perf_counter()
    retriever.build_corpus(catalog)
    print(f"corpus of {len(catalog)} items indexed in "
          f"{time.perf_counter() - t0:.1f}s "
          f"({retriever.index.stats()['n_postings']} postings)")

    rng = np.random.default_rng(1)
    users = rng.integers(0, 1000, size=(16, 4)).astype(np.int32)

    t0 = time.perf_counter()
    s_ann, ids_ann = retriever.retrieve(users, k=10)
    t_ann = time.perf_counter() - t0
    t0 = time.perf_counter()
    s_bf, ids_bf = retriever.retrieve_bruteforce(users, k=10)
    t_bf = time.perf_counter() - t0

    hits = sum(
        len(set(a.tolist()) & set(b.tolist()))
        for a, b in zip(ids_ann, ids_bf)
    )
    print(f"ANN recall vs brute force: {hits / 160:.3f} "
          f"(ann {t_ann * 1e3:.0f}ms vs gemm {t_bf * 1e3:.0f}ms for 16 queries)")

    # --- streaming catalog churn: no index rebuild ---
    new_items = np.arange(15000, 16000)
    t0 = time.perf_counter()
    retriever.add_items(new_items)
    print(f"+1000 items in-place in {time.perf_counter() - t0:.1f}s; "
          f"stats: splits={retriever.index.stats()['n_splits']}, "
          f"reassigned={retriever.index.stats()['n_reassigned']}")
    s2, ids2 = retriever.retrieve(users, k=10)
    fresh = (ids2 >= 15000).sum()
    print(f"fresh items now appearing in top-10s: {fresh}")

    # --- the serving pipeline in front of the corpus ---
    # attach_engine accepts a ServiceSpec: the serve/scan/maintenance
    # sub-specs compile to the pipeline config (the preferred surface).
    import spfresh
    from repro.serve.policy import BacklogPolicy

    engine = retriever.attach_engine(
        spfresh.ServiceSpec(
            index=spfresh.IndexSpec(config=index_cfg),
            serve=spfresh.ServeSpec(search_k=10, max_batch=128,
                                    policy="backlog"),
            maintenance=spfresh.MaintenanceSpec(maintain_budget=16),
        ),
        policy=BacklogPolicy(threshold=1, budget=16),
    )
    t0 = time.perf_counter()
    for _ in range(8):                       # a burst of lookup traffic
        users = rng.integers(0, 1000, size=(16, 4)).astype(np.int32)
        retriever.retrieve(users, k=10)
    retriever.add_items(np.arange(16000, 16500))   # churn mid-traffic
    retriever.remove_items(np.arange(100))
    retriever.retrieve(users, k=10)
    engine.drain()
    rep = engine.report()
    print(f"pipeline: {8 + 1} retrievals + churn in "
          f"{time.perf_counter() - t0:.1f}s — "
          f"search p50={rep['search']['p50_ms']:.1f}ms "
          f"p99={rep['search']['p99_ms']:.1f}ms, "
          f"pad_waste={rep['queue']['padding_waste_frac']:.3f}, "
          f"maint {rep['maintenance']['steps']} steps "
          f"@{rep['maintenance']['steps_per_s']:.1f}/s "
          f"({rep['maintenance']['policy']})")


if __name__ == "__main__":
    main()
