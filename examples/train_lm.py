"""Training driver: train a GQA transformer LM with the fault-tolerant
Trainer (checkpoint/restart, straggler accounting) on synthetic tokens.

Default config is CPU-sized (~8M params, 200 steps, a couple of minutes);
``--large`` switches to a ~110M-param config (the '100M-class' driver —
expect hours on CPU, minutes on real accelerators).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--large]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.models import transformer as tf
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def make_batch_fn(cfg, batch, seq):
    """Deterministic synthetic pipeline: step -> batch (replay-exact on
    restart).  A Zipfian unigram stream with local repetition so the loss
    has structure to learn."""

    def batch_fn(step: int) -> dict:
        rng = np.random.default_rng(1234 + step)
        probs = 1.0 / np.arange(1, cfg.vocab + 1) ** 1.1
        probs /= probs.sum()
        toks = rng.choice(cfg.vocab, size=(batch, seq), p=probs)
        # repetition structure: second half mirrors the first
        toks[:, seq // 2:] = toks[:, : seq - seq // 2]
        import jax.numpy as jnp

        t = jnp.asarray(toks, jnp.int32)
        labels = jnp.concatenate([t[:, 1:], -jnp.ones((batch, 1), jnp.int32)], 1)
        return {"tokens": t, "labels": labels}

    return batch_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--large", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.large:
        cfg = tf.LMConfig(name="lm-110m", vocab=32000, n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                          dtype="float32", kv_chunk=256)
        batch, seq = 8, 512
    else:
        cfg = tf.LMConfig(name="lm-8m", vocab=2048, n_layers=4, d_model=128,
                          n_heads=4, n_kv_heads=2, d_ff=512,
                          dtype="float32", kv_chunk=64)
        batch, seq = 8, 128

    ckpt_dir = args.ckpt or os.path.join(tempfile.mkdtemp(), "ckpt")
    trainer = Trainer(
        loss_fn=lambda p, b: tf.loss_fn(p, b, cfg),
        init_params_fn=lambda: tf.init_params(jax.random.PRNGKey(0), cfg),
        batch_fn=make_batch_fn(cfg, batch, seq),
        opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=20, decay_steps=args.steps),
        trainer_cfg=TrainerConfig(
            total_steps=args.steps, checkpoint_every=50, log_every=10,
        ),
        ckpt_dir=ckpt_dir,
    )
    print(f"model: {cfg.name}  params={cfg.n_params / 1e6:.1f}M  "
          f"ckpt={ckpt_dir}")
    # first half
    trainer.run(steps=args.steps // 2)
    print(f"[mid] step={trainer.step} loss={trainer.history[-1]['loss']:.3f}")

    # simulate a failure + restart: a fresh Trainer resumes from checkpoint
    trainer2 = Trainer(
        loss_fn=lambda p, b: tf.loss_fn(p, b, cfg),
        init_params_fn=lambda: tf.init_params(jax.random.PRNGKey(0), cfg),
        batch_fn=make_batch_fn(cfg, batch, seq),
        opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=20, decay_steps=args.steps),
        trainer_cfg=TrainerConfig(
            total_steps=args.steps, checkpoint_every=50, log_every=10,
        ),
        ckpt_dir=ckpt_dir,
    )
    result = trainer2.run()
    print(f"[restart] resumed at step "
          f"{result['history'][0]['step'] if result['history'] else '?'} → "
          f"finished step={result['final_step']} "
          f"loss={result['final_loss']:.3f} "
          f"stragglers={result['straggler_steps']}")
    for h in result["history"]:
        print(f"  step {h['step']:4d}  loss {h['loss']:.3f}  {h['dt'] * 1e3:.0f}ms")


if __name__ == "__main__":
    main()
