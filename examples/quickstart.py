"""Quickstart: open a SPFresh *service*, search it, stream updates
through LIRE, checkpoint, crash, and recover — all through the unified
``spfresh.open(ServiceSpec)`` API.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import spfresh
from repro.core import LireConfig
from repro.data import make_sift_like


def main() -> None:
    dim = 16
    base = make_sift_like(5000, dim, seed=0)

    # ONE spec describes the whole service: index geometry, serving,
    # scan path, maintenance, durability, sharding.  Add ``.with_shards(4)``
    # and the same spec serves a 4-shard mesh.
    spec = spfresh.ServiceSpec(
        index=spfresh.IndexSpec(config=LireConfig(
            dim=dim, block_size=8, max_blocks_per_posting=8, num_blocks=8192,
            num_postings_cap=1024, num_vectors_cap=65536,
            split_limit=48, merge_limit=6, reassign_range=8, replica_count=2,
            nprobe=8,
        )),
        serve=spfresh.ServeSpec(search_k=5),
        durability=spfresh.DurabilitySpec(root=tempfile.mkdtemp()),
    )

    service = spfresh.open(spec, vectors=base)
    print(f"opened: {service.stats()['n_postings']} postings over "
          f"{len(base)} vectors (durable root, open-time snapshot written)")

    # --- search ---
    queries = base[:5] + 0.01 * np.random.default_rng(1).normal(
        size=(5, dim)).astype(np.float32)
    dists, ids = service.search(queries, k=5)
    print("top-5 of query 0:", ids[0].tolist())

    # --- streaming updates (in-place, no rebuild; WAL'd per dispatch) ---
    rng = np.random.default_rng(2)
    new_vecs = (base[0] + 0.02 * rng.normal(size=(200, dim))).astype(np.float32)
    new_ids = np.arange(10000, 10200, dtype=np.int32)
    service.insert(new_vecs, new_ids)    # foreground Updater (backpressured)
    service.delete(np.arange(10, 20, dtype=np.int32))  # tombstones
    jobs = service.drain()               # background Local Rebuilder (LIRE)
    st = service.stats()
    print(f"maintain: {jobs} jobs, {st['n_splits']} splits, "
          f"{st['n_reassigned']} reassigned "
          f"(checked {st['n_reassign_checked']})")

    _, ids = service.search(new_vecs[:3], k=3)
    print("fresh vectors recalled:",
          [int(i) in ids[j] for j, i in enumerate(new_ids[:3])])

    # --- crash recovery: checkpoint, update, "crash", reopen ---
    service.checkpoint()                 # snapshot + WAL truncate
    service.insert(new_vecs[:50], np.arange(20000, 20050, dtype=np.int32))
    # no close(): the post-checkpoint inserts live only in the WAL
    recovered = spfresh.open(spec)       # snapshot + WAL replay
    print("recovered:", recovered.recovered)
    _, ids2 = recovered.search(new_vecs[:1], k=5)
    print("recovered service answers queries:", ids2[0].tolist())
    recovered.close()


if __name__ == "__main__":
    main()
