"""Quickstart: build a SPFresh index, search it, stream updates through
LIRE, snapshot + crash-recover.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import LireConfig, SPFreshIndex
from repro.data import make_sift_like


def main() -> None:
    dim = 16
    base = make_sift_like(5000, dim, seed=0)

    cfg = LireConfig(
        dim=dim, block_size=8, max_blocks_per_posting=8, num_blocks=8192,
        num_postings_cap=1024, num_vectors_cap=65536,
        split_limit=48, merge_limit=6, reassign_range=8, replica_count=2,
        nprobe=8,
    )

    tmp = tempfile.mkdtemp()
    wal = os.path.join(tmp, "wal.log")
    index = SPFreshIndex.build(cfg, base, wal_path=wal)
    print(f"built: {index.stats()['n_postings']} postings over {len(base)} vectors")

    # --- search ---
    queries = base[:5] + 0.01 * np.random.default_rng(1).normal(size=(5, dim)).astype(np.float32)
    dists, ids = index.search(queries, k=5)
    print("top-5 of query 0:", ids[0].tolist())

    # --- streaming updates (in-place, no rebuild) ---
    rng = np.random.default_rng(2)
    new_vecs = (base[0] + 0.02 * rng.normal(size=(200, dim))).astype(np.float32)
    new_ids = np.arange(10000, 10200, dtype=np.int32)
    index.insert(new_vecs, new_ids)      # foreground Updater (backpressured)
    index.delete(np.arange(10, 20, dtype=np.int32))  # tombstones
    steps = index.maintain()             # background Local Rebuilder (LIRE)
    st = index.stats()
    print(f"maintain: {steps} steps, {st['n_splits']} splits, "
          f"{st['n_reassigned']} reassigned "
          f"(checked {st['n_reassign_checked']})")

    _, ids = index.search(new_vecs[:3], k=3)
    print("fresh vectors recalled:", [int(i) in ids[j] for j, i in enumerate(new_ids[:3])])

    # --- crash recovery: snapshot + WAL replay ---
    snap = os.path.join(tmp, "snap")
    index.snapshot(snap)
    index.insert(new_vecs[:50], np.arange(20000, 20050, dtype=np.int32))
    recovered = SPFreshIndex.restore(snap, cfg, wal_path=wal)
    _, ids2 = recovered.search(new_vecs[:1], k=5)
    print("recovered index answers queries:", ids2[0].tolist())


if __name__ == "__main__":
    main()
