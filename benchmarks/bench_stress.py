"""Paper Fig. 9 (scaled): stress test on uniform vs skew datasets —
sustained mixed search+update load; stability of recall/tail latency and
throughput accounting."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_cfg, posting_stats, recall_at, timed_search
from repro import api
from repro.data.vectors import UpdateWorkload


def run(quick: bool = True) -> list[str]:
    n = 8000 if quick else 60000
    epochs = 6 if quick else 20
    rate = 0.05  # stress: 5% churn per epoch
    out = []
    for name, maker in (("uniform", UpdateWorkload.sift),
                        ("skew", UpdateWorkload.spacev)):
        wl = maker(n=n, dim=16, rate=rate, seed=21)
        vecs, _ = wl.live_vectors()
        service = api.open(api.ServiceSpec(
            index=api.IndexSpec(config=bench_cfg(num_blocks=16384)),
            serve=api.ServeSpec(fg_bg_ratio=2),
            maintenance=api.MaintenanceSpec(maintain_budget=16),
        ), vectors=vecs)
        idx, engine = service.index, service.engine
        recalls, p99s = [], []
        n_upd = 0
        t0 = time.perf_counter()
        for _ in range(epochs):
            dv, iv, ii = wl.epoch()
            engine.delete(dv.astype(np.int32))
            engine.insert(iv, ii.astype(np.int32))
            n_upd += len(dv) + len(ii)
            q, gt = wl.queries(64)
            recalls.append(recall_at(idx, q, gt))
            p99s.append(timed_search(idx, q, chunk=64)["p99_ms"])
        wall = time.perf_counter() - t0
        ps = posting_stats(idx)
        out.append(
            f"stress/{name},{wall / max(n_upd, 1) * 1e6:.1f},"
            f"update_qps={n_upd / wall:.0f};"
            f"recall_min={min(recalls):.3f};recall_max={max(recalls):.3f};"
            f"p99_drift={max(p99s) / max(min(p99s), 1e-9):.2f};"
            f"scan_p99={ps['scan_cost_p99']:.0f}"
        )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
