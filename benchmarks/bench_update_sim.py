"""Paper Fig. 7: real-world update simulation (workload A = SPACEV-like
skew, workload B = SIFT-like uniform).  N epochs of 1% delete + 1% insert
driven through the batched serving pipeline; per-epoch tail latency,
recall, resource accounting, protocol stats, pipeline metrics."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_cfg, posting_stats, recall_at, timed_search
from repro import api
from repro.data.vectors import UpdateWorkload


def simulate(workload: UpdateWorkload, *, spfresh: bool, epochs: int) -> dict:
    cfg = bench_cfg() if spfresh else bench_cfg(
        max_blocks_per_posting=32, num_blocks=32768,
        enable_split=False, enable_merge=False, enable_reassign=False,
    )
    vecs, ids = workload.live_vectors()
    service = api.open(api.ServiceSpec(
        index=api.IndexSpec(config=cfg),
        serve=api.ServeSpec(search_k=10, max_batch=256, fg_bg_ratio=2),
        maintenance=api.MaintenanceSpec(maintain_budget=16),
    ), vectors=vecs)
    idx, engine = service.index, service.engine

    series = []
    for _ in range(epochs):
        del_vids, ins_vecs, ins_vids = workload.epoch()
        engine.submit_delete(del_vids.astype(np.int32))
        if spfresh:
            engine.submit_insert(ins_vecs, ins_vids.astype(np.int32))
            engine.pump()
        else:
            engine.pump()
            idx.insert(ins_vecs, ins_vids.astype(np.int32), max_retries=0)
        queries, gt = workload.queries(64)
        r = recall_at(idx, queries, gt)
        lat = timed_search(idx, queries, chunk=64)
        ps = posting_stats(idx)
        mem = idx.memory_bytes()
        series.append({
            "recall": r, "p99_ms": lat["p99_ms"], "mean_ms": lat["mean_ms"],
            "scan_p99": ps["scan_cost_p99"], "mem_mb": mem["memory"] / 1e6,
        })
    if spfresh:
        engine.drain()
    stats = idx.stats()
    return {"series": series, "stats": stats, "report": engine.report()}


def run(quick: bool = True) -> list[str]:
    n = 6000 if quick else 50000
    epochs = 8 if quick else 50
    out = []
    for wl_name, maker in (("A_spacev", UpdateWorkload.spacev),
                           ("B_sift", UpdateWorkload.sift)):
        for sys_name, spfresh in (("spfresh", True), ("spann+", False)):
            wl = maker(n=n, dim=16, rate=0.01, seed=7)
            res = simulate(wl, spfresh=spfresh, epochs=epochs)
            s = res["series"]
            first, last = s[0], s[-1]
            st = res["stats"]
            rep = res["report"]
            reassign_frac = st["n_reassigned"] / max(st["n_reassign_checked"], 1)
            out.append(
                f"update_sim/{wl_name}/{sys_name},"
                f"{np.mean([x['mean_ms'] for x in s]) * 1e3:.1f},"
                f"recall_first={first['recall']:.3f};"
                f"recall_last={last['recall']:.3f};"
                f"scan_p99_last={last['scan_p99']:.0f};"
                f"splits={st['n_splits']};merges={st['n_merges']};"
                f"reassigned={st['n_reassigned']};"
                f"reassign_frac={reassign_frac:.4f};"
                f"maint_sps={rep['maintenance']['steps_per_s']:.1f};"
                f"pad_waste={rep['queue']['padding_waste_frac']:.3f}"
            )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
