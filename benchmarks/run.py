"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


BENCHES = [
    ("shift", "benchmarks.bench_shift"),                 # Fig. 2 / Fig. 10
    ("update_sim", "benchmarks.bench_update_sim"),       # Fig. 7 (workload A/B)
    ("stress", "benchmarks.bench_stress"),               # Fig. 9 (workload C)
    ("reassign_range", "benchmarks.bench_reassign_range"),  # Fig. 11
    ("pipeline", "benchmarks.bench_pipeline_balance"),   # Fig. 12
    ("rebuild_cost", "benchmarks.bench_rebuild_cost"),   # Table 1
    ("kernels", "benchmarks.bench_kernels"),             # hot-path micro
    ("roofline", "benchmarks.roofline_report"),          # §Roofline summary
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow); default quick")
    ap.add_argument("--dry", action="store_true",
                    help="import smoke: load every bench module, run nothing")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for name, module in BENCHES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            import importlib

            mod = importlib.import_module(module)
            if args.dry:
                assert callable(getattr(mod, "run")), f"{module}.run missing"
                print(f"# {name} dry ok", flush=True)
                continue
            for line in mod.run(quick=not args.full):
                print(line, flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
