"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
    PYTHONPATH=src python -m benchmarks.run --json BENCH_search.json

``--json PATH`` runs the search data-path benchmark and writes a
machine-readable report (p50/p99 search latency + modeled scan GB/query
for the oracle vs per-query vs batch-dedup Pallas schedules) so the perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


BENCHES = [
    ("shift", "benchmarks.bench_shift"),                 # Fig. 2 / Fig. 10
    ("scenarios", "benchmarks.bench_scenarios"),         # serving gauntlet
    ("update_sim", "benchmarks.bench_update_sim"),       # Fig. 7 (workload A/B)
    ("stress", "benchmarks.bench_stress"),               # Fig. 9 (workload C)
    ("reassign_range", "benchmarks.bench_reassign_range"),  # Fig. 11
    ("pipeline", "benchmarks.bench_pipeline_balance"),   # Fig. 12
    ("serve_async", "benchmarks.bench_serve_async"),     # open-loop tails
    ("replicas", "benchmarks.bench_replicas"),           # read replicas
    ("rebuild_cost", "benchmarks.bench_rebuild_cost"),   # Table 1
    ("maintenance", "benchmarks.bench_maintenance"),     # batched rounds
    ("recovery", "benchmarks.bench_recovery"),           # §4.4 durability
    ("kernels", "benchmarks.bench_kernels"),             # hot-path micro
    ("search_path", "benchmarks.bench_search_path"),     # scan data paths
    ("roofline", "benchmarks.roofline_report"),          # §Roofline summary
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow); default quick")
    ap.add_argument("--dry", action="store_true",
                    help="import smoke: load every bench module, run nothing")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a machine-readable report to PATH and exit")
    ap.add_argument("--report",
                    choices=["auto", "search", "maintenance", "recovery",
                             "scenarios", "serve", "replicas"],
                    default="auto",
                    help="which --json report to write; 'auto' picks "
                         "maintenance for paths containing 'update'/'maint', "
                         "recovery for 'recover', scenarios for "
                         "'scenario', replicas for 'replica', serve for "
                         "'serve', else search")
    args = ap.parse_args()

    if args.json:
        import os

        base = os.path.basename(args.json).lower()
        which = args.report
        if which == "auto":
            if "update" in base or "maint" in base:
                which = "maintenance"
            elif "recover" in base:
                which = "recovery"
            elif "scenario" in base:
                which = "scenarios"
            elif "replica" in base:
                which = "replicas"
            elif "serve" in base:
                which = "serve"
            else:
                which = "search"
        if which == "scenarios":
            from benchmarks.bench_scenarios import run_json

            report = run_json(quick=not args.full)
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            shift = report["scenarios"]["shift"]
            print(f"# wrote {args.json}: shift drift_minus_size="
                  f"{shift['drift_minus_size']:+.3f} at "
                  f"jobs_per_round={shift['jobs_per_round']}")
            return
        if which == "replicas":
            from benchmarks.bench_replicas import run_json

            report = run_json(quick=not args.full)
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            s = report["summary"]
            print(f"# wrote {args.json}: "
                  f"read_scaling_2r={s['read_scaling_2r']:.2f}x (modeled) "
                  f"ack_overhead={s['ack_overhead_frac'] * 100:+.1f}% "
                  f"parity={s['bit_identical_at_equal_seqno']}")
            return
        if which == "serve":
            from benchmarks.bench_serve_async import run_json

            report = run_json(quick=not args.full)
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            s = report["summary"]
            print(f"# wrote {args.json}: "
                  f"search_p99 sync={s['sync_search_p99_ms']:.1f}ms "
                  f"async={s['async_search_p99_ms']:.1f}ms "
                  f"({s['search_p99_reduction_x']:.2f}x) "
                  f"overlap_frac={s['async_overlap_frac']:.2f}")
            return
        if which == "recovery":
            from benchmarks.bench_recovery import run_json

            report = run_json(quick=not args.full)
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            rec = report["recovery"]
            print(f"# wrote {args.json}: "
                  f"replayed_rows_s={rec['replayed_rows_s']:.0f} "
                  f"recover_open_s={rec['recover_open_s']:.2f}s "
                  f"snapshot_write_mb_s={report['snapshot']['write_mb_s']:.0f}")
            return
        if which == "maintenance":
            from benchmarks.bench_maintenance import run_json

            report = run_json(quick=not args.full)
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            sp = report["round_speedup_vs_step"]
            stall = report["insert_stall"]["stall_reduction"]
            print(f"# wrote {args.json}: round_speedup_vs_step="
                  + ",".join(f"j{j}:{v:.2f}x" for j, v in sp.items())
                  + f" insert_stall_reduction={stall:.2f}x")
            return
        from benchmarks.bench_search_path import run_json

        report = run_json(quick=not args.full)
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        mult = report["probe_multiplicity"]
        saving = report["batched_traffic_saving"]
        print(f"# wrote {args.json}: probe_multiplicity={mult:.2f}x "
              f"batched_traffic_saving={saving:.2f}x")
        return

    print("name,us_per_call,derived")
    failures = 0
    for name, module in BENCHES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            import importlib

            mod = importlib.import_module(module)
            if args.dry:
                assert callable(getattr(mod, "run")), f"{module}.run missing"
                print(f"# {name} dry ok", flush=True)
                continue
            for line in mod.run(quick=not args.full):
                print(line, flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
