"""Open-loop tail-latency harness: sync vs async serving under Poisson
arrivals (the paper's Fig. 7/9 measurement discipline, done honestly).

The engine's own ``report()`` percentiles measure a *closed* loop — each
caller waits for its previous request, so queueing delay never appears.
This harness drives an **open loop** instead: multi-threaded submitters
fire a mixed search/insert/delete stream (gauntlet-ish 70/20/10 ratios)
at a fixed offered QPS from pre-generated Poisson schedules, and latency
is measured from the *scheduled arrival time* to ticket completion — so
a backed-up engine accrues queueing delay exactly like a real service.

Two engine modes over identical schedules and identical index builds:

* ``sync`` — the cooperative model: submitters serialize on one lock
  and pump the engine themselves (`ticket.result()`), so every
  maintenance slot and every other caller's batch sits on each
  request's critical path.
* ``async`` — the background pump thread (``EngineConfig.async_serve``)
  with a batch-formation window: submitters only enqueue; maintenance
  runs in queue-idle gaps; search readbacks are deferred for device
  overlap.

Emits ``BENCH_serve.json``: p50/p99/p99.9 per op vs offered load for
both modes, the maintenance-overlap fraction (rebuilder seconds spent in
idle gaps vs inline on the serve path), and the batching window's
bucket-fill / padding-waste delta.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import bench_cfg
from repro.core.index import SPFreshIndex
from repro.data.vectors import make_shifting_stream, make_sift_like
from repro.serve.engine import EngineConfig, ServeEngine

DIM = 16
N_THREADS = 4
MIX = (0.7, 0.2, 0.1)           # search / insert / delete
_SEARCH, _INSERT, _DELETE = 0, 1, 2


def _poisson_schedule(rng, qps: float, duration: float) -> np.ndarray:
    """Arrival offsets (seconds) of a Poisson process at ``qps``."""
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / qps)
        if t >= duration:
            return np.asarray(out)
        out.append(t)


def _build_engine(mode: str, base: np.ndarray, max_wait_ms: float,
                  ) -> ServeEngine:
    idx = SPFreshIndex.build(bench_cfg(), base, seed=41)
    return ServeEngine(idx, EngineConfig(
        search_k=10, max_batch=64, min_bucket=8,
        policy="ratio", fg_bg_ratio=2, maintain_budget=8,
        async_serve=(mode == "async"),
        max_wait_ms=max_wait_ms if mode == "async" else 0.0,
    ))


def _warmup(eng: ServeEngine, queries: np.ndarray, inserts: np.ndarray,
            n_base: int) -> None:
    """Compile every (op, bucket) executable + one maintenance round
    before the timed window, identically for both modes."""
    vid = n_base + 1000          # < num_vectors_cap (bench_cfg: 65536)
    for b in (1, 8, 16, 32, 64):
        eng.search(queries[:b])
        eng.insert(inserts[:b], np.arange(vid, vid + b, dtype=np.int32))
        vid += b
        eng.delete(np.arange(vid - b, vid, dtype=np.int32))
    eng.pump()
    with eng.exclusive():
        eng.backend.maintain(eng.policy.budget)


def _run_mode(mode: str, load_qps: float, duration: float,
              base: np.ndarray, queries: np.ndarray, inserts: np.ndarray,
              max_wait_ms: float) -> dict:
    eng = _build_engine(mode, base, max_wait_ms)
    n_base = len(base)
    _warmup(eng, queries, inserts, n_base)

    master = np.random.default_rng(97)
    plans = []
    for tid in range(N_THREADS):
        sched = _poisson_schedule(master, load_qps / N_THREADS, duration)
        ops = master.choice(3, size=len(sched), p=MIX)
        plans.append((sched, ops))

    sync_lock = threading.Lock()            # the cooperative-mode model
    records: list[list[tuple[int, float, object]]] = [[] for _ in plans]
    errors: list[BaseException] = []
    start = time.perf_counter() + 0.05

    def submitter(tid: int) -> None:
        sched, ops = plans[tid]
        rng = np.random.default_rng(1000 + tid)
        # per-thread vid range, all < num_vectors_cap (65536) so
        # maintenance never GCs an over-cap vid out from under us
        vid_next = n_base + 2000 + 10_000 * tid
        own_vids: list[int] = []
        recs = records[tid]
        try:
            for t_rel, op in zip(sched, ops):
                tgt = start + t_rel
                wait = tgt - time.perf_counter()
                if wait > 0:
                    time.sleep(wait)
                if op == _DELETE and not own_vids:
                    op = _INSERT          # nothing of ours to delete yet
                if op == _SEARCH:
                    q = queries[rng.integers(0, len(queries))][None]
                    if mode == "async":
                        tk = eng.submit_search(q)
                    else:
                        with sync_lock:
                            tk = eng.submit_search(q)
                            tk.result()
                elif op == _INSERT:
                    v = inserts[rng.integers(0, len(inserts))][None]
                    vid = vid_next
                    vid_next += 1
                    own_vids.append(vid)
                    ids = np.asarray([vid], np.int32)
                    if mode == "async":
                        tk = eng.submit_insert(v, ids)
                    else:
                        with sync_lock:
                            tk = eng.submit_insert(v, ids)
                            tk.result()
                else:
                    ids = np.asarray([own_vids.pop(0)], np.int32)
                    if mode == "async":
                        tk = eng.submit_delete(ids)
                    else:
                        with sync_lock:
                            tk = eng.submit_delete(ids)
                            tk.result()
                recs.append((op, tgt, tk))
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [
        threading.Thread(target=submitter, args=(tid,), daemon=True)
        for tid in range(len(plans))
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(duration * 20 + 120)
    assert not any(t.is_alive() for t in threads), "submitter hung"
    if errors:
        raise errors[0]
    eng.pump()                   # async: barrier — every ticket completes
    wall = time.perf_counter() - t0

    lats: dict[int, list[float]] = {_SEARCH: [], _INSERT: [], _DELETE: []}
    for recs in records:
        for op, tgt, tk in recs:
            assert tk.t_done is not None, "ticket incomplete after flush"
            # open-loop latency: scheduled arrival -> completion
            lats[op].append(tk.t_done - tgt)

    def pct(xs: list[float]) -> dict:
        if not xs:
            return {}
        a = np.asarray(xs) * 1e3
        return {
            "p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "p999_ms": float(np.percentile(a, 99.9)),
            "mean_ms": float(a.mean()),
            "n": len(a),
        }

    rep = eng.report()
    m, q = rep["maintenance"], rep["queue"]
    if mode == "async":
        eng.shutdown()
    n_ops = sum(len(r) for r in records)
    return {
        "mode": mode,
        "offered_qps": load_qps,
        "achieved_qps": n_ops / wall if wall > 0 else 0.0,
        "n_ops": n_ops,
        "search": pct(lats[_SEARCH]),
        "insert": pct(lats[_INSERT]),
        "delete": pct(lats[_DELETE]),
        "maintenance": {
            "slots": m["slots"],
            "time_s": m["time_s"],
            "idle_time_s": m["idle_time_s"],
            "inline_time_s": m["time_s"] - m["idle_time_s"],
            "overlap_frac": m["overlap_frac"],
            "deferred": m["deferred"],
            "forced": m["forced"],
        },
        "insert_stall_s": rep["insert_stall_s"],
        "batching": {
            "batches": q["batches"],
            "rows": q["rows"],
            "rows_per_batch": q["rows"] / q["batches"] if q["batches"] else 0,
            "padding_waste_frac": q["padding_waste_frac"],
            "bucket_fill_frac": 1.0 - q["padding_waste_frac"],
            "window_waits": q["window_waits"],
        },
    }


def run_json(quick: bool = True) -> dict:
    n_base = 4000 if quick else 20000
    duration = 5.0 if quick else 20.0
    loads = (100.0, 250.0) if quick else (100.0, 250.0, 500.0)
    max_wait_ms = 2.0
    base = make_sift_like(n_base, DIM, seed=41)
    queries = make_sift_like(512, DIM, seed=43)
    inserts = make_shifting_stream(4096, DIM, seed=44)

    cells: dict[str, dict] = {}
    for load in loads:
        cells[str(int(load))] = {
            mode: _run_mode(mode, load, duration, base, queries, inserts,
                            max_wait_ms)
            for mode in ("sync", "async")
        }

    # reference cell: the highest load BOTH modes actually sustained
    # (achieved >= 90% of offered) — overload cells measure queue
    # growth, not steady-state tails; fall back to the lowest load
    ref = int(loads[0])
    for load in loads:
        cell = cells[str(int(load))]
        if all(cell[m]["achieved_qps"] >= 0.9 * load
               for m in ("sync", "async")):
            ref = int(load)
    ref = str(ref)
    s, a = cells[ref]["sync"], cells[ref]["async"]
    summary = {
        "reference_load_qps": float(ref),
        "sync_search_p99_ms": s["search"]["p99_ms"],
        "async_search_p99_ms": a["search"]["p99_ms"],
        "search_p99_reduction_x": (
            s["search"]["p99_ms"] / a["search"]["p99_ms"]
            if a["search"]["p99_ms"] > 0 else float("inf")
        ),
        # "insert stall -> background work": rebuilder seconds that sat on
        # the serve path (inline) vs in queue-idle gaps (overlapped)
        "sync_maint_inline_s": s["maintenance"]["inline_time_s"],
        "async_maint_inline_s": a["maintenance"]["inline_time_s"],
        "async_overlap_frac": a["maintenance"]["overlap_frac"],
        "sync_insert_stall_s": s["insert_stall_s"],
        "async_insert_stall_s": a["insert_stall_s"],
        "padding_waste_sync": s["batching"]["padding_waste_frac"],
        "padding_waste_async": a["batching"]["padding_waste_frac"],
        "rows_per_batch_sync": s["batching"]["rows_per_batch"],
        "rows_per_batch_async": a["batching"]["rows_per_batch"],
    }
    return {
        "bench": "serve_async",
        "config": {
            "dim": DIM, "n_base": n_base, "duration_s": duration,
            "threads": N_THREADS, "mix_search_insert_delete": MIX,
            "max_wait_ms": max_wait_ms, "max_batch": 64,
            "policy": "ratio 2:1, budget 8",
        },
        "loads": cells,
        "summary": summary,
    }


def run(quick: bool = True) -> list[str]:
    rep = run_json(quick=quick)
    out = []
    for load, modes in rep["loads"].items():
        for mode, cell in modes.items():
            sp = cell["search"]
            out.append(
                f"serve_async/{mode}@{load}qps,{sp.get('mean_ms', 0) * 1e3:.1f},"
                f"srch_p50={sp.get('p50_ms', 0):.1f};"
                f"srch_p99={sp.get('p99_ms', 0):.1f};"
                f"srch_p999={sp.get('p999_ms', 0):.1f};"
                f"achieved={cell['achieved_qps']:.0f}qps;"
                f"overlap={cell['maintenance']['overlap_frac']:.2f};"
                f"fill={cell['batching']['bucket_fill_frac']:.2f}"
            )
    s = rep["summary"]
    out.append(
        f"serve_async/summary,0.0,"
        f"p99_reduction={s['search_p99_reduction_x']:.2f}x;"
        f"maint_inline_sync={s['sync_maint_inline_s']:.2f}s;"
        f"maint_inline_async={s['async_maint_inline_s']:.2f}s;"
        f"overlap_frac={s['async_overlap_frac']:.2f}"
    )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
