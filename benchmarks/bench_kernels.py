"""Kernel-level microbench: centroid navigation + posting scan hot paths.

Wall-times the XLA CPU paths (the Pallas kernels target TPU and are
validated in interpret mode by tests); derived column reports the
bytes/flops the op moves — the roofline quantities the TPU kernels are
tiled for — plus the batch-dedup scan saving (beyond-paper opt #4)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lire
from repro.core.index import SPFreshIndex
from benchmarks.common import bench_cfg
from repro.data.vectors import make_sift_like


def _timeit(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(quick: bool = True) -> list[str]:
    n = 8000 if quick else 100000
    dim = 16
    base = make_sift_like(n, dim, seed=51)
    idx = SPFreshIndex.build(bench_cfg(num_blocks=16384), base)
    state = idx.state
    rng = np.random.default_rng(52)
    queries = jnp.asarray(base[rng.integers(0, n, 256)])

    out = []

    # navigation (l2_topk target)
    nav = jax.jit(lambda s, q: lire.navigate(s, q, 8))
    t = _timeit(nav, state, queries)
    p = int(np.asarray(state.centroid_valid).sum())
    nav_flops = 2 * 256 * p * dim
    out.append(
        f"kernel/navigate,{t * 1e6:.1f},"
        f"flops={nav_flops};centroids={p}"
    )

    # posting scan (posting_scan target) — full search minus navigation
    srch = jax.jit(lambda s, q: lire.search(s, q, k=10, nprobe=8))
    t_all = _timeit(srch, state, queries)
    cap = state.cfg.posting_capacity
    scan_bytes = 256 * 8 * cap * dim * 4
    out.append(
        f"kernel/search_e2e,{t_all * 1e6:.1f},"
        f"scan_bytes={scan_bytes};probe=8"
    )

    # batch-dedup saving: unique postings probed by the batch vs total probes
    _, pids = lire.navigate(state, queries, 8)
    pids = np.asarray(pids)
    uniq = len(np.unique(pids[pids >= 0]))
    total = int((pids >= 0).sum())
    out.append(
        f"kernel/batch_dedup,0.0,"
        f"unique_postings={uniq};total_probes={total};"
        f"hbm_saving={total / max(uniq, 1):.2f}x"
    )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
