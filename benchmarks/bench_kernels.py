"""Kernel-level microbench: centroid navigation + posting scan hot paths.

Wall-times the XLA CPU paths and the Pallas posting-scan kernels in
interpret mode (the compiled kernels target TPU); every scan row reports
the *effective HBM bytes per query* of its schedule next to the wall time
— the traffic model the paged kernels are tiled for:

    oracle       Q·nprobe·MB pages gathered (full fixed-capacity buffers)
    per_query    only present pages, once per (query, probe)
    batched      each micro-batch-unique page once (÷ probe multiplicity)

Also times the dedup-top-k reduce rewrite against the old lexsort
reference (same candidate arrays)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lire
from repro.core.distance import MASK_DISTANCE
from repro.core.index import SPFreshIndex
from benchmarks.common import bench_cfg
from repro.data.vectors import make_sift_like


def _timeit(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(quick: bool = True) -> list[str]:
    n = 8000 if quick else 100000
    dim = 16
    nprobe = 8
    base = make_sift_like(n, dim, seed=51)
    idx = SPFreshIndex.build(bench_cfg(num_blocks=16384), base)
    state = idx.state
    cfg = state.cfg
    rng = np.random.default_rng(52)
    q_n = 256
    queries = jnp.asarray(base[rng.integers(0, n, q_n)])

    out = []

    # navigation (l2_topk target)
    nav = jax.jit(lambda s, q: lire.navigate(s, q, nprobe))
    t = _timeit(nav, state, queries)
    p = int(np.asarray(state.centroid_valid).sum())
    nav_flops = 2 * q_n * p * dim
    out.append(
        f"kernel/navigate,{t * 1e6:.1f},"
        f"flops={nav_flops};centroids={p}"
    )

    # --- scan traffic model (shared by every schedule row below) ---
    # pallas rows use a smaller query batch: interpret mode executes the
    # page grid sequentially on CPU, so Q=256 would take minutes; the
    # bytes/query model is Q-normalized either way
    from benchmarks.common import scan_traffic

    pq_n = 32
    pqueries = queries[:pq_n]
    traffic = scan_traffic(state, pqueries, nprobe)
    table = traffic["page_table"]
    present = table >= 0
    total_pages = traffic["total_pages"]
    uniq_pages = traffic["unique_pages"]
    page_bytes = traffic["page_bytes"]
    mb = cfg.max_blocks_per_posting

    def bpq(pages: float) -> float:
        return pages * page_bytes / pq_n

    # full search, oracle gather path
    srch = jax.jit(lambda s, q: lire.search(s, q, k=10, nprobe=nprobe))
    t_all = _timeit(srch, state, queries)
    out.append(
        f"kernel/search_e2e_oracle,{t_all * 1e6:.1f},"
        f"hbm_bytes_per_query={page_bytes * nprobe * mb:.0f};probe={nprobe}"
    )

    # full search, Pallas paged schedules (interpret mode on CPU — the
    # wall time is the interpreter's, the bytes/query column is the model
    # the TPU kernel realizes)
    for sched, pages in (("per_query", total_pages), ("batched", uniq_pages)):
        f = jax.jit(lambda s, q, sched=sched: lire.search(
            s, q, k=10, nprobe=nprobe,
            use_pallas_scan=True, scan_schedule=sched,
        ))
        t_s = _timeit(f, state, pqueries, reps=2)
        out.append(
            f"kernel/search_e2e_pallas_{sched},{t_s * 1e6:.1f},"
            f"hbm_bytes_per_query={bpq(pages):.0f};probe={nprobe}"
        )

    # raw per-page top-k kernel variants (scan only, no navigation/reduce)
    from repro.kernels.posting_scan import ops as scan_ops

    flat = jnp.asarray(np.where(present, table, -1))
    pvids, live = lire._page_slot_live(state, flat)
    kpage = min(10, cfg.block_size)  # per-page k, clamped like the search path
    pq = jax.jit(lambda q: scan_ops.scan_posting_blocks_topk(
        q, flat, live, state.pool.blocks, k=kpage, interpret=True))
    t_pq = _timeit(pq, pqueries, reps=2)
    out.append(
        f"kernel/scan_per_query_topk,{t_pq * 1e6:.1f},"
        f"hbm_bytes_per_query={bpq(total_pages):.0f};pages={total_pages}"
    )
    budget = int(2 ** np.ceil(np.log2(max(uniq_pages, 2))))
    uniqb, _, _, _ = scan_ops.dedup_pages(
        flat.reshape(-1), budget=budget, num_blocks=cfg.num_blocks
    )
    _, ulive = lire._page_slot_live(state, uniqb)
    bt = jax.jit(lambda q: scan_ops.scan_unique_blocks_topk(
        q, uniqb, ulive, state.pool.blocks, k=kpage, interpret=True))
    t_bt = _timeit(bt, pqueries, reps=2)
    out.append(
        f"kernel/scan_batched_topk,{t_bt * 1e6:.1f},"
        f"hbm_bytes_per_query={bpq(uniq_pages):.0f};pages={uniq_pages}"
    )

    # batch-dedup saving: unique postings probed by the batch vs total probes
    out.append(
        f"kernel/batch_dedup,0.0,"
        f"unique_pages={uniq_pages};total_pages={total_pages};"
        f"hbm_saving={total_pages / max(uniq_pages, 1):.2f}x"
    )

    # dedup-top-k reduce: lexsort reference vs top_k-prefilter rewrite
    cand = nprobe * cfg.posting_capacity
    d = jnp.asarray(rng.random((q_n, cand)), jnp.float32)
    v = jnp.asarray(rng.integers(0, n, (q_n, cand)), jnp.int32)
    m = jnp.asarray(rng.random((q_n, cand)) < 0.9)
    dm = jnp.where(m, d, MASK_DISTANCE)
    ref = jax.jit(jax.vmap(
        lambda a, b, c: lire._dedup_topk_1d_ref(a, b, c, 10)))
    new = jax.jit(jax.vmap(
        lambda a, b, c: lire._dedup_topk_1d(
            a, b, c, 10, lire._dedup_prefilter(cfg, 10, cand))))
    t_ref = _timeit(ref, dm, v, m)
    t_new = _timeit(new, dm, v, m)
    out.append(
        f"kernel/dedup_topk_lexsort_ref,{t_ref * 1e6:.1f},candidates={cand}"
    )
    out.append(
        f"kernel/dedup_topk_prefilter,{t_new * 1e6:.1f},"
        f"candidates={cand};speedup={t_ref / max(t_new, 1e-12):.2f}x"
    )

    # reassign same-vid dedup (maintenance round, _execute_reassigns):
    # O(n²) pairwise mask reference vs the sort-based first-occurrence
    # rewrite — n is the fused round's reassign budget (2·K·budget rows)
    for rows in (256, 2048):
        vids_r = jnp.asarray(
            rng.integers(0, max(rows // 4, 1), rows), jnp.int32
        )
        mask_r = jnp.asarray(rng.random(rows) < 0.7)
        refm = jax.jit(lire._dedup_vid_mask_ref)
        newm = jax.jit(lire._dedup_vid_mask)
        t_rm = _timeit(refm, vids_r, mask_r)
        t_nm = _timeit(newm, vids_r, mask_r)
        out.append(
            f"kernel/reassign_dedup_pairwise_ref,{t_rm * 1e6:.1f},rows={rows}"
        )
        out.append(
            f"kernel/reassign_dedup_sort,{t_nm * 1e6:.1f},"
            f"rows={rows};speedup={t_rm / max(t_nm, 1e-12):.2f}x"
        )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
