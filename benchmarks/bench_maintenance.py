"""Batched LIRE maintenance: round drain vs sequential step drain.

The Local Rebuilder must keep pace with a 1%-daily update firehose using
a sliver of compute (paper §5.2, Fig. 7/9).  The sequential driver pays
a full-centroid GEMM, a ``reassign_range`` neighbor gather, a ``route``
pass, and a device→host bool sync PER JOB; ``lire.maintenance_round``
amortizes all four over the round's K jobs (one wide GEMM, one batched
block scatter, one fused reassign pass, one did-work readback).

Rows report drain wall-clock to quiescence on a hot-region churn
workload, splits/sec and reassigns/sec, host syncs paid, and the
engine-level insert stall (serve-path time burned in backpressure slots).

``python -m benchmarks.run --json BENCH_update.json`` writes the
machine-readable report tracked across PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg
from repro.core import lire
from repro.core.index import SPFreshIndex, build_state
from repro.serve.engine import EngineConfig, LocalBackend, ServeEngine
from repro.serve.policy import RatioPolicy


def _churned_state(n: int, seed: int = 33, jobs_per_round: int = 4):
    """Build + hot-region inserts + clustered deletes, NO maintenance:
    the rebuild backlog the drains race on."""
    cfg = bench_cfg(num_blocks=16384, jobs_per_round=jobs_per_round)
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(12, 16)) * 5
    base = (
        centers[rng.integers(0, 12, n)] + rng.normal(size=(n, 16))
    ).astype(np.float32)
    state = build_state(cfg, base)

    # Hot inserts around several centers (oversize postings, no splits yet).
    hot = n // 5
    picks = rng.integers(0, 4, hot)
    ins = (
        centers[picks] + 0.05 * rng.normal(size=(hot, 16))
    ).astype(np.float32)
    idx = SPFreshIndex(state)
    idx.insert(ins, np.arange(n, n + hot, dtype=np.int32), max_retries=0)
    # Clustered deletes (undersize postings for the merge path).
    d = ((base - centers[8]) ** 2).sum(-1)
    victims = np.argsort(d)[: n // 6].astype(np.int32)
    idx.delete(victims)
    return idx.state, {"n": n, "hot_inserts": hot, "deletes": len(victims)}


def _copy_state(state):
    """Deep-copy the device buffers: several drain variants run donating
    executables, which would delete the shared start state."""
    return jax.tree_util.tree_map(jnp.copy, state)


def _stats_of(state) -> dict:
    return {
        k: int(getattr(state.stats, k))
        for k in ("n_splits", "n_merges", "n_gc_writebacks", "n_reassigned")
    }


def _delta(a: dict, b: dict) -> dict:
    return {k: b[k] - a[k] for k in a}


def _seq_step_drain(state):
    """The pre-round driver: one bool device→host sync per split+merge step."""
    jobs = 0
    syncs = 0
    for _ in range(2 * state.cfg.num_postings_cap):
        state, did = lire.maintenance_step(state)
        syncs += 1
        jobs += 1
        if not bool(did):
            break
    return state, jobs, syncs


def _seq_fused_drain(state, budget: int = 8):
    """PR-1 production path: lax.scan of `budget` sequential steps per
    dispatch, one count readback per slot."""
    from repro.core.index import fused_maintenance_step

    step = fused_maintenance_step(budget)
    jobs = 0
    syncs = 0
    for _ in range(2 * state.cfg.num_postings_cap // budget + 1):
        state, did = step(state)
        syncs += 1
        d = int(did)
        jobs += d
        if d == 0:
            break
    return state, jobs, syncs


def _round_drain(state, jobs_per_round: int):
    # donate: the bench hands each drain its own state copy
    state, jobs, rounds = lire.rebuild_drain(
        state, jobs_per_round=jobs_per_round, donate=True
    )
    return state, jobs, rounds


def _timed_drain(drain, state0, **kw):
    """Warm the jit cache with one full drain, then time a second from a
    fresh copy of the start state (copies happen outside the timer)."""
    out = drain(_copy_state(state0), **kw)
    jax.block_until_ready(out[0].pool.posting_len)
    before = _stats_of(state0)
    start = _copy_state(state0)
    jax.block_until_ready(start.pool.posting_len)
    t0 = time.perf_counter()
    state, jobs, syncs = drain(start, **kw)
    jax.block_until_ready(state.pool.posting_len)
    dt = time.perf_counter() - t0
    d = _delta(before, _stats_of(state))
    return {
        "wall_s": dt,
        "jobs": jobs,
        "syncs": syncs,
        "splits": d["n_splits"],
        "merges": d["n_merges"],
        "gc_writebacks": d["n_gc_writebacks"],
        "reassigned": d["n_reassigned"],
        "splits_per_s": d["n_splits"] / dt if dt > 0 else 0.0,
        "reassigns_per_s": d["n_reassigned"] / dt if dt > 0 else 0.0,
    }


class _SeqBackend(LocalBackend):
    """LocalBackend whose maintenance slots run the SEQUENTIAL fused step
    (the PR-1 path) instead of the batched round — the insert-stall
    baseline."""

    def maintain(self, jobs):
        return self.index.maintain_fused_seq(jobs)


def _insert_stall(state0, *, seq: bool, jobs: int, seed: int = 77) -> dict:
    """Hot-region insert stream under churn: total insert wall time and
    the slice of it burned in backpressure maintenance slots."""
    rng = np.random.default_rng(seed)
    idx = SPFreshIndex(_copy_state(state0))
    backend = _SeqBackend(idx) if seq else LocalBackend(idx)
    engine = ServeEngine(
        backend,
        EngineConfig(search_k=10, maintain_budget=jobs, max_batch=128),
        policy=RatioPolicy(ratio=2, budget=jobs),
    )
    hot = np.asarray(state0.centroids)[np.asarray(state0.centroid_valid)][0]
    n_ins = 384
    vecs = (hot[None, :] + 0.05 * rng.normal(size=(n_ins, 16))).astype(
        np.float32
    )
    vids = np.arange(50_000, 50_000 + n_ins, dtype=np.int32)
    # warm the compile caches (insert step AND the maintenance executable)
    # outside the timed window
    engine.insert(vecs[:8], vids[:8])
    backend.maintain(jobs)
    t0 = time.perf_counter()
    for s in range(8, n_ins, 128):
        engine.insert(vecs[s : s + 128], vids[s : s + 128])
    wall = time.perf_counter() - t0
    rep = engine.report()
    return {
        "insert_wall_s": wall,
        "stall_s": rep["insert_stall_s"],
        "retries": rep["insert_retries"],
        "maint_slots": rep["maintenance"]["slots"],
        "maint_jobs": rep["maintenance"]["steps"],
    }


def run_json(quick: bool = True) -> dict:
    n = 6000 if quick else 40000
    state0, wl = _churned_state(n)
    lens = np.asarray(state0.pool.posting_len)
    valid = np.asarray(state0.centroid_valid)
    wl["backlog_oversized"] = int(
        ((lens > state0.cfg.split_limit) & valid).sum()
    )
    wl["backlog_undersized"] = int(
        ((lens < state0.cfg.merge_limit) & valid).sum()
    )

    seq = _timed_drain(lambda s: _seq_step_drain(s), state0)
    seq_fused = _timed_drain(lambda s: _seq_fused_drain(s, budget=8), state0)
    rounds = {}
    for j in (4, 8):
        r = _timed_drain(lambda s, j=j: _round_drain(s, j), state0)
        r["rounds"] = r.pop("syncs")
        rounds[str(j)] = r

    stall_seq = _insert_stall(state0, seq=True, jobs=8)
    stall_round = _insert_stall(state0, seq=False, jobs=8)

    return {
        "bench": "maintenance",
        "quick": quick,
        "workload": wl,
        "sequential_step_drain": seq,
        "sequential_fused_drain_b8": seq_fused,
        "round_drain": rounds,
        "round_speedup_vs_step": {
            j: seq["wall_s"] / max(r["wall_s"], 1e-9)
            for j, r in rounds.items()
        },
        "round_speedup_vs_fused": {
            j: seq_fused["wall_s"] / max(r["wall_s"], 1e-9)
            for j, r in rounds.items()
        },
        "insert_stall": {
            "sequential_b8": stall_seq,
            "round_j8": stall_round,
            "stall_reduction": stall_seq["stall_s"]
            / max(stall_round["stall_s"], 1e-9),
        },
    }


def run(quick: bool = True) -> list[str]:
    rep = run_json(quick=quick)
    out = []

    def drain_row(name, r, extra=""):
        out.append(
            f"maintenance/{name},{r['wall_s'] * 1e6:.1f},"
            f"jobs={r['jobs']};splits={r['splits']};merges={r['merges']};"
            f"reassigned={r['reassigned']};"
            f"splits_per_s={r['splits_per_s']:.1f};"
            f"reassigns_per_s={r['reassigns_per_s']:.1f}{extra}"
        )

    seq = rep["sequential_step_drain"]
    drain_row("seq_step_drain", seq, f";syncs={seq['syncs']}")
    sf = rep["sequential_fused_drain_b8"]
    drain_row("seq_fused_drain_b8", sf, f";syncs={sf['syncs']}")
    for j, r in rep["round_drain"].items():
        sp = rep["round_speedup_vs_step"][j]
        drain_row(
            f"round_drain_j{j}", r,
            f";rounds={r['rounds']};speedup_vs_step={sp:.2f}x",
        )
    for name, s in (
        ("insert_stall_seq_b8", rep["insert_stall"]["sequential_b8"]),
        ("insert_stall_round_j8", rep["insert_stall"]["round_j8"]),
    ):
        out.append(
            f"maintenance/{name},{s['insert_wall_s'] * 1e6:.1f},"
            f"stall_s={s['stall_s']:.3f};retries={s['retries']};"
            f"maint_slots={s['maint_slots']};maint_jobs={s['maint_jobs']}"
        )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
