"""Search data-path benchmark: XLA gather oracle vs Pallas paged scan
(per-query and batch-dedup schedules).

Reports wall-clock latency percentiles AND the modeled HBM scan traffic —
the quantity the paged kernels are built to minimize:

* **oracle**      — `bp.parallel_get` gathers the full fixed-capacity
  probe buffer: ``Q · nprobe · MB`` pages regardless of occupancy.
* **per_query**   — streams only *present* pages, once per (query, probe):
  ``sum_q |pages(q)|`` page transfers.
* **batched**     — streams each micro-batch-unique page ONCE:
  ``|union_q pages(q)|`` transfers; traffic divides by the average probe
  multiplicity (how many queries probe the same page).

``run_json`` emits the machine-readable BENCH_search.json payload that
``python -m benchmarks.run --json`` writes, so the perf trajectory is
tracked across PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg
from repro.core import lire
from repro.core.index import SPFreshIndex
from repro.data.vectors import make_sift_like

# (label, search kwargs) — the three data paths under test
PATHS = (
    ("oracle", dict()),
    ("pallas_per_query",
     dict(use_pallas_scan=True, scan_schedule="per_query")),
    ("pallas_batched",
     dict(use_pallas_scan=True, scan_schedule="batched")),
)

# (codec, rerank_factor) cells: lossy codecs over-fetch rerank_factor×k
# quantized candidates and rerank them against the exact fp32 tier
CODEC_CELLS = (("fp32", 1), ("bf16", 4), ("int8", 4))


def _build(quick: bool, codec: str = "fp32", rerank_factor: int = 1):
    n = 6000 if quick else 60000
    dim = 16
    base = make_sift_like(n, dim, seed=71)
    idx = SPFreshIndex.build(
        bench_cfg(num_blocks=16384, num_postings_cap=2048,
                  num_vectors_cap=max(65536, 2 * n),
                  codec=codec, rerank_factor=rerank_factor),
        base,
    )
    rng = np.random.default_rng(72)
    q_n = 32 if quick else 256
    # serving-shaped query mix: half uniform, half from a few hot spots
    # (trending-content skew) — probe multiplicity comes from the skew
    uni = base[rng.integers(0, n, q_n // 2)]
    hot_centers = base[rng.integers(0, n, 4)]
    hot = hot_centers[rng.integers(0, 4, q_n - q_n // 2)]
    queries = np.concatenate([uni, hot]) \
        + 0.02 * rng.normal(size=(q_n, dim)).astype(np.float32)
    return idx, jnp.asarray(queries, jnp.float32), base


def _traffic_model(state, queries, nprobe: int) -> dict:
    """Pages touched per schedule on this workload + probe multiplicity."""
    from benchmarks.common import scan_traffic

    t = scan_traffic(state, queries, nprobe)
    q_n = t["q_n"]
    return {
        "page_bytes": t["page_bytes"],
        "probe_multiplicity": t["probe_multiplicity"],
        "pages_per_query": {
            "oracle": t["oracle_pages"] / q_n,
            "pallas_per_query": t["total_pages"] / q_n,
            "pallas_batched": t["unique_pages"] / q_n,
        },
    }


def _timed(fn, reps: int) -> dict:
    jax.block_until_ready(fn())  # compile
    lats = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        lats.append((time.perf_counter() - t0) * 1e3)
    arr = np.asarray(lats)
    return {
        "mean_ms": float(arr.mean()),
        "p50_ms": float(np.percentile(arr, 50)),
        "p99_ms": float(np.percentile(arr, 99)),
    }


def _codec_cell(state, queries, gt, nprobe: int, k: int) -> dict:
    """One per-codec BENCH cell: the traffic model's page bytes (actual
    hot-tier payload itemsize + scale/zero DMA) and recall@k through the
    quantized batched Pallas path (rerank included when configured)."""
    from benchmarks.common import scan_traffic

    t = scan_traffic(state, queries, nprobe)
    _, got = lire.search(
        state, queries, k=k, nprobe=nprobe,
        use_pallas_scan=True, scan_schedule="batched",
    )
    got = np.asarray(got)
    hits = sum(
        len(set(a.tolist()) & set(b.tolist())) for a, b in zip(gt, got)
    )
    ppq = t["unique_pages"] / t["q_n"]
    return {
        "page_bytes": t["page_bytes"],
        "pages_per_query": ppq,
        "scan_bytes_per_query": ppq * t["page_bytes"],
        "recall_at_k": hits / gt.size,
    }


def run_json(quick: bool = True) -> dict:
    idx, queries, base = _build(quick)
    state = idx.state
    nprobe = 8
    k = 10
    reps = 10 if quick else 30
    model = _traffic_model(state, queries, nprobe)
    page_bytes = model["page_bytes"]

    # batched-schedule page accounting (overflow > 0 = budget dropped pages)
    pstats = {
        kk: int(v) for kk, v in
        lire.scan_page_stats(state, queries, nprobe=nprobe).items()
    }

    out = {
        "workload": {
            "q": int(queries.shape[0]),
            "dim": state.cfg.dim,
            "nprobe": nprobe,
            "k": k,
            "block_size": state.cfg.block_size,
            "page_bytes": page_bytes,
            "n_postings": int(np.asarray(state.n_postings)),
        },
        "probe_multiplicity": model["probe_multiplicity"],
        "page_dedup": pstats,
        "paths": {},
    }
    for label, kw in PATHS:
        fn = lambda kw=kw: lire.search(
            state, queries, k=k, nprobe=nprobe, **kw
        )
        lat = _timed(fn, reps)
        ppq = model["pages_per_query"][label]
        out["paths"][label] = {
            **lat,
            "pages_per_query": ppq,
            "scan_bytes_per_query": ppq * page_bytes,
            "scan_gb_per_query": ppq * page_bytes / 1e9,
        }
    b = out["paths"]["pallas_batched"]["scan_bytes_per_query"]
    p = out["paths"]["pallas_per_query"]["scan_bytes_per_query"]
    out["batched_traffic_saving"] = p / max(b, 1e-12)

    # per-codec cells: same workload + probe/page budgets, hot tier
    # re-encoded per codec; savings/recall compared against the fp32 cell
    from benchmarks.common import brute_force_gt

    gt = brute_force_gt(
        np.asarray(queries), base, np.arange(len(base)), k=k
    )
    cells: dict[str, dict] = {}
    for codec, rf in CODEC_CELLS:
        st = state if codec == "fp32" else _build(
            quick, codec=codec, rerank_factor=rf
        )[0].state
        cells[codec] = {
            "rerank_factor": rf,
            **_codec_cell(st, queries, gt, nprobe, k),
        }
    fp = cells["fp32"]
    for cell in cells.values():
        cell["scan_bytes_saving_vs_fp32"] = (
            fp["scan_bytes_per_query"]
            / max(cell["scan_bytes_per_query"], 1e-12)
        )
        cell["recall_delta_vs_fp32"] = (
            cell["recall_at_k"] - fp["recall_at_k"]
        )
    out["codecs"] = cells
    return out


def run(quick: bool = True) -> list[str]:
    res = run_json(quick)
    lines = []
    for label, r in res["paths"].items():
        lines.append(
            f"search_path/{label},{r['mean_ms'] * 1e3:.1f},"
            f"p50_ms={r['p50_ms']:.3f};p99_ms={r['p99_ms']:.3f};"
            f"scan_bytes_per_query={r['scan_bytes_per_query']:.0f}"
        )
    lines.append(
        "search_path/traffic,0.0,"
        f"probe_multiplicity={res['probe_multiplicity']:.2f}x;"
        f"batched_saving={res['batched_traffic_saving']:.2f}x"
    )
    for codec, c in res["codecs"].items():
        lines.append(
            f"search_path/codec_{codec},0.0,"
            f"scan_bytes_per_query={c['scan_bytes_per_query']:.0f};"
            f"saving_vs_fp32={c['scan_bytes_saving_vs_fp32']:.2f}x;"
            f"recall_delta={c['recall_delta_vs_fp32']:+.4f}"
        )
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
