"""Paper Fig. 11: reassign-range parameter study.

Recall after a shifted update workload as a function of the number of
nearby postings checked by LIRE reassignment (0 = only the split posting).
The paper finds diminishing returns by 64 (at their billion scale); the
same saturation shows up here at smaller ranges for smaller indexes.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_cfg, recall_at
from repro.core.index import SPFreshIndex
from repro.data.vectors import make_shifting_stream, make_sift_like


def run(quick: bool = True) -> list[str]:
    n_base = 4000 if quick else 20000
    n_ins = 2000 if quick else 10000
    dim = 16
    base = make_sift_like(n_base, dim, seed=11)
    inserts = make_shifting_stream(n_ins, dim, seed=12)
    all_vecs = np.concatenate([base, inserts])
    all_ids = np.arange(len(all_vecs))
    rng = np.random.default_rng(13)
    qsel = rng.integers(n_base, len(all_vecs), size=128)
    queries = all_vecs[qsel] + 0.01 * rng.normal(size=(128, dim)).astype(np.float32)
    d = ((queries[:, None, :] - all_vecs[None]) ** 2).sum(-1)
    gt = all_ids[np.argsort(d, axis=1)[:, :10]]
    ins_ids = np.arange(n_base, len(all_vecs)).astype(np.int32)

    ranges = [0, 1, 2, 4, 8, 16] if quick else [0, 1, 2, 4, 8, 16, 32, 64]
    out = []
    for rr in ranges:
        idx = SPFreshIndex.build(bench_cfg(reassign_range=max(rr, 1)), base)
        if rr == 0:
            # range 0 = only the split posting itself: neighbor scan disabled
            idx = SPFreshIndex.build(bench_cfg(reassign_range=1), base)
        idx.insert(inserts, ins_ids)
        idx.maintain()
        r = recall_at(idx, queries, gt)
        st = idx.stats()
        out.append(
            f"reassign_range/{rr},0.0,"
            f"recall={r:.4f};checked={st['n_reassign_checked']};"
            f"reassigned={st['n_reassigned']}"
        )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
