"""Scenario gauntlet — four reproducible serving scenarios, all driven
through the unified Service API (``spfresh.open``), each emitting a
recall@10 / latency-over-time series plus maintenance-job accounting.

Cells (fixed seeds; the gate tests re-run tiny-N versions of each):

  * **burst** — bursty insert flood: quiet trickle punctuated by large
    insert bursts; recall dips at each burst and the budgeted rounds
    claw it back.
  * **shift** — adversarial centroid shift: a queried hot region drifts
    every step while an unqueried cold region floods the longest
    postings.  Run TWICE at the SAME explicit jobs-per-round budget —
    ``policy="size"`` vs ``policy="drift"`` — the drift-aware cost model
    spends the budget on the hot drifting postings instead of the cold
    flood, so its recall curve dominates (the PR's headline claim).
  * **churn** — TTL/churn stream: a sliding live window (insert N, delete
    the N oldest) with live-set conservation checked host-side.
  * **skew** — Zipfian skewed reads: a heavy-tailed query mix over a
    skewed index; access telemetry concentrates and the drift policy's
    accounting shows where the budget went.

Background maintenance slots are suppressed (backlog policy with an
unreachable threshold) so the job accounting is EXACTLY the explicit
per-step budget — the size-vs-drift comparison is at equal rounds.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    bench_cfg,
    brute_force_gt,
    service_recall,
)
from repro.data.vectors import make_sift_like, make_spacev_like


def _open_service(policy: str | None = None, alpha: float | None = None,
                  beta: float | None = None, vectors: np.ndarray | None = None,
                  **cfg_kw):
    import spfresh

    cfg = bench_cfg(max_blocks_per_posting=16, **cfg_kw)
    spec = spfresh.ServiceSpec(
        index=spfresh.IndexSpec(config=cfg),
        serve=spfresh.ServeSpec(
            search_k=10,
            # no background slots: maintenance happens ONLY via the
            # explicit per-step budget, so job accounting is exact
            policy="backlog", backlog_threshold=1 << 30,
            max_insert_retries=0,
        ),
        maintenance=spfresh.MaintenanceSpec(
            policy=policy, alpha=alpha, beta=beta,
        ),
    )
    return spfresh.open(spec, vectors=vectors, fresh=True)


class _LiveSet:
    """Host-side ground-truth ledger: vid -> vector, insertion-ordered."""

    def __init__(self, vecs: np.ndarray, ids: np.ndarray):
        self._d: dict[int, np.ndarray] = {
            int(i): v for i, v in zip(ids, vecs)
        }

    def add(self, vecs: np.ndarray, ids: np.ndarray,
            landed: np.ndarray | None = None) -> None:
        for j, (i, v) in enumerate(zip(ids, vecs)):
            if landed is None or bool(landed[j]):
                self._d[int(i)] = v

    def remove(self, ids: np.ndarray) -> None:
        for i in ids:
            self._d.pop(int(i), None)

    def oldest(self, n: int) -> np.ndarray:
        return np.asarray(list(self._d.keys())[:n], np.int64)

    def __len__(self) -> int:
        return len(self._d)

    def arrays(self) -> tuple[np.ndarray, np.ndarray]:
        ids = np.fromiter(self._d.keys(), dtype=np.int64)
        vecs = np.stack([self._d[int(i)] for i in ids]) if len(ids) \
            else np.zeros((0, 16), np.float32)
        return vecs, ids


def _step_series() -> dict:
    return {"step": [], "recall": [], "search_ms": [], "jobs": [],
            "n_live": [], "n_postings": []}


def _record(series: dict, step: int, recall: float, search_ms: float,
            jobs: int, live: int, svc) -> None:
    series["step"].append(step)
    series["recall"].append(round(float(recall), 4))
    series["search_ms"].append(round(float(search_ms), 3))
    series["jobs"].append(int(jobs))
    series["n_live"].append(int(live))
    series["n_postings"].append(int(svc.stats()["n_postings"]))


def _timed_recall(svc, queries, gt) -> tuple[float, float]:
    t0 = time.perf_counter()
    r = service_recall(svc, queries, gt)
    return r, (time.perf_counter() - t0) * 1e3 / len(queries)


# ---------------------------------------------------------------------------
# burst — bursty insert flood
# ---------------------------------------------------------------------------

def burst_cell(*, n_base: int = 2000, steps: int = 10, quiet: int = 100,
               burst: int = 800, burst_every: int = 4, jobs: int = 4,
               n_queries: int = 64, seed: int = 11) -> dict:
    rng = np.random.default_rng(seed)
    dim = 16
    base = make_sift_like(n_base, dim, seed=seed)
    svc = _open_service(policy="drift", alpha=2.0, vectors=base)
    live = _LiveSet(base, np.arange(n_base))
    next_vid = n_base
    series = _step_series()
    try:
        for t in range(steps):
            n_ins = burst if (t + 1) % burst_every == 0 else quiet
            vecs = make_sift_like(n_ins, dim, seed=seed + 100 + t)
            vids = np.arange(next_vid, next_vid + n_ins)
            next_vid += n_ins
            _, landed = svc.insert(vecs, vids.astype(np.int32))
            live.add(vecs, vids, landed)
            lv, li = live.arrays()
            q_src = rng.integers(0, len(lv), size=n_queries)
            q = lv[q_src] + 0.01 * rng.normal(
                size=(n_queries, dim)).astype(np.float32)
            gt = brute_force_gt(q, lv, li)
            r, ms = _timed_recall(svc, q, gt)
            done = svc.maintain(jobs)
            _record(series, t, r, ms, done, len(live), svc)
        stats = svc.stats()
    finally:
        svc.close()
    return {
        "series": series,
        "summary": {
            "final_recall": series["recall"][-1],
            "min_recall": min(series["recall"]),
            "total_jobs": sum(series["jobs"]),
            "n_splits": stats["n_splits"],
            "access_total": stats["access_total"],
        },
    }


# ---------------------------------------------------------------------------
# shift — adversarial centroid shift, size vs drift at equal budget
# ---------------------------------------------------------------------------

def shift_cell(*, policy: str = "size", alpha: float = 4.0,
               beta: float = 1.0, n_base: int = 1500, steps: int = 8,
               n_hot: int = 60, n_cold: int = 150, jobs: int = 1,
               n_queries: int = 48, drift_rate: float = 0.15,
               nprobe: int = 4, seed: int = 7) -> dict:
    """One policy's run of the shift scenario.  The workload is a pure
    function of the sizing args + seed, so ``size`` and ``drift`` runs
    see byte-identical streams — only the job selection differs.

    The queried hot region drifts and grows moderately; an unqueried
    cold region floods HARDER, so its postings are always the longest.
    At one job per round the size policy spends every round on the cold
    flood and the hot postings saturate; the drift policy's access boost
    sends the same budget to the hot postings instead.

    Ground truth covers every ATTEMPTED insert (the paper's freshness
    framing): an insert the index dropped because its target posting was
    full and never split is recall the maintenance policy failed to
    protect — exactly the failure the drift-aware budget prevents on the
    queried hot region."""
    rng = np.random.default_rng(seed)
    dim = 16
    base = make_sift_like(n_base, dim, seed=seed)
    # hot anchor at a real cluster; cold flood at the farthest one, so
    # the two streams land in disjoint posting sets
    hot_c = base[0]
    cold_c = base[int(np.argmax(((base - base[0]) ** 2).sum(-1)))]
    direction = rng.normal(size=(dim,)).astype(np.float32)
    direction /= np.linalg.norm(direction)
    svc = _open_service(policy=policy, alpha=alpha, beta=beta,
                        vectors=base, nprobe=nprobe, replica_count=1)
    live = _LiveSet(base, np.arange(n_base))
    next_vid = n_base
    series = _step_series()
    try:
        for t in range(steps):
            pos = hot_c + (t + 1) * drift_rate * direction
            hot = (pos + 0.05 * rng.normal(size=(n_hot, dim))
                   ).astype(np.float32)
            cold = (cold_c + 0.08 * rng.normal(size=(n_cold, dim))
                    ).astype(np.float32)
            vecs = np.concatenate([hot, cold])
            vids = np.arange(next_vid, next_vid + len(vecs))
            next_vid += len(vecs)
            svc.insert(vecs, vids.astype(np.int32))
            live.add(vecs, vids)  # attempted, not just landed
            # queries target ONLY the drifting hot region — the access
            # telemetry the drift policy ranks by
            q = (pos + 0.05 * rng.normal(size=(n_queries, dim))
                 ).astype(np.float32)
            lv, li = live.arrays()
            gt = brute_force_gt(q, lv, li)
            r, ms = _timed_recall(svc, q, gt)
            done = svc.maintain(jobs)
            _record(series, t, r, ms, done, len(live), svc)
        # one post-loop measurement so the LAST round's effect is seen
        q = (pos + 0.05 * rng.normal(size=(n_queries, dim))
             ).astype(np.float32)
        gt = brute_force_gt(q, *live.arrays())
        final_recall, _ = _timed_recall(svc, q, gt)
        stats = svc.stats()
    finally:
        svc.close()
    tail = series["recall"][-3:] + [round(float(final_recall), 4)]
    curve = series["recall"] + [round(float(final_recall), 4)]
    return {
        "series": series,
        "summary": {
            "policy": policy,
            "final_recall": round(float(final_recall), 4),
            # the headline metric: recall@10 integrated over the stream —
            # what a reader of the recall-over-time curve compares
            "mean_recall": round(float(np.mean(curve)), 4),
            "tail_recall_mean": round(float(np.mean(tail)), 4),
            "total_jobs": sum(series["jobs"]),
            "n_splits": stats["n_splits"],
            "access_total": stats["access_total"],
            "update_total": stats["update_total"],
        },
    }


def shift_compare(*, jobs: int = 1, **kw) -> dict:
    """The headline cell: size vs drift at equal jobs-per-round budget."""
    size = shift_cell(policy="size", jobs=jobs, **kw)
    drift = shift_cell(policy="drift", jobs=jobs, **kw)
    return {
        "jobs_per_round": jobs,
        "policies": {"size": size, "drift": drift},
        "drift_minus_size": round(
            drift["summary"]["mean_recall"]
            - size["summary"]["mean_recall"], 4
        ),
    }


# ---------------------------------------------------------------------------
# churn — TTL/churn stream (sliding live window)
# ---------------------------------------------------------------------------

def churn_cell(*, n_base: int = 2000, steps: int = 10, churn: int = 200,
               jobs: int = 2, n_queries: int = 64, seed: int = 23) -> dict:
    rng = np.random.default_rng(seed)
    dim = 16
    base = make_spacev_like(n_base, dim, seed=seed)
    svc = _open_service(policy="drift", alpha=1.0, vectors=base)
    live = _LiveSet(base, np.arange(n_base))
    next_vid = n_base
    series = _step_series()
    conserved = True
    deleted: set[int] = set()
    try:
        for t in range(steps):
            # TTL expiry: the CHURN oldest vids age out...
            dead = live.oldest(churn)
            svc.delete(dead.astype(np.int32))
            live.remove(dead)
            deleted.update(int(i) for i in dead)
            # ...and a fresh batch replaces them
            vecs = make_spacev_like(churn, dim, seed=seed + 100 + t)
            vids = np.arange(next_vid, next_vid + churn)
            next_vid += churn
            _, landed = svc.insert(vecs, vids.astype(np.int32))
            live.add(vecs, vids, landed)
            lv, li = live.arrays()
            q_src = rng.integers(0, len(lv), size=n_queries)
            q = lv[q_src] + 0.01 * rng.normal(
                size=(n_queries, dim)).astype(np.float32)
            gt = brute_force_gt(q, lv, li)
            r, ms = _timed_recall(svc, q, gt)
            # live-set conservation: no tombstoned vid may surface (a
            # replica of an un-"landed" insert legitimately can, so the
            # check is against the deleted set, not live membership)
            _, got = svc.search(q, k=10)
            leaked = [int(i) for i in np.unique(got)
                      if i >= 0 and int(i) in deleted]
            conserved = conserved and not leaked
            done = svc.maintain(jobs)
            _record(series, t, r, ms, done, len(live), svc)
        stats = svc.stats()
    finally:
        svc.close()
    return {
        "series": series,
        "summary": {
            "final_recall": series["recall"][-1],
            "live_set_conserved": bool(conserved),
            "total_jobs": sum(series["jobs"]),
            "n_merges": stats["n_merges"],
            "n_splits": stats["n_splits"],
        },
    }


# ---------------------------------------------------------------------------
# skew — Zipfian skewed reads
# ---------------------------------------------------------------------------

def skew_cell(*, n_base: int = 3000, steps: int = 8, n_queries: int = 96,
              trickle: int = 60, jobs: int = 2, zipf_a: float = 1.3,
              seed: int = 31) -> dict:
    rng = np.random.default_rng(seed)
    dim = 16
    base = make_spacev_like(n_base, dim, seed=seed)
    svc = _open_service(policy="drift", alpha=4.0, vectors=base)
    live = _LiveSet(base, np.arange(n_base))
    next_vid = n_base
    series = _step_series()
    try:
        for t in range(steps):
            vecs = make_spacev_like(trickle, dim, seed=seed + 100 + t)
            vids = np.arange(next_vid, next_vid + trickle)
            next_vid += trickle
            _, landed = svc.insert(vecs, vids.astype(np.int32))
            live.add(vecs, vids, landed)
            lv, li = live.arrays()
            # Zipfian read skew: rank-r row queried with weight 1/r^a
            ranks = np.minimum(
                rng.zipf(zipf_a, size=n_queries) - 1, len(lv) - 1
            )
            q = lv[ranks] + 0.01 * rng.normal(
                size=(n_queries, dim)).astype(np.float32)
            gt = brute_force_gt(q, lv, li)
            r, ms = _timed_recall(svc, q, gt)
            done = svc.maintain(jobs)
            _record(series, t, r, ms, done, len(live), svc)
        stats = svc.stats()
        # access concentration: top-5% postings' share of all probes
        tel = np.asarray(svc.index.state.telemetry.access_count)
        valid = np.asarray(svc.index.state.centroid_valid)
        acc = np.sort(tel[valid])[::-1]
        top = max(1, len(acc) // 20)
        conc = float(acc[:top].sum()) / max(float(acc.sum()), 1.0)
    finally:
        svc.close()
    return {
        "series": series,
        "summary": {
            "final_recall": series["recall"][-1],
            "access_top5pct_share": round(conc, 4),
            "access_total": stats["access_total"],
            "total_jobs": sum(series["jobs"]),
        },
    }


# ---------------------------------------------------------------------------
# harness entry points
# ---------------------------------------------------------------------------

def _sizes(quick: bool) -> dict:
    if quick:
        return {}
    return {
        "burst": dict(n_base=10000, steps=16, quiet=400, burst=3200),
        "shift": dict(n_base=8000, steps=12, n_hot=250, n_cold=600),
        "churn": dict(n_base=10000, steps=16, churn=800),
        "skew": dict(n_base=12000, steps=12, n_queries=128, trickle=200),
    }


def run_json(quick: bool = True) -> dict:
    sz = _sizes(quick)
    shift = shift_compare(**sz.get("shift", {}))
    return {
        "quick": bool(quick),
        "scenarios": {
            "burst": burst_cell(**sz.get("burst", {})),
            "shift": shift,
            "churn": churn_cell(**sz.get("churn", {})),
            "skew": skew_cell(**sz.get("skew", {})),
        },
    }


def run(quick: bool = True) -> list[str]:
    rep = run_json(quick)
    out = []
    for name, cell in rep["scenarios"].items():
        if name == "shift":
            for pol, sub in cell["policies"].items():
                s = sub["summary"]
                out.append(
                    f"scenarios/shift[{pol}],"
                    f"{np.mean(sub['series']['search_ms']) * 1e3:.1f},"
                    f"recall={s['mean_recall']:.3f};"
                    f"final={s['final_recall']:.3f};"
                    f"jobs={s['total_jobs']};splits={s['n_splits']}"
                )
            out.append(
                f"scenarios/shift_gap,0.0,"
                f"drift_minus_size={cell['drift_minus_size']:+.3f};"
                f"jobs_per_round={cell['jobs_per_round']}"
            )
            continue
        s = cell["summary"]
        derived = ";".join(
            f"{k}={v}" for k, v in s.items() if not isinstance(v, float)
        )
        rec = s.get("final_recall", 0.0)
        out.append(
            f"scenarios/{name},"
            f"{np.mean(cell['series']['search_ms']) * 1e3:.1f},"
            f"recall={rec:.3f};{derived}"
        )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
