"""Paper Fig. 12: foreground/background resource balance, measured on the
batched serving pipeline.

The paper tunes fg:bg *threads* (2:1 optimum).  The jit-world analogue is
the engine's MaintenancePolicy: RatioPolicy interleaves one fixed-budget
rebuilder slot every N foreground update batches (the feed-forward
pipeline); BacklogPolicy fires slots reactively when oversized postings
exist.  We run a mixed search+insert stream through the ServeEngine under
each policy and report per-op latency percentiles, insert throughput,
queue depth, padding waste, maintenance throughput, and the steady-state
rebuild backlog — balanced means max throughput with ~zero backlog.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_cfg
from repro.core.index import SPFreshIndex
from repro.data.vectors import make_shifting_stream, make_sift_like
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.policy import BacklogPolicy, RatioPolicy
from repro.serve.queue import INSERT, RequestQueue, Ticket, default_buckets


def _drive(engine: ServeEngine, inserts, queries, n_base: int,
           chunk: int = 256, q_chunk: int = 64) -> float:
    """Mixed stream: alternate one insert chunk and one search chunk
    through the async pipeline; returns the wall time."""
    ids = np.arange(n_base, n_base + len(inserts)).astype(np.int32)
    qi = 0
    t0 = time.perf_counter()
    for s in range(0, len(inserts), chunk):
        engine.submit_insert(inserts[s:s + chunk], ids[s:s + chunk])
        q = queries[qi:qi + q_chunk]
        qi = (qi + q_chunk) % max(len(queries) - q_chunk, 1)
        engine.submit_search(q)
        engine.pump()
    engine.pump()
    return time.perf_counter() - t0


def _bench_pop_batch(reuse: bool, rounds: int = 3000) -> float:
    """Host-side batch-formation cost: submit a 200-row insert request
    and pop it as one padded 256-bucket batch.  ``reuse=False`` is the
    old concatenate+pad path (one fresh allocation pair per batch);
    ``reuse=True`` copies into cached per-(op, bucket) staging buffers."""
    q = RequestQueue(default_buckets(8, 256), reuse_staging=reuse)
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(200, 16)).astype(np.float32)
    vids = np.arange(200, dtype=np.int32)
    t0 = time.perf_counter()
    for _ in range(rounds):
        t = Ticket(INSERT, 200, ())
        q.submit(t, {"vecs": vecs, "vids": vids})
        q.pop_batch()
    return (time.perf_counter() - t0) / rounds


def run(quick: bool = True) -> list[str]:
    n_base = 4000 if quick else 20000
    n_ins = 2000 if quick else 20000
    base = make_sift_like(n_base, 16, seed=31)
    inserts = make_shifting_stream(n_ins, 16, seed=32)
    queries = make_sift_like(512, 16, seed=33)
    policies = (
        ("off", lambda: RatioPolicy(ratio=0)),
        ("ratio_8to1", lambda: RatioPolicy(ratio=8, budget=4)),
        ("ratio_2to1", lambda: RatioPolicy(ratio=2, budget=8)),
        ("ratio_1to1", lambda: RatioPolicy(ratio=1, budget=16)),
        ("backlog_t1", lambda: BacklogPolicy(threshold=1, budget=16)),
    )
    out = []
    for label, make_policy in policies:
        idx = SPFreshIndex.build(bench_cfg(num_blocks=16384), base)
        eng = ServeEngine(
            idx, EngineConfig(search_k=10, max_batch=256),
            policy=make_policy(),
        )
        wall = _drive(eng, inserts, queries, n_base)
        rep = eng.report()
        ins, srch, qacc, maint = (
            rep["insert"], rep["search"], rep["queue"], rep["maintenance"]
        )
        out.append(
            f"pipeline/{label},{wall / n_ins * 1e6:.1f},"
            f"insert_qps={n_ins / wall:.0f};"
            f"ins_p50={ins['p50_ms']:.1f};ins_p99={ins['p99_ms']:.1f};"
            f"srch_p50={srch['p50_ms']:.1f};srch_p99={srch['p99_ms']:.1f};"
            f"depth_avg={qacc['depth_rows_avg']:.0f};"
            f"depth_max={qacc['depth_rows_max']};"
            f"pad_waste={qacc['padding_waste_frac']:.3f};"
            f"maint_sps={maint['steps_per_s']:.1f};"
            f"maint_steps={maint['steps']};"
            f"backlog={rep['backlog']};splits={idx.stats()['n_splits']}"
        )
    # per-batch host allocation: concat+pad (pre-staging) vs reused
    # per-(op, bucket) staging buffers
    rounds = 1000 if quick else 5000
    t_old = _bench_pop_batch(reuse=False, rounds=rounds)
    t_new = _bench_pop_batch(reuse=True, rounds=rounds)
    out.append(
        f"pipeline/pop_batch_concat_pad,{t_old * 1e6:.2f},staging=off"
    )
    out.append(
        f"pipeline/pop_batch_staging,{t_new * 1e6:.2f},"
        f"speedup={t_old / t_new:.2f}x"
    )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
