"""Paper Fig. 12: foreground/background resource balance.

The paper tunes fg:bg *threads* (2:1 optimum).  The jit-world analogue is
the engine's fg:bg *slot ratio* (foreground insert batches per background
maintenance slot).  We sweep the ratio and report insert throughput and the
rebuild backlog (oversized postings left waiting) — the pipeline is
balanced when throughput is maximal with ~zero steady-state backlog.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_cfg
from repro.core.index import SPFreshIndex
from repro.data.vectors import make_shifting_stream, make_sift_like
from repro.serve.engine import EngineConfig, ServeEngine


def run(quick: bool = True) -> list[str]:
    n_base = 4000 if quick else 20000
    n_ins = 2000 if quick else 20000
    base = make_sift_like(n_base, 16, seed=31)
    inserts = make_shifting_stream(n_ins, 16, seed=32)
    out = []
    for ratio, budget in ((0, 0), (8, 4), (4, 8), (2, 8), (1, 16)):
        idx = SPFreshIndex.build(bench_cfg(num_blocks=16384), base)
        eng = ServeEngine(
            idx,
            EngineConfig(fg_bg_ratio=max(ratio, 10**9) if ratio == 0 else ratio,
                         maintain_budget=budget),
        )
        t0 = time.perf_counter()
        ids = np.arange(n_base, n_base + n_ins).astype(np.int32)
        chunk = 256
        for s in range(0, n_ins, chunk):
            eng.insert(inserts[s:s + chunk], ids[s:s + chunk])
        wall = time.perf_counter() - t0
        lens = np.asarray(idx.state.pool.posting_len)
        valid = np.asarray(idx.state.centroid_valid)
        backlog = int(((lens > idx.state.cfg.split_limit) & valid).sum())
        label = "off" if ratio == 0 else f"{ratio}to1"
        out.append(
            f"pipeline/{label},{wall / n_ins * 1e6:.1f},"
            f"insert_qps={n_ins / wall:.0f};backlog={backlog};"
            f"splits={idx.stats()['n_splits']}"
        )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
