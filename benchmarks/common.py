"""Shared benchmark helpers."""
from __future__ import annotations

import time

import numpy as np

from repro.core.index import SPFreshIndex
from repro.core.types import LireConfig


def bench_cfg(**kw) -> LireConfig:
    args = dict(
        dim=16, block_size=8, max_blocks_per_posting=8, num_blocks=8192,
        num_postings_cap=1024, num_vectors_cap=65536, split_limit=48,
        merge_limit=6, reassign_range=8, reassign_budget=256,
        replica_count=2, nprobe=8,
    )
    args.update(kw)
    return LireConfig(**args)


def recall_at(index: SPFreshIndex, queries: np.ndarray, gt: np.ndarray,
              k: int = 10, nprobe: int | None = None) -> float:
    _, got = index.search(queries, k, nprobe=nprobe)
    hits = 0
    for row_gt, row_got in zip(gt, got):
        hits += len(set(row_gt.tolist()) & set(row_got.tolist()))
    return hits / (gt.shape[0] * gt.shape[1])


def timed_search(index: SPFreshIndex, queries: np.ndarray, k: int = 10,
                 nprobe: int | None = None, chunk: int = 64) -> dict:
    """Per-chunk search wall times (warm) → latency percentiles in ms."""
    # warmup/compile
    index.search(queries[:chunk], k, nprobe=nprobe)
    lats = []
    for s in range(0, len(queries), chunk):
        q = queries[s:s + chunk]
        if len(q) < chunk:
            break
        t0 = time.perf_counter()
        index.search(q, k, nprobe=nprobe)
        lats.append((time.perf_counter() - t0) * 1e3 / chunk)
    arr = np.asarray(lats) if lats else np.asarray([0.0])
    return {
        "mean_ms": float(arr.mean()),
        "p50_ms": float(np.percentile(arr, 50)),
        "p99_ms": float(np.percentile(arr, 99)),
    }


def posting_stats(index: SPFreshIndex) -> dict:
    lens = np.asarray(index.state.pool.posting_len)
    valid = np.asarray(index.state.centroid_valid)
    lv = lens[valid]
    return {
        "n_postings": int(valid.sum()),
        "max_len": int(lv.max()) if lv.size else 0,
        "mean_len": float(lv.mean()) if lv.size else 0.0,
        # tail-latency driver in the paper: candidates scanned per query
        "scan_cost_p99": float(np.percentile(lv, 99)) if lv.size else 0.0,
    }


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# ---------------------------------------------------------------------------
# Service-API helpers (benchmarks driven through ``spfresh.open``)
# ---------------------------------------------------------------------------

def brute_force_gt(queries: np.ndarray, vecs: np.ndarray, ids: np.ndarray,
                   k: int = 10) -> np.ndarray:
    """Exact k-NN ids over a host-tracked live set."""
    d = ((queries[:, None, :].astype(np.float32)
          - vecs[None].astype(np.float32)) ** 2).sum(-1)
    return np.asarray(ids)[np.argsort(d, axis=1)[:, :k]]


def service_recall(service, queries: np.ndarray, gt: np.ndarray,
                   k: int = 10) -> float:
    """recall@k through the serving surface (micro-batched search)."""
    _, got = service.search(queries, k=k)
    hits = 0
    for row_gt, row_got in zip(gt, got):
        hits += len(set(row_gt.tolist()) & set(row_got.tolist()))
    return hits / (gt.shape[0] * gt.shape[1])


def timed_service_search(service, queries: np.ndarray, k: int = 10,
                         chunk: int = 64) -> dict:
    """Per-chunk search wall times through the service → percentiles."""
    service.search(queries[:chunk], k=k)  # warmup/compile
    lats = []
    for s in range(0, len(queries), chunk):
        q = queries[s:s + chunk]
        if len(q) < chunk:
            break
        t0 = time.perf_counter()
        service.search(q, k=k)
        lats.append((time.perf_counter() - t0) * 1e3 / chunk)
    arr = np.asarray(lats) if lats else np.asarray([0.0])
    return {
        "mean_ms": float(arr.mean()),
        "p50_ms": float(np.percentile(arr, 50)),
        "p99_ms": float(np.percentile(arr, 99)),
    }


def scan_traffic(state, queries, nprobe: int) -> dict:
    """Page-granular scan traffic model for a query micro-batch — the
    quantities the paged posting-scan schedules move per query:

    oracle     ``Q·nprobe·MB`` pages (full fixed-capacity gather),
    per_query  present pages once per (query, probe) = ``total_pages``,
    batched    each batch-unique page once = ``unique_pages``.
    """
    from repro.core import lire
    from repro.core.distance import MASK_DISTANCE

    cfg = state.cfg
    nav_d, pids = lire.navigate(state, queries, nprobe)
    probe_valid = nav_d < MASK_DISTANCE / 2
    table = np.asarray(lire._page_table(state, pids, probe_valid))
    present = table >= 0
    total_pages = int(present.sum())
    unique_pages = len(np.unique(table[present]))
    q_n = table.shape[0]
    # Traffic is what the scan ACTUALLY moves: the pool's hot-tier payload
    # itemsize (int8 = 1 B, bf16 = 2 B — not the logical vector_dtype),
    # plus the per-page scale/zero-point pair that rides the DMA when the
    # payload is quantized.
    from repro.storage import codec as pcodec

    payload_item = np.dtype(state.pool.blocks.dtype).itemsize
    page_bytes = cfg.block_size * cfg.dim * payload_item
    if pcodec.is_quantized(state.pool.codec):
        page_bytes += 2 * 4  # f32 (scale, zero) per page
    return {
        "q_n": q_n,
        "page_table": table,
        "page_bytes": page_bytes,
        "total_pages": total_pages,
        "unique_pages": unique_pages,
        "oracle_pages": q_n * nprobe * cfg.max_blocks_per_posting,
        "probe_multiplicity": total_pages / max(unique_pages, 1),
    }
