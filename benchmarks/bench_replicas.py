"""Read-replica benchmark: throughput scaling, ack cost, and parity
(ROADMAP: read replicas + async WAL replication).

Three questions, answered honestly on this container:

1. **Read scaling vs replica count (shards fixed).**  This container has
   ONE core, so wall-clock read throughput cannot scale with replicas —
   replica search compute and WAL-replay compute timeshare the same CPU
   that runs the primary (the same limit PR 2 hit for scan traffic:
   modeled, not measured).  What CAN be measured is the substrate the
   scaling is made of: the pump-side cost of serving a search batch on
   the primary (dispatch + readback) vs the pump-side cost of *routing*
   it to a replica (a lock + staging-buffer copy), and a replica's own
   search service time.  ``modeled_multicore`` combines them: on a
   deployment with a core per replica, baseline read capacity is
   ``1/t_pump_search``; with R replicas the pump only pays ``t_route``
   per batch and capacity is ``min(R / t_search, 1 / t_route)``.  The
   measured open-loop cells (goodput at a latency SLO under a live
   update + maintenance stream) are reported alongside so the modeled
   claim is anchored to real end-to-end behavior: on one core the
   goodput ratio hovers near 1.0 while the p99 tail improves (routed
   searches stop queueing behind update/maintenance dispatches).

2. **Write-ack latency, replication on vs off.**  The publish sink is an
   in-memory window append (after the WAL fsync assigns the seqno), so
   acks should not move.  Measured as the median of closed-loop durable
   insert acks at a paced rate (the pacing gap lets the replica's replay
   run off the ack path, as it would on its own core).

3. **Bit-parity.**  After the loaded cell quiesces, ``wait_sync`` +
   ``states_equal`` checks the replica is bit-identical to the primary
   at equal WAL seqno (dirty-block checkpoint bookkeeping excluded).

Emits ``BENCH_replicas.json``.
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
import threading
import time

import numpy as np

from benchmarks.common import bench_cfg

DIM = 16
SLO_MS = 25.0
SEARCH_QPS = 80.0
INSERT_PERIOD_S = 0.04          # one 8-row durable insert every 40 ms
N_SEARCH_THREADS = 2
ACK_PERIOD_S = 0.01             # paced ack measurement: 100 inserts/s


def _spec(root: str, n_replicas: int):
    import spfresh

    return spfresh.ServiceSpec(
        index=spfresh.IndexSpec(config=bench_cfg()),
        serve=spfresh.ServeSpec(
            search_k=10, nprobe=8, max_batch=64, min_bucket=8,
            async_serve=True, policy="ratio", fg_bg_ratio=4,
        ),
        maintenance=spfresh.MaintenanceSpec(jobs_per_round=8),
        durability=spfresh.DurabilitySpec(root=root),
        shards=spfresh.ShardSpec(n_shards=1, n_replicas=n_replicas),
    )


def _open_service(workdir: str, n_replicas: int, base, queries, inserts):
    import spfresh

    root = f"{workdir}/svc_{n_replicas}"
    shutil.rmtree(root, ignore_errors=True)
    svc = spfresh.open(_spec(root, n_replicas), vectors=base, fresh=True)
    eng = svc.engine
    # warm every executable the loaded run touches — including the
    # policy-budget maintain shape (jobs is a static arg: a different
    # budget is a different executable, and a mid-run compile would be
    # charged to whichever cell runs first)
    eng.search(queries[:1])
    eng.search(queries[:8])
    eng.insert(inserts[:8], np.arange(50_000, 50_008, dtype=np.int32))
    eng.barrier()
    with eng.exclusive():
        eng.backend.maintain(eng.policy.budget)
    if svc.replicas is not None:
        svc.replicas.wait_sync()
    return svc


def _poisson_scheds(rng, qps: float, duration: float, n_threads: int):
    scheds = []
    for _ in range(n_threads):
        out, t = [], 0.0
        while True:
            t += rng.exponential(n_threads / qps)
            if t >= duration:
                break
            out.append(t)
        scheds.append(out)
    return scheds


def _loaded_cell(svc, duration: float, queries, inserts) -> dict:
    """Open-loop searches at SEARCH_QPS against a live durable insert
    stream (which drags maintenance slots along via the ratio policy);
    latency is scheduled-arrival -> ticket completion."""
    eng = svc.engine
    stop = threading.Event()
    vid = [54_000]

    def updater():
        while not stop.is_set():
            t0 = time.perf_counter()
            v = vid[0]
            vid[0] += 8
            row = (v // 8) % 500 * 8
            eng.submit_insert(inserts[row:row + 8],
                              np.arange(v, v + 8, dtype=np.int32))
            dt = INSERT_PERIOD_S - (time.perf_counter() - t0)
            if dt > 0:
                time.sleep(dt)

    scheds = _poisson_scheds(np.random.default_rng(11), SEARCH_QPS,
                             duration, N_SEARCH_THREADS)
    lats: list[tuple[float, object]] = []
    lats_lock = threading.Lock()
    errors: list[BaseException] = []

    def searcher(tid: int):
        rng = np.random.default_rng(13 + tid)
        start = time.perf_counter() + 0.05
        try:
            for t_rel in scheds[tid]:
                tgt = start + t_rel
                w = tgt - time.perf_counter()
                if w > 0:
                    time.sleep(w)
                q = queries[rng.integers(0, len(queries))][None]
                tk = eng.submit_search(q)
                with lats_lock:
                    lats.append((tgt, tk))
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    ut = threading.Thread(target=updater, daemon=True)
    sts = [threading.Thread(target=searcher, args=(i,), daemon=True)
           for i in range(N_SEARCH_THREADS)]
    ut.start()
    for t in sts:
        t.start()
    for t in sts:
        t.join(duration * 10 + 120)
    stop.set()
    ut.join(30)
    assert not any(t.is_alive() for t in sts), "searcher hung"
    eng.barrier()
    if errors:
        raise errors[0]

    xs = []
    for tgt, tk in lats:
        assert tk.t_done is not None, "ticket incomplete after barrier"
        xs.append(tk.t_done - tgt)
    a = np.asarray(xs) * 1e3
    rep = eng.report()
    m = rep["maintenance"]
    out = {
        "offered_qps": SEARCH_QPS,
        "n_searches": len(a),
        "p50_ms": float(np.percentile(a, 50)),
        "p99_ms": float(np.percentile(a, 99)),
        "slo_ms": SLO_MS,
        "goodput_qps": float((a <= SLO_MS).sum() / duration),
        "slo_miss_frac": float((a > SLO_MS).mean()),
        "maint_slots": m["slots"],
        "maint_time_s": m["time_s"],
    }
    r = rep["replicas"]
    if r is not None:
        out["routed_batches"] = r["routed_batches"]
        out["fallback_primary"] = r["fallback_primary"]
        out["published"] = r["published"]
        out["replica_lag_now"] = [x["lag"] for x in r["per_replica"]]
    return out


def _substrate_costs(svc, queries) -> dict:
    """The measured costs the multi-core model is built from."""
    from repro.distributed.replication import ReplicaSet
    from repro.serve.queue import MicroBatch

    eng = svc.engine
    backend = eng.backend
    q8 = np.ascontiguousarray(queries[:8])

    # primary pump-side service time per search batch (dispatch+readback:
    # what the serialized pump pays per batch with no replicas)
    with eng.exclusive():
        backend.search(q8, 10, 8)       # warm
        n = 50
        t0 = time.perf_counter()
        for _ in range(n):
            backend.search(q8, 10, 8)
        t_search = (time.perf_counter() - t0) / n

    # pump-side cost of routing instead: lock + staging copy + enqueue.
    # A detached ReplicaSet (workers never started, huge inflight cap)
    # measures route() itself without a worker consuming the batches.
    rs = ReplicaSet(backend, [backend.clone()], inflight=1 << 30)
    batch = MicroBatch(op="search", key=(10, 8), parts=[],
                      arrays={"queries": q8}, n_valid=8, bucket=8)
    rs.route(batch)                     # warm
    n = 200
    t0 = time.perf_counter()
    for _ in range(n):
        rs.route(batch)
    t_route = (time.perf_counter() - t0) / n
    return {
        "t_pump_search_us": t_search * 1e6,
        "t_route_us": t_route * 1e6,
        "t_replica_search_us": t_search * 1e6,  # a clone runs the same
                                                # executables at the same
                                                # measured rate
        "batch_rows": 8,
    }


def _modeled_scaling(costs: dict, n_replicas: int) -> float:
    """Read capacity on a deployment with a core per index copy,
    relative to the no-replica baseline (batches/s): the primary still
    serves 1x itself, each replica adds its own measured service rate,
    and the pump's routing rate (1/t_route per batch) caps the total."""
    if n_replicas <= 1:
        return 1.0
    t_pump = costs["t_pump_search_us"]
    replicas_rel = 1.0 + (n_replicas - 1) * t_pump / costs["t_replica_search_us"]
    routing_cap_rel = t_pump / costs["t_route_us"]
    return min(replicas_rel, routing_cap_rel)


def _ack_latency(svc, inserts, n: int, vid0: int) -> dict:
    """Median closed-loop durable insert ack, paced at 1/ACK_PERIOD_S."""
    eng = svc.engine
    xs = []
    vid = vid0
    for i in range(n):
        row = i % 500 * 8
        t0 = time.perf_counter()
        tk = eng.submit_insert(inserts[row:row + 8],
                               np.arange(vid, vid + 8, dtype=np.int32))
        tk.result()
        xs.append(time.perf_counter() - t0)
        vid += 8
        time.sleep(ACK_PERIOD_S)
    a = np.asarray(xs) * 1e3
    return {
        "n": n,
        "p50_ms": float(np.percentile(a, 50)),
        "mean_ms": float(a.mean()),
        "p99_ms": float(np.percentile(a, 99)),
    }


def run_json(quick: bool = True) -> dict:
    from repro.distributed.replication import states_equal

    duration = 5.0 if quick else 15.0
    n_ack = 60 if quick else 200
    rng = np.random.default_rng(7)
    base = rng.normal(size=(4000, DIM)).astype(np.float32)
    queries = rng.normal(size=(512, DIM)).astype(np.float32)
    inserts = rng.normal(size=(4096, DIM)).astype(np.float32)

    workdir = tempfile.mkdtemp(prefix="bench_replicas_")
    cells: dict[str, dict] = {}
    costs = None
    parity = None
    acks: dict[str, dict] = {}
    try:
        for n_rep in (1, 2, 4):
            svc = _open_service(workdir, n_rep, base, queries, inserts)
            cell = _loaded_cell(svc, duration, queries, inserts)
            if n_rep == 1:
                costs = _substrate_costs(svc, queries)
                acks["replication_off"] = _ack_latency(
                    svc, inserts, n_ack, vid0=58_000)
            if n_rep == 2:
                # (a) replay racing the ack on this single core — the
                # honest wall-clock number HERE, dominated by CPU
                # contention between the replica's replay dispatch and
                # the primary's next insert (each replica has its own
                # core in deployment, so this contention is a container
                # artifact, reported but not gated)
                acks["replication_on"] = _ack_latency(
                    svc, inserts, n_ack, vid0=58_000)
                # (b) the ack-path cost of replication itself: publish
                # (seqno stamp + staging copy + window append) stays on
                # the ack path, replay is deferred (paused worker) —
                # what "replication on" costs a multi-core deployment's
                # acks; this is the gated number
                svc.replicas.pause(0)
                acks["replication_on_replay_deferred"] = _ack_latency(
                    svc, inserts, n_ack, vid0=60_000)
                svc.replicas.resume(0)
                svc.drain()
                svc.replicas.wait_sync()
                parity = {
                    "checked_at_seqno": int(svc.backend._wal_applied),
                    "replica_seqno": svc.replicas.replicas[0].applied,
                    "bit_identical": bool(states_equal(
                        svc.backend.index.state,
                        svc.replicas.replicas[0].backend.index.state,
                    )),
                }
            cell["read_scaling_modeled_multicore"] = _modeled_scaling(
                costs, n_rep)
            cells[str(n_rep)] = cell
            svc.close()
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    off = acks["replication_off"]
    on = acks["replication_on_replay_deferred"]
    summary = {
        # the acceptance metric: read capacity scaling at 2 replicas,
        # shards fixed — modeled from measured substrate costs because
        # this container has a single core (replica compute timeshares
        # with the primary; see module docstring)
        "read_scaling_2r": cells["2"]["read_scaling_modeled_multicore"],
        "read_scaling_4r": cells["4"]["read_scaling_modeled_multicore"],
        "read_scaling_basis": "modeled_multicore_from_measured_costs",
        # measured end-to-end anchors for the model, same container
        "goodput_ratio_2r_measured": (
            cells["2"]["goodput_qps"] / cells["1"]["goodput_qps"]
            if cells["1"]["goodput_qps"] > 0 else float("inf")
        ),
        "p99_ms_1r": cells["1"]["p99_ms"],
        "p99_ms_2r": cells["2"]["p99_ms"],
        "ack_p50_off_ms": off["p50_ms"],
        "ack_p50_on_ms": on["p50_ms"],
        # ack-path cost of replication (publish on, replay deferred to
        # its own core as in deployment); the same-core contended number
        # is in ack["replication_on"]
        "ack_overhead_frac": (
            (on["p50_ms"] - off["p50_ms"]) / off["p50_ms"]
            if off["p50_ms"] > 0 else 0.0
        ),
        "ack_p50_on_contended_ms": acks["replication_on"]["p50_ms"],
        "bit_identical_at_equal_seqno": parity["bit_identical"],
    }
    return {
        "bench": "replicas",
        "config": {
            "dim": DIM, "n_base": len(base), "duration_s": duration,
            "search_qps": SEARCH_QPS, "slo_ms": SLO_MS,
            "insert_period_s": INSERT_PERIOD_S,
            "ack_period_s": ACK_PERIOD_S, "shards": 1,
            "single_core_container": True,
        },
        "substrate_costs": costs,
        "cells": cells,
        "ack": acks,
        "parity": parity,
        "summary": summary,
    }


def run(quick: bool = True) -> list[str]:
    rep = run_json(quick=quick)
    out = []
    for n_rep, cell in rep["cells"].items():
        out.append(
            f"replicas/r{n_rep},{cell['p50_ms'] * 1e3:.1f},"
            f"goodput={cell['goodput_qps']:.0f}qps;"
            f"p99={cell['p99_ms']:.1f};"
            f"scaling_modeled={cell['read_scaling_modeled_multicore']:.2f}x"
        )
    s = rep["summary"]
    out.append(
        f"replicas/summary,0.0,"
        f"scaling_2r={s['read_scaling_2r']:.2f}x;"
        f"ack_overhead={s['ack_overhead_frac'] * 100:+.1f}%;"
        f"parity={s['bit_identical_at_equal_seqno']}"
    )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
