"""Durability lifecycle costs (paper §4.4, the service API's recovery
path): snapshot write/restore bandwidth, WAL append + fsync throughput,
and end-to-end crash recovery (snapshot load + per-shard WAL replay
through the backend's jitted dispatches) via ``spfresh.open``.

    PYTHONPATH=src python -m benchmarks.run --only recovery
    PYTHONPATH=src python -m benchmarks.run --json BENCH_recovery.json
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import bench_cfg
from repro import api
from repro.data.vectors import make_shifting_stream, make_sift_like
from repro.storage.snapshot import load_snapshot, save_snapshot
from repro.storage.wal import WalSet, iter_wal
from repro.core.types import make_empty_state


def _state_bytes(state) -> int:
    import jax

    return sum(
        np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(state)
    )


def _bench_snapshot(idx, root: str, repeats: int) -> dict:
    path = os.path.join(root, "snap_bench")
    nbytes = _state_bytes(idx.state)
    t_w = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        save_snapshot(path, idx.state)
        t_w.append(time.perf_counter() - t0)
    template = make_empty_state(idx.state.cfg)
    t_r = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        load_snapshot(path, template)
        t_r.append(time.perf_counter() - t0)
    return {
        "state_mb": nbytes / 1e6,
        "write_s": float(np.median(t_w)),
        "write_mb_s": nbytes / 1e6 / float(np.median(t_w)),
        "restore_s": float(np.median(t_r)),
        "restore_mb_s": nbytes / 1e6 / float(np.median(t_r)),
    }


def _bench_wal(root: str, batch: int, n_batches: int, dim: int) -> dict:
    """Append (fsync'd) + sequential replay-scan throughput."""
    wal_dir = os.path.join(root, "wal_bench")
    ws = WalSet(wal_dir, 1)
    vecs = np.zeros((batch, dim), np.float32)
    vids = np.arange(batch, dtype=np.int32)
    valid = np.ones(batch, bool)
    t0 = time.perf_counter()
    for _ in range(n_batches):
        ws.append("insert", {"vecs": vecs, "vids": vids, "valid": valid})
    t_append = time.perf_counter() - t0
    nbytes = os.path.getsize(ws.shard_path(0))
    t0 = time.perf_counter()
    n_rec = sum(1 for _ in iter_wal(ws.shard_path(0)))
    t_scan = time.perf_counter() - t0
    ws.close()
    assert n_rec == n_batches
    return {
        "append_batches_s": n_batches / t_append,
        "append_rows_s": n_batches * batch / t_append,
        "append_mb_s": nbytes / 1e6 / t_append,
        "scan_records_s": n_rec / max(t_scan, 1e-9),
        "log_mb": nbytes / 1e6,
    }


def _bench_open_recovery(root: str, n_base: int, n_updates: int,
                         dim: int = 16) -> dict:
    """Crash → ``spfresh.open`` wall time, split into snapshot load and
    WAL replay (replay re-runs the update dispatches, so its throughput
    is the real recovery bound — Fig. 7's update path re-applied)."""
    svc_root = os.path.join(root, "svc")
    spec = api.ServiceSpec(
        index=api.IndexSpec(config=bench_cfg(dim=dim)),
        durability=api.DurabilitySpec(root=svc_root),
    )
    base = make_sift_like(n_base, dim, seed=41)
    svc = api.open(spec, vectors=base)
    fresh = make_shifting_stream(n_updates, dim, seed=42)
    ids = np.arange(n_base, n_base + n_updates, dtype=np.int32)
    t0 = time.perf_counter()
    for s in range(0, n_updates, 256):
        svc.insert(fresh[s:s + 256], ids[s:s + 256])
    t_updates = time.perf_counter() - t0
    # crash: abandon without checkpoint/close; everything since the
    # open-time snapshot lives only in the WAL
    t0 = time.perf_counter()
    svc2 = api.open(spec)
    t_open = time.perf_counter() - t0
    assert svc2.recovered
    d, v = svc2.search(fresh[:4], k=5)
    assert (v[:, 0] == ids[:4]).all(), "recovery lost updates"
    svc2.close()
    return {
        "n_base": n_base,
        "n_updates": n_updates,
        "update_wall_s": t_updates,
        "recover_open_s": t_open,
        "replayed_rows_s": n_updates / max(t_open, 1e-9),
        "recover_vs_update": t_open / max(t_updates, 1e-9),
    }


def run_json(quick: bool = True) -> dict:
    n_base = 4000 if quick else 40000
    n_updates = 1024 if quick else 8192
    root = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        base = make_sift_like(n_base, 16, seed=40)
        svc = api.open(api.ServiceSpec(index=api.IndexSpec(
            config=bench_cfg())), vectors=base)
        snap = _bench_snapshot(svc.index, root, repeats=3 if quick else 5)
        wal = _bench_wal(root, batch=256, n_batches=16 if quick else 64,
                         dim=16)
        rec = _bench_open_recovery(root, n_base, n_updates)
        return {"snapshot": snap, "wal": wal, "recovery": rec}
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run(quick: bool = True) -> list[str]:
    r = run_json(quick=quick)
    s, w, o = r["snapshot"], r["wal"], r["recovery"]
    return [
        f"recovery/snapshot,{s['write_s'] * 1e6:.0f},"
        f"state_mb={s['state_mb']:.1f};write_mb_s={s['write_mb_s']:.0f};"
        f"restore_mb_s={s['restore_mb_s']:.0f}",
        f"recovery/wal,{1e6 / w['append_batches_s']:.0f},"
        f"append_rows_s={w['append_rows_s']:.0f};"
        f"append_mb_s={w['append_mb_s']:.1f};"
        f"scan_records_s={w['scan_records_s']:.0f}",
        f"recovery/open,{o['recover_open_s'] * 1e6:.0f},"
        f"replayed_rows_s={o['replayed_rows_s']:.0f};"
        f"recover_vs_update={o['recover_vs_update']:.2f}",
    ]


if __name__ == "__main__":
    for line in run():
        print(line)
