"""Durability lifecycle costs (paper §4.4, the service API's recovery
path): snapshot write/restore bandwidth, WAL append + fsync throughput,
end-to-end crash recovery (snapshot load + per-shard WAL replay through
the backend's jitted dispatches) via ``spfresh.open`` — plus the
durability FAST PATH: delta-checkpoint bytes as a function of churn
(block-granular dirty tracking), fsyncs/dispatch under WAL group commit,
and replay throughput before/after WAL compaction.

    PYTHONPATH=src python -m benchmarks.run --only recovery
    PYTHONPATH=src python -m benchmarks.run --json BENCH_recovery.json
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import bench_cfg
from repro import api
from repro.data.vectors import make_shifting_stream, make_sift_like
from repro.storage.snapshot import SnapshotStore, load_snapshot, save_snapshot
from repro.storage.wal import WalSet, compact_wal_records, iter_wal
from repro.core.types import make_empty_state


def _state_bytes(state) -> int:
    import jax

    return sum(
        np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(state)
    )


def _bench_snapshot(idx, root: str, repeats: int) -> dict:
    path = os.path.join(root, "snap_bench")
    nbytes = _state_bytes(idx.state)
    t_w = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        save_snapshot(path, idx.state)
        t_w.append(time.perf_counter() - t0)
    template = make_empty_state(idx.state.cfg)
    t_r = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        load_snapshot(path, template)
        t_r.append(time.perf_counter() - t0)
    return {
        "state_mb": nbytes / 1e6,
        "write_s": float(np.median(t_w)),
        "write_mb_s": nbytes / 1e6 / float(np.median(t_w)),
        "restore_s": float(np.median(t_r)),
        "restore_mb_s": nbytes / 1e6 / float(np.median(t_r)),
    }


def _bench_wal(root: str, batch: int, n_batches: int, dim: int) -> dict:
    """Append (fsync'd) + sequential replay-scan throughput."""
    wal_dir = os.path.join(root, "wal_bench")
    ws = WalSet(wal_dir, 1)
    vecs = np.zeros((batch, dim), np.float32)
    vids = np.arange(batch, dtype=np.int32)
    valid = np.ones(batch, bool)
    t0 = time.perf_counter()
    for _ in range(n_batches):
        ws.append("insert", {"vecs": vecs, "vids": vids, "valid": valid})
    t_append = time.perf_counter() - t0
    nbytes = os.path.getsize(ws.shard_path(0))
    t0 = time.perf_counter()
    n_rec = sum(1 for _ in iter_wal(ws.shard_path(0)))
    t_scan = time.perf_counter() - t0
    ws.close()
    assert n_rec == n_batches
    return {
        "append_batches_s": n_batches / t_append,
        "append_rows_s": n_batches * batch / t_append,
        "append_mb_s": nbytes / 1e6 / t_append,
        "scan_records_s": n_rec / max(t_scan, 1e-9),
        "log_mb": nbytes / 1e6,
    }


def _bench_open_recovery(root: str, n_base: int, n_updates: int,
                         dim: int = 16) -> dict:
    """Crash → ``spfresh.open`` wall time, split into snapshot load and
    WAL replay (replay re-runs the update dispatches, so its throughput
    is the real recovery bound — Fig. 7's update path re-applied)."""
    svc_root = os.path.join(root, "svc")
    spec = api.ServiceSpec(
        index=api.IndexSpec(config=bench_cfg(dim=dim)),
        durability=api.DurabilitySpec(root=svc_root),
    )
    base = make_sift_like(n_base, dim, seed=41)
    svc = api.open(spec, vectors=base)
    fresh = make_shifting_stream(n_updates, dim, seed=42)
    ids = np.arange(n_base, n_base + n_updates, dtype=np.int32)
    t0 = time.perf_counter()
    for s in range(0, n_updates, 256):
        svc.insert(fresh[s:s + 256], ids[s:s + 256])
    t_updates = time.perf_counter() - t0
    # crash: abandon without checkpoint/close; everything since the
    # open-time snapshot lives only in the WAL
    t0 = time.perf_counter()
    svc2 = api.open(spec)
    t_open = time.perf_counter() - t0
    assert svc2.recovered
    d, v = svc2.search(fresh[:4], k=5)
    assert (v[:, 0] == ids[:4]).all(), "recovery lost updates"
    svc2.close()
    return {
        "n_base": n_base,
        "n_updates": n_updates,
        "update_wall_s": t_updates,
        "recover_open_s": t_open,
        "replayed_rows_s": n_updates / max(t_open, 1e-9),
        "recover_vs_update": t_open / max(t_updates, 1e-9),
    }


def _bench_delta_vs_churn(root: str, n_base: int, dim: int = 16) -> dict:
    """Checkpoint bytes vs churn: write a full base, then for each churn
    fraction update churn·n rows and commit a DELTA unit — its on-disk
    bytes should scale with the dirty-block count, not the index size
    (the paper's copy-on-write block controller, measured)."""
    svc_root = os.path.join(root, "delta_churn")
    spec = api.ServiceSpec(
        index=api.IndexSpec(config=bench_cfg(dim=dim)),
        durability=api.DurabilitySpec(root=svc_root),
    )
    base = make_sift_like(n_base, dim, seed=43)
    svc = api.open(spec, vectors=base)          # open-time base snapshot
    store = SnapshotStore(spec.durability.resolved_snapshot_dir())
    full_bytes = store.unit_bytes()
    out = {"full_snapshot_mb": full_bytes / 1e6, "churn": []}
    rng = np.random.default_rng(44)
    next_id = n_base
    for churn in (0.01, 0.05, 0.20):
        n_upd = max(1, int(round(churn * n_base)))
        vecs = make_shifting_stream(n_upd, dim, seed=next_id)
        ids = np.arange(next_id, next_id + n_upd, dtype=np.int32)
        next_id += n_upd
        svc.insert(vecs, ids)
        dead = rng.choice(ids, size=max(1, n_upd // 4), replace=False)
        svc.delete(dead.astype(np.int32))
        t0 = time.perf_counter()
        svc.checkpoint(delta=True)
        dt = time.perf_counter() - t0
        delta_bytes = store.unit_bytes()
        out["churn"].append({
            "update_rate": churn,
            "rows": int(n_upd),
            "delta_mb": delta_bytes / 1e6,
            "delta_vs_full": delta_bytes / full_bytes,
            "write_s": dt,
        })
        svc.checkpoint(delta=False)             # re-base between levels
        full_bytes = store.unit_bytes()
    svc.close()
    return out


def _bench_group_commit(root: str, n_base: int, dim: int = 16,
                        group_n: int = 32) -> dict:
    """fsyncs per update dispatch, fsync-every-dispatch vs group commit.
    Both runs push the same stream through ``insert_bulk`` (many padded
    micro-batch dispatches per call); group commit closes the window once
    per bulk call / every ``group_n`` dispatches instead of per append."""
    base = make_sift_like(n_base, dim, seed=45)
    stream = make_shifting_stream(1024, dim, seed=46)
    out = {}
    for label, gc in (("fsync_per_dispatch", 0), ("group_commit", group_n)):
        svc_root = os.path.join(root, f"gc_{label}")
        spec = api.ServiceSpec(
            index=api.IndexSpec(config=bench_cfg(dim=dim)),
            serve=api.ServeSpec(max_batch=64),
            durability=api.DurabilitySpec(root=svc_root, group_commit=gc),
        )
        svc = api.open(spec, vectors=base)
        ids = np.arange(n_base, n_base + len(stream), dtype=np.int32)
        t0 = time.perf_counter()
        svc.insert_bulk(stream, ids, chunk=64)
        dt = time.perf_counter() - t0
        st = svc.backend.wal_set.stats()
        out[label] = {
            "dispatches": st["appends"],
            "fsyncs": st["fsyncs"],
            "fsyncs_per_dispatch": st["fsyncs_per_append"],
            "wall_s": dt,
            "rows_s": len(stream) / max(dt, 1e-9),
        }
        svc.close()
    a = out["fsync_per_dispatch"]["fsyncs_per_dispatch"]
    b = out["group_commit"]["fsyncs_per_dispatch"]
    out["fsync_reduction"] = a / max(b, 1e-9)
    out["group_n"] = group_n
    return out


def _bench_wal_compaction(root: str, n_base: int, dim: int = 16) -> dict:
    """Replay throughput before/after ``compact_wal_records``: a churny
    stream (most inserted vids deleted again before the crash) leaves a
    WAL full of dead rows; compaction masks them out of the replay."""
    svc_root = os.path.join(root, "wal_compact")
    spec = api.ServiceSpec(
        index=api.IndexSpec(config=bench_cfg(dim=dim)),
        serve=api.ServeSpec(max_batch=64),
        durability=api.DurabilitySpec(root=svc_root),
    )
    base = make_sift_like(n_base, dim, seed=47)
    svc = api.open(spec, vectors=base)
    n_waves, wave = 24, 128
    next_id = n_base
    n_rows = 0
    for w in range(n_waves):
        vecs = make_shifting_stream(wave, dim, seed=next_id)
        ids = np.arange(next_id, next_id + wave, dtype=np.int32)
        next_id += wave
        svc.insert(vecs, ids)
        n_rows += wave
        if w < n_waves - 2:
            # TTL churn: whole waves expire before the crash — their
            # insert dispatches are fully dead and compact away entirely
            svc.delete(ids)
            n_rows += wave
    # crash: abandon the handle; measure the records the recovery replays
    wal_dir = spec.durability.resolved_wal_dir()
    records = list(iter_wal(os.path.join(wal_dir, "shard_000.wal")))
    compacted, dropped = compact_wal_records(records)
    out = {"records": len(records), "records_compacted": len(compacted),
           "rows_dropped": int(dropped), "update_rows": int(n_rows)}
    for label, compact in (("replay", False), ("replay_compacted", True)):
        spec_r = dataclasses.replace(
            spec, durability=dataclasses.replace(
                spec.durability, compact_wal=compact),
        )
        t0 = time.perf_counter()
        twin = api.open(spec_r)
        dt = time.perf_counter() - t0
        assert twin.recovered
        out[label] = {
            "open_s": dt,
            "rows_s": n_rows / max(dt, 1e-9),
        }
        twin.engine.backend.wal_set.close()     # reopen same root next loop
    out["replay_speedup"] = (out["replay"]["open_s"]
                             / max(out["replay_compacted"]["open_s"], 1e-9))
    svc.close()
    return out


def run_json(quick: bool = True) -> dict:
    n_base = 4000 if quick else 40000
    n_updates = 1024 if quick else 8192
    root = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        base = make_sift_like(n_base, 16, seed=40)
        svc = api.open(api.ServiceSpec(index=api.IndexSpec(
            config=bench_cfg())), vectors=base)
        snap = _bench_snapshot(svc.index, root, repeats=3 if quick else 5)
        wal = _bench_wal(root, batch=256, n_batches=16 if quick else 64,
                         dim=16)
        rec = _bench_open_recovery(root, n_base, n_updates)
        delta = _bench_delta_vs_churn(root, n_base)
        gc = _bench_group_commit(root, n_base)
        compact = _bench_wal_compaction(root, n_base)
        return {
            "snapshot": snap, "wal": wal, "recovery": rec,
            "delta_vs_churn": delta, "group_commit": gc,
            "wal_compaction": compact,
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run(quick: bool = True) -> list[str]:
    r = run_json(quick=quick)
    s, w, o = r["snapshot"], r["wal"], r["recovery"]
    d, g, c = r["delta_vs_churn"], r["group_commit"], r["wal_compaction"]
    d1 = d["churn"][0]
    return [
        f"recovery/snapshot,{s['write_s'] * 1e6:.0f},"
        f"state_mb={s['state_mb']:.1f};write_mb_s={s['write_mb_s']:.0f};"
        f"restore_mb_s={s['restore_mb_s']:.0f}",
        f"recovery/wal,{1e6 / w['append_batches_s']:.0f},"
        f"append_rows_s={w['append_rows_s']:.0f};"
        f"append_mb_s={w['append_mb_s']:.1f};"
        f"scan_records_s={w['scan_records_s']:.0f}",
        f"recovery/open,{o['recover_open_s'] * 1e6:.0f},"
        f"replayed_rows_s={o['replayed_rows_s']:.0f};"
        f"recover_vs_update={o['recover_vs_update']:.2f}",
        f"recovery/delta,{d1['write_s'] * 1e6:.0f},"
        f"delta_vs_full@{d1['update_rate']:.0%}={d1['delta_vs_full']:.3f};"
        f"full_mb={d['full_snapshot_mb']:.1f}",
        f"recovery/group_commit,{g['group_commit']['wall_s'] * 1e6:.0f},"
        f"fsync_reduction={g['fsync_reduction']:.1f}x;"
        f"fsyncs_per_dispatch={g['group_commit']['fsyncs_per_dispatch']:.3f}",
        f"recovery/wal_compaction,"
        f"{c['replay_compacted']['open_s'] * 1e6:.0f},"
        f"replay_speedup={c['replay_speedup']:.2f}x;"
        f"rows_dropped={c['rows_dropped']}",
    ]


if __name__ == "__main__":
    for line in run():
        print(line)
