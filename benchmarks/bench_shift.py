"""Paper Fig. 2 + Fig. 10: data-distribution-shift micro-benchmark.

Four systems on the same shifted workload, ALL driven through the
unified Service API (``spfresh.open`` + :class:`ServiceSpec`) — the
ablation axis is the spec's LIRE feature flags, not hand-wired indexes:

  * static          — index rebuilt from scratch over base+inserts (ideal)
  * spann+          — in-place appends only (no Local Rebuilder)
  * +split          — appends + splits, NO reassignment
  * spfresh         — full LIRE (splits + merges + reassignment)

Reported per system: recall@10, measured search latency through the
serving surface, and the paper's latency driver (p99 posting length =
candidates scanned).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    bench_cfg,
    brute_force_gt,
    posting_stats,
    service_recall,
    timed_service_search,
)
from repro.data.vectors import make_shifting_stream, make_sift_like


def _open(cfg, vectors, max_insert_retries: int = 4):
    import spfresh

    spec = spfresh.ServiceSpec(
        index=spfresh.IndexSpec(config=cfg),
        serve=spfresh.ServeSpec(
            search_k=10, max_insert_retries=max_insert_retries,
        ),
    )
    return spfresh.open(spec, vectors=vectors, fresh=True)


def run(quick: bool = True) -> list[str]:
    n_base = 4000 if quick else 20000
    n_ins = 2000 if quick else 10000
    dim = 16
    base = make_sift_like(n_base, dim, seed=1)
    inserts = make_shifting_stream(n_ins, dim, seed=2)
    all_vecs = np.concatenate([base, inserts])
    all_ids = np.arange(len(all_vecs))
    rng = np.random.default_rng(3)
    qsel = rng.integers(n_base, len(all_vecs), size=128)  # query the hot region
    queries = all_vecs[qsel] + 0.01 * rng.normal(size=(128, dim)).astype(np.float32)
    gt = brute_force_gt(queries, all_vecs, all_ids)

    ins_ids = np.arange(n_base, len(all_vecs)).astype(np.int32)

    systems = {}

    # static (global rebuild — the paper's ideal reference)
    t0 = time.perf_counter()
    static = _open(bench_cfg(), all_vecs)
    systems["static"] = (static, time.perf_counter() - t0)

    # spann+ (append only, larger posting capacity so postings can grow)
    t0 = time.perf_counter()
    sp = _open(
        bench_cfg(max_blocks_per_posting=32, num_blocks=32768,
                  enable_split=False, enable_merge=False,
                  enable_reassign=False),
        base, max_insert_retries=0,
    )
    sp.insert(inserts, ins_ids)
    systems["spann+"] = (sp, time.perf_counter() - t0)

    # +split only
    t0 = time.perf_counter()
    so = _open(bench_cfg(enable_reassign=False), base)
    so.insert(inserts, ins_ids)
    so.drain()
    systems["split_only"] = (so, time.perf_counter() - t0)

    # full LIRE
    t0 = time.perf_counter()
    fl = _open(bench_cfg(), base)
    fl.insert(inserts, ins_ids)
    fl.drain()
    systems["spfresh"] = (fl, time.perf_counter() - t0)

    out = []
    for name, (svc, build_s) in systems.items():
        r = service_recall(svc, queries, gt)
        lat = timed_service_search(svc, queries)
        ps = posting_stats(svc.index)
        svc.close()
        out.append(
            f"shift/{name},{lat['mean_ms'] * 1e3:.1f},"
            f"recall={r:.3f};scan_p99={ps['scan_cost_p99']:.0f};"
            f"max_len={ps['max_len']};postings={ps['n_postings']};"
            f"wall_s={build_s:.1f}"
        )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
