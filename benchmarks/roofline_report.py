"""§Roofline report: aggregate the dry-run JSONs into the roofline table
(used verbatim in EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os


def load(out_dir: str = "results/dryrun_final") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as fh:
            rows.append(json.load(fh))
    return rows


def table(rows: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant |"
        " mem/dev GB | model/HLO | compile_s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | "
                f"skipped: {r.get('skip_reason', '')[:70]} | — | — | — |"
            )
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | "
                f"{r.get('status')} | — | — | — |"
            )
            continue
        t = r["roofline"]
        ma = r.get("memory_analysis", {})
        ratio = r.get("model_to_hlo_flops")
        ratio_s = f"{ratio:.2f}" if ratio is not None else "n/a"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['dominant'].replace('_s', '')} | "
            f"{ma.get('total_bytes', 0) / 1e9:.2f} | {ratio_s} | "
            f"{r.get('compile_s', 0):.0f} |"
        )
    return "\n".join(lines)


def collective_breakdown(rows: list[dict], arch: str, shape: str,
                         mesh: str = "single") -> dict:
    for r in rows:
        if (r.get("arch"), r.get("shape"), r.get("mesh")) == (arch, shape, mesh):
            return {
                "bytes": r.get("collective_bytes", {}),
                "counts": r.get("hlo_collective_counts", {}),
            }
    return {}


def run(quick: bool = True) -> list[str]:
    rows = load()
    ok = sum(1 for r in rows if r.get("status") == "ok")
    skipped = sum(1 for r in rows if r.get("status") == "skipped")
    bad = [r for r in rows if r.get("status") not in ("ok", "skipped")]
    return [
        f"roofline/cells,0.0,ok={ok};skipped={skipped};failed={len(bad)}"
    ] + [
        f"roofline/failed/{r['arch']}_{r['shape']}_{r['mesh']},0.0,"
        f"status={r.get('status')}"
        for r in bad
    ]


if __name__ == "__main__":
    rows = load()
    print("## single-pod (16×16 = 256 chips)\n")
    print(table(rows, "single"))
    print("\n## multi-pod (2×16×16 = 512 chips)\n")
    print(table(rows, "multi"))
