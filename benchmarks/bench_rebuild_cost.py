"""Paper Table 1 + §2.3: global-rebuild cost vs LIRE incremental cost.

After the same update stream, compare:
  * global rebuild — hierarchical balanced clustering from scratch
    (the DiskANN/SPANN maintenance model),
  * LIRE incremental — the split/merge/reassign work actually done.

Reported: wall time and bytes moved (vectors rewritten ×dim×4) — the
resource argument of the paper (1100 GB DRAM / days of compute vs local
fixes)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_cfg
from repro.core.index import SPFreshIndex, build_state
from repro.data.vectors import make_shifting_stream, make_sift_like


def run(quick: bool = True) -> list[str]:
    n_base = 6000 if quick else 40000
    n_ins = 3000 if quick else 20000
    dim = 16
    base = make_sift_like(n_base, dim, seed=41)
    inserts = make_shifting_stream(n_ins, dim, seed=42)
    ins_ids = np.arange(n_base, n_base + n_ins).astype(np.int32)

    # LIRE incremental
    idx = SPFreshIndex.build(bench_cfg(num_blocks=16384), base)
    t0 = time.perf_counter()
    idx.insert(inserts, ins_ids)
    idx.maintain()
    lire_wall = time.perf_counter() - t0
    st = idx.stats()
    # bytes moved = appends (inserts+reassigns) + split rewrites
    moved = (
        st["n_appends"]
        + st["n_splits"] * idx.state.cfg.split_limit
        + st["n_gc_writebacks"] * idx.state.cfg.split_limit
    ) * dim * 4

    # global rebuild over the merged dataset
    all_vecs = np.concatenate([base, inserts])
    t0 = time.perf_counter()
    build_state(bench_cfg(num_blocks=16384), all_vecs)
    rebuild_wall = time.perf_counter() - t0
    # hierarchical balanced k-means reads the full dataset ~iters times per
    # tree level (~2 levels), then writes every posting + closure replicas
    rebuild_moved = len(all_vecs) * dim * 4 * (10 * 2 + 2)

    out = [
        (
            f"rebuild_cost/lire,{lire_wall * 1e6 / max(n_ins, 1):.1f},"
            f"wall_s={lire_wall:.2f};bytes_moved_mb={moved / 1e6:.1f}"
        ),
        (
            f"rebuild_cost/global,{rebuild_wall * 1e6 / max(n_ins, 1):.1f},"
            f"wall_s={rebuild_wall:.2f};bytes_moved_mb={rebuild_moved / 1e6:.1f};"
            f"lire_speedup={rebuild_wall / max(lire_wall, 1e-9):.2f}x"
        ),
    ]
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
