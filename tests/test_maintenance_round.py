"""Batched maintenance rounds: round-vs-sequential equivalence + the
multi-pid storage ops behind them.

The round-parity gate (`tools/check.sh`): a `lire.maintenance_round`
must preserve the same invariants as K sequential `maintenance_step`s —
no live-vector loss, posting lengths within capacity/split-limit,
version monotonicity, matching post-drain recall — under random
insert/delete churn, and the batched blockpool/pid ops must match their
sequential counterparts observably.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# check.sh runs this suite as its own explicit gate step; the tier-1
# step excludes it via the marker (no hand-maintained --ignore list).
pytestmark = pytest.mark.gate

from repro.core import lire
from repro.core import types as T
from repro.core.index import SPFreshIndex
from repro.core.types import LireConfig
from repro.storage import blockpool as bp
from repro.storage import versionmap as vm


def small_cfg(**kw):
    args = dict(
        dim=16, block_size=8, max_blocks_per_posting=8, num_blocks=2048,
        num_postings_cap=256, num_vectors_cap=8192, split_limit=48,
        merge_limit=6, merge_fanout=4, reassign_range=8,
        reassign_budget=128, replica_count=2, nprobe=8, jobs_per_round=4,
    )
    args.update(kw)
    return LireConfig(**args)


def clustered(rng, n, dim=16, n_clusters=8):
    centers = rng.normal(size=(n_clusters, dim)) * 5
    return (
        centers[rng.integers(0, n_clusters, n)] + rng.normal(size=(n, dim))
    ).astype(np.float32)


def live_vid_set(state) -> set:
    vids = np.asarray(state.pool.block_vid).reshape(-1)
    vers = np.asarray(state.pool.block_ver).reshape(-1)
    stale = np.asarray(
        vm.is_stale(state.versions, jnp.asarray(vids), jnp.asarray(vers))
    )
    return set(vids[(vids >= 0) & ~stale].tolist())


def check_invariants(state):
    cfg = state.cfg
    lens = np.asarray(state.pool.posting_len)
    valid = np.asarray(state.centroid_valid)
    assert (lens[valid] <= cfg.posting_capacity).all()
    used = int(bp.used_blocks(state.pool))
    by_len = int(
        sum(-(-int(l) // cfg.block_size) for l in lens[valid] if l > 0)
    )
    assert used == by_len, f"block leak: used={used} by_len={by_len}"
    assert int(state.n_postings) == cfg.num_postings_cap - int(
        state.pid_free_top
    )
    # invalid postings hold no blocks
    pb = np.asarray(state.pool.posting_blocks)
    assert (pb[~valid] == -1).all()


# ---------------------------------------------------------------------------
# Batched blockpool ops vs their sequential counterparts
# ---------------------------------------------------------------------------

def _pool_with_postings(seed=0, n_postings=6, fill=20):
    rng = np.random.default_rng(seed)
    pool = bp.make_block_pool(
        num_blocks=64, block_size=4, dim=4, num_postings_cap=8,
        max_blocks_per_posting=8,
    )
    for pid in range(n_postings):
        k = int(rng.integers(1, fill))
        for i in range(k):
            pool, ok = bp.append_one(
                pool, jnp.asarray(pid),
                jnp.asarray(rng.normal(size=4), jnp.float32),
                jnp.asarray(pid * 100 + i), jnp.asarray(0, jnp.uint8),
                jnp.asarray(True),
            )
            assert bool(ok)
    return pool


def _pool_view(pool, pid):
    vecs, vids, _, valid = bp.gather_posting(pool, jnp.asarray(pid))
    v = np.asarray(valid)
    return (
        np.asarray(vids)[v].tolist(),
        np.asarray(vecs)[v].round(5).tolist(),
        int(pool.posting_len[pid]),
    )


def test_gather_postings_matches_gather_posting():
    pool = _pool_with_postings()
    pids = jnp.asarray([0, 3, 5, -1], jnp.int32)
    vecs, vids, vers, valid = bp.gather_postings(pool, pids)
    for row, pid in enumerate([0, 3, 5, 0]):
        v1, i1, r1, ok1 = bp.gather_posting(pool, jnp.asarray(pid))
        np.testing.assert_array_equal(np.asarray(vids[row]), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(valid[row]), np.asarray(ok1))
        np.testing.assert_allclose(np.asarray(vecs[row]), np.asarray(v1))


def test_free_postings_matches_sequential():
    pids = [1, 4, 5]
    p_batch = _pool_with_postings()
    p_seq = _pool_with_postings()
    enable = jnp.asarray([True, True, True])
    p_batch = bp.free_postings(p_batch, jnp.asarray(pids, jnp.int32), enable)
    for pid in pids:
        p_seq = bp.free_posting(p_seq, jnp.asarray(pid), jnp.asarray(True))
    assert int(p_batch.free_top) == int(p_seq.free_top)
    for pid in range(8):
        assert _pool_view(p_batch, pid) == _pool_view(p_seq, pid)
    # same FREE SET (stack order may differ)
    fb = set(np.asarray(p_batch.free_stack)[: int(p_batch.free_top)].tolist())
    fs = set(np.asarray(p_seq.free_stack)[: int(p_seq.free_top)].tolist())
    assert fb == fs


def test_free_postings_disabled_and_negative_rows_are_inert():
    pool = _pool_with_postings()
    before = int(pool.free_top)
    out = bp.free_postings(
        pool, jnp.asarray([2, -1, 3], jnp.int32),
        jnp.asarray([False, True, False]),
    )
    assert int(out.free_top) == before
    for pid in range(8):
        assert _pool_view(out, pid) == _pool_view(pool, pid)


def test_put_postings_matches_sequential():
    rng = np.random.default_rng(3)
    pids = [0, 2, 6]
    ns = [7, 0, 13]
    cap = 32
    vecs = rng.normal(size=(3, cap, 4)).astype(np.float32)
    vids = rng.integers(0, 500, size=(3, cap)).astype(np.int32)
    vers = rng.integers(0, 4, size=(3, cap)).astype(np.uint8)
    p_batch = _pool_with_postings(seed=1)
    p_seq = _pool_with_postings(seed=1)
    p_batch, ok_b = bp.put_postings(
        p_batch, jnp.asarray(pids, jnp.int32), jnp.asarray(vecs),
        jnp.asarray(vids), jnp.asarray(vers), jnp.asarray(ns, jnp.int32),
        jnp.ones(3, bool),
    )
    oks = []
    for j, pid in enumerate(pids):
        p_seq, ok = bp.put_posting(
            p_seq, jnp.asarray(pid), jnp.asarray(vecs[j]),
            jnp.asarray(vids[j]), jnp.asarray(vers[j]),
            jnp.asarray(ns[j]), jnp.asarray(True),
        )
        oks.append(bool(ok))
    np.testing.assert_array_equal(np.asarray(ok_b), oks)
    assert int(p_batch.free_top) == int(p_seq.free_top)
    for pid in range(8):
        assert _pool_view(p_batch, pid) == _pool_view(p_seq, pid)


def test_put_postings_pool_oom_fails_cleanly():
    pool = bp.make_block_pool(
        num_blocks=4, block_size=4, dim=4, num_postings_cap=8,
        max_blocks_per_posting=8,
    )
    cap = 32
    vecs = jnp.ones((2, cap, 4), jnp.float32)
    vids = jnp.arange(2 * cap, dtype=jnp.int32).reshape(2, cap)
    vers = jnp.zeros((2, cap), jnp.uint8)
    # first job takes all 4 blocks, second can't fit
    pool, ok = bp.put_postings(
        pool, jnp.asarray([0, 1], jnp.int32), vecs, vids, vers,
        jnp.asarray([16, 8], jnp.int32), jnp.ones(2, bool),
    )
    assert bool(ok[0]) and not bool(ok[1])
    assert int(pool.posting_len[0]) == 16
    assert int(pool.posting_len[1]) == 0
    assert int(pool.free_top) == 0


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_append_scatter_matches_append_batch(seed):
    """Collision-ranked scatter append == sequential scan append: same
    landed set, same pool contents — including capacity-pressure rows."""
    rng = np.random.default_rng(seed)
    n = 48
    p_scatter = _pool_with_postings(seed=seed, n_postings=6, fill=28)
    p_scan = _pool_with_postings(seed=seed, n_postings=6, fill=28)
    pids = rng.integers(-1, 8, n).astype(np.int32)   # incl. invalid + empty
    vecs = rng.normal(size=(n, 4)).astype(np.float32)
    vids = np.arange(1000, 1000 + n, dtype=np.int32)
    vers = rng.integers(0, 3, n).astype(np.uint8)
    enable = rng.random(n) < 0.85
    args = (
        jnp.asarray(np.maximum(pids, 0)), jnp.asarray(vecs),
        jnp.asarray(vids), jnp.asarray(vers),
        jnp.asarray(enable & (pids >= 0)),
    )
    p_scatter, ok_a = bp.append_scatter(p_scatter, *args)
    p_scan, ok_b = bp.append_batch(p_scan, *args)
    np.testing.assert_array_equal(np.asarray(ok_a), np.asarray(ok_b))
    assert int(p_scatter.free_top) == int(p_scan.free_top)
    for pid in range(8):
        assert _pool_view(p_scatter, pid) == _pool_view(p_scan, pid)


def test_append_scatter_capacity_and_block_boundaries():
    """Appends that cross multiple block boundaries on one posting."""
    pool = bp.make_block_pool(
        num_blocks=16, block_size=4, dim=2, num_postings_cap=2,
        max_blocks_per_posting=3,
    )
    n = 14                                    # capacity is 12
    pool, ok = bp.append_scatter(
        pool, jnp.zeros(n, jnp.int32), jnp.ones((n, 2), jnp.float32),
        jnp.arange(n, dtype=jnp.int32), jnp.zeros(n, jnp.uint8),
        jnp.ones(n, bool),
    )
    ok = np.asarray(ok)
    assert ok[:12].all() and not ok[12:].any()
    assert int(pool.posting_len[0]) == 12
    assert int(bp.used_blocks(pool)) == 3
    vids, _, valid = bp.gather_posting_ids(pool, jnp.asarray(0))
    got = np.asarray(vids)[np.asarray(valid)]
    np.testing.assert_array_equal(np.sort(got), np.arange(12))


def test_alloc_free_pids_match_sequential():
    state = T.make_empty_state(small_cfg())
    enable = jnp.asarray([True, False, True, True])
    s_batch, pids_b = T.alloc_pids(state, enable)
    s_seq = state
    pids_s = []
    for e in [True, False, True, True]:
        s_seq, p = T.alloc_pid(s_seq, jnp.asarray(e))
        pids_s.append(int(p))
    np.testing.assert_array_equal(np.asarray(pids_b), pids_s)
    assert int(s_batch.pid_free_top) == int(s_seq.pid_free_top)
    # round-trip: free them again in batch
    s_batch = T.free_pids(s_batch, pids_b, pids_b >= 0)
    assert int(s_batch.pid_free_top) == int(state.pid_free_top)


# ---------------------------------------------------------------------------
# Sort-based reassign dedup == O(n²) reference
# ---------------------------------------------------------------------------

def test_dedup_vid_mask_matches_reference():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(
        vids=st.lists(st.integers(-1, 5), min_size=1, max_size=24),
        bits=st.lists(st.booleans(), min_size=1, max_size=24),
    )
    def inner(vids, bits):
        n = min(len(vids), len(bits))
        v = jnp.asarray(vids[:n], jnp.int32)
        m = jnp.asarray(bits[:n])
        got = np.asarray(lire._dedup_vid_mask(v, m))
        want = np.asarray(lire._dedup_vid_mask_ref(v, m))
        np.testing.assert_array_equal(got, want)

    inner()


# ---------------------------------------------------------------------------
# Round vs sequential drains under churn
# ---------------------------------------------------------------------------

def _churn(idx, rng, n_base):
    """Deterministic hot-insert + clustered-delete churn; returns the
    expected live-vid set."""
    centroid = np.asarray(idx.state.centroids)[
        np.asarray(idx.state.centroid_valid)
    ][0]
    extra = (
        centroid[None, :] + 0.05 * rng.normal(size=(180, 16))
    ).astype(np.float32)
    ids = np.arange(4000, 4180, dtype=np.int32)
    idx.insert(extra, ids)
    d = ((np.asarray(idx.state.centroids)[0] - centroid) ** 2).sum()
    victims = rng.choice(n_base, size=120, replace=False).astype(np.int32)
    idx.delete(victims)
    return (set(range(n_base)) | set(ids.tolist())) - set(victims.tolist())


def _seq_drain(state):
    for _ in range(2 * state.cfg.num_postings_cap):
        state, did = lire.maintenance_step(state)
        if not bool(did):
            break
    return state


def _recall(state, base_all, vids_all, queries, k=10, nprobe=16):
    d = ((queries[:, None, :] - base_all[None, :, :]) ** 2).sum(-1)
    gt = vids_all[np.argsort(d, axis=1)[:, :k]]
    _, got = lire.search(state, jnp.asarray(queries), k=k, nprobe=nprobe)
    got = np.asarray(got)
    hits = sum(
        len(set(g.tolist()) & set(o.tolist())) for g, o in zip(gt, got)
    )
    return hits / (len(queries) * k)


def test_round_drain_matches_sequential_fixed_seed():
    """The deterministic round-parity gate: same live set, same invariants,
    matching post-drain recall for jobs_per_round in {1, 4}."""
    rng = np.random.default_rng(11)
    base = clustered(rng, 1200)
    idx = SPFreshIndex.build(small_cfg(), base)
    expected_live = _churn(idx, rng, len(base))
    state0 = idx.state

    live0 = live_vid_set(state0)
    assert live0 == expected_live, "churn itself dropped vectors"

    drained = {"seq": _seq_drain(state0)}
    for j in (1, 4):
        s, jobs, rounds = lire.rebuild_drain(state0, jobs_per_round=j)
        assert jobs >= 0 and rounds >= 1
        drained[f"round_j{j}"] = s

    # recall ground truth over the live corpus
    all_vecs = np.concatenate(
        [base, np.zeros((4180 - 1200, 16), np.float32)]
    )
    # (vid -> vector) for inserted hot vectors is not tracked here; compare
    # recall on base-only queries whose ground truth we can rebuild
    live_base = sorted(v for v in expected_live if v < 1200)
    base_live = base[live_base]
    vids_live = np.asarray(live_base)
    queries = base_live[rng.integers(0, len(base_live), 32)]

    recalls = {}
    for name, s in drained.items():
        assert live_vid_set(s) == expected_live, f"{name} lost live vectors"
        check_invariants(s)
        lens = np.asarray(s.pool.posting_len)
        valid = np.asarray(s.centroid_valid)
        assert (lens[valid] <= s.cfg.split_limit).all(), name
        # version monotonicity: live vids' versions only moved forward
        v0 = np.asarray(state0.versions).astype(np.int32)
        v1 = np.asarray(s.versions).astype(np.int32)
        lv = np.asarray(sorted(expected_live))
        assert ((v1[lv] & 0x7F) >= (v0[lv] & 0x7F)).all(), name
        # deletion bits untouched by maintenance
        np.testing.assert_array_equal(v1 & 0x80, v0 & 0x80)
        recalls[name] = _recall(s, base_live, vids_live, queries)

    r = list(recalls.values())
    assert max(r) - min(r) <= 0.1, f"post-drain recall diverged: {recalls}"
    assert min(r) > 0.8, recalls


_PROP_CFG = dict(
    dim=8, num_postings_cap=128, num_blocks=1024, num_vectors_cap=2048,
    split_limit=24, merge_limit=4, reassign_range=4, reassign_budget=64,
)


def _random_churn_trial(cfg, seed: int, n_ops: int, jobs: int):
    """One randomized insert/delete churn trial: drain sequentially and in
    rounds from the same state; both must preserve the invariants."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(300, 8)).astype(np.float32)
    idx = SPFreshIndex.build(cfg, base)
    live = set(range(300))
    next_vid = 300
    for _ in range(n_ops):
        op = rng.choice(["insert", "hot_insert", "delete"])
        if op == "delete" and live:
            k = min(int(rng.integers(1, 30)), len(live))
            victims = rng.choice(sorted(live), size=k, replace=False)
            idx.delete(victims.astype(np.int32))
            live -= set(int(v) for v in victims)
            continue
        k = int(rng.integers(1, 40))
        if op == "hot_insert":
            c = base[int(rng.integers(0, 300))]
            vecs = (c[None] + 0.05 * rng.normal(size=(k, 8))).astype(
                np.float32
            )
        else:
            vecs = rng.normal(size=(k, 8)).astype(np.float32)
        vids = np.arange(next_vid, next_vid + k, dtype=np.int32)
        idx.insert(vecs, vids)
        live |= set(vids.tolist())
        next_vid += k

    state0 = idx.state
    assert live_vid_set(state0) == live

    sa = _seq_drain(state0)
    sb, _, _ = lire.rebuild_drain(state0, jobs_per_round=jobs)
    for s in (sa, sb):
        check_invariants(s)
        assert live_vid_set(s) == live, "drain lost/resurrected vectors"
        lens = np.asarray(s.pool.posting_len)
        valid = np.asarray(s.centroid_valid)
        assert (lens[valid] <= cfg.split_limit).all()
    # quiescent: one more round does nothing
    _, did = lire.maintenance_round(sb, jobs)
    assert int(did) == 0


@pytest.mark.parametrize("seed,jobs", [(0, 2), (1, 4), (2, 8)])
def test_round_vs_sequential_seeded(seed, jobs):
    """Randomized churn trials that run even without hypothesis (the
    container-independent half of the round-parity gate)."""
    _random_churn_trial(small_cfg(**_PROP_CFG), seed, n_ops=3, jobs=jobs)


def test_round_vs_sequential_property():
    """Hypothesis: random insert/delete churn, then a round drain preserves
    the same invariants as the sequential step drain."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg = small_cfg(**_PROP_CFG)

    @settings(max_examples=4, deadline=None)
    @given(data=st.data())
    def inner(data):
        rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
        base = rng.normal(size=(300, 8)).astype(np.float32)
        idx = SPFreshIndex.build(cfg, base)
        live = set(range(300))
        next_vid = 300
        for _ in range(data.draw(st.integers(1, 3))):
            op = data.draw(st.sampled_from(["insert", "hot_insert", "delete"]))
            if op == "delete":
                k = min(data.draw(st.integers(1, 30)), len(live))
                victims = rng.choice(sorted(live), size=k, replace=False)
                idx.delete(victims.astype(np.int32))
                live -= set(int(v) for v in victims)
                continue
            k = data.draw(st.integers(1, 40))
            if op == "hot_insert":
                c = base[data.draw(st.integers(0, 299))]
                vecs = (c[None] + 0.05 * rng.normal(size=(k, 8))).astype(
                    np.float32
                )
            else:
                vecs = rng.normal(size=(k, 8)).astype(np.float32)
            vids = np.arange(next_vid, next_vid + k, dtype=np.int32)
            idx.insert(vecs, vids)
            live |= set(vids.tolist())
            next_vid += k

        state0 = idx.state
        live0 = live_vid_set(state0)
        assert live0 == live

        jobs = data.draw(st.sampled_from([2, 4, 8]))
        sa = _seq_drain(state0)
        sb, _, _ = lire.rebuild_drain(state0, jobs_per_round=jobs)
        for s in (sa, sb):
            check_invariants(s)
            assert live_vid_set(s) == live, "drain lost/resurrected vectors"
            lens = np.asarray(s.pool.posting_len)
            valid = np.asarray(s.centroid_valid)
            assert (lens[valid] <= cfg.split_limit).all()
        # quiescent: one more round does nothing
        _, did = lire.maintenance_round(sb, jobs)
        assert int(did) == 0

    inner()


def test_round_one_readback_counts(rng=None):
    """rebuild_drain reports rounds ≈ jobs/jobs_per_round host syncs."""
    rng = np.random.default_rng(21)
    base = clustered(rng, 1000)
    idx = SPFreshIndex.build(small_cfg(), base)
    # backlog WITHOUT maintenance (max_retries=0 skips insert backpressure)
    centroid = np.asarray(idx.state.centroids)[
        np.asarray(idx.state.centroid_valid)
    ]
    hot = np.concatenate([
        (c[None, :] + 0.05 * rng.normal(size=(40, 16))).astype(np.float32)
        for c in centroid[:6]
    ])
    idx.insert(hot, np.arange(4000, 4000 + len(hot), dtype=np.int32),
               max_retries=0)
    assert idx.backlog() >= 2, "churn failed to build a multi-job backlog"
    s4, jobs4, rounds4 = lire.rebuild_drain(idx.state, jobs_per_round=4)
    s1, jobs1, rounds1 = lire.rebuild_drain(idx.state, jobs_per_round=1)
    assert jobs4 >= 2 and jobs1 >= 2
    assert rounds4 < rounds1, (rounds4, rounds1)
    # engine surfaces rounds
    from repro.serve.engine import EngineConfig, ServeEngine

    eng = ServeEngine(SPFreshIndex(idx.state), EngineConfig())
    eng.drain()
    rep = eng.report()
    assert rep["maintenance"]["rounds"] >= 1
    assert "insert_stall_s" in rep


def test_merge_fanout_is_threaded(monkeypatch=None):
    """merge_fanout=1 must still merge into the single nearest posting."""
    rng = np.random.default_rng(5)
    base = clustered(rng, 600, n_clusters=5)
    for fanout in (1, 6):
        cfg = small_cfg(merge_fanout=fanout)
        idx = SPFreshIndex.build(cfg, base)
        d = ((base - base[0]) ** 2).sum(-1)
        victims = np.argsort(d)[:200]
        idx.delete(victims.astype(np.int32))
        idx.maintain()
        check_invariants(idx.state)
        _, got = idx.search(base[victims[:8]], 5)
        leaked = set(got.reshape(-1).tolist()) & set(victims[:8].tolist())
        assert not leaked
