"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import lire
from repro.core.index import SPFreshIndex
from repro.core.types import LireConfig
from repro.storage import blockpool as bp
from repro.storage import versionmap as vm

SETTINGS = dict(max_examples=15, deadline=None)


# ---------------------------------------------------------------------------
# Version map
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    ops=st.lists(
        st.tuples(st.sampled_from(["bump", "delete", "clear"]),
                  st.integers(0, 6)),
        max_size=30,
    )
)
def test_versionmap_model(ops):
    """The uint8 bit-twiddling matches a reference dict model."""
    versions = jnp.zeros(8, jnp.uint8)  # 7 usable + scratch
    model = {i: {"ver": 0, "del": False} for i in range(7)}
    for op, vid in ops:
        ids = jnp.asarray([vid])
        if op == "bump":
            versions = vm.bump_version(versions, ids)
            model[vid]["ver"] = (model[vid]["ver"] + 1) % 128
        elif op == "delete":
            versions = vm.mark_deleted(versions, ids)
            model[vid]["del"] = True
        else:
            versions = vm.clear(versions, ids)
            model[vid] = {"ver": 0, "del": False}
    for i in range(7):
        assert int(versions[i] & vm.VERSION_MASK) == model[i]["ver"]
        assert bool(versions[i] & vm.DELETED_BIT) == model[i]["del"]
        stale = vm.is_stale(
            versions, jnp.asarray([i]),
            jnp.asarray([model[i]["ver"]], jnp.uint8),
        )
        assert bool(stale[0]) == model[i]["del"]


# ---------------------------------------------------------------------------
# Block pool
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    appends=st.lists(st.integers(0, 3), min_size=1, max_size=60),
)
def test_blockpool_append_accounting(appends):
    """posting_len == successful appends; gather returns exactly those vids;
    used_blocks == Σ ceil(len/BS)."""
    pool = bp.make_block_pool(
        num_blocks=32, block_size=4, dim=4, num_postings_cap=4,
        max_blocks_per_posting=4,
    )
    model = {p: [] for p in range(4)}
    for i, pid in enumerate(appends):
        pool, ok = bp.append_one(
            pool, jnp.asarray(pid), jnp.full((4,), float(i)),
            jnp.asarray(i), jnp.asarray(0, jnp.uint8), jnp.asarray(True),
        )
        if bool(ok):
            model[pid].append(i)
    total_blocks = 0
    for pid in range(4):
        assert int(pool.posting_len[pid]) == len(model[pid])
        _, vids, _, valid = bp.gather_posting(pool, jnp.asarray(pid))
        got = set(np.asarray(vids)[np.asarray(valid)].tolist())
        assert got == set(model[pid])
        total_blocks += -(-len(model[pid]) // 4) if model[pid] else 0
    assert int(bp.used_blocks(pool)) == total_blocks


@settings(**SETTINGS)
@given(
    n1=st.integers(0, 16), n2=st.integers(0, 16),
)
def test_blockpool_put_free_conservation(n1, n2):
    """PUT twice then free: the free pool returns to its initial size."""
    pool = bp.make_block_pool(
        num_blocks=16, block_size=4, dim=2, num_postings_cap=2,
        max_blocks_per_posting=4,
    )
    start_free = int(pool.free_top)
    cap = pool.posting_capacity
    buf = jnp.zeros((cap, 2))
    vids = jnp.arange(cap, dtype=jnp.int32)
    vers = jnp.zeros(cap, jnp.uint8)
    pool, ok1 = bp.put_posting(pool, jnp.asarray(0), buf, vids, vers,
                               jnp.asarray(n1), jnp.asarray(True))
    pool, ok2 = bp.put_posting(pool, jnp.asarray(0), buf, vids, vers,
                               jnp.asarray(n2), jnp.asarray(True))
    pool = bp.free_posting(pool, jnp.asarray(0), jnp.asarray(True))
    assert int(pool.free_top) == start_free
    assert int(pool.posting_len[0]) == 0


# ---------------------------------------------------------------------------
# Search / dedup
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    dists=st.lists(st.floats(0, 100, allow_nan=False), min_size=8, max_size=8),
    vids=st.lists(st.integers(0, 3), min_size=8, max_size=8),
)
def test_dedup_topk_no_duplicates_and_sorted(dists, vids):
    d = jnp.asarray(dists, jnp.float32)
    v = jnp.asarray(vids, jnp.int32)
    live = jnp.ones(8, bool)
    top_d, top_v = lire._dedup_topk_1d(d, v, live, 4, 8)
    top_d, top_v = np.asarray(top_d), np.asarray(top_v)
    real = top_v[top_v >= 0]
    assert len(real) == len(set(real.tolist())), "duplicate vid survived"
    fin = top_d[top_v >= 0]
    assert (np.diff(fin) >= -1e-6).all(), "not sorted"
    # each returned vid's distance == its minimum input distance
    for dd, vv in zip(top_d, top_v):
        if vv >= 0:
            want = min(ds for ds, vs in zip(dists, vids) if vs == vv)
            assert abs(dd - want) < 1e-4


# ---------------------------------------------------------------------------
# LIRE end-to-end invariants under random op sequences
# ---------------------------------------------------------------------------

def _small_cfg():
    return LireConfig(
        dim=8, block_size=4, max_blocks_per_posting=8, num_blocks=1024,
        num_postings_cap=128, num_vectors_cap=2048, split_limit=24,
        merge_limit=4, reassign_range=4, reassign_budget=64,
        replica_count=2, nprobe=8,
    )


@settings(max_examples=5, deadline=None)
@given(data=st.data())
def test_lire_invariants_random_ops(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
    base = rng.normal(size=(300, 8)).astype(np.float32)
    idx = SPFreshIndex.build(_small_cfg(), base)
    live = set(range(300))
    next_vid = 300
    for _ in range(data.draw(st.integers(1, 4))):
        op = data.draw(st.sampled_from(["insert", "delete", "maintain"]))
        if op == "insert":
            k = data.draw(st.integers(1, 40))
            vecs = rng.normal(size=(k, 8)).astype(np.float32)
            vids = np.arange(next_vid, next_vid + k, dtype=np.int32)
            idx.insert(vecs, vids)
            live |= set(vids.tolist())
            next_vid += k
        elif op == "delete" and live:
            k = min(data.draw(st.integers(1, 20)), len(live))
            victims = rng.choice(sorted(live), size=k, replace=False)
            idx.delete(victims.astype(np.int32))
            live -= set(int(v) for v in victims)
        else:
            idx.maintain(max_steps=16)
    idx.maintain()

    state = idx.state
    cfg = state.cfg
    lens = np.asarray(state.pool.posting_len)
    valid = np.asarray(state.centroid_valid)
    # 1. no posting over hard capacity; post-maintenance none over the limit
    assert (lens[valid] <= cfg.posting_capacity).all()
    assert (lens[valid] <= cfg.split_limit).all()
    # 2. block accounting: used + free == total
    used = int(bp.used_blocks(state.pool))
    blocks_by_len = int(
        sum(-(-int(l) // cfg.block_size) for l in lens[valid] if l > 0)
    )
    assert used == blocks_by_len
    # 3. pid accounting
    assert int(state.n_postings) == cfg.num_postings_cap - int(state.pid_free_top)
    # 4. deleted vids never surface
    if live and len(live) > 10:
        some = rng.choice(sorted(live), size=8, replace=False)
        all_data = np.concatenate([base, rng.normal(size=(next_vid - 300, 8))]).astype(np.float32)
        _, got = idx.search(all_data[some], 5)
        dead = set(range(next_vid)) - live
        leaked = set(got.reshape(-1).tolist()) & dead
        assert not leaked, f"deleted vids leaked: {leaked}"
