"""Read-replica replication: routing, freshness bound, window, catch-up.

Two layers:

1. **Logic tests** (tier-1, fast) — ReplicaSet's routing/window/catch-up
   machinery driven through duck-typed fake backends, so round-robin
   order, the inflight cap, the ``max_lag`` freshness bound, window
   eviction → ``_GAP``, ordered replay, and failure rerouting are each
   pinned deterministically without building an index.
2. **Service gates** (``gate`` marker, run as an explicit check.sh
   step) — a real replicated durable service: bit-parity at equal seqno,
   induced-lag fallback, snapshot catch-up, parity through checkpoint
   and crash recovery; plus the 2-shard × 2-replica mesh suite in a
   4-fake-device subprocess (``replica_script.py``).
"""
import dataclasses
import os
import subprocess
import sys
import time
from contextlib import contextmanager

import numpy as np
import pytest

from repro.distributed.replication import _GAP, ReplicaSet, states_equal
from repro.serve.queue import MicroBatch
from repro.storage.wal import WalRecord


# ---------------------------------------------------------------------------
# Fakes
# ---------------------------------------------------------------------------

class FakeBackend:
    """Duck-typed DurableBackend: ordered replay + forkable state."""

    def __init__(self, marker: int = 0):
        self.marker = marker
        self._wal_applied = -1
        self.replayed: list[WalRecord] = []
        self.adopted = None

    def replay(self, records, after_seqno: int = -1) -> int:
        n = 0
        for r in records:
            if r.seqno <= after_seqno:
                continue
            assert r.seqno == self._wal_applied + 1, (
                "out-of-order replay", r.seqno, self._wal_applied)
            self.replayed.append(r)
            self._wal_applied = r.seqno
            n += 1
        return n

    def search(self, queries, k, nprobe, valid=None):
        n = len(queries)
        return (np.zeros((n, k), np.float32),
                np.full((n, k), self.marker, np.int32))

    def fork_state(self):
        return ("fork", self._wal_applied)

    def adopt_state(self, state):
        self.adopted = state


class FailingBackend(FakeBackend):
    def search(self, queries, k, nprobe, valid=None):
        raise RuntimeError("replica scan exploded")


class FakeQueue:
    def __init__(self):
        self.requeued = []

    def requeue(self, parts):
        self.requeued.append(list(parts))


class FakeEngine:
    def __init__(self):
        self.queue = FakeQueue()
        self.metrics = type("M", (), {"note_ticket": lambda s, t: None})()

    @contextmanager
    def exclusive(self):
        yield


def rec(seqno: int) -> WalRecord:
    return WalRecord("delete", {"vids": np.asarray([seqno])}, seqno)


def search_batch(n: int = 4, k: int = 5) -> MicroBatch:
    return MicroBatch(
        op="search", key=(k, None), parts=[],
        arrays={"queries": np.zeros((n, 4), np.float32)},
        n_valid=n, bucket=n,
    )


def make_set(n_replicas=1, *, cls=FakeBackend, **kw) -> ReplicaSet:
    primary = FakeBackend(marker=-1)
    return ReplicaSet(
        primary, [cls(marker=i) for i in range(n_replicas)], **kw
    )


# ---------------------------------------------------------------------------
# Routing (workers never started: pure bookkeeping)
# ---------------------------------------------------------------------------

def test_route_round_robins_over_replicas():
    rs = make_set(2, inflight=8)
    for _ in range(4):
        assert rs.route(search_batch())
    assert [len(r.batches) for r in rs.replicas] == [2, 2]
    assert rs.routed == 4 and rs.fallback == 0
    assert [r.inflight for r in rs.replicas] == [2, 2]


def test_route_ignores_non_search_ops():
    rs = make_set(1)
    assert not rs.route(MicroBatch(
        op="insert", key=(), parts=[], arrays={}, n_valid=4, bucket=4))
    assert rs.routed == 0 and rs.fallback == 0   # not even counted


def test_route_inflight_cap_then_fallback():
    rs = make_set(2, inflight=1)
    assert rs.route(search_batch()) and rs.route(search_batch())
    assert not rs.route(search_batch())          # both at the cap
    assert rs.fallback == 1 and rs.routed == 2


def test_route_skips_replica_past_max_lag():
    rs = make_set(2, max_lag=3, inflight=8)
    rs.primary._wal_applied = 10
    rs.replicas[0].backend._wal_applied = 5      # lag 5 > 3: stale
    rs.replicas[1].backend._wal_applied = 8      # lag 2: fresh
    for _ in range(3):
        assert rs.route(search_batch())
    assert len(rs.replicas[0].batches) == 0
    assert len(rs.replicas[1].batches) == 3
    # everyone stale: fallback to the primary
    rs.replicas[1].backend._wal_applied = 0
    assert not rs.route(search_batch())
    assert rs.fallback == 1


def test_route_skips_failed_replica():
    rs = make_set(2, inflight=8)
    rs.replicas[0].error = RuntimeError("dead")
    for _ in range(3):
        assert rs.route(search_batch())
    assert len(rs.replicas[1].batches) == 3


def test_route_copies_out_of_staging_buffers():
    """The queue reuses per-bucket staging arrays: a routed batch must
    hold its own copy or the next pop overwrites the queries under the
    replica worker."""
    rs = make_set(1)
    b = search_batch()
    staging = b.arrays["queries"]
    assert rs.route(b)
    staging[:] = 7.0                             # simulate buffer reuse
    routed = rs.replicas[0].batches[0]
    assert not np.shares_memory(routed.arrays["queries"], staging)
    assert (routed.arrays["queries"] == 0.0).all()


# ---------------------------------------------------------------------------
# Window / publish / gap detection
# ---------------------------------------------------------------------------

def test_publish_window_is_bounded_and_gap_detected():
    rs = make_set(1, window=4)
    for s in range(10):
        rs.publish(s, "delete", {"vids": np.asarray([s])})
    assert [r.seqno for r in rs._window] == [6, 7, 8, 9]
    r = rs.replicas[0]
    assert rs._next_record(r) is _GAP            # cursor -1, tail evicted
    r.backend._wal_applied = 6
    nxt = rs._next_record(r)
    assert nxt is not _GAP and nxt.seqno == 7    # contiguous from 6
    r.backend._wal_applied = 9
    assert rs._next_record(r) is None            # caught up
    assert rs.published == 10


def test_publish_copies_payload_arrays():
    rs = make_set(1, window=8)
    vids = np.asarray([1, 2, 3])
    rs.publish(0, "delete", {"vids": vids})
    vids[:] = -9                                 # engine reuses the buffer
    np.testing.assert_array_equal(rs._window[0].payload["vids"], [1, 2, 3])


def test_worker_replays_in_seqno_order_and_redelivery_is_noop():
    rs = make_set(1, window=64)
    rs.start()
    try:
        for s in range(20):
            rs.primary._wal_applied = s
            rs.publish(s, "delete", {"vids": np.asarray([s])})
        rs.wait_sync(timeout=10)
        r = rs.replicas[0]
        assert [x.seqno for x in r.backend.replayed] == list(range(20))
        # redelivery (at-least-once window semantics) must not re-apply
        assert r.backend.replay([rec(3), rec(19)], after_seqno=r.applied) == 0
        assert r.applied == 19
    finally:
        rs.stop()


def test_catch_up_forks_primary_on_window_overflow():
    rs = make_set(1, window=2)
    rs.pause(0)
    rs.start()
    try:
        for s in range(8):
            rs.primary._wal_applied = s
            rs.publish(s, "delete", {"vids": np.asarray([s])})
        rs.resume(0)
        rs.wait_sync(timeout=10)
        r = rs.replicas[0]
        assert r.catchups >= 1
        assert r.backend.adopted == ("fork", 7)  # forked AT the head seqno
        assert r.applied == 7
        assert rs.report()["per_replica"][0]["lag"] == 0
    finally:
        rs.stop()


def test_failed_worker_reroutes_pending_batches():
    rs = make_set(1, cls=FailingBackend, inflight=8)
    eng = FakeEngine()
    rs.bind(eng)
    b1 = search_batch()
    b2 = dataclasses.replace(search_batch(), parts=["p2"])
    b3 = dataclasses.replace(search_batch(), parts=["p3"])
    for b in (b1, b2, b3):
        assert rs.route(b)
    rs.start()
    try:
        deadline = time.monotonic() + 10
        while rs.replicas[0].error is None:
            assert time.monotonic() < deadline, "replica never failed"
            time.sleep(0.005)
    finally:
        rs.stop()
    # b1 crashed in-flight; b2/b3 were handed back to the engine queue
    assert eng.queue.requeued == [["p2"], ["p3"]]
    # a failed replica is out of rotation: the next route falls back
    assert not rs.route(search_batch())
    assert rs.fallback == 1


def test_wait_sync_times_out_on_a_stuck_replica():
    rs = make_set(1)
    rs.primary._wal_applied = 5
    with pytest.raises(TimeoutError):
        rs.wait_sync(timeout=0.05)


def test_report_shape():
    rs = make_set(2, max_lag=7, inflight=3, window=32)
    rs.primary._wal_applied = 4
    rep = rs.report()
    assert rep["n_replicas"] == 3                # total copies incl. primary
    assert rep["max_lag"] == 7 and rep["inflight_cap"] == 3
    assert rep["window"] == 32 and rep["primary_seqno"] == 4
    assert [x["lag"] for x in rep["per_replica"]] == [5, 5]


def test_states_equal_is_bitwise():
    a = {"x": np.arange(4, dtype=np.float32), "y": np.ones(2, np.int32)}
    b = {"x": np.arange(4, dtype=np.float32), "y": np.ones(2, np.int32)}
    assert states_equal(a, b)
    b["y"] = np.ones(2, np.int64)                # dtype drift
    assert not states_equal(a, b)
    b["y"] = np.asarray([1, 2], np.int32)        # value drift
    assert not states_equal(a, b)


# ---------------------------------------------------------------------------
# Real-service gates
# ---------------------------------------------------------------------------

@pytest.fixture
def replicated_spec(tmp_path):
    from tests.test_service_api import tiny_spec

    spec = tiny_spec(tmp_path / "svc")
    spec = dataclasses.replace(
        spec, serve=dataclasses.replace(spec.serve, async_serve=True)
    )
    return spec.with_replicas(2, max_lag=4)


@pytest.mark.gate
def test_replicated_service_parity_fallback_catchup_recovery(
        replicated_spec, rng):
    """The local-backend end-to-end gate: one durable replicated service
    through the full replica lifecycle — parity at equal seqno, the
    freshness-bound fallback under induced lag, window-overflow snapshot
    catch-up, parity across a primary checkpoint, and a recovery reopen
    whose replicas start bit-identical at the recovered seqno."""
    import spfresh
    from tests.conftest import make_clustered

    base = make_clustered(rng, 600, 16, n_clusters=4)
    svc = spfresh.open(replicated_spec, vectors=base)
    rs = svc.replicas
    assert rs is not None and len(rs.replicas) == 1

    # parity at equal seqno
    vecs = make_clustered(rng, 24, 16, n_clusters=2)
    for s in range(0, 24, 8):
        svc.insert(vecs[s:s + 8],
                   np.arange(2000 + s, 2008 + s, dtype=np.int32))
    svc.drain()
    rs.wait_sync()
    assert states_equal(svc.backend.index.state,
                        rs.replicas[0].backend.index.state)

    # routed searches answer like the primary at equal seqno
    routed0 = rs.routed
    q = np.concatenate([vecs[:8], base[:8]])
    d0, v0 = svc.search(q, k=10)
    assert rs.routed > routed0
    with svc.engine.exclusive():
        dp, vp = svc.backend.search(q, 10, None)
    np.testing.assert_array_equal(v0, np.asarray(vp))
    np.testing.assert_allclose(d0, np.asarray(dp), rtol=1e-5)

    # induced lag beyond max_lag: searches fall back to the primary
    rs.pause(0)
    wave = make_clustered(rng, 24, 16, n_clusters=2)
    for s in range(0, 24, 4):                    # 6 dispatches > max_lag=4
        svc.insert(wave[s:s + 4],
                   np.arange(3000 + s, 3004 + s, dtype=np.int32))
    svc.drain()
    assert rs.report()["per_replica"][0]["lag"] > replicated_spec.serve.max_lag
    fb0, routed1 = rs.fallback, rs.routed
    _, hit = svc.search(wave[:6], k=1)
    assert rs.fallback > fb0 and rs.routed == routed1
    assert (hit[:, 0] == np.arange(3000, 3006)).all()   # primary answered

    # window overflow while paused → snapshot catch-up on resume
    rs.window_cap = 4
    for s in range(5):
        svc.insert(make_clustered(rng, 4, 16),
                   np.arange(4000 + 4 * s, 4004 + 4 * s, dtype=np.int32))
    svc.drain()
    rs.resume(0)
    rs.wait_sync()
    rep = rs.report()["per_replica"][0]
    assert rep["catchups"] >= 1 and rep["lag"] == 0
    assert states_equal(svc.backend.index.state,
                        rs.replicas[0].backend.index.state)

    # a primary checkpoint (dirty-ledger bookkeeping) must not break parity
    svc.checkpoint()
    svc.insert(make_clustered(rng, 8, 16),
               np.arange(5000, 5008, dtype=np.int32))
    svc.drain()
    rs.wait_sync()
    assert states_equal(svc.backend.index.state,
                        rs.replicas[0].backend.index.state)
    want = svc.search(q, k=10)
    svc.close()

    # recovery: replicas of a reopened service start bit-identical at the
    # recovered seqno and serve immediately
    twin = spfresh.open(replicated_spec)
    assert twin.recovered
    rs2 = twin.replicas
    assert states_equal(twin.backend.index.state,
                        rs2.replicas[0].backend.index.state)
    assert rs2.replicas[0].applied == int(twin.backend._wal_applied)
    got = twin.search(q, k=10)
    np.testing.assert_array_equal(want[1], got[1])
    np.testing.assert_allclose(want[0], got[0], rtol=1e-5)
    twin.close()


@pytest.mark.gate
def test_ephemeral_replication_mints_local_seqnos(rng):
    """No durable root: ``_log`` mints a contiguous local seqno stream so
    replicas stay consistent without a WAL (sync engine: routing happens
    on the cooperative pump path too)."""
    import spfresh
    from tests.conftest import make_clustered
    from tests.test_service_api import tiny_spec

    spec = tiny_spec().with_replicas(2, max_lag=8)
    base = make_clustered(rng, 500, 16, n_clusters=4)
    svc = spfresh.open(spec, vectors=base)
    rs = svc.replicas
    assert svc.backend.wal_set is None
    vecs = make_clustered(rng, 16, 16)
    for s in range(0, 16, 8):
        svc.insert(vecs[s:s + 8],
                   np.arange(2000 + s, 2008 + s, dtype=np.int32))
    svc.drain()
    rs.wait_sync()
    assert rs.report()["primary_seqno"] >= 1     # minted, not WAL-assigned
    assert states_equal(svc.backend.index.state,
                        rs.replicas[0].backend.index.state)
    routed0 = rs.routed
    _, hit = svc.search(vecs[:8], k=1)
    assert rs.routed > routed0                   # sync pump routed it
    assert (hit[:, 0] == np.arange(2000, 2008)).all()
    svc.close()


@pytest.mark.gate
@pytest.mark.slow
def test_replicas_over_two_shard_two_replica_mesh(tmp_path):
    """The replica-aware CI leg: 2 shards × 2 replicas on a 4-fake-device
    (data, model) mesh, in a subprocess so the main pytest process keeps
    one device."""
    script = os.path.join(os.path.dirname(__file__), "replica_script.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, script, str(tmp_path)],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0
    assert "ALL_REPLICA_PASS" in proc.stdout
