"""Data substrate: workload generators behave per paper §5.1."""
import numpy as np

from repro.data.vectors import (
    UpdateWorkload,
    make_shifting_stream,
    make_sift_like,
    make_spacev_like,
)


def test_workload_epoch_semantics():
    wl = UpdateWorkload.spacev(n=1000, dim=8, rate=0.01, seed=0)
    live0 = set(wl.live_ids().tolist())
    assert len(live0) == 1000
    del_vids, ins_vecs, ins_vids = wl.epoch()
    assert len(del_vids) == 10 and len(ins_vids) == 10  # 1% each
    live1 = set(wl.live_ids().tolist())
    assert live1 == (live0 - set(del_vids.tolist())) | set(ins_vids.tolist())
    assert len(live1) == 1000
    # inserted ids are fresh
    assert not (set(ins_vids.tolist()) & live0)


def test_workload_queries_have_valid_gt():
    wl = UpdateWorkload.sift(n=500, dim=8, seed=1)
    wl.epoch()
    q, gt = wl.queries(16)
    assert q.shape == (16, 8) and gt.shape == (16, 10)
    live = set(wl.live_ids().tolist())
    assert set(gt.reshape(-1).tolist()).issubset(live)


def test_skew_vs_uniform_distributions():
    """SPACEV-like data must be measurably more cluster-skewed than
    SIFT-like (the paper's central data property)."""
    from repro.core.clustering import hierarchical_balanced_kmeans

    uni = make_sift_like(3000, 8, seed=2)
    skew = make_spacev_like(3000, 8, seed=2)

    def cluster_mass_cv(x):
        _, assign = hierarchical_balanced_kmeans(x, max_posting_size=3000,
                                                 branch=8, seed=0)
        # one-level split: measure geometric imbalance instead via
        # distance-to-mean spread of 8-means masses
        import jax
        import jax.numpy as jnp
        from repro.core.clustering import balanced_kmeans

        _, a = balanced_kmeans(
            jax.random.PRNGKey(0), jnp.asarray(x), jnp.ones(len(x), bool),
            k=8, balance_weight=0.0,
        )
        counts = np.bincount(np.asarray(a), minlength=8)
        return counts.std() / counts.mean()

    assert cluster_mass_cv(skew) > cluster_mass_cv(uni)


def test_shifting_stream_is_hot():
    """The shift stream is denser (hot regions) than a uniform stream —
    measured as median 10-NN distance over a sample."""

    def density(x, sample=200):
        rng = np.random.default_rng(0)
        sel = rng.integers(0, len(x), sample)
        d = ((x[sel][:, None, :] - x[None]) ** 2).sum(-1)
        knn = np.sort(d, axis=1)[:, 10]  # 10th NN (0th is self)
        return float(np.median(knn))

    hot = density(make_shifting_stream(2000, 8, seed=3, hot_fraction=0.8))
    uni = density(make_sift_like(2000, 8, seed=3))
    assert hot < uni * 0.5, (hot, uni)


def test_deterministic_replay():
    a = UpdateWorkload.spacev(n=300, dim=8, seed=5)
    b = UpdateWorkload.spacev(n=300, dim=8, seed=5)
    for _ in range(3):
        da, ia, va = a.epoch()
        db, ib, vb = b.epoch()
        np.testing.assert_array_equal(da, db)
        np.testing.assert_array_equal(va, vb)
        np.testing.assert_allclose(ia, ib)
