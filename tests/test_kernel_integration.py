"""End-to-end: the Pallas l2_topk kernel drives the real index search
(interpret mode) and matches the XLA navigation path."""
import dataclasses

import numpy as np

from repro.core.index import SPFreshIndex, build_state
from tests.conftest import make_clustered
from tests.test_lire import small_cfg


def test_search_with_pallas_navigation_matches(rng):
    base = make_clustered(rng, 600, 16, n_clusters=6)
    cfg = small_cfg()
    state = build_state(cfg, base)
    idx_xla = SPFreshIndex(state)
    idx_pl = SPFreshIndex(
        state.replace(cfg=dataclasses.replace(cfg, use_pallas_nav=True))
    )
    queries = base[:24] + 0.01 * rng.normal(size=(24, 16)).astype(np.float32)
    d0, v0 = idx_xla.search(queries, 10)
    d1, v1 = idx_pl.search(queries, 10)
    np.testing.assert_allclose(d0, d1, rtol=1e-3, atol=1e-3)
    overlap = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / 10 for a, b in zip(v0, v1)
    ])
    assert overlap > 0.95, overlap


def test_insert_with_pallas_routing(rng):
    base = make_clustered(rng, 400, 16, n_clusters=4)
    cfg = dataclasses.replace(small_cfg(), use_pallas_nav=True)
    idx = SPFreshIndex.build(cfg, base)
    new = make_clustered(rng, 20, 16, n_clusters=2)
    ids = np.arange(2000, 2020, dtype=np.int32)
    idx.insert(new, ids)
    _, got = idx.search(new, 5)
    found = sum(int(ids[i]) in got[i].tolist() for i in range(20))
    assert found >= 18, found
