import jax.numpy as jnp
import numpy as np

from repro.storage import blockpool as bp


def make_pool(**kw):
    args = dict(
        num_blocks=32, block_size=4, dim=8, num_postings_cap=8,
        max_blocks_per_posting=4,
    )
    args.update(kw)
    return bp.make_block_pool(**args)


def _append(pool, pid, vec, vid, ver=0, enable=True):
    return bp.append_one(
        pool,
        jnp.asarray(pid, jnp.int32),
        jnp.asarray(vec, jnp.float32),
        jnp.asarray(vid, jnp.int32),
        jnp.asarray(ver, jnp.uint8),
        jnp.asarray(enable),
    )


def test_append_and_gather_roundtrip(rng):
    pool = make_pool()
    vecs = rng.normal(size=(6, 8)).astype(np.float32)
    for i in range(6):
        pool, ok = _append(pool, 2, vecs[i], 100 + i)
        assert bool(ok)
    out_vecs, out_vids, out_vers, valid = bp.gather_posting(pool, jnp.asarray(2))
    valid = np.asarray(valid)
    assert valid.sum() == 6
    np.testing.assert_allclose(np.asarray(out_vecs)[valid], vecs, rtol=1e-6)
    assert set(np.asarray(out_vids)[valid].tolist()) == {100 + i for i in range(6)}


def test_append_allocates_blocks_lazily(rng):
    pool = make_pool()
    start_free = int(pool.free_top)
    pool, _ = _append(pool, 0, np.zeros(8), 1)
    assert int(pool.free_top) == start_free - 1
    # 3 more appends fill the block; no new allocation
    for i in range(3):
        pool, _ = _append(pool, 0, np.zeros(8), 2 + i)
    assert int(pool.free_top) == start_free - 1
    pool, _ = _append(pool, 0, np.zeros(8), 9)
    assert int(pool.free_top) == start_free - 2


def test_append_posting_capacity_drop(rng):
    pool = make_pool()
    for i in range(16):  # capacity = 4*4
        pool, ok = _append(pool, 1, np.zeros(8), i)
        assert bool(ok)
    pool, ok = _append(pool, 1, np.zeros(8), 99)
    assert not bool(ok)
    assert int(pool.posting_len[1]) == 16


def test_pool_oom_returns_not_ok(rng):
    pool = make_pool(num_blocks=1)
    pool, ok = _append(pool, 0, np.zeros(8), 0)
    assert bool(ok)
    pool, ok = _append(pool, 1, np.zeros(8), 1)  # needs a second block
    assert not bool(ok)


def test_put_and_free_posting(rng):
    pool = make_pool()
    cap = pool.posting_capacity
    vecs = rng.normal(size=(cap, 8)).astype(np.float32)
    vids = np.arange(cap, dtype=np.int32)
    vers = np.zeros(cap, np.uint8)
    pool, ok = bp.put_posting(
        pool, jnp.asarray(3), jnp.asarray(vecs), jnp.asarray(vids),
        jnp.asarray(vers), jnp.asarray(10), jnp.asarray(True),
    )
    assert bool(ok)
    assert int(pool.posting_len[3]) == 10
    out_vecs, out_vids, _, valid = bp.gather_posting(pool, jnp.asarray(3))
    assert np.asarray(valid).sum() == 10
    np.testing.assert_allclose(
        np.asarray(out_vecs)[np.asarray(valid)], vecs[:10], rtol=1e-6
    )
    used_before = int(bp.used_blocks(pool))
    pool = bp.free_posting(pool, jnp.asarray(3), jnp.asarray(True))
    assert int(pool.posting_len[3]) == 0
    assert int(bp.used_blocks(pool)) == used_before - 3  # ceil(10/4) freed


def test_put_overwrites_and_releases_old_blocks(rng):
    pool = make_pool()
    cap = pool.posting_capacity
    buf = lambda: (
        jnp.asarray(rng.normal(size=(cap, 8)).astype(np.float32)),
        jnp.asarray(np.arange(cap, dtype=np.int32)),
        jnp.asarray(np.zeros(cap, np.uint8)),
    )
    v, i, r = buf()
    pool, _ = bp.put_posting(pool, jnp.asarray(0), v, i, r, jnp.asarray(16), jnp.asarray(True))
    used = int(bp.used_blocks(pool))
    v, i, r = buf()
    pool, _ = bp.put_posting(pool, jnp.asarray(0), v, i, r, jnp.asarray(4), jnp.asarray(True))
    assert int(bp.used_blocks(pool)) == used - 3  # 4 blocks -> 1 block


def test_append_batch_sequential_collisions(rng):
    pool = make_pool()
    n = 10
    pids = jnp.zeros(n, jnp.int32)
    vecs = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
    vids = jnp.arange(n, dtype=jnp.int32)
    vers = jnp.zeros(n, jnp.uint8)
    enable = jnp.ones(n, bool)
    pool, oks = bp.append_batch(pool, pids, vecs, vids, vers, enable)
    assert np.asarray(oks).all()
    assert int(pool.posting_len[0]) == n


def test_disabled_append_is_noop(rng):
    pool = make_pool()
    pool2, ok = _append(pool, 0, np.ones(8), 5, enable=False)
    assert not bool(ok)
    assert int(pool2.posting_len[0]) == 0
    assert int(pool2.free_top) == int(pool.free_top)


# ---------------------------------------------------------------------------
# Dirty tracking (delta-snapshot ledger)
# ---------------------------------------------------------------------------

def _changed_blocks(before, after):
    """Block ids whose payload or slot metadata differ between pools."""
    diff = (
        (np.asarray(before.blocks) != np.asarray(after.blocks)).any((1, 2))
        | (np.asarray(before.block_vid) != np.asarray(after.block_vid)).any(1)
        | (np.asarray(before.block_ver) != np.asarray(after.block_ver)).any(1)
    )
    return set(np.flatnonzero(diff).tolist())


def test_dirty_starts_clean_and_append_marks(rng):
    pool = make_pool()
    assert not np.asarray(pool.dirty).any()
    before = pool
    pool, ok = _append(pool, 2, rng.normal(size=8), 7)
    assert bool(ok)
    marked = set(np.flatnonzero(np.asarray(pool.dirty)).tolist())
    assert _changed_blocks(before, pool) <= marked and marked
    pool2 = bp.clear_dirty(pool)
    assert not np.asarray(pool2.dirty).any()


def test_dirty_covers_every_write_path(rng):
    """Every block whose content changed since clear_dirty must be marked
    — the delta-snapshot correctness invariant (a changed-but-clean block
    would silently vanish from the recovery chain)."""
    pool = make_pool(num_blocks=64, num_postings_cap=16)
    cap = pool.posting_capacity
    # seed three postings through different paths, then clear the ledger
    vecs = rng.normal(size=(cap, 8)).astype(np.float32)
    vids = np.arange(cap, dtype=np.int32)
    for pid in (0, 1, 2):
        pool, ok = bp.put_posting(
            pool, jnp.asarray(pid), jnp.asarray(vecs),
            jnp.asarray(vids + 100 * pid),
            jnp.zeros(cap, jnp.uint8), jnp.asarray(10), jnp.asarray(True),
        )
        assert bool(ok)
    pool = bp.clear_dirty(pool)
    before = pool

    # batched appends (scatter), bulk PUT rewrite, batched frees
    pool, oks = bp.append_scatter(
        pool, jnp.asarray([0, 0, 1], jnp.int32),
        jnp.asarray(rng.normal(size=(3, 8)), jnp.float32),
        jnp.asarray([500, 501, 502], jnp.int32),
        jnp.zeros(3, jnp.uint8), jnp.ones(3, bool),
    )
    assert np.asarray(oks).all()
    pool, ok = bp.put_postings(
        pool, jnp.asarray([2], jnp.int32),
        jnp.asarray(vecs[None], jnp.float32),
        jnp.asarray(vids[None] + 900, jnp.int32),
        jnp.zeros((1, cap), jnp.uint8), jnp.asarray([6], jnp.int32),
        jnp.ones(1, bool),
    )
    assert np.asarray(ok).all()
    pool = bp.free_postings(
        pool, jnp.asarray([1], jnp.int32), jnp.ones(1, bool)
    )
    marked = set(np.flatnonzero(np.asarray(pool.dirty)).tolist())
    changed = _changed_blocks(before, pool)
    assert changed <= marked, f"changed-but-clean blocks {changed - marked}"
    assert marked, "write paths marked nothing dirty"


def test_dirty_scatter_matches_sequential_appends(rng):
    """append_scatter and append_batch mark the same dirty set for the
    same landed rows (parity of the ledger, not just the payload)."""
    pids = jnp.asarray([0, 1, 0, 2, 1, 0], jnp.int32)
    vecs = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    vids = jnp.arange(6, dtype=jnp.int32)
    vers = jnp.zeros(6, jnp.uint8)
    en = jnp.ones(6, bool)
    p_seq, ok_a = bp.append_batch(make_pool(), pids, vecs, vids, vers, en)
    p_sc, ok_b = bp.append_scatter(make_pool(), pids, vecs, vids, vers, en)
    np.testing.assert_array_equal(np.asarray(ok_a), np.asarray(ok_b))
    np.testing.assert_array_equal(
        np.asarray(p_seq.dirty), np.asarray(p_sc.dirty)
    )
