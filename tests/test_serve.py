"""Serve engine + indexed retrieval integration."""
import jax
import numpy as np

from repro.core.index import SPFreshIndex
from repro.core.types import LireConfig
from repro.data.vectors import make_sift_like, make_shifting_stream
from repro.models import recsys as R
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.retrieval import IndexedRetriever
from tests.test_lire import small_cfg


def test_engine_pipeline_keeps_postings_bounded(rng):
    base = make_sift_like(2000, 16, seed=5)
    idx = SPFreshIndex.build(small_cfg(), base)
    eng = ServeEngine(idx, EngineConfig(fg_bg_ratio=2, maintain_budget=8))
    inserts = make_shifting_stream(600, 16, seed=6)
    ids = np.arange(5000, 5600, dtype=np.int32)
    for s in range(0, 600, 100):
        eng.insert(inserts[s:s + 100], ids[s:s + 100])
    eng.drain()
    lens = np.asarray(idx.state.pool.posting_len)
    valid = np.asarray(idx.state.centroid_valid)
    assert (lens[valid] <= idx.state.cfg.split_limit).all()
    lat = eng.latency_percentiles("insert")
    assert lat["n"] == 6


def test_indexed_retriever_matches_bruteforce(rng):
    model_cfg = R.TwoTowerConfig(
        n_items=2000, n_user_fields=4, user_vocab_per_field=100,
        embed_dim=16, tower_dims=(32, 8),
    )
    params = R.twotower_init(jax.random.PRNGKey(0), model_cfg)
    index_cfg = LireConfig(
        dim=8, block_size=8, max_blocks_per_posting=8, num_blocks=4096,
        num_postings_cap=512, num_vectors_cap=16384, split_limit=48,
        merge_limit=6, reassign_range=8, replica_count=2, nprobe=16,
    )
    retr = IndexedRetriever(params, model_cfg, index_cfg)
    retr.build_corpus(np.arange(1500))
    users = rng.integers(0, 100, size=(8, 4)).astype(np.int32)
    _, ids_ann = retr.retrieve(users, k=10)
    _, ids_bf = retr.retrieve_bruteforce(users, k=10)
    hits = sum(
        len(set(a.tolist()) & set(b.tolist()))
        for a, b in zip(ids_ann, ids_bf)
    )
    assert hits / 80 > 0.8, f"ANN retrieval recall {hits / 80}"
    # churn: fresh items retrievable without rebuild
    retr.add_items(np.arange(1500, 1600))
    _, ids2 = retr.retrieve(users, k=10)
    assert np.isfinite(ids2.astype(float)).all()
