"""Trainer: loss goes down, checkpoint/restart resumes exactly."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, schedule
from repro.train.trainer import Trainer, TrainerConfig


def tiny_cfg():
    return tf.LMConfig(name="tiny", vocab=64, n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=64, dtype="float32",
                       kv_chunk=16)


def batch_fn_for(cfg, batch=4, seq=16):
    def batch_fn(step):
        rng = np.random.default_rng(step)
        toks = rng.integers(0, cfg.vocab, size=(batch, seq))
        toks[:, seq // 2:] = toks[:, : seq - seq // 2]
        t = jnp.asarray(toks, jnp.int32)
        return {"tokens": t, "labels": t}
    return batch_fn


def make_trainer(cfg, ckpt_dir, total=30):
    return Trainer(
        loss_fn=lambda p, b: tf.loss_fn(p, b, cfg),
        init_params_fn=lambda: tf.init_params(jax.random.PRNGKey(0), cfg),
        batch_fn=batch_fn_for(cfg),
        opt_cfg=AdamWConfig(lr=1e-2, warmup_steps=5, decay_steps=total),
        trainer_cfg=TrainerConfig(total_steps=total, checkpoint_every=10,
                                  log_every=5),
        ckpt_dir=ckpt_dir,
    )


def test_loss_decreases(tmp_path):
    cfg = tiny_cfg()
    t = make_trainer(cfg, str(tmp_path / "ck"))
    res = t.run()
    assert res["final_step"] == 30
    first = t.history[0]["loss"]
    assert res["final_loss"] < first * 0.9


def test_restart_resumes_identically(tmp_path):
    cfg = tiny_cfg()
    # uninterrupted run
    t1 = make_trainer(cfg, str(tmp_path / "a"))
    res1 = t1.run()
    # interrupted at 20 (checkpoint boundary), then a FRESH trainer resumes
    t2 = make_trainer(cfg, str(tmp_path / "b"))
    t2.run(steps=20)
    t3 = make_trainer(cfg, str(tmp_path / "b"))
    res3 = t3.run()
    assert res3["final_step"] == 30
    np.testing.assert_allclose(res1["final_loss"], res3["final_loss"],
                               rtol=1e-4)
    # params identical too (bitwise-deterministic pipeline)
    for a, b in zip(jax.tree_util.tree_leaves(t1.params),
                    jax.tree_util.tree_leaves(t3.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lr0 = float(schedule(cfg, jnp.asarray(0)))
    lr10 = float(schedule(cfg, jnp.asarray(10)))
    lr100 = float(schedule(cfg, jnp.asarray(100)))
    assert lr0 < 0.2 * lr10
    assert abs(lr10 - 1.0) < 1e-5
    assert abs(lr100 - 0.1) < 1e-2


def test_adamw_updates_params():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 0.5)}
    opt = adamw_init(params)
    new_p, new_opt, m = adamw_update(
        grads, opt, params, AdamWConfig(lr=0.1, warmup_steps=1)
    )
    assert not np.allclose(np.asarray(new_p["w"]), np.asarray(params["w"]))
    assert int(new_opt["count"]) == 1
    assert float(m["grad_norm"]) > 0
