"""Dry-run machinery regression test: lower+compile one small cell on an
8-device fake mesh in a subprocess (the full production sweep lives in
results/dryrun_final; this guards the *mechanism*)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(r"{repo}"), "{repo}", "src"))

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_cell
from repro.distributed.sharding import mesh_context
from repro.launch.roofline import collective_bytes, roofline_terms

mesh = jax.make_mesh((2, 4), ("data", "model"))

cell = get_cell("deepfm", "serve_p99")
args = cell.input_specs()
specs = cell.in_shardings(False)


def fix(tree):
    def conv(s):
        # remap 16-way specs onto the tiny mesh by replication fallback
        return NamedSharding(mesh, P(*[None] * len(s)))
    return jax.tree_util.tree_map(
        conv, tree, is_leaf=lambda x: isinstance(x, P)
    )

with mesh_context(mesh):
    lowered = jax.jit(cell.step_fn, in_shardings=fix(specs)).lower(*args)
    compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):
    ca = ca[0]
cb = collective_bytes(compiled.as_text())
t = roofline_terms(
    flops_per_device=float(ca.get("flops", 0.0)),
    bytes_per_device=float(ca.get("bytes accessed", 0.0)),
    collective_bytes_per_device=float(cb["total"]),
)
assert t["dominant"] in ("compute_s", "memory_s", "collective_s")
assert float(ca.get("flops", 0.0)) > 0
print("MINI_DRYRUN_PASS", t["dominant"])
"""


@pytest.mark.slow
def test_dryrun_mechanism_on_mini_mesh(tmp_path):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = tmp_path / "mini_dryrun.py"
    script.write_text(SCRIPT.replace("{repo}", repo))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(script)],
        capture_output=True, text=True, timeout=900, env=env,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-2000:])
    assert proc.returncode == 0
    assert "MINI_DRYRUN_PASS" in proc.stdout
