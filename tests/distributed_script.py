"""Multi-device sharded-index checks — run as a subprocess with 8 fake CPU
devices (spawned by tests/test_distributed.py so the main pytest process
keeps exactly one device)."""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import SPFreshIndex, build_state
from repro.core.types import LireConfig
from repro.distributed import sharded_index as D

assert len(jax.devices()) == 8, jax.devices()

MESH = jax.make_mesh((2, 4), ("data", "model"))
CFG = LireConfig(
    dim=16, block_size=8, max_blocks_per_posting=8, num_blocks=1024,
    num_postings_cap=128, num_vectors_cap=4096, split_limit=48,
    merge_limit=6, reassign_range=8, reassign_budget=128, replica_count=2,
    nprobe=8,
)


def make_clustered(rng, n, d, n_clusters=8, spread=0.05):
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    assign = rng.integers(0, n_clusters, size=n)
    return (centers[assign] + spread * rng.normal(size=(n, d))).astype(np.float32)


rng = np.random.default_rng(0)
base = make_clustered(rng, 2000, 16, n_clusters=12)

# ---- build sharded over 4 model shards ----
stacked, handles = D.build_sharded_state(CFG, base, 4)
assert (handles >= 0).all()

with MESH:
    search = D.make_search_step(MESH, CFG, k=10)
    queries = base[rng.integers(0, len(base), 64)] + 0.01 * rng.normal(
        size=(64, 16)
    ).astype(np.float32)
    alive = jnp.ones((4,), bool)
    d, v = search(stacked, jnp.asarray(queries), alive)
    d, v = np.asarray(d), np.asarray(v)

    # brute-force ground truth by handle
    bf = ((queries[:, None, :] - base[None]) ** 2).sum(-1)
    gt = handles[np.argsort(bf, axis=1)[:, :10]]
    hits = sum(
        len(set(gt[i].tolist()) & set(v[i].tolist())) for i in range(len(queries))
    )
    recall = hits / (len(queries) * 10)
    assert recall > 0.85, f"distributed recall {recall}"
    print(f"PASS distributed_search recall={recall:.3f}")

    # ---- distributed insert: new vectors become searchable ----
    insert = D.make_insert_step(MESH, CFG)
    new = make_clustered(rng, 32, 16, n_clusters=2)
    stacked, new_handles = insert(
        stacked, jnp.asarray(new), jnp.ones(len(new), bool)
    )
    new_handles = np.asarray(new_handles)
    assert (new_handles >= 0).all(), new_handles
    d2, v2 = search(stacked, jnp.asarray(new), alive)
    v2 = np.asarray(v2)
    found = sum(int(new_handles[i]) in v2[i].tolist() for i in range(32))
    assert found >= 30, f"only {found}/32 distributed inserts recalled"
    print(f"PASS distributed_insert found={found}/32")

    # owners spread across shards (centroid-space routing, clustered data)
    owners = np.unique(new_handles // CFG.num_vectors_cap)
    print(f"PASS insert_owners shards={owners.tolist()}")

    # ---- distributed delete ----
    delete = D.make_delete_step(MESH, CFG)
    stacked = delete(stacked, jnp.asarray(new_handles[:16]))
    d3, v3 = search(stacked, jnp.asarray(new[:16]), alive)
    v3 = np.asarray(v3)
    still = sum(int(new_handles[i]) in v3[i].tolist() for i in range(16))
    assert still == 0, f"{still} deleted handles still returned"
    print("PASS distributed_delete")

    # ---- maintenance step runs sharded ----
    maintain = D.make_maintenance_step(MESH, CFG)
    stacked, _did = maintain(stacked)
    print("PASS distributed_maintenance")

    # ---- shard-down graceful degradation ----
    alive_down = jnp.asarray([True, True, False, True])
    d4, v4 = search(stacked, jnp.asarray(queries), alive_down)
    v4 = np.asarray(v4)
    assert np.isfinite(np.asarray(d4)[v4 >= 0]).all()
    dead_shard_hits = ((v4 // CFG.num_vectors_cap) == 2) & (v4 >= 0)
    assert not dead_shard_hits.any(), "dead shard leaked results"
    hits4 = sum(
        len(set(gt[i].tolist()) & set(v4[i].tolist())) for i in range(len(queries))
    )
    recall4 = hits4 / (len(queries) * 10)
    assert recall4 > 0.45, f"degraded recall too low {recall4}"
    print(f"PASS shard_down degraded_recall={recall4:.3f} (full={recall:.3f})")

# ---- document-sharding over BOTH axes (8 shards, billion-scale layout) ----
stacked8, handles8 = D.build_sharded_state(CFG, base, 8)
with MESH:
    search8 = D.make_search_step(
        MESH, CFG, k=10, shard_axes=("data", "model"), probe_chunk=4
    )
    insert8 = D.make_insert_step(MESH, CFG, shard_axes=("data", "model"))
    d8, v8 = search8(stacked8, jnp.asarray(queries), jnp.ones((8,), bool))
    v8 = np.asarray(v8)
    gt8 = handles8[np.argsort(bf, axis=1)[:, :10]]
    hits8 = sum(
        len(set(gt8[i].tolist()) & set(v8[i].tolist())) for i in range(len(queries))
    )
    recall8 = hits8 / (len(queries) * 10)
    assert recall8 > 0.85, f"8-shard recall {recall8}"
    stacked8, h8 = insert8(stacked8, jnp.asarray(new), jnp.ones(len(new), bool))
    assert (np.asarray(h8) >= 0).all()
    print(f"PASS document_sharded_8 recall={recall8:.3f}")

# ---- elastic re-shard 4 -> 2 ----
restacked, handles2 = D.reshard(CFG, stacked, 4, 2)
MESH2 = jax.make_mesh((4, 2), ("data", "model"))
with MESH2:
    search2 = D.make_search_step(MESH2, CFG, k=10)
    d5, v5 = search2(restacked, jnp.asarray(queries), jnp.ones((2,), bool))
    assert (np.asarray(v5)[:, 0] >= 0).all()
    print("PASS elastic_reshard 4->2")

print("ALL_DISTRIBUTED_PASS")
