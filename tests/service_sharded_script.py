"""Crash-recovery parity for the unified service API over a 2-shard mesh
— run as a subprocess with 2 fake CPU devices (spawned by
tests/test_service_api.py so the main pytest process keeps one device).

The tentpole acceptance criterion, executable: the SAME ServiceSpec
(modulo ShardSpec) opens a local and a sharded service; the sharded
service is killed before ``checkpoint`` and reopened via
``spfresh.open`` — per-shard WAL replay on top of the open-time snapshot
must answer queries with exact parity to the uncrashed run.
"""
import os
import sys
import tempfile

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=2 "
    + os.environ.get("XLA_FLAGS", "")
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

import spfresh
from repro.core.types import LireConfig
from repro.storage.wal import iter_wal

assert len(jax.devices()) == 2, jax.devices()

root = sys.argv[1] if len(sys.argv) > 1 else tempfile.mkdtemp()

CFG = LireConfig(
    dim=16, block_size=8, max_blocks_per_posting=8, num_blocks=1024,
    num_postings_cap=128, num_vectors_cap=4096, split_limit=48,
    merge_limit=6, reassign_range=8, reassign_budget=128, replica_count=2,
    nprobe=8,
)
BASE_SPEC = spfresh.ServiceSpec(
    index=spfresh.IndexSpec(config=CFG),
    serve=spfresh.ServeSpec(search_k=10, max_batch=64, min_bucket=16),
)
SPEC = BASE_SPEC.with_durability(
    os.path.join(root, "svc")).with_shards(2)


def make_clustered(rng, n, d, n_clusters=8, spread=0.05):
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    assign = rng.integers(0, n_clusters, size=n)
    return (centers[assign] + spread * rng.normal(size=(n, d))).astype(
        np.float32)


rng = np.random.default_rng(0)
base = make_clustered(rng, 1000, 16, n_clusters=10)

# ---- the SAME spec (modulo ShardSpec) opens both backends ----
local = spfresh.open(BASE_SPEC, vectors=base)
assert local.index is not None
svc = spfresh.open(SPEC, vectors=base)
assert svc.index is None and svc.initial_handles is not None
d_l, _ = local.search(base[:8], k=5)
d_s, _ = svc.search(base[:8], k=5)
np.testing.assert_allclose(d_l[:, 0], d_s[:, 0], rtol=1e-4)  # same corpus
local.close()
print("PASS one_spec_two_backends")

# ---- stream updates through the pipeline (no checkpoint) ----
new = make_clustered(rng, 90, 16, n_clusters=3)
handles = []
for s in range(0, 90, 30):
    h, landed = svc.insert(new[s:s + 30])
    assert landed.all()
    handles.extend(h.tolist())
handles = np.asarray(handles, np.int64)
svc.delete(handles[:10].astype(np.int32))
queries = np.concatenate([new[:12], base[:12]])
want_d, want_v = svc.search(queries, k=10)
for shard in range(2):
    wal = os.path.join(SPEC.durability.resolved_wal_dir(),
                       f"shard_{shard:03d}.wal")
    assert len(list(iter_wal(wal))) > 0, f"shard {shard} WAL empty"
print("PASS sharded_stream_walled")

# ---- crash (abandon the handle) → reopen: per-shard WAL replay ----
twin = spfresh.open(SPEC)
assert twin.recovered
got_d, got_v = twin.search(queries, k=10)
np.testing.assert_array_equal(want_v, got_v)
np.testing.assert_allclose(want_d, got_d, rtol=1e-5)
leaked = set(got_v.reshape(-1).tolist()) & set(handles[:10].tolist())
assert not leaked, f"recovery resurrected deleted handles {leaked}"
_, hit = twin.search(new[20:30], k=3)
assert (hit[:, 0] == handles[20:30]).all(), "replayed handles diverged"
assert twin.stats() == svc.stats(), "stacked stats diverged after replay"
print("PASS sharded_crash_recovery_exact_parity")

# ---- recall parity vs brute force survives recovery ----
live_vecs = np.concatenate([base, new[10:]])
live_h = np.concatenate([svc.initial_handles, handles[10:]])
bf = ((queries[:, None, :] - live_vecs[None]) ** 2).sum(-1)
gt = live_h[np.argsort(bf, axis=1)[:, :10]]


def recall(v):
    hits = sum(len(set(gt[i].tolist()) & set(v[i].tolist()))
               for i in range(len(queries)))
    return hits / (len(queries) * 10)


r_live, r_twin = recall(want_v), recall(got_v)
assert r_twin == r_live and r_twin > 0.85, (r_live, r_twin)
print(f"PASS sharded_recall_parity recall={r_twin:.3f}")

# ---- checkpoint → tail replay → drain invariants ----
twin.checkpoint()
more = make_clustered(rng, 30, 16, n_clusters=2)
h2, _ = twin.insert(more)
want2 = twin.search(more[:8], k=5)
svc3 = spfresh.open(SPEC)          # snapshot + post-checkpoint tail only
got2 = svc3.search(more[:8], k=5)
np.testing.assert_array_equal(want2[1], got2[1])
svc3.drain()
assert svc3.backlog() == 0
svc3.close()
print("PASS sharded_checkpoint_tail_replay")

# ---- delta-snapshot cycle: base → delta (per-shard files) → crash ----
from repro.storage.snapshot import SnapshotStore

store = SnapshotStore(SPEC.durability.resolved_snapshot_dir())
svc4 = spfresh.open(SPEC)          # recover from the clean close (a base)
assert store.has_base() and store.chain_len() == 0
more2 = make_clustered(rng, 24, 16, n_clusters=2)
h3, landed3 = svc4.insert(more2)
assert landed3.all()
svc4.checkpoint(delta=True)
assert store.chain_len() == 1
unit_dir = os.path.join(SPEC.durability.resolved_snapshot_dir(),
                        store._head())
shard_files = sorted(f for f in os.listdir(unit_dir) if f.endswith(".npz"))
assert shard_files == ["shard_000.npz", "shard_001.npz"], shard_files
more3 = make_clustered(rng, 12, 16, n_clusters=2)
svc4.insert(more3)                 # WAL tail on top of the delta
want3 = svc4.search(more2[:8], k=5)

svc5 = spfresh.open(SPEC)          # crash → base + delta + tail replay
assert svc5.recovered
got3 = svc5.search(more2[:8], k=5)
np.testing.assert_array_equal(want3[1], got3[1])
np.testing.assert_allclose(want3[0], got3[0], rtol=1e-5)
assert svc5.stats() == svc4.stats(), "delta-chain recovery stats diverged"
_, hit3 = svc5.search(more2[:8], k=1)
assert (hit3[:, 0] == h3[:8]).all(), "delta-chain recovery lost handles"
svc5.checkpoint(delta=False)       # compaction folds + prunes the chain
assert store.chain_len() == 0
svc5.close()
print("PASS sharded_delta_chain_cycle")

print("ALL_SERVICE_SHARDED_PASS")
