"""Read replicas over the 2-shard × 2-replica (data, model) mesh — run
as a subprocess with 4 fake CPU devices (spawned by
tests/test_replication.py so the main pytest process keeps one device).

The replica-aware CI leg, executable: with ``n_replicas=2, n_shards=2``
the service opens a 4-device mesh, the primary row alone runs the
WAL-append + dispatch order, and the replica row replays the published
stream in seqno order.  The suite checks the four replica contracts:

* routing fan-out — search batches land on the replica worker;
* lag-bound fallback — a replica past ``max_lag`` is skipped and the
  batch is served on the primary (counted);
* catch-up after induced lag — a window overflow forces the
  snapshot-fork + tail-replay path;
* bit-parity at equal seqno — the replica's stacked state equals the
  primary's on every content leaf once both have applied the same seqno.
"""
import os
import sys
import tempfile

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 "
    + os.environ.get("XLA_FLAGS", "")
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

import spfresh
from repro.core.types import LireConfig
from repro.distributed.replication import states_equal

assert len(jax.devices()) == 4, jax.devices()

root = sys.argv[1] if len(sys.argv) > 1 else tempfile.mkdtemp()

CFG = LireConfig(
    dim=16, block_size=8, max_blocks_per_posting=8, num_blocks=1024,
    num_postings_cap=128, num_vectors_cap=4096, split_limit=48,
    merge_limit=6, reassign_range=8, reassign_budget=128, replica_count=2,
    nprobe=8,
)
SPEC = (
    spfresh.ServiceSpec(
        index=spfresh.IndexSpec(config=CFG),
        serve=spfresh.ServeSpec(search_k=10, max_batch=64, min_bucket=16,
                                async_serve=True),
    )
    .with_durability(os.path.join(root, "svc"))
    .with_shards(2)
    .with_replicas(2, max_lag=4)
)


def make_clustered(rng, n, d, n_clusters=8, spread=0.05):
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    assign = rng.integers(0, n_clusters, size=n)
    return (centers[assign] + spread * rng.normal(size=(n, d))).astype(
        np.float32)


rng = np.random.default_rng(0)
base = make_clustered(rng, 1000, 16, n_clusters=10)

svc = spfresh.open(SPEC, vectors=base)
rs = svc.replicas
assert rs is not None and len(rs.replicas) == 1
# the primary and the replica compile on DISJOINT mesh rows: replication
# composes with sharding instead of timesharing the primary's devices
prim_dev = set(d.id for d in svc.backend.mesh.devices.flat)
repl_dev = set(d.id for d in rs.replicas[0].backend.mesh.devices.flat)
assert len(prim_dev) == 2 and len(repl_dev) == 2
assert not (prim_dev & repl_dev), (prim_dev, repl_dev)
print("PASS replicated_mesh_rows_disjoint")

# ---- bit-parity at equal seqno ----
new = make_clustered(rng, 60, 16, n_clusters=3)
handles = []
for s in range(0, 60, 20):
    h, landed = svc.insert(new[s:s + 20])
    assert landed.all()
    handles.extend(h.tolist())
svc.delete(np.asarray(handles[:8], np.int32))
svc.drain()
rs.wait_sync()
rep = rs.report()
assert rep["per_replica"][0]["lag"] == 0, rep
assert rep["published"] > 0
assert states_equal(svc.backend.stacked, rs.replicas[0].backend.stacked)
print("PASS bit_parity_at_equal_seqno (seqno=%d)" % rep["primary_seqno"])

# ---- routing fan-out: searches land on the replica worker ----
routed0 = rs.routed
queries = np.concatenate([new[8:16], base[:8]])
d0, v0 = svc.search(queries, k=10)
for _ in range(4):
    d1, v1 = svc.search(queries, k=10)
    np.testing.assert_array_equal(v0, v1)   # replica answers == replica answers
    np.testing.assert_allclose(d0, d1, rtol=1e-5)
rep = rs.report()
assert rs.routed > routed0, (rs.routed, routed0, rs.fallback)
assert rep["per_replica"][0]["batches"] > 0, rep
# at equal seqno the replica's answers equal the primary's own
with svc.engine.exclusive():
    dp, vp = svc.backend.search(queries, 10, None)
np.testing.assert_array_equal(v0, np.asarray(vp))
np.testing.assert_allclose(d0, np.asarray(dp), rtol=1e-5)
print("PASS routing_fanout routed=%d" % rs.routed)

# ---- lag-bound fallback: a stale replica is skipped, not served ----
rs.pause(0)
wave = make_clustered(rng, 48, 16, n_clusters=2)
h2 = []
for s in range(0, 48, 6):            # 8 separate dispatches: lag > max_lag
    h, landed2 = svc.insert(wave[s:s + 6])
    assert landed2.all()
    h2.extend(h.tolist())
h2 = np.asarray(h2)
svc.drain()
rep = rs.report()["per_replica"][0]
assert rep["lag"] > SPEC.serve.max_lag, rep   # > max_lag=4 dispatches behind
fb0, routed1 = rs.fallback, rs.routed
_, hit = svc.search(wave[:8], k=1)
assert rs.fallback > fb0, (rs.fallback, fb0)
assert rs.routed == routed1                   # nothing routed while stale
# fallback answers are PRIMARY answers: the paused replica has never
# seen this wave, yet the fresh inserts are recalled
assert (hit[:, 0] == h2[:8]).all(), (hit[:, 0], h2[:8])
print("PASS lag_bound_fallback fallback=%d" % rs.fallback)

# ---- catch-up after induced lag: window overflow -> snapshot fork ----
rs.window_cap = 4          # shrink so the paused replica falls off the tail
for s in range(6):
    svc.insert(make_clustered(rng, 8, 16, n_clusters=2))
svc.drain()
rs.resume(0)
rs.wait_sync()
rep = rs.report()["per_replica"][0]
assert rep["catchups"] >= 1, rep
assert rep["lag"] == 0, rep
assert states_equal(svc.backend.stacked, rs.replicas[0].backend.stacked)
# and the caught-up replica serves routed searches again
routed2 = rs.routed
for _ in range(3):
    svc.search(base[:16], k=5)
assert rs.routed > routed2, (rs.routed, routed2, rs.fallback)
print("PASS catch_up_after_induced_lag catchups=%d" % rep["catchups"])

svc.close()
print("ALL_REPLICA_PASS")
