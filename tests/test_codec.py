"""Posting payload codec: quantization round-trip properties, dequant
kernel parity, pool-tier invariants, legacy-snapshot migration, and the
int8+rerank recall-floor gate.

check.sh runs this suite as its own explicit gate step; tier-1 excludes
it via the marker.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.gate

from repro.core.index import SPFreshIndex
from repro.core.types import LireConfig
from repro.data.vectors import make_sift_like
from repro.kernels.posting_scan import ops as scan_ops
from repro.kernels.posting_scan.kernel import (
    scan_batched_topk_q8,
    scan_per_query_topk_q8,
)
from repro.kernels.posting_scan.ref import (
    scan_batched_topk_q8_ref,
    scan_per_query_topk_q8_ref,
)
from repro.storage import blockpool as bp
from repro.storage import codec as pc


# ---------------------------------------------------------------------------
# Round-trip properties
# ---------------------------------------------------------------------------

def _roundtrip_bound(rows: np.ndarray, mag: float = 1.0) -> None:
    """decode(encode(x)) is within scale/2 per dimension (+fp32 slack)."""
    scale, zero = pc.np_train_scale_zero(rows)
    dec = pc.np_decode(pc.np_encode(rows, scale, zero), scale, zero)
    bound = float(scale) * 0.5 * (1 + 1e-3) + 1e-5 * max(mag, 1.0)
    assert np.max(np.abs(dec - rows)) <= bound, (scale, mag)


def test_roundtrip_error_bound_hypothesis():
    """Property form: the bound holds at any posting size, dim, and
    scale magnitude (outlier postings just get a larger scale)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(
        n=st.integers(1, 12),
        d=st.sampled_from([4, 8, 16]),
        mag=st.floats(1e-3, 1e6),
        seed=st.integers(0, 2**31 - 1),
    )
    def inner(n, d, mag, seed):
        rng = np.random.default_rng(seed)
        rows = (mag * rng.normal(size=(n, d))).astype(np.float32)
        _roundtrip_bound(rows, mag)

    inner()


def test_roundtrip_error_bound_seeded():
    """Deterministic trials that run even without hypothesis, covering
    the same envelope: sizes, dims, and outlier scale magnitudes."""
    for seed in range(20):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 13))
        d = int(rng.choice([4, 8, 16]))
        mag = float(10.0 ** rng.uniform(-3, 6))
        rows = (mag * rng.normal(size=(n, d))).astype(np.float32)
        _roundtrip_bound(rows, mag)


def test_all_zero_posting_roundtrips_exactly():
    for n, d in ((1, 4), (8, 16)):
        rows = np.zeros((n, d), np.float32)
        scale, zero = pc.np_train_scale_zero(rows)
        assert scale == 1.0 and zero == 0.0
        dec = pc.np_decode(pc.np_encode(rows, scale, zero), scale, zero)
        np.testing.assert_array_equal(dec, rows)


def test_single_vector_posting_bound():
    for seed in range(10):
        rng = np.random.default_rng(seed)
        rows = rng.normal(size=(1, int(rng.choice([4, 8, 16])))) \
            .astype(np.float32)
        _roundtrip_bound(rows)


def test_constant_posting_roundtrips_exactly():
    rows = np.full((5, 8), 3.25, np.float32)
    scale, zero = pc.np_train_scale_zero(rows)
    assert scale == 1.0 and zero == np.float32(3.25)
    dec = pc.np_decode(pc.np_encode(rows, scale, zero), scale, zero)
    np.testing.assert_array_equal(dec, rows)


def test_jnp_train_matches_np_train():
    """The traced trainer (masked, batched) agrees with the host one."""
    for seed in range(10):
        rng = np.random.default_rng(seed)
        n, d = 6, 8
        rows = (10.0 * rng.normal(size=(n, d))).astype(np.float32)
        n_valid = int(rng.integers(1, n + 1))
        valid = np.arange(n) < n_valid
        s_j, z_j = pc.train_scale_zero(jnp.asarray(rows), jnp.asarray(valid))
        s_n, z_n = pc.np_train_scale_zero(rows[:n_valid])
        np.testing.assert_allclose(float(s_j), float(s_n), rtol=1e-6)
        np.testing.assert_allclose(
            float(z_j), float(z_n), rtol=1e-6, atol=1e-6
        )


# ---------------------------------------------------------------------------
# Dequant-fused kernel parity (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q_n,n_blocks,bs,d,nb,k", [
    (4, 32, 8, 16, 6, 4),
    (2, 16, 8, 32, 3, 8),
])
def test_q8_per_query_topk_matches_ref(rng, q_n, n_blocks, bs, d, nb, k):
    blocks = jnp.asarray(
        rng.integers(-127, 128, size=(n_blocks, bs, d)), jnp.int8
    )
    queries = jnp.asarray(rng.normal(size=(q_n, d)), jnp.float32)
    table = jnp.asarray(rng.integers(0, n_blocks, size=(q_n, nb)), jnp.int32)
    bias = jnp.zeros((q_n, nb, bs), jnp.float32)
    page_sz = jnp.asarray(
        np.stack(
            [rng.uniform(1e-3, 0.1, size=(q_n, nb)),
             rng.normal(size=(q_n, nb))], axis=-1
        ), jnp.float32,
    )
    got_d, got_i = scan_per_query_topk_q8(
        table, queries, blocks, bias, page_sz, k=k, interpret=True
    )
    want_d, want_i = scan_per_query_topk_q8_ref(
        table, queries, blocks, bias, page_sz, k=k
    )
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


@pytest.mark.parametrize("q_n,n_blocks,bs,d,nb,k", [
    (4, 32, 8, 16, 6, 4),
    (8, 64, 16, 128, 5, 8),
])
def test_q8_batched_topk_matches_ref(rng, q_n, n_blocks, bs, d, nb, k):
    blocks = jnp.asarray(
        rng.integers(-127, 128, size=(n_blocks, bs, d)), jnp.int8
    )
    queries = jnp.asarray(rng.normal(size=(q_n, d)), jnp.float32)
    ids = jnp.asarray(rng.choice(n_blocks, size=nb, replace=False), jnp.int32)
    bias = jnp.zeros((nb, bs), jnp.float32)
    page_sz = jnp.asarray(
        np.stack(
            [rng.uniform(1e-3, 0.1, size=(nb,)),
             rng.normal(size=(nb,))], axis=-1
        ), jnp.float32,
    )
    got_d, got_i = scan_batched_topk_q8(
        ids, queries, blocks, bias, page_sz, k=k, interpret=True
    )
    want_d, want_i = scan_batched_topk_q8_ref(
        ids, queries, blocks, bias, page_sz, k=k
    )
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


def test_q8_wrapper_equals_dequantized_fp32_wrapper(rng):
    """The q8 ops wrapper over codes == the fp32 wrapper over the
    decoded payload (same pages, same bias) — the dequant really is the
    only difference in the data path."""
    n_blocks, bs, d, q_n, nb, k = 16, 8, 16, 3, 4, 4
    scale = rng.uniform(1e-3, 0.05, size=(q_n, nb)).astype(np.float32)
    zero = rng.normal(size=(q_n, nb)).astype(np.float32)
    codes = rng.integers(-127, 128, size=(n_blocks, bs, d)).astype(np.int8)
    queries = jnp.asarray(rng.normal(size=(q_n, d)), jnp.float32)
    table = jnp.asarray(rng.integers(0, n_blocks, size=(q_n, nb)), jnp.int32)
    live = jnp.ones((q_n, nb, bs), bool)
    got_d, _ = scan_ops.scan_posting_blocks_topk_q8(
        queries, table, live, jnp.asarray(codes),
        jnp.asarray(scale), jnp.asarray(zero), k=k, interpret=True,
    )
    # decode each probed page under ITS page's params, then fp32-scan
    dec = np.zeros((q_n, nb, bs, d), np.float32)
    for q in range(q_n):
        for j in range(nb):
            dec[q, j] = pc.np_decode(
                codes[np.asarray(table)[q, j]], scale[q, j], zero[q, j]
            )
    diff = dec - np.asarray(queries)[:, None, None, :]
    dist = (diff * diff).sum(-1)
    want_d = np.sort(dist.reshape(q_n, nb, bs), axis=-1)[..., :k]
    np.testing.assert_allclose(
        np.sort(np.asarray(got_d), axis=-1), want_d, rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# Pool tiers
# ---------------------------------------------------------------------------

def _int8_pool(dim=8, cap=4):
    return bp.make_block_pool(
        num_blocks=32, block_size=4, dim=dim, num_postings_cap=8,
        max_blocks_per_posting=cap, codec="int8",
    )


def _put(pool, pid, vecs):
    """put_posting with fixed-capacity padding around (n, d) rows."""
    cap = pool.posting_capacity
    n = vecs.shape[0]
    buf = np.zeros((cap, pool.dim), np.float32)
    buf[:n] = vecs
    vids = np.full((cap,), -1, np.int32)
    vids[:n] = np.arange(n)
    return bp.put_posting(
        pool, jnp.int32(pid), jnp.asarray(buf), jnp.asarray(vids),
        jnp.zeros((cap,), pool.block_ver.dtype), jnp.int32(n),
        jnp.bool_(True),
    )


def test_int8_pool_put_roundtrip_and_exact_tier(rng):
    pool = _int8_pool()
    vecs = rng.normal(size=(12, 8)).astype(np.float32)
    pool, ok = _put(pool, 2, vecs)
    assert bool(ok)
    exact, _, _, valid = bp.gather_posting(pool, 2)
    assert int(np.asarray(valid).sum()) == 12
    # cold tier is EXACT fp32
    np.testing.assert_array_equal(np.asarray(exact)[:12], vecs)
    # hot tier decodes within the posting's quantization bound
    hot, _, _, _ = bp.gather_posting_hot(pool, 2)
    bound = float(pool.post_scale[2]) * 0.5 * (1 + 1e-3)
    assert np.max(np.abs(np.asarray(hot)[:12] - vecs)) <= bound


def test_int8_pool_free_resets_codec_params(rng):
    pool = _int8_pool()
    vecs = rng.normal(size=(4, 8)).astype(np.float32)
    pool, ok = _put(pool, 1, vecs)
    assert bool(ok)
    assert float(pool.post_scale[1]) != 1.0
    pool = bp.free_posting(pool, jnp.int32(1), jnp.bool_(True))
    assert float(pool.post_scale[1]) == 1.0
    assert float(pool.post_zero[1]) == 0.0


def test_fp32_pool_has_no_exact_tier():
    pool = bp.make_block_pool(
        num_blocks=16, block_size=4, dim=8, num_postings_cap=4,
        max_blocks_per_posting=2, codec="fp32",
    )
    assert pool.blocks_exact is None
    assert pool.blocks.dtype == jnp.float32


# ---------------------------------------------------------------------------
# Legacy snapshot migration + replay-drift rejection
# ---------------------------------------------------------------------------

def _tiny_cfg(**kw):
    args = dict(
        dim=8, block_size=4, max_blocks_per_posting=4, num_blocks=256,
        num_postings_cap=64, num_vectors_cap=1024, split_limit=12,
        merge_limit=2, reassign_range=4, reassign_budget=32,
        replica_count=1, nprobe=4,
    )
    args.update(kw)
    return LireConfig(**args)


def test_pre_codec_snapshot_migrates(tmp_path, rng):
    """A snapshot written before the codec leaves existed loads as fp32
    with identity codec params reconstructed (scale=1, zero=0)."""
    import jax
    from repro.storage import snapshot as snap

    base = make_sift_like(200, 8, seed=3)
    idx = SPFreshIndex.build(_tiny_cfg(), base)
    state = idx.state
    leaves = jax.tree_util.tree_leaves(state)
    codec_at = snap._codec_leaf_indices(state)
    assert len(codec_at) == 2
    drop = set(codec_at.values())
    kept = [np.asarray(x) for i, x in enumerate(leaves) if i not in drop]
    path = os.path.join(tmp_path, "snap")
    os.makedirs(path)
    np.savez(
        os.path.join(path, "leaves.npz"),
        **{f"leaf_{i}": a for i, a in enumerate(kept)},
    )
    with open(os.path.join(path, "manifest.json"), "w") as fh:
        json.dump({"format": 2, "kind": "base", "n_leaves": len(kept),
                   "step": 0, "extra": {}}, fh)
    restored, _ = snap.load_snapshot(path, state)
    np.testing.assert_array_equal(
        np.asarray(restored.pool.post_scale),
        np.ones_like(np.asarray(state.pool.post_scale)),
    )
    np.testing.assert_array_equal(
        np.asarray(restored.pool.post_zero),
        np.zeros_like(np.asarray(state.pool.post_zero)),
    )
    np.testing.assert_array_equal(
        np.asarray(restored.pool.blocks), np.asarray(state.pool.blocks)
    )


def test_pre_codec_delta_chain_folds_then_migrates(tmp_path, rng):
    """A base+delta chain written before the codec leaves existed must
    fold in ITS OWN leaf coordinates (the deltas stamp old indices) and
    migrate once at the end."""
    import jax
    from repro.storage import snapshot as snap

    base_vecs = make_sift_like(200, 8, seed=5)
    idx = SPFreshIndex.build(_tiny_cfg(), base_vecs)
    state = idx.state
    leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(state)]
    drop = sorted(snap._codec_leaf_indices(state).values())
    old = [a for i, a in enumerate(leaves) if i not in drop]
    # new-coordinate block leaf indices -> old-coordinate ones
    blk_new = snap._block_leaf_indices(state)
    to_old = lambda i: i - sum(1 for d in drop if d < i)
    blk_old = {name: to_old(i) for name, i in blk_new.items()}

    root = os.path.join(tmp_path, "store")
    bdir = os.path.join(root, "base-0000000001")
    os.makedirs(bdir)
    np.savez(os.path.join(bdir, "leaves.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(old)})
    with open(os.path.join(bdir, "manifest.json"), "w") as fh:
        json.dump({"format": 2, "kind": "base", "unit": "base-0000000001",
                   "parent": None, "chain_len": 0, "n_leaves": len(old),
                   "step": 0, "extra": {}}, fh)

    # delta touching one block, everything in OLD coordinates
    bid = 0
    new_page = rng.normal(size=old[blk_old["blocks"]].shape[1:]) \
        .astype(old[blk_old["blocks"]].dtype)
    ddir = os.path.join(root, "delta-0000000002")
    os.makedirs(ddir)
    arrays = {"dirty_idx": np.asarray([bid], np.int32)}
    for name in ("blocks", "block_vid", "block_ver"):
        rowval = new_page[None] if name == "blocks" \
            else old[blk_old[name]][bid:bid + 1]
        arrays[f"blk_{name}"] = rowval
    blk_idx = set(blk_old.values())
    for j, a in enumerate(old):
        if j not in blk_idx:
            arrays[f"leaf_{j}"] = a
    np.savez(os.path.join(ddir, "shard_000.npz"), **arrays)
    with open(os.path.join(ddir, "manifest.json"), "w") as fh:
        json.dump({"format": 2, "kind": "delta", "unit": "delta-0000000002",
                   "parent": "base-0000000001", "chain_len": 1,
                   "n_leaves": len(old), "n_shards": 1,
                   "block_leaves": blk_old, "step": 0, "extra": {}}, fh)
    with open(os.path.join(root, "CURRENT"), "w") as fh:
        fh.write("delta-0000000002")

    restored, _ = snap.SnapshotStore(root).load(state)
    np.testing.assert_array_equal(
        np.asarray(restored.pool.blocks)[bid], new_page
    )
    np.testing.assert_array_equal(
        np.asarray(restored.pool.post_scale),
        np.ones_like(np.asarray(state.pool.post_scale)),
    )


def test_replay_rejects_codec_drift():
    from repro.storage.durability import check_replay_config

    cfg = _tiny_cfg(codec="int8", rerank_factor=4)
    stamped_fp32 = {"extra": {"lire_config": {"codec": "fp32",
                                              "rerank_factor": 1}}}
    with pytest.raises(ValueError, match="codec"):
        check_replay_config(stamped_fp32, cfg)
    # pre-codec snapshots never stamped the field -> they still pass
    legacy = {"extra": {"lire_config": {"dim": cfg.dim}}}
    check_replay_config(legacy, cfg)


# ---------------------------------------------------------------------------
# Recall-floor gate: int8 + exact rerank within 0.01 recall@10 of fp32
# ---------------------------------------------------------------------------

def _recall_cell(codec: str, rerank_factor: int) -> float:
    n, dim, k = 600, 16, 10
    base = make_sift_like(n, dim, seed=41)
    cfg = _tiny_cfg(
        dim=dim, num_blocks=1024, num_postings_cap=128,
        num_vectors_cap=4096, codec=codec, rerank_factor=rerank_factor,
    )
    idx = SPFreshIndex.build(cfg, base)
    rng = np.random.default_rng(42)
    queries = (base[rng.integers(0, n, 24)]
               + 0.02 * rng.normal(size=(24, dim))).astype(np.float32)
    d = ((queries[:, None, :] - base[None]) ** 2).sum(-1)
    gt = np.argsort(d, axis=1)[:, :k]
    _, got = idx.search(queries, k, nprobe=8)
    hits = sum(
        len(set(a.tolist()) & set(b.tolist())) for a, b in zip(gt, got)
    )
    return hits / gt.size


def test_int8_rerank_recall_floor():
    r_fp32 = _recall_cell("fp32", 1)
    r_int8 = _recall_cell("int8", 4)
    assert r_fp32 - r_int8 <= 0.01, (r_fp32, r_int8)


def test_bf16_rerank_recall_floor():
    r_fp32 = _recall_cell("fp32", 1)
    r_bf16 = _recall_cell("bf16", 4)
    assert r_fp32 - r_bf16 <= 0.01, (r_fp32, r_bf16)
