"""Async serving gate: background pump thread correctness.

The stress test drives an async engine from N submitter threads with a
mixed search/insert/delete stream and asserts the three things the pump
thread must never break: result integrity (every search's top-1 is the
exact vector the same thread inserted and awaited earlier), live-set
conservation (inserted − deleted rows all survive, none resurrect), and
no deadlock (join timeouts + a faulthandler watchdog instead of
pytest-timeout, which this environment does not ship).

The rest are satellite regressions: the batch-formation window, the
falsy-zero ``submit_search`` key fix, the bounded latency reservoir,
and ``ticket.dropped`` backpressure accounting.
"""
import faulthandler
import logging
import threading
import time

import numpy as np
import pytest

# check.sh runs this suite as its own explicit gate step; the tier-1
# step excludes it via the marker (no hand-maintained --ignore list).
pytestmark = pytest.mark.gate

from repro.core.index import SPFreshIndex
from repro.serve.engine import (
    EngineConfig,
    ServeEngine,
    ServeMetrics,
    _LatReservoir,
)
from repro.serve.queue import (
    INSERT,
    SEARCH,
    RequestQueue,
    Ticket,
    default_buckets,
)
from tests.conftest import make_clustered
from tests.test_lire import small_cfg

DIM = 16


def _async_engine(rng, n_base=600, **cfg_kw):
    base = make_clustered(rng, n_base, DIM, n_clusters=4)
    idx = SPFreshIndex.build(small_cfg(), base)
    cfg = dict(
        search_k=10, max_batch=32, min_bucket=8,
        policy="ratio", fg_bg_ratio=2, maintain_budget=4,
        async_serve=True,
        # the whole async suite runs under the instrumented lock: any
        # shared-field write off the declared ownership map raises
        lock_check=True,
    )
    cfg.update(cfg_kw)
    return ServeEngine(idx, EngineConfig(**cfg)), base


# ---------------------------------------------------------------------------
# Pump thread lifecycle
# ---------------------------------------------------------------------------

def test_async_engine_roundtrip_and_shutdown(rng):
    eng, base = _async_engine(rng)
    assert eng.is_async and eng.report()["async"]
    d, v = eng.search(base[:4], k=5)
    assert v.shape == (4, 5) and (v[:, 0] == np.arange(4)).all()

    vecs = make_clustered(rng, 8, DIM)
    ids = np.arange(5000, 5008, dtype=np.int32)
    tk = eng.submit_insert(vecs, ids)
    got_ids, landed = tk.result(timeout=60)
    assert landed.all() and (got_ids == ids).all()
    _, hit = eng.search(vecs, k=3)
    assert (hit[:, 0] == ids).all()

    eng.shutdown()
    assert not eng.is_async
    # post-shutdown the engine reverts to cooperative pumping
    _, hit = eng.search(vecs[:2], k=1)
    assert (hit[:, 0] == ids[:2]).all()


def test_async_pump_error_surfaces_at_result(rng):
    eng, _ = _async_engine(rng)
    try:
        # sabotage the backend: the pump thread hits this on dispatch
        def boom(*a, **k):
            raise RuntimeError("injected backend failure")

        eng.backend.insert = boom
        tk = eng.submit_insert(
            make_clustered(rng, 4, DIM), np.arange(4, dtype=np.int32)
        )
        with pytest.raises(RuntimeError, match="pump thread died"):
            tk.result(timeout=60)
    finally:
        # deliberate internals poke (clearing a simulated pump error from
        # the main thread): bypass the ownership checker explicitly
        object.__setattr__(eng, "_pump_error", None)
        eng.shutdown()


# ---------------------------------------------------------------------------
# Multi-threaded stress: integrity, conservation, no deadlock
# ---------------------------------------------------------------------------

def test_async_multithreaded_stress(rng):
    n_threads, ops_each = 4, 60
    eng, base = _async_engine(rng, n_base=800, max_wait_ms=1.0)
    st0 = eng.stats()
    faulthandler.dump_traceback_later(240, exit=False)
    errors: list[BaseException] = []
    live_sets: list[dict[int, np.ndarray]] = [{} for _ in range(n_threads)]
    dead_sets: list[dict[int, np.ndarray]] = [{} for _ in range(n_threads)]
    op_counts = [0] * n_threads

    def worker(tid: int) -> None:
        trng = np.random.default_rng(100 + tid)
        # vids must stay < num_vectors_cap (8192): the version map is
        # sized by it, and over-cap vids are GC'd at the next split
        vid = 2000 + 1000 * tid
        live, dead = live_sets[tid], dead_sets[tid]
        try:
            for i in range(ops_each):
                op = trng.integers(0, 10)
                if op < 5 or not live:            # insert
                    v = make_clustered(trng, 1, DIM)
                    ids = np.asarray([vid], np.int32)
                    got, landed = eng.submit_insert(v, ids).result(
                        timeout=120)
                    assert landed.all(), f"t{tid} op{i}: insert rejected"
                    live[vid] = v
                    vid += 1
                elif op < 8:                      # search for an OWN vector
                    pick = int(trng.choice(sorted(live)))
                    # integrity = ORDERING, not ANN recall: the awaited
                    # insert must be visible to a later search dispatch.
                    # Probe wide (nprobe=32 vs config 8) so replica
                    # placement under concurrent splits can't alias a
                    # pipeline reordering bug as a recall miss.
                    d, hit = eng.submit_search(
                        live[pick], k=5, nprobe=32).result(timeout=120)
                    assert pick in hit[0].tolist(), (
                        f"t{tid} op{i}: vid {pick} invisible: {hit[0]}"
                    )
                else:                             # delete an OWN vector
                    pick = int(trng.choice(sorted(live)))
                    eng.submit_delete(
                        np.asarray([pick], np.int32)).result(timeout=120)
                    dead[pick] = live.pop(pick)
                op_counts[tid] += 1
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [
        threading.Thread(target=worker, args=(t,), daemon=True)
        for t in range(n_threads)
    ]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=240)
        hung = [t.name for t in threads if t.is_alive()]
        assert not hung, f"deadlock: submitters still alive: {hung}"
        if errors:
            raise errors[0]
        assert sum(op_counts) == n_threads * ops_each
        eng.pump()                                # flush barrier
        assert eng._pump_error is None

        # live-set conservation, counter side: every landed insert and
        # every delete reached the state exactly once
        st = eng.stats()
        n_ins = sum(len(l) for l in live_sets) + sum(
            len(d) for d in dead_sets)
        n_del = sum(len(d) for d in dead_sets)
        assert st["n_inserts"] - st0["n_inserts"] == n_ins
        assert st["n_deletes"] - st0["n_deletes"] == n_del
        assert eng.report()["insert_dropped"] == 0

        # ...and recall side: survivors stay findable, tombstones stay gone
        for live, dead in zip(live_sets, dead_sets):
            for pick in sorted(live)[:3]:
                _, hit = eng.search(live[pick], k=5, nprobe=32)
                assert pick in hit[0].tolist(), "live vector lost"
            for pick in sorted(dead)[:3]:
                _, hit = eng.search(dead[pick], k=5, nprobe=32)
                assert pick not in hit[0].tolist(), "delete resurrected"
    finally:
        faulthandler.cancel_dump_traceback_later()
        eng.shutdown()


# ---------------------------------------------------------------------------
# Batch-formation window (queue-level)
# ---------------------------------------------------------------------------

def test_window_coalesces_head_run():
    q = RequestQueue(default_buckets(8, 8), max_wait_ms=500.0)
    t1 = Ticket(SEARCH, 4, (10, None))
    q.submit(t1, {"queries": np.zeros((4, DIM), np.float32)})

    def late_submit():
        time.sleep(0.05)
        t2 = Ticket(SEARCH, 4, (10, None))
        q.submit(t2, {"queries": np.ones((4, DIM), np.float32)})

    threading.Thread(target=late_submit, daemon=True).start()
    t0 = time.perf_counter()
    b = q.pop_batch()
    took = time.perf_counter() - t0
    # the window held the 4-row head run until the second part arrived,
    # filled the top bucket, and released ONE coalesced batch (not two
    # dispatches) well before the 500ms window expired
    assert b.n_valid == 8 and b.bucket == 8
    assert took < 0.4, "window did not release on coalesced fill"
    assert q.accounting()["window_waits"] >= 1
    assert q.pop_batch() is None


def test_window_fenced_by_other_op_releases_immediately():
    q = RequestQueue(default_buckets(8, 64), max_wait_ms=500.0)
    q.submit(Ticket(SEARCH, 4, (10, None)),
             {"queries": np.zeros((4, DIM), np.float32)})
    q.submit(Ticket(INSERT, 4, ()),
             {"vecs": np.zeros((4, DIM), np.float32),
              "vids": np.arange(4, dtype=np.int32)})
    t0 = time.perf_counter()
    b = q.pop_batch()
    # a different-kind part fences the head run: no window hold
    assert b.op == SEARCH and time.perf_counter() - t0 < 0.25
    assert q.pop_batch().op == INSERT


def test_window_force_pop_skips_wait():
    q = RequestQueue(default_buckets(8, 64), max_wait_ms=500.0)
    q.submit(Ticket(SEARCH, 2, (10, None)),
             {"queries": np.zeros((2, DIM), np.float32)})
    t0 = time.perf_counter()
    b = q.pop_batch(force=True)
    assert b.n_valid == 2 and time.perf_counter() - t0 < 0.25


def test_window_expires_and_releases_partial_batch():
    q = RequestQueue(default_buckets(8, 64), max_wait_ms=40.0)
    q.submit(Ticket(SEARCH, 2, (10, None)),
             {"queries": np.zeros((2, DIM), np.float32)})
    t0 = time.perf_counter()
    b = q.pop_batch()
    took = time.perf_counter() - t0
    assert b.n_valid == 2
    assert took >= 0.02, "window never held the under-filled head run"


# ---------------------------------------------------------------------------
# Satellite regressions
# ---------------------------------------------------------------------------

def test_submit_search_explicit_zero_k_nprobe_not_replaced(rng):
    """Falsy-zero fix: k=0 / nprobe=0 must not silently become the
    config defaults (the old code used ``k or cfg.search_k``)."""
    base = make_clustered(rng, 400, DIM)
    eng = ServeEngine(SPFreshIndex.build(small_cfg(), base),
                      EngineConfig(search_k=10, nprobe=8))
    empty = np.zeros((0, DIM), np.float32)
    t = eng.submit_search(empty, k=0, nprobe=0)
    assert t.key == (0, 0), f"explicit zeros replaced by defaults: {t.key}"
    d, v = t.result()
    assert d.shape == (0, 0) and v.shape == (0, 0)
    # defaults still apply when the caller passes nothing
    assert eng.submit_search(empty).key == (10, 8)


def test_latency_reservoir_is_bounded_and_counts_all():
    r = _LatReservoir(cap=64, seed=0)
    for i in range(10_000):
        r.add(float(i))
    assert len(r.values()) == 64          # memory stays O(cap)
    assert r.n == 10_000                  # ...but the count is exact
    # algorithm R keeps a uniform sample: the mean of a 0..9999 ramp
    # must land near the middle, not stick to the first 64 values
    assert 2000 < float(np.mean(r.values())) < 8000

    m = ServeMetrics(reservoir=32)
    for i in range(500):
        tk = Ticket(SEARCH, 1, ())
        tk.t_done = tk.t_submit + 0.001 * (i + 1)
        m.note_ticket(tk)
    p = m.percentiles(SEARCH)
    assert set(p) == {"p50_ms", "p90_ms", "p99_ms", "p999_ms",
                      "mean_ms", "n"}
    assert p["n"] == 500
    assert len(m.lat[SEARCH].values()) == 32


def test_insert_backpressure_exhaustion_counts_drops(rng, caplog):
    base = make_clustered(rng, 400, DIM)
    eng = ServeEngine(SPFreshIndex.build(small_cfg(), base),
                      EngineConfig(max_insert_retries=2))

    def never_lands(vecs, vids, valid):
        return np.asarray(vids).copy(), np.zeros(len(vids), bool)

    eng.backend.insert = never_lands
    eng.backend.maintain = lambda budget: 0
    vecs = make_clustered(rng, 4, DIM)
    tk = eng.submit_insert(vecs, np.arange(4, dtype=np.int32))
    with caplog.at_level(logging.WARNING, logger="repro.serve"):
        ids, landed = tk.result()
    assert not landed.any()
    assert tk.dropped == 4                 # per-ticket accounting
    assert eng.metrics.insert_dropped == 4
    assert any("backpressure exhausted" in r.message for r in caplog.records)
