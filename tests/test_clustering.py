import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import (
    balanced_kmeans,
    balanced_two_means,
    hierarchical_balanced_kmeans,
)
from tests.conftest import make_clustered


def test_balanced_kmeans_assigns_valid_only(rng):
    x = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    valid = jnp.asarray(np.arange(64) < 40)
    cen, assign = balanced_kmeans(jax.random.PRNGKey(0), x, valid, k=4)
    assign = np.asarray(assign)
    assert (assign[40:] == -1).all()
    assert set(np.unique(assign[:40])).issubset({0, 1, 2, 3})


def test_balanced_kmeans_balances(rng):
    # Heavily skewed data: one dense blob + sparse outliers.
    x = np.concatenate(
        [
            rng.normal(size=(90, 4)).astype(np.float32) * 0.01,
            rng.normal(size=(10, 4)).astype(np.float32) * 5 + 10,
        ]
    )
    cen, assign = balanced_kmeans(
        jax.random.PRNGKey(1), jnp.asarray(x), jnp.ones(100, bool),
        k=4, balance_weight=4.0, iters=20,
    )
    counts = np.bincount(np.asarray(assign), minlength=4)
    assert counts.max() <= 60, counts  # without penalty one cluster gets ~90


def test_two_means_halves(rng):
    x = jnp.asarray(make_clustered(rng, 100, 16, n_clusters=2))
    valid = jnp.ones(100, bool)
    cen, a = balanced_two_means(jax.random.PRNGKey(0), x, valid)
    a = np.asarray(a)
    n0, n1 = (a == 0).sum(), (a == 1).sum()
    assert n0 + n1 == 100
    assert abs(n0 - n1) <= 1  # hard rebalance to ceil(n/2)


def test_two_means_respects_mask(rng):
    x = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    valid = jnp.asarray(np.arange(32) < 20)
    _, a = balanced_two_means(jax.random.PRNGKey(0), x, valid)
    a = np.asarray(a)
    assert (a[20:] == -1).all()
    assert ((a[:20] == 0) | (a[:20] == 1)).all()


def test_hierarchical_build_bounds_leaf_size(rng):
    x = make_clustered(rng, 2000, 16, n_clusters=10)
    cen, assign = hierarchical_balanced_kmeans(x, max_posting_size=64)
    counts = np.bincount(assign, minlength=cen.shape[0])
    assert counts.max() <= 64
    assert cen.shape[0] >= 2000 // 64
    # every vector assigned
    assert (assign >= 0).all() and assign.max() < cen.shape[0]


def test_hierarchical_build_degenerate_identical_points():
    x = np.ones((100, 8), np.float32)
    cen, assign = hierarchical_balanced_kmeans(x, max_posting_size=16)
    counts = np.bincount(assign, minlength=cen.shape[0])
    assert counts.sum() == 100
