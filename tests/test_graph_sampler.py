"""Neighbor sampler: shape stability, edge validity, GAT trainability."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.graphs import CSRGraph, minibatch_stream, sample_subgraph
from repro.models import gnn


def test_fixed_shapes_across_batches():
    g = CSRGraph.random(500, avg_degree=6, d_feat=8, n_classes=5, seed=0)
    stream = minibatch_stream(g, batch_nodes=16, fanouts=(4, 3))
    b0, b1 = stream(0), stream(1)
    for k in ("features", "edge_src", "edge_dst", "labels"):
        assert b0[k].shape == b1[k].shape, k
    n_expect = 16 + 16 * 4 + 16 * 4 * 3
    e_expect = 16 * 4 + 16 * 4 * 3 + n_expect  # + per-slot self-loops
    assert b0["features"].shape == (n_expect, 8)
    assert b0["edge_src"].shape == (e_expect,)


def test_edges_reference_true_neighbors():
    g = CSRGraph.random(200, avg_degree=5, d_feat=4, n_classes=3, seed=1)
    rng = np.random.default_rng(2)
    targets = rng.choice(200, size=8, replace=False)
    b = sample_subgraph(g, targets, (4,), rng)
    ids = b["node_ids"]
    for s, d in zip(b["edge_src"], b["edge_dst"]):
        if s < 0 or d < 0 or s == d:  # skip pads and self-loops
            continue
        child, parent = ids[s], ids[d]
        assert child in g.neighbors(int(parent)), (child, parent)
    # labels only on targets
    assert (b["labels"][:8] >= 0).all()
    assert (b["labels"][8:] == -1).all()


def test_gat_trains_on_sampled_minibatches():
    from repro.train.optimizer import AdamWConfig, adamw_init, make_train_step

    g = CSRGraph.random(400, avg_degree=8, d_feat=8, n_classes=3, seed=3,
                        feature_signal=1.5)
    cfg = gnn.GATConfig(d_in=8, d_hidden=8, n_heads=2, n_classes=3)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    stream = minibatch_stream(g, batch_nodes=32, fanouts=(5, 3), seed=4)
    step_fn = jax.jit(make_train_step(
        lambda p, b: gnn.loss_fn(p, b, cfg),
        AdamWConfig(lr=2e-2, warmup_steps=5, decay_steps=60,
                    weight_decay=0.0),
    ))
    losses, accs = [], []
    for step in range(60):
        raw = stream(step)
        batch = {k: jnp.asarray(v) for k, v in raw.items() if k != "node_ids"}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        accs.append(float(m["acc"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.9, losses
    assert np.mean(accs[-10:]) > 0.55, accs[-10:]
