import os
import sys

# Tests must see exactly ONE device (the dry-run sets 512 itself, in its own
# process). Keep XLA quiet and deterministic on CPU.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_clustered(rng, n, d, n_clusters=8, spread=0.05):
    """Clustered synthetic vectors (unit-ish scale)."""
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    assign = rng.integers(0, n_clusters, size=n)
    x = centers[assign] + spread * rng.normal(size=(n, d)).astype(np.float32)
    return x.astype(np.float32)
