"""Per-arch smoke tests: every assigned (arch × shape) cell instantiates a
REDUCED config and runs one step on CPU, asserting output shapes + no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import numpy as np
import pytest

from repro.configs import all_cells, arch_names, get_cells

EXPECTED_ARCHS = {
    "granite-20b", "deepseek-7b", "qwen1.5-110b", "granite-moe-1b-a400m",
    "phi3.5-moe-42b-a6.6b", "gat-cora", "bert4rec", "mind",
    "two-tower-retrieval", "deepfm", "spfresh-1b",
}


def test_registry_complete():
    assert set(arch_names()) == EXPECTED_ARCHS
    # 10 assigned archs × their shapes: LM 4 (one skipped), GNN 4, recsys 4.
    # two-tower carries a 5th, beyond-paper cell (retrieval_cand_ann).
    for arch in EXPECTED_ARCHS - {"spfresh-1b", "two-tower-retrieval"}:
        assert len(get_cells(arch)) == 4
    assert len(get_cells("two-tower-retrieval")) == 5
    assert any(
        c.shape == "retrieval_cand_ann"
        for c in get_cells("two-tower-retrieval")
    )


def test_lm_long_500k_skip_reasons():
    for arch in ("granite-20b", "deepseek-7b", "qwen1.5-110b",
                 "granite-moe-1b-a400m", "phi3.5-moe-42b-a6.6b"):
        cells = {c.shape: c for c in get_cells(arch)}
        assert cells["long_500k"].skip_reason is not None
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cells[s].skip_reason is None


def test_exact_assigned_configs():
    from repro.configs import granite_20b, qwen15_110b, phi35_moe_42b_a6_6b, \
        gat_cora, deepfm, two_tower_retrieval, bert4rec, mind, deepseek_7b, \
        granite_moe_1b_a400m
    g = granite_20b.CONFIG
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff, g.vocab) \
        == (52, 6144, 48, 1, 24576, 49152)
    d = deepseek_7b.CONFIG
    assert (d.n_layers, d.d_model, d.n_heads, d.n_kv_heads, d.d_ff, d.vocab) \
        == (30, 4096, 32, 32, 11008, 102400)
    q = qwen15_110b.CONFIG
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff, q.vocab,
            q.qkv_bias) == (80, 8192, 64, 8, 49152, 152064, True)
    gm = granite_moe_1b_a400m.CONFIG
    assert (gm.n_layers, gm.d_model, gm.n_heads, gm.n_kv_heads, gm.d_ff,
            gm.vocab, gm.n_experts, gm.moe_top_k) \
        == (24, 1024, 16, 8, 512, 49155, 32, 8)
    p = phi35_moe_42b_a6_6b.CONFIG
    assert (p.n_layers, p.d_model, p.n_heads, p.n_kv_heads, p.d_ff, p.vocab,
            p.n_experts, p.moe_top_k) == (32, 4096, 32, 8, 6400, 32064, 16, 2)
    ga = gat_cora.CONFIG
    assert (ga.n_layers, ga.d_hidden, ga.n_heads) == (2, 8, 8)
    df = deepfm.CONFIG
    assert (df.n_fields, df.embed_dim, df.mlp_dims) == (39, 10, (400, 400, 400))
    tt = two_tower_retrieval.CONFIG
    assert (tt.embed_dim, tt.tower_dims) == (256, (1024, 512, 256))
    b4 = bert4rec.CONFIG
    assert (b4.embed_dim, b4.n_blocks, b4.n_heads, b4.seq_len) == (64, 2, 2, 200)
    mi = mind.CONFIG
    assert (mi.embed_dim, mi.n_interests, mi.capsule_iters) == (64, 4, 3)


SMOKE_CELLS = [
    c for c in all_cells() if c.skip_reason is None and c.make_smoke_inputs
]


@pytest.mark.parametrize("cell", SMOKE_CELLS, ids=lambda c: c.name)
def test_cell_smoke(cell):
    rng = np.random.default_rng(42)
    args = cell.make_smoke_inputs(cell.smoke_cfg, rng)
    out = jax.jit(cell.smoke_step_fn)(*args)
    leaves = jax.tree_util.tree_leaves(out)
    assert leaves, cell.name
    for leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype.kind == "f":
            assert np.isfinite(arr).all(), f"{cell.name}: non-finite output"
    # train cells must actually change the params
    if cell.kind == "train":
        params_in = jax.tree_util.tree_leaves(args[0])
        params_out = jax.tree_util.tree_leaves(out[0])
        changed = any(
            not np.allclose(np.asarray(a), np.asarray(b))
            for a, b in zip(params_in, params_out)
        )
        assert changed, f"{cell.name}: train step did not update params"
