"""NPA necessary conditions (paper Eq. 1/2) — checked against brute force.

The key property: the conditions are *necessary*, i.e. every vector whose
true nearest posting changes because of the split MUST be flagged.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.npa import split_neighbor_candidates, split_old_posting_candidates


def _dist(a, b):
    return ((a - b) ** 2).sum(-1)


def test_eq1_flags_every_true_violation(rng):
    d = 8
    old = rng.normal(size=(d,)).astype(np.float32)
    new = (old[None, :] + 0.3 * rng.normal(size=(2, d))).astype(np.float32)
    other = rng.normal(size=(20, d)).astype(np.float32)  # other centroids
    v = (old[None, :] + 0.6 * rng.normal(size=(500, d))).astype(np.float32)

    flagged = np.asarray(
        split_old_posting_candidates(jnp.asarray(v), jnp.asarray(old), jnp.asarray(new))
    )
    # Brute force: v was NPA-assigned to old (assume it was). After split its
    # home is one of new; a violation = some *other* centroid is closer than
    # both new ones.
    d_new = np.stack([_dist(v, c) for c in new], axis=1).min(1)
    d_other = np.stack([_dist(v, c) for c in other], axis=1).min(1)
    violated = d_other < d_new
    # Necessary condition: violated ⇒ flagged, *for vectors where old was
    # their previous nearest* (NPA precondition of the proof).
    d_old = _dist(v, old)
    npa_ok = d_old <= d_other  # old centroid was nearest before
    mask = violated & npa_ok
    assert (flagged[mask]).all(), "Eq1 missed a true NPA violation"


def test_eq1_rules_out_safe_vectors(rng):
    # If v is strictly closer to a new centroid than to the old one, Eq. 1
    # says no check is needed.
    d = 4
    old = np.zeros(d, np.float32)
    new = np.stack([np.ones(d), -np.ones(d)]).astype(np.float32)
    v = np.asarray([[1.0, 1.0, 1.0, 1.0]], np.float32)  # on top of new[0]
    flagged = np.asarray(
        split_old_posting_candidates(jnp.asarray(v), jnp.asarray(old), jnp.asarray(new))
    )
    assert not flagged[0]


def test_eq2_flags_neighbors_that_gain_a_closer_centroid(rng):
    d = 8
    old = rng.normal(size=(d,)).astype(np.float32)
    new = (old[None, :] + 0.5 * rng.normal(size=(2, d))).astype(np.float32)
    b = (old + 1.2 * rng.normal(size=(d,))).astype(np.float32)  # neighbor centroid
    v = (b[None, :] + 0.5 * rng.normal(size=(500, d))).astype(np.float32)

    flagged = np.asarray(
        split_neighbor_candidates(jnp.asarray(v), jnp.asarray(old), jnp.asarray(new))
    )
    d_new = np.stack([_dist(v, c) for c in new], axis=1).min(1)
    d_b = _dist(v, b)
    # True violation: a new centroid is now closer than v's current centroid,
    # and v complied with NPA before (d_b <= d_old).
    d_old = _dist(v, old)
    violated = (d_new < d_b) & (d_b <= d_old)
    assert flagged[violated].all(), "Eq2 missed a true violation"


def test_eq2_no_flag_when_new_centroids_farther(rng):
    d = 4
    old = np.zeros(d, np.float32)
    new = np.stack([10 * np.ones(d), -10 * np.ones(d)]).astype(np.float32)
    v = np.asarray([[0.1, 0.0, 0.0, 0.0]], np.float32)
    flagged = np.asarray(
        split_neighbor_candidates(jnp.asarray(v), jnp.asarray(old), jnp.asarray(new))
    )
    assert not flagged[0]
