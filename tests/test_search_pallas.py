"""End-to-end parity: the Pallas paged posting scan (interpret mode) vs the
XLA gather oracle, both schedules, under inserts/deletes/splits.

The two data paths compute ``‖q−x‖²`` with different contraction layouts
(diff² gather vs per-page GEMM expansion), so distances can differ by the
f32 cancellation error of the expansion (~eps·‖q‖²).  On workloads whose
distance gaps resolve above that noise the top-k vids are identical; the
adversarial near-duplicate workload asserts the tie-tolerant contract
instead (any positional difference must be a sub-tolerance distance tie).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

# check.sh runs this suite as its own explicit gate step; the tier-1
# step excludes it via the marker (no hand-maintained --ignore list).
pytestmark = pytest.mark.gate

from repro.core import lire
from repro.core.index import SPFreshIndex
from tests.conftest import make_clustered
from tests.test_lire import small_cfg

SCHEDULES = ("per_query", "batched")

_CACHE: dict = {}


def _churned_index(rng, *, near_dup=False):
    """Build + insert + delete + maintain: splits, stale replicas, GC'd
    postings, freed pages — every masking path the scan must honor.
    Built once per workload shape (fixed seed) and cached — the index is
    read-only in every test; tests that mutate copy the state first."""
    if near_dup in _CACHE:
        return _CACHE[near_dup]
    rng = np.random.default_rng(17 if near_dup else 7)
    base = make_clustered(rng, 900, 16, n_clusters=8)
    idx = SPFreshIndex.build(small_cfg(), base)
    if near_dup:
        extra = (base[0][None, :] + 0.02 * rng.normal(size=(300, 16))
                 ).astype(np.float32)
    else:
        extra = make_clustered(rng, 250, 16, n_clusters=5)
    idx.insert(extra, np.arange(3000, 3000 + len(extra), dtype=np.int32))
    idx.delete(rng.choice(900, size=120, replace=False).astype(np.int32))
    idx.maintain()
    assert idx.stats()["n_splits"] > 0
    queries = np.concatenate([base[200:216], extra[:16]]) \
        + 0.01 * rng.normal(size=(32, 16)).astype(np.float32)
    _CACHE[near_dup] = (idx, jnp.asarray(queries))
    return _CACHE[near_dup]


def _assert_tie_tolerant(d0, v0, d1, v1, tol=1e-4):
    """Positions may differ only where the two paths report a distance tie
    within ``tol`` (f32 expansion noise); everything else is bit-equal."""
    np.testing.assert_allclose(d0, d1, atol=tol)
    mismatch = v0 != v1
    assert (np.abs(d0 - d1)[mismatch] < tol).all(), (
        v0[mismatch], v1[mismatch], d0[mismatch], d1[mismatch]
    )


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_search_parity_under_churn(rng, schedule):
    idx, queries = _churned_index(rng)
    d0, v0 = lire.search(idx.state, queries, k=10, nprobe=8)
    d1, v1 = lire.search(
        idx.state, queries, k=10, nprobe=8,
        use_pallas_scan=True, scan_schedule=schedule,
    )
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), atol=1e-4)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_search_parity_near_duplicates(rng, schedule):
    """300 near-identical inserts: distance gaps at f32 resolution — the
    tie-tolerant contract is the strongest claim either path can make."""
    idx, queries = _churned_index(rng, near_dup=True)
    d0, v0 = lire.search(idx.state, queries, k=10, nprobe=8)
    d1, v1 = lire.search(
        idx.state, queries, k=10, nprobe=8,
        use_pallas_scan=True, scan_schedule=schedule,
    )
    _assert_tie_tolerant(
        np.asarray(d0), np.asarray(v0), np.asarray(d1), np.asarray(v1)
    )


def test_schedules_agree_with_each_other(rng):
    """Both Pallas schedules share kernel math → bit-identical results."""
    idx, queries = _churned_index(rng, near_dup=True)
    d1, v1 = lire.search(
        idx.state, queries, k=10, nprobe=8,
        use_pallas_scan=True, scan_schedule="per_query",
    )
    d2, v2 = lire.search(
        idx.state, queries, k=10, nprobe=8,
        use_pallas_scan=True, scan_schedule="batched",
    )
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_search_parity_respects_deletes(rng, schedule):
    """Deleted vids never surface through the paged scan."""
    cached, queries = _churned_index(rng)
    idx = SPFreshIndex(cached.state)  # jax state is immutable; cache intact
    victims = np.arange(200, 216, dtype=np.int32)
    idx.delete(victims)
    _, v1 = lire.search(
        idx.state, queries, k=10, nprobe=8,
        use_pallas_scan=True, scan_schedule=schedule,
    )
    assert not (set(victims.tolist()) & set(np.asarray(v1).reshape(-1).tolist()))


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_search_parity_config_flag(rng, schedule):
    """The LireConfig flags (not just the call-site override) select the
    Pallas path end-to-end through SPFreshIndex.search."""
    idx, queries = _churned_index(rng)
    d0, v0 = idx.search(np.asarray(queries), 10, nprobe=8)
    flagged = SPFreshIndex(idx.state.replace(cfg=dataclasses.replace(
        idx.state.cfg, use_pallas_scan=True, scan_schedule=schedule,
    )))
    d1, v1 = flagged.search(np.asarray(queries), 10, nprobe=8)
    np.testing.assert_array_equal(v0, v1)
    np.testing.assert_allclose(d0, d1, atol=1e-4)


def test_batched_page_budget_overflow_degrades_gracefully(rng):
    """A starved page budget drops pages (recall loss) but never produces
    duplicates, dead vids, or unsorted results."""
    idx, queries = _churned_index(rng)
    cfg = dataclasses.replace(idx.state.cfg, scan_page_budget=16)
    state = idx.state.replace(cfg=cfg)
    d1, v1 = lire.search(
        state, queries, k=10, nprobe=8,
        use_pallas_scan=True, scan_schedule="batched",
    )
    d1, v1 = np.asarray(d1), np.asarray(v1)
    for row_d, row_v in zip(d1, v1):
        valid = row_v >= 0
        ids = row_v[valid].tolist()
        assert len(ids) == len(set(ids))
        assert (np.diff(row_d[valid]) >= -1e-6).all()
    # a generous budget matches the oracle again
    cfg2 = dataclasses.replace(idx.state.cfg, scan_page_budget=4096)
    d2, v2 = lire.search(
        idx.state.replace(cfg=cfg2), queries, k=10, nprobe=8,
        use_pallas_scan=True, scan_schedule="batched",
    )
    d0, v0 = lire.search(idx.state, queries, k=10, nprobe=8)
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v2))


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_grouped_search_pallas_parity(rng, schedule):
    from repro.core.grouping import build_group_index, search_grouped

    idx, queries = _churned_index(rng)
    gidx = build_group_index(idx.state, n_groups=8, capacity=64)
    d0, v0 = search_grouped(idx.state, gidx, queries, k=10, nprobe=8, gprobe=8)
    d1, v1 = search_grouped(
        idx.state, gidx, queries, k=10, nprobe=8, gprobe=8,
        use_pallas_scan=True, scan_schedule=schedule,
    )
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), atol=1e-4)


def test_grouped_search_probe_chunk_no_longer_dropped(rng):
    """search_grouped used to ignore probe_chunk; the shared reduce
    honors it (same results, chunked gather)."""
    from repro.core.grouping import build_group_index, search_grouped

    idx, queries = _churned_index(rng)
    gidx = build_group_index(idx.state, n_groups=8, capacity=64)
    d0, v0 = search_grouped(idx.state, gidx, queries, k=10, nprobe=8, gprobe=8)
    d1, v1 = search_grouped(
        idx.state, gidx, queries, k=10, nprobe=8, gprobe=8, probe_chunk=4,
    )
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), atol=1e-5)


def test_dedup_topk_matches_reference(rng):
    """The rewritten reduce (top_k prefilter + segment-min) must agree with
    the lexsort reference whenever prefilter covers the duplicates."""
    for trial in range(30):
        n = int(rng.integers(20, 400))
        k = int(rng.integers(1, 12))
        n_vids = max(2, n // int(rng.integers(1, 6)))
        vids = jnp.asarray(rng.integers(0, n_vids, size=n), jnp.int32)
        dists = jnp.asarray(rng.random(size=n), jnp.float32)
        live = jnp.asarray(rng.random(size=n) < 0.8)
        # pre-mask dead entries: the reference otherwise drops a vid whose
        # min-dist occurrence is dead (see _dedup_topk_1d_ref caveat)
        masked = jnp.where(live, dists, lire.MASK_DISTANCE)
        want_d, want_v = lire._dedup_topk_1d_ref(masked, vids, live, k)
        got_d, got_v = lire._dedup_topk_1d(dists, vids, live, k, n)
        np.testing.assert_array_equal(np.asarray(want_v), np.asarray(got_v))
        np.testing.assert_allclose(np.asarray(want_d), np.asarray(got_d))


def test_sharded_index_scan_flags(rng):
    """ShardedIndex threads the scan flags into its shard_map search step
    (1-shard mesh; tie-tolerant — shard_map changes contraction layout)."""
    import jax

    from repro.core.types import LireConfig
    from repro.distributed.sharded_index import ShardedIndex

    cfg = LireConfig(
        dim=16, block_size=8, max_blocks_per_posting=8, num_blocks=2048,
        num_postings_cap=256, num_vectors_cap=8192, split_limit=48,
        merge_limit=6, reassign_range=8, replica_count=2, nprobe=8,
    )
    base = make_clustered(rng, 800, 16, n_clusters=6)
    mesh = jax.make_mesh((1,), ("model",))
    idx0, _ = ShardedIndex.build(mesh, cfg, base, 1)
    idxp = ShardedIndex(
        mesh, cfg, idx0.stacked, 1,
        use_pallas_scan=True, scan_schedule="batched",
    )
    q = base[:16]
    d0, v0 = idx0.search(q, 10, 8)
    d1, v1 = idxp.search(q, 10, 8)
    _assert_tie_tolerant(d0, v0, d1, v1)


def test_engine_scan_knobs(rng):
    """EngineConfig scan knobs reach the search dispatch (results match a
    direct oracle search)."""
    from repro.serve.engine import EngineConfig, ServeEngine

    base = make_clustered(rng, 600, 16, n_clusters=6)
    idx = SPFreshIndex.build(small_cfg(), base)
    queries = base[:16]
    d0, v0 = idx.search(queries, 10)
    eng = ServeEngine(idx, EngineConfig(
        search_k=10, use_pallas_scan=True, scan_schedule="batched",
        probe_chunk=0,
    ))
    d1, v1 = eng.search(queries)
    np.testing.assert_array_equal(v0, v1)
    # probe_chunk knob on the oracle path
    eng2 = ServeEngine(SPFreshIndex(idx.state),
                       EngineConfig(search_k=10, probe_chunk=4))
    d2, v2 = eng2.search(queries)
    np.testing.assert_array_equal(v0, v2)
