"""Crash recovery (paper §4.4): snapshot + WAL replay."""
import os

import numpy as np

from repro.core.index import SPFreshIndex
from repro.core.types import LireConfig
from repro.storage.wal import WriteAheadLog, iter_wal
from tests.conftest import make_clustered
from tests.test_lire import small_cfg


def test_wal_roundtrip(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("insert", {"vecs": np.ones((2, 4), np.float32), "vids": np.asarray([1, 2])})
    wal.append("delete", {"vids": np.asarray([7])})
    wal.close()
    recs = list(iter_wal(path))
    assert [r.op for r in recs] == ["insert", "delete"]
    np.testing.assert_array_equal(recs[0].payload["vids"], [1, 2])
    assert recs[0].seqno == 0 and recs[1].seqno == 1


def test_wal_tolerates_torn_tail(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("delete", {"vids": np.asarray([1])})
    wal.close()
    with open(path, "ab") as fh:
        fh.write(b"SPFW\x99\x00\x00\x00partial")  # torn record
    recs = list(iter_wal(path))
    assert len(recs) == 1


def test_snapshot_then_wal_replay_recovers(tmp_path, rng):
    cfg = small_cfg()
    base = make_clustered(rng, 500, 16, n_clusters=4)
    wal_path = str(tmp_path / "wal.log")
    snap_path = str(tmp_path / "snap")

    idx = SPFreshIndex.build(cfg, base, wal_path=wal_path)
    idx.snapshot(snap_path)

    # Updates after the snapshot — these live only in the WAL.
    extra = make_clustered(rng, 60, 16, n_clusters=2)
    ids = np.arange(6000, 6060, dtype=np.int32)
    idx.insert(extra, ids)
    idx.delete(np.asarray([3, 4], np.int32))
    want_d, want_v = idx.search(extra[:8], 5)

    # "Crash": rebuild from snapshot + WAL.
    rec = SPFreshIndex.restore(snap_path, cfg, wal_path=wal_path)
    got_d, got_v = rec.search(extra[:8], 5)
    np.testing.assert_array_equal(want_v, got_v)
    np.testing.assert_allclose(want_d, got_d, rtol=1e-5)
    # Deleted stay deleted.
    _, got = rec.search(base[3:4], 5)
    assert 3 not in got[0].tolist()


def test_snapshot_truncates_wal(tmp_path, rng):
    cfg = small_cfg()
    base = make_clustered(rng, 300, 16)
    wal_path = str(tmp_path / "wal.log")
    idx = SPFreshIndex.build(cfg, base, wal_path=wal_path)
    idx.insert(base[:4], np.arange(1000, 1004, dtype=np.int32))
    assert os.path.getsize(wal_path) > 0
    idx.snapshot(str(tmp_path / "snap"))
    assert len(list(iter_wal(wal_path))) == 0


def test_restore_without_snapshot_replays_full_wal(tmp_path, rng):
    cfg = small_cfg()
    wal_path = str(tmp_path / "wal.log")
    # Start from an EMPTY index: build 0 postings is degenerate; instead use
    # a small build then log inserts.
    base = make_clustered(rng, 200, 16)
    idx = SPFreshIndex.build(cfg, base, wal_path=wal_path)
    extra = make_clustered(rng, 20, 16)
    idx.insert(extra, np.arange(7000, 7020, dtype=np.int32))
    # No snapshot: restoring from scratch replays the WAL over the template —
    # only the WAL'd updates come back (build state is not in the WAL).
    rec = SPFreshIndex.restore(str(tmp_path / "nosnap"), cfg, wal_path=wal_path)
    assert rec._wal_applied == idx._wal_applied
