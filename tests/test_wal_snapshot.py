"""Crash recovery (paper §4.4): snapshot + WAL replay."""
import os
import struct

import numpy as np
import pytest

from repro.core.index import SPFreshIndex
from repro.core.types import LireConfig
from repro.storage.wal import (
    WalCorruptionError, WalSet, WriteAheadLog, iter_wal,
)
from tests.conftest import make_clustered
from tests.test_lire import small_cfg


def test_wal_roundtrip(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("insert", {"vecs": np.ones((2, 4), np.float32), "vids": np.asarray([1, 2])})
    wal.append("delete", {"vids": np.asarray([7])})
    wal.close()
    recs = list(iter_wal(path))
    assert [r.op for r in recs] == ["insert", "delete"]
    np.testing.assert_array_equal(recs[0].payload["vids"], [1, 2])
    assert recs[0].seqno == 0 and recs[1].seqno == 1


def test_wal_tolerates_torn_tail(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("delete", {"vids": np.asarray([1])})
    wal.close()
    with open(path, "ab") as fh:
        fh.write(b"SPFW\x99\x00\x00\x00partial")  # torn record
    recs = list(iter_wal(path))
    assert len(recs) == 1


def _record_offsets(blob: bytes) -> list[int]:
    """Start offset of every record in an encoded WAL image."""
    offsets, pos = [], 0
    while pos < len(blob):
        _, length = struct.unpack_from("<4sI", blob, pos)
        offsets.append(pos)
        pos += 8 + length
    return offsets


def test_wal_torn_tail_property_every_byte_offset(tmp_path):
    """Truncating the log at EVERY byte offset of the last record must
    yield exactly the earlier records — the crash-mid-append property the
    recovery path relies on (torn tail = op never acknowledged)."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    for i in range(3):
        wal.append("insert", {
            "vecs": np.full((4, 8), i, np.float32),
            "vids": np.arange(4, dtype=np.int32) + 10 * i,
        })
    wal.close()
    with open(path, "rb") as fh:
        blob = fh.read()
    last_start = _record_offsets(blob)[-1]
    trunc = str(tmp_path / "trunc.log")
    for cut in range(last_start, len(blob)):
        with open(trunc, "wb") as fh:
            fh.write(blob[:cut])
        recs = list(iter_wal(trunc))
        assert [r.seqno for r in recs] == [0, 1], f"cut at byte {cut}"
    assert [r.seqno for r in iter_wal(path)] == [0, 1, 2]


def test_wal_midfile_magic_mismatch_raises(tmp_path):
    """A fully-written header with bad magic is corruption, not a tail —
    silently truncating there would drop acknowledged (fsync'd) records."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    for i in range(3):
        wal.append("delete", {"vids": np.asarray([i])})
    wal.close()
    with open(path, "rb") as fh:
        blob = fh.read()
    mid = _record_offsets(blob)[1]
    corrupt = bytearray(blob)
    corrupt[mid:mid + 4] = b"XXXX"
    with open(path, "wb") as fh:
        fh.write(bytes(corrupt))
    with pytest.raises(WalCorruptionError):
        list(iter_wal(path))
    with pytest.raises(WalCorruptionError):
        WriteAheadLog(path)


def test_wal_garbage_magic_at_tail_is_a_tear_not_corruption(tmp_path):
    """A multi-page append can persist later pages without the first
    (no prefix ordering before fsync), leaving garbage where the final
    record's header should be.  That is an UNACKNOWLEDGED tail — it must
    be trimmed, not raised, or a normal crash bricks recovery."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("delete", {"vids": np.asarray([1])})
    wal.append("delete", {"vids": np.asarray([2])})
    wal.close()
    with open(path, "ab") as fh:
        fh.write(b"\x00GARBAGE\x00" * 40)       # bad magic, no record after
    assert [r.seqno for r in iter_wal(path)] == [0, 1]
    wal2 = WriteAheadLog(path)                  # trims the garbage tail
    wal2.append("delete", {"vids": np.asarray([3])})
    wal2.close()
    assert [r.seqno for r in iter_wal(path)] == [0, 1, 2]


def test_wal_reopen_trims_torn_tail_then_appends(tmp_path):
    """Reopening a log with a torn tail must trim it — otherwise new
    appends land after the garbage and the reader never sees them."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("delete", {"vids": np.asarray([1])})
    wal.append("delete", {"vids": np.asarray([2])})
    wal.close()
    size = os.path.getsize(path)
    with open(path, "ab") as fh:
        fh.write(b"SPFW\x99\x00\x00\x00partial")   # torn record
    wal2 = WriteAheadLog(path)
    assert os.path.getsize(path) == size           # tail trimmed
    wal2.append("delete", {"vids": np.asarray([3])})
    wal2.close()
    assert [r.seqno for r in iter_wal(path)] == [0, 1, 2]


def test_wal_append_is_immediately_durable(tmp_path):
    """The fsync-per-append contract: a record must be readable through a
    fresh file handle the moment append() returns (no close/flush)."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("insert", {"vecs": np.ones((2, 4), np.float32),
                          "vids": np.asarray([5, 6])})
    recs = list(iter_wal(path))      # separate fd, wal still open
    assert len(recs) == 1 and recs[0].seqno == 0
    wal.close()


def test_walset_resyncs_lagging_shard_logs(tmp_path):
    """A crash can tear the per-shard logs at different records; recovery
    takes the longest clean log as authoritative and re-syncs the rest."""
    ws = WalSet(str(tmp_path / "wal"), 3)
    for i in range(4):
        ws.append("delete", {"vids": np.asarray([i])})
    ws.close()
    # shard 1 lost its last record, shard 2 its last two (torn at the
    # record boundary = fsync'd on shard 0 only)
    for shard, keep in ((1, 3), (2, 2)):
        path = ws.shard_path(shard)
        with open(path, "rb") as fh:
            blob = fh.read()
        cut = _record_offsets(blob)[keep]
        with open(path, "wb") as fh:
            fh.write(blob[:cut])
    ws2 = WalSet(str(tmp_path / "wal"), 3)
    recs = ws2.recover_records()
    assert [r.seqno for r in recs] == [0, 1, 2, 3]
    assert ws2.last_seqnos() == [3, 3, 3]
    for shard in range(3):           # every log re-synced on disk
        assert [r.seqno for r in iter_wal(ws2.shard_path(shard))] == [0, 1, 2, 3]
    assert ws2.append("delete", {"vids": np.asarray([9])}) == 4
    ws2.close()


def test_walset_salvages_one_corrupt_log_from_clean_replicas(tmp_path):
    """Mid-file corruption in ONE shard log must not brick recovery when
    clean replicas exist: the corrupt log is repaired from the longest
    readable stream.  Only all-logs-corrupt raises."""
    ws = WalSet(str(tmp_path / "wal"), 3)
    for i in range(4):
        ws.append("delete", {"vids": np.asarray([i])})
    ws.close()
    path1 = ws.shard_path(1)
    with open(path1, "rb") as fh:
        blob = fh.read()
    mid = _record_offsets(blob)[1]
    corrupt = bytearray(blob)
    corrupt[mid:mid + 4] = b"XXXX"
    with open(path1, "wb") as fh:
        fh.write(bytes(corrupt))
    ws2 = WalSet(str(tmp_path / "wal"), 3)       # salvage, no raise
    recs = ws2.recover_records()
    assert [r.seqno for r in recs] == [0, 1, 2, 3]
    assert [r.seqno for r in iter_wal(path1)] == [0, 1, 2, 3]  # repaired
    ws2.close()
    # single-log set (local backend): corruption has no replica to heal
    # from and must surface
    ws3 = WalSet(str(tmp_path / "wal1"), 1)
    ws3.append("delete", {"vids": np.asarray([0])})
    ws3.append("delete", {"vids": np.asarray([1])})
    ws3.close()
    p = ws3.shard_path(0)
    with open(p, "rb") as fh:
        blob = fh.read()
    corrupt = bytearray(blob)
    corrupt[0:4] = b"XXXX"
    with open(p, "wb") as fh:
        fh.write(bytes(corrupt))
    with pytest.raises(WalCorruptionError):
        WalSet(str(tmp_path / "wal1"), 1)


def test_snapshot_swap_never_leaves_no_snapshot(tmp_path, rng):
    """save_snapshot rotates the old snapshot aside before the new one
    commits; a crash between the two renames leaves ``path.old``, which
    snapshot_exists/load_snapshot resolve — never zero snapshots."""
    from repro.storage.snapshot import (
        load_snapshot, save_snapshot, snapshot_exists,
    )

    snap = str(tmp_path / "snap")
    state = {"x": np.arange(4, dtype=np.float32),
             "y": np.ones((2, 2), np.float32)}
    save_snapshot(snap, state, extra={"gen": 1})
    save_snapshot(snap, state, extra={"gen": 2})
    assert not os.path.exists(snap + ".old")     # happy path cleans up
    # crash window: the previous snapshot was rotated aside but the new
    # one never landed
    os.replace(snap, snap + ".old")
    assert snapshot_exists(snap)
    _, manifest = load_snapshot(snap, state)
    assert manifest["extra"]["gen"] == 2
    # and the next save must not delete the fallback before its own
    # commit: even simulating a crash right before that commit (the .old
    # is all there is), a snapshot remains loadable
    assert snapshot_exists(snap)
    save_snapshot(snap, state, extra={"gen": 3})
    _, manifest = load_snapshot(snap, state)
    assert manifest["extra"]["gen"] == 3
    assert not os.path.exists(snap + ".old")


def test_snapshot_then_wal_replay_recovers(tmp_path, rng):
    cfg = small_cfg()
    base = make_clustered(rng, 500, 16, n_clusters=4)
    wal_path = str(tmp_path / "wal.log")
    snap_path = str(tmp_path / "snap")

    idx = SPFreshIndex.build(cfg, base, wal_path=wal_path)
    idx.snapshot(snap_path)

    # Updates after the snapshot — these live only in the WAL.
    extra = make_clustered(rng, 60, 16, n_clusters=2)
    ids = np.arange(6000, 6060, dtype=np.int32)
    idx.insert(extra, ids)
    idx.delete(np.asarray([3, 4], np.int32))
    want_d, want_v = idx.search(extra[:8], 5)

    # "Crash": rebuild from snapshot + WAL.
    rec = SPFreshIndex.restore(snap_path, cfg, wal_path=wal_path)
    got_d, got_v = rec.search(extra[:8], 5)
    np.testing.assert_array_equal(want_v, got_v)
    np.testing.assert_allclose(want_d, got_d, rtol=1e-5)
    # Deleted stay deleted.
    _, got = rec.search(base[3:4], 5)
    assert 3 not in got[0].tolist()


def test_snapshot_truncates_wal(tmp_path, rng):
    cfg = small_cfg()
    base = make_clustered(rng, 300, 16)
    wal_path = str(tmp_path / "wal.log")
    idx = SPFreshIndex.build(cfg, base, wal_path=wal_path)
    idx.insert(base[:4], np.arange(1000, 1004, dtype=np.int32))
    assert os.path.getsize(wal_path) > 0
    idx.snapshot(str(tmp_path / "snap"))
    assert len(list(iter_wal(wal_path))) == 0


def test_restore_without_snapshot_replays_full_wal(tmp_path, rng):
    cfg = small_cfg()
    wal_path = str(tmp_path / "wal.log")
    # Start from an EMPTY index: build 0 postings is degenerate; instead use
    # a small build then log inserts.
    base = make_clustered(rng, 200, 16)
    idx = SPFreshIndex.build(cfg, base, wal_path=wal_path)
    extra = make_clustered(rng, 20, 16)
    idx.insert(extra, np.arange(7000, 7020, dtype=np.int32))
    # No snapshot: restoring from scratch replays the WAL over the template —
    # only the WAL'd updates come back (build state is not in the WAL).
    rec = SPFreshIndex.restore(str(tmp_path / "nosnap"), cfg, wal_path=wal_path)
    assert rec._wal_applied == idx._wal_applied
