"""Crash recovery (paper §4.4): snapshot + WAL replay."""
import os
import struct

import numpy as np
import pytest

from repro.core.index import SPFreshIndex
from repro.core.types import LireConfig
from repro.storage.wal import (
    WalCorruptionError, WalSet, WriteAheadLog, iter_wal,
)
from tests.conftest import make_clustered
from tests.test_lire import small_cfg


def test_wal_roundtrip(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("insert", {"vecs": np.ones((2, 4), np.float32), "vids": np.asarray([1, 2])})
    wal.append("delete", {"vids": np.asarray([7])})
    wal.close()
    recs = list(iter_wal(path))
    assert [r.op for r in recs] == ["insert", "delete"]
    np.testing.assert_array_equal(recs[0].payload["vids"], [1, 2])
    assert recs[0].seqno == 0 and recs[1].seqno == 1


def test_wal_tolerates_torn_tail(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("delete", {"vids": np.asarray([1])})
    wal.close()
    with open(path, "ab") as fh:
        fh.write(b"SPFW\x99\x00\x00\x00partial")  # torn record
    recs = list(iter_wal(path))
    assert len(recs) == 1


def _record_offsets(blob: bytes) -> list[int]:
    """Start offset of every record in an encoded WAL image."""
    offsets, pos = [], 0
    while pos < len(blob):
        _, length = struct.unpack_from("<4sI", blob, pos)
        offsets.append(pos)
        pos += 8 + length
    return offsets


def test_wal_torn_tail_property_every_byte_offset(tmp_path):
    """Truncating the log at EVERY byte offset of the last record must
    yield exactly the earlier records — the crash-mid-append property the
    recovery path relies on (torn tail = op never acknowledged)."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    for i in range(3):
        wal.append("insert", {
            "vecs": np.full((4, 8), i, np.float32),
            "vids": np.arange(4, dtype=np.int32) + 10 * i,
        })
    wal.close()
    with open(path, "rb") as fh:
        blob = fh.read()
    last_start = _record_offsets(blob)[-1]
    trunc = str(tmp_path / "trunc.log")
    for cut in range(last_start, len(blob)):
        with open(trunc, "wb") as fh:
            fh.write(blob[:cut])
        recs = list(iter_wal(trunc))
        assert [r.seqno for r in recs] == [0, 1], f"cut at byte {cut}"
    assert [r.seqno for r in iter_wal(path)] == [0, 1, 2]


def test_wal_midfile_magic_mismatch_raises(tmp_path):
    """A fully-written header with bad magic is corruption, not a tail —
    silently truncating there would drop acknowledged (fsync'd) records."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    for i in range(3):
        wal.append("delete", {"vids": np.asarray([i])})
    wal.close()
    with open(path, "rb") as fh:
        blob = fh.read()
    mid = _record_offsets(blob)[1]
    corrupt = bytearray(blob)
    corrupt[mid:mid + 4] = b"XXXX"
    with open(path, "wb") as fh:
        fh.write(bytes(corrupt))
    with pytest.raises(WalCorruptionError):
        list(iter_wal(path))
    with pytest.raises(WalCorruptionError):
        WriteAheadLog(path)


def test_wal_garbage_magic_at_tail_is_a_tear_not_corruption(tmp_path):
    """A multi-page append can persist later pages without the first
    (no prefix ordering before fsync), leaving garbage where the final
    record's header should be.  That is an UNACKNOWLEDGED tail — it must
    be trimmed, not raised, or a normal crash bricks recovery."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("delete", {"vids": np.asarray([1])})
    wal.append("delete", {"vids": np.asarray([2])})
    wal.close()
    with open(path, "ab") as fh:
        fh.write(b"\x00GARBAGE\x00" * 40)       # bad magic, no record after
    assert [r.seqno for r in iter_wal(path)] == [0, 1]
    wal2 = WriteAheadLog(path)                  # trims the garbage tail
    wal2.append("delete", {"vids": np.asarray([3])})
    wal2.close()
    assert [r.seqno for r in iter_wal(path)] == [0, 1, 2]


def test_wal_reopen_trims_torn_tail_then_appends(tmp_path):
    """Reopening a log with a torn tail must trim it — otherwise new
    appends land after the garbage and the reader never sees them."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("delete", {"vids": np.asarray([1])})
    wal.append("delete", {"vids": np.asarray([2])})
    wal.close()
    size = os.path.getsize(path)
    with open(path, "ab") as fh:
        fh.write(b"SPFW\x99\x00\x00\x00partial")   # torn record
    wal2 = WriteAheadLog(path)
    assert os.path.getsize(path) == size           # tail trimmed
    wal2.append("delete", {"vids": np.asarray([3])})
    wal2.close()
    assert [r.seqno for r in iter_wal(path)] == [0, 1, 2]


def test_wal_append_is_immediately_durable(tmp_path):
    """The fsync-per-append contract: a record must be readable through a
    fresh file handle the moment append() returns (no close/flush)."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append("insert", {"vecs": np.ones((2, 4), np.float32),
                          "vids": np.asarray([5, 6])})
    recs = list(iter_wal(path))      # separate fd, wal still open
    assert len(recs) == 1 and recs[0].seqno == 0
    wal.close()


def test_walset_resyncs_lagging_shard_logs(tmp_path):
    """A crash can tear the per-shard logs at different records; recovery
    takes the longest clean log as authoritative and re-syncs the rest."""
    ws = WalSet(str(tmp_path / "wal"), 3)
    for i in range(4):
        ws.append("delete", {"vids": np.asarray([i])})
    ws.close()
    # shard 1 lost its last record, shard 2 its last two (torn at the
    # record boundary = fsync'd on shard 0 only)
    for shard, keep in ((1, 3), (2, 2)):
        path = ws.shard_path(shard)
        with open(path, "rb") as fh:
            blob = fh.read()
        cut = _record_offsets(blob)[keep]
        with open(path, "wb") as fh:
            fh.write(blob[:cut])
    ws2 = WalSet(str(tmp_path / "wal"), 3)
    recs = ws2.recover_records()
    assert [r.seqno for r in recs] == [0, 1, 2, 3]
    assert ws2.last_seqnos() == [3, 3, 3]
    for shard in range(3):           # every log re-synced on disk
        assert [r.seqno for r in iter_wal(ws2.shard_path(shard))] == [0, 1, 2, 3]
    assert ws2.append("delete", {"vids": np.asarray([9])}) == 4
    ws2.close()


def test_walset_salvages_one_corrupt_log_from_clean_replicas(tmp_path):
    """Mid-file corruption in ONE shard log must not brick recovery when
    clean replicas exist: the corrupt log is repaired from the longest
    readable stream.  Only all-logs-corrupt raises."""
    ws = WalSet(str(tmp_path / "wal"), 3)
    for i in range(4):
        ws.append("delete", {"vids": np.asarray([i])})
    ws.close()
    path1 = ws.shard_path(1)
    with open(path1, "rb") as fh:
        blob = fh.read()
    mid = _record_offsets(blob)[1]
    corrupt = bytearray(blob)
    corrupt[mid:mid + 4] = b"XXXX"
    with open(path1, "wb") as fh:
        fh.write(bytes(corrupt))
    ws2 = WalSet(str(tmp_path / "wal"), 3)       # salvage, no raise
    recs = ws2.recover_records()
    assert [r.seqno for r in recs] == [0, 1, 2, 3]
    assert [r.seqno for r in iter_wal(path1)] == [0, 1, 2, 3]  # repaired
    ws2.close()
    # single-log set (local backend): corruption has no replica to heal
    # from and must surface
    ws3 = WalSet(str(tmp_path / "wal1"), 1)
    ws3.append("delete", {"vids": np.asarray([0])})
    ws3.append("delete", {"vids": np.asarray([1])})
    ws3.close()
    p = ws3.shard_path(0)
    with open(p, "rb") as fh:
        blob = fh.read()
    corrupt = bytearray(blob)
    corrupt[0:4] = b"XXXX"
    with open(p, "wb") as fh:
        fh.write(bytes(corrupt))
    with pytest.raises(WalCorruptionError):
        WalSet(str(tmp_path / "wal1"), 1)


def test_snapshot_swap_never_leaves_no_snapshot(tmp_path, rng):
    """save_snapshot rotates the old snapshot aside before the new one
    commits; a crash between the two renames leaves ``path.old``, which
    snapshot_exists/load_snapshot resolve — never zero snapshots."""
    from repro.storage.snapshot import (
        load_snapshot, save_snapshot, snapshot_exists,
    )

    snap = str(tmp_path / "snap")
    state = {"x": np.arange(4, dtype=np.float32),
             "y": np.ones((2, 2), np.float32)}
    save_snapshot(snap, state, extra={"gen": 1})
    save_snapshot(snap, state, extra={"gen": 2})
    assert not os.path.exists(snap + ".old")     # happy path cleans up
    # crash window: the previous snapshot was rotated aside but the new
    # one never landed
    os.replace(snap, snap + ".old")
    assert snapshot_exists(snap)
    _, manifest = load_snapshot(snap, state)
    assert manifest["extra"]["gen"] == 2
    # and the next save must not delete the fallback before its own
    # commit: even simulating a crash right before that commit (the .old
    # is all there is), a snapshot remains loadable
    assert snapshot_exists(snap)
    save_snapshot(snap, state, extra={"gen": 3})
    _, manifest = load_snapshot(snap, state)
    assert manifest["extra"]["gen"] == 3
    assert not os.path.exists(snap + ".old")


def test_snapshot_then_wal_replay_recovers(tmp_path, rng):
    cfg = small_cfg()
    base = make_clustered(rng, 500, 16, n_clusters=4)
    wal_path = str(tmp_path / "wal.log")
    snap_path = str(tmp_path / "snap")

    idx = SPFreshIndex.build(cfg, base, wal_path=wal_path)
    idx.snapshot(snap_path)

    # Updates after the snapshot — these live only in the WAL.
    extra = make_clustered(rng, 60, 16, n_clusters=2)
    ids = np.arange(6000, 6060, dtype=np.int32)
    idx.insert(extra, ids)
    idx.delete(np.asarray([3, 4], np.int32))
    want_d, want_v = idx.search(extra[:8], 5)

    # "Crash": rebuild from snapshot + WAL.
    rec = SPFreshIndex.restore(snap_path, cfg, wal_path=wal_path)
    got_d, got_v = rec.search(extra[:8], 5)
    np.testing.assert_array_equal(want_v, got_v)
    np.testing.assert_allclose(want_d, got_d, rtol=1e-5)
    # Deleted stay deleted.
    _, got = rec.search(base[3:4], 5)
    assert 3 not in got[0].tolist()


def test_snapshot_truncates_wal(tmp_path, rng):
    cfg = small_cfg()
    base = make_clustered(rng, 300, 16)
    wal_path = str(tmp_path / "wal.log")
    idx = SPFreshIndex.build(cfg, base, wal_path=wal_path)
    idx.insert(base[:4], np.arange(1000, 1004, dtype=np.int32))
    assert os.path.getsize(wal_path) > 0
    idx.snapshot(str(tmp_path / "snap"))
    assert len(list(iter_wal(wal_path))) == 0


def test_restore_without_snapshot_replays_full_wal(tmp_path, rng):
    cfg = small_cfg()
    wal_path = str(tmp_path / "wal.log")
    # Start from an EMPTY index: build 0 postings is degenerate; instead use
    # a small build then log inserts.
    base = make_clustered(rng, 200, 16)
    idx = SPFreshIndex.build(cfg, base, wal_path=wal_path)
    extra = make_clustered(rng, 20, 16)
    idx.insert(extra, np.arange(7000, 7020, dtype=np.int32))
    # No snapshot: restoring from scratch replays the WAL over the template —
    # only the WAL'd updates come back (build state is not in the WAL).
    rec = SPFreshIndex.restore(str(tmp_path / "nosnap"), cfg, wal_path=wal_path)
    assert rec._wal_applied == idx._wal_applied


# ---------------------------------------------------------------------------
# Delta snapshot chain (SnapshotStore)
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return LireConfig(
        dim=8, block_size=4, max_blocks_per_posting=4, num_blocks=128,
        num_postings_cap=32, num_vectors_cap=1024, split_limit=12,
        merge_limit=2, replica_count=2, nprobe=4,
    )


def _evolve_states(rng, n_steps=3):
    """A build + a few update batches; returns the per-checkpoint states
    with the dirty ledger cleared exactly as the backends do."""
    import jax.numpy as jnp
    from repro.core import lire
    from repro.core.index import build_state
    from repro.storage import blockpool as bp

    cfg = _tiny_cfg()
    base = make_clustered(rng, 120, 8, n_clusters=4)
    state = build_state(cfg, base)
    state = state.replace(pool=bp.clear_dirty(state.pool))
    states = [state]
    nid = 200
    for step in range(n_steps):
        vecs = make_clustered(rng, 12, 8, n_clusters=2)
        state, _ = lire.insert_batch(
            state, jnp.asarray(vecs),
            jnp.arange(nid, nid + 12, dtype=jnp.int32), jnp.ones(12, bool),
        )
        state = lire.delete_batch(
            state, jnp.arange(nid, nid + 3, dtype=jnp.int32),
            jnp.ones(3, bool),
        )
        nid += 12
        states.append(state)           # dirty ledger still set: delta input
        state = state.replace(pool=bp.clear_dirty(state.pool))
        states[-1] = (states[-1], state)   # (delta input, cleared twin)
    return cfg, states


def _assert_states_equal(a, b):
    import jax

    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_snapshot_store_delta_chain_roundtrip(tmp_path, rng):
    """base → delta → delta restores the exact final state (blocks folded
    block-by-block, dense leaves overwritten, dirty ledger reset)."""
    from repro.core.types import make_empty_state
    from repro.storage.snapshot import SnapshotStore

    cfg, states = _evolve_states(rng)
    store = SnapshotStore(str(tmp_path / "snap"))
    store.save_base(states[0], extra={"wal_seqnos": [0]})
    for i, (dirty_state, _cleared) in enumerate(states[1:], start=1):
        store.save_delta(dirty_state, extra={"wal_seqnos": [i]})
    assert store.chain_len() == len(states) - 1
    got, manifest = store.load(make_empty_state(cfg))
    assert manifest["extra"]["wal_seqnos"] == [len(states) - 1]
    _assert_states_equal(got, states[-1][1])   # == final cleared state
    # a delta is much smaller than the base it chains to
    head_bytes = store.unit_bytes()
    base_bytes = store.unit_bytes(store._chain(store._head())[0])
    assert head_bytes < 0.5 * base_bytes, (head_bytes, base_bytes)


def test_snapshot_store_compaction_folds_and_prunes(tmp_path, rng):
    from repro.core.types import make_empty_state
    from repro.storage.snapshot import SnapshotStore

    cfg, states = _evolve_states(rng)
    store = SnapshotStore(str(tmp_path / "snap"))
    store.save_base(states[0])
    for dirty_state, _ in states[1:]:
        store.save_delta(dirty_state)
    final = states[-1][1]
    store.save_base(final)                      # the compaction fold
    assert store.chain_len() == 0
    units = store._units()
    assert len(units) == 1 and units[0].startswith("base-")
    got, _ = store.load(make_empty_state(cfg))
    _assert_states_equal(got, final)


def test_snapshot_store_crash_at_every_fold_step(tmp_path, rng):
    """Kill the store at EVERY crash point of the base→delta→compaction
    lifecycle; after each kill a fresh SnapshotStore must still resolve a
    complete recovery point equal to the last committed logical state.
    (The torn-tail harness's discipline applied to the snapshot chain.)"""
    from repro.core.types import make_empty_state
    from repro.storage import snapshot as snap_mod
    from repro.storage.snapshot import SnapshotStore

    cfg, states = _evolve_states(rng)
    template = make_empty_state(cfg)
    final = states[-1][1]

    class Boom(Exception):
        pass

    def run_lifecycle(store):
        """(label, expected-state-after-commit) steps of the lifecycle."""
        store.save_base(states[0])
        yield "base"
        for i, (dirty_state, cleared) in enumerate(states[1:]):
            store.save_delta(dirty_state)
            yield f"delta{i}"
        store.save_base(final)                  # compaction
        yield "compact"

    # Pass 1: count the crash points of each lifecycle stage.
    labels = []
    snap_mod._crash_hook = lambda label: labels.append(label)
    try:
        root0 = str(tmp_path / "count")
        for _ in run_lifecycle(SnapshotStore(root0)):
            pass
    finally:
        snap_mod._crash_hook = None
    n_points = len(labels)
    assert n_points >= 8, f"expected several crash points, saw {labels}"

    # Pass 2: for every k, crash at the k-th point and assert recovery.
    committed = {  # stage completed before the crash → expected state
        "start": states[0], "base": states[0], "compact": final,
    }
    for i, (_d, cleared) in enumerate(states[1:]):
        committed[f"delta{i}"] = cleared
    for k in range(1, n_points + 1):
        calls = {"n": 0}

        def hook(label, _k=k):
            calls["n"] += 1
            if calls["n"] == _k:
                raise Boom(label)

        root = str(tmp_path / f"crash_{k}")
        store = SnapshotStore(root)
        done = "start"
        snap_mod._crash_hook = hook
        try:
            for stage in run_lifecycle(store):
                done = stage
        except Boom:
            pass
        finally:
            snap_mod._crash_hook = None
        reopened = SnapshotStore(root)
        if done == "start" and not reopened.exists():
            continue  # crashed before the very first commit: empty root
        got, _ = reopened.load(template)
        want = committed[done]
        try:
            _assert_states_equal(got, want)
        except AssertionError:
            # a crash AFTER the unit commit but before cleanup may
            # already expose the next stage — equally valid (the WAL is
            # truncated only after save returns, and replay is
            # idempotent past the stamped seqno)
            stages = ["start", "base"] + [
                f"delta{i}" for i in range(len(states) - 1)
            ] + ["compact"]
            nxt = stages[stages.index(done) + 1]
            _assert_states_equal(got, committed[nxt])


def test_snapshot_store_reads_legacy_full_snapshot(tmp_path, rng):
    """A durable root written by the pre-chain code (manifest.json at the
    store root, one leaf short of today's pool) must load: the missing
    dirty ledger is migrated in as all-clean, and the first save_base
    converts the root to the chained layout."""
    import jax
    from repro.core.types import make_empty_state
    from repro.storage.snapshot import SnapshotStore, _dirty_leaf_index
    import json as json_mod

    cfg, states = _evolve_states(rng, n_steps=1)
    final = states[-1][1]
    leaves = jax.tree_util.tree_leaves(final)
    di = _dirty_leaf_index(final)
    legacy = [np.asarray(x) for i, x in enumerate(leaves) if i != di]
    root = tmp_path / "snap"
    root.mkdir()
    np.savez(root / "leaves.npz",
             **{f"leaf_{i}": a for i, a in enumerate(legacy)})
    (root / "manifest.json").write_text(json_mod.dumps(
        {"n_leaves": len(legacy), "step": 0, "extra": {"wal_seqnos": [5]}}
    ))
    store = SnapshotStore(str(root))
    assert store.exists() and not store.has_base()
    got, manifest = store.load(make_empty_state(cfg))
    assert manifest["extra"]["wal_seqnos"] == [5]
    _assert_states_equal(got, final)
    store.save_base(got)
    assert store.has_base()
    assert not (root / "manifest.json").exists()   # legacy files pruned


# ---------------------------------------------------------------------------
# WAL group commit + compaction
# ---------------------------------------------------------------------------

def test_wal_group_commit_batches_fsyncs(tmp_path):
    ws = WalSet(str(tmp_path / "wal"), 2)
    ws.set_group_commit(4)
    for i in range(10):
        ws.append("delete", {"vids": np.asarray([i])})
    # 10 appends → 2 full windows of 4; 2 records still pending
    assert ws.pending == 2
    assert ws.n_fsyncs == 2 * 2                 # 2 windows × 2 shard logs
    ws.sync()                                   # the ack point
    assert ws.pending == 0 and ws.n_fsyncs == 3 * 2
    ws.sync()                                   # clean sync is free
    assert ws.n_fsyncs == 3 * 2
    st = ws.stats()
    assert st["appends"] == 10
    assert st["fsyncs_per_append"] < 1.0
    # every record is readable post-sync
    assert [r.seqno for r in iter_wal(ws.shard_path(0))] == list(range(10))
    ws.close()


def test_wal_group_commit_off_syncs_every_append(tmp_path):
    ws = WalSet(str(tmp_path / "wal"), 1)
    for i in range(5):
        ws.append("delete", {"vids": np.asarray([i])})
    assert ws.pending == 0 and ws.n_fsyncs == 5
    ws.close()


def test_compact_wal_records_drops_dead_insert_rows():
    from repro.storage.wal import WalRecord, compact_wal_records

    def ins(seq, vids, valid=None):
        vids = np.asarray(vids, np.int32)
        return WalRecord("insert", {
            "vecs": np.zeros((len(vids), 4), np.float32), "vids": vids,
            "valid": (np.ones(len(vids), bool) if valid is None
                      else np.asarray(valid, bool)),
        }, seq)

    def dele(seq, vids):
        vids = np.asarray(vids, np.int32)
        return WalRecord("delete", {
            "vids": vids, "valid": np.ones(len(vids), bool)}, seq)

    recs = [
        ins(0, [1, 2, 3]),
        dele(1, [2]),            # kills row vid=2 of record 0
        ins(2, [4, 5]),
        dele(3, [4, 5]),         # record 2 fully dead → dropped
        ins(4, [2]),             # REINSERT of 2 after its delete: kept
        WalRecord("maintain", {"jobs": np.asarray(4)}, 5),
    ]
    out, dropped = compact_wal_records(recs)
    assert dropped == 3          # vid2@0, vid4@2, vid5@2
    assert [r.seqno for r in out] == [0, 1, 3, 4, 5]
    np.testing.assert_array_equal(out[0].payload["valid"],
                                  [True, False, True])
    assert out[3].op == "insert"          # the reinsert survives intact
    np.testing.assert_array_equal(out[3].payload["valid"], [True])
    # deletes and maintains are never dropped
    assert [r.op for r in out] == [
        "insert", "delete", "delete", "insert", "maintain"]


# ---------------------------------------------------------------------------
# Replication stream (read replicas tail the dispatch log)
# ---------------------------------------------------------------------------

def _durable_backend(tmp_path, rng, n=400):
    """A LocalBackend with a WalSet attached — the replication primary."""
    from repro.serve.engine import LocalBackend

    cfg = small_cfg()
    base = make_clustered(rng, n, 16, n_clusters=4)
    backend = LocalBackend(SPFreshIndex.build(cfg, base))
    ws = WalSet(str(tmp_path / "wal"), 1)
    backend.attach_durability(ws)
    return backend, ws


def _pad_insert(backend, rng, vid0, n=8):
    vecs = make_clustered(rng, n, 16, n_clusters=2)
    backend.insert(vecs, np.arange(vid0, vid0 + n, dtype=np.int32),
                   np.ones(n, bool))


def test_replica_tails_live_wal_in_seqno_order(tmp_path, rng):
    """The async-replication contract over a LIVE WalSet tail: a replica
    that repeatedly replays ``iter_wal(path, after_seqno=cursor)`` while
    the primary keeps appending receives exactly the records past its
    cursor, contiguous and in seqno order, and converges to bit-parity
    every time it drains the tail."""
    from repro.distributed.replication import states_equal

    primary, ws = _durable_backend(tmp_path, rng)
    replica = primary.clone()                  # applied == primary (-1)
    path = ws.shard_path(0)
    for step in range(3):
        _pad_insert(primary, rng, 1000 + 100 * step)
        primary.delete(np.asarray([1000 + 100 * step], np.int32),
                       np.ones(1, bool))
        cursor = replica._wal_applied
        recs = list(iter_wal(path, after_seqno=cursor))
        assert [r.seqno for r in recs] == list(
            range(cursor + 1, primary._wal_applied + 1))
        replica.replay(recs, after_seqno=cursor)
        assert replica._wal_applied == primary._wal_applied
        assert states_equal(primary.index.state, replica.index.state)


def test_replica_replay_is_idempotent_on_redelivery(tmp_path, rng):
    """The window hands a replica at-least-once delivery: re-replaying
    records at or below the cursor (an overlapping read of the tail)
    must apply nothing and leave the state bit-identical."""
    from repro.distributed.replication import states_equal

    primary, ws = _durable_backend(tmp_path, rng)
    replica = primary.clone()
    for step in range(2):
        _pad_insert(primary, rng, 2000 + 100 * step)
    all_recs = list(iter_wal(ws.shard_path(0), after_seqno=-1))
    assert replica.replay(all_recs, after_seqno=replica._wal_applied) == 2
    before = replica.fork_state()
    # full redelivery, then an overlapping window: both no-ops
    assert replica.replay(all_recs, after_seqno=replica._wal_applied) == 0
    assert replica.replay(all_recs[-1:],
                          after_seqno=replica._wal_applied) == 0
    assert replica._wal_applied == primary._wal_applied
    assert states_equal(before, replica.index.state)
    assert states_equal(primary.index.state, replica.index.state)


def test_replica_catchup_from_snapshot_plus_tail(tmp_path, rng):
    """The window-overflow path: a replica too far behind adopts a fork
    of the primary at seqno S and replays only the tail past S —
    landing bit-identical to a replica that replayed everything."""
    from repro.distributed.replication import states_equal

    primary, ws = _durable_backend(tmp_path, rng)
    patient = primary.clone()                  # replays the full stream
    for step in range(2):
        _pad_insert(primary, rng, 3000 + 100 * step)
    fork, fork_seqno = primary.fork_state(), primary._wal_applied
    for step in range(2):                      # the tail past the fork
        _pad_insert(primary, rng, 4000 + 100 * step)

    late = primary.clone()
    late.adopt_state(fork)                     # snapshot catch-up
    late._wal_applied = fork_seqno
    tail = list(iter_wal(ws.shard_path(0), after_seqno=fork_seqno))
    assert [r.seqno for r in tail] == [fork_seqno + 1, fork_seqno + 2]
    late.replay(tail, after_seqno=fork_seqno)

    patient.replay(list(iter_wal(ws.shard_path(0), after_seqno=-1)),
                   after_seqno=patient._wal_applied)
    assert late._wal_applied == patient._wal_applied == primary._wal_applied
    assert states_equal(late.index.state, primary.index.state)
    assert states_equal(late.index.state, patient.index.state)


def test_compact_wal_records_leaves_sharded_streams_untouched():
    from repro.storage.wal import WalRecord, compact_wal_records

    recs = [
        WalRecord("insert", {"vecs": np.zeros((2, 4), np.float32),
                             "valid": np.ones(2, bool)}, 0),
        WalRecord("delete", {"handles": np.asarray([3, 9])}, 1),
    ]
    out, dropped = compact_wal_records(recs)
    assert dropped == 0 and [r.seqno for r in out] == [0, 1]
