import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distance import (
    MASK_DISTANCE,
    masked_topk,
    pairwise_sql2,
    sql2,
    squared_norms,
)


@pytest.mark.parametrize("m,n,d", [(4, 7, 16), (1, 1, 8), (32, 64, 128)])
def test_pairwise_matches_naive(rng, m, n, d):
    q = rng.normal(size=(m, d)).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    got = np.asarray(pairwise_sql2(jnp.asarray(q), jnp.asarray(x)))
    want = ((q[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pairwise_uses_cached_norms(rng):
    q = rng.normal(size=(3, 8)).astype(np.float32)
    x = rng.normal(size=(5, 8)).astype(np.float32)
    sqn = squared_norms(jnp.asarray(x))
    a = pairwise_sql2(jnp.asarray(q), jnp.asarray(x))
    b = pairwise_sql2(jnp.asarray(q), jnp.asarray(x), sqn)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_sql2_broadcast(rng):
    a = rng.normal(size=(4, 8)).astype(np.float32)
    b = rng.normal(size=(4, 8)).astype(np.float32)
    got = np.asarray(sql2(jnp.asarray(a), jnp.asarray(b)))
    want = ((a - b) ** 2).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_masked_topk_excludes_invalid(rng):
    d = jnp.asarray([[3.0, 1.0, 2.0, 0.5]])
    valid = jnp.asarray([[True, True, True, False]])
    dist, idx = masked_topk(d, valid, 2)
    assert idx.tolist() == [[1, 2]]
    np.testing.assert_allclose(np.asarray(dist), [[1.0, 2.0]])


def test_masked_topk_fewer_than_k():
    d = jnp.asarray([[1.0, 2.0]])
    valid = jnp.asarray([[True, False]])
    dist, idx = masked_topk(d, valid, 2)
    assert float(dist[0, 1]) >= float(MASK_DISTANCE) / 2
