import jax.numpy as jnp
import numpy as np

from repro.storage import versionmap as vm


def test_bump_and_stale():
    versions = jnp.zeros(8, jnp.uint8)
    vids = jnp.asarray([1, 2])
    stored = jnp.zeros(2, jnp.uint8)
    assert not np.asarray(vm.is_stale(versions, vids, stored)).any()
    versions = vm.bump_version(versions, jnp.asarray([1]))
    stale = np.asarray(vm.is_stale(versions, vids, stored))
    assert stale.tolist() == [True, False]


def test_version_wraps_mod_128():
    versions = jnp.full(2, 127, jnp.uint8)  # index 1 = scratch
    versions = vm.bump_version(versions, jnp.asarray([0]))
    assert int(versions[0] & vm.VERSION_MASK) == 0
    assert int(versions[0] & vm.DELETED_BIT) == 0


def test_bump_preserves_delete_bit():
    versions = jnp.zeros(4, jnp.uint8)
    versions = vm.mark_deleted(versions, jnp.asarray([2]))
    versions = vm.bump_version(versions, jnp.asarray([2]))
    assert bool(vm.is_deleted(versions, jnp.asarray([2]))[0])


def test_deleted_is_stale():
    versions = jnp.zeros(4, jnp.uint8)  # index 3 = scratch; usable vids 0..2
    versions = vm.mark_deleted(versions, jnp.asarray([2]))
    stale = vm.is_stale(versions, jnp.asarray([2]), jnp.asarray([0], jnp.uint8))
    assert bool(stale[0])


def test_scratch_slot_protects_real_vids():
    """Disabled rows must not race with enabled writes to the same vid."""
    versions = jnp.zeros(4, jnp.uint8)
    vids = jnp.asarray([0, 0, 0, 0])
    enable = jnp.asarray([True, False, False, False])
    versions = vm.mark_deleted(versions, vids, enable)
    assert bool(vm.is_deleted(versions, jnp.asarray([0]))[0])


def test_negative_vid_is_stale():
    versions = jnp.zeros(4, jnp.uint8)
    stale = vm.is_stale(versions, jnp.asarray([-1]), jnp.asarray([0], jnp.uint8))
    assert bool(stale[0])


def test_enable_mask():
    versions = jnp.zeros(4, jnp.uint8)
    versions = vm.bump_version(
        versions, jnp.asarray([0, 1]), jnp.asarray([True, False])
    )
    assert int(versions[0] & vm.VERSION_MASK) == 1
    assert int(versions[1] & vm.VERSION_MASK) == 0
