"""End-to-end LIRE protocol behaviour (paper §3, §5.4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lire
from repro.core.index import SPFreshIndex, build_state
from repro.core.types import LireConfig
from tests.conftest import make_clustered


def small_cfg(**kw):
    args = dict(
        dim=16,
        block_size=8,
        max_blocks_per_posting=8,   # capacity 64
        num_blocks=2048,
        num_postings_cap=256,
        num_vectors_cap=8192,
        split_limit=48,
        merge_limit=6,
        reassign_range=8,
        reassign_budget=128,
        replica_count=2,
        nprobe=8,
    )
    args.update(kw)
    return LireConfig(**args)


def brute_force_knn(base, vids, queries, k):
    d = ((queries[:, None, :] - base[None, :, :]) ** 2).sum(-1)
    idx = np.argsort(d, axis=1)[:, :k]
    return vids[idx]


def recall_at_k(index, base, vids, queries, k=10, nprobe=None):
    gt = brute_force_knn(base, vids, queries, k)
    _, got = index.search(queries, k, nprobe=nprobe)
    hits = 0
    for row_gt, row_got in zip(gt, got):
        hits += len(set(row_gt.tolist()) & set(row_got.tolist()))
    return hits / (len(queries) * k)


@pytest.fixture
def built(rng):
    base = make_clustered(rng, 1500, 16, n_clusters=12)
    cfg = small_cfg()
    idx = SPFreshIndex.build(cfg, base)
    return idx, base


def test_build_recall(built, rng):
    idx, base = built
    queries = base[rng.integers(0, len(base), 32)] + 0.01 * rng.normal(
        size=(32, 16)
    ).astype(np.float32)
    r = recall_at_k(idx, base, np.arange(len(base)), queries, k=10)
    assert r > 0.9, f"build recall {r}"


def test_search_returns_sorted_unique(built, rng):
    idx, base = built
    queries = base[:8]
    d, v = idx.search(queries, 10)
    for row_d, row_v in zip(d, v):
        valid = row_v >= 0
        assert (np.diff(row_d[valid]) >= -1e-6).all()
        ids = row_v[valid].tolist()
        assert len(ids) == len(set(ids)), "duplicate vids in top-k (replicas)"


def test_insert_then_searchable(built, rng):
    idx, base = built
    new = make_clustered(rng, 50, 16, n_clusters=3)
    new_ids = np.arange(5000, 5050, dtype=np.int32)
    idx.insert(new, new_ids)
    _, got = idx.search(new, 5)
    found = sum(int(new_ids[i]) in got[i].tolist() for i in range(len(new)))
    assert found >= 45, f"only {found}/50 fresh vectors recalled"


def test_delete_removes_from_results(built, rng):
    idx, base = built
    victim = 7
    q = base[victim : victim + 1]
    _, got = idx.search(q, 5)
    assert victim in got[0].tolist()
    idx.delete(np.asarray([victim]))
    _, got = idx.search(q, 5)
    assert victim not in got[0].tolist()


def test_split_triggers_and_preserves_recall(rng):
    base = make_clustered(rng, 800, 16, n_clusters=6)
    cfg = small_cfg()
    idx = SPFreshIndex.build(cfg, base)
    # Hammer one region with inserts to force splits.
    center = base[0]
    extra = (center[None, :] + 0.02 * rng.normal(size=(300, 16))).astype(np.float32)
    ids = np.arange(3000, 3300, dtype=np.int32)
    idx.insert(extra, ids)
    idx.maintain()
    after = idx.stats()
    assert after["n_splits"] > 0, "no split happened"
    # Backpressure pipeline: every insert landed eventually.
    assert after["n_inserts"] >= 300
    lens = np.asarray(idx.state.pool.posting_len)
    valid = np.asarray(idx.state.centroid_valid)
    assert (lens[valid] <= cfg.posting_capacity).all()
    # After maintenance no posting stays oversized.
    assert (lens[valid] <= cfg.split_limit).all(), lens[valid].max()
    all_base = np.concatenate([base, extra])
    all_ids = np.concatenate([np.arange(len(base)), ids])
    queries = extra[:32]
    # 300 near-duplicate inserts into one region is adversarial: allow a
    # deeper probe for the recall check (ties dominate at k=10).
    r = recall_at_k(idx, all_base, all_ids, queries, k=10, nprobe=16)
    assert r > 0.85, f"post-split recall {r}"


def test_reassign_stats_sane(rng):
    base = make_clustered(rng, 800, 16, n_clusters=6)
    idx = SPFreshIndex.build(small_cfg(), base)
    extra = (base[0][None, :] + 0.02 * rng.normal(size=(300, 16))).astype(np.float32)
    idx.insert(extra, np.arange(3000, 3300, dtype=np.int32))
    idx.maintain()
    s = idx.stats()
    assert s["n_reassign_checked"] > 0
    assert s["n_reassign_candidates"] <= s["n_reassign_checked"]
    assert s["n_reassigned"] <= s["n_reassign_candidates"]
    # Paper: only a small fraction of evaluated vectors actually move.
    assert s["n_reassigned"] < 0.5 * max(s["n_reassign_checked"], 1)


def test_merge_triggers_after_mass_delete(rng):
    base = make_clustered(rng, 600, 16, n_clusters=5)
    cfg = small_cfg()
    idx = SPFreshIndex.build(cfg, base)
    # Delete 80% of one cluster's vectors to create undersized postings.
    # Find vectors near base[0].
    d = ((base - base[0]) ** 2).sum(-1)
    victims = np.argsort(d)[:200]
    idx.delete(victims.astype(np.int32))
    # Force GC first (splits clean postings), then merges of small postings.
    idx.maintain()
    s = idx.stats()
    assert s["n_deletes"] == 200
    # Deleted ids never come back.
    _, got = idx.search(base[victims[:16]], 5)
    got_set = set(got.reshape(-1).tolist())
    assert not (set(victims[:16].tolist()) & got_set)


def test_maintenance_converges(rng):
    """§3.4: the split/merge cascade terminates."""
    base = make_clustered(rng, 1000, 16, n_clusters=8)
    cfg = small_cfg()
    idx = SPFreshIndex.build(cfg, base)
    extra = make_clustered(rng, 400, 16, n_clusters=2)
    idx.insert(extra, np.arange(4000, 4400, dtype=np.int32))
    steps = idx.maintain()
    assert steps < 2 * cfg.num_postings_cap
    # quiescent: one more step does nothing
    _, did = lire.maintenance_step(idx.state)
    assert not bool(did)


def test_version_bump_invalidates_replicas(rng):
    base = make_clustered(rng, 400, 16, n_clusters=4)
    cfg = small_cfg(replica_count=3, replica_rng=1.5)
    idx = SPFreshIndex.build(cfg, base)
    # Replicas exist
    pool = idx.state.pool
    vids = np.asarray(pool.block_vid).reshape(-1)
    unique, counts = np.unique(vids[vids >= 0], return_counts=True)
    assert counts.max() >= 2, "expected closure replicas in the build"
    # Search never returns the same vid twice (stale/dup suppression).
    _, got = idx.search(base[:16], 10)
    for row in got:
        ids = row[row >= 0].tolist()
        assert len(ids) == len(set(ids))


def test_insert_into_empty_id_reuse(rng):
    base = make_clustered(rng, 300, 16)
    idx = SPFreshIndex.build(small_cfg(), base)
    idx.delete(np.asarray([5]))
    # Re-insert the same id with new data: becomes live again.
    newvec = rng.normal(size=(1, 16)).astype(np.float32)
    idx.insert(newvec, np.asarray([5], np.int32))
    _, got = idx.search(newvec, 3)
    assert 5 in got[0].tolist()
