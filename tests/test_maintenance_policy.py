"""Maintenance-policy ranking + per-posting telemetry unit/property tests.

Pins the PR's two contracts:

* ``policy="size"`` is BIT-IDENTICAL to the original top-K/bottom-K
  selection (regression pin vs an inline re-implementation), and a
  cold-start ``policy="drift"`` round (all-zero telemetry) produces
  bit-identical state leaves to the size round.
* The telemetry leaves obey conservation laws under split/merge/free
  (split halves carry the parent's access counts exactly; freed pids
  zero theirs) and the update counter tracks landed appends exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# check.sh runs this suite as its own explicit gate step; the tier-1
# step excludes it via the marker.
pytestmark = pytest.mark.gate

from repro.core import lire
from repro.core import types as T
from repro.core.index import SPFreshIndex, build_state
from repro.core.types import LireConfig


def small_cfg(**kw):
    args = dict(
        dim=16, block_size=8, max_blocks_per_posting=8, num_blocks=2048,
        num_postings_cap=256, num_vectors_cap=8192, split_limit=48,
        merge_limit=6, merge_fanout=4, reassign_range=8,
        reassign_budget=128, replica_count=2, nprobe=8, jobs_per_round=4,
    )
    args.update(kw)
    return LireConfig(**args)


def clustered(rng, n, dim=16, n_clusters=8):
    centers = rng.normal(size=(n_clusters, dim)) * 5
    return (
        centers[rng.integers(0, n_clusters, n)] + rng.normal(size=(n, dim))
    ).astype(np.float32)


def _churned_index(seed=3, policy="size", **cfg_kw):
    """A built index with enough hot-insert churn to create split and
    merge candidates."""
    rng = np.random.default_rng(seed)
    base = clustered(rng, 1000)
    idx = SPFreshIndex.build(small_cfg(maintain_policy=policy, **cfg_kw),
                             base)
    centroids = np.asarray(idx.state.centroids)[
        np.asarray(idx.state.centroid_valid)
    ]
    hot = np.concatenate([
        (c[None, :] + 0.05 * rng.normal(size=(40, 16))).astype(np.float32)
        for c in centroids[:4]
    ])
    idx.insert(hot, np.arange(4000, 4000 + len(hot), dtype=np.int32),
               max_retries=0)
    d = ((base - base[0]) ** 2).sum(-1)
    idx.delete(np.argsort(d)[:150].astype(np.int32))
    return idx


# ---------------------------------------------------------------------------
# policy="size" — regression pin against the original inline selection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 4, 8])
def test_size_policy_reproduces_original_topk_bottomk(k):
    idx = _churned_index()
    state = idx.state
    split_pids, split_en, merge_pids, merge_en = lire._select_jobs(state, k)

    # the ORIGINAL selection, verbatim
    cfg = state.cfg
    lens = state.pool.posting_len
    valid = state.centroid_valid
    split_scores = jnp.where(valid, lens, -1)
    top_l, want_sp = jax.lax.top_k(split_scores, k)
    want_se = top_l > cfg.split_limit
    merge_scores = jnp.where(
        valid & (lens < cfg.merge_limit), lens, jnp.iinfo(jnp.int32).max
    )
    neg_l, want_mp = jax.lax.top_k(-merge_scores, k)
    want_me = (-neg_l) < cfg.merge_limit

    np.testing.assert_array_equal(np.asarray(split_pids), np.asarray(want_sp))
    np.testing.assert_array_equal(np.asarray(split_en), np.asarray(want_se))
    np.testing.assert_array_equal(np.asarray(merge_pids), np.asarray(want_mp))
    np.testing.assert_array_equal(np.asarray(merge_en), np.asarray(want_me))
    assert bool(np.asarray(split_en).any()), "fixture produced no splits"
    assert bool(np.asarray(merge_en).any()), "fixture produced no merges"


def test_size_policy_ignores_telemetry():
    """Size selection must not read the telemetry leaves at all."""
    idx = _churned_index()
    state = idx.state
    tel = state.telemetry
    noisy = state.replace(telemetry=tel.replace(
        access_count=tel.access_count + 1000,
        update_count=tel.update_count + 7,
    ))
    for a, b in zip(lire._select_jobs(state, 4),
                    lire._select_jobs(noisy, 4)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# cold start: drift with all-zero telemetry == size, bit-exactly
# ---------------------------------------------------------------------------

def test_drift_cold_start_round_is_bit_identical_to_size():
    """With zero telemetry the drift formulas are monotone in length, so
    a whole maintenance_round produces bit-identical state leaves.

    The fixture builds OVERSIZED postings directly (build_posting_size >
    split_limit) and deletes a cluster for merge candidates — inserts
    would bump update/drift telemetry and leave cold-start territory."""
    rng = np.random.default_rng(9)
    base = clustered(rng, 1500)
    cfg_size = small_cfg(maintain_policy="size")
    state = build_state(cfg_size, base, build_posting_size=60)
    d = ((base - base[0]) ** 2).sum(-1)
    state = lire.delete_batch(
        state, jnp.asarray(np.argsort(d)[:256].astype(np.int32)),
        jnp.ones(256, bool),
    )
    assert int(np.asarray(state.telemetry.access_count).sum()) == 0
    assert int(np.asarray(state.telemetry.update_count).sum()) == 0

    cfg_drift = small_cfg(maintain_policy="drift", maintain_alpha=4.0,
                          maintain_beta=2.0)
    out_size, did_size = lire.maintenance_round(state, 4)
    out_drift, did_drift = lire.maintenance_round(
        state.replace(cfg=cfg_drift), 4
    )
    assert int(did_size) == int(did_drift) > 0
    for a, b in zip(jax.tree_util.tree_leaves(out_size),
                    jax.tree_util.tree_leaves(out_drift)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# drift ranking: access boost + drift term change the order
# ---------------------------------------------------------------------------

def _two_oversized(seed=13):
    """A state with ≥2 oversized postings; returns (state, long_pid,
    short_pid) where long > short in length, both split-eligible."""
    rng = np.random.default_rng(seed)
    base = clustered(rng, 1500)
    state = build_state(small_cfg(), base, build_posting_size=60)
    lens = np.asarray(state.pool.posting_len)
    valid = np.asarray(state.centroid_valid)
    eligible = np.flatnonzero(valid & (lens > state.cfg.split_limit))
    assert len(eligible) >= 2, "fixture needs 2+ oversized postings"
    order = eligible[np.argsort(-lens[eligible], kind="stable")]
    return state, int(order[0]), int(order[-1])


def test_drift_access_boost_beats_length_with_k1():
    state, long_pid, short_pid = _two_oversized()
    cfg = small_cfg(maintain_policy="drift", maintain_alpha=8.0,
                    maintain_beta=0.0)
    state = state.replace(cfg=cfg)

    # no access: drift degrades to size ordering -> the longest wins
    sp, se, _, _ = lire._select_jobs(state, 1)
    assert bool(np.asarray(se)[0])
    assert int(np.asarray(sp)[0]) == long_pid

    # all probes hit the SHORT oversized posting -> it outranks
    acc = np.zeros(cfg.num_postings_cap, np.int32)
    acc[short_pid] = 500
    hot = state.replace(telemetry=state.telemetry.replace(
        access_count=jnp.asarray(acc)
    ))
    sp, se, _, _ = lire._select_jobs(hot, 1)
    assert bool(np.asarray(se)[0])
    assert int(np.asarray(sp)[0]) == short_pid


def test_drift_term_prioritizes_drifted_posting():
    state, long_pid, short_pid = _two_oversized()
    cfg = small_cfg(maintain_policy="drift", maintain_alpha=0.0,
                    maintain_beta=50.0)
    state = state.replace(cfg=cfg)
    # the short posting's appends drifted far from its centroid
    drift = np.zeros((cfg.num_postings_cap, cfg.dim), np.float32)
    drift[short_pid] = 40.0
    upd = np.zeros(cfg.num_postings_cap, np.int32)
    upd[short_pid] = 4
    moved = state.replace(telemetry=state.telemetry.replace(
        drift_vec=jnp.asarray(drift), update_count=jnp.asarray(upd)
    ))
    sp, se, _, _ = lire._select_jobs(moved, 1)
    assert bool(np.asarray(se)[0])
    assert int(np.asarray(sp)[0]) == short_pid


def test_drift_merge_keeps_hot_runts_last():
    """Among mergeable runts of EQUAL length, accessed ones rank later
    (merged last) under the drift policy."""
    rng = np.random.default_rng(21)
    base = clustered(rng, 300)
    # build with tiny postings -> EVERY posting is a merge candidate
    state = build_state(small_cfg(), base, build_posting_size=3)
    lens = np.asarray(state.pool.posting_len)
    valid = np.asarray(state.centroid_valid)
    runts = np.flatnonzero(valid & (lens < state.cfg.merge_limit)
                           & (lens > 0))
    assert len(runts) >= 2, "fixture needs 2+ mergeable runts"
    # the size tie-break would merge the lowest-index runt first; heat it
    a = int(runts[0])
    acc = np.zeros(state.cfg.num_postings_cap, np.int32)
    acc[a] = 100
    cfg = small_cfg(maintain_policy="drift", maintain_alpha=8.0)
    hot = state.replace(cfg=cfg, telemetry=state.telemetry.replace(
        access_count=jnp.asarray(acc)
    ))
    _, _, mp, me = lire._select_jobs(hot, 1)
    assert bool(np.asarray(me)[0])
    assert int(np.asarray(mp)[0]) != a, "hot runt merged first"


# ---------------------------------------------------------------------------
# K edge cases
# ---------------------------------------------------------------------------

def test_jobs_per_round_zero_defers_to_cfg():
    """jobs_per_round=0 is falsy -> cfg.jobs_per_round, and huge K is
    clamped to num_postings_cap // 2 — both pin the `max(1, min(...))`
    behavior."""
    idx = _churned_index()
    s0, did0 = lire.maintenance_round(idx.state, 0)
    s_cfg, did_cfg = lire.maintenance_round(
        idx.state, idx.state.cfg.jobs_per_round
    )
    assert int(did0) == int(did_cfg)
    for a, b in zip(jax.tree_util.tree_leaves(s0),
                    jax.tree_util.tree_leaves(s_cfg)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # huge K clamps instead of erroring
    _, did_huge = lire.maintenance_round(
        idx.state, 10 * idx.state.cfg.num_postings_cap
    )
    assert int(did_huge) >= int(did_cfg)


def test_all_ties_pick_lowest_indices_under_both_policies():
    """All-equal lengths (and zero telemetry): both policies must pick
    the same lowest-index pids — top_k's documented tie-breaking."""
    rng = np.random.default_rng(17)
    base = clustered(rng, 1200)
    state = build_state(small_cfg(), base, build_posting_size=60)
    lens = np.asarray(state.pool.posting_len)
    valid = np.asarray(state.centroid_valid)
    tied = np.flatnonzero(valid & (lens == lens[valid].max()))
    k = min(3, len(tied))
    sel_size = lire._select_jobs(state, k)
    sel_drift = lire._select_jobs(
        state.replace(cfg=small_cfg(maintain_policy="drift")), k
    )
    for a, b in zip(sel_size, sel_drift):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# telemetry semantics: search histogram, conservation, zeroing
# ---------------------------------------------------------------------------

def test_search_probe_histogram_counts_and_qvalid_mask():
    idx = _churned_index()
    state = idx.state
    rng = np.random.default_rng(1)
    q = rng.normal(size=(8, 16)).astype(np.float32)
    d, v, hist = lire.search(state, jnp.asarray(q), k=10, nprobe=4,
                             with_access=True)
    hist = np.asarray(hist)
    assert hist.shape == (state.cfg.num_postings_cap,)
    assert hist.sum() == 8 * 4, "every (query, probe) counted once"
    assert (hist[~np.asarray(state.centroid_valid)] == 0).all()

    # qvalid masks padded rows out of the HISTOGRAM only
    qv = np.zeros(8, bool)
    qv[:3] = True
    d2, v2, hist2 = lire.search(state, jnp.asarray(q), k=10, nprobe=4,
                                with_access=True, qvalid=jnp.asarray(qv))
    assert np.asarray(hist2).sum() == 3 * 4
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v))

    # with_access=False returns the original 2-tuple, bit-identical
    d3, v3 = lire.search(state, jnp.asarray(q), k=10, nprobe=4)
    np.testing.assert_array_equal(np.asarray(d3), np.asarray(d))
    np.testing.assert_array_equal(np.asarray(v3), np.asarray(v))


def test_update_count_tracks_landed_appends_exactly():
    rng = np.random.default_rng(2)
    base = clustered(rng, 800)
    idx = SPFreshIndex.build(small_cfg(), base)
    s0 = idx.state
    appends0 = int(s0.stats.n_appends)
    assert int(np.asarray(s0.telemetry.update_count).sum()) == 0

    vecs = clustered(rng, 120)
    idx.insert(vecs, np.arange(4000, 4120, dtype=np.int32), max_retries=0)
    s1 = idx.state
    d_appends = int(s1.stats.n_appends) - appends0
    assert d_appends > 0
    assert int(np.asarray(s1.telemetry.update_count).sum()) == d_appends


def test_split_conserves_access_and_freed_pids_zero():
    """Run drift-policy rounds with folded access over a churned state:
    split halves carry the parent's counts exactly (total conserved when
    merges are disabled), and invalid pids hold zero telemetry."""
    idx = _churned_index(policy="drift", enable_merge=False)
    state = idx.state
    cap = state.cfg.num_postings_cap
    rng = np.random.default_rng(4)
    access = rng.integers(0, 50, size=cap).astype(np.int32)
    access[~np.asarray(state.centroid_valid)] = 0
    total = int(np.asarray(state.telemetry.access_count).sum()
                + access.sum())
    out, did = lire.maintenance_round(state, 4, jnp.asarray(access))
    assert int(did) > 0
    out_acc = np.asarray(out.telemetry.access_count)
    valid = np.asarray(out.centroid_valid)
    assert int(out_acc.sum()) == total, "split did not conserve access"
    assert (out_acc[~valid] == 0).all()
    assert (np.asarray(out.telemetry.update_count)[~valid] == 0).all()
    assert (np.asarray(out.telemetry.drift_vec)[~valid] == 0).all()


def test_merge_moves_access_to_target_and_zeroes_source():
    idx = _churned_index(policy="drift")
    state = idx.state
    lens = np.asarray(state.pool.posting_len)
    valid = np.asarray(state.centroid_valid)
    runts = np.flatnonzero(valid & (lens < state.cfg.merge_limit)
                           & (lens > 0))
    assert len(runts) >= 1
    cap = state.cfg.num_postings_cap
    access = np.zeros(cap, np.int32)
    access[runts[0]] = 77
    before = int(np.asarray(state.telemetry.access_count).sum()) + 77
    out, did = lire.maintenance_round(state, 4, jnp.asarray(access))
    assert int(did) > 0
    out_acc = np.asarray(out.telemetry.access_count)
    out_valid = np.asarray(out.centroid_valid)
    if not out_valid[runts[0]]:
        # the runt merged away: its counts moved to the absorb target
        # (total conserved up to split-free/retire bookkeeping)
        assert out_acc[runts[0]] == 0
    assert (out_acc[~out_valid] == 0).all()
    assert int(out_acc.sum()) <= before


def test_telemetry_conservation_property():
    """Hypothesis: random churn + drift rounds — invalid pids always hold
    zero telemetry and valid access never exceeds what was folded in."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg = small_cfg(
        dim=8, num_postings_cap=128, num_blocks=1024, num_vectors_cap=2048,
        split_limit=24, merge_limit=4, reassign_range=4, reassign_budget=64,
        maintain_policy="drift", maintain_alpha=2.0,
    )

    @settings(max_examples=4, deadline=None)
    @given(data=st.data())
    def inner(data):
        rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
        base = rng.normal(size=(300, 8)).astype(np.float32)
        idx = SPFreshIndex.build(cfg, base)
        next_vid = 300
        folded = 0
        for _ in range(data.draw(st.integers(1, 3))):
            k = data.draw(st.integers(1, 40))
            if data.draw(st.booleans()):
                c = base[data.draw(st.integers(0, 299))]
                vecs = (c[None] + 0.05 * rng.normal(size=(k, 8))
                        ).astype(np.float32)
            else:
                vecs = rng.normal(size=(k, 8)).astype(np.float32)
            idx.insert(vecs, np.arange(next_vid, next_vid + k,
                                       dtype=np.int32), max_retries=0)
            next_vid += k
            access = rng.integers(0, 20, size=cfg.num_postings_cap
                                  ).astype(np.int32)
            access[~np.asarray(idx.state.centroid_valid)] = 0
            folded += int(access.sum())
            idx.maintain_round(data.draw(st.sampled_from([1, 4])),
                               access=access)
            s = idx.state
            valid = np.asarray(s.centroid_valid)
            acc = np.asarray(s.telemetry.access_count)
            upd = np.asarray(s.telemetry.update_count)
            dv = np.asarray(s.telemetry.drift_vec)
            assert (acc >= 0).all()
            assert (acc[~valid] == 0).all()
            assert (upd[~valid] == 0).all()
            assert (dv[~valid] == 0).all()
            assert int(acc.sum()) <= folded, "access appeared from nowhere"

    inner()


def test_spec_threads_policy_into_config():
    import spfresh

    spec = spfresh.ServiceSpec(
        index=spfresh.IndexSpec(config=small_cfg()),
        maintenance=spfresh.MaintenanceSpec(
            policy="drift", alpha=2.5, beta=0.5
        ),
    )
    cfg = spec.lire_config()
    assert cfg.maintain_policy == "drift"
    assert cfg.maintain_alpha == 2.5
    assert cfg.maintain_beta == 0.5
    # None defers to IndexSpec.config
    spec2 = spfresh.ServiceSpec(index=spfresh.IndexSpec(config=small_cfg()))
    assert spec2.lire_config() == small_cfg()
    with pytest.raises(AssertionError):
        spfresh.ServiceSpec(
            index=spfresh.IndexSpec(config=small_cfg()),
            maintenance=spfresh.MaintenanceSpec(policy="sizzle"),
        ).validate()
