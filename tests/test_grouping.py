"""Two-level centroid routing: exactness at full gprobe, recall at small
gprobe, graceful staleness after splits."""
import jax.numpy as jnp
import numpy as np

from repro.core import lire
from repro.core.grouping import build_group_index, navigate_grouped, search_grouped
from repro.core.index import SPFreshIndex
from tests.conftest import make_clustered
from tests.test_lire import brute_force_knn, small_cfg


def test_grouped_exact_when_probing_all_groups(rng):
    base = make_clustered(rng, 1200, 16, n_clusters=10)
    idx = SPFreshIndex.build(small_cfg(), base)
    gidx = build_group_index(idx.state, n_groups=8, capacity=64)
    q = jnp.asarray(base[:16])
    d0, p0 = lire.navigate(idx.state, q, 8)
    d1, p1 = navigate_grouped(idx.state, gidx, q, nprobe=8, gprobe=8)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-4,
                               atol=1e-4)
    # pids may differ on exact distance ties; check distances only + overlap
    overlap = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / 8
        for a, b in zip(np.asarray(p0), np.asarray(p1))
    ])
    assert overlap > 0.9


def test_grouped_search_recall_small_gprobe(rng):
    base = make_clustered(rng, 1500, 16, n_clusters=12)
    idx = SPFreshIndex.build(small_cfg(), base)
    gidx = build_group_index(idx.state, n_groups=16, capacity=32)
    queries = base[rng.integers(0, len(base), 32)] + 0.01 * rng.normal(
        size=(32, 16)
    ).astype(np.float32)
    gt = brute_force_knn(base, np.arange(len(base)), queries, 10)
    _, got = search_grouped(
        idx.state, gidx, jnp.asarray(queries), k=10, nprobe=8, gprobe=6
    )
    got = np.asarray(got)
    hits = sum(
        len(set(a.tolist()) & set(b.tolist())) for a, b in zip(gt, got)
    )
    recall = hits / 320
    assert recall > 0.85, f"grouped recall {recall}"


def test_grouped_staleness_degrades_gracefully(rng):
    """Splits between group refreshes leave new centroids unrouted —
    recall dips but queries keep working; a refresh restores it."""
    base = make_clustered(rng, 1000, 16, n_clusters=8)
    idx = SPFreshIndex.build(small_cfg(), base)
    gidx = build_group_index(idx.state, n_groups=16, capacity=32)
    extra = (base[0][None, :] + 0.02 * rng.normal(size=(200, 16))).astype(np.float32)
    ids = np.arange(5000, 5200, dtype=np.int32)
    idx.insert(extra, ids)
    idx.maintain()
    q = jnp.asarray(extra[:16])
    _, got_stale = search_grouped(idx.state, gidx, q, k=5, nprobe=8, gprobe=6)
    # no crash; results well-formed
    assert np.asarray(got_stale).shape == (16, 5)
    # refresh restores fresh-vector recall
    gidx2 = build_group_index(idx.state, n_groups=16, capacity=64)
    _, got = search_grouped(idx.state, gidx2, q, k=5, nprobe=8, gprobe=6)
    got = np.asarray(got)
    found = sum(int(ids[i]) in got[i].tolist() for i in range(16))
    assert found >= 14, f"{found}/16 after refresh"
