"""l2_topk Pallas kernel vs pure-jnp oracle (interpret mode, shape/dtype sweep)."""
import jax.numpy as jnp
import numpy as np
import pytest

# check.sh runs this suite as its own explicit gate step; the tier-1
# step excludes it via the marker (no hand-maintained --ignore list).
pytestmark = pytest.mark.gate

from repro.kernels.l2_topk.ops import l2_topk
from repro.kernels.l2_topk.ref import l2_topk_ref


def _check(rng, q_n, p_n, d, k, dtype, n_invalid=0):
    q = rng.normal(size=(q_n, d)).astype(np.float32)
    c = rng.normal(size=(p_n, d)).astype(np.float32)
    valid = np.ones(p_n, bool)
    if n_invalid:
        valid[rng.choice(p_n, size=n_invalid, replace=False)] = False
    qj = jnp.asarray(q, dtype)
    cj = jnp.asarray(c, dtype)
    got_d, got_i = l2_topk(
        qj, cj, jnp.asarray(valid), k=k, block_q=8, block_p=128, interpret=True
    )
    want_d, want_i = l2_topk_ref(qj, cj, jnp.asarray(valid), k=k)
    got_d, got_i = np.asarray(got_d), np.asarray(got_i)
    want_d, want_i = np.asarray(want_d), np.asarray(want_i)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    # Distances must match (sorted ascending) — tie-tolerant on indices.
    np.testing.assert_allclose(got_d, np.maximum(want_d, 0), rtol=tol, atol=tol)
    n_valid = valid.sum()
    for r in range(q_n):
        kk = min(k, n_valid)
        assert (np.asarray(got_i[r][:kk]) >= 0).all()
        # indices agree as sets up to distance ties
        gs, ws = set(got_i[r][:kk].tolist()), set(want_i[r][:kk].tolist())
        if gs != ws:
            diff = gs.symmetric_difference(ws)
            dd = np.sort(
                ((q[r] - c[list(diff)]) ** 2).sum(-1)
            )
            assert np.allclose(dd, dd[0], rtol=tol, atol=tol), (
                f"row {r}: index mismatch not explained by ties"
            )


@pytest.mark.parametrize("q_n,p_n,d,k", [
    (4, 128, 16, 4),
    (8, 256, 32, 8),
    (16, 512, 128, 16),
    (3, 300, 100, 8),     # unaligned shapes exercise padding
    (1, 128, 64, 1),
])
def test_l2_topk_f32(rng, q_n, p_n, d, k):
    _check(rng, q_n, p_n, d, k, jnp.float32)


@pytest.mark.parametrize("q_n,p_n,d,k", [(8, 256, 64, 8)])
def test_l2_topk_bf16(rng, q_n, p_n, d, k):
    _check(rng, q_n, p_n, d, k, jnp.bfloat16)


def test_l2_topk_invalid_centroids(rng):
    _check(rng, 4, 128, 16, 8, jnp.float32, n_invalid=100)


def test_l2_topk_fewer_valid_than_k(rng):
    q = rng.normal(size=(2, 8)).astype(np.float32)
    c = rng.normal(size=(128, 8)).astype(np.float32)
    valid = np.zeros(128, bool)
    valid[:3] = True
    d, i = l2_topk(
        jnp.asarray(q), jnp.asarray(c), jnp.asarray(valid), k=8,
        block_q=8, block_p=128, interpret=True,
    )
    i = np.asarray(i)
    assert (i[:, 3:] == -1).all()
    assert set(i[:, :3].reshape(-1).tolist()).issubset({0, 1, 2})
