"""spflint gate: the static passes themselves.

Three layers, mirroring how the tool is trusted in CI:

1. **Seeded violations** — `tests/fixtures/spflint/badpkg/` plants one
   violation per rule ID, each marked in-line with ``# expect: SPF...``;
   the passes must report EXACTLY that (file, line, rule) multiset.
2. **Clean-tree gate** — `python -m repro.analysis src` semantics: the
   shipped tree has zero findings, the baseline stays empty, and the
   VMEM pass covers 100% of the ``pl.pallas_call`` sites in
   ``src/repro/kernels/``.
3. **Parity** — the analyzer's static VMEM estimate for one real
   ``posting_scan`` configuration must equal the bytes computed from
   actual operand arrays at the reference shape (and the kernel must
   actually run at those shapes).

Plus the runtime half of the lock discipline: ``install_lock_check``
must reject exactly the writes the ownership map forbids.
"""
import ast
import json
import re
import threading
from pathlib import Path

import numpy as np
import pytest

pytestmark = pytest.mark.gate

from repro.analysis import run_all
from repro.analysis.__main__ import main as spflint_main
from repro.analysis.common import (
    RULES,
    load_baseline,
    parse_tree,
    split_by_baseline,
    write_baseline,
)
from repro.analysis.config import (
    VMEM_BINDINGS,
    AnalysisSpec,
    LockSpec,
    ReplaySpec,
    VmemSpec,
)
from repro.serve.ownership import (
    GUARDED,
    INIT,
    LIFECYCLE,
    PUMP,
    CheckedRLock,
    LockDisciplineError,
    install_lock_check,
)

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "spflint"

# The fixture twin of config.DEFAULT_SPEC: same passes, aimed at badpkg.
FIXTURE_SPEC = AnalysisSpec(
    replay=ReplaySpec(
        roots=("badpkg.steps:build_step",),
        config_class="badpkg.types:Cfg",
        critical_stamp="badpkg.stamps:REPLAY_CRITICAL_FIELDS",
        exempt_stamp="badpkg.stamps:REPLAY_EXEMPT_FIELDS",
    ),
    locks=LockSpec(module_prefixes=("badpkg.serve",)),
    vmem=VmemSpec(
        module_prefixes=("badpkg.kern",),
        budget_bytes=16 * 1024 * 1024,
        bindings={"dim": 128},
        dtype_overrides={},
    ),
)

_MARKER = re.compile(r"#\s*expect:\s*([A-Z0-9 ]+)$")


def _expected_markers() -> list[tuple[str, int, str]]:
    """(rel-file, line, rule) for every ``# expect:`` marker token."""
    out = []
    for path in sorted(FIXTURES.rglob("*.py")):
        rel = path.relative_to(FIXTURES.parent).as_posix()
        for lineno, text in enumerate(path.read_text().splitlines(), 1):
            m = _MARKER.search(text)
            if m:
                out.extend((rel, lineno, r) for r in m.group(1).split())
    return out


# ---------------------------------------------------------------------------
# 1. Seeded violations: exact (file, line, rule) agreement
# ---------------------------------------------------------------------------

def test_seeded_fixtures_report_exact_findings():
    result = run_all(FIXTURES, spec=FIXTURE_SPEC)
    got = sorted((f.file, f.line, f.rule) for f in result["findings"])
    want = sorted(_expected_markers())
    assert got == want, (
        "spflint findings diverge from the seeded # expect markers:\n"
        f"  missing: {sorted(set(want) - set(got))}\n"
        f"  extra:   {sorted(set(got) - set(want))}"
    )
    # every rule in the registry is exercised by at least one seed
    assert {r for _, _, r in want} == set(RULES)


def test_fixture_baseline_roundtrip(tmp_path):
    findings = run_all(FIXTURES, spec=FIXTURE_SPEC)["findings"]
    assert findings
    path = tmp_path / "baseline.json"
    write_baseline(path, findings)
    new, suppressed = split_by_baseline(findings, load_baseline(path))
    assert new == [] and len(suppressed) == len(findings)
    # keys are (rule, file, symbol) — line-stable on edits above the site
    entry = json.loads(path.read_text())["suppressions"][0]
    assert set(entry) == {"rule", "file", "symbol", "reason"}


# ---------------------------------------------------------------------------
# 2. Clean-tree gate + 100% pallas_call coverage
# ---------------------------------------------------------------------------

def _count_pallas_sites() -> int:
    n = 0
    for path in sorted((SRC / "repro" / "kernels").rglob("*.py")):
        for node in ast.walk(ast.parse(path.read_text())):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "pallas_call"
            ):
                n += 1
    return n


def test_shipped_tree_is_clean():
    result = run_all(SRC)
    assert [f.render() for f in result["findings"]] == []


def test_shipped_baseline_is_empty():
    assert load_baseline(REPO / "tools" / "spflint_baseline.json") == set()


def test_vmem_pass_covers_every_pallas_call_site():
    result = run_all(SRC)
    n_sites = _count_pallas_sites()
    assert n_sites >= 7
    assert len(result["vmem_table"]) == n_sites
    budget = result["vmem_budget_mib"] * 1024 * 1024
    for row in result["vmem_table"]:
        assert row["vmem_bytes"] <= budget, row


def test_cli_exit_codes(tmp_path, capsys):
    report = tmp_path / "report.json"
    rc = spflint_main([
        str(SRC),
        "--baseline", str(REPO / "tools" / "spflint_baseline.json"),
        "--json", str(report),
    ])
    assert rc == 0
    data = json.loads(report.read_text())
    assert data["summary"]["new"] == 0
    assert data["summary"]["kernels_analyzed"] == _count_pallas_sites()
    assert data["rules"] == RULES

    assert spflint_main([str(tmp_path / "no_such_tree")]) == 2

    assert spflint_main(["--rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule in out


# ---------------------------------------------------------------------------
# 3. VMEM estimate vs actual shapes: one real posting_scan configuration
# ---------------------------------------------------------------------------

def test_vmem_estimate_matches_actual_scan_batched_topk_shapes():
    """The static estimate for ``scan_batched_topk`` must equal the bytes
    of the real operand blocks at the reference serving shape — and the
    kernel must actually accept operands of those shapes."""
    import jax.numpy as jnp

    from repro.kernels.posting_scan.kernel import scan_batched_topk

    result = run_all(SRC)
    (row,) = [
        r for r in result["vmem_table"]
        if r["kernel"] == "scan_batched_topk"
    ]

    b = VMEM_BINDINGS
    q_n, dim, bs, k = b["q_n"], b["dim"], b["bs"], b["k"]

    # the real per-grid-step blocks, from the wrapper's BlockSpecs
    rng = np.random.default_rng(0)
    queries = rng.standard_normal((q_n, dim)).astype(np.float32)
    blocks = rng.standard_normal((8, bs, dim)).astype(np.float32)
    slot_bias = np.zeros((8, bs), np.float32)
    expect = [
        ("in", (q_n, dim), queries.itemsize),         # resident queries
        ("in", (1, bs, dim), blocks.itemsize),        # one streamed page
        ("in", (1, bs), slot_bias.itemsize),          # liveness bias row
        ("out", (1, q_n, k), np.dtype(np.float32).itemsize),
        ("out", (1, q_n, k), np.dtype(np.int32).itemsize),
    ]
    got = [(o["role"], tuple(o["shape"])) for o in row["operands"]]
    assert got == [(r, s) for r, s, _ in expect]
    manual = 2 * sum(int(np.prod(s)) * isz for _, s, isz in expect)
    assert row["vmem_bytes"] == manual
    assert tuple(row["grid"]) == (b["nb"],)

    # the wrapper really runs at these shapes (nb shrunk to keep the
    # interpret-mode run cheap; per-block shapes are nb-independent)
    kd, ki = scan_batched_topk(
        jnp.arange(8, dtype=jnp.int32), jnp.asarray(queries),
        jnp.asarray(blocks), jnp.asarray(slot_bias),
        k=k, interpret=True,
    )
    assert kd.shape == (8, q_n, k) and ki.shape == (8, q_n, k)
    assert bool(jnp.isfinite(kd).all()) and int(ki.max()) < bs


# ---------------------------------------------------------------------------
# Runtime lock checker (the dynamic half of the SPF20x discipline)
# ---------------------------------------------------------------------------

class _Dummy:
    FIELD_OWNERSHIP = {
        "_work": INIT,
        "cfg": INIT,
        "_inflight": GUARDED,
        "_busy": PUMP,
        "_pump_thread": LIFECYCLE,
    }

    def __init__(self):
        self._work = threading.RLock()
        self.cfg = 1
        self._inflight = 0
        self._busy = False
        self._pump_thread = None


def test_runtime_lock_check_enforces_ownership():
    d = _Dummy()
    install_lock_check(d)
    assert isinstance(d._work, CheckedRLock)

    with pytest.raises(LockDisciplineError, match="guarded"):
        d._inflight = 1
    with d._work:
        d._inflight = 2                   # guarded write under the lock
    assert d._inflight == 2

    with pytest.raises(LockDisciplineError, match="init-only"):
        d.cfg = 99

    d._busy = True                        # no live pump thread: allowed
    d._pump_thread = None                 # not on the pump thread: allowed

    # pump-only field from a foreign thread while the pump is "alive"
    # (main thread plays the pump: it is certainly alive)
    object.__setattr__(d, "_pump_thread", threading.current_thread())
    try:
        err = []

        def foreign():
            try:
                d._busy = False
            except LockDisciplineError as e:
                err.append(e)

        ft = threading.Thread(target=foreign)
        ft.start()
        ft.join()
        assert err and "pump-thread-only" in str(err[0])
        d._busy = False                   # ...but the "pump" thread may
    finally:
        object.__setattr__(d, "_pump_thread", None)

    # escape hatch tests rely on: bypasses the checker entirely
    object.__setattr__(d, "cfg", 7)
    assert d.cfg == 7

    install_lock_check(d)                 # idempotent
    assert type(d).__name__ == "_DummyLockChecked"


def test_checked_rlock_tracks_owner():
    lk = CheckedRLock()
    assert not lk.held_by_me
    with lk:
        assert lk.held_by_me
        with lk:                          # re-entrant
            assert lk.held_by_me
        assert lk.held_by_me
    assert not lk.held_by_me


def test_fixture_tree_parses_under_expected_names():
    mods = parse_tree(FIXTURES)
    assert {
        "badpkg", "badpkg.types", "badpkg.stamps", "badpkg.steps",
        "badpkg.serve_bad", "badpkg.kern_bad",
    } <= set(mods)


def test_pytest_never_collects_the_fixture_tree():
    """pytest.ini pins ``norecursedirs = tests/fixtures/spflint``: the
    seeded-violation package is broken ON PURPOSE, so pytest must never
    recurse into it — a ``test_*.py`` landing there would otherwise be
    imported at collection time and take the whole suite down.  Run a
    real collection pass over tests/ and assert the pin holds."""
    import subprocess
    import sys

    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q",
         "--co", "-p", "no:cacheprovider", "tests/fixtures"],
        capture_output=True, text=True, cwd=REPO, timeout=120,
    )
    # exit code 5 = "no tests collected" — exactly what the pin demands
    assert proc.returncode == 5, proc.stdout + proc.stderr
    assert "spflint" not in proc.stdout
