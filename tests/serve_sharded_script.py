"""Engine round-trip over a 2-shard stacked state — run as a subprocess
with 2 fake CPU devices (spawned by tests/test_serve_pipeline.py so the
main pytest process keeps exactly one device).

Exercises the tentpole claim: the SAME ServeEngine drives a sharded
backend (shard_map steps from distributed/sharded_index.py) through the
same micro-batched padded pipeline as the single-host index.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=2 "
    + os.environ.get("XLA_FLAGS", "")
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.types import LireConfig
from repro.distributed.sharded_index import ShardedIndex
from repro.serve import BacklogPolicy, EngineConfig, ServeEngine

assert len(jax.devices()) == 2, jax.devices()

MESH = jax.make_mesh((2,), ("model",))
CFG = LireConfig(
    dim=16, block_size=8, max_blocks_per_posting=8, num_blocks=1024,
    num_postings_cap=128, num_vectors_cap=4096, split_limit=48,
    merge_limit=6, reassign_range=8, reassign_budget=128, replica_count=2,
    nprobe=8,
)


def make_clustered(rng, n, d, n_clusters=8, spread=0.05):
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    assign = rng.integers(0, n_clusters, size=n)
    return (centers[assign] + spread * rng.normal(size=(n, d))).astype(np.float32)


rng = np.random.default_rng(0)
base = make_clustered(rng, 1200, 16, n_clusters=10)

sidx, handles = ShardedIndex.build(MESH, CFG, base, 2)
engine = ServeEngine(
    sidx, EngineConfig(search_k=10, max_batch=64, min_bucket=16),
)
assert engine.index is None  # sharded backend: no single-host index

# ---- batched search through the pipeline, vs brute force ----
queries = base[rng.integers(0, len(base), 48)] + 0.01 * rng.normal(
    size=(48, 16)
).astype(np.float32)
t_search = engine.submit_search(queries)      # 48 rows -> one padded bucket
d, v = t_search.result()
assert d.shape == (48, 10) and v.shape == (48, 10)
bf = ((queries[:, None, :] - base[None]) ** 2).sum(-1)
gt = handles[np.argsort(bf, axis=1)[:, :10]]
hits = sum(len(set(gt[i].tolist()) & set(v[i].tolist())) for i in range(48))
recall = hits / (48 * 10)
assert recall > 0.85, f"sharded engine recall {recall}"
print(f"PASS sharded_engine_search recall={recall:.3f}")

# ---- insert through the pipeline: handles come back, rows searchable ----
new = make_clustered(rng, 40, 16, n_clusters=3)
t_ins = engine.submit_insert(new, np.full(40, -1, np.int32))
new_handles, landed = t_ins.result()
assert landed.all(), f"unlanded sharded inserts: {(~landed).sum()}"
assert (new_handles >= 0).all()
owners = np.unique(new_handles // CFG.num_vectors_cap)
d2, v2 = engine.search(new)
found = sum(int(new_handles[i]) in v2[i].tolist() for i in range(40))
assert found >= 36, f"only {found}/40 pipeline inserts recalled"
print(f"PASS sharded_engine_insert found={found}/40 owners={owners.tolist()}")

# ---- delete through the pipeline ----
engine.delete(new_handles[:20])
_, v3 = engine.search(new[:20])
still = sum(int(new_handles[i]) in v3[i].tolist() for i in range(20))
assert still == 0, f"{still} deleted handles still returned"
print("PASS sharded_engine_delete")

# ---- maintenance slots fire on the sharded backend too ----
engine.drain()
rep = engine.report()
assert rep["queue"]["depth_rows_now"] == 0
assert rep["queue"]["rows"] >= 48 + 40 + 20 + 40
assert rep["backlog"] == 0
st = engine.stats()
assert st["n_shards"] == 2 and st["n_inserts"] >= 40
print(f"PASS sharded_engine_report waste={rep['queue']['padding_waste_frac']:.3f} "
      f"stats_inserts={st['n_inserts']}")

# ---- BacklogPolicy on the sharded backend ----
eng2 = ServeEngine(
    sidx, EngineConfig(search_k=10, max_batch=64),
    policy=BacklogPolicy(threshold=1, budget=8),
)
more = make_clustered(rng, 120, 16, n_clusters=2)
for s in range(0, 120, 40):
    eng2.insert(more[s:s + 40], np.full(40, -1, np.int32))
eng2.drain()
assert eng2.backend.backlog() == 0
print("PASS sharded_engine_backlog_policy")

print("ALL_SERVE_SHARDED_PASS")
