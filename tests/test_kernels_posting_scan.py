"""posting_scan Pallas kernels vs pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.posting_scan.kernel import scan_batched, scan_per_query
from repro.kernels.posting_scan.ops import BIG, scan_posting_blocks, scan_unique_blocks
from repro.kernels.posting_scan.ref import (
    scan_posting_blocks_ref,
    scan_unique_blocks_ref,
)


@pytest.mark.parametrize("q_n,n_blocks,bs,d,nb,dtype", [
    (4, 32, 8, 16, 6, jnp.float32),
    (8, 64, 16, 128, 4, jnp.float32),
    (2, 16, 8, 32, 3, jnp.bfloat16),
    (1, 8, 4, 8, 1, jnp.float32),
])
def test_scan_per_query_matches_ref(rng, q_n, n_blocks, bs, d, nb, dtype):
    blocks = jnp.asarray(rng.normal(size=(n_blocks, bs, d)), dtype)
    queries = jnp.asarray(rng.normal(size=(q_n, d)), dtype)
    table = jnp.asarray(rng.integers(0, n_blocks, size=(q_n, nb)), jnp.int32)
    got = scan_per_query(table, queries, blocks, interpret=True)
    want = scan_posting_blocks_ref(table, queries, blocks)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("q_n,n_blocks,bs,d,nb,dtype", [
    (4, 32, 8, 16, 6, jnp.float32),
    (8, 64, 16, 128, 12, jnp.float32),
    (2, 16, 8, 32, 3, jnp.bfloat16),
])
def test_scan_batched_matches_ref(rng, q_n, n_blocks, bs, d, nb, dtype):
    blocks = jnp.asarray(rng.normal(size=(n_blocks, bs, d)), dtype)
    queries = jnp.asarray(rng.normal(size=(q_n, d)), dtype)
    ids = jnp.asarray(
        rng.choice(n_blocks, size=nb, replace=False), jnp.int32
    )
    got = scan_batched(ids, queries, blocks, interpret=True)
    want = scan_unique_blocks_ref(ids, queries, blocks)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_scan_posting_blocks_masks_absent_pages(rng):
    n_blocks, bs, d = 16, 4, 8
    blocks = jnp.asarray(rng.normal(size=(n_blocks, bs, d)), jnp.float32)
    queries = jnp.asarray(rng.normal(size=(2, d)), jnp.float32)
    posting_blocks = jnp.asarray(
        [[0, 1, -1, -1], [2, -1, -1, -1], [3, 4, 5, -1]], jnp.int32
    )
    pids = jnp.asarray([[0, 2], [1, -1]], jnp.int32)
    dists, page_ok = scan_posting_blocks(
        queries, posting_blocks, pids, blocks, interpret=True
    )
    dists = np.asarray(dists).reshape(2, 2, 4, bs)
    # query 0, posting 0 has pages {0,1}; pages 2,3 masked
    assert (dists[0, 0, 2:] >= BIG / 2).all()
    assert (dists[0, 0, :2] < BIG / 2).all()
    # query 1 probed only posting 1 (page 2); second probe fully masked
    assert (dists[1, 1] >= BIG / 2).all()
    ok = np.asarray(page_ok).reshape(2, 2, 4, bs)
    assert ok[0, 0, :2].all() and not ok[0, 0, 2:].any()


def test_scan_unique_blocks_padding(rng):
    blocks = jnp.asarray(rng.normal(size=(8, 4, 8)), jnp.float32)
    queries = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    ids = jnp.asarray([2, 5, -1, -1], jnp.int32)
    d = np.asarray(scan_unique_blocks(queries, ids, blocks, interpret=True))
    assert (d[2:] >= BIG / 2).all()
    want = np.asarray(scan_unique_blocks_ref(ids[:2], queries, blocks))
    np.testing.assert_allclose(d[:2], want, rtol=1e-4)


def test_scan_consistency_between_variants(rng):
    """Both schedules must produce identical distances for shared pages."""
    n_blocks, bs, d, q_n = 32, 8, 16, 4
    blocks = jnp.asarray(rng.normal(size=(n_blocks, bs, d)), jnp.float32)
    queries = jnp.asarray(rng.normal(size=(q_n, d)), jnp.float32)
    table = jnp.asarray(rng.integers(0, n_blocks, size=(q_n, 5)), jnp.int32)
    per_q = np.asarray(scan_per_query(table, queries, blocks, interpret=True))
    uniq = jnp.asarray(np.unique(np.asarray(table)), jnp.int32)
    batched = np.asarray(scan_batched(uniq, queries, blocks, interpret=True))
    uniq_np = np.asarray(uniq)
    for q in range(q_n):
        for j, b in enumerate(np.asarray(table)[q]):
            bi = int(np.where(uniq_np == b)[0][0])
            np.testing.assert_allclose(
                per_q[q, j], batched[bi, q], rtol=1e-5, atol=1e-5
            )
