"""posting_scan Pallas kernels vs pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

# check.sh runs this suite as its own explicit gate step; the tier-1
# step excludes it via the marker (no hand-maintained --ignore list).
pytestmark = pytest.mark.gate

from repro.kernels.posting_scan.kernel import (
    scan_batched,
    scan_batched_topk,
    scan_per_query,
    scan_per_query_topk,
)
from repro.kernels.posting_scan.ops import (
    BIG,
    dedup_pages,
    scan_posting_blocks,
    scan_posting_blocks_topk,
    scan_unique_blocks,
    scan_unique_blocks_topk,
)
from repro.kernels.posting_scan.ref import (
    scan_batched_topk_ref,
    scan_per_query_topk_ref,
    scan_posting_blocks_ref,
    scan_unique_blocks_ref,
)


@pytest.mark.parametrize("q_n,n_blocks,bs,d,nb,dtype", [
    (4, 32, 8, 16, 6, jnp.float32),
    (8, 64, 16, 128, 4, jnp.float32),
    (2, 16, 8, 32, 3, jnp.bfloat16),
    (1, 8, 4, 8, 1, jnp.float32),
])
def test_scan_per_query_matches_ref(rng, q_n, n_blocks, bs, d, nb, dtype):
    blocks = jnp.asarray(rng.normal(size=(n_blocks, bs, d)), dtype)
    queries = jnp.asarray(rng.normal(size=(q_n, d)), dtype)
    table = jnp.asarray(rng.integers(0, n_blocks, size=(q_n, nb)), jnp.int32)
    got = scan_per_query(table, queries, blocks, interpret=True)
    want = scan_posting_blocks_ref(table, queries, blocks)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("q_n,n_blocks,bs,d,nb,dtype", [
    (4, 32, 8, 16, 6, jnp.float32),
    (8, 64, 16, 128, 12, jnp.float32),
    (2, 16, 8, 32, 3, jnp.bfloat16),
])
def test_scan_batched_matches_ref(rng, q_n, n_blocks, bs, d, nb, dtype):
    blocks = jnp.asarray(rng.normal(size=(n_blocks, bs, d)), dtype)
    queries = jnp.asarray(rng.normal(size=(q_n, d)), dtype)
    ids = jnp.asarray(
        rng.choice(n_blocks, size=nb, replace=False), jnp.int32
    )
    got = scan_batched(ids, queries, blocks, interpret=True)
    want = scan_unique_blocks_ref(ids, queries, blocks)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_scan_posting_blocks_masks_absent_pages(rng):
    n_blocks, bs, d = 16, 4, 8
    blocks = jnp.asarray(rng.normal(size=(n_blocks, bs, d)), jnp.float32)
    queries = jnp.asarray(rng.normal(size=(2, d)), jnp.float32)
    posting_blocks = jnp.asarray(
        [[0, 1, -1, -1], [2, -1, -1, -1], [3, 4, 5, -1]], jnp.int32
    )
    pids = jnp.asarray([[0, 2], [1, -1]], jnp.int32)
    dists, page_ok = scan_posting_blocks(
        queries, posting_blocks, pids, blocks, interpret=True
    )
    dists = np.asarray(dists).reshape(2, 2, 4, bs)
    # query 0, posting 0 has pages {0,1}; pages 2,3 masked
    assert (dists[0, 0, 2:] >= BIG / 2).all()
    assert (dists[0, 0, :2] < BIG / 2).all()
    # query 1 probed only posting 1 (page 2); second probe fully masked
    assert (dists[1, 1] >= BIG / 2).all()
    ok = np.asarray(page_ok).reshape(2, 2, 4, bs)
    assert ok[0, 0, :2].all() and not ok[0, 0, 2:].any()


def test_scan_unique_blocks_padding(rng):
    blocks = jnp.asarray(rng.normal(size=(8, 4, 8)), jnp.float32)
    queries = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    ids = jnp.asarray([2, 5, -1, -1], jnp.int32)
    d = np.asarray(scan_unique_blocks(queries, ids, blocks, interpret=True))
    assert (d[2:] >= BIG / 2).all()
    want = np.asarray(scan_unique_blocks_ref(ids[:2], queries, blocks))
    np.testing.assert_allclose(d[:2], want, rtol=1e-4)


@pytest.mark.parametrize("q_n,n_blocks,bs,d,nb,k,dtype", [
    (4, 32, 8, 16, 6, 4, jnp.float32),
    (8, 64, 16, 128, 4, 10, jnp.float32),
    (2, 16, 8, 32, 3, 8, jnp.bfloat16),
    (1, 8, 4, 8, 1, 2, jnp.float32),
])
def test_scan_per_query_topk_matches_ref(rng, q_n, n_blocks, bs, d, nb, k, dtype):
    blocks = jnp.asarray(rng.normal(size=(n_blocks, bs, d)), dtype)
    queries = jnp.asarray(rng.normal(size=(q_n, d)), dtype)
    table = jnp.asarray(rng.integers(0, n_blocks, size=(q_n, nb)), jnp.int32)
    bias = jnp.where(
        jnp.asarray(rng.random(size=(q_n, nb, bs)) < 0.3), BIG, jnp.float32(0)
    )
    got_d, got_i = scan_per_query_topk(
        table, queries, blocks, bias, k=k, interpret=True
    )
    want_d, want_i = scan_per_query_topk_ref(table, queries, blocks, bias, k)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    gd, wd = np.asarray(got_d), np.asarray(want_d)
    live = wd < BIG / 2
    np.testing.assert_allclose(gd[live], wd[live], rtol=tol, atol=tol)
    assert (gd[~live] >= BIG / 2).all()
    # slot indices agree wherever the selection is unambiguous (live rows)
    assert (np.asarray(got_i)[live] == np.asarray(want_i)[live]).all()


@pytest.mark.parametrize("q_n,n_blocks,bs,d,nb,k,dtype", [
    (4, 32, 8, 16, 6, 4, jnp.float32),
    (8, 64, 16, 128, 12, 10, jnp.float32),
    (2, 16, 8, 32, 3, 8, jnp.bfloat16),
])
def test_scan_batched_topk_matches_ref(rng, q_n, n_blocks, bs, d, nb, k, dtype):
    blocks = jnp.asarray(rng.normal(size=(n_blocks, bs, d)), dtype)
    queries = jnp.asarray(rng.normal(size=(q_n, d)), dtype)
    ids = jnp.asarray(rng.choice(n_blocks, size=nb, replace=False), jnp.int32)
    bias = jnp.where(
        jnp.asarray(rng.random(size=(nb, bs)) < 0.3), BIG, jnp.float32(0)
    )
    got_d, got_i = scan_batched_topk(
        ids, queries, blocks, bias, k=k, interpret=True
    )
    want_d, want_i = scan_batched_topk_ref(ids, queries, blocks, bias, k)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    gd, wd = np.asarray(got_d), np.asarray(want_d)
    live = wd < BIG / 2
    np.testing.assert_allclose(gd[live], wd[live], rtol=tol, atol=tol)
    assert (gd[~live] >= BIG / 2).all()
    assert (np.asarray(got_i)[live] == np.asarray(want_i)[live]).all()


def test_scan_topk_wrappers_mask_dead_pages(rng):
    """Absent pages / dead slots never produce live candidates."""
    n_blocks, bs, d, k = 16, 8, 8, 3
    blocks = jnp.asarray(rng.normal(size=(n_blocks, bs, d)), jnp.float32)
    queries = jnp.asarray(rng.normal(size=(2, d)), jnp.float32)
    table = jnp.asarray([[0, -1, 3], [-1, -1, -1]], jnp.int32)
    live = jnp.ones((2, 3, bs), bool)
    live = live.at[0, 0, :4].set(False)  # half of page 0 dead
    dists, slots = scan_posting_blocks_topk(
        queries, table, live, blocks, k=k, interpret=True
    )
    dists, slots = np.asarray(dists), np.asarray(slots)
    assert (dists[1] >= BIG / 2).all()          # query 1 probed nothing
    assert (dists[0, 1] >= BIG / 2).all()       # absent page masked
    assert (slots[0, 0] >= 4).all()             # dead slots never selected
    assert (dists[0, 0] < BIG / 2).all()
    # batched wrapper: -1 padded pages masked
    uniq = jnp.asarray([0, 3, -1], jnp.int32)
    ulive = jnp.ones((3, bs), bool)
    bd, _ = scan_unique_blocks_topk(
        queries, uniq, ulive, blocks, k=k, interpret=True
    )
    bd = np.asarray(bd)
    assert (bd[2] >= BIG / 2).all()
    assert (bd[:2] < BIG / 2).all()


def test_dedup_pages_basic(rng):
    pages = jnp.asarray([5, 3, 5, -1, 9, 3, 3, -1], jnp.int32)
    uniq, pos, n_uniq, overflow = dedup_pages(pages, budget=6, num_blocks=16)
    uniq, pos = np.asarray(uniq), np.asarray(pos)
    assert uniq[:3].tolist() == [3, 5, 9]
    assert (uniq[3:] == -1).all()
    assert int(n_uniq) == 3 and int(overflow) == 0
    # membership rows point each probe at its page's row
    for p, r in zip([5, 3, 5, -1, 9, 3, 3, -1], pos.tolist()):
        if p < 0:
            assert r == -1
        else:
            assert uniq[r] == p


def test_dedup_pages_overflow_property(rng):
    """Budget compaction: kept pages are always a subset of the probed
    pages, counts are exact, and overflow == distinct - kept."""
    for trial in range(20):
        n_blocks = int(rng.integers(8, 64))
        n = int(rng.integers(4, 128))
        budget = int(rng.integers(1, 24))
        pages_np = rng.integers(-1, n_blocks, size=n).astype(np.int32)
        uniq, pos, n_uniq, overflow = dedup_pages(
            jnp.asarray(pages_np), budget=budget, num_blocks=n_blocks
        )
        uniq, pos = np.asarray(uniq), np.asarray(pos)
        real = np.unique(pages_np[pages_np >= 0])
        kept = uniq[uniq >= 0]
        assert int(n_uniq) == len(real)
        assert int(overflow) == max(len(real) - budget, 0)
        assert len(kept) == min(len(real), budget)
        # kept = the smallest-numbered distinct pages, sorted, no dups
        np.testing.assert_array_equal(kept, real[: len(kept)])
        # every probe of a kept page is mapped to its row; dropped/invalid -> -1
        for p, r in zip(pages_np.tolist(), pos.tolist()):
            if p >= 0 and p in kept:
                assert uniq[r] == p
            else:
                assert r == -1


def test_scan_consistency_between_variants(rng):
    """Both schedules must produce identical distances for shared pages."""
    n_blocks, bs, d, q_n = 32, 8, 16, 4
    blocks = jnp.asarray(rng.normal(size=(n_blocks, bs, d)), jnp.float32)
    queries = jnp.asarray(rng.normal(size=(q_n, d)), jnp.float32)
    table = jnp.asarray(rng.integers(0, n_blocks, size=(q_n, 5)), jnp.int32)
    per_q = np.asarray(scan_per_query(table, queries, blocks, interpret=True))
    uniq = jnp.asarray(np.unique(np.asarray(table)), jnp.int32)
    batched = np.asarray(scan_batched(uniq, queries, blocks, interpret=True))
    uniq_np = np.asarray(uniq)
    for q in range(q_n):
        for j, b in enumerate(np.asarray(table)[q]):
            bi = int(np.where(uniq_np == b)[0][0])
            np.testing.assert_allclose(
                per_q[q, j], batched[bi, q], rtol=1e-5, atol=1e-5
            )
