"""Tiny-N gate versions of the scenario gauntlet cells.

The benchmark (``benchmarks/bench_scenarios.py``) runs the real-size
cells and emits ``BENCH_scenarios.json``; these tests re-run shrunken
versions of all four so CI proves every cell's *mechanics* — recall
floors, live-set conservation, exact job accounting, and fixed-seed
determinism of the size vs drift job selection — in minutes, not hours.
"""
import numpy as np
import pytest

pytestmark = pytest.mark.gate

from benchmarks.bench_scenarios import (
    burst_cell,
    churn_cell,
    shift_cell,
    shift_compare,
    skew_cell,
)


TINY_SHIFT = dict(n_base=600, steps=3, n_hot=30, n_cold=60,
                  n_queries=24, jobs=1)


def _check_series(series: dict, steps: int) -> None:
    for key in ("step", "recall", "search_ms", "jobs", "n_live",
                "n_postings"):
        assert len(series[key]) == steps, key
    assert series["step"] == list(range(steps))
    assert all(0.0 <= r <= 1.0 for r in series["recall"])
    assert all(ms >= 0.0 for ms in series["search_ms"])


def test_burst_cell_recall_floor_and_accounting():
    cell = burst_cell(n_base=600, steps=4, quiet=40, burst=200,
                      burst_every=2, jobs=2, n_queries=24)
    _check_series(cell["series"], 4)
    s = cell["summary"]
    # recall-over-time floor: bursts may dip but must never crater
    assert s["min_recall"] >= 0.5
    assert s["final_recall"] >= 0.6
    # background slots are suppressed -> jobs never exceed the budget
    # (a budget of k ranks k split AND k merge candidates per round)
    assert all(j <= 2 * 2 for j in cell["series"]["jobs"])
    assert s["total_jobs"] == sum(cell["series"]["jobs"])
    assert s["access_total"] > 0, "search path did not bump telemetry"


def test_churn_cell_conserves_live_set():
    cell = churn_cell(n_base=600, steps=4, churn=80, jobs=2, n_queries=24)
    _check_series(cell["series"], 4)
    s = cell["summary"]
    assert s["live_set_conserved"], "tombstoned vid surfaced in results"
    assert s["final_recall"] >= 0.5
    # sliding window: insert N / delete N — the live ledger never grows
    # (it can shrink when a full posting drops an un-landed insert)
    assert all(0 < n <= 600 for n in cell["series"]["n_live"])


def test_skew_cell_concentrates_access():
    cell = skew_cell(n_base=800, steps=3, trickle=30, n_queries=48,
                     jobs=2)
    _check_series(cell["series"], 3)
    s = cell["summary"]
    assert s["final_recall"] >= 0.5
    # Zipfian reads must concentrate probes well above the uniform share
    assert s["access_top5pct_share"] > 0.10
    assert s["access_total"] > 0


def test_shift_cell_deterministic_under_fixed_seed():
    """Same seed + same policy -> bit-identical series (the WAL-replay
    story depends on job selection being a pure function of state)."""
    for policy in ("size", "drift"):
        a = shift_cell(policy=policy, **TINY_SHIFT)
        b = shift_cell(policy=policy, **TINY_SHIFT)
        for key in ("step", "recall", "jobs", "n_live", "n_postings"):
            assert a["series"][key] == b["series"][key], (policy, key)
        sa = {k: v for k, v in a["summary"].items()}
        sb = {k: v for k, v in b["summary"].items()}
        assert sa == sb, policy


def test_shift_compare_equal_budget_accounting():
    cmp = shift_compare(**TINY_SHIFT)
    size = cmp["policies"]["size"]["summary"]
    drift = cmp["policies"]["drift"]["summary"]
    # the comparison is only meaningful at EQUAL jobs-per-round budget
    assert cmp["jobs_per_round"] == 1
    assert size["total_jobs"] == drift["total_jobs"]
    assert cmp["drift_minus_size"] == round(
        drift["mean_recall"] - size["mean_recall"], 4
    )
    # both runs saw the byte-identical stream
    assert (cmp["policies"]["size"]["series"]["n_live"]
            == cmp["policies"]["drift"]["series"]["n_live"])
