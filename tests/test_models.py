"""Model zoo correctness: chunked attention vs oracle, MoE vs naive loop,
LM/GNN/recsys smoke (shapes + finiteness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn, layers as L, recsys, transformer as tf


# ------------------------- chunked attention ----------------------------

@pytest.mark.parametrize("b,sq,skv,h,kh,d,causal", [
    (2, 16, 16, 4, 4, 8, True),
    (2, 16, 16, 4, 2, 8, True),    # GQA
    (1, 8, 32, 4, 1, 16, False),   # MQA cross
    (2, 32, 32, 8, 4, 16, True),
])
def test_chunked_attention_matches_full(rng, b, sq, skv, h, kh, d, causal):
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, skv, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, skv, kh, d)), jnp.float32)
    got = L.chunked_attention(q, k, v, causal=causal, kv_chunk=8)
    want = L.full_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_chunked_attention_valid_len(rng):
    b, s, h, d = 1, 1, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, 16, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, 16, h, d)), jnp.float32)
    got = L.chunked_attention(
        q, k, v, causal=False, kv_chunk=4, kv_valid_len=jnp.asarray(5),
        q_offset=jnp.asarray(4),
    )
    want = L.full_attention_ref(q, k[:, :5], v[:, :5], causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


# ------------------------------- MoE ------------------------------------

def test_moe_matches_naive_when_capacity_ample(rng):
    key = jax.random.PRNGKey(0)
    params = L.init_moe(key, 16, 32, n_experts=4, dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    out, aux = L.moe(params, x, top_k=2, capacity_factor=4.0)  # no drops
    want = L.moe_ref(params, x, top_k=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-3, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_dont_nan(rng):
    key = jax.random.PRNGKey(1)
    params = L.init_moe(key, 8, 16, n_experts=2, dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    out, aux = L.moe(params, x, top_k=2, capacity_factor=0.25)  # heavy drops
    assert np.isfinite(np.asarray(out)).all()


# ------------------------------ LM --------------------------------------

def smoke_lm_cfg(moe=False):
    return tf.LMConfig(
        name="smoke", vocab=128, n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, dtype="float32", kv_chunk=8,
        moe=moe, n_experts=4 if moe else 0, moe_top_k=2 if moe else 0,
        qkv_bias=moe,  # exercise bias path too
    )


@pytest.mark.parametrize("moe", [False, True])
def test_lm_train_loss_finite(rng, moe):
    cfg = smoke_lm_cfg(moe)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 16)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    loss, metrics = tf.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: tf.loss_fn(p, batch, cfg)[0])(params)
    flat = jax.tree_util.tree_leaves(g)
    assert all(np.isfinite(np.asarray(x)).all() for x in flat)


def test_lm_prefill_decode_consistency(rng):
    """Decode at position S must equal a full forward over S+1 tokens."""
    cfg = smoke_lm_cfg()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    s = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, s + 1)), jnp.int32)
    # full forward on s+1 tokens: logits at last position
    logits_full, _ = tf.prefill(params, tokens, cfg)
    # prefill on s, then decode token s
    _, cache = tf.prefill(params, tokens[:, :s], cfg)
    # pad cache to s+1 capacity
    cache = {
        k: jnp.pad(v, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
        for k, v in cache.items()
    }
    logits_dec, _ = tf.decode_step(
        params, cache, tokens[:, s], jnp.asarray(s, jnp.int32), cfg
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), rtol=2e-3, atol=2e-3
    )


def test_lm_param_count_formula():
    cfg = smoke_lm_cfg()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    actual = sum(np.asarray(x).size for x in jax.tree_util.tree_leaves(params))
    assert abs(actual - cfg.n_params) / cfg.n_params < 0.02


# ------------------------------ GNN -------------------------------------

def test_gat_node_classification_smoke(rng):
    cfg = gnn.GATConfig(d_in=32, d_hidden=8, n_heads=4, n_classes=5)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    n, e = 50, 200
    batch = {
        "features": jnp.asarray(rng.normal(size=(n, 32)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, n, size=e), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, n, size=e), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 5, size=n), jnp.int32),
    }
    loss, m = gnn.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    logits = gnn.forward(params, batch, cfg)
    assert logits.shape == (n, 5)


def test_gat_learns_trivial_task(rng):
    """A few gradient steps reduce loss on a separable toy graph."""
    cfg = gnn.GATConfig(d_in=8, d_hidden=8, n_heads=2, n_classes=2)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    n = 40
    labels = np.concatenate([np.zeros(20), np.ones(20)]).astype(np.int32)
    feats = rng.normal(size=(n, 8)).astype(np.float32) + labels[:, None] * 3
    # edges within class
    src, dst = [], []
    for c in (0, 1):
        idx = np.where(labels == c)[0]
        for i in idx:
            for j in rng.choice(idx, size=3):
                src.append(i); dst.append(j)
    batch = {
        "features": jnp.asarray(feats),
        "edge_src": jnp.asarray(src, jnp.int32),
        "edge_dst": jnp.asarray(dst, jnp.int32),
        "labels": jnp.asarray(labels),
    }
    loss0, _ = gnn.loss_fn(params, batch, cfg)
    grad_fn = jax.jit(jax.grad(lambda p: gnn.loss_fn(p, batch, cfg)[0]))
    for _ in range(80):
        g = grad_fn(params)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.2 * gg, params, g)
    loss1, m = gnn.loss_fn(params, batch, cfg)
    assert float(loss1) < float(loss0) * 0.5
    assert float(m["acc"]) > 0.9


def test_gat_padded_edges_are_ignored(rng):
    cfg = gnn.GATConfig(d_in=8, d_hidden=4, n_heads=2, n_classes=3)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    n = 10
    feats = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)
    src = jnp.asarray([0, 1, 2, -1, -1], jnp.int32)
    dst = jnp.asarray([1, 2, 0, -1, -1], jnp.int32)
    out1 = gnn.forward(
        params, {"features": feats, "edge_src": src, "edge_dst": dst}, cfg
    )
    out2 = gnn.forward(
        params, {"features": feats, "edge_src": src[:3], "edge_dst": dst[:3]}, cfg
    )
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5, atol=1e-6)


def test_gat_graph_readout(rng):
    cfg = gnn.GATConfig(d_in=8, d_hidden=4, n_heads=2, n_classes=3,
                        readout="mean", n_graphs=2)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    n = 12
    batch = {
        "features": jnp.asarray(rng.normal(size=(n, 8)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, n, size=20), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, n, size=20), jnp.int32),
        "graph_ids": jnp.asarray([0] * 6 + [1] * 6, jnp.int32),
        "labels": jnp.asarray([0, 1], jnp.int32),
    }
    logits = gnn.forward(params, batch, cfg)
    assert logits.shape == (2, 3)
    loss, _ = gnn.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))


# ----------------------------- recsys -----------------------------------

def test_embedding_bag_fixed_vs_ragged(rng):
    table = jnp.asarray(rng.normal(size=(100, 8)), jnp.float32)
    ids = jnp.asarray([[1, 2, -1], [3, -1, -1]], jnp.int32)
    fixed = recsys.bag_lookup(table, ids, combiner="mean")
    flat = jnp.asarray([1, 2, 3, -1], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1], jnp.int32)
    ragged = recsys.embedding_bag_ragged(table, flat, seg, 2, combiner="mean")
    np.testing.assert_allclose(np.asarray(fixed), np.asarray(ragged), rtol=1e-6)


def test_deepfm_smoke(rng):
    cfg = recsys.DeepFMConfig(n_fields=6, vocab_per_field=50, embed_dim=4,
                              mlp_dims=(16, 16))
    params = recsys.deepfm_init(jax.random.PRNGKey(0), cfg)
    batch = {
        "fields": jnp.asarray(rng.integers(0, 50, size=(8, 6)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 2, size=8), jnp.int32),
    }
    loss, _ = recsys.deepfm_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: recsys.deepfm_loss(p, batch, cfg)[0])(params)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree_util.tree_leaves(g))


def test_twotower_smoke_and_retrieval(rng):
    cfg = recsys.TwoTowerConfig(
        n_items=500, n_user_fields=4, user_vocab_per_field=100,
        embed_dim=16, tower_dims=(32, 16),
    )
    params = recsys.twotower_init(jax.random.PRNGKey(0), cfg)
    batch = {
        "user_fields": jnp.asarray(rng.integers(0, 100, size=(8, 4)), jnp.int32),
        "item_ids": jnp.asarray(rng.integers(0, 500, size=8), jnp.int32),
        "item_logq": jnp.zeros(8, jnp.float32),
    }
    loss, _ = recsys.twotower_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    scores = recsys.twotower_retrieval(
        params,
        {
            "user_fields": batch["user_fields"][:1],
            "candidate_ids": jnp.arange(500, dtype=jnp.int32),
        },
        cfg,
    )
    assert scores.shape == (1, 500)
    assert np.isfinite(np.asarray(scores)).all()


def test_bert4rec_smoke(rng):
    cfg = recsys.Bert4RecConfig(n_items=200, embed_dim=16, n_blocks=2,
                                n_heads=2, d_ff=32, seq_len=12)
    params = recsys.bert4rec_init(jax.random.PRNGKey(0), cfg)
    items = rng.integers(0, 200, size=(4, 12)).astype(np.int32)
    items[:, 5] = cfg.mask_id
    batch = {
        "items": jnp.asarray(items),
        "mask_pos": jnp.asarray(np.full((4, 1), 5, np.int32)),
        "mask_label": jnp.asarray(
            rng.integers(0, 200, size=(4, 1)).astype(np.int32)
        ),
    }
    loss, _ = recsys.bert4rec_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    scores = recsys.bert4rec_score(params, {"items": jnp.asarray(items)}, cfg)
    assert scores.shape == (4, 200)


def test_mind_smoke(rng):
    cfg = recsys.MINDConfig(n_items=300, embed_dim=16, n_interests=4,
                            seq_len=10)
    params = recsys.mind_init(jax.random.PRNGKey(0), cfg)
    items = jnp.asarray(rng.integers(0, 300, size=(6, 10)), jnp.int32)
    target = jnp.asarray(rng.integers(0, 300, size=6), jnp.int32)
    loss, _ = recsys.mind_loss(params, {"items": items, "target": target}, cfg)
    assert np.isfinite(float(loss))
    caps = recsys.mind_serve(params, {"items": items}, cfg)
    assert caps.shape == (6, 4, 16)
    assert np.isfinite(np.asarray(caps)).all()
