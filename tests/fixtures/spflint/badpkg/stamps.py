"""Fixture stamp tuples: one unclassified field, one stale name."""

REPLAY_CRITICAL_FIELDS = ("dim", "ghost")  # expect: SPF105 SPF106
REPLAY_EXEMPT_FIELDS = ("nprobe",)
