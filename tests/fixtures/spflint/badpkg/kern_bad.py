"""Fixture Pallas wrappers: one seeded violation per resource rule.

Parsed, never imported — the imports exist only so the file stays a
plausible kernel module.
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _clean_kernel(q_ref, o_ref):
    o_ref[...] = q_ref[...] * 2.0


def _host_kernel(q_ref, o_ref):
    o_ref[...] = q_ref[...] + np.float32(1.0)   # expect: SPF302
    print("debug")                              # expect: SPF302


def over_budget(q):
    # 2 * (16 MiB in + 16 MiB out) = 64 MiB >> the 16 MiB budget
    return pl.pallas_call(                      # expect: SPF301
        _clean_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((2048, 2048), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((2048, 2048), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((8192, 2048), jnp.float32),
    )(q)


def interp_only(q):
    return pl.pallas_call(
        _host_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((8, dim), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
    )(q)


def unanalyzable(q):
    return pl.pallas_call(                      # expect: SPF303
        _clean_kernel,
        out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
    )(q)


def unknown_symbol(q):
    return pl.pallas_call(                      # expect: SPF304
        _clean_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((mystery_rows, dim), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
    )(q)
