"""Fixture engine class: one seeded violation per lock-discipline rule."""
import threading


def holds_work(fn):
    return fn


class BadEngine:                           # expect: SPF206
    LOCK_FIELD = "_work"
    PUMP_METHODS = ("_pump",)
    LIFECYCLE_METHODS = ("start", "stop")
    FIELD_OWNERSHIP = {
        "_work": "init",
        "cfg": "init",
        "_inflight": "guarded",
        "_busy": "pump",
        "_thread": "lifecycle",
        "_ghost": "guarded",
    }

    def __init__(self):
        self._work = threading.RLock()
        self.cfg = None
        self._inflight = 0
        self._busy = False
        self._thread = None

    # ------------------------- clean accesses -------------------------
    def ok_locked_read(self):
        with self._work:
            return self._inflight

    @holds_work
    def _locked_helper(self):
        self._inflight += 1

    def ok_locked_call(self):
        with self._work:
            self._locked_helper()

    def _pump(self):
        self._busy = True

    def start(self):
        self._thread = object()

    def stop(self):
        self._busy = False
        self._thread = None

    # ----------------------- seeded violations ------------------------
    def bad_read(self):
        return self._inflight              # expect: SPF201

    def bad_write(self):
        self._inflight = 0                 # expect: SPF202

    def bad_pump_write(self):
        self._busy = True                  # expect: SPF203

    def bad_init_write(self):
        self.cfg = 1                       # expect: SPF204

    def bad_lifecycle_write(self):
        self._thread = None                # expect: SPF204

    def bad_undeclared_write(self):
        self._stray = 1                    # expect: SPF205

    def bad_unlocked_call(self):
        self._locked_helper()              # expect: SPF207
