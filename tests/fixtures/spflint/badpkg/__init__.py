"""Seeded-violation fixture package for the spflint pass tests.

Every deliberate violation line carries a trailing ``# expect: SPF...``
marker; ``tests/test_spflint.py`` parses the markers and asserts the
passes report EXACTLY that (file, line, rule) set — nothing missing,
nothing extra.  These modules are parsed, never imported.
"""
