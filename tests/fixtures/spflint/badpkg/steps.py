"""Fixture replay surface: nondeterminism + an unstamped config read,
all reachable from the declared root ``build_step``."""
import random
import time

import numpy as np
from numpy.random import default_rng


def _noise(n):
    t = time.time()                       # expect: SPF101
    j = random.random()                   # expect: SPF102
    r = np.random.rand(n)                 # expect: SPF102
    g = default_rng()                     # expect: SPF102
    return t, j, r, g


def _seeded_ok(n):
    # seeded Generator + list iteration: must NOT fire SPF102/SPF103
    g = default_rng(1234)
    for _ in [1, 2, 3]:
        pass
    return g.integers(0, n)


def build_step(cfg):
    for vid in {1, 2, 3}:                 # expect: SPF103
        _ = vid
    _noise(4)
    _seeded_ok(4)
    w = cfg.doubled
    return cfg.dim + cfg.extra + w        # expect: SPF104
