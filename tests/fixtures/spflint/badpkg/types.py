"""Fixture config class: three fields + a derived property."""


class Cfg:
    dim: int = 8
    nprobe: int = 4
    extra: int = 0      # classified nowhere -> SPF105 (at the stamp site)

    @property
    def doubled(self) -> int:
        # property reads expand to their underlying fields: `cfg.doubled`
        # on the replay path must NOT fire SPF104 (dim is stamped)
        return self.dim * 2
