"""Unified service API gate: ServiceSpec compilation + end-to-end
crash-recovery parity through ``spfresh.open`` (the tentpole acceptance
criterion).

The parity tests build a durable service, stream inserts/deletes through
the micro-batched pipeline (maintenance slots interleave), "crash" by
abandoning the handle before any checkpoint, reopen via ``spfresh.open``
— and assert the recovered service answers queries EXACTLY like the
uncrashed twin (dispatch-level WAL replay is bit-deterministic).  The
2-shard mesh version runs in a subprocess (fake CPU devices) so the main
pytest process keeps exactly one device.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

# check.sh runs this suite as its own explicit gate step; the tier-1
# step excludes it via the marker (no hand-maintained --ignore list).
pytestmark = pytest.mark.gate

import spfresh
from repro.core.types import LireConfig
from repro.storage.wal import iter_wal
from tests.conftest import make_clustered


def tiny_cfg(**kw):
    args = dict(
        dim=16, block_size=8, max_blocks_per_posting=8, num_blocks=1024,
        num_postings_cap=128, num_vectors_cap=4096, split_limit=48,
        merge_limit=6, reassign_range=8, reassign_budget=128,
        replica_count=2, nprobe=8,
    )
    args.update(kw)
    return LireConfig(**args)


def tiny_spec(root=None, **dur_kw) -> spfresh.ServiceSpec:
    spec = spfresh.ServiceSpec(
        index=spfresh.IndexSpec(config=tiny_cfg()),
        serve=spfresh.ServeSpec(search_k=10, max_batch=64),
    )
    if root is not None:
        spec = spec.with_durability(str(root), **dur_kw)
    return spec


def _stream(svc, rng, n=90, base_id=2000):
    """Inserts in 3 chunks (maintenance slots fire) + a delete batch;
    returns (inserted vecs, ids, deleted ids)."""
    vecs = make_clustered(rng, n, 16, n_clusters=3)
    ids = np.arange(base_id, base_id + n, dtype=np.int32)
    for s in range(0, n, 30):
        svc.insert(vecs[s:s + 30], ids[s:s + 30])
    dead = ids[:10]
    svc.delete(dead)
    return vecs, ids, dead


# ---------------------------------------------------------------------------
# Spec compilation
# ---------------------------------------------------------------------------

def test_spec_is_frozen_and_composable():
    spec = tiny_spec()
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.serve.search_k = 5
    sharded = spec.with_shards(4)
    assert sharded.shards.n_shards == 4 and spec.shards.n_shards == 1
    durable = spec.with_durability("/data/svc", checkpoint_every=100)
    assert durable.durability.resolved_wal_dir() == "/data/svc/wal"
    assert durable.durability.resolved_snapshot_dir() == "/data/svc/snapshot"
    assert not spec.durability.enabled


def test_spec_folds_scan_and_maintenance_into_lire_config():
    spec = dataclasses.replace(
        tiny_spec(),
        scan=spfresh.ScanSpec(use_pallas_scan=True, scan_schedule="batched",
                              scan_page_budget=64),
        maintenance=spfresh.MaintenanceSpec(jobs_per_round=2, merge_fanout=3),
    )
    cfg = spec.lire_config()
    assert cfg.use_pallas_scan is True and cfg.scan_schedule == "batched"
    assert cfg.scan_page_budget == 64
    assert cfg.jobs_per_round == 2 and cfg.merge_fanout == 3
    # None fields defer to IndexSpec.config
    assert tiny_spec().lire_config() == tiny_cfg()


def test_spec_compiles_engine_config():
    spec = dataclasses.replace(
        tiny_spec(),
        serve=spfresh.ServeSpec(search_k=7, nprobe=4, policy="backlog",
                                backlog_threshold=3, max_batch=128),
        scan=spfresh.ScanSpec(probe_chunk=2),
        maintenance=spfresh.MaintenanceSpec(jobs_per_round=2),
    )
    ecfg = spec.engine_config()
    assert ecfg.search_k == 7 and ecfg.nprobe == 4
    assert ecfg.policy == "backlog" and ecfg.backlog_threshold == 3
    assert ecfg.probe_chunk == 2
    assert ecfg.maintain_budget == 2      # defaults to jobs_per_round
    assert ecfg.make_policy().describe().startswith("backlog")


def test_spec_validate_rejects_bad_values():
    with pytest.raises(AssertionError):
        dataclasses.replace(
            tiny_spec(), serve=spfresh.ServeSpec(policy="nope")
        ).validate()
    with pytest.raises(AssertionError):
        dataclasses.replace(
            tiny_spec(), scan=spfresh.ScanSpec(scan_schedule="zigzag")
        ).validate()
    # half-configured durability would silently run ephemeral
    with pytest.raises(ValueError, match="BOTH wal_dir and snapshot_dir"):
        dataclasses.replace(
            tiny_spec(),
            durability=spfresh.DurabilitySpec(wal_dir="/data/wal"),
        ).validate()


# ---------------------------------------------------------------------------
# open() lifecycle, local backend
# ---------------------------------------------------------------------------

def test_open_requires_vectors_or_snapshot(tmp_path):
    with pytest.raises(FileNotFoundError):
        spfresh.open(tiny_spec())
    with pytest.raises(FileNotFoundError):
        spfresh.open(tiny_spec(tmp_path / "svc"))


def test_ephemeral_service_serves_but_cannot_checkpoint(rng):
    base = make_clustered(rng, 600, 16)
    svc = spfresh.open(tiny_spec(), vectors=base)
    assert not svc.durable and svc.initial_handles is not None
    d, v = svc.search(base[:4], k=5)
    assert (v[:, 0] == np.arange(4)).all()
    with pytest.raises(RuntimeError):
        svc.checkpoint()
    svc.close()   # close on an ephemeral service is a flush, not an error


def test_local_insert_requires_vids(rng):
    svc = spfresh.open(tiny_spec(), vectors=make_clustered(rng, 400, 16))
    with pytest.raises(ValueError):
        svc.insert(make_clustered(rng, 4, 16))


def test_local_crash_recovery_exact_parity(tmp_path, rng):
    """Kill before any checkpoint: reopen = open-time snapshot + full WAL
    replay.  The recovered service must equal the uncrashed twin."""
    base = make_clustered(rng, 800, 16, n_clusters=6)
    spec = tiny_spec(tmp_path / "svc")
    svc = spfresh.open(spec, vectors=base)
    vecs, ids, dead = _stream(svc, rng)
    queries = np.concatenate([vecs[:12], base[:12]])
    want_d, want_v = svc.search(queries, k=10)

    twin = spfresh.open(spec)          # crash: no checkpoint, no close
    assert twin.recovered
    got_d, got_v = twin.search(queries, k=10)
    np.testing.assert_array_equal(want_v, got_v)
    np.testing.assert_allclose(want_d, got_d, rtol=1e-5)
    # deleted ids stay deleted through recovery
    leaked = set(got_v.reshape(-1).tolist()) & set(dead.tolist())
    assert not leaked, f"recovery resurrected {leaked}"
    # fresh inserts are recalled
    _, hit = twin.search(vecs[20:30], k=3)
    assert (hit[:, 0] == ids[20:30]).all()


def test_local_checkpoint_then_tail_replay(tmp_path, rng):
    """Checkpoint mid-stream: recovery = snapshot + WAL *tail* only."""
    base = make_clustered(rng, 700, 16)
    spec = tiny_spec(tmp_path / "svc")
    svc = spfresh.open(spec, vectors=base)
    _stream(svc, rng, n=60)
    svc.checkpoint()
    wal0 = spec.durability.resolved_wal_dir() + "/shard_000.wal"
    assert list(iter_wal(wal0)) == []            # truncated post-snapshot
    vecs2, ids2, _ = _stream(svc, rng, n=30, base_id=3000)
    assert len(list(iter_wal(wal0))) > 0         # tail since checkpoint
    want = svc.search(vecs2[:8], k=5)

    twin = spfresh.open(spec)
    got = twin.search(vecs2[:8], k=5)
    np.testing.assert_array_equal(want[1], got[1])
    np.testing.assert_allclose(want[0], got[0], rtol=1e-5)


def test_auto_checkpoint_every_n_update_rows(tmp_path, rng):
    base = make_clustered(rng, 500, 16)
    spec = tiny_spec(tmp_path / "svc", checkpoint_every=50)
    svc = spfresh.open(spec, vectors=base)
    vecs = make_clustered(rng, 60, 16)
    svc.insert(vecs, np.arange(2000, 2060, dtype=np.int32))
    # 60 rows >= 50: an auto-checkpoint fired and truncated the WAL
    rep = svc.report()["durability"]
    assert rep["updates_since_checkpoint"] == 0
    wal0 = spec.durability.resolved_wal_dir() + "/shard_000.wal"
    assert list(iter_wal(wal0)) == []
    twin = spfresh.open(spec)                    # snapshot alone recovers
    _, got = twin.search(vecs[:6], k=3)
    assert (got[:, 0] == np.arange(2000, 2006)).all()


def test_clean_close_then_reopen_and_continue(tmp_path, rng):
    base = make_clustered(rng, 600, 16)
    spec = tiny_spec(tmp_path / "svc")
    svc = spfresh.open(spec, vectors=base)
    vecs, ids, _ = _stream(svc, rng, n=30)
    want = svc.search(vecs[:8], k=5)
    svc.close()                                  # final checkpoint
    svc.close()                                  # idempotent

    svc2 = spfresh.open(spec)
    got = svc2.search(vecs[:8], k=5)
    np.testing.assert_array_equal(want[1], got[1])
    # the recovered service keeps serving updates durably
    more = make_clustered(rng, 20, 16)
    svc2.insert(more, np.arange(3000, 3020, dtype=np.int32))
    svc2.close()
    svc3 = spfresh.open(spec)
    _, got3 = svc3.search(more[:5], k=3)
    assert (got3[:, 0] == np.arange(3000, 3005)).all()


def test_double_crash_cycle_keeps_post_recovery_updates(tmp_path, rng):
    """Regression: checkpoint → crash → recover → update → crash →
    recover.  The first recovery finds truncated (empty) WALs; its seqno
    numbering must resume ABOVE the snapshot's stamped seqno or the
    post-recovery update is logged with an already-stamped seqno and the
    SECOND recovery silently skips it as already-applied."""
    base = make_clustered(rng, 500, 16)
    spec = tiny_spec(tmp_path / "svc")
    svc = spfresh.open(spec, vectors=base)
    svc.insert(make_clustered(rng, 20, 16),
               np.arange(2000, 2020, dtype=np.int32))
    svc.checkpoint()                   # stamps wal_seqnos, truncates WAL

    svc2 = spfresh.open(spec)          # crash #1: recover from snapshot
    vecs = make_clustered(rng, 20, 16)
    svc2.insert(vecs, np.arange(3000, 3020, dtype=np.int32))  # acknowledged
    want = svc2.search(vecs[:6], k=3)

    svc3 = spfresh.open(spec)          # crash #2: replay must keep them
    got = svc3.search(vecs[:6], k=3)
    np.testing.assert_array_equal(want[1], got[1])
    assert (got[1][:, 0] == np.arange(3000, 3006)).all(), (
        "post-recovery insert lost by the second recovery"
    )


def test_open_fresh_rebuilds_over_existing_root(tmp_path, rng):
    """``fresh=True`` supersedes a durable root instead of recovering it
    (the launcher's no---recover path)."""
    base1 = make_clustered(rng, 400, 16)
    spec = tiny_spec(tmp_path / "svc")
    svc = spfresh.open(spec, vectors=base1)
    svc.insert(make_clustered(rng, 10, 16),
               np.arange(2000, 2010, dtype=np.int32))
    svc.close()

    base2 = make_clustered(rng, 500, 16)
    svc2 = spfresh.open(spec, vectors=base2, fresh=True)
    assert not svc2.recovered
    _, got = svc2.search(base2[:4], k=3)
    assert (got[:, 0] == np.arange(4)).all()       # the NEW corpus
    svc3 = spfresh.open(spec)                      # root now holds build #2
    assert svc3.recovered
    _, got3 = svc3.search(base2[:4], k=3)
    np.testing.assert_array_equal(got, got3)
    with pytest.raises(ValueError):
        spfresh.open(spec, fresh=True)             # fresh needs vectors


def test_fresh_open_crash_window_preserves_previous_incarnation(tmp_path, rng):
    """A fresh rebuild over a non-empty durable root must not touch the
    old snapshot/WAL before its own open-time checkpoint commits: a crash
    mid-rebuild (simulated by snapshotting the root's WAL bytes before
    open(fresh=True) reaches its checkpoint) recovers run 1 intact."""
    base = make_clustered(rng, 400, 16)
    spec = tiny_spec(tmp_path / "svc")
    svc = spfresh.open(spec, vectors=base)
    vecs = make_clustered(rng, 20, 16)
    svc.insert(vecs, np.arange(2000, 2020, dtype=np.int32))  # WAL only
    want = svc.search(vecs[:6], k=3)
    # crash + operator re-runs the build; the rebuild itself crashes
    # before its open-time checkpoint: the root must still recover run 1.
    # (open()'s build path no longer truncates the WAL up front, so the
    # pre-checkpoint window leaves snapshot+WAL untouched — we verify the
    # recovery-relevant artifacts directly.)
    wal0 = spec.durability.resolved_wal_dir() + "/shard_000.wal"
    n_records_before = len(list(iter_wal(wal0)))
    assert n_records_before > 0
    twin = spfresh.open(spec)                  # recovery still sees run 1
    got = twin.search(vecs[:6], k=3)
    np.testing.assert_array_equal(want[1], got[1])
    # snapshot_on_open=False over a dirty root is refused outright
    dirty = dataclasses.replace(
        spec, durability=dataclasses.replace(
            spec.durability, snapshot_on_open=False),
    )
    with pytest.raises(ValueError, match="non-empty durable root"):
        spfresh.open(dirty, vectors=base, fresh=True)


def test_recovery_rejects_replay_critical_config_drift(tmp_path, rng):
    """Reopening under different geometry/protocol parameters must fail
    with the mismatched field names (not a cryptic leaf-shape error);
    serving-side knobs like nprobe may drift freely."""
    base = make_clustered(rng, 400, 16)
    spec = tiny_spec(tmp_path / "svc")
    spfresh.open(spec, vectors=base).close()

    drifted = dataclasses.replace(
        spec, index=spfresh.IndexSpec(config=tiny_cfg(split_limit=32)),
    )
    with pytest.raises(ValueError, match="split_limit"):
        spfresh.open(drifted)
    resized = dataclasses.replace(
        spec, index=spfresh.IndexSpec(config=tiny_cfg(num_blocks=2048)),
    )
    with pytest.raises(ValueError, match="num_blocks"):
        spfresh.open(resized)
    serving_drift = dataclasses.replace(
        spec, index=spfresh.IndexSpec(config=tiny_cfg(nprobe=4)),
    )
    assert spfresh.open(serving_drift).recovered   # nprobe is not critical
    # shard-count drift is caught by the manifest check, before the
    # stacked template turns it into a leaf-shape error (or a mesh build)
    with pytest.raises(ValueError, match="n_shards"):
        spfresh.open(spec.with_shards(2))


def test_recovery_preserves_maintenance_invariants(tmp_path, rng):
    """Post-recovery the index obeys the LIRE invariants and drains to a
    zero backlog — replay re-ran the logged maintenance rounds."""
    base = make_clustered(rng, 800, 16, n_clusters=2)   # skewed: splits fire
    spec = tiny_spec(tmp_path / "svc")
    svc = spfresh.open(spec, vectors=base)
    _stream(svc, rng, n=120)
    assert svc.stats()["n_splits"] > 0

    twin = spfresh.open(spec)
    assert twin.stats() == svc.stats()           # counters replay too
    twin.drain()
    assert twin.backlog() == 0
    lens = np.asarray(twin.index.state.pool.posting_len)
    valid = np.asarray(twin.index.state.centroid_valid)
    assert (lens[valid] <= twin.index.state.cfg.split_limit).all()


# ---------------------------------------------------------------------------
# The same spec over the 2-shard mesh (subprocess: fake CPU devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_service_crash_recovery_over_two_shard_mesh(tmp_path):
    script = os.path.join(os.path.dirname(__file__),
                          "service_sharded_script.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, script, str(tmp_path)],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0
    assert "ALL_SERVICE_SHARDED_PASS" in proc.stdout


# ---------------------------------------------------------------------------
# Durability fast path: delta snapshots, group commit, WAL compaction
# ---------------------------------------------------------------------------

def test_delta_checkpoint_crash_cycle_exact_parity(tmp_path, rng):
    """The tentpole acceptance gate (local backend): stream → base →
    delta → delta → compaction → more stream → crash.  Every reopen along
    the way must answer queries exactly like the uncrashed twin — the
    delta chain folds block-granular dirty writes back into the same
    state the full snapshot would have captured."""
    base = make_clustered(rng, 800, 16, n_clusters=6)
    spec = tiny_spec(tmp_path / "svc", delta_every=30, compact_every=2)
    svc = spfresh.open(spec, vectors=base)     # open-time FULL base

    from repro.storage.snapshot import SnapshotStore
    store = SnapshotStore(spec.durability.resolved_snapshot_dir())
    assert store.has_base() and store.chain_len() == 0

    # _stream inserts in 30-row chunks: the delta_every=30 cadence fires
    # an auto-checkpoint per chunk; compact_every=2 folds the chain after
    # two deltas, so the cycle base→delta→delta→compact happens by itself
    vecs, ids, dead = _stream(svc, rng, n=90)
    assert store.chain_len() <= 2              # compaction kept it bounded
    chain_seen = svc.report()["durability"]["snapshot_chain_len"]
    assert chain_seen == store.chain_len()

    queries = np.concatenate([vecs[:12], base[:12]])
    want_d, want_v = svc.search(queries, k=10)

    twin = spfresh.open(spec)                  # crash: WAL tail over chain
    assert twin.recovered
    got_d, got_v = twin.search(queries, k=10)
    np.testing.assert_array_equal(want_v, got_v)
    np.testing.assert_allclose(want_d, got_d, rtol=1e-5)
    assert twin.stats() == svc.stats()
    leaked = set(got_v.reshape(-1).tolist()) & set(dead.tolist())
    assert not leaked, f"delta-chain recovery resurrected {leaked}"

    # keep going through another delta→crash cycle on the recovered twin
    more = make_clustered(rng, 30, 16)
    twin.insert(more, np.arange(5000, 5030, dtype=np.int32))
    want2 = twin.search(more[:8], k=5)
    third = spfresh.open(spec)
    got2 = third.search(more[:8], k=5)
    np.testing.assert_array_equal(want2[1], got2[1])
    np.testing.assert_allclose(want2[0], got2[0], rtol=1e-5)


def test_explicit_delta_and_compaction_checkpoints(tmp_path, rng):
    """checkpoint(delta=True/False) force the unit kind; a delta with no
    chain promotes to a base instead of failing; compaction prunes."""
    base = make_clustered(rng, 500, 16)
    spec = tiny_spec(tmp_path / "svc", snapshot_on_open=False)
    from repro.storage.snapshot import SnapshotStore
    store = SnapshotStore(spec.durability.resolved_snapshot_dir())

    svc = spfresh.open(spec, vectors=base)
    assert not store.exists()                  # no open-time snapshot
    svc.checkpoint(delta=True)                 # promotes: nothing to chain to
    assert store.has_base() and store.chain_len() == 0
    svc.insert(make_clustered(rng, 20, 16),
               np.arange(2000, 2020, dtype=np.int32))
    svc.checkpoint(delta=True)
    assert store.chain_len() == 1
    full = store.unit_bytes(store._chain(store._head())[0])
    assert store.unit_bytes() < 0.5 * full     # delta ≪ base on disk
    svc.checkpoint(delta=False)                # explicit compaction
    assert store.chain_len() == 0 and len(store._units()) == 1
    want = svc.search(base[:6], k=5)
    twin = spfresh.open(spec)
    got = twin.search(base[:6], k=5)
    np.testing.assert_array_equal(want[1], got[1])


def test_group_commit_acks_then_recovers_exactly(tmp_path, rng):
    """Group commit: many insert dispatches share one fsync through
    ``insert_bulk``; everything acknowledged must survive a crash."""
    base = make_clustered(rng, 600, 16)
    spec = tiny_spec(tmp_path / "svc", group_commit=16)
    svc = spfresh.open(spec, vectors=base)
    stream = make_clustered(rng, 96, 16, n_clusters=3)
    ids = np.arange(3000, 3096, dtype=np.int32)
    got_ids, landed = svc.insert_bulk(stream, ids, chunk=32)
    assert landed.all() and (got_ids == ids).all()
    st = svc.report()["durability"]["wal"]
    assert st["pending"] == 0                  # acked ⇒ fsync'd
    assert st["fsyncs_per_append"] < 0.5, st   # ≥2 dispatches per fsync
    svc.delete(ids[:5])
    want = svc.search(stream[:10], k=5)

    twin = spfresh.open(spec)                  # crash after the acks
    got = twin.search(stream[:10], k=5)
    np.testing.assert_array_equal(want[1], got[1])
    np.testing.assert_allclose(want[0], got[0], rtol=1e-5)
    _, hit = twin.search(stream[10:20], k=1)
    assert (hit[:, 0] == ids[10:20]).all(), "acked insert lost post-crash"


def test_wal_compaction_recovery_preserves_live_set(tmp_path, rng):
    """compact_wal=True recovery: dead insert rows never re-land, the
    live set and deletions are preserved, and the recovered service
    recalls every surviving vector."""
    base = make_clustered(rng, 600, 16)
    spec = tiny_spec(tmp_path / "svc", compact_wal=True)
    svc = spfresh.open(spec, vectors=base)
    wave1 = make_clustered(rng, 30, 16)
    ids1 = np.arange(2000, 2030, dtype=np.int32)
    svc.insert(wave1, ids1)
    svc.delete(ids1)                           # whole wave dies pre-crash
    wave2 = make_clustered(rng, 30, 16)
    ids2 = np.arange(4000, 4030, dtype=np.int32)
    svc.insert(wave2, ids2)

    twin = spfresh.open(spec)
    assert twin.recovered
    _, hit = twin.search(wave2[:10], k=1)
    assert (hit[:, 0] == ids2[:10]).all(), "live insert lost by compaction"
    _, got = twin.search(wave1[:10], k=10)
    leaked = set(got.reshape(-1).tolist()) & set(ids1.tolist())
    assert not leaked, f"compaction resurrected deleted vids {leaked}"
    # compaction really skipped replay work: fewer physical appends than
    # the uncrashed service performed
    assert twin.stats()["n_appends"] < svc.stats()["n_appends"]


# ---------------------------------------------------------------------------
# Maintenance-policy telemetry through the WAL
# ---------------------------------------------------------------------------

def _drift_spec(root) -> spfresh.ServiceSpec:
    return dataclasses.replace(
        tiny_spec(root),
        maintenance=spfresh.MaintenanceSpec(
            policy="drift", alpha=4.0, beta=1.0
        ),
    )


def test_crash_recovery_replays_telemetry_bit_exactly(tmp_path, rng):
    """Access/update/drift telemetry leaves are STATE: searches feed the
    pending access buffer, maintain logs it with the round dispatch, and
    replay must reproduce every leaf bit-exactly — under the drift policy
    the counters also decide job selection, so any divergence would show
    up as different postings being split."""
    import jax

    base = make_clustered(rng, 800, 16, n_clusters=2)   # skewed: splits fire
    spec = _drift_spec(tmp_path / "svc")
    svc = spfresh.open(spec, vectors=base)
    vecs, ids, _ = _stream(svc, rng, n=90)
    # searches between maintains: the probe histogram lands in the NEXT
    # logged round's payload, in several installments
    for qs in (base[:32], vecs[:32], base[100:132]):
        svc.search(qs, k=10)
        svc.maintain(2)
    st = svc.stats()
    assert st["access_total"] > 0 and st["update_total"] > 0

    twin = spfresh.open(spec)                  # crash: full WAL replay
    assert twin.recovered
    assert twin.stats() == st                  # incl. telemetry totals
    for a, b in zip(
        jax.tree_util.tree_leaves(svc.index.state.telemetry),
        jax.tree_util.tree_leaves(twin.index.state.telemetry),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the full state agrees, not just the telemetry
    q = np.concatenate([vecs[:10], base[:10]])
    want, got = svc.search(q, k=10), twin.search(q, k=10)
    np.testing.assert_array_equal(want[1], got[1])


def test_pending_access_is_not_state_until_logged(tmp_path, rng):
    """Probes accumulated since the last maintain live in a host-side
    buffer, NOT the state: a crash before the next round loses them, and
    the recovered twin must agree with the state (zero), not the buffer."""
    base = make_clustered(rng, 500, 16)
    spec = _drift_spec(tmp_path / "svc")
    svc = spfresh.open(spec, vectors=base)
    svc.search(base[:64], k=10)                # buffered, never logged
    assert svc.stats()["access_total"] == 0    # stats read STATE only

    twin = spfresh.open(spec)
    assert twin.recovered
    assert twin.stats()["access_total"] == 0
    assert twin.stats() == svc.stats()


def test_recovery_rejects_maintain_policy_drift(tmp_path, rng):
    """maintain_policy/alpha/beta shape which postings every logged round
    touches, so they are replay-critical: reopening under a different
    policy must fail loudly instead of replaying a diverged history."""
    base = make_clustered(rng, 400, 16)
    spec = _drift_spec(tmp_path / "svc")
    spfresh.open(spec, vectors=base).close()

    with pytest.raises(ValueError, match="maintain_policy"):
        spfresh.open(tiny_spec(tmp_path / "svc"))   # default: size
    reweighted = dataclasses.replace(
        spec, maintenance=spfresh.MaintenanceSpec(
            policy="drift", alpha=8.0, beta=1.0
        ),
    )
    with pytest.raises(ValueError, match="maintain_alpha"):
        spfresh.open(reweighted)
    assert spfresh.open(spec).recovered        # same policy: fine


# ---------------------------------------------------------------------------
# Async serving (background pump thread)
# ---------------------------------------------------------------------------

def _async_spec(root=None, max_wait_ms=2.0, **dur_kw) -> spfresh.ServiceSpec:
    spec = tiny_spec(root, **dur_kw)
    return dataclasses.replace(
        spec,
        serve=dataclasses.replace(
            spec.serve, async_serve=True, max_wait_ms=max_wait_ms
        ),
    )


def test_async_service_crash_replay_bit_exact(tmp_path, rng):
    """The async durability gate: with the pump thread owning every WAL
    append + dispatch in ONE serialized order, a threaded async run's
    WAL must replay to a BIT-IDENTICAL index — window coalescing,
    deferred readbacks and idle maintenance slots may change batch
    timing, never logged content or order."""
    import jax
    import threading

    base = make_clustered(rng, 800, 16, n_clusters=6)
    spec = _async_spec(tmp_path / "svc", group_commit=8)
    svc = spfresh.open(spec, vectors=base)
    assert svc.engine.is_async

    errors: list[BaseException] = []

    def worker(tid: int) -> None:
        trng = np.random.default_rng(50 + tid)
        vecs = make_clustered(trng, 24, 16, n_clusters=2)
        ids = np.arange(3000 + 100 * tid, 3024 + 100 * tid, dtype=np.int32)
        try:
            for s in range(0, 24, 8):
                svc.insert(vecs[s : s + 8], ids[s : s + 8])
                svc.search(vecs[s : s + 4], k=5)
            svc.delete(ids[:4])
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [
        threading.Thread(target=worker, args=(t,), daemon=True)
        for t in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not any(t.is_alive() for t in threads), "async submitter hung"
    assert not errors, errors
    svc.flush()
    want = svc.search(base[:16], k=10)
    state = svc.index.state
    svc.engine.shutdown()      # stop the pump; no checkpoint, no close

    twin = spfresh.open(spec)  # crash: open-time snapshot + WAL replay
    assert twin.recovered
    for a, b in zip(
        jax.tree_util.tree_leaves(state),
        jax.tree_util.tree_leaves(twin.index.state),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    got = twin.search(base[:16], k=10)
    np.testing.assert_array_equal(want[1], got[1])
    np.testing.assert_allclose(want[0], got[0], rtol=1e-5)


def test_async_matches_sync_state_bit_exactly(rng):
    """Async mode must not change WHAT is dispatched, only WHERE it runs:
    the same single-threaded op sequence, flushed after every op (so
    batching and deferred maintenance slots land at the same positions),
    leaves bit-identical index state in both modes."""
    import jax

    base = make_clustered(rng, 600, 16, n_clusters=4)
    states = {}
    for mode in ("sync", "async"):
        spec = tiny_spec() if mode == "sync" else _async_spec(
            max_wait_ms=0.0)
        svc = spfresh.open(spec, vectors=base)
        srng = np.random.default_rng(7)
        vecs = make_clustered(srng, 48, 16, n_clusters=3)
        ids = np.arange(2000, 2048, dtype=np.int32)
        for s in range(0, 48, 8):
            svc.insert(vecs[s : s + 8], ids[s : s + 8])
            svc.flush()
            svc.search(vecs[s : s + 4], k=5)
            svc.flush()
        svc.delete(ids[:6])
        svc.flush()
        states[mode] = svc.index.state
        if mode == "async":
            svc.engine.shutdown()
    for a, b in zip(
        jax.tree_util.tree_leaves(states["sync"]),
        jax.tree_util.tree_leaves(states["async"]),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
