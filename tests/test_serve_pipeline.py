"""Serving pipeline unit + integration tests: queue bucketing/padding
invariants, MaintenancePolicy firing semantics, engine round-trips over
the local backend, and (slow, subprocess) a 2-shard stacked state."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.index import SPFreshIndex
from repro.data.vectors import make_shifting_stream, make_sift_like
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.policy import BacklogPolicy, RatioPolicy
from repro.serve.queue import RequestQueue, Ticket, default_buckets
from tests.test_lire import small_cfg


# ---------------------------------------------------------------------------
# RequestQueue: bucketing / padding / ordering invariants
# ---------------------------------------------------------------------------

def _ticket(op, n, key=()):
    return Ticket(op, n, key)


def _submit(q, op, n, key=(), tag=0.0):
    t = _ticket(op, n, key)
    if op == "search":
        arrays = {"queries": np.full((n, 4), tag, np.float32)}
    elif op == "insert":
        arrays = {"vecs": np.full((n, 4), tag, np.float32),
                  "vids": np.arange(n, dtype=np.int32)}
    else:
        arrays = {"vids": np.arange(n, dtype=np.int32)}
    return q.submit(t, arrays)


def test_default_buckets_ladder():
    assert default_buckets(8, 256) == (8, 16, 32, 64, 128, 256)
    assert default_buckets(8, 100) == (8, 16, 32, 64, 100)
    assert default_buckets(4, 4) == (4,)


def test_queue_pads_to_bucket_and_accounts_waste():
    q = RequestQueue(buckets=(8, 16, 32))
    _submit(q, "search", 11, key=(10, None))
    assert q.depth_rows == 11
    b = q.pop_batch()
    assert b.bucket == 16 and b.n_valid == 11
    assert b.arrays["queries"].shape == (16, 4)
    assert b.valid.sum() == 11
    # padding rows are zero-filled
    assert (b.arrays["queries"][11:] == 0).all()
    acc = q.accounting()
    assert acc["rows"] == 11 and acc["padded_rows"] == 5
    assert acc["padding_waste_frac"] == pytest.approx(5 / 16)
    assert q.depth_rows == 0


def test_queue_coalesces_contiguous_same_op_runs_only():
    q = RequestQueue(buckets=(8, 16, 32))
    _submit(q, "insert", 5, tag=1.0)
    _submit(q, "insert", 6, tag=2.0)
    _submit(q, "delete", 3)
    _submit(q, "insert", 4, tag=3.0)
    b1 = q.pop_batch()   # both head inserts coalesce: 11 rows -> bucket 16
    assert b1.op == "insert" and b1.n_valid == 11 and b1.bucket == 16
    assert (b1.arrays["vecs"][:5] == 1.0).all()
    assert (b1.arrays["vecs"][5:11] == 2.0).all()
    b2 = q.pop_batch()   # the delete fences the later insert (op order kept)
    assert b2.op == "delete" and b2.n_valid == 3
    b3 = q.pop_batch()
    assert b3.op == "insert" and b3.n_valid == 4
    assert q.pop_batch() is None


def test_queue_never_mixes_search_keys():
    q = RequestQueue(buckets=(8, 16))
    _submit(q, "search", 4, key=(10, None))
    _submit(q, "search", 4, key=(5, None))   # different k: separate batch
    b1, b2 = q.pop_batch(), q.pop_batch()
    assert b1.key == (10, None) and b1.n_valid == 4
    assert b2.key == (5, None) and b2.n_valid == 4


def test_queue_splits_oversized_requests_into_parts():
    q = RequestQueue(buckets=(8, 16))
    t = _submit(q, "delete", 40)             # 16 + 16 + 8
    sizes = []
    while (b := q.pop_batch()) is not None:
        sizes.append((b.n_valid, b.bucket))
        b.scatter({})
    assert sizes == [(16, 16), (16, 16), (8, 8)]
    assert t.done
    acc = q.accounting()
    assert acc["rows"] == 40 and acc["batches"] == 3


def test_queue_vid_padding_is_minus_one():
    q = RequestQueue(buckets=(8,))
    _submit(q, "delete", 3)
    b = q.pop_batch()
    assert (b.arrays["vids"][3:] == -1).all()


# ---------------------------------------------------------------------------
# MaintenancePolicy firing semantics
# ---------------------------------------------------------------------------

def test_ratio_policy_fires_every_n_foreground_batches():
    pol = RatioPolicy(ratio=3, budget=8)
    fired = []
    for _ in range(9):
        pol.note_foreground()
        fired.append(pol.want_maintenance(lambda: 99))
    assert fired == [False, False, True] * 3
    assert pol.budget == 8


def test_ratio_policy_zero_disables_maintenance():
    pol = RatioPolicy(ratio=0, budget=8)
    for _ in range(10):
        pol.note_foreground()
        assert not pol.want_maintenance(lambda: 99)


def test_ratio_policy_never_reads_backlog():
    pol = RatioPolicy(ratio=1, budget=4)

    def boom():
        raise AssertionError("ratio policy must not probe the backlog")

    pol.note_foreground()
    assert pol.want_maintenance(boom)


def test_backlog_policy_fires_iff_threshold_reached():
    pol = BacklogPolicy(threshold=2, budget=16)
    backlog = {"v": 0}
    pol.note_foreground()
    assert not pol.want_maintenance(lambda: backlog["v"])
    backlog["v"] = 1
    pol.note_foreground()
    assert not pol.want_maintenance(lambda: backlog["v"])
    backlog["v"] = 2
    pol.note_foreground()
    assert pol.want_maintenance(lambda: backlog["v"])


def test_backlog_policy_rate_limits_probes():
    pol = BacklogPolicy(threshold=1, budget=4, check_every=4)
    calls = {"n": 0}

    def probe():
        calls["n"] += 1
        return 5

    fired = 0
    for _ in range(8):
        pol.note_foreground()
        fired += bool(pol.want_maintenance(probe))
    assert calls["n"] == 2 and fired == 2


# ---------------------------------------------------------------------------
# Engine over the local backend
# ---------------------------------------------------------------------------

def test_engine_async_tickets_and_metrics(rng):
    base = make_sift_like(1500, 16, seed=9)
    idx = SPFreshIndex.build(small_cfg(), base)
    eng = ServeEngine(idx, EngineConfig(search_k=5, max_batch=64))

    t1 = eng.submit_search(base[:10])
    t2 = eng.submit_insert(make_shifting_stream(30, 16, seed=10),
                           np.arange(4000, 4030, dtype=np.int32))
    t3 = eng.submit_delete(np.arange(5, dtype=np.int32))
    assert not (t1.done or t2.done or t3.done)
    assert eng.queue.depth_rows == 45

    d, v = t1.result()              # pumps until t1 completes
    assert t1.done and d.shape == (10, 5)
    assert (v[:, 0] == np.arange(10)).all()

    ids, landed = t2.result()
    assert landed.all() and (ids == np.arange(4000, 4030)).all()
    assert t3.result() is None and t3.done

    rep = eng.report()
    assert rep["search"]["n"] == 1 and rep["insert"]["n"] == 1
    assert rep["queue"]["rows"] == 45
    assert rep["queue"]["depth_rows_now"] == 0
    assert rep["queue"]["padded_rows"] > 0   # 10->16, 30->32, 5->8


def test_engine_search_matches_direct_index(rng):
    base = make_sift_like(1200, 16, seed=11)
    idx = SPFreshIndex.build(small_cfg(), base)
    eng = ServeEngine(idx, EngineConfig(search_k=10))
    q = base[rng.integers(0, 1200, 40)]
    d_eng, v_eng = eng.search(q)
    d_ref, v_ref = idx.search(q, 10)
    np.testing.assert_allclose(d_eng, d_ref, rtol=1e-5)
    np.testing.assert_array_equal(v_eng, v_ref)


def test_engine_backlog_policy_keeps_postings_bounded(rng):
    base = make_sift_like(2000, 16, seed=5)
    idx = SPFreshIndex.build(small_cfg(), base)
    eng = ServeEngine(
        idx, EngineConfig(search_k=10),
        policy=BacklogPolicy(threshold=1, budget=16),
    )
    inserts = make_shifting_stream(600, 16, seed=6)
    ids = np.arange(5000, 5600, dtype=np.int32)
    for s in range(0, 600, 100):
        eng.insert(inserts[s:s + 100], ids[s:s + 100])
    eng.drain()
    assert idx.backlog() == 0
    lens = np.asarray(idx.state.pool.posting_len)
    valid = np.asarray(idx.state.centroid_valid)
    assert (lens[valid] <= idx.state.cfg.split_limit).all()
    rep = eng.report()
    assert rep["maintenance"]["policy"].startswith("backlog")
    assert rep["maintenance"]["steps"] > 0


def test_engine_ratio_off_accumulates_backlog_then_drains(rng):
    base = make_sift_like(2000, 16, seed=5)
    idx = SPFreshIndex.build(small_cfg(), base)
    eng = ServeEngine(idx, EngineConfig(fg_bg_ratio=0, max_insert_retries=0))
    inserts = make_shifting_stream(400, 16, seed=8)
    eng.insert(inserts, np.arange(6000, 6400, dtype=np.int32))
    assert eng.report()["maintenance"]["slots"] == 0
    eng.drain()
    assert idx.backlog() == 0


def test_engine_fused_maintenance_equivalent_to_drain(rng):
    base = make_sift_like(1500, 16, seed=13)
    idx = SPFreshIndex.build(small_cfg(), base)
    idx.insert(make_shifting_stream(300, 16, seed=14),
               np.arange(3000, 3300, dtype=np.int32))
    while idx.maintain_fused(8):
        pass
    assert idx.backlog() == 0
    lens = np.asarray(idx.state.pool.posting_len)
    valid = np.asarray(idx.state.centroid_valid)
    assert (lens[valid] <= idx.state.cfg.split_limit).all()


def test_engine_empty_requests_are_noops(rng):
    base = make_sift_like(800, 16, seed=15)
    idx = SPFreshIndex.build(small_cfg(), base)
    eng = ServeEngine(idx, EngineConfig(search_k=7))
    d, v = eng.submit_search(np.zeros((0, 16), np.float32)).result()
    assert d.shape == (0, 7) and v.shape == (0, 7)
    ids, landed = eng.submit_insert(
        np.zeros((0, 16), np.float32), np.zeros(0, np.int32)
    ).result()
    assert ids.shape == (0,) and landed.shape == (0,)
    assert eng.submit_delete(np.zeros(0, np.int32)).result() is None
    # sync facades too
    eng.delete(np.zeros(0, np.int32))
    assert eng.queue.accounting()["batches"] == 0


def test_engine_updates_reach_the_wal(rng, tmp_path):
    wal_path = str(tmp_path / "serve.wal")
    snap = str(tmp_path / "base.snap")
    base = make_sift_like(1000, 16, seed=16)
    idx = SPFreshIndex.build(small_cfg(), base, wal_path=wal_path)
    idx.snapshot(snap)
    eng = ServeEngine(idx, EngineConfig(search_k=5))
    fresh = make_shifting_stream(60, 16, seed=17)
    ids = np.arange(2000, 2060, dtype=np.int32)
    eng.insert(fresh, ids)
    eng.delete(ids[:10])
    eng.drain()
    # crash: rebuild from the pre-pipeline snapshot + WAL tail replay
    idx2 = SPFreshIndex.restore(snap, small_cfg(), wal_path=wal_path)
    _, got = idx2.search(fresh[10:20], 5)
    assert (got[:, 0] == ids[10:20]).all(), "WAL replay lost pipeline inserts"
    _, got_del = idx2.search(fresh[:10], 5)
    leaked = set(got_del.reshape(-1).tolist()) & set(ids[:10].tolist())
    assert not leaked, f"WAL replay resurrected deleted ids: {leaked}"


# ---------------------------------------------------------------------------
# Engine over a 2-shard stacked state (subprocess: fake 2-device mesh)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_over_two_shard_mesh():
    script = os.path.join(os.path.dirname(__file__), "serve_sharded_script.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, script],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0
    assert "ALL_SERVE_SHARDED_PASS" in proc.stdout
