"""Sharded-index behaviour on an 8-device fake mesh (subprocess so the
main test process keeps one device)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_distributed_index_suite():
    script = os.path.join(os.path.dirname(__file__), "distributed_script.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, script],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0
    assert "ALL_DISTRIBUTED_PASS" in proc.stdout
