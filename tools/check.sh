#!/usr/bin/env bash
# One local gate for builders: byte-compile, fast tier-1 tests, bench smoke.
#
#   tools/check.sh            # the full gate
#   tools/check.sh --fast     # skip the bench smoke
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compileall =="
python -m compileall -q src benchmarks examples tools

echo "== spflint (replay / lock / VMEM static invariants) =="
python -m repro.analysis src

echo "== kernel parity (Pallas interpret vs XLA oracles) =="
python -m pytest -q tests/test_kernels_posting_scan.py \
    tests/test_kernels_l2topk.py tests/test_search_pallas.py

echo "== maintenance round parity (batched rounds vs sequential LIRE) =="
python -m pytest -q tests/test_maintenance_round.py

echo "== service API crash-recovery parity (spfresh.open, local + 2-shard) =="
python -m pytest -q tests/test_service_api.py

echo "== maintenance policy ranking + telemetry conservation =="
python -m pytest -q tests/test_maintenance_policy.py

echo "== scenario gauntlet (tiny-N cells) =="
python -m pytest -q tests/test_scenario_gauntlet.py

echo "== posting codec (quant round-trip, dequant kernels, recall floor) =="
python -m pytest -q tests/test_codec.py

echo "== async serving (pump thread stress, window, reservoir, drops) =="
python -m pytest -q tests/test_serve_async.py

echo "== replication (routing/window units, parity + fallback + catch-up) =="
python -m pytest -q tests/test_replication.py

echo "== spflint self-test (seeded fixtures, coverage, VMEM parity) =="
python -m pytest -q tests/test_spflint.py

# The parity suites above carry ``pytestmark = pytest.mark.gate``; the
# tier-1 step excludes them BY MARKER, so adding a gated suite is one
# marker + one explicit step — the old hand-maintained --ignore list
# could silently double-run (marker forgotten) or un-run (step
# forgotten) a suite when the two drifted.
echo "== pytest (tier-1, -m 'not slow and not gate') =="
python -m pytest -q -m "not slow and not gate"

if [[ "${1:-}" != "--fast" ]]; then
  echo "== benchmarks dry smoke =="
  python -m benchmarks.run --dry
fi

echo "check.sh: ALL GREEN"
