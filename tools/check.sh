#!/usr/bin/env bash
# One local gate for builders: byte-compile, fast tier-1 tests, bench smoke.
#
#   tools/check.sh            # the full gate
#   tools/check.sh --fast     # skip the bench smoke
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compileall =="
python -m compileall -q src benchmarks examples tools

echo "== kernel parity (Pallas interpret vs XLA oracles) =="
python -m pytest -q tests/test_kernels_posting_scan.py \
    tests/test_kernels_l2topk.py tests/test_search_pallas.py

echo "== maintenance round parity (batched rounds vs sequential LIRE) =="
python -m pytest -q tests/test_maintenance_round.py

echo "== service API crash-recovery parity (spfresh.open, local + 2-shard) =="
python -m pytest -q tests/test_service_api.py

echo "== pytest (tier-1, -m 'not slow') =="
python -m pytest -q -m "not slow" \
    --ignore=tests/test_kernels_posting_scan.py \
    --ignore=tests/test_kernels_l2topk.py \
    --ignore=tests/test_search_pallas.py \
    --ignore=tests/test_maintenance_round.py \
    --ignore=tests/test_service_api.py

if [[ "${1:-}" != "--fast" ]]; then
  echo "== benchmarks dry smoke =="
  python -m benchmarks.run --dry
fi

echo "check.sh: ALL GREEN"
