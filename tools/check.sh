#!/usr/bin/env bash
# One local gate for builders: byte-compile, fast tier-1 tests, bench smoke.
#
#   tools/check.sh            # the full gate
#   tools/check.sh --fast     # skip the bench smoke
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compileall =="
python -m compileall -q src benchmarks examples tools

echo "== pytest (tier-1, -m 'not slow') =="
python -m pytest -q -m "not slow"

if [[ "${1:-}" != "--fast" ]]; then
  echo "== benchmarks dry smoke =="
  python -m benchmarks.run --dry
fi

echo "check.sh: ALL GREEN"
