#!/usr/bin/env python
"""bench_diff — the bench-regression bot behind the nightly gate.

Compares freshly regenerated ``BENCH_*.json`` reports against the
checked-in baselines with per-metric tolerances, enforces each report's
absolute invariants (the contracts that used to live as inline ``python
- <<EOF`` steps in the workflow), renders one markdown table into
``$GITHUB_STEP_SUMMARY`` (and stdout), and exits nonzero on any
regression or violated invariant.

    python tools/bench_diff.py --new-dir out BENCH_serve.json ...
    python tools/bench_diff.py --new-dir out --all

Tolerances by metric kind:

* ``latency``  — regress if new > baseline × (1 + 20%)
* ``bytes``    — regress if new > baseline × (1 + 10%)   (modeled scan
  traffic: deterministic, so the slack only absorbs workload-size drift)
* ``recall``   — regress if new < baseline − 0.01        (absolute)
* ``info``     — reported, never gated (e.g. single-core open-loop tails
  in BENCH_replicas.json, which are bistable run-to-run by design — see
  the report's ``read_scaling_basis`` field)

A missing baseline file or metric path is reported and tolerated (new
benchmarks land before their first baseline); a missing NEW report is an
error — the step that should have generated it failed.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# --------------------------------------------------------------------------
# Per-report specification
# --------------------------------------------------------------------------

TOL = {"latency": 0.20, "bytes": 0.10, "recall": 0.01}

# (dotted path, kind) — kind keys TOL; "info" rows are never gated.
METRICS: dict[str, list[tuple[str, str]]] = {
    "BENCH_serve.json": [
        ("summary.sync_search_p99_ms", "latency"),
        ("summary.async_search_p99_ms", "latency"),
        ("summary.search_p99_reduction_x", "info"),
        ("summary.async_overlap_frac", "info"),
    ],
    "BENCH_search.json": [
        ("paths.pallas_per_query.p99_ms", "latency"),
        ("paths.pallas_batched.p99_ms", "latency"),
        ("codecs.fp32.scan_bytes_per_query", "bytes"),
        ("codecs.bf16.scan_bytes_per_query", "bytes"),
        ("codecs.int8.scan_bytes_per_query", "bytes"),
        ("codecs.fp32.recall_at_k", "recall"),
        ("codecs.bf16.recall_at_k", "recall"),
        ("codecs.int8.recall_at_k", "recall"),
    ],
    "BENCH_scenarios.json": [
        ("scenarios.shift.drift_minus_size", "info"),
    ],
    "BENCH_recovery.json": [
        ("recovery.replayed_rows_s", "info"),
        ("snapshot.write_mb_s", "info"),
        ("group_commit.fsync_reduction", "info"),
    ],
    "BENCH_update.json": [],
    "BENCH_replicas.json": [
        # Measured open-loop tails on the 1-core CI box are bistable —
        # report, never gate (the gated numbers are the invariants below).
        ("summary.p99_ms_1r", "info"),
        ("summary.p99_ms_2r", "info"),
        ("summary.goodput_ratio_2r_measured", "info"),
    ],
}

# Absolute contracts, independent of any baseline.  Each entry:
# (label, dotted path, op, bound).  op: ">=" / "<=" / "is_true", or
# "<=path:" compare against another path in the same report.
INVARIANTS: dict[str, list[tuple[str, str, str, object]]] = {
    "BENCH_serve.json": [
        ("async search p99 beats sync at the reference load",
         "summary.async_search_p99_ms", "<=path",
         "summary.sync_search_p99_ms"),
        ("async leaves less rebuilder time inline than sync",
         "summary.async_maint_inline_s", "<=path",
         "summary.sync_maint_inline_s"),
    ],
    "BENCH_search.json": [
        ("int8 scan traffic <= 0.30x fp32",
         "codecs.int8.scan_bytes_per_query", "<=ratio",
         ("codecs.fp32.scan_bytes_per_query", 0.30)),
        ("bf16 scan-bytes saving >= 1.9x",
         "codecs.bf16.scan_bytes_saving_vs_fp32", ">=", 1.9),
        ("int8+rerank recall within 1% of fp32",
         "codecs.int8.recall_delta_vs_fp32", ">=", -0.01),
        ("bf16+rerank recall within 1% of fp32",
         "codecs.bf16.recall_delta_vs_fp32", ">=", -0.01),
    ],
    "BENCH_scenarios.json": [
        ("drift-aware policy >= size-only at equal budget",
         "scenarios.shift.drift_minus_size", ">=", 0.0),
        ("churn conserves the live set",
         "scenarios.churn.summary.live_set_conserved", "is_true", True),
    ],
    "BENCH_replicas.json": [
        ("read throughput scaling >= 1.6x at 2 replicas (modeled)",
         "summary.read_scaling_2r", ">=", 1.6),
        ("write-ack overhead with replication on <= 15%",
         "summary.ack_overhead_frac", "<=", 0.15),
        ("replica bit-identical to primary at equal seqno",
         "summary.bit_identical_at_equal_seqno", "is_true", True),
    ],
}

ALL_REPORTS = sorted(set(METRICS) | set(INVARIANTS))


# --------------------------------------------------------------------------
# Mechanics
# --------------------------------------------------------------------------

def get_path(report: dict, dotted: str):
    cur = report
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def fmt(v) -> str:
    if v is None:
        return "—"
    if isinstance(v, bool):
        return str(v).lower()
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def diff_metric(kind: str, base, new) -> tuple[str, bool]:
    """(status, failed) for one metric row."""
    if new is None:
        return "missing-new", True
    if base is None:
        return "no-baseline", False
    if kind == "info":
        return "info", False
    if kind == "recall":
        ok = new >= base - TOL["recall"]
        return ("ok" if ok else f"regressed (> −{TOL['recall']})", not ok)
    tol = TOL[kind]
    if base == 0:
        ok = new <= 0
    else:
        ok = new <= base * (1.0 + tol)
    return ("ok" if ok else f"regressed (> +{tol:.0%})", not ok)


def check_invariant(report: dict, label, path, op, bound):
    val = get_path(report, path)
    if val is None:
        return label, None, f"{op} {bound}", True   # missing value = fail
    if op == ">=":
        ok, btxt = val >= bound, f">= {fmt(bound)}"
    elif op == "<=":
        ok, btxt = val <= bound, f"<= {fmt(bound)}"
    elif op == "is_true":
        ok, btxt = bool(val) is True, "== true"
    elif op == "<=path":
        other = get_path(report, bound)
        ok = other is not None and val <= other
        btxt = f"<= {bound.split('.')[-1]} ({fmt(other)})"
    elif op == "<=ratio":
        other_path, ratio = bound
        other = get_path(report, other_path)
        ok = other is not None and val <= other * ratio
        btxt = f"<= {ratio}x {other_path.split('.')[-1]}"
    else:  # pragma: no cover - spec typo guard
        raise ValueError(op)
    return label, val, btxt, not ok


def run(names: list[str], new_dir: str, baseline_dir: str) -> tuple[str, int]:
    lines = ["# Bench regression report", ""]
    failures = 0

    m_rows, i_rows = [], []
    for name in names:
        new_path = os.path.join(new_dir, name)
        base_path = os.path.join(baseline_dir, name)
        if not os.path.exists(new_path):
            m_rows.append((name, "(report)", "—", "—", "missing-new", True))
            failures += 1
            continue
        with open(new_path) as f:
            new_rep = json.load(f)
        base_rep = None
        if os.path.exists(base_path):
            with open(base_path) as f:
                base_rep = json.load(f)

        for dotted, kind in METRICS.get(name, []):
            new_v = get_path(new_rep, dotted)
            base_v = get_path(base_rep, dotted) if base_rep else None
            status, failed = diff_metric(kind, base_v, new_v)
            m_rows.append((name, f"{dotted} [{kind}]",
                           fmt(base_v), fmt(new_v), status, failed))
            failures += failed

        for label, path, op, bound in INVARIANTS.get(name, []):
            label, val, btxt, failed = check_invariant(
                new_rep, label, path, op, bound)
            i_rows.append((name, label, fmt(val), btxt, failed))
            failures += failed

    if m_rows:
        lines += ["## Metrics vs checked-in baselines", "",
                  "| report | metric | baseline | new | status |",
                  "|---|---|---|---|---|"]
        for name, metric, b, n, status, failed in m_rows:
            mark = "❌" if failed else ("➖" if status != "ok" else "✅")
            lines.append(f"| {name} | {metric} | {b} | {n} "
                         f"| {mark} {status} |")
        lines.append("")
    if i_rows:
        lines += ["## Invariants (absolute contracts)", "",
                  "| report | invariant | value | bound | status |",
                  "|---|---|---|---|---|"]
        for name, label, val, btxt, failed in i_rows:
            mark = "❌ FAIL" if failed else "✅ ok"
            lines.append(f"| {name} | {label} | {val} | {btxt} | {mark} |")
        lines.append("")
    lines.append(f"**{failures} failure(s)** across {len(names)} report(s).")
    return "\n".join(lines), failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("reports", nargs="*",
                    help="report basenames, e.g. BENCH_serve.json")
    ap.add_argument("--all", action="store_true",
                    help="diff every report bench_diff knows about")
    ap.add_argument("--new-dir", default="out",
                    help="directory holding the regenerated reports")
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the checked-in baselines")
    args = ap.parse_args(argv)
    names = list(args.reports)
    if args.all:
        names += [n for n in ALL_REPORTS if n not in names]
    if not names:
        ap.error("no reports given (pass basenames or --all)")
    unknown = [n for n in names if n not in ALL_REPORTS]
    if unknown:
        ap.error(f"no metric/invariant spec for {unknown}; "
                 f"known: {ALL_REPORTS}")

    table, failures = run(names, args.new_dir, args.baseline_dir)
    print(table)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(table + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
