import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "src")
import dataclasses
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.granite_20b import CONFIG
from repro.distributed import sharding as sr
from repro.models import transformer as tf
from repro.train.optimizer import adamw_init
from repro.launch.mesh import make_production_mesh

n_layers = int(sys.argv[1]) if len(sys.argv) > 1 else 2
kind = sys.argv[2] if len(sys.argv) > 2 else "train"
cfg = dataclasses.replace(CONFIG, n_layers=n_layers, scan_unroll=n_layers)
mesh = make_production_mesh(multi_pod=False)

def to_sh(tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))

with jax.set_mesh(mesh):
    if kind == "train":
        from repro.train.optimizer import make_train_step
        from repro.configs.common import OPT
        step = make_train_step(lambda p, b: tf.loss_fn(p, b, cfg), OPT)
        p = tf.param_specs(cfg)
        o = jax.eval_shape(adamw_init, p)
        b = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
             "labels": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
        ps = sr.lm_param_specs(cfg)
        ins = to_sh((ps, sr.opt_state_specs(ps), sr.lm_batch_specs("train")))
        lowered = jax.jit(step, in_shardings=ins).lower(p, o, b)
    else:
        p = tf.param_specs(cfg)
        cache = jax.eval_shape(lambda: tf.init_cache(cfg, 128, 32768))
        ins = to_sh((sr.lm_param_specs(cfg), sr.lm_cache_specs(False), P(("data",)), P()))
        outs = to_sh((P(("data",), "model"), sr.lm_cache_specs(False)))
        lowered = jax.jit(
            lambda pp, cc, tt, po: tf.decode_step(pp, cc, tt, po, cfg),
            in_shardings=ins, out_shardings=outs,
        ).lower(p, cache, jax.ShapeDtypeStruct((128,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    print("temp GB:", ma.temp_size_in_bytes / 1e9,
          "arg GB:", ma.argument_size_in_bytes / 1e9)
    # find the biggest buffers via buffer assignment dump in HLO text
    txt = compiled.as_text()
    import re
    sizes = {}
    for m in re.finditer(r"(bf16|f32)\[([0-9,]+)\]", txt):
        dims = [int(x) for x in m.group(2).split(",")]
        nbytes = (2 if m.group(1) == "bf16" else 4)
        for d in dims:
            nbytes *= d
        key = f"{m.group(1)}[{m.group(2)}]"
        sizes[key] = (nbytes, sizes.get(key, (0, 0))[1] + 1)
    top = sorted(sizes.items(), key=lambda kv: -kv[1][0])[:12]
    for k, (nb, cnt) in top:
        print(f"  {k:48s} {nb/1e9:8.2f} GB x{cnt}")
