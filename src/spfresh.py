"""``import spfresh`` — the stable top-level namespace of the repo.

    import spfresh

    spec = spfresh.ServiceSpec(
        index=spfresh.IndexSpec(config=my_lire_config),
        durability=spfresh.DurabilitySpec(root="/data/svc"),
    )
    svc = spfresh.open(spec, vectors=base)      # build (+ open-time snapshot)
    svc.insert(new_vecs, new_ids)
    svc.checkpoint()
    svc.close()

    svc = spfresh.open(spec)                    # crash recovery: snapshot +
                                                # per-shard WAL replay

Everything here re-exports :mod:`repro.api`; the implementation modules
(`repro.core`, `repro.serve`, `repro.distributed`, `repro.storage`)
remain importable directly.
"""
from repro.api import (  # noqa: F401
    DurabilitySpec,
    IndexSpec,
    MaintenanceSpec,
    ScanSpec,
    ServeSpec,
    Service,
    ServiceSpec,
    ShardSpec,
    open,
)

__all__ = [
    "DurabilitySpec", "IndexSpec", "MaintenanceSpec", "ScanSpec",
    "ServeSpec", "Service", "ServiceSpec", "ShardSpec", "open",
]
