"""Fused L2-distance + per-tile k-min Pallas kernel (centroid navigation).

Computes ``d(q, c) = ||q||^2 - 2 q·c + ||c||^2`` for a (query-tile ×
centroid-tile) block on the MXU, then extracts the k smallest per query row
with an unrolled min/mask loop on the VPU, writing a per-tile candidate set.
The caller merges per-tile candidates with one final ``lax.top_k`` — a
two-stage tournament that never materializes the full (Q, P) distance matrix
in HBM (for P ~ 1e7 centroids per shard that matrix would be >GBs).

Masking: invalid centroids are encoded by the caller as ``c_sqn = +BIG`` so
no separate mask operand is needed in VMEM.

Tiling: queries (BQ, d), centroids (BP, d), ``d`` contracted in full (vector
dims ≤ a few hundred — fits VMEM comfortably: BQ=128, BP=512, d=128 f32 →
64 KB + 256 KB tiles).  MXU dims: (BQ×d)·(d×BP), all multiples of 128 when
padded by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Plain Python float: a jnp scalar would be a captured traced constant,
# which pallas_call rejects.
BIG = 3.0e38


def _l2_topk_kernel(q_ref, c_ref, csq_ref, out_d_ref, out_i_ref, *, k: int,
                    block_p: int):
    pi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)           # (BQ, d)
    c = c_ref[...].astype(jnp.float32)           # (BP, d)
    csq = csq_ref[0, :]                          # (BP,) f32 (BIG if invalid)

    qsq = jnp.sum(q * q, axis=1, keepdims=True)  # (BQ, 1)
    cross = jax.lax.dot_general(
        q, c, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (BQ, BP)
    d = qsq - 2.0 * cross + csq[None, :]

    bq = d.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, block_p), 1)
    # Unrolled k-min extraction (k is small: nprobe candidates per tile).
    for j in range(k):
        m = jnp.min(d, axis=1)
        a = jnp.argmin(d, axis=1).astype(jnp.int32)
        out_d_ref[:, j] = m
        out_i_ref[:, j] = a + pi * block_p
        d = jnp.where(col == a[:, None], BIG, d)


@functools.partial(
    jax.jit,
    static_argnames=("k", "block_q", "block_p", "interpret"),
)
def l2_topk_tiles(
    queries: jax.Array,   # (Q, d) — Q multiple of block_q
    centroids: jax.Array,  # (P, d) — P multiple of block_p
    c_sqn: jax.Array,      # (1, P) f32, +BIG on invalid/padded centroids
    *,
    k: int,
    block_q: int = 128,
    block_p: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-tile candidates: ``(dists (Q, T*k), indices (Q, T*k))`` where
    T = P/block_p.  Final global top-k is done by the caller."""
    q_n, dim = queries.shape
    p_n = centroids.shape[0]
    assert q_n % block_q == 0 and p_n % block_p == 0, (q_n, p_n)
    t = p_n // block_p

    kernel = functools.partial(_l2_topk_kernel, k=k, block_p=block_p)
    out_d, out_i = pl.pallas_call(
        kernel,
        grid=(q_n // block_q, t),
        in_specs=[
            pl.BlockSpec((block_q, dim), lambda qi, pi: (qi, 0)),
            pl.BlockSpec((block_p, dim), lambda qi, pi: (pi, 0)),
            pl.BlockSpec((1, block_p), lambda qi, pi: (0, pi)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, pi: (qi, pi)),
            pl.BlockSpec((block_q, k), lambda qi, pi: (qi, pi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_n, t * k), jnp.float32),
            jax.ShapeDtypeStruct((q_n, t * k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, centroids, c_sqn)
    return out_d, out_i
