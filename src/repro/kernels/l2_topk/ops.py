"""Public jit'd wrapper for the l2_topk kernel: padding, masking, final merge."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.l2_topk.kernel import BIG, l2_topk_tiles


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_p", "interpret")
)
def l2_topk(
    queries: jax.Array,    # (Q, d)
    centroids: jax.Array,  # (P, d)
    valid: jax.Array,      # (P,) bool
    *,
    k: int,
    block_q: int = 128,
    block_p: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Masked k-nearest centroids: ``(dists (Q,k), idx (Q,k))``.

    Two-stage tournament: per-tile k-min in the Pallas kernel, then one
    ``lax.top_k`` over the T*k survivors.  Correct because the global top-k
    is a subset of the union of per-tile top-k sets.
    """
    q_n, dim = queries.shape
    p_n = centroids.shape[0]
    block_q = min(block_q, _round_up(q_n, 8))
    block_p = min(block_p, _round_up(p_n, 128))
    qp = _round_up(q_n, block_q)
    pp = _round_up(p_n, block_p)
    k_tile = min(k, block_p)

    qpad = jnp.pad(queries, ((0, qp - q_n), (0, 0)))
    cpad = jnp.pad(centroids, ((0, pp - p_n), (0, 0)))
    csq = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1)
    csq = jnp.where(valid, csq, BIG)
    csq = jnp.pad(csq, (0, pp - p_n), constant_values=BIG)[None, :]

    tile_d, tile_i = l2_topk_tiles(
        qpad, cpad, csq, k=k_tile, block_q=block_q, block_p=block_p,
        interpret=interpret,
    )
    # Final merge over per-tile candidates.
    neg, sel = jax.lax.top_k(-tile_d, k)
    dists = -neg
    idx = jnp.take_along_axis(tile_i, sel, axis=1)
    idx = jnp.where(dists < BIG / 2, idx, -1)
    dists = jnp.maximum(dists, 0.0)
    return dists[:q_n], idx[:q_n]
