"""Pure-jnp oracle for the l2_topk kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

BIG = jnp.float32(3.0e38)


@functools.partial(jax.jit, static_argnames=("k",))
def l2_topk_ref(
    queries: jax.Array,
    centroids: jax.Array,
    valid: jax.Array,
    *,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Exact masked top-k smallest distances: ``(dists (Q,k), idx (Q,k))``."""
    q = queries.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    qsq = jnp.sum(q * q, axis=1, keepdims=True)
    csq = jnp.sum(c * c, axis=1)
    d = qsq - 2.0 * (q @ c.T) + csq[None, :]
    d = jnp.maximum(d, 0.0)
    d = jnp.where(valid[None, :], d, BIG)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx
