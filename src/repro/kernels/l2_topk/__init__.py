from repro.kernels.l2_topk.ops import l2_topk  # noqa: F401
from repro.kernels.l2_topk.ref import l2_topk_ref  # noqa: F401
