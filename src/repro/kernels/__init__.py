"""Pallas TPU kernels for the paper's compute hot spots.

* ``l2_topk``      — fused squared-L2 distance + per-tile k-min reduction for
                     centroid navigation (the SPTAG-graph replacement).
* ``posting_scan`` — paged posting scan with block-table indirection (the
                     ParallelGET + distance scan fused, paged-attention style).

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd public wrapper with padding/masking), and ``ref.py`` (pure-jnp
oracle).  Kernels target TPU; tests validate them in ``interpret=True`` mode
on CPU against the oracles across shape/dtype sweeps.
"""
