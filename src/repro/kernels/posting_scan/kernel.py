"""Paged posting-scan Pallas kernels (block-table indirection).

Two variants of the same hot loop — compute query↔vector distances for
vectors that live in SSD-block-sized pages of the BlockPool, addressed
through a block table (exactly the paged-attention KV indirection):

* ``scan_kernel_per_query`` — the paper-faithful ParallelGET schedule: each
  grid step streams one page of one query's probed posting from HBM to VMEM
  and emits that query's distances.  HBM traffic = Q * nprobe * page bytes.

* ``scan_kernel_batched`` — beyond-paper batch-dedup schedule: the caller
  dedups the pages probed by the *whole query batch*; each unique page is
  streamed ONCE and scored against all Q queries with one (Q×d)·(d×BS) MXU
  GEMM.  HBM traffic divides by the average probe multiplicity.

Both use ``PrefetchScalarGridSpec`` so the block table is available to the
BlockSpec index_map (the indirection happens in the DMA engine, not in the
kernel body).

Each variant also has a ``*_topk`` form that fuses the per-page reduce: the
kernel takes a per-slot distance bias (0 live / +BIG dead — absent page,
empty slot, stale version, deletion) and emits only the ``k`` smallest
candidates of each (page, query) tile with an unrolled min/mask loop, the
same VPU idiom as ``l2_topk``.  The caller's merge works over
``(Q, NB·k)`` candidates instead of the full ``(Q, NB·BS)`` distance
matrix, which is what lets the search hot path stream pages without ever
materializing the distance tiles in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_per_query_kernel(table_ref, q_ref, blk_ref, out_ref):
    # q_ref: (1, d); blk_ref: (1, BS, d); out: (1, 1, BS)
    q = q_ref[0, :].astype(jnp.float32)
    b = blk_ref[0].astype(jnp.float32)            # (BS, d)
    bsq = jnp.sum(b * b, axis=1)                  # (BS,)
    cross = jnp.dot(b, q, preferred_element_type=jnp.float32)  # (BS,)
    qsq = jnp.sum(q * q)
    out_ref[0, 0, :] = jnp.maximum(qsq - 2.0 * cross + bsq, 0.0)


@functools.partial(
    jax.jit, static_argnames=("interpret",)
)
def scan_per_query(
    block_table: jax.Array,  # (Q, NB) i32 — block pool indices (clamped >=0)
    queries: jax.Array,      # (Q, d)
    blocks: jax.Array,       # (B, BS, d) — the block pool payload
    *,
    interpret: bool = False,
) -> jax.Array:
    """Distances (Q, NB, BS): page j of query q scored against query q."""
    q_n, nb = block_table.shape
    _, bs, dim = blocks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q_n, nb),
        in_specs=[
            pl.BlockSpec((1, dim), lambda q, j, table: (q, 0)),
            pl.BlockSpec((1, bs, dim), lambda q, j, table: (table[q, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bs), lambda q, j, table: (q, j, 0)),
    )
    return pl.pallas_call(
        _scan_per_query_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q_n, nb, bs), jnp.float32),
        interpret=interpret,
    )(block_table, queries, blocks)


def _scan_batched_kernel(ids_ref, q_ref, blk_ref, out_ref):
    # q_ref: (Q, d) resident; blk_ref: (1, BS, d); out: (1, Q, BS)
    q = q_ref[...].astype(jnp.float32)            # (Q, d)
    b = blk_ref[0].astype(jnp.float32)            # (BS, d)
    qsq = jnp.sum(q * q, axis=1, keepdims=True)   # (Q, 1)
    bsq = jnp.sum(b * b, axis=1)                  # (BS,)
    cross = jax.lax.dot_general(
        q, b, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (Q, BS)
    out_ref[0] = jnp.maximum(qsq - 2.0 * cross + bsq[None, :], 0.0)


@functools.partial(
    jax.jit, static_argnames=("interpret",)
)
def scan_batched(
    unique_blocks: jax.Array,  # (NB,) i32 unique block pool indices
    queries: jax.Array,        # (Q, d)
    blocks: jax.Array,         # (B, BS, d)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Distances (NB, Q, BS): each unique page scored against ALL queries."""
    nb = unique_blocks.shape[0]
    q_n, dim = queries.shape
    _, bs, _ = blocks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((q_n, dim), lambda i, ids: (0, 0)),
            pl.BlockSpec((1, bs, dim), lambda i, ids: (ids[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_n, bs), lambda i, ids: (i, 0, 0)),
    )
    return pl.pallas_call(
        _scan_batched_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, q_n, bs), jnp.float32),
        interpret=interpret,
    )(unique_blocks, queries, blocks)


# ---------------------------------------------------------------------------
# Fused per-page top-k variants (streaming running-top-k reduce)
# ---------------------------------------------------------------------------

# Plain Python float: a jnp scalar would be a captured traced constant,
# which pallas_call rejects (same trick as l2_topk).
BIG = 3.0e38


def _kmin_rows(d, *, k: int):
    """Unrolled k-min per row of ``d (rows, cols)``: the l2_topk min/mask
    loop.  Returns ``(dists (rows, k), argmins (rows, k))``."""
    rows, cols = d.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1)
    ms, as_ = [], []
    for _ in range(k):
        m = jnp.min(d, axis=1)
        a = jnp.argmin(d, axis=1).astype(jnp.int32)
        ms.append(m)
        as_.append(a)
        d = jnp.where(col == a[:, None], BIG, d)
    return jnp.stack(ms, axis=1), jnp.stack(as_, axis=1)


def _scan_per_query_topk_kernel(
    table_ref, q_ref, blk_ref, bias_ref, out_d_ref, out_i_ref, *, k: int
):
    # q_ref: (1, d); blk_ref: (1, BS, d); bias_ref: (1, 1, BS) f32 (0 live,
    # +BIG dead); out: (1, 1, k) dists + slot indices within the page.
    q = q_ref[0, :].astype(jnp.float32)
    b = blk_ref[0].astype(jnp.float32)            # (BS, d)
    bsq = jnp.sum(b * b, axis=1)                  # (BS,)
    cross = jnp.dot(b, q, preferred_element_type=jnp.float32)  # (BS,)
    qsq = jnp.sum(q * q)
    d = jnp.maximum(qsq - 2.0 * cross + bsq, 0.0) + bias_ref[0, 0, :]
    kd, ki = _kmin_rows(d[None, :], k=k)          # (1, k)
    out_d_ref[0] = kd
    out_i_ref[0] = ki


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def scan_per_query_topk(
    block_table: jax.Array,  # (Q, NB) i32 — block pool indices (clamped >=0)
    queries: jax.Array,      # (Q, d)
    blocks: jax.Array,       # (B, BS, d)
    slot_bias: jax.Array,    # (Q, NB, BS) f32 — 0 live, +BIG dead
    *,
    k: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-query paged scan with fused per-page k-min.

    Returns ``(dists (Q, NB, k), slots (Q, NB, k))`` where ``slots`` index
    into the page (0..BS); dead candidates carry dist >= BIG."""
    q_n, nb = block_table.shape
    _, bs, dim = blocks.shape
    assert k <= bs, (k, bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q_n, nb),
        in_specs=[
            pl.BlockSpec((1, dim), lambda q, j, table: (q, 0)),
            pl.BlockSpec((1, bs, dim), lambda q, j, table: (table[q, j], 0, 0)),
            pl.BlockSpec((1, 1, bs), lambda q, j, table: (q, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, k), lambda q, j, table: (q, j, 0)),
            pl.BlockSpec((1, 1, k), lambda q, j, table: (q, j, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_scan_per_query_topk_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q_n, nb, k), jnp.float32),
            jax.ShapeDtypeStruct((q_n, nb, k), jnp.int32),
        ],
        interpret=interpret,
    )(block_table, queries, blocks, slot_bias)


def _scan_per_query_topk_q8_kernel(
    table_ref, q_ref, blk_ref, bias_ref, sz_ref, out_d_ref, out_i_ref, *, k: int
):
    # Dequant-fused variant: blk_ref holds int8 codes; sz_ref (1, 1, 2)
    # carries the page's posting [scale, zero], riding the block-table DMA
    # exactly like the liveness bias — the page streams at 1 byte/dim and
    # is reconstructed on the VPU before the distance math.
    q = q_ref[0, :].astype(jnp.float32)
    scale = sz_ref[0, 0, 0]
    zero = sz_ref[0, 0, 1]
    b = blk_ref[0].astype(jnp.float32) * scale + zero   # (BS, d) dequant
    bsq = jnp.sum(b * b, axis=1)                  # (BS,)
    cross = jnp.dot(b, q, preferred_element_type=jnp.float32)  # (BS,)
    qsq = jnp.sum(q * q)
    d = jnp.maximum(qsq - 2.0 * cross + bsq, 0.0) + bias_ref[0, 0, :]
    kd, ki = _kmin_rows(d[None, :], k=k)          # (1, k)
    out_d_ref[0] = kd
    out_i_ref[0] = ki


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def scan_per_query_topk_q8(
    block_table: jax.Array,  # (Q, NB) i32 — block pool indices (clamped >=0)
    queries: jax.Array,      # (Q, d)
    blocks: jax.Array,       # (B, BS, d) int8 codes
    slot_bias: jax.Array,    # (Q, NB, BS) f32 — 0 live, +BIG dead
    page_sz: jax.Array,      # (Q, NB, 2) f32 — per-page [scale, zero]
    *,
    k: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-query paged scan over int8 codes with in-kernel dequant.

    Same contract as `scan_per_query_topk`; distances are computed on the
    reconstructed ``code * scale + zero`` values."""
    q_n, nb = block_table.shape
    _, bs, dim = blocks.shape
    assert k <= bs, (k, bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q_n, nb),
        in_specs=[
            pl.BlockSpec((1, dim), lambda q, j, table: (q, 0)),
            pl.BlockSpec((1, bs, dim), lambda q, j, table: (table[q, j], 0, 0)),
            pl.BlockSpec((1, 1, bs), lambda q, j, table: (q, j, 0)),
            pl.BlockSpec((1, 1, 2), lambda q, j, table: (q, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, k), lambda q, j, table: (q, j, 0)),
            pl.BlockSpec((1, 1, k), lambda q, j, table: (q, j, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_scan_per_query_topk_q8_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q_n, nb, k), jnp.float32),
            jax.ShapeDtypeStruct((q_n, nb, k), jnp.int32),
        ],
        interpret=interpret,
    )(block_table, queries, blocks, slot_bias, page_sz)


def _scan_batched_topk_kernel(
    ids_ref, q_ref, blk_ref, bias_ref, out_d_ref, out_i_ref, *, k: int
):
    # q_ref: (Q, d) resident; blk_ref: (1, BS, d); bias_ref: (1, BS);
    # out: (1, Q, k) dists + slot indices.
    q = q_ref[...].astype(jnp.float32)            # (Q, d)
    b = blk_ref[0].astype(jnp.float32)            # (BS, d)
    qsq = jnp.sum(q * q, axis=1, keepdims=True)   # (Q, 1)
    bsq = jnp.sum(b * b, axis=1)                  # (BS,)
    cross = jax.lax.dot_general(
        q, b, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (Q, BS)
    d = jnp.maximum(qsq - 2.0 * cross + bsq[None, :], 0.0)
    d = d + bias_ref[0, :][None, :]
    kd, ki = _kmin_rows(d, k=k)                   # (Q, k)
    out_d_ref[0] = kd
    out_i_ref[0] = ki


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def scan_batched_topk(
    unique_blocks: jax.Array,  # (NB,) i32 unique block pool indices (>=0)
    queries: jax.Array,        # (Q, d)
    blocks: jax.Array,         # (B, BS, d)
    slot_bias: jax.Array,      # (NB, BS) f32 — 0 live, +BIG dead
    *,
    k: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Batch-dedup paged scan with fused per-(page, query) k-min.

    Returns ``(dists (NB, Q, k), slots (NB, Q, k))``."""
    nb = unique_blocks.shape[0]
    q_n, dim = queries.shape
    _, bs, _ = blocks.shape
    assert k <= bs, (k, bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((q_n, dim), lambda i, ids: (0, 0)),
            pl.BlockSpec((1, bs, dim), lambda i, ids: (ids[i], 0, 0)),
            pl.BlockSpec((1, bs), lambda i, ids: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_n, k), lambda i, ids: (i, 0, 0)),
            pl.BlockSpec((1, q_n, k), lambda i, ids: (i, 0, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_scan_batched_topk_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nb, q_n, k), jnp.float32),
            jax.ShapeDtypeStruct((nb, q_n, k), jnp.int32),
        ],
        interpret=interpret,
    )(unique_blocks, queries, blocks, slot_bias)


def _scan_batched_topk_q8_kernel(
    ids_ref, q_ref, blk_ref, bias_ref, sz_ref, out_d_ref, out_i_ref, *, k: int
):
    # Batched dequant-fused variant: sz_ref (1, 2) carries the unique
    # page's [scale, zero] (one posting owns each block, so the page has a
    # single parameter pair no matter how many queries probe it).
    q = q_ref[...].astype(jnp.float32)            # (Q, d)
    scale = sz_ref[0, 0]
    zero = sz_ref[0, 1]
    b = blk_ref[0].astype(jnp.float32) * scale + zero   # (BS, d) dequant
    qsq = jnp.sum(q * q, axis=1, keepdims=True)   # (Q, 1)
    bsq = jnp.sum(b * b, axis=1)                  # (BS,)
    cross = jax.lax.dot_general(
        q, b, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (Q, BS)
    d = jnp.maximum(qsq - 2.0 * cross + bsq[None, :], 0.0)
    d = d + bias_ref[0, :][None, :]
    kd, ki = _kmin_rows(d, k=k)                   # (Q, k)
    out_d_ref[0] = kd
    out_i_ref[0] = ki


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def scan_batched_topk_q8(
    unique_blocks: jax.Array,  # (NB,) i32 unique block pool indices (>=0)
    queries: jax.Array,        # (Q, d)
    blocks: jax.Array,         # (B, BS, d) int8 codes
    slot_bias: jax.Array,      # (NB, BS) f32 — 0 live, +BIG dead
    page_sz: jax.Array,        # (NB, 2) f32 — per-page [scale, zero]
    *,
    k: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Batch-dedup paged scan over int8 codes with in-kernel dequant.

    Same contract as `scan_batched_topk`."""
    nb = unique_blocks.shape[0]
    q_n, dim = queries.shape
    _, bs, _ = blocks.shape
    assert k <= bs, (k, bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((q_n, dim), lambda i, ids: (0, 0)),
            pl.BlockSpec((1, bs, dim), lambda i, ids: (ids[i], 0, 0)),
            pl.BlockSpec((1, bs), lambda i, ids: (i, 0)),
            pl.BlockSpec((1, 2), lambda i, ids: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_n, k), lambda i, ids: (i, 0, 0)),
            pl.BlockSpec((1, q_n, k), lambda i, ids: (i, 0, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_scan_batched_topk_q8_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nb, q_n, k), jnp.float32),
            jax.ShapeDtypeStruct((nb, q_n, k), jnp.int32),
        ],
        interpret=interpret,
    )(unique_blocks, queries, blocks, slot_bias, page_sz)
