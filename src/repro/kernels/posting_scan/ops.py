"""Public wrappers for the posting-scan kernels.

These integrate the BlockPool with the Pallas kernels: build the block
table from posting ids, clamp absent pages to page 0, and mask distances of
invalid/stale slots to +BIG for the downstream top-k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.posting_scan import kernel as K

BIG = jnp.float32(3.0e38)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scan_posting_blocks(
    queries: jax.Array,       # (Q, d)
    posting_blocks: jax.Array,  # (P_cap, MB) i32 block table rows
    pids: jax.Array,          # (Q, nprobe) probed postings (-1 = none)
    blocks: jax.Array,        # (B, BS, d)
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-query paged scan.  Returns ``(dists (Q, nprobe*MB*BS), flat_slot
    (Q, nprobe*MB*BS) bool valid-page mask)`` — caller applies vid/version
    masks and top-k."""
    q_n = queries.shape[0]
    bs = blocks.shape[1]
    table = posting_blocks[jnp.maximum(pids, 0)]        # (Q, nprobe, MB)
    table = jnp.where(pids[..., None] >= 0, table, -1)
    flat = table.reshape(q_n, -1)                       # (Q, NB)
    page_ok = flat >= 0
    d = K.scan_per_query(
        jnp.maximum(flat, 0), queries, blocks, interpret=interpret
    )                                                   # (Q, NB, BS)
    d = jnp.where(page_ok[:, :, None], d, BIG)
    return d.reshape(q_n, -1), jnp.repeat(page_ok, bs, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scan_unique_blocks(
    queries: jax.Array,      # (Q, d)
    unique_blocks: jax.Array,  # (NB,) i32, -1 = padding
    blocks: jax.Array,       # (B, BS, d)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Batch-dedup scan.  Returns dists (NB, Q, BS) with padded pages = BIG."""
    ok = unique_blocks >= 0
    d = K.scan_batched(
        jnp.maximum(unique_blocks, 0), queries, blocks, interpret=interpret
    )
    return jnp.where(ok[:, None, None], d, BIG)


# ---------------------------------------------------------------------------
# Fused per-page top-k wrappers + batch page dedup (the search hot path)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def scan_posting_blocks_topk(
    queries: jax.Array,      # (Q, d)
    page_table: jax.Array,   # (Q, NB) i32 block ids, -1 = absent/not probed
    slot_live: jax.Array,    # (Q, NB, BS) bool — live slots of each page
    blocks: jax.Array,       # (B, BS, d)
    *,
    k: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-query paged scan with fused per-page k-min.

    Returns ``(dists (Q, NB, k), slots (Q, NB, k))``; dead candidates
    (absent page or dead slot) carry dist >= BIG."""
    bias = jnp.where(
        slot_live & (page_table >= 0)[:, :, None], jnp.float32(0), BIG
    )
    return K.scan_per_query_topk(
        jnp.maximum(page_table, 0), queries, blocks, bias,
        k=k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def scan_unique_blocks_topk(
    queries: jax.Array,       # (Q, d)
    unique_blocks: jax.Array,  # (NB,) i32, -1 = padding
    slot_live: jax.Array,     # (NB, BS) bool — live slots of each page
    blocks: jax.Array,        # (B, BS, d)
    *,
    k: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Batch-dedup paged scan with fused per-(page, query) k-min.

    Returns ``(dists (NB, Q, k), slots (NB, Q, k))``."""
    bias = jnp.where(
        slot_live & (unique_blocks >= 0)[:, None], jnp.float32(0), BIG
    )
    return K.scan_batched_topk(
        jnp.maximum(unique_blocks, 0), queries, blocks, bias,
        k=k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def scan_posting_blocks_topk_q8(
    queries: jax.Array,      # (Q, d)
    page_table: jax.Array,   # (Q, NB) i32 block ids, -1 = absent/not probed
    slot_live: jax.Array,    # (Q, NB, BS) bool — live slots of each page
    blocks: jax.Array,       # (B, BS, d) int8 codes
    page_scale: jax.Array,   # (Q, NB) f32 — per-page posting scale
    page_zero: jax.Array,    # (Q, NB) f32 — per-page posting zero-point
    *,
    k: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """`scan_posting_blocks_topk` over int8 codes: the per-page scale/zero
    ride the DMA and the page is dequantized inside the kernel."""
    bias = jnp.where(
        slot_live & (page_table >= 0)[:, :, None], jnp.float32(0), BIG
    )
    page_sz = jnp.stack(
        [page_scale.astype(jnp.float32), page_zero.astype(jnp.float32)],
        axis=-1,
    )                                                   # (Q, NB, 2)
    return K.scan_per_query_topk_q8(
        jnp.maximum(page_table, 0), queries, blocks, bias, page_sz,
        k=k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def scan_unique_blocks_topk_q8(
    queries: jax.Array,       # (Q, d)
    unique_blocks: jax.Array,  # (NB,) i32, -1 = padding
    slot_live: jax.Array,     # (NB, BS) bool — live slots of each page
    blocks: jax.Array,        # (B, BS, d) int8 codes
    page_scale: jax.Array,    # (NB,) f32 — per-unique-page posting scale
    page_zero: jax.Array,     # (NB,) f32 — per-unique-page zero-point
    *,
    k: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """`scan_unique_blocks_topk` over int8 codes with in-kernel dequant."""
    bias = jnp.where(
        slot_live & (unique_blocks >= 0)[:, None], jnp.float32(0), BIG
    )
    page_sz = jnp.stack(
        [page_scale.astype(jnp.float32), page_zero.astype(jnp.float32)],
        axis=-1,
    )                                                   # (NB, 2)
    return K.scan_batched_topk_q8(
        jnp.maximum(unique_blocks, 0), queries, blocks, bias, page_sz,
        k=k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("budget", "num_blocks"))
def dedup_pages(
    pages: jax.Array,         # (N,) i32 probed block ids, -1 = invalid
    *,
    budget: int,
    num_blocks: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fixed-shape batch page dedup (the batched schedule's compaction).

    Returns ``(unique (budget,), member_pos (N,), n_unique (), overflow ())``:

    * ``unique`` — sorted distinct valid page ids, -1-padded; when more
      than ``budget`` distinct pages were probed, the *highest-numbered*
      pages are dropped (jnp.unique keeps the smallest ``budget``).
    * ``member_pos`` — for every input probe, the row of ``unique``
      holding its page (clipped; -1 where the probe is invalid or its
      page was dropped by the budget).
    * ``n_unique`` / ``overflow`` — distinct valid pages probed, and how
      many of them the budget dropped (the recall-accounting signal).
    """
    sentinel = jnp.int32(num_blocks)  # > every real page id
    flat = jnp.where(pages >= 0, pages, sentinel)
    # ONE sort serves both the unique compaction and the distinct count
    # (jnp.unique would sort a second time just to recount)
    srt = jnp.sort(flat)
    first = jnp.concatenate([jnp.ones((1,), bool), srt[1:] != srt[:-1]])
    first = first & (srt < sentinel)
    n_unique = jnp.sum(first)
    (pos,) = jnp.nonzero(first, size=budget, fill_value=0)
    kept = jnp.minimum(n_unique, budget)
    uniq = jnp.where(jnp.arange(budget) < kept, srt[pos], sentinel)
    uniq_valid = uniq < sentinel
    overflow = jnp.maximum(n_unique - kept, 0)
    # membership: searchsorted into the sorted unique rows
    pos = jnp.searchsorted(uniq, flat).astype(jnp.int32)
    pos = jnp.minimum(pos, budget - 1)
    hit = (uniq[pos] == flat) & (pages >= 0)
    member_pos = jnp.where(hit, pos, -1)
    return jnp.where(uniq_valid, uniq, -1), member_pos, n_unique, overflow
