"""Public wrappers for the posting-scan kernels.

These integrate the BlockPool with the Pallas kernels: build the block
table from posting ids, clamp absent pages to page 0, and mask distances of
invalid/stale slots to +BIG for the downstream top-k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.posting_scan import kernel as K

BIG = jnp.float32(3.0e38)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scan_posting_blocks(
    queries: jax.Array,       # (Q, d)
    posting_blocks: jax.Array,  # (P_cap, MB) i32 block table rows
    pids: jax.Array,          # (Q, nprobe) probed postings (-1 = none)
    blocks: jax.Array,        # (B, BS, d)
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-query paged scan.  Returns ``(dists (Q, nprobe*MB*BS), flat_slot
    (Q, nprobe*MB*BS) bool valid-page mask)`` — caller applies vid/version
    masks and top-k."""
    q_n = queries.shape[0]
    bs = blocks.shape[1]
    table = posting_blocks[jnp.maximum(pids, 0)]        # (Q, nprobe, MB)
    table = jnp.where(pids[..., None] >= 0, table, -1)
    flat = table.reshape(q_n, -1)                       # (Q, NB)
    page_ok = flat >= 0
    d = K.scan_per_query(
        jnp.maximum(flat, 0), queries, blocks, interpret=interpret
    )                                                   # (Q, NB, BS)
    d = jnp.where(page_ok[:, :, None], d, BIG)
    return d.reshape(q_n, -1), jnp.repeat(page_ok, bs, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scan_unique_blocks(
    queries: jax.Array,      # (Q, d)
    unique_blocks: jax.Array,  # (NB,) i32, -1 = padding
    blocks: jax.Array,       # (B, BS, d)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Batch-dedup scan.  Returns dists (NB, Q, BS) with padded pages = BIG."""
    ok = unique_blocks >= 0
    d = K.scan_batched(
        jnp.maximum(unique_blocks, 0), queries, blocks, interpret=interpret
    )
    return jnp.where(ok[:, None, None], d, BIG)
