"""Pure-jnp oracles for the posting_scan kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_posting_blocks_ref(
    block_table: jax.Array, queries: jax.Array, blocks: jax.Array
) -> jax.Array:
    """(Q, NB, BS) distances — per-query page scan."""
    gathered = blocks[block_table]                 # (Q, NB, BS, d)
    q = queries.astype(jnp.float32)[:, None, None, :]
    diff = gathered.astype(jnp.float32) - q
    return jnp.sum(diff * diff, axis=-1)


def scan_unique_blocks_ref(
    unique_blocks: jax.Array, queries: jax.Array, blocks: jax.Array
) -> jax.Array:
    """(NB, Q, BS) distances — batched unique-page scan."""
    gathered = blocks[unique_blocks].astype(jnp.float32)  # (NB, BS, d)
    q = queries.astype(jnp.float32)
    diff = gathered[:, None, :, :] - q[None, :, None, :]
    return jnp.sum(diff * diff, axis=-1)


def _kmin_ref(d: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Row-wise k smallest of ``d (..., cols)`` with index-order tie-break
    (matches the kernels' min/mask loop)."""
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx.astype(jnp.int32)


def scan_per_query_topk_ref(
    block_table: jax.Array, queries: jax.Array, blocks: jax.Array,
    slot_bias: jax.Array, k: int,
) -> tuple[jax.Array, jax.Array]:
    """(Q, NB, k) per-page k-min candidates — per-query schedule."""
    d = scan_posting_blocks_ref(block_table, queries, blocks) + slot_bias
    return _kmin_ref(d, k)


def scan_batched_topk_ref(
    unique_blocks: jax.Array, queries: jax.Array, blocks: jax.Array,
    slot_bias: jax.Array, k: int,
) -> tuple[jax.Array, jax.Array]:
    """(NB, Q, k) per-(page, query) k-min candidates — batched schedule."""
    d = scan_unique_blocks_ref(unique_blocks, queries, blocks)
    d = d + slot_bias[:, None, :]
    return _kmin_ref(d, k)


def scan_per_query_topk_q8_ref(
    block_table: jax.Array, queries: jax.Array, blocks: jax.Array,
    slot_bias: jax.Array, page_sz: jax.Array, k: int,
) -> tuple[jax.Array, jax.Array]:
    """Dequant-fused per-query oracle: reconstruct ``code*scale+zero``
    per page ((Q, NB, 2) params) before the distance math."""
    g = blocks[block_table].astype(jnp.float32)           # (Q, NB, BS, d)
    g = g * page_sz[..., 0][:, :, None, None] + page_sz[..., 1][:, :, None, None]
    q = queries.astype(jnp.float32)[:, None, None, :]
    diff = g - q
    d = jnp.sum(diff * diff, axis=-1) + slot_bias
    return _kmin_ref(d, k)


def scan_batched_topk_q8_ref(
    unique_blocks: jax.Array, queries: jax.Array, blocks: jax.Array,
    slot_bias: jax.Array, page_sz: jax.Array, k: int,
) -> tuple[jax.Array, jax.Array]:
    """Dequant-fused batched oracle ((NB, 2) per-unique-page params)."""
    g = blocks[unique_blocks].astype(jnp.float32)         # (NB, BS, d)
    g = g * page_sz[:, 0][:, None, None] + page_sz[:, 1][:, None, None]
    q = queries.astype(jnp.float32)
    diff = g[:, None, :, :] - q[None, :, None, :]
    d = jnp.sum(diff * diff, axis=-1) + slot_bias[:, None, :]
    return _kmin_ref(d, k)
