"""Pure-jnp oracles for the posting_scan kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_posting_blocks_ref(
    block_table: jax.Array, queries: jax.Array, blocks: jax.Array
) -> jax.Array:
    """(Q, NB, BS) distances — per-query page scan."""
    gathered = blocks[block_table]                 # (Q, NB, BS, d)
    q = queries.astype(jnp.float32)[:, None, None, :]
    diff = gathered.astype(jnp.float32) - q
    return jnp.sum(diff * diff, axis=-1)


def scan_unique_blocks_ref(
    unique_blocks: jax.Array, queries: jax.Array, blocks: jax.Array
) -> jax.Array:
    """(NB, Q, BS) distances — batched unique-page scan."""
    gathered = blocks[unique_blocks].astype(jnp.float32)  # (NB, BS, d)
    q = queries.astype(jnp.float32)
    diff = gathered[:, None, :, :] - q[None, :, None, :]
    return jnp.sum(diff * diff, axis=-1)
