from repro.kernels.posting_scan.ops import (  # noqa: F401
    dedup_pages,
    scan_posting_blocks,
    scan_posting_blocks_topk,
    scan_unique_blocks,
    scan_unique_blocks_topk,
)
from repro.kernels.posting_scan.ref import (  # noqa: F401
    scan_batched_topk_ref,
    scan_per_query_topk_ref,
    scan_posting_blocks_ref,
    scan_unique_blocks_ref,
)
