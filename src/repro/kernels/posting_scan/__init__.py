from repro.kernels.posting_scan.ops import scan_posting_blocks, scan_unique_blocks  # noqa: F401
from repro.kernels.posting_scan.ref import scan_posting_blocks_ref, scan_unique_blocks_ref  # noqa: F401
