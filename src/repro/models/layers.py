"""Shared neural-net layers (pure JAX, functional params-as-dicts).

Conventions:
  * params are nested dicts of arrays; init fns take an rng key and return
    the dict; forward fns take (params, inputs, ...).
  * compute dtype is configurable (bf16 default for LMs); accumulation and
    softmax/norm statistics are always f32.
  * attention is chunked (online-softmax over KV chunks, lax.scan) so the
    32k-prefill cells compile with bounded memory — the pure-JAX flash
    pattern.  TPU deployments would swap in a Pallas flash kernel; the scan
    form has the same HBM traffic shape, which is what the roofline reads.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """Apply RoPE.  x: (..., S, H, D) with positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked (online-softmax) attention — the pure-JAX flash pattern
# ---------------------------------------------------------------------------

def chunked_attention(
    q: Array,  # (B, Sq, H, D)
    k: Array,  # (B, Skv, KH, D)
    v: Array,  # (B, Skv, KH, D)
    *,
    causal: bool,
    q_offset: Array | int = 0,
    kv_chunk: int = 1024,
    kv_valid_len: Array | None = None,
) -> Array:
    """GQA attention with online softmax over KV chunks.

    ``q_offset`` shifts the query positions (decode: q_offset = cache length).
    ``kv_valid_len`` masks KV positions >= len (ragged caches).
    Memory: O(B * Sq * H * D + chunk scores), never O(Sq * Skv).
    """
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    kv_chunk = min(kv_chunk, skv)
    pad = (-skv) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_valid_len is None:
            kv_valid_len = skv
        skv = skv + pad
    nc = skv // kv_chunk
    scale = d ** -0.5

    qr = (q.astype(jnp.float32) * scale).reshape(b, sq, kh, g, d)
    q_pos = jnp.asarray(q_offset) + jnp.arange(sq)  # (sq,)

    ks = k.reshape(b, nc, kv_chunk, kh, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nc, kv_chunk, kh, d).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, ci = inp  # (B, C, KH, D) x2, chunk index
        kv_pos = ci * kv_chunk + jnp.arange(kv_chunk)  # (C,)
        s = jnp.einsum(
            "bqkgd,bckd->bkgqc", qr, kc.astype(jnp.float32),
        )  # (B, KH, G, Sq, C)
        mask = jnp.ones((sq, kv_chunk), bool)
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if kv_valid_len is not None:
            mask = mask & (kv_pos[None, :] < kv_valid_len)
        s = jnp.where(mask[None, None, None], s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p, vc.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kh, g, sq, d), jnp.float32)
    # Nested remat: without it the scan saves every chunk's f32 score tile
    # for the backward pass — i.e. the full attention matrix (the exact
    # thing flash attention exists to avoid).
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), (ks, vs, jnp.arange(nc))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KH, G, Sq, D)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def full_attention_ref(q, k, v, *, causal, q_offset=0, kv_valid_len=None):
    """Naive reference attention (oracle for chunked_attention tests)."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = d ** -0.5
    qr = (q.astype(jnp.float32) * scale).reshape(b, sq, kh, g, d)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qr, k.astype(jnp.float32))
    q_pos = jnp.asarray(q_offset) + jnp.arange(sq)
    kv_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask = mask & (kv_pos[None, :] <= q_pos[:, None])
    if kv_valid_len is not None:
        mask = mask & (kv_pos[None, :] < kv_valid_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bkgqd", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU) and MoE (capacity-based dispatch, EP-shardable)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff, dtype),
        "wi_up": dense_init(k2, d_model, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params: dict, x: Array) -> Array:
    gate = jax.nn.silu(x @ params["wi_gate"])
    up = x @ params["wi_up"]
    return (gate * up) @ params["wo"]


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype) -> dict:
    k0, k1, k2, k3 = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d_model)
    return {
        "router": dense_init(k0, d_model, n_experts, jnp.float32),
        "wi_gate": (
            jax.random.normal(k1, (n_experts, d_model, d_ff), jnp.float32) * scale
        ).astype(dtype),
        "wi_up": (
            jax.random.normal(k2, (n_experts, d_model, d_ff), jnp.float32) * scale
        ).astype(dtype),
        "wo": (
            jax.random.normal(k3, (n_experts, d_ff, d_model), jnp.float32)
            / math.sqrt(d_ff)
        ).astype(dtype),
    }


def moe(
    params: dict,
    x: Array,  # (T, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
) -> tuple[Array, Array]:
    """Top-k token-choice MoE with per-expert capacity (GShard-style).

    Dispatch is sort-free: per-(expert, slot) buffers are built with a
    stable intra-expert rank (cumsum over the token axis) + scatter; tokens
    over capacity are dropped (standard).  Shards cleanly: tokens over
    ('pod','data'), experts over 'model'.

    Returns (out (T, d), aux_loss scalar).
    """
    t, d = x.shape
    e = params["router"].shape[1]
    logits = (x.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(me * ce)

    capacity = max(1, int(capacity_factor * t * top_k / e))
    # (T*K,) flattened assignments, token-major so ranks are stable.
    flat_e = gate_idx.reshape(-1)  # (T*K,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*K, E)
    rank = jnp.cumsum(onehot, axis=0) - 1  # rank within expert
    my_rank = jnp.sum(rank * onehot, axis=-1)  # (T*K,)
    keep = my_rank < capacity
    slot = flat_e * capacity + jnp.minimum(my_rank, capacity - 1)
    slot = jnp.where(keep, slot, e * capacity)  # overflow -> scratch row

    token_of = jnp.repeat(jnp.arange(t), top_k)
    # Dispatch via "scatter ids, gather payload": the data-dependent
    # scatter moves 4-byte token ids; the d-wide rows then move through ONE
    # gather.  GSPMD realizes sharded scatters as full-buffer all-reduces,
    # so scattering payload directly costs an (E*C, d) all-reduce per layer
    # (measured: 34s collective term at phi3.5/train_4k); scattering ids
    # shrinks that to (E*C,) i32.  (Sharding-constraint variants on the
    # payload buffer fare even worse — "involuntary full rematerialization",
    # 166s.  See EXPERIMENTS.md §Perf iteration log.)
    buf_tok = jnp.full((e * capacity + 1,), t, jnp.int32)
    buf_tok = buf_tok.at[slot].set(token_of, mode="drop")
    x_aug = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    buf = x_aug[buf_tok[:-1]].reshape(e, capacity, d)

    # Expert computation: grouped einsum, E-sharded.
    gate_h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"])
    )
    up_h = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"])
    out_e = jnp.einsum("ecf,efd->ecd", gate_h * up_h, params["wo"])

    # Combine back: gather each kept (token, k) slot's output, weight, sum.
    out_flat = out_e.reshape(e * capacity, d)
    safe_slot = jnp.minimum(slot, e * capacity - 1)
    per_k = out_flat[safe_slot] * jnp.where(keep, 1.0, 0.0)[:, None].astype(x.dtype)
    per_k = per_k * gate_vals.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.sum(per_k.reshape(t, top_k, d), axis=1)
    return out, aux


def moe_ref(params: dict, x: Array, *, top_k: int) -> Array:
    """Naive per-token loop MoE oracle (no capacity drops)."""
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    out = jnp.zeros_like(x)
    for ki in range(top_k):
        e_idx = gate_idx[:, ki]
        wg = params["wi_gate"][e_idx]  # (T, d, f)
        wu = params["wi_up"][e_idx]
        wo = params["wo"][e_idx]
        h = jax.nn.silu(jnp.einsum("td,tdf->tf", x, wg)) * jnp.einsum(
            "td,tdf->tf", x, wu
        )
        out = out + jnp.einsum("tf,tfd->td", h, wo) * gate_vals[:, ki : ki + 1].astype(x.dtype)
    return out
