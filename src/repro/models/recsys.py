"""Recsys model zoo: EmbeddingBag + DeepFM, two-tower retrieval, BERT4Rec,
MIND — pure JAX.

JAX has no native ``nn.EmbeddingBag``; the assignment makes it part of the
system: :func:`bag_lookup` (fixed-size bags, -1 padded) and
:func:`embedding_bag_ragged` (flat ids + segment ids → segment_sum) implement
sum/mean bags via ``jnp.take`` + ``jax.ops.segment_sum``.

The embedding tables are the sharding story (rows over the 'model' axis);
interaction layers are tiny MLPs (see repro/distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array


# ---------------------------------------------------------------------------
# EmbeddingBag
# ---------------------------------------------------------------------------

def bag_lookup(
    table: Array, ids: Array, *, combiner: str = "sum"
) -> Array:
    """Fixed-size bags: ``ids (..., L)`` with -1 padding → ``(..., dim)``."""
    safe = jnp.maximum(ids, 0)
    emb = jnp.take(table, safe, axis=0)            # (..., L, dim)
    mask = (ids >= 0).astype(emb.dtype)[..., None]
    emb = emb * mask
    if combiner == "sum":
        return jnp.sum(emb, axis=-2)
    if combiner == "mean":
        denom = jnp.maximum(jnp.sum(mask, axis=-2), 1.0)
        return jnp.sum(emb, axis=-2) / denom
    raise ValueError(combiner)


def embedding_bag_ragged(
    table: Array,
    flat_ids: Array,      # (T,) i32, -1 padding
    segment_ids: Array,   # (T,) i32 bag index per id
    n_segments: int,
    *,
    combiner: str = "sum",
) -> Array:
    """Ragged bags via take + segment_sum (the torch EmbeddingBag analogue)."""
    safe = jnp.maximum(flat_ids, 0)
    emb = jnp.take(table, safe, axis=0)
    valid = (flat_ids >= 0)
    emb = emb * valid[:, None].astype(emb.dtype)
    seg = jnp.where(valid, segment_ids, n_segments)  # scratch row
    out = jax.ops.segment_sum(emb, seg, num_segments=n_segments + 1)[:-1]
    if combiner == "mean":
        cnt = jax.ops.segment_sum(
            valid.astype(emb.dtype), seg, num_segments=n_segments + 1
        )[:-1]
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def _mlp_init(key, dims: Sequence[int], dtype) -> list[dict]:
    layers = []
    for i in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        layers.append({
            "w": L.dense_init(sub, dims[i], dims[i + 1], dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
    return layers


def _mlp_apply(layers: list[dict], x: Array, *, final_act: bool = False) -> Array:
    for i, lp in enumerate(layers):
        x = x @ lp["w"] + lp["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _bce(logits: Array, labels: Array) -> Array:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


# ---------------------------------------------------------------------------
# DeepFM (arXiv:1703.04247)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    name: str = "deepfm"
    n_fields: int = 39
    vocab_per_field: int = 1_000_000
    embed_dim: int = 10
    mlp_dims: tuple[int, ...] = (400, 400, 400)
    dtype: str = "float32"


def deepfm_init(key: Array, cfg: DeepFMConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    rows = cfg.n_fields * cfg.vocab_per_field
    return {
        "embed": L.embed_init(k1, rows, cfg.embed_dim, dt),
        "linear": L.embed_init(k2, rows, 1, dt),
        "bias": jnp.zeros((), dt),
        "mlp": _mlp_init(
            k3,
            [cfg.n_fields * cfg.embed_dim, *cfg.mlp_dims, 1],
            dt,
        ),
    }


def deepfm_forward(params: dict, batch: dict, cfg: DeepFMConfig) -> Array:
    """batch: fields (B, n_fields) per-field categorical ids → logits (B,)."""
    ids = batch["fields"]
    offsets = jnp.arange(cfg.n_fields, dtype=ids.dtype) * cfg.vocab_per_field
    flat = jnp.clip(ids, 0, cfg.vocab_per_field - 1) + offsets[None, :]
    v = jnp.take(params["embed"], flat, axis=0)        # (B, F, dim)
    first = jnp.take(params["linear"], flat, axis=0)[..., 0].sum(-1)  # (B,)
    s = jnp.sum(v, axis=1)
    fm = 0.5 * jnp.sum(s * s - jnp.sum(v * v, axis=1), axis=-1)       # (B,)
    deep = _mlp_apply(params["mlp"], v.reshape(v.shape[0], -1))[:, 0]
    return params["bias"] + first + fm + deep


def deepfm_loss(params: dict, batch: dict, cfg: DeepFMConfig):
    logits = deepfm_forward(params, batch, cfg)
    loss = _bce(logits, batch["labels"])
    return loss, {"bce": loss}


# ---------------------------------------------------------------------------
# Two-tower retrieval (YouTube RecSys'19-style, sampled softmax + logQ)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    n_items: int = 10_000_000
    n_user_fields: int = 8
    user_vocab_per_field: int = 100_000
    embed_dim: int = 256
    tower_dims: tuple[int, ...] = (1024, 512, 256)
    temperature: float = 0.05
    dtype: str = "float32"
    serve_dtype: str | None = None  # §Perf iter 2: bf16 serving path


def twotower_init(key: Array, cfg: TwoTowerConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    user_rows = cfg.n_user_fields * cfg.user_vocab_per_field
    return {
        "user_embed": L.embed_init(k1, user_rows, cfg.embed_dim, dt),
        "item_embed": L.embed_init(k2, cfg.n_items, cfg.embed_dim, dt),
        "user_mlp": _mlp_init(
            k3, [cfg.n_user_fields * cfg.embed_dim, *cfg.tower_dims], dt
        ),
        "item_mlp": _mlp_init(k4, [cfg.embed_dim, *cfg.tower_dims], dt),
    }


def user_tower(params: dict, user_fields: Array, cfg: TwoTowerConfig) -> Array:
    offsets = jnp.arange(cfg.n_user_fields, dtype=user_fields.dtype) * (
        cfg.user_vocab_per_field
    )
    flat = jnp.clip(user_fields, 0, cfg.user_vocab_per_field - 1) + offsets[None, :]
    v = jnp.take(params["user_embed"], flat, axis=0)
    u = _mlp_apply(params["user_mlp"], v.reshape(v.shape[0], -1))
    return u / jnp.linalg.norm(u, axis=-1, keepdims=True).clip(1e-6)


def item_tower(params: dict, item_ids: Array, cfg: TwoTowerConfig) -> Array:
    v = jnp.take(params["item_embed"], jnp.clip(item_ids, 0, cfg.n_items - 1), axis=0)
    i = _mlp_apply(params["item_mlp"], v)
    return i / jnp.linalg.norm(i, axis=-1, keepdims=True).clip(1e-6)


def twotower_loss(params: dict, batch: dict, cfg: TwoTowerConfig):
    """In-batch sampled softmax with logQ correction.

    batch: user_fields (B, Fu), item_ids (B,), item_logq (B,) — log sampling
    probability of each in-batch negative.
    """
    u = user_tower(params, batch["user_fields"], cfg)   # (B, D)
    i = item_tower(params, batch["item_ids"], cfg)      # (B, D)
    logits = (u @ i.T).astype(jnp.float32) / cfg.temperature
    logits = logits - batch["item_logq"][None, :]       # logQ correction
    b = logits.shape[0]
    labels = jnp.arange(b)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = logits[jnp.arange(b), labels]
    loss = jnp.mean(logz - gold)
    return loss, {"softmax": loss}


def twotower_score_pairs(params: dict, batch: dict, cfg: TwoTowerConfig) -> Array:
    u = user_tower(params, batch["user_fields"], cfg)
    i = item_tower(params, batch["item_ids"], cfg)
    return jnp.sum(u * i, axis=-1)


def twotower_retrieval(params: dict, batch: dict, cfg: TwoTowerConfig) -> Array:
    """One query vs n_candidates item ids → scores (Q, C).  The brute-force
    path; the SPFresh-index path serves the same query in
    repro/serve/retrieval.py."""
    u = user_tower(params, batch["user_fields"], cfg)       # (Q, D)
    c = item_tower(params, batch["candidate_ids"], cfg)     # (C, D)
    return jax.lax.dot_general(
        u, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


# ---------------------------------------------------------------------------
# BERT4Rec (arXiv:1904.06690) — bidirectional encoder over item sequences
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    d_ff: int = 256
    seq_len: int = 200
    dtype: str = "float32"

    @property
    def mask_id(self) -> int:
        return self.n_items  # vocab row n_items = [MASK]


def bert4rec_init(key: Array, cfg: Bert4RecConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    blocks = []
    for i in range(cfg.n_blocks):
        k3, ka, kb = jax.random.split(k3, 3)
        d = cfg.embed_dim
        blocks.append({
            "ln1": jnp.ones((d,), dt),
            "ln2": jnp.ones((d,), dt),
            "wq": L.dense_init(ka, d, d, dt),
            "wk": L.dense_init(jax.random.fold_in(ka, 1), d, d, dt),
            "wv": L.dense_init(jax.random.fold_in(ka, 2), d, d, dt),
            "wo": L.dense_init(jax.random.fold_in(ka, 3), d, d, dt),
            "mlp": L.init_mlp(kb, d, cfg.d_ff, dt),
        })
    return {
        "item_embed": L.embed_init(k1, cfg.n_items + 1, cfg.embed_dim, dt),
        "pos_embed": L.embed_init(k2, cfg.seq_len, cfg.embed_dim, dt),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.embed_dim,), dt),
    }


def bert4rec_encode(params: dict, items: Array, cfg: Bert4RecConfig) -> Array:
    """items (B, S) with -1 padding → hidden (B, S, d).  Bidirectional."""
    b, s = items.shape
    safe = jnp.clip(items, 0, cfg.n_items)
    x = params["item_embed"][safe] + params["pos_embed"][None, :s]
    pad = (items < 0)
    x = jnp.where(pad[..., None], 0.0, x)
    h = cfg.embed_dim // cfg.n_heads

    def block(x, blk):
        y = L.rms_norm(x, blk["ln1"])
        q = (y @ blk["wq"]).reshape(b, s, cfg.n_heads, h)
        k = (y @ blk["wk"]).reshape(b, s, cfg.n_heads, h)
        v = (y @ blk["wv"]).reshape(b, s, cfg.n_heads, h)
        # padded positions masked by zeroing their keys' contribution via
        # valid-length trick is wrong for mid-sequence pads; use additive mask.
        s_logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                              k.astype(jnp.float32)) / (h ** 0.5)
        s_logits = jnp.where(pad[:, None, None, :], -1e30, s_logits)
        p = jax.nn.softmax(s_logits, axis=-1)
        att = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(x.dtype)
        x = x + att.reshape(b, s, -1) @ blk["wo"]
        x = x + L.mlp(blk["mlp"], L.rms_norm(x, blk["ln2"]))
        return x

    for blk in params["blocks"]:
        x = block(x, blk)
    return L.rms_norm(x, params["final_norm"])


def bert4rec_loss(params: dict, batch: dict, cfg: Bert4RecConfig):
    """Masked-item prediction.  batch: items (B,S) with mask_id at the
    masked slots, mask_pos (B, M) positions, mask_label (B, M) with -1
    ignore.  Scoring ONLY the masked positions keeps the logits buffer at
    (B·M, V) instead of (B·S, V) — at the train_batch cell that is the
    difference between 3 GB and 660 GB per device (EXPERIMENTS.md)."""
    hidden = bert4rec_encode(params, batch["items"], cfg)  # (B, S, d)
    mask_pos = batch["mask_pos"]        # (B, M)
    labels = batch["mask_label"]        # (B, M)
    picked = jnp.take_along_axis(
        hidden, jnp.maximum(mask_pos, 0)[..., None], axis=1
    )  # (B, M, d)
    logits = jax.lax.dot_general(
        picked, params["item_embed"][: cfg.n_items],
        (((2,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )  # (B, M, V) — tied output embedding
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce, {"ce": ce}


def bert4rec_score(params: dict, batch: dict, cfg: Bert4RecConfig) -> Array:
    """Next-item scores from the last position: (B, V)."""
    hidden = bert4rec_encode(params, batch["items"], cfg)[:, -1]
    return jax.lax.dot_general(
        hidden, params["item_embed"][: cfg.n_items],
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# MIND (arXiv:1904.08030) — multi-interest capsule routing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50
    label_pow: float = 2.0
    dtype: str = "float32"


def mind_init(key: Array, cfg: MINDConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "item_embed": L.embed_init(k1, cfg.n_items, cfg.embed_dim, dt),
        "bilinear": L.dense_init(k2, cfg.embed_dim, cfg.embed_dim, dt),
        # fixed (untrained) routing-logit init, per the paper's B2I setup
        "routing_init": (
            jax.random.normal(k3, (cfg.n_interests, cfg.seq_len), jnp.float32)
        ),
    }


def _squash(x: Array) -> Array:
    n2 = jnp.sum(x * x, axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def mind_interests(params: dict, items: Array, cfg: MINDConfig) -> Array:
    """Behavior sequence (B, S) → K interest capsules (B, K, d)."""
    valid = (items >= 0)
    e = params["item_embed"][jnp.clip(items, 0, cfg.n_items - 1)]
    e = jnp.where(valid[..., None], e, 0.0)
    u = e @ params["bilinear"]                      # (B, S, d)
    b_logits = jnp.broadcast_to(
        params["routing_init"][None], (items.shape[0], cfg.n_interests, cfg.seq_len)
    )

    caps = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(
            jnp.where(valid[:, None, :], b_logits, -1e30), axis=1
        )  # softmax over interests per behavior
        z = jnp.einsum("bks,bsd->bkd", w.astype(u.dtype), u)
        caps = _squash(z.astype(jnp.float32)).astype(u.dtype)  # (B, K, d)
        b_logits = b_logits + jnp.einsum(
            "bkd,bsd->bks", caps.astype(jnp.float32), u.astype(jnp.float32)
        )
    return caps


def mind_loss(params: dict, batch: dict, cfg: MINDConfig):
    """Label-aware attention + in-batch sampled softmax.

    batch: items (B, S), target (B,) target item id.
    """
    caps = mind_interests(params, batch["items"], cfg)       # (B, K, d)
    t = params["item_embed"][jnp.clip(batch["target"], 0, cfg.n_items - 1)]
    att = jnp.einsum("bkd,bd->bk", caps.astype(jnp.float32), t.astype(jnp.float32))
    att = jax.nn.softmax(cfg.label_pow * att, axis=-1)
    user = jnp.einsum("bk,bkd->bd", att.astype(caps.dtype), caps)  # (B, d)
    logits = (user @ t.T).astype(jnp.float32)                # in-batch sampled softmax
    b = logits.shape[0]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = logits[jnp.arange(b), jnp.arange(b)]
    loss = jnp.mean(logz - gold)
    return loss, {"softmax": loss}


def mind_serve(params: dict, batch: dict, cfg: MINDConfig) -> Array:
    """Interest capsules for retrieval: (B, K, d) — each is an ANN query."""
    return mind_interests(params, batch["items"], cfg)
