"""Graph attention network (GAT, arXiv:1710.10903) with segment-op message
passing — the JAX-native SpMM/SDDMM formulation.

JAX has no CSR sparse; message passing is implemented over an explicit edge
index with ``jax.ops.segment_sum`` / ``segment_max`` (the assignment calls
this out as part of the system).  Edge softmax = SDDMM scores → per-dst
segment softmax → weighted scatter-add (SpMM).

Supports all four assigned shape cells:
  * full-graph (cora / ogb_products)      — one big edge list
  * sampled minibatch (fanout sampler in repro/data/graphs.py)
  * batched small graphs (molecule)       — block-diagonal edge batching +
    per-graph readout via graph_ids segment_sum
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat"
    d_in: int = 1433
    d_hidden: int = 8
    n_heads: int = 8
    n_layers: int = 2
    n_classes: int = 7
    negative_slope: float = 0.2
    dtype: str = "float32"
    readout: str = "none"  # "mean" for graph-level tasks (molecule cell)
    n_graphs: int = 0      # static graph count for batched-small-graph cells


def init_params(key: Array, cfg: GATConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        node_head = last and cfg.readout == "none"
        heads = 1 if node_head else cfg.n_heads
        d_out = cfg.n_classes if node_head else cfg.d_hidden
        k1, k2, k3 = jax.random.split(keys[i], 3)
        layers.append({
            "w": L.dense_init(k1, d_in, heads * d_out, dt),
            "a_src": (jax.random.normal(k2, (heads, d_out), jnp.float32) * 0.1).astype(dt),
            "a_dst": (jax.random.normal(k3, (heads, d_out), jnp.float32) * 0.1).astype(dt),
        })
        d_in = d_out if node_head else heads * d_out
    params = {"layers": layers}
    if cfg.readout != "none":
        params["head"] = L.dense_init(keys[-1], d_in, cfg.n_classes, dt)
    return params


def gat_layer(
    lp: dict,
    x: Array,          # (N, d_in)
    edge_src: Array,   # (E,) i32 — -1 for padded edges
    edge_dst: Array,   # (E,) i32
    *,
    heads: int,
    d_out: int,
    negative_slope: float,
    concat: bool,
) -> Array:
    n = x.shape[0]
    h = (x @ lp["w"]).reshape(n, heads, d_out)  # (N, H, D)
    src = jnp.maximum(edge_src, 0)
    dst = jnp.maximum(edge_dst, 0)
    valid = (edge_src >= 0) & (edge_dst >= 0)

    # SDDMM: per-edge unnormalized attention logits.
    alpha_src = jnp.sum(h * lp["a_src"][None], axis=-1)  # (N, H)
    alpha_dst = jnp.sum(h * lp["a_dst"][None], axis=-1)
    e = alpha_src[src] + alpha_dst[dst]                  # (E, H)
    e = jax.nn.leaky_relu(e, negative_slope).astype(jnp.float32)
    e = jnp.where(valid[:, None], e, -1e30)

    # Segment softmax over incoming edges of each dst node.
    e_max = jax.ops.segment_max(e, dst, num_segments=n)  # (N, H)
    e_max = jnp.where(jnp.isfinite(e_max), e_max, 0.0)
    p = jnp.exp(e - e_max[dst])
    p = jnp.where(valid[:, None], p, 0.0)
    denom = jax.ops.segment_sum(p, dst, num_segments=n)  # (N, H)
    w = p / jnp.maximum(denom[dst], 1e-16)               # (E, H)

    # SpMM: weighted scatter-add of source features into dst.
    msg = h[src].astype(jnp.float32) * w[..., None]      # (E, H, D)
    out = jax.ops.segment_sum(msg, dst, num_segments=n)  # (N, H, D)
    out = out.astype(x.dtype)
    return out.reshape(n, heads * d_out) if concat else jnp.mean(out, axis=1)


def forward(params: dict, batch: dict, cfg: GATConfig) -> Array:
    """Node logits (N, C), or graph logits (G, C) when readout != none."""
    x = batch["features"]
    es, ed = batch["edge_src"], batch["edge_dst"]
    for i, lp in enumerate(params["layers"]):
        last = i == cfg.n_layers - 1
        node_head = last and cfg.readout == "none"
        heads = 1 if node_head else cfg.n_heads
        d_out = cfg.n_classes if node_head else cfg.d_hidden
        x = gat_layer(
            lp, x, es, ed, heads=heads, d_out=d_out,
            negative_slope=cfg.negative_slope, concat=not node_head,
        )
        if not last:
            x = jax.nn.elu(x)
    if cfg.readout == "none":
        return x
    # Graph-level: mean readout by graph id, then classify.
    gid = batch["graph_ids"]
    n_graphs = cfg.n_graphs
    summed = jax.ops.segment_sum(x, gid, num_segments=n_graphs)
    counts = jax.ops.segment_sum(
        jnp.ones((x.shape[0],), x.dtype), gid, num_segments=n_graphs
    )
    pooled = summed / jnp.maximum(counts, 1.0)[:, None]
    return pooled @ params["head"]


def loss_fn(params: dict, batch: dict, cfg: GATConfig) -> tuple[Array, dict]:
    """Masked cross-entropy over labeled nodes (or graphs)."""
    logits = forward(params, batch, cfg).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / jnp.maximum(
        jnp.sum(mask), 1.0
    )
    return ce, {"ce": ce, "acc": acc}


def param_specs(cfg: GATConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
