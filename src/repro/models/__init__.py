"""Model zoo for the assigned architectures: GQA transformer LMs (dense +
MoE), GAT GNN, and four recsys models — all pure-JAX functional modules."""
