"""Decoder-only GQA transformer LM (dense + MoE) — scan-over-layers, remat.

Covers the five assigned LM architectures (granite-20b, deepseek-7b,
qwen1.5-110b w/ QKV bias, granite-moe-1b-a400m 32e top-8, phi3.5-moe 16e
top-2).  Three entry points per model:

  * ``loss_fn``     — next-token CE (+ MoE aux) for ``train_step``
  * ``prefill``     — prompt pass producing the KV cache + last-pos logits
  * ``decode_step`` — one-token decode against a KV cache

Layers are stacked (leading L axis) and scanned; each layer body is
``jax.checkpoint``-ed (remat) so 32k-prefill activations stay bounded.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import act_constraint
from repro.models import layers as L

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    vocab: int = 32000
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    head_dim: int | None = None
    qkv_bias: bool = False
    moe: bool = False
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    kv_chunk: int = 1024
    remat: bool = True
    aux_loss_weight: float = 0.01
    # scan-over-layers unroll factor.  The production configs fully unroll
    # (scan_unroll = n_layers) so cost_analysis / collective parsing see
    # every layer (a lax.scan body is counted ONCE by XLA's analysis).
    scan_unroll: int = 1

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding/head tables padded to a multiple of 256 so the vocab
        dim shards over any production mesh axis (e.g. granite's 49155).
        Logit columns >= vocab are masked to -inf in the loss/serving."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def n_params(self) -> int:
        """Total parameter count (for 6ND model-FLOPs accounting)."""
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.moe:
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab_padded * d + d

    @property
    def n_active_params(self) -> int:
        """Activated params per token (MoE: top_k experts only)."""
        if not self.moe:
            return self.n_params
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        ffn = self.moe_top_k * 3 * d * self.d_ff + d * self.n_experts
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab_padded * d + d


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def _init_layer(key: Array, cfg: LMConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 8)
    p = {
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        "wq": L.dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": L.dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": L.dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": L.dense_init(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
    if cfg.moe:
        p["moe"] = L.init_moe(ks[4], d, cfg.d_ff, cfg.n_experts, dt)
    else:
        p["mlp"] = L.init_mlp(ks[4], d, cfg.d_ff, dt)
    return p


def init_params(key: Array, cfg: LMConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    return {
        "embed": L.embed_init(k_embed, cfg.vocab_padded, cfg.d_model, dt),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.vocab_padded, dt),
    }


def param_specs(cfg: LMConfig) -> Any:
    """Abstract params (no allocation) — for .lower() in the dry-run."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Layer body (shared by train/prefill/decode)
# ---------------------------------------------------------------------------

def _qkv(lp: dict, h: Array, cfg: LMConfig):
    b, s, _ = h.shape
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q = q + lp["bq"]
        k = k + lp["bk"]
        v = v + lp["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def _ffn(lp: dict, x2: Array, cfg: LMConfig):
    if cfg.moe:
        b, s, d = x2.shape
        y, aux = L.moe(
            lp["moe"], x2.reshape(b * s, d), top_k=cfg.moe_top_k,
            capacity_factor=cfg.capacity_factor,
        )
        return y.reshape(b, s, d), aux
    return L.mlp(lp["mlp"], x2), jnp.zeros((), jnp.float32)


def _layer_train(x: Array, lp: dict, cfg: LMConfig, positions: Array):
    h = L.rms_norm(x, lp["ln1"])
    q, k, v = _qkv(lp, h, cfg)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    att = L.chunked_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk)
    b, s, _, _ = att.shape
    x = x + att.reshape(b, s, -1) @ lp["wo"]
    y, aux = _ffn(lp, L.rms_norm(x, lp["ln2"]), cfg)
    # residual stream: batch over data axes, d_model over model (keeps the
    # remat-saved per-layer activations sharded — 42 GB/device otherwise).
    out = act_constraint(x + y, None, "model")
    return out, aux, k, v


# ---------------------------------------------------------------------------
# Train loss
# ---------------------------------------------------------------------------

def _mask_pad_vocab(logits: Array, cfg: LMConfig) -> Array:
    if cfg.vocab_padded == cfg.vocab:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < cfg.vocab, logits, jnp.float32(-1e30))


def loss_fn(params: dict, batch: dict, cfg: LMConfig) -> tuple[Array, dict]:
    """Next-token cross entropy.  batch: tokens (B,S), labels (B,S) with
    -1 = ignore."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    b, s = tokens.shape
    x = act_constraint(params["embed"][tokens], None, "model")
    positions = jnp.arange(s)

    def body(carry, lp):
        x, aux = carry
        x, a, _, _ = _layer_train(x, lp, cfg, positions)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"], unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"])
    logits = jax.lax.dot_general(
        x, params["lm_head"], (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (B, S, V) f32
    logits = act_constraint(logits, None, "model")
    logits = _mask_pad_vocab(logits, cfg)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce + cfg.aux_loss_weight * aux / cfg.n_layers
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(params: dict, tokens: Array, cfg: LMConfig):
    """Prompt pass.  Returns (last-position logits (B, V), cache dict with
    k/v stacked (L, B, S, KH, D))."""
    b, s = tokens.shape
    x = act_constraint(params["embed"][tokens], None, "model")
    positions = jnp.arange(s)

    def body(carry, lp):
        x = carry
        x, _, k, v = _layer_train(x, lp, cfg, positions)
        # cache layout: batch over data axes, sequence over model
        k = act_constraint(k, "model", None, None)
        v = act_constraint(v, "model", None, None)
        return x, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"],
                               unroll=cfg.scan_unroll)
    x = L.rms_norm(x[:, -1:], params["final_norm"])
    logits = jax.lax.dot_general(
        x, params["lm_head"], (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]
    return _mask_pad_vocab(logits, cfg), {"k": ks, "v": vs}


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_step(params: dict, cache: dict, tokens: Array, pos: Array,
                cfg: LMConfig):
    """One decode step at position ``pos`` (scalar i32): attends to
    cache[:pos] plus the new token; returns (logits (B,V), new cache)."""
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None, :]  # (B, 1, d)
    positions = jnp.broadcast_to(jnp.asarray(pos), (1,))

    def body(x, inp):
        lp, kc, vc = inp
        h = L.rms_norm(x, lp["ln1"])
        q, k_new, v_new = _qkv(lp, h, cfg)
        q = L.rope(q, positions, cfg.rope_theta)
        k_new = L.rope(k_new, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k_new.astype(kc.dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v_new.astype(vc.dtype), pos, axis=1)
        kc = act_constraint(kc, "model", None, None)
        vc = act_constraint(vc, "model", None, None)
        # decode uses single-chunk attention (plain softmax) so a
        # sequence-sharded cache becomes classic sequence-parallel decode:
        # partial scores per shard + all-reduce'd softmax stats.
        att = L.chunked_attention(
            q, kc, vc, causal=False, q_offset=pos,
            kv_chunk=kc.shape[1], kv_valid_len=pos + 1,
        )
        x = x + att.reshape(b, 1, -1) @ lp["wo"]
        y, _ = _ffn(lp, L.rms_norm(x, lp["ln2"]), cfg)
        return x + y, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x,
                               (params["layers"], cache["k"], cache["v"]),
                               unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"])
    logits = jax.lax.dot_general(
        x, params["lm_head"], (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]
    return _mask_pad_vocab(logits, cfg), {"k": ks, "v": vs}
