"""Distributed SPFresh: the index sharded over the ``model`` axis,
queries parallel over ``data`` (and ``pod``) — shard_map'd LIRE.

Design (DESIGN.md §4):
  * postings are partitioned in *centroid space* (balanced k-means over
    shards) so LIRE's reassignment locality stays shard-local;
  * each (pod, data) row holds a full replica of every index shard —
    data-axis = query parallelism / read replicas;
  * updates are replicated deterministically across rows (every replica
    applies the same jitted transition), so replicas never diverge;
  * search does a per-shard local top-k then ONE all_gather(k) over
    ``model`` — the tournament merge (O(k·M) bytes, not O(candidates));
  * vector handles are (shard, slot): global_vid = shard * N_shard + slot;
    version state is owned by exactly one shard — no cross-shard races;
  * a ``shard_alive`` mask degrades dead shards gracefully (closure
    replicas keep recall from collapsing — measured in tests).

All ops below are *global* jittable functions over a stacked state whose
leaves carry a leading (n_shards,) axis sharded P('model').
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import lire
from repro.core.clustering import balanced_kmeans
from repro.core.index import build_state
from repro.core.types import IndexState, LireConfig, make_empty_state
from repro.core.distance import MASK_DISTANCE
from repro.storage.durability import DurableBackend

Array = jax.Array


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (new API vs experimental)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


# ---------------------------------------------------------------------------
# Stacked-state helpers
# ---------------------------------------------------------------------------

def stack_states(states: list[IndexState]) -> IndexState:
    """Stack per-shard states along a new leading axis (P('model'))."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *states)


def unstack_state(stacked: IndexState, i: int) -> IndexState:
    return jax.tree_util.tree_map(lambda x: x[i], stacked)


def state_pspecs(stacked: IndexState) -> Any:
    """P('model', None, ...) for every leaf of the stacked state."""
    return jax.tree_util.tree_map(
        lambda x: P("model", *([None] * (x.ndim - 1))), stacked
    )


def _squeeze(tree):
    return jax.tree_util.tree_map(lambda x: x[0], tree)


def _expand(tree):
    return jax.tree_util.tree_map(lambda x: x[None], tree)


def _data_axes(mesh: Mesh):
    return tuple(a for a in mesh.axis_names if a != "model")


# ---------------------------------------------------------------------------
# Distributed search
# ---------------------------------------------------------------------------

def _axis_size(a):
    """jax.lax.axis_size compat (older jax: psum of ones)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)


def _flat_axis_index(axes):
    """Flattened linear index over one or more mesh axes (row-major)."""
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx


def make_search_step(
    mesh: Mesh, cfg: LireConfig, *, k: int, nprobe: int | None = None,
    shard_axes: tuple[str, ...] = ("model",), probe_chunk: int = 0,
    gprobe: int = 0, use_pallas_scan: bool | None = None,
    scan_schedule: str | None = None,
):
    """Returns a jitted ``search(state_stacked, queries, shard_alive[,
    group_index_stacked]) -> (dists (Q, k), global_vids (Q, k))``.

    queries are sharded over the data axes; the per-shard local top-k is
    merged with one all_gather over 'model' (the tournament merge).
    ``gprobe > 0`` switches navigation to the two-level group router (the
    step then takes a stacked GroupIndex as 4th argument).
    ``use_pallas_scan`` / ``scan_schedule`` select each shard's local
    posting-scan data path (None = the config flags); the batched
    schedule dedups pages *per shard* — exactly the per-micro-batch
    traffic model of the single-host path.
    """
    da = tuple(a for a in mesh.axis_names if a not in shard_axes)
    nprobe_ = nprobe or cfg.nprobe
    n_shard_vecs = cfg.num_vectors_cap

    def local(state_stacked, queries, shard_alive, *rest):
        state = _squeeze(state_stacked)
        my = _flat_axis_index(shard_axes)
        if gprobe > 0:
            from repro.core.grouping import search_grouped

            gidx = _squeeze(rest[0])
            d, v = search_grouped(
                state, gidx, queries, k=k, nprobe=nprobe_, gprobe=gprobe,
                probe_chunk=probe_chunk, use_pallas_scan=use_pallas_scan,
                scan_schedule=scan_schedule,
            )
        else:
            d, v = lire.search(
                state, queries, k=k, nprobe=nprobe_, probe_chunk=probe_chunk,
                use_pallas_scan=use_pallas_scan, scan_schedule=scan_schedule,
            )
        # globalize vids: handle = shard * N_shard + slot
        gv = jnp.where(v >= 0, my * n_shard_vecs + v, -1)
        alive = shard_alive[my]
        d = jnp.where(alive, d, MASK_DISTANCE)
        gv = jnp.where(alive, gv, -1)
        # tournament merge over the shard axes
        all_d = jax.lax.all_gather(d, shard_axes, tiled=False)   # (M, Q, k)
        all_v = jax.lax.all_gather(gv, shard_axes, tiled=False)
        all_d = all_d.reshape(-1, *d.shape)
        all_v = all_v.reshape(-1, *gv.shape)
        m, q, kk = all_d.shape
        all_d = all_d.transpose(1, 0, 2).reshape(q, m * kk)
        all_v = all_v.transpose(1, 0, 2).reshape(q, m * kk)
        neg, sel = jax.lax.top_k(-all_d, k)
        out_d = -neg
        out_v = jnp.take_along_axis(all_v, sel, axis=1)
        out_v = jnp.where(out_d < MASK_DISTANCE / 2, out_v, -1)
        return out_d, out_v

    qspec = P(da, None) if da else P(None, None)
    in_specs = [state_pspecs_for(cfg, shard_axes), qspec, P(None)]
    if gprobe > 0:
        ax = shard_axes if len(shard_axes) > 1 else shard_axes[0]
        in_specs.append(
            jax.tree_util.tree_map(lambda _: P(ax), GroupIndexSpec())
        )
    sm = _shard_map(
        local, mesh=mesh, in_specs=tuple(in_specs), out_specs=(qspec, qspec)
    )
    return jax.jit(sm)


class GroupIndexSpec:
    """Pytree stand-in with the GroupIndex structure (4 array leaves)."""

    def __new__(cls):
        from repro.core.grouping import GroupIndex

        z = jnp.zeros(())
        return GroupIndex(group_centroids=z, group_sqn=z, members=z,
                          member_valid=z)


def state_pspecs_for(
    cfg: LireConfig, shard_axes: tuple[str, ...] = ("model",)
) -> Any:
    """Leaf pspecs from an abstract empty state (avoids materializing)."""
    abstract = jax.eval_shape(lambda: make_empty_state(cfg))
    ax = shard_axes if len(shard_axes) > 1 else shard_axes[0]
    return jax.tree_util.tree_map(
        lambda x: P(ax, *([None] * x.ndim)), abstract
    )


# ---------------------------------------------------------------------------
# Distributed insert / delete
# ---------------------------------------------------------------------------

def make_insert_step(
    mesh: Mesh, cfg: LireConfig, *, shard_axes: tuple[str, ...] = ("model",)
):
    """Returns jitted ``insert(state_stacked, vecs (B, d), valid (B,)) ->
    (state, handles (B,))``.

    The update batch is REPLICATED over data rows (read-replica design);
    ownership = shard with the globally nearest centroid, computed by one
    all_gather of per-shard best distances.  Each shard allocates local
    slots for its vectors and appends; handles are psum-combined.
    ``valid`` masks out padding rows (the serving pipeline pads batches
    to fixed bucket shapes); invalid rows get handle -1.
    """
    n_shard_vecs = cfg.num_vectors_cap

    def local(state_stacked, vecs, valid):
        state = _squeeze(state_stacked)
        my = _flat_axis_index(shard_axes)
        b = vecs.shape[0]

        # my best distance per vector
        d, _ = lire.navigate(state, vecs, 1)  # (B, 1)
        all_d = jax.lax.all_gather(d[:, 0], shard_axes, tiled=False)
        all_d = all_d.reshape(-1, b)                   # (M, B)
        owner = jnp.argmin(all_d, axis=0)              # (B,)
        mine = (owner == my) & valid

        # local slot allocation for owned vectors
        order = jnp.cumsum(mine.astype(jnp.int32)) - 1
        slots = jnp.where(mine, state.next_vid + order, -1)
        cap_ok = slots < cfg.num_vectors_cap
        mine = mine & cap_ok
        n_new = jnp.sum(mine)
        state = state.replace(next_vid=state.next_vid + n_new)

        state, landed = lire.insert_batch(
            state, vecs, jnp.maximum(slots, 0), mine
        )
        # a dropped primary append (posting at hard capacity) must NOT get
        # a handle — the engine's backpressure/retry path keys off -1
        ok = mine & landed

        # combine handles across shards (exactly one shard owns each vector)
        handle_part = jnp.where(ok, my * n_shard_vecs + slots, 0)
        handles = jax.lax.psum(handle_part, shard_axes)
        handles = jnp.where(
            jax.lax.psum(ok.astype(jnp.int32), shard_axes) > 0, handles, -1
        )
        return _expand(state), handles

    sm = _shard_map(
        local, mesh=mesh,
        in_specs=(state_pspecs_for(cfg, shard_axes), P(None, None), P(None)),
        out_specs=(state_pspecs_for(cfg, shard_axes), P(None)),
    )
    return jax.jit(sm, donate_argnums=(0,))


def make_delete_step(
    mesh: Mesh, cfg: LireConfig, *, shard_axes: tuple[str, ...] = ("model",)
):
    """jitted ``delete(state_stacked, handles (B,)) -> state``."""
    n_shard_vecs = cfg.num_vectors_cap

    def local(state_stacked, handles):
        state = _squeeze(state_stacked)
        my = _flat_axis_index(shard_axes)
        owner = handles // n_shard_vecs
        slot = handles % n_shard_vecs
        mine = (owner == my) & (handles >= 0)
        state = lire.delete_batch(state, slot, mine)
        return _expand(state)

    sm = _shard_map(
        local, mesh=mesh,
        in_specs=(state_pspecs_for(cfg, shard_axes), P(None)),
        out_specs=state_pspecs_for(cfg, shard_axes),
    )
    return jax.jit(sm, donate_argnums=(0,))


def make_maintenance_step(
    mesh: Mesh, cfg: LireConfig, *, shard_axes: tuple[str, ...] = ("model",),
    budget: int = 1,
):
    """jitted ``maintain(state_stacked) -> (state, n_did_work)``.

    Every shard runs ``budget`` SEQUENTIAL LIRE maintenance steps on its
    own postings (fused into one executable via lax.scan, mirroring
    ``core.index.fused_maintenance_step``).  Kept as the baseline the
    batched round is measured against; the serving path dispatches
    `make_maintenance_round`.
    """

    def local(state_stacked):
        state = _squeeze(state_stacked)

        def body(s, _):
            s, did = lire.maintenance_step(s)
            return s, did.astype(jnp.int32)

        state, dids = jax.lax.scan(body, state, None, length=budget)
        any_did = jax.lax.pmax(jnp.sum(dids), shard_axes)
        return _expand(state), any_did

    sm = _shard_map(
        local, mesh=mesh,
        in_specs=(state_pspecs_for(cfg, shard_axes),),
        out_specs=(state_pspecs_for(cfg, shard_axes), P()),
    )
    return jax.jit(sm, donate_argnums=(0,))


def make_maintenance_round(
    mesh: Mesh, cfg: LireConfig, *, shard_axes: tuple[str, ...] = ("model",),
    jobs_per_round: int = 4,
):
    """jitted ``maintain(state_stacked) -> (state, n_jobs_done)``.

    Every shard runs ONE batched `lire.maintenance_round`
    (``jobs_per_round`` splits + merges with a fused reassign pass) on its
    own postings — rebalancing is embarrassingly parallel across shards
    because the reassign neighborhood is shard-local by the centroid-space
    partition.  ``n_jobs_done`` is the max-over-shards job count, the ONE
    scalar the host drain loop reads back per round.
    """

    def local(state_stacked):
        state = _squeeze(state_stacked)
        state, did = lire.maintenance_round(state, jobs_per_round)
        any_did = jax.lax.pmax(did, shard_axes)
        return _expand(state), any_did

    sm = _shard_map(
        local, mesh=mesh,
        in_specs=(state_pspecs_for(cfg, shard_axes),),
        out_specs=(state_pspecs_for(cfg, shard_axes), P()),
    )
    return jax.jit(sm, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# Sharded build (host, offline) + elastic re-sharding
# ---------------------------------------------------------------------------

def partition_vectors(
    vectors: np.ndarray, n_shards: int, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Centroid-space partition: balanced k-means into n_shards groups.
    Returns (assignment (n,), shard_centroids (n_shards, d))."""
    if n_shards == 1:
        return (
            np.zeros(len(vectors), np.int32),
            vectors.mean(axis=0, keepdims=True).astype(np.float32),
        )
    cen, assign = balanced_kmeans(
        jax.random.PRNGKey(seed),
        jnp.asarray(vectors, jnp.float32),
        jnp.ones(len(vectors), bool),
        k=n_shards, iters=12, balance_weight=2.0,
    )
    return np.asarray(assign), np.asarray(cen)


def build_sharded_state(
    cfg: LireConfig, vectors: np.ndarray, n_shards: int, *, seed: int = 0
) -> tuple[IndexState, np.ndarray]:
    """Offline build: partition by centroid space, SPANN-build each shard,
    stack.  Returns (stacked_state, global_vid_of_input (n,)) where
    handles follow the (shard, slot) scheme."""
    assign, _ = partition_vectors(vectors, n_shards, seed)
    states, handles = [], np.full(len(vectors), -1, np.int64)
    for s in range(n_shards):
        idx = np.where(assign == s)[0]
        if len(idx) == 0:
            st = make_empty_state(cfg, seed=seed + s)
        else:
            st = build_state(cfg, vectors[idx], seed=seed + s)
            st = st.replace(next_vid=jnp.asarray(len(idx), jnp.int32))
            handles[idx] = s * cfg.num_vectors_cap + np.arange(len(idx))
        states.append(st)
    return stack_states(states), handles


def gather_live_vectors(
    stacked: IndexState, n_shards: int
) -> tuple[np.ndarray, np.ndarray]:
    """Extract all live vectors (+ global handles) from a stacked state —
    the elastic re-sharding path reads a snapshot through this."""
    from repro.storage import versionmap as vm

    out_v, out_h = [], []
    for s in range(n_shards):
        st = unstack_state(stacked, s)
        vids = np.asarray(st.pool.block_vid).reshape(-1)
        vers = np.asarray(st.pool.block_ver).reshape(-1)
        # re-sharding rebuilds the index from these rows, so read the
        # exact fp32 tier when the codec keeps one (no requant error)
        tier = (st.pool.blocks_exact if st.pool.blocks_exact is not None
                else st.pool.blocks)
        vecs = np.asarray(tier, dtype=np.float32).reshape(-1, st.pool.dim)
        stale = np.asarray(
            vm.is_stale(st.versions, jnp.asarray(vids), jnp.asarray(vers))
        )
        live = (vids >= 0) & ~stale
        # dedup replicas: keep first occurrence of each vid
        vids_live = vids[live]
        vecs_live = vecs[live]
        _, first = np.unique(vids_live, return_index=True)
        out_v.append(vecs_live[first])
        out_h.append(s * st.cfg.num_vectors_cap + vids_live[first])
    return np.concatenate(out_v), np.concatenate(out_h)


def reshard(
    cfg: LireConfig, stacked: IndexState, old_shards: int, new_shards: int,
    *, seed: int = 0,
) -> tuple[IndexState, np.ndarray]:
    """Elastic scaling: rebuild the partition for a different shard count
    from the live contents (snapshot-driven re-shard)."""
    vecs, _ = gather_live_vectors(stacked, old_shards)
    return build_sharded_state(cfg, vecs, new_shards, seed=seed)


# ---------------------------------------------------------------------------
# ShardedIndex — the stateful handle the serving pipeline drives
# ---------------------------------------------------------------------------

class ShardedIndex(DurableBackend):
    """Stacked sharded state + its jitted shard_map steps, behind the
    ServeEngine backend protocol (`repro.serve.engine.IndexBackend`).

    The engine feeds the same padded micro-batches it feeds a single-host
    index; every op here is one dispatch of a cached shard_map executable,
    with the stacked state donated on updates.  Search/insert/delete use
    global (shard, slot) handles; ``shard_alive`` degrades dead shards.

    Direct construction (the loose kwarg pile below) is deprecated as a
    user surface: declare a :class:`repro.api.ServiceSpec` and let
    ``spfresh.open`` build/recover the backend — that path also attaches
    the durable lifecycle (per-shard WAL + snapshot checkpoints).
    """

    def __init__(
        self,
        mesh: Mesh,
        cfg: LireConfig,
        stacked: IndexState,
        n_shards: int,
        *,
        shard_axes: tuple[str, ...] = ("model",),
        probe_chunk: int = 0,
        use_pallas_scan: bool | None = None,
        scan_schedule: str | None = None,
        jobs_per_round: int | None = None,
    ):
        self.mesh = mesh
        self.cfg = cfg
        self.stacked = stacked
        self.n_shards = n_shards
        self.shard_axes = shard_axes
        self.probe_chunk = probe_chunk
        self.use_pallas_scan = use_pallas_scan
        self.scan_schedule = scan_schedule
        self.jobs_per_round = jobs_per_round or cfg.jobs_per_round
        self.shard_alive = jnp.ones((n_shards,), bool)
        self._search_steps: dict[tuple, Any] = {}
        self._maintain_steps: dict[int, Any] = {}
        self._insert_step = make_insert_step(mesh, cfg, shard_axes=shard_axes)
        self._delete_step = make_delete_step(mesh, cfg, shard_axes=shard_axes)

    @classmethod
    def build(
        cls,
        mesh: Mesh,
        cfg: LireConfig,
        vectors: np.ndarray,
        n_shards: int,
        *,
        seed: int = 0,
        shard_axes: tuple[str, ...] = ("model",),
        probe_chunk: int = 0,
        use_pallas_scan: bool | None = None,
        scan_schedule: str | None = None,
        jobs_per_round: int | None = None,
    ) -> tuple["ShardedIndex", np.ndarray]:
        """Offline sharded build; returns (index, handles of the inputs)."""
        stacked, handles = build_sharded_state(cfg, vectors, n_shards, seed=seed)
        idx = cls(mesh, cfg, stacked, n_shards, shard_axes=shard_axes,
                  probe_chunk=probe_chunk, use_pallas_scan=use_pallas_scan,
                  scan_schedule=scan_schedule, jobs_per_round=jobs_per_round)
        return idx, handles

    def set_alive(self, alive: np.ndarray) -> None:
        self.shard_alive = jnp.asarray(alive, bool)

    # ---------------- replication hooks (replica cloning) ---------------
    def fork_state(self) -> IndexState:
        """Deep copy of the stacked state.  The update steps donate their
        stacked-state argument, so a replica sharing buffers with the
        primary would be invalidated by the primary's next update."""
        return jax.tree_util.tree_map(jnp.copy, self.stacked)

    def adopt_state(self, stacked: IndexState) -> None:
        """Install a (forked) stacked state, re-placed onto THIS index's
        mesh — the replica rows of a (data, model) mesh each run their
        own single-axis submesh (see ``sharding.replica_submeshes``)."""
        specs = state_pspecs(stacked)
        shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        self.stacked = jax.device_put(stacked, shardings)

    def clone(self, mesh: Mesh | None = None) -> "ShardedIndex":
        """A read replica of this index on ``mesh`` (default: same mesh):
        same config and step geometry, its own deep-copied state, its own
        compiled steps."""
        twin = ShardedIndex(
            mesh or self.mesh, self.cfg, self.stacked, self.n_shards,
            shard_axes=self.shard_axes, probe_chunk=self.probe_chunk,
            use_pallas_scan=self.use_pallas_scan,
            scan_schedule=self.scan_schedule,
            jobs_per_round=self.jobs_per_round,
        )
        twin.adopt_state(self.fork_state())
        twin._wal_applied = self._wal_applied
        return twin

    # --------------------------- backend ops ---------------------------
    def search(
        self, queries: np.ndarray, k: int, nprobe: int | None = None,
        valid: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        # ``valid`` (padded-row mask) is accepted for backend-protocol
        # parity but unused: the sharded backend does not accumulate
        # access telemetry (see ARCHITECTURE.md — the drift policy on
        # shards ranks by the update/drift leaves, which the jitted steps
        # bump deterministically; access_count stays zero).
        return self.search_begin(queries, k, nprobe, valid)()

    def search_begin(
        self, queries: np.ndarray, k: int, nprobe: int | None = None,
        valid: np.ndarray | None = None,
    ):
        """Issue ONE shard_map'd search dispatch and return a zero-arg
        ``finalize`` materializing ``(dists, ids)``; the dispatch is in
        flight when this returns, so the engine's pump thread can defer
        the host readback to scatter time (device overlap)."""
        key = (k, nprobe)
        step = self._search_steps.get(key)
        if step is None:
            step = make_search_step(
                self.mesh, self.cfg, k=k, nprobe=nprobe,
                shard_axes=self.shard_axes, probe_chunk=self.probe_chunk,
                use_pallas_scan=self.use_pallas_scan,
                scan_schedule=self.scan_schedule,
            )
            self._search_steps[key] = step
        d, v = step(self.stacked, jnp.asarray(queries), self.shard_alive)

        def finalize():
            return np.asarray(d), np.asarray(v)
        return finalize

    def insert(
        self, vecs: np.ndarray, vids: np.ndarray, valid: np.ndarray,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Caller vids are ignored: the sharded index owns id assignment
        (global handle = shard * N_cap + slot).  Returns (handles, landed)."""
        self._log("insert", {
            "vecs": np.asarray(vecs, np.float32),
            "valid": np.asarray(valid, bool),
        })
        self.stacked, handles = self._insert_step(
            self.stacked, jnp.asarray(vecs), jnp.asarray(valid)
        )
        handles = np.asarray(handles)
        return handles, handles >= 0

    def delete(self, vids: np.ndarray, valid: np.ndarray) -> None:
        handles = np.where(np.asarray(valid), np.asarray(vids), -1)
        self._log("delete", {"handles": np.asarray(handles, np.int32)})
        self.stacked = self._delete_step(
            self.stacked, jnp.asarray(handles, jnp.int32)
        )

    def log_update(self, op: str, payload: dict) -> None:
        """Engine-level batch logging is a no-op here: the backend logs
        every update DISPATCH itself (`_log`) when a WalSet is attached —
        dispatch-level records make replay bit-deterministic (handles are
        assigned inside the jitted step, so replaying the exact dispatch
        stream reproduces them)."""

    def maintain(self, jobs: int) -> int:
        """One fused maintenance round: ``jobs`` split+merge jobs per
        shard, ONE dispatch (cached per jobs count), ONE did-work scalar
        read back.  Returns the max-over-shards jobs done."""
        self._log("maintain", {"jobs": np.asarray(jobs, np.int32)})
        step = self._maintain_steps.get(jobs)
        if step is None:
            step = make_maintenance_round(
                self.mesh, self.cfg, shard_axes=self.shard_axes,
                jobs_per_round=jobs,
            )
            self._maintain_steps[jobs] = step
        self.stacked, did = step(self.stacked)
        return int(did)

    def drain(self) -> tuple[int, int]:
        """Rounds to quiescence; returns ``(jobs_done, rounds)``."""
        total = 0
        rounds = 0
        jobs = self.jobs_per_round
        # convergence bound: at most ~2*P_cap useful jobs (§3.4)
        for _ in range(2 * self.cfg.num_postings_cap // jobs + 1):
            did = self.maintain(jobs)
            rounds += 1
            total += did
            if did == 0:
                break
        return total, rounds

    def backlog(self) -> int:
        lens = np.asarray(self.stacked.pool.posting_len)      # (M, P)
        valid = np.asarray(self.stacked.centroid_valid)       # (M, P)
        return int(((lens > self.cfg.split_limit) & valid).sum())

    # ---------------------- durability lifecycle -----------------------
    # Paper §4.4 promoted to the sharded backend (DurableBackend mixin):
    # per-shard WAL append on every update dispatch, one atomic
    # stacked-state snapshot stamping each shard's applied seqno, replay
    # through the same shard_map'd steps on open — deterministic, so
    # handles land exactly as pre-crash.  This closes the old
    # "snapshot-only" gap.

    @property
    def _wal_shards(self) -> int:
        return self.n_shards

    def _snapshot_state(self):
        return self.stacked

    def _set_snapshot_state(self, state):
        self.stacked = state

    def _snapshot_extra(self):
        return {"backend": "sharded", "n_shards": self.n_shards}

    def _lire_config(self):
        return self.cfg

    def _apply_record(self, rec) -> None:
        p = rec.payload
        if rec.op == "insert":
            self.insert(
                p["vecs"], np.full(len(p["vecs"]), -1, np.int32),
                p["valid"],
            )
        elif rec.op == "delete":
            handles = p["handles"]
            self.delete(handles, handles >= 0)
        elif rec.op == "maintain":
            self.maintain(int(p["jobs"]))
        else:
            raise ValueError(f"unknown WAL op {rec.op!r}")

    @classmethod
    def restore(
        cls,
        mesh: Mesh,
        cfg: LireConfig,
        snapshot_dir: str,
        n_shards: int,
        **kwargs: Any,
    ) -> tuple["ShardedIndex", dict]:
        """Load a stacked-state snapshot chain (base + per-shard deltas);
        returns (index, manifest).  WAL replay on top is the caller's
        move (`spfresh.open` wires ``WalSet.recover_records`` →
        ``replay``)."""
        from repro.storage.snapshot import SnapshotStore

        template = stack_states(
            [make_empty_state(cfg) for _ in range(n_shards)]
        )
        stacked, manifest = SnapshotStore(snapshot_dir).load(template)
        extra = manifest.get("extra", {})
        if extra.get("n_shards", n_shards) != n_shards:
            raise ValueError(
                f"snapshot has {extra['n_shards']} shards, want {n_shards}"
            )
        idx = cls(mesh, cfg, stacked, n_shards, **kwargs)
        seqnos = extra.get("wal_seqnos", [-1])
        idx._wal_applied = min(seqnos) if seqnos else -1
        return idx, manifest

    def stats(self) -> dict:
        s = self.stacked.stats
        out = {
            k: int(np.asarray(getattr(s, k)).sum())
            for k in (
                "n_inserts", "n_deletes", "n_appends", "n_append_drops",
                "n_splits", "n_gc_writebacks", "n_merges",
                "n_reassign_checked", "n_reassign_candidates",
                "n_reassigned", "n_reassign_overflow",
            )
        }
        valid = np.asarray(self.stacked.centroid_valid)
        out["n_postings"] = int(valid.sum())
        out["n_shards"] = self.n_shards
        out["used_blocks"] = int(
            self.n_shards * self.stacked.pool.num_blocks_cap
            - np.asarray(self.stacked.pool.free_top).sum()
        )
        # Telemetry aggregates summed over shards (state leaves only, same
        # keys as the local backend).
        tel = self.stacked.telemetry
        out["access_total"] = int(np.asarray(tel.access_count)[valid].sum())
        out["update_total"] = int(np.asarray(tel.update_count)[valid].sum())
        out["drift_norm_total"] = float(
            np.linalg.norm(np.asarray(tel.drift_vec)[valid], axis=-1).sum()
        )
        return out
