"""Read replicas over the WAL dispatch stream (ROADMAP: read replicas +
async replication on multi-axis meshes).

The primary backend alone runs the WAL-append + dispatch order (PR 8's
serialized pump).  ``DurableBackend._log`` publishes every logged update
dispatch — AFTER the WAL append assigns its seqno — into the
:class:`ReplicaSet`'s bounded in-memory window; one worker thread per
replica replays the records **in seqno order** through the replica
backend's own jitted dispatches (``DurableBackend.replay``, the exact
crash-recovery code path).  Because every dispatch is a pure function of
(state, batch), a replica that has applied seqno S is bit-identical to
the primary as it was at seqno S; staleness is the measurable seqno lag
``primary_applied - replica_applied``.

Routing: the engine's pump offers each SEARCH micro-batch to
:meth:`ReplicaSet.route` — round-robin over replicas, skipping any that
is paused/failed, over its ``inflight`` cap, or more than ``max_lag``
seqnos behind the primary (the freshness bound); when no replica
qualifies the batch falls back to the primary (counted).  Routed batches
are served on the replica's worker thread, off the primary's serialized
pump — searches never queue behind update or maintenance dispatches.

Catch-up: a replica that falls behind the window (paused too long,
slow, or freshly failed-over) finds a seqno GAP and recovers exactly
like a crashed service: fork the primary's state under the engine's
exclusive lock (a consistent snapshot at a known seqno — update steps
donate their buffers, so the fork is a deep copy), adopt it, then
resume tail replay from the window.

Lock ordering (deadlock freedom): the pump thread acquires the engine's
``_work`` lock and may then take ``ReplicaSet._lock`` (route) or a
replica's cond (publish notify).  Worker threads take ``_work`` only
via ``engine.exclusive()`` during catch-up and NEVER while holding any
ReplicaSet lock.
"""
from __future__ import annotations

import logging
import threading
import time
from collections import deque

import numpy as np

from repro.storage.wal import WalRecord

log = logging.getLogger("repro.replication")

SEARCH = "search"

_GAP = object()   # sentinel: the needed seqno was evicted from the window


def states_equal(tree_a, tree_b, *, ignore_dirty: bool = True) -> bool:
    """Bit-exact pytree equality (shape + dtype + raw bytes per leaf) —
    the parity check behind "replicas are bit-identical at equal seqno".

    ``ignore_dirty`` masks the block pool's dirty-block bitmap before
    comparing: that leaf is CHECKPOINT bookkeeping (which blocks changed
    since the last snapshot unit), and only the primary checkpoints —
    every index-content leaf (payloads, ids, versions, postings,
    telemetry, stats) is still compared bit-for-bit.  Pass False for
    literal full-state parity on services that never checkpoint."""
    import jax

    if ignore_dirty and hasattr(tree_a, "pool") and hasattr(tree_b, "pool"):
        from repro.storage.blockpool import clear_dirty

        tree_a = tree_a.replace(pool=clear_dirty(tree_a.pool))
        tree_b = tree_b.replace(pool=clear_dirty(tree_b.pool))
    la = jax.tree_util.tree_leaves(tree_a)
    lb = jax.tree_util.tree_leaves(tree_b)
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        ax, ay = np.asarray(x), np.asarray(y)
        if ax.shape != ay.shape or ax.dtype != ay.dtype:
            return False
        if ax.tobytes() != ay.tobytes():
            return False
    return True


class _Replica:
    """One read replica: a cloned backend + its worker thread's state."""

    def __init__(self, idx: int, backend):
        self.idx = idx
        self.backend = backend
        self.cond = threading.Condition()
        self.batches: deque = deque()     # routed search batches (guarded
                                          # by ReplicaSet._lock)
        self.thread: threading.Thread | None = None
        self.inflight = 0                 # routed-but-unfinished batches
        self.paused = False               # test hook: stop applying records
        self.error: BaseException | None = None
        # counters (single-writer: the worker; racy reads are benign)
        self.batches_served = 0
        self.rows_served = 0
        self.records_applied = 0
        self.catchups = 0

    @property
    def applied(self) -> int:
        return int(self.backend._wal_applied)


class ReplicaSet:
    """N-1 read replicas behind one primary, fed by the publish sink.

    Implements the ``publish(seqno, op, payload)`` sink protocol of
    ``DurableBackend.attach_replication`` plus the engine-facing routing
    surface (``route`` / ``idle`` / ``report``).  ``n_replicas`` in specs
    counts TOTAL copies including the primary, so a ReplicaSet holds
    ``n_replicas - 1`` clone backends.
    """

    def __init__(self, primary, replicas, *, max_lag: int = 64,
                 inflight: int = 2, window: int = 256):
        assert window >= 1 and inflight >= 1 and max_lag >= 0
        self.primary = primary
        self.replicas = [_Replica(i, b) for i, b in enumerate(replicas)]
        self.max_lag = max_lag
        self.inflight_cap = inflight
        self.window_cap = window
        self._engine = None
        self._lock = threading.Lock()     # routing + inflight bookkeeping
        self._wlock = threading.Lock()    # the replication window
        self._window: deque[WalRecord] = deque()
        self._head = int(primary._wal_applied)
        self._stopev = threading.Event()
        self._rr = 0
        # global counters
        self.published = 0
        self.routed = 0
        self.fallback = 0

    # --------------------------- lifecycle -----------------------------
    def bind(self, engine) -> None:
        """Attach the engine whose pump routes batches here (gives the
        workers access to ``exclusive()`` for catch-up and to the metrics
        sink for routed-search latencies)."""
        self._engine = engine

    def start(self) -> None:
        for r in self.replicas:
            if r.thread is not None:
                continue
            t = threading.Thread(
                target=self._run, args=(r,),
                name=f"spfresh-replica-{r.idx}", daemon=True,
            )
            r.thread = t
            t.start()

    def stop(self, timeout: float = 60.0) -> None:
        """Stop the workers.  Routed batches still pending are served
        first so no search ticket is stranded; unapplied tail records are
        abandoned (the replicas are caches — the WAL is truth)."""
        self._stopev.set()
        for r in self.replicas:
            with r.cond:
                r.cond.notify_all()
        for r in self.replicas:
            t = r.thread
            if t is not None:
                t.join(timeout)
                if t.is_alive():
                    raise RuntimeError(
                        f"replica worker {r.idx} failed to stop"
                    )
            r.thread = None

    # ------------------------- publish (sink) --------------------------
    def publish(self, seqno: int, op: str, payload: dict) -> None:
        """Called by the primary's ``_log`` on the pump thread, after the
        WAL append.  Payload arrays are copied: the engine reuses batch
        staging buffers, so a reference would be overwritten before a
        slow replica replays it."""
        rec = WalRecord(
            op=op,
            payload={
                k: np.array(v, copy=True) if isinstance(v, np.ndarray)
                else v
                for k, v in payload.items()
            },
            seqno=seqno,
        )
        with self._wlock:
            self._window.append(rec)
            while len(self._window) > self.window_cap:
                self._window.popleft()
            self._head = seqno
            self.published += 1
        for r in self.replicas:
            with r.cond:
                r.cond.notify()

    def _next_record(self, r: _Replica):
        """The record after ``r``'s cursor: a WalRecord, None (caught
        up), or ``_GAP`` (evicted — snapshot catch-up needed)."""
        cursor = r.applied
        with self._wlock:
            if self._head <= cursor:
                return None
            if not self._window or self._window[0].seqno > cursor + 1:
                return _GAP
            return self._window[cursor + 1 - self._window[0].seqno]

    # --------------------------- routing -------------------------------
    def route(self, batch) -> bool:
        """Offer a SEARCH micro-batch to a replica (pump thread, under
        the engine's ``_work``).  Returns True when routed; False means
        the caller serves it on the primary (fallback)."""
        if batch.op != SEARCH or not self.replicas:
            return False
        primary_seq = int(self.primary._wal_applied)
        with self._lock:
            n = len(self.replicas)
            for i in range(n):
                r = self.replicas[(self._rr + i) % n]
                if r.error is not None or r.inflight >= self.inflight_cap:
                    continue
                if primary_seq - r.applied > self.max_lag:
                    continue  # staler than the freshness bound
                self._rr = (self._rr + i + 1) % n
                # copy out of the queue's reused staging buffers
                batch.arrays = {
                    k: np.array(v, copy=True)
                    for k, v in batch.arrays.items()
                }
                r.inflight += 1
                r.batches.append(batch)
                self.routed += 1
                routed_to = r
                break
            else:
                self.fallback += 1
                return False
        with routed_to.cond:
            routed_to.cond.notify()
        return True

    def idle(self) -> bool:
        """No routed batch pending or in flight (the engine's barrier
        folds this into its quiescence condition)."""
        with self._lock:
            return all(r.inflight == 0 and not r.batches
                       for r in self.replicas)

    # ------------------------- worker thread ---------------------------
    def _run(self, r: _Replica) -> None:
        try:
            while True:
                with self._lock:
                    batch = r.batches.popleft() if r.batches else None
                if batch is not None:
                    self._serve(r, batch)
                    continue
                if self._stopev.is_set():
                    return
                did = False
                if not r.paused:
                    nxt = self._next_record(r)
                    if nxt is _GAP:
                        self._catch_up(r)
                        did = True
                    elif nxt is not None:
                        r.backend.replay([nxt], after_seqno=r.applied)
                        r.records_applied += 1
                        did = True
                if not did:
                    with r.cond:
                        with self._lock:
                            has_work = bool(r.batches)
                        if not has_work:
                            r.cond.wait(0.005)
        except BaseException as e:  # noqa: BLE001 — fail the replica, not
            self._fail(r, e)        # the service

    def _serve(self, r: _Replica, batch) -> None:
        """Serve one routed search batch on the replica's own state."""
        k, nprobe = batch.key
        d, v = r.backend.search(batch.arrays["queries"], k, nprobe,
                                batch.valid)
        batch.scatter({"dists": d, "ids": v})
        eng = self._engine
        for part in batch.parts:
            t = part.ticket
            if t.done:
                if eng is not None:
                    eng.metrics.note_ticket(t)
                t._signal()
        with self._lock:
            r.inflight -= 1
            r.batches_served += 1
            r.rows_served += batch.n_valid

    def _catch_up(self, r: _Replica) -> None:
        """Snapshot catch-up — the crash-recovery path: fork the
        primary's state at a known seqno (under the engine's exclusive
        lock, so no dispatch is mid-flight), adopt it, resume tail
        replay.  MUST NOT hold any ReplicaSet lock here (lock order:
        ``_work`` is always taken before ReplicaSet locks)."""
        eng = self._engine
        if eng is not None:
            with eng.exclusive():
                state = self.primary.fork_state()
                seqno = int(self.primary._wal_applied)
        else:
            state = self.primary.fork_state()
            seqno = int(self.primary._wal_applied)
        r.backend.adopt_state(state)
        r.backend._wal_applied = seqno
        r.catchups += 1
        log.info("replica %d caught up by snapshot at seqno %d",
                 r.idx, seqno)

    def _fail(self, r: _Replica, e: BaseException) -> None:
        """Take a replica out of rotation and hand its pending batches
        back to the engine queue (the pump re-serves them on the primary
        or another replica)."""
        r.error = e
        log.exception("replica %d worker died; rerouting its batches",
                      r.idx)
        with self._lock:
            pending = list(r.batches)
            r.batches.clear()
            r.inflight -= len(pending)
        eng = self._engine
        for b in pending:
            if eng is not None:
                eng.queue.requeue(b.parts)
            else:  # no engine to reroute through: mask the rows out
                k = b.key[0] if b.key else 0
                b.scatter({
                    "dists": np.full((b.bucket, k), np.inf, np.float32),
                    "ids": np.full((b.bucket, k), -1, np.int32),
                })
                for part in b.parts:
                    part.ticket._signal()

    # --------------------------- test hooks ----------------------------
    def pause(self, i: int) -> None:
        """Stop replica ``i`` applying records (induces seqno lag)."""
        self.replicas[i].paused = True

    def resume(self, i: int) -> None:
        r = self.replicas[i]
        r.paused = False
        with r.cond:
            r.cond.notify()

    def wait_sync(self, timeout: float = 60.0) -> None:
        """Block until every live, unpaused replica has applied the
        primary's current seqno (quiesce the primary first — e.g.
        ``engine.barrier()`` — or this chases a moving target)."""
        deadline = time.monotonic() + timeout
        while True:
            prim = int(self.primary._wal_applied)
            lagging = [
                r.idx for r in self.replicas
                if r.error is None and not r.paused and r.applied < prim
            ]
            if not lagging:
                return
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"replicas {lagging} still behind seqno {prim} "
                    f"after {timeout}s"
                )
            time.sleep(0.001)

    # ---------------------------- metrics ------------------------------
    def report(self) -> dict:
        primary_seq = int(self.primary._wal_applied)
        with self._lock:
            reps = [
                {
                    "replica": r.idx,
                    "applied_seqno": r.applied,
                    "lag": max(0, primary_seq - r.applied),
                    "batches": r.batches_served,
                    "rows": r.rows_served,
                    "records_applied": r.records_applied,
                    "catchups": r.catchups,
                    "paused": r.paused,
                    "failed": r.error is not None,
                }
                for r in self.replicas
            ]
        return {
            "n_replicas": len(self.replicas) + 1,
            "primary_seqno": primary_seq,
            "published": self.published,
            "routed_batches": self.routed,
            "fallback_primary": self.fallback,
            "max_lag": self.max_lag,
            "inflight_cap": self.inflight_cap,
            "window": self.window_cap,
            "per_replica": reps,
        }
