"""Distribution layer: production meshes, per-family sharding rules,
shard_map'd sharded index, distributed top-k, elastic re-sharding."""
