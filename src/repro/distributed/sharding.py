"""PartitionSpec rules for every model family + the index.

Axis semantics on the production mesh (see launch/mesh.py):
  * ``pod``   — outermost replication/DP axis (multi-pod only)
  * ``data``  — DP/FSDP axis
  * ``model`` — TP/EP/vocab axis; also the index-shard axis

Rules of thumb applied here:
  * params: FSDP over ``data`` on the d_model-ish dimension, TP over
    ``model`` on heads/ffn/vocab/experts
  * batch: sharded over (pod, data)
  * optimizer state: identical specs as the param it tracks
  * a weight axis is sharded over ``model`` only when divisible by the
    model-axis size (checked by the caller via divisor arguments)
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P


def data_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def make_replicated_mesh(n_replicas: int, n_shards: int,
                         axes: tuple[str, str] = ("data", "model")):
    """The 2-axis (data, model) mesh of a replicated sharded service:
    the model axis shards postings (unchanged), the data axis holds
    ``n_replicas`` full copies of the index.  Needs
    ``n_replicas * n_shards`` devices."""
    assert n_replicas >= 1 and n_shards >= 1
    return jax.make_mesh((n_replicas, n_shards), axes)


def replica_submeshes(mesh, replica_axis: str = "data"):
    """Split a replicated mesh into one single-row submesh per replica
    (each over the remaining axes).  Row 0 is the primary's mesh; every
    replica's shard_map'd steps compile against its own row, so the
    per-shard step code is identical to the unreplicated path."""
    from jax.sharding import Mesh

    import numpy as np

    axis = mesh.axis_names.index(replica_axis)
    devs = np.moveaxis(np.asarray(mesh.devices), axis, 0)
    rest = tuple(a for a in mesh.axis_names if a != replica_axis)
    return [Mesh(devs[i], rest) for i in range(devs.shape[0])]


def current_mesh():
    """The ambient (abstract) mesh, across jax versions.

    Newer jax: ``jax.sharding.get_abstract_mesh()`` (set via
    ``jax.set_mesh``).  Older jax: the physical mesh installed by the
    ``with mesh:`` context.  Returns None when no mesh is active.
    """
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        am = get_am()
        return am if am.axis_names else None
    from jax._src import mesh as mesh_lib

    pm = mesh_lib.thread_resources.env.physical_mesh
    return pm if pm.axis_names else None


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` on newer jax, ``with mesh:`` on older."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # Mesh is itself a context manager on older jax


def _axes_size(am, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for n in names:
        size *= am.shape[n]
    return size


def _guarded_constraint(x, am, spec_entries):
    """Apply with_sharding_constraint, dropping axes that don't divide."""
    entries = []
    for dim, entry in zip(x.shape, spec_entries):
        if entry is not None and dim % _axes_size(am, entry) != 0:
            entry = None  # degrade: replicate this dim
        entries.append(entry)
    return jax.lax.with_sharding_constraint(x, P(*entries))


def act_constraint(x, *tail):
    """Mesh-adaptive activation sharding constraint.

    Shards dim 0 over every non-'model' mesh axis and the remaining dims per
    ``tail`` (e.g. ``act_constraint(x, None, 'model')`` for a (B, S, d)
    residual stream).  Dims that don't divide their axis set are left
    replicated.  No-op when tracing without a mesh context (CPU smoke
    tests) — the dry-run sets the mesh via ``jax.set_mesh``.
    """
    am = current_mesh()
    if am is None or "model" not in am.axis_names:
        return x
    da = tuple(a for a in am.axis_names if a != "model")
    return _guarded_constraint(x, am, (da if da else None, *tail))


def act_constraint_leading(x, lead, *tail):
    """Like :func:`act_constraint` but dim 0 shards over ``lead`` (e.g.
    'model' for expert-parallel buffers) and dim 1 over the data axes."""
    am = current_mesh()
    if am is None or "model" not in am.axis_names:
        return x
    da = tuple(a for a in am.axis_names if a != "model")
    return _guarded_constraint(x, am, (lead, da if da else None, *tail))


def act_constraint_flat2d(x):
    """Rows of a 2D buffer sharded over ('model', data-axes) flattened —
    the flat form of an (E over model, C over data) expert buffer, placed
    BEFORE the split-dim reshape so GSPMD treats the reshape as free."""
    am = current_mesh()
    if am is None or "model" not in am.axis_names:
        return x
    da = tuple(a for a in am.axis_names if a != "model")
    return _guarded_constraint(x, am, (("model", *da), None))


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def lm_param_specs(cfg, *, model_size: int = 16, multi_pod: bool = False):
    """Pytree of PartitionSpec matching transformer.init_params structure."""
    da = data_axes(multi_pod)
    fs = da[-1]  # FSDP axis ("data")
    kv_width = cfg.n_kv_heads * cfg.hd
    kv_model = "model" if kv_width % model_size == 0 else None
    layer = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "wq": P(None, fs, "model"),
        "wk": P(None, fs, kv_model),
        "wv": P(None, fs, kv_model),
        "wo": P(None, "model", fs),
    }
    if cfg.qkv_bias:
        layer["bq"] = P(None, "model")
        layer["bk"] = P(None, kv_model)
        layer["bv"] = P(None, kv_model)
    if cfg.moe:
        e_model = "model" if cfg.n_experts % model_size == 0 else None
        layer["moe"] = {
            "router": P(None, fs, None),
            "wi_gate": P(None, e_model, fs, None),
            "wi_up": P(None, e_model, fs, None),
            "wo": P(None, e_model, None, fs),
        }
    else:
        layer["mlp"] = {
            "wi_gate": P(None, fs, "model"),
            "wi_up": P(None, fs, "model"),
            "wo": P(None, "model", fs),
        }
    return {
        "embed": P("model", fs),
        "layers": layer,
        "final_norm": P(None),
        "lm_head": P(fs, "model"),
    }


def lm_batch_specs(kind: str, *, multi_pod: bool = False):
    da = data_axes(multi_pod)
    if kind in ("train", "prefill"):
        return {"tokens": P(da, None), "labels": P(da, None)} if kind == "train" \
            else {"tokens": P(da, None)}
    if kind == "decode":
        return {
            "cache": {
                "k": P(None, da, None, None, None),
                "v": P(None, da, None, None, None),
            },
            "tokens": P(da),
            "pos": P(),
        }
    raise ValueError(kind)


def lm_cache_specs(multi_pod: bool = False):
    # (L, B, S, KH, hd): batch over data axes, SEQUENCE over model —
    # kv-head counts (1..8) don't divide the 16-way model axis, and a 32k
    # cache replicated over model would blow per-device HBM.
    da = data_axes(multi_pod)
    return {
        "k": P(None, da, "model", None, None),
        "v": P(None, da, "model", None, None),
    }


# ---------------------------------------------------------------------------
# GNN family — edge-parallel: edges sharded over every axis, nodes replicated
# ---------------------------------------------------------------------------

def gnn_param_specs(params_tree: Any):
    return jax.tree_util.tree_map(lambda _: P(), params_tree)


def gnn_batch_specs(batch_tree: dict, *, multi_pod: bool = False):
    axes = (("pod", "data", "model") if multi_pod else ("data", "model"))
    specs = {}
    for k, v in batch_tree.items():
        if k in ("edge_src", "edge_dst"):
            specs[k] = P(axes)
        elif k == "n_graphs":
            specs[k] = None
        else:
            specs[k] = P(*([None] * getattr(v, "ndim", 0)))
    return specs


# ---------------------------------------------------------------------------
# Recsys family — tables row-sharded over model, batch over (pod, data)
# ---------------------------------------------------------------------------

def recsys_param_specs(params_tree: Any, *, model_size: int = 16,
                       multi_pod: bool = False):
    """Any leaf with >= 2**16 rows is treated as an embedding table
    (row-sharded over 'model'); everything else FSDP over 'data' on dim 0
    when divisible, else replicated."""
    da = data_axes(multi_pod)
    fs = da[-1]

    import math

    def rule(leaf):
        shape = leaf.shape
        if (len(shape) == 2 and shape[0] >= (1 << 16)
                and shape[0] % model_size == 0):
            return P("model", None)
        # FSDP only pays for itself on big weights: sharding a tiny tower
        # MLP over 'data' forces the huge per-candidate activations through
        # contraction-partial all-reduces (§Perf: 512 MB/step at
        # retrieval_cand).  Replicate anything under 2^22 elements.
        if (len(shape) >= 1 and shape[0] % model_size == 0
                and shape[0] >= 256 and math.prod(shape) >= (1 << 22)):
            return P(fs, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map(rule, params_tree)


def recsys_batch_specs(batch_tree: dict, *, multi_pod: bool = False):
    da = data_axes(multi_pod)
    da_size = 32 if multi_pod else 16
    specs = {}
    for k, v in batch_tree.items():
        ndim = getattr(v, "ndim", 0)
        if k == "candidate_ids":
            # candidates shard over 'model' (1M % 16 == 0; the full data×
            # model product does not divide 1M)
            specs[k] = P("model")
        elif ndim == 0:
            specs[k] = P()
        elif v.shape[0] % da_size != 0:
            # retrieval_cand has batch=1: replicate tiny leading dims
            specs[k] = P(*([None] * ndim))
        else:
            specs[k] = P(da, *([None] * (ndim - 1)))
    return specs


# ---------------------------------------------------------------------------
# Optimizer state: mirror the param specs
# ---------------------------------------------------------------------------

def opt_state_specs(param_specs_tree: Any):
    return {
        "m": param_specs_tree,
        "v": param_specs_tree,
        "count": P(),
    }
