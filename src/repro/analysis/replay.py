"""Replay-determinism pass (SPF10x).

WAL recovery replays the logged dispatch stream through the jit-step
builders; the result is bit-identical to the live run ONLY if (a) every
config field those dispatches read is pinned by the snapshot stamp (or
provably serving-side and declared exempt), and (b) nothing on the
dispatch path consults wall clocks, unseeded RNG, or set iteration
order.  This pass walks the conservative call graph from the declared
roots and checks both.

The call graph is reference-based: any Name/Attribute inside a function
that resolves to a known function counts as an edge — which naturally
covers ``jax.jit(f)``, ``functools.partial(lire.search, ...)``,
``lax.scan(body, ...)`` and decorator wrapping, at the cost of a few
false edges (conservative = more code scanned, never less).
"""
from __future__ import annotations

import ast
import dataclasses

from repro.analysis.common import (
    Finding, Module, enclosing_symbol, literal_str_tuple, module_assign,
)
from repro.analysis.config import ReplaySpec

# numpy.random callables that read/seed MODULE-GLOBAL state; an explicit
# Generator from a seeded default_rng(seed) is fine.
_NP_RANDOM_GLOBAL = {
    "random", "rand", "randn", "randint", "integers", "choice", "shuffle",
    "permutation", "normal", "uniform", "seed", "standard_normal",
}


# ---------------------------------------------------------------------------
# Import + symbol resolution
# ---------------------------------------------------------------------------

def _import_map(mod: Module) -> dict[str, tuple[str, str]]:
    """{local name: ("mod", dotted) | ("sym", "dotted:attr")}."""
    out: dict[str, tuple[str, str]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    "mod", a.name if a.asname else a.name.split(".")[0]
                )
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = ("sym", f"{node.module}:{a.name}")
    return out


def _function_table(
    modules: dict[str, Module]
) -> dict[tuple[str, str], ast.AST]:
    table: dict[tuple[str, str], ast.AST] = {}
    for mod in modules.values():
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                table[(mod.name, node.name)] = node
            elif isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        table[(mod.name, f"{node.name}.{sub.name}")] = sub
    return table


def _callees(
    mod: Module, fn: ast.AST, cls_name: str | None,
    modules: dict[str, Module],
    table: dict[tuple[str, str], ast.AST],
) -> set[tuple[str, str]]:
    imap = _import_map(mod)
    edges: set[tuple[str, str]] = set()

    def resolve(name_mod: str, name_fn: str) -> None:
        if (name_mod, name_fn) in table:
            edges.add((name_mod, name_fn))

    for node in ast.walk(fn):
        if isinstance(node, ast.Name):
            # same-module function, or a from-imported symbol
            resolve(mod.name, node.id)
            tgt = imap.get(node.id)
            if tgt and tgt[0] == "sym":
                m, s = tgt[1].split(":")
                resolve(m, s)
        elif isinstance(node, ast.Attribute):
            v = node.value
            if isinstance(v, ast.Name):
                if v.id == "self" and cls_name is not None:
                    resolve(mod.name, f"{cls_name}.{node.attr}")
                tgt = imap.get(v.id)
                if tgt is None:
                    continue
                if tgt[0] == "mod":
                    resolve(tgt[1], node.attr)
                else:  # `from pkg import mod` — the name may BE a module
                    resolve(tgt[1].replace(":", "."), node.attr)
    return edges


def reachable_functions(
    modules: dict[str, Module], roots: tuple[str, ...]
) -> dict[tuple[str, str], ast.AST]:
    """BFS over the reference graph; raises on a root the tree lacks
    (spec drift must fail loudly, not silently shrink coverage)."""
    table = _function_table(modules)
    queue: list[tuple[str, str]] = []
    for r in roots:
        m, q = r.split(":")
        if (m, q) not in table:
            raise ValueError(f"replay root not found in tree: {r}")
        queue.append((m, q))
    seen: dict[tuple[str, str], ast.AST] = {}
    while queue:
        key = queue.pop()
        if key in seen:
            continue
        fn = table[key]
        seen[key] = fn
        mod = modules[key[0]]
        cls = key[1].split(".")[0] if "." in key[1] else None
        queue.extend(_callees(mod, fn, cls, modules, table))
    return seen


# ---------------------------------------------------------------------------
# Config class introspection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ConfigShape:
    fields: set[str]
    properties: dict[str, set[str]]   # property -> underlying field reads
    class_line: int
    module: Module


def _config_shape(modules: dict[str, Module], ref: str) -> ConfigShape:
    mod_name, cls_name = ref.split(":")
    mod = modules.get(mod_name)
    if mod is None:
        raise ValueError(f"config module not in tree: {mod_name}")
    for node in mod.tree.body:
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            fields: set[str] = set()
            props: dict[str, set[str]] = {}
            for sub in node.body:
                if isinstance(sub, ast.AnnAssign) and isinstance(
                    sub.target, ast.Name
                ):
                    fields.add(sub.target.id)
                elif isinstance(sub, ast.FunctionDef) and any(
                    isinstance(d, ast.Name) and d.id == "property"
                    for d in sub.decorator_list
                ):
                    reads = {
                        n.attr for n in ast.walk(sub)
                        if isinstance(n, ast.Attribute)
                        and isinstance(n.value, ast.Name)
                        and n.value.id == "self"
                    }
                    props[sub.name] = reads
            # properties may read other properties — expand one level
            for name, reads in props.items():
                expanded = set()
                for r in reads:
                    expanded |= props.get(r, {r} if r in fields else set())
                props[name] = expanded & fields | (reads & fields)
            return ConfigShape(fields, props, node.lineno, mod)
    raise ValueError(f"config class not found: {ref}")


def _stamp_tuple(
    modules: dict[str, Module], ref: str
) -> tuple[tuple[str, ...], Module, int]:
    mod_name, name = ref.split(":")
    mod = modules.get(mod_name)
    if mod is None:
        raise ValueError(f"stamp module not in tree: {mod_name}")
    node = module_assign(mod, name)
    if node is None:
        raise ValueError(f"stamp tuple not found: {ref}")
    vals = literal_str_tuple(node)
    if vals is None:
        raise ValueError(f"stamp tuple is not a string-literal tuple: {ref}")
    return vals, mod, node.lineno


# ---------------------------------------------------------------------------
# Per-function scans
# ---------------------------------------------------------------------------

def _cfg_aliases(fn: ast.AST) -> set[str]:
    """Local names bound from ``<expr>.cfg`` (e.g. ``cfg = state.cfg``)."""
    out = {"cfg"}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(
            node.value, ast.Attribute
        ) and node.value.attr == "cfg":
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out.add(tgt.id)
    return out


def _cfg_reads(fn: ast.AST, shape: ConfigShape) -> list[tuple[str, int]]:
    """(field-or-property, line) reads of the config inside ``fn``."""
    aliases = _cfg_aliases(fn)
    reads = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Attribute):
            continue
        v = node.value
        via_alias = isinstance(v, ast.Name) and v.id in aliases
        via_chain = isinstance(v, ast.Attribute) and v.attr == "cfg"
        if (via_alias or via_chain) and (
            node.attr in shape.fields or node.attr in shape.properties
        ):
            reads.append((node.attr, node.lineno))
    return reads


def _nondeterminism(
    mod: Module, fn: ast.AST, qual: str
) -> list[Finding]:
    imap = _import_map(mod)

    def module_of(name: str) -> str | None:
        tgt = imap.get(name)
        if tgt is None:
            return None
        return tgt[1] if tgt[0] == "mod" else tgt[1].replace(":", ".")

    out: list[Finding] = []

    def emit(rule: str, line: int, msg: str) -> None:
        out.append(Finding(rule, mod.rel, line, f"{mod.name}.{qual}", msg))

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            # time.* / datetime.now — wall clock on the dispatch path
            if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
                m = module_of(f.value.id)
                if m == "time":
                    emit("SPF101", node.lineno,
                         f"wall-clock call time.{f.attr}() on a "
                         "replay-critical path")
                elif m == "random":
                    emit("SPF102", node.lineno,
                         f"process-global RNG random.{f.attr}() on a "
                         "replay-critical path")
            # np.random.<fn> — module-global numpy RNG state
            if isinstance(f, ast.Attribute) and isinstance(
                f.value, ast.Attribute
            ) and f.value.attr == "random" and isinstance(
                f.value.value, ast.Name
            ) and module_of(f.value.value.id) == "numpy":
                if f.attr == "default_rng":
                    if not node.args and not node.keywords:
                        emit("SPF102", node.lineno,
                             "np.random.default_rng() without a seed on a "
                             "replay-critical path")
                elif f.attr in _NP_RANDOM_GLOBAL:
                    emit("SPF102", node.lineno,
                         f"module-global np.random.{f.attr}() on a "
                         "replay-critical path")
            # bare default_rng() imported from numpy.random
            if isinstance(f, ast.Name):
                tgt = imap.get(f.id)
                if (
                    tgt == ("sym", "numpy.random:default_rng")
                    and not node.args and not node.keywords
                ):
                    emit("SPF102", node.lineno,
                         "default_rng() without a seed on a "
                         "replay-critical path")
        # iteration over a set: order varies across processes (hash
        # randomization), so any dispatch built from it diverges on replay
        iters = []
        if isinstance(node, ast.For):
            iters.append(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                               ast.DictComp)):
            iters.extend(g.iter for g in node.generators)
        for it in iters:
            is_set = isinstance(it, (ast.Set, ast.SetComp)) or (
                isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                and it.func.id in ("set", "frozenset")
            )
            if is_set:
                emit("SPF103", it.lineno,
                     "iteration over a set in replay-critical dispatch "
                     "construction (hash order is per-process)")
    return out


# ---------------------------------------------------------------------------
# The pass
# ---------------------------------------------------------------------------

def run(modules: dict[str, Module], spec: ReplaySpec) -> list[Finding]:
    findings: list[Finding] = []
    shape = _config_shape(modules, spec.config_class)
    critical, crit_mod, crit_line = _stamp_tuple(modules, spec.critical_stamp)
    exempt, ex_mod, ex_line = _stamp_tuple(modules, spec.exempt_stamp)
    classified = set(critical) | set(exempt)

    # SPF105/106 — the classification itself must partition the config
    for f in sorted(shape.fields - classified):
        findings.append(Finding(
            "SPF105", crit_mod.rel, crit_line,
            enclosing_symbol(crit_mod, crit_line),
            f"config field {f!r} is in neither "
            "REPLAY_CRITICAL_FIELDS nor REPLAY_EXEMPT_FIELDS",
        ))
    for name, where_mod, where_line in (
        [(n, crit_mod, crit_line) for n in critical]
        + [(n, ex_mod, ex_line) for n in exempt]
    ):
        if name not in shape.fields:
            findings.append(Finding(
                "SPF106", where_mod.rel, where_line,
                enclosing_symbol(where_mod, where_line),
                f"stamped name {name!r} is not a config field (stale stamp)",
            ))

    # SPF101–104 over the reachable dispatch surface
    for (mod_name, qual), fn in sorted(
        reachable_functions(modules, spec.roots).items()
    ):
        mod = modules[mod_name]
        findings.extend(_nondeterminism(mod, fn, qual))
        for field, line in _cfg_reads(fn, shape):
            under = shape.properties.get(field, {field})
            missing = sorted(set(under) - classified)
            if missing:
                findings.append(Finding(
                    "SPF104", mod.rel, line, f"{mod.name}.{qual}",
                    f"config read .{field} on the replay path but "
                    f"{missing} stamped in neither REPLAY_CRITICAL_FIELDS "
                    "nor REPLAY_EXEMPT_FIELDS",
                ))
    return findings
