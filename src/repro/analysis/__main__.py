"""spflint CLI: ``python -m repro.analysis src``.

Runs the three passes over a source tree, prints findings, and exits
nonzero on any finding not covered by the baseline — the CI ratchet.

    python -m repro.analysis src                  # check (exit 1 on new)
    python -m repro.analysis src --json out.json  # + machine report
    python -m repro.analysis src --write-baseline # accept current findings
    python -m repro.analysis --rules              # rule table
    python -m repro.analysis src --table          # per-kernel VMEM table
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import run_all
from repro.analysis.common import (
    RULES, load_baseline, split_by_baseline, write_baseline,
)


def _print_rules() -> None:
    for rule, desc in sorted(RULES.items()):
        print(f"{rule}  {desc}")


def _print_table(table: list[dict], budget_mib: float) -> None:
    print(f"per-kernel VMEM at the reference shape (budget {budget_mib:.0f} "
          "MiB, double-buffered):")
    for row in table:
        ops = " + ".join(
            f"{'x'.join(map(str, o['shape']))}:{o['dtype']}"
            for o in row["operands"]
        )
        print(f"  {row['vmem_mib']:8.3f} MiB  {row['kernel']:<24} "
              f"grid={tuple(row['grid'])}  [{ops}]")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("root", nargs="?", default="src",
                    help="source tree to analyze (default: src)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full JSON report here")
    ap.add_argument("--baseline", metavar="PATH",
                    default="tools/spflint_baseline.json",
                    help="suppression file (default: "
                         "tools/spflint_baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline")
    ap.add_argument("--rules", action="store_true",
                    help="print the rule table and exit")
    ap.add_argument("--table", action="store_true",
                    help="print the per-kernel VMEM table")
    args = ap.parse_args(argv)

    if args.rules:
        _print_rules()
        return 0

    root = Path(args.root)
    if not root.is_dir():
        print(f"spflint: no such source tree: {root}", file=sys.stderr)
        return 2

    result = run_all(root)
    findings = result["findings"]
    baseline = load_baseline(Path(args.baseline))
    new, suppressed = split_by_baseline(findings, baseline)

    if args.write_baseline:
        write_baseline(Path(args.baseline), findings)
        print(f"spflint: wrote {len(findings)} suppression(s) to "
              f"{args.baseline}")
        return 0

    if args.table:
        _print_table(result["vmem_table"], result["vmem_budget_mib"])

    for f in new:
        print(f.render())

    if args.json:
        report = {
            "findings": [
                {"rule": f.rule, "file": f.file, "line": f.line,
                 "symbol": f.symbol, "message": f.message}
                for f in new
            ],
            "suppressed": [
                {"rule": f.rule, "file": f.file, "line": f.line,
                 "symbol": f.symbol}
                for f in suppressed
            ],
            "vmem_table": result["vmem_table"],
            "vmem_budget_mib": result["vmem_budget_mib"],
            "rules": RULES,
            "summary": {
                "new": len(new),
                "suppressed": len(suppressed),
                "kernels_analyzed": len(result["vmem_table"]),
            },
        }
        Path(args.json).write_text(json.dumps(report, indent=2) + "\n")

    n_k = len(result["vmem_table"])
    if new:
        print(f"spflint: {len(new)} new finding(s) "
              f"({len(suppressed)} baselined, {n_k} kernels analyzed)")
        return 1
    print(f"spflint: clean ({len(suppressed)} baselined, "
          f"{n_k} kernels analyzed)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
