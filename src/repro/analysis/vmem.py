"""Pallas resource pass (SPF30x).

Statically evaluates every ``pl.pallas_call`` in the kernel modules at
the spec's reference serving shape: BlockSpec block shapes × operand
dtypes × grid → per-kernel VMEM footprint, doubled for Pallas's
double-buffered pipelining.  Scalar-prefetch operands
(``PrefetchScalarGridSpec.num_scalar_prefetch``) live in SMEM and are
excluded — they never appear in ``in_specs``.

Shape symbols are resolved from, in order: the enclosing wrapper's
straight-line integer assignments (``t = p_n // block_p``), its keyword
parameter defaults (``block_q=128``), and the spec bindings.  A symbol
none of those cover is SPF304; a site whose structure the evaluator
does not recognize at all is SPF303 — either way the site is visibly
NOT covered, never silently skipped.

Also flags interpret-only constructs inside kernel bodies (SPF302):
``print``/``breakpoint`` and host ``np.*`` calls trace fine under
``interpret=True`` but have no TPU lowering.
"""
from __future__ import annotations

import ast
import dataclasses

from repro.analysis.common import Finding, Module, enclosing_symbol
from repro.analysis.config import VmemSpec

_DTYPE_BYTES = {
    "float32": 4, "f32": 4, "int32": 4, "i32": 4,
    "bfloat16": 2, "bf16": 2, "float16": 2,
    "int8": 1, "uint8": 1, "bool": 1,
    "float64": 8, "int64": 8,
}


class Unresolved(Exception):
    def __init__(self, symbol: str):
        self.symbol = symbol
        super().__init__(symbol)


class Unanalyzable(Exception):
    pass


@dataclasses.dataclass
class Operand:
    role: str               # "in" | "out"
    shape: tuple[int, ...]
    dtype: str
    nbytes: int


@dataclasses.dataclass
class KernelReport:
    module: str
    file: str
    line: int
    wrapper: str            # enclosing wrapper function qualname
    grid: tuple[int, ...]
    operands: list[Operand]
    vmem_bytes: int         # sum(block bytes) * 2 (double buffering)

    def as_dict(self) -> dict:
        return {
            "kernel": self.wrapper,
            "module": self.module,
            "file": self.file,
            "line": self.line,
            "grid": list(self.grid),
            "operands": [
                {"role": o.role, "shape": list(o.shape), "dtype": o.dtype,
                 "bytes": o.nbytes}
                for o in self.operands
            ],
            "vmem_bytes": self.vmem_bytes,
            "vmem_mib": round(self.vmem_bytes / (1024 * 1024), 3),
        }


# ---------------------------------------------------------------------------
# Symbol environment + expression evaluation
# ---------------------------------------------------------------------------

def _env_for(fn: ast.AST | None, bindings: dict) -> dict[str, int]:
    env = dict(bindings)
    if fn is None:
        return env
    # keyword parameter defaults (block_q=128, ...)
    args = fn.args
    for a, d in zip(args.args[len(args.args) - len(args.defaults):],
                    args.defaults):
        if isinstance(d, ast.Constant) and isinstance(d.value, int):
            env.setdefault(a.arg, d.value)
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if d is not None and isinstance(d, ast.Constant) and \
                isinstance(d.value, int):
            env.setdefault(a.arg, d.value)
    # straight-line integer assignments (t = p_n // block_p)
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            try:
                env[node.targets[0].id] = _eval(node.value, env)
            except (Unresolved, Unanalyzable):
                pass
    return env


def _eval(node: ast.AST, env: dict[str, int]) -> int:
    if isinstance(node, ast.Constant):
        if isinstance(node.value, int):
            return node.value
        raise Unanalyzable
    if isinstance(node, ast.Name):
        if node.id in env:
            return int(env[node.id])
        raise Unresolved(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -_eval(node.operand, env)
    if isinstance(node, ast.BinOp):
        a, b = _eval(node.left, env), _eval(node.right, env)
        if isinstance(node.op, ast.Add):
            return a + b
        if isinstance(node.op, ast.Sub):
            return a - b
        if isinstance(node.op, ast.Mult):
            return a * b
        if isinstance(node.op, (ast.FloorDiv, ast.Div)):
            return a // b
        if isinstance(node.op, ast.Mod):
            return a % b
    raise Unanalyzable


# ---------------------------------------------------------------------------
# pallas_call site parsing
# ---------------------------------------------------------------------------

def _is_pallas_call(node: ast.Call) -> bool:
    f = node.func
    return (
        isinstance(f, ast.Attribute) and f.attr == "pallas_call"
    )


def _kw(node: ast.Call, name: str) -> ast.AST | None:
    for k in node.keywords:
        if k.arg == name:
            return k.value
    return None


def _blockspec_shape(spec: ast.AST) -> ast.AST:
    """The block-shape tuple node of a ``pl.BlockSpec(shape, index_map)``."""
    if isinstance(spec, ast.Call) and isinstance(spec.func, ast.Attribute) \
            and spec.func.attr == "BlockSpec" and spec.args:
        return spec.args[0]
    raise Unanalyzable


def _spec_list(node: ast.AST | None) -> list[ast.AST]:
    if node is None:
        return []
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


def _out_dtypes(node: ast.AST | None) -> list[str]:
    """dtypes from ``jax.ShapeDtypeStruct(shape, jnp.<dtype>)`` entries."""
    out = []
    for e in _spec_list(node):
        if isinstance(e, ast.Call) and len(e.args) >= 2 and isinstance(
            e.args[1], ast.Attribute
        ):
            out.append(e.args[1].attr)
        else:
            out.append("float32")
    return out


def _eval_shape(node: ast.AST, env: dict[str, int]) -> tuple[int, ...]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(_eval(e, env) for e in node.elts)
    raise Unanalyzable


def _unwrap_partial(a: ast.AST) -> str | None:
    if isinstance(a, ast.Name):
        return a.id
    if isinstance(a, ast.Call) and a.args and isinstance(a.args[0], ast.Name):
        f = a.func
        is_partial = (isinstance(f, ast.Attribute) and f.attr == "partial") \
            or (isinstance(f, ast.Name) and f.id == "partial")
        if is_partial:
            return a.args[0].id
    return None


def _kernel_fn_name(node: ast.Call, wrapper: ast.AST | None) -> str | None:
    """Resolve the kernel body reference: ``_kernel``,
    ``functools.partial(_kernel, ...)``, or a local variable bound to
    either form inside the wrapper."""
    if not node.args:
        return None
    name = _unwrap_partial(node.args[0])
    if name is None:
        return None
    # chase one level of local binding: `kernel = functools.partial(_k, ...)`
    for n in ast.walk(wrapper) if wrapper is not None else ():
        if isinstance(n, ast.Assign) and len(n.targets) == 1 and \
                isinstance(n.targets[0], ast.Name) and \
                n.targets[0].id == name:
            inner = _unwrap_partial(n.value)
            if inner is not None:
                return inner
    return name


def _interpret_only(mod: Module, kernel: ast.AST, qual: str) -> list[Finding]:
    out = []
    for node in ast.walk(kernel):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name) and f.id in ("print", "breakpoint"):
            out.append(Finding(
                "SPF302", mod.rel, node.lineno, f"{mod.name}.{qual}",
                f"{f.id}() inside a Pallas kernel body has no TPU "
                "lowering (interpret-only)",
            ))
        elif isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id in ("np", "numpy"):
            out.append(Finding(
                "SPF302", mod.rel, node.lineno, f"{mod.name}.{qual}",
                f"host numpy call np.{f.attr}() inside a Pallas kernel "
                "body (interpret-only; use jnp)",
            ))
    return out


# ---------------------------------------------------------------------------
# The pass
# ---------------------------------------------------------------------------

def _analyze_site(
    mod: Module, call: ast.Call, wrapper: ast.AST | None, wrapper_qual: str,
    spec: VmemSpec,
) -> tuple[KernelReport | None, list[Finding]]:
    findings: list[Finding] = []
    env = _env_for(wrapper, spec.bindings)
    line = call.lineno
    sym = f"{mod.name}.{wrapper_qual}"

    grid_node = _kw(call, "grid")
    in_specs = _kw(call, "in_specs")
    out_specs = _kw(call, "out_specs")
    gs = _kw(call, "grid_spec")
    if gs is not None and isinstance(gs, ast.Name):
        # grid_spec built earlier in the wrapper: find its assignment
        for node in ast.walk(wrapper) if wrapper is not None else ():
            if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name) and \
                    node.targets[0].id == gs.id:
                gs = node.value
    if isinstance(gs, ast.Call):
        grid_node = _kw(gs, "grid") or grid_node
        in_specs = _kw(gs, "in_specs") or in_specs
        out_specs = _kw(gs, "out_specs") or out_specs

    try:
        if grid_node is None or (in_specs is None and out_specs is None):
            raise Unanalyzable
        grid = _eval_shape(grid_node, env)
        overrides = spec.dtype_overrides.get((mod.name, wrapper_qual), {})
        out_dts = _out_dtypes(_kw(call, "out_shape"))
        operands: list[Operand] = []
        for i, s in enumerate(_spec_list(in_specs)):
            shape = _eval_shape(_blockspec_shape(s), env)
            dt = overrides.get(i, "float32")
            nbytes = _DTYPE_BYTES[dt]
            for d in shape:
                nbytes *= d
            operands.append(Operand("in", shape, dt, nbytes))
        outs = _spec_list(out_specs)
        for i, s in enumerate(outs):
            shape = _eval_shape(_blockspec_shape(s), env)
            dt = out_dts[i] if i < len(out_dts) else "float32"
            nbytes = _DTYPE_BYTES.get(dt, 4)
            for d in shape:
                nbytes *= d
            operands.append(Operand("out", shape, dt, nbytes))
        total = 2 * sum(o.nbytes for o in operands)  # double-buffered
        report = KernelReport(
            module=mod.name, file=mod.rel, line=line, wrapper=wrapper_qual,
            grid=grid, operands=operands, vmem_bytes=total,
        )
        if total > spec.budget_bytes:
            findings.append(Finding(
                "SPF301", mod.rel, line, sym,
                f"kernel VMEM footprint {total / 2**20:.2f} MiB exceeds "
                f"the {spec.budget_bytes / 2**20:.0f} MiB per-core budget "
                "at the reference shape",
            ))
        return report, findings
    except Unresolved as e:
        findings.append(Finding(
            "SPF304", mod.rel, line, sym,
            f"shape symbol {e.symbol!r} has no value in the analysis "
            "bindings (add it to VMEM_BINDINGS)",
        ))
    except Unanalyzable:
        findings.append(Finding(
            "SPF303", mod.rel, line, sym,
            "pallas_call site the resource pass cannot statically "
            "evaluate (unrecognized grid/BlockSpec structure)",
        ))
    return None, findings


def run(
    modules: dict[str, Module], spec: VmemSpec
) -> tuple[list[Finding], list[KernelReport]]:
    findings: list[Finding] = []
    reports: list[KernelReport] = []
    for mod in sorted(modules.values(), key=lambda m: m.name):
        if not mod.name.startswith(spec.module_prefixes):
            continue
        # index module functions so sites map to their enclosing wrapper
        fns = {
            n.name: n for n in mod.tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        for call in ast.walk(mod.tree):
            if not (isinstance(call, ast.Call) and _is_pallas_call(call)):
                continue
            qual = enclosing_symbol(mod, call.lineno).removeprefix(
                mod.name + "."
            )
            wrapper = fns.get(qual)
            report, fs = _analyze_site(mod, call, wrapper, qual, spec)
            findings.extend(fs)
            if report is not None:
                reports.append(report)
            kname = _kernel_fn_name(call, wrapper)
            if kname is not None and kname in fns:
                findings.extend(_interpret_only(mod, fns[kname], kname))
    return findings, reports
