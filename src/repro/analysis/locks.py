"""Lock-discipline pass (SPF20x).

Opt-in per class: a class that declares a ``FIELD_OWNERSHIP`` map (see
`repro.serve.ownership`) gets every ``self.<field>`` access in its
methods checked against the declared category:

* ``guarded``   — reads AND writes only inside a lexical
                  ``with self._work:`` block or a ``@holds_work`` method
                  (whose callers are in turn checked, SPF207);
* ``pump``      — written only by the pump thread's methods
                  (``PUMP_METHODS``) or lifecycle methods (which run
                  strictly before/after the pump thread); reads are
                  unrestricted (racy-but-benign pointer/flag reads);
* ``init``      — written only in ``__init__``;
* ``lifecycle`` — written only in ``LIFECYCLE_METHODS`` (+ ``__init__``).

``__init__`` is exempt from the guarded check: construction precedes
sharing.  The map must also be exact: every assigned field appears in it
(SPF205) and every declared field is assigned somewhere (SPF206).
"""
from __future__ import annotations

import ast
import dataclasses

from repro.analysis.common import Finding, Module, literal_str_tuple
from repro.analysis.config import LockSpec

CATEGORIES = ("guarded", "pump", "init", "lifecycle")


@dataclasses.dataclass
class ClassDecl:
    node: ast.ClassDef
    ownership: dict[str, str]
    lock_field: str
    pump_methods: set[str]
    lifecycle_methods: set[str]
    holds_methods: set[str]


def _literal_str_dict(node: ast.AST) -> dict[str, str] | None:
    if not isinstance(node, ast.Dict):
        return None
    out = {}
    for k, v in zip(node.keys, node.values):
        if not (
            isinstance(k, ast.Constant) and isinstance(k.value, str)
            and isinstance(v, ast.Constant) and isinstance(v.value, str)
        ):
            return None
        out[k.value] = v.value
    return out


def _class_decl(cls: ast.ClassDef) -> ClassDecl | None:
    ownership = None
    lock_field = "_work"
    pump: set[str] = set()
    life: set[str] = set()
    for sub in cls.body:
        if isinstance(sub, ast.Assign) and len(sub.targets) == 1 and \
                isinstance(sub.targets[0], ast.Name):
            name = sub.targets[0].id
            if name == "FIELD_OWNERSHIP":
                ownership = _literal_str_dict(sub.value)
            elif name == "LOCK_FIELD":
                if isinstance(sub.value, ast.Constant):
                    lock_field = sub.value.value
            elif name == "PUMP_METHODS":
                pump = set(literal_str_tuple(sub.value) or ())
            elif name == "LIFECYCLE_METHODS":
                life = set(literal_str_tuple(sub.value) or ())
    if ownership is None:
        return None
    holds = set()
    for sub in cls.body:
        if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for d in sub.decorator_list:
                name = d.id if isinstance(d, ast.Name) else (
                    d.attr if isinstance(d, ast.Attribute) else None
                )
                if name == "holds_work":
                    holds.add(sub.name)
    return ClassDecl(cls, ownership, lock_field, pump, life, holds)


def _locked_spans(
    meth: ast.AST, lock_field: str
) -> list[tuple[int, int]]:
    spans = []
    for node in ast.walk(meth):
        if isinstance(node, ast.With):
            for item in node.items:
                e = item.context_expr
                # `with self._work:` or `with self.exclusive():`
                if isinstance(e, ast.Attribute) and isinstance(
                    e.value, ast.Name
                ) and e.value.id == "self" and e.attr == lock_field:
                    spans.append((node.lineno, node.end_lineno))
                elif isinstance(e, ast.Call) and isinstance(
                    e.func, ast.Attribute
                ) and isinstance(e.func.value, ast.Name) and \
                        e.func.value.id == "self" and \
                        e.func.attr == "exclusive":
                    spans.append((node.lineno, node.end_lineno))
    return spans


def _check_class(mod: Module, decl: ClassDecl) -> list[Finding]:
    cls = decl.node
    findings: list[Finding] = []
    assigned: set[str] = set()

    for cat in decl.ownership.values():
        assert cat in CATEGORIES, cat

    for meth in cls.body:
        if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        qual = f"{mod.name}.{cls.name}.{meth.name}"
        is_init = meth.name == "__init__"
        holds = meth.name in decl.holds_methods
        is_pump = meth.name in decl.pump_methods
        is_life = meth.name in decl.lifecycle_methods
        spans = _locked_spans(meth, decl.lock_field)

        def locked(line: int) -> bool:
            return holds or any(a <= line <= b for a, b in spans)

        for node in ast.walk(meth):
            # --- self.<field> accesses against the ownership map ---
            if isinstance(node, ast.Attribute) and isinstance(
                node.value, ast.Name
            ) and node.value.id == "self":
                f = node.attr
                is_store = isinstance(node.ctx, (ast.Store, ast.Del))
                if is_store:
                    assigned.add(f)
                cat = decl.ownership.get(f)
                if cat is None:
                    if is_store and not f.startswith("__"):
                        findings.append(Finding(
                            "SPF205", mod.rel, node.lineno, qual,
                            f"self.{f} assigned but missing from "
                            f"{cls.name}.FIELD_OWNERSHIP",
                        ))
                    continue
                line = node.lineno
                if cat == "guarded" and not is_init and not locked(line):
                    findings.append(Finding(
                        "SPF202" if is_store else "SPF201",
                        mod.rel, line, qual,
                        f"{'write to' if is_store else 'read of'} "
                        f"guarded field self.{f} outside "
                        f"`with self.{decl.lock_field}`",
                    ))
                elif cat == "pump" and is_store and not (
                    is_pump or is_life or is_init
                ):
                    findings.append(Finding(
                        "SPF203", mod.rel, line, qual,
                        f"write to pump-thread-only field self.{f} from "
                        "a non-pump, non-lifecycle method",
                    ))
                elif cat == "init" and is_store and not is_init:
                    findings.append(Finding(
                        "SPF204", mod.rel, line, qual,
                        f"write to init-only field self.{f} outside "
                        "__init__",
                    ))
                elif cat == "lifecycle" and is_store and not (
                    is_life or is_init
                ):
                    findings.append(Finding(
                        "SPF204", mod.rel, line, qual,
                        f"write to lifecycle field self.{f} outside "
                        f"{sorted(decl.lifecycle_methods)}",
                    ))
            # --- calls into @holds_work methods need the lock ---
            if isinstance(node, ast.Call) and isinstance(
                node.func, ast.Attribute
            ) and isinstance(node.func.value, ast.Name) and \
                    node.func.value.id == "self" and \
                    node.func.attr in decl.holds_methods:
                if not (is_init or locked(node.lineno)):
                    findings.append(Finding(
                        "SPF207", mod.rel, node.lineno, qual,
                        f"call to @holds_work method self."
                        f"{node.func.attr}() without holding "
                        f"self.{decl.lock_field}",
                    ))

    for f in sorted(set(decl.ownership) - assigned):
        findings.append(Finding(
            "SPF206", mod.rel, cls.lineno, f"{mod.name}.{cls.name}",
            f"FIELD_OWNERSHIP declares {f!r} but the class never "
            "assigns it (stale declaration)",
        ))
    return findings


def run(modules: dict[str, Module], spec: LockSpec) -> list[Finding]:
    findings: list[Finding] = []
    for mod in modules.values():
        if not mod.name.startswith(spec.module_prefixes):
            continue
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef):
                decl = _class_decl(node)
                if decl is not None:
                    findings.extend(_check_class(mod, decl))
    return findings
