"""spflint: static analysis enforcing the repo's replay / locking /
kernel-resource invariants (see ARCHITECTURE.md, "Static analysis &
enforced invariants").

Pure-stdlib AST passes — importing this package must stay cheap and
jax-free so the CLI can run before the environment can even build an
index (CI's fast tier runs it first).
"""
from __future__ import annotations

from pathlib import Path

from repro.analysis import locks, replay, vmem
from repro.analysis.common import Finding, parse_tree
from repro.analysis.config import DEFAULT_SPEC, AnalysisSpec

__all__ = ["run_all", "Finding", "AnalysisSpec", "DEFAULT_SPEC"]


def run_all(root: Path, spec: AnalysisSpec = DEFAULT_SPEC) -> dict:
    """Run all three passes over the tree at ``root``; returns
    ``{"findings", "vmem_table", "vmem_budget_mib"}`` with findings
    sorted by (file, line, rule)."""
    modules = parse_tree(Path(root))
    findings: list[Finding] = []
    findings += replay.run(modules, spec.replay)
    findings += locks.run(modules, spec.locks)
    vmem_findings, reports = vmem.run(modules, spec.vmem)
    findings += vmem_findings
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return {
        "findings": findings,
        "vmem_table": [r.as_dict() for r in reports],
        "vmem_budget_mib": spec.vmem.budget_bytes / (1024 * 1024),
    }
