"""spflint infrastructure: findings, rule registry, baseline, AST walking.

A *finding* is one rule violation at one source location.  Findings are
keyed for suppression purposes by ``(rule, file, symbol)`` — the enclosing
function/class qualname, NOT the line number — so a checked-in baseline
survives unrelated edits above the finding.  The shipped baseline
(`tools/spflint_baseline.json`) is the CI ratchet: a finding not listed
there fails the run, so the tree can only get cleaner.
"""
from __future__ import annotations

import ast
import dataclasses
import json
from pathlib import Path


# --------------------------------------------------------------------------
# Rule registry (one line per rule; --rules prints this, docs copy it)
# --------------------------------------------------------------------------

RULES: dict[str, str] = {
    # Pass 1 — replay determinism (replay.py)
    "SPF101": "wall-clock read (time.*) reachable from a replay-critical "
              "dispatch path",
    "SPF102": "unseeded RNG (random.* / np.random module state / "
              "default_rng()) reachable from a replay-critical dispatch path",
    "SPF103": "set/dict iteration-order dependence in replay-critical "
              "dispatch construction",
    "SPF104": "config field read on a replay-critical path but stamped in "
              "neither REPLAY_CRITICAL_FIELDS nor REPLAY_EXEMPT_FIELDS",
    "SPF105": "config field classified in neither REPLAY_CRITICAL_FIELDS "
              "nor REPLAY_EXEMPT_FIELDS",
    "SPF106": "stamp names a field the config class does not define "
              "(stale stamp)",
    # Pass 2 — lock discipline (locks.py)
    "SPF201": "read of a guarded field outside the declared lock",
    "SPF202": "write to a guarded field outside the declared lock",
    "SPF203": "write to a pump-thread-only field from a non-pump method",
    "SPF204": "write to an init-only/lifecycle field outside its owner "
              "methods",
    "SPF205": "shared field assigned but missing from FIELD_OWNERSHIP",
    "SPF206": "FIELD_OWNERSHIP declares a field the class never assigns "
              "(stale declaration)",
    "SPF207": "call to a @holds_work method from a site that does not hold "
              "the lock",
    # Pass 3 — Pallas resources (vmem.py)
    "SPF301": "kernel VMEM footprint exceeds the per-core budget",
    "SPF302": "interpret-only construct inside a Pallas kernel body",
    "SPF303": "pallas_call site the resource pass cannot statically "
              "evaluate",
    "SPF304": "shape symbol with no value in the analysis bindings",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    file: str      # repo-relative posix path
    line: int
    symbol: str    # enclosing def/class qualname ("mod.Class.meth")
    message: str

    @property
    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.file, self.symbol)

    def render(self) -> str:
        return f"{self.file}:{self.line}: {self.rule} [{self.symbol}] " \
               f"{self.message}"


# --------------------------------------------------------------------------
# Baseline / suppression file
# --------------------------------------------------------------------------

def load_baseline(path: Path | None) -> set[tuple[str, str, str]]:
    if path is None or not Path(path).exists():
        return set()
    data = json.loads(Path(path).read_text())
    return {
        (s["rule"], s["file"], s["symbol"])
        for s in data.get("suppressions", [])
    }


def write_baseline(path: Path, findings: list[Finding]) -> None:
    data = {
        "version": 1,
        "comment": "spflint suppressions: each entry hides ONE existing "
                   "finding (rule, file, enclosing symbol).  CI fails on "
                   "any finding not listed here — remove entries as "
                   "violations are fixed; never add one without a reason.",
        "suppressions": [
            {"rule": f.rule, "file": f.file, "symbol": f.symbol,
             "reason": "baselined"}
            for f in sorted(findings, key=lambda f: f.key)
        ],
    }
    Path(path).write_text(json.dumps(data, indent=2) + "\n")


def split_by_baseline(
    findings: list[Finding], baseline: set[tuple[str, str, str]]
) -> tuple[list[Finding], list[Finding]]:
    """-> (unsuppressed, suppressed)."""
    new, old = [], []
    for f in findings:
        (old if f.key in baseline else new).append(f)
    return new, old


# --------------------------------------------------------------------------
# Source tree walking
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Module:
    name: str          # dotted module name relative to the tree root
    path: Path
    rel: str           # path to render in findings (posix, repo-relative)
    tree: ast.Module


def parse_tree(
    root: Path, *, rel_to: Path | None = None, skip_dirs: tuple[str, ...] = (
        "__pycache__",
    ),
) -> dict[str, Module]:
    """Parse every ``*.py`` under ``root`` into a {dotted-name: Module} map.

    ``root`` is the directory CONTAINING the top-level package(s) (e.g.
    ``src/`` → modules named ``repro.core.lire``).  ``rel_to`` controls the
    path rendered in findings (defaults to ``root``'s parent so findings
    read ``src/repro/...`` from the repo root).
    """
    root = Path(root).resolve()
    rel_to = Path(rel_to).resolve() if rel_to else root.parent
    out: dict[str, Module] = {}
    for path in sorted(root.rglob("*.py")):
        if any(part in skip_dirs for part in path.parts):
            continue
        parts = path.relative_to(root).with_suffix("").parts
        if parts[-1] == "__init__":
            parts = parts[:-1]
        name = ".".join(parts) if parts else root.name
        try:
            rel = path.relative_to(rel_to).as_posix()
        except ValueError:
            rel = path.as_posix()
        out[name] = Module(
            name=name, path=path, rel=rel,
            tree=ast.parse(path.read_text(), filename=str(path)),
        )
    return out


def qualname_index(mod: Module) -> dict[str, ast.AST]:
    """{qualname: def node} for functions/classes/methods of a module
    (one level of class nesting — the repo's actual shape)."""
    out: dict[str, ast.AST] = {}
    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = node
        elif isinstance(node, ast.ClassDef):
            out[node.name] = node
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    out[f"{node.name}.{sub.name}"] = sub
    return out


def enclosing_symbol(mod: Module, lineno: int) -> str:
    """Qualname of the innermost def/class containing ``lineno`` (module
    name when at top level) — the line-stable suppression key."""
    best, best_span = mod.name, None
    for qual, node in qualname_index(mod).items():
        end = getattr(node, "end_lineno", node.lineno)
        if node.lineno <= lineno <= end:
            span = end - node.lineno
            if best_span is None or span <= best_span:
                best, best_span = f"{mod.name}.{qual}", span
    return best


def literal_str_tuple(node: ast.AST) -> tuple[str, ...] | None:
    """Evaluate a tuple/list of string constants; None if not one."""
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                vals.append(elt.value)
            else:
                return None
        return tuple(vals)
    return None


def module_assign(mod: Module, name: str) -> ast.AST | None:
    """RHS of the (last) top-level assignment to ``name`` in a module."""
    found = None
    for node in mod.tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == name:
                    found = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            if isinstance(node.target, ast.Name) and node.target.id == name:
                found = node.value
    return found
