"""spflint analysis spec: what the passes check, declared as data.

The passes themselves are generic AST machinery (replay.py / locks.py /
vmem.py); everything repo-specific — which jit-step builders are replay
roots, where the stamp tuples live, the VMEM reference serving shape —
is pinned HERE so the fixture tests can aim the same passes at seeded
violation trees with a different spec.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ReplaySpec:
    """Replay-determinism pass inputs.

    ``roots`` are the functions whose transitive callees constitute the
    WAL-replayed dispatch surface: every config field read reachable from
    them must be classified (stamped replay-critical, or explicitly
    exempt with a reason) and no wall-clock / unseeded-RNG / set-order
    dependence may be reachable.
    """

    roots: tuple[str, ...]        # "module:qualname" entries
    config_class: str             # "module:Class" (the frozen config)
    critical_stamp: str           # "module:NAME" tuple of stamped fields
    exempt_stamp: str             # "module:NAME" tuple of exempt fields


@dataclasses.dataclass(frozen=True)
class LockSpec:
    """Lock-discipline pass inputs: modules scanned for classes that
    declare a ``FIELD_OWNERSHIP`` map (the pass is opt-in per class)."""

    module_prefixes: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class VmemSpec:
    """Pallas resource pass inputs.

    ``bindings`` is the reference serving shape every ``pl.pallas_call``
    site is evaluated at — symbols the kernel wrappers take from operand
    shapes or parameters.  ``dtype_overrides`` maps
    ``(module, wrapper_qualname) -> {in_spec index: dtype}`` for
    operands that are not the default float32 (the int8 code pages).
    """

    module_prefixes: tuple[str, ...]
    budget_bytes: int
    bindings: dict
    dtype_overrides: dict


@dataclasses.dataclass(frozen=True)
class AnalysisSpec:
    replay: ReplaySpec
    locks: LockSpec
    vmem: VmemSpec


# ---------------------------------------------------------------------------
# The repo's own spec
# ---------------------------------------------------------------------------

# Reference serving shape for the VMEM table: the TPU-target geometry the
# kernel docstrings reason about (LireConfig defaults: dim=128, block_size
# =16, nprobe=8 → nb = nprobe * max_blocks_per_posting = 64 pages), a
# 256-query navigation tile, and the l2_topk defaults (block_q=128,
# block_p=512 over a 4096-centroid shard).  BENCH_search.json's CPU
# traffic model runs far smaller shapes; this is the budget-sizing shape.
VMEM_BINDINGS = {
    "dim": 128,        # vector dimension
    "bs": 16,          # block_size: vectors per SSD page
    "k": 8,            # per-page / per-tile candidates kept
    "q_n": 256,        # queries per micro-batch dispatch
    "nb": 64,          # pages per query (nprobe * max_blocks_per_posting)
    "block_q": 128,    # l2_topk query tile
    "block_p": 512,    # l2_topk centroid tile
    "p_n": 4096,       # centroids per shard (l2_topk input rows)
}

DEFAULT_SPEC = AnalysisSpec(
    replay=ReplaySpec(
        roots=(
            # single-host jit-step builders (the WAL dispatch surface)
            "repro.core.index:insert_step",
            "repro.core.index:delete_step",
            "repro.core.index:fused_maintenance_step",
            "repro.core.index:fused_maintenance_round",
            # sharded builders (shard_map'd twins of the same dispatches)
            "repro.distributed.sharded_index:make_insert_step",
            "repro.distributed.sharded_index:make_delete_step",
            "repro.distributed.sharded_index:make_maintenance_step",
            # template + codec selection: recovery rebuilds the state
            # pytree from the config before replaying the WAL onto it
            "repro.core.types:make_empty_state",
        ),
        config_class="repro.core.types:LireConfig",
        critical_stamp="repro.storage.durability:REPLAY_CRITICAL_FIELDS",
        exempt_stamp="repro.storage.durability:REPLAY_EXEMPT_FIELDS",
    ),
    locks=LockSpec(module_prefixes=("repro.serve",)),
    vmem=VmemSpec(
        module_prefixes=("repro.kernels",),
        budget_bytes=16 * 1024 * 1024,   # VMEM per TensorCore (~16 MiB)
        bindings=VMEM_BINDINGS,
        dtype_overrides={
            # int8 code pages: in_specs index 1 is the block-pool operand
            ("repro.kernels.posting_scan.kernel", "scan_per_query_topk_q8"):
                {1: "int8"},
            ("repro.kernels.posting_scan.kernel", "scan_batched_topk_q8"):
                {1: "int8"},
        },
    ),
)
