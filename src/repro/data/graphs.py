"""Graph data substrate: CSR adjacency + the layer-wise fanout neighbor
sampler the ``minibatch_lg`` cell requires (GraphSAGE-style, fanout 15-10).

The sampler produces FIXED-SHAPE padded subgraphs (jit-friendly): for
targets B and fanouts (f1, f2, ...) it emits
    nodes   : B + B·f1 + B·f1·f2 + ...   node slots (-1 padded, w/ repeats)
    edges   : B·f1 + B·f1·f2 + ...       (src, dst) pairs into slot space
so every batch lowers to the same HLO.  Sampling-with-replacement repeats
are kept (standard GraphSAGE estimator); padded slots carry -1 and are
ignored by the GAT segment ops.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray    # (N+1,) int64
    indices: np.ndarray   # (E,) int32 neighbor ids
    features: np.ndarray  # (N, F) float32
    labels: np.ndarray    # (N,) int32

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @classmethod
    def random(cls, n_nodes: int, avg_degree: int, d_feat: int,
               n_classes: int, seed: int = 0,
               feature_signal: float = 0.5,
               homophily: float = 0.8) -> "CSRGraph":
        """Synthetic power-lawish graph for tests/examples.

        ``homophily`` = probability an edge stays within the node's class
        (real GNN benchmarks like Cora/Reddit are strongly homophilous —
        without it, message passing has nothing to aggregate).
        """
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
        by_class = [np.where(labels == c)[0] for c in range(n_classes)]
        deg = np.maximum(
            1, rng.zipf(1.7, size=n_nodes).clip(max=avg_degree * 8)
        )
        deg = (deg * (avg_degree / max(deg.mean(), 1e-9))).astype(np.int64).clip(1)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(deg, out=indptr[1:])
        indices = np.empty(indptr[-1], np.int32)
        for v in range(n_nodes):
            d = deg[v]
            same = rng.random(d) < homophily
            pool = by_class[labels[v]]
            nbrs = np.where(
                same & (len(pool) > 0),
                rng.choice(pool, size=d) if len(pool) else 0,
                rng.integers(0, n_nodes, size=d),
            )
            indices[indptr[v]:indptr[v + 1]] = nbrs
        feats = (rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
                 + labels[:, None] * feature_signal)
        return cls(indptr=indptr, indices=indices, features=feats,
                   labels=labels)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]


def sample_subgraph(
    graph: CSRGraph,
    targets: np.ndarray,
    fanouts: tuple[int, ...],
    rng: np.random.Generator,
) -> dict:
    """Layer-wise fanout sampling → fixed-shape padded batch for GAT.

    Node slot layout: [targets | layer-1 samples | layer-2 samples | ...].
    Edges point sampled-neighbor-slot → parent-slot (message flow toward
    the targets) plus per-slot self-loops.  Labels only on target slots
    (-1 elsewhere).
    """
    b = len(targets)
    frontier = np.asarray(targets, np.int64)
    slot_of_frontier = np.arange(b)
    node_ids = [frontier]
    edge_src, edge_dst = [], []
    next_slot = b

    for fanout in fanouts:
        n_par = len(frontier)
        sampled = np.full((n_par, fanout), -1, np.int64)
        for i, v in enumerate(frontier):
            if v < 0:
                continue
            nbrs = graph.neighbors(int(v))
            if len(nbrs) == 0:
                continue
            sampled[i] = rng.choice(nbrs, size=fanout, replace=True)
        slots = next_slot + np.arange(n_par * fanout)
        next_slot += n_par * fanout
        src = slots
        dst = np.repeat(slot_of_frontier, fanout)
        valid = sampled.reshape(-1) >= 0
        edge_src.append(np.where(valid, src, -1))
        edge_dst.append(np.where(valid, dst, -1))
        frontier = sampled.reshape(-1)
        slot_of_frontier = slots
        node_ids.append(frontier)

    all_ids = np.concatenate(node_ids)
    # self-loops on every slot (standard GAT practice — without them a
    # node's own features never reach its own output)
    slots = np.arange(len(all_ids))
    self_valid = all_ids >= 0
    edge_src.append(np.where(self_valid, slots, -1))
    edge_dst.append(np.where(self_valid, slots, -1))
    safe = np.maximum(all_ids, 0)
    features = graph.features[safe]
    features[all_ids < 0] = 0.0
    labels = np.full(len(all_ids), -1, np.int32)
    labels[:b] = graph.labels[targets]
    return {
        "features": features.astype(np.float32),
        "edge_src": np.concatenate(edge_src).astype(np.int32),
        "edge_dst": np.concatenate(edge_dst).astype(np.int32),
        "labels": labels,
        "node_ids": all_ids,
    }


def minibatch_stream(
    graph: CSRGraph, batch_nodes: int, fanouts: tuple[int, ...],
    seed: int = 0,
):
    """Infinite deterministic sampler stream (step -> batch)."""

    def batch_fn(step: int) -> dict:
        rng = np.random.default_rng(seed + step)
        targets = rng.choice(graph.n_nodes, size=batch_nodes, replace=False)
        return sample_subgraph(graph, targets, fanouts, rng)

    return batch_fn
