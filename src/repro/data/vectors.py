"""Synthetic vector datasets + the paper's update workloads (§5.1).

* :func:`make_sift_like`   — near-uniform clustered byte-ish vectors (the
  SIFT regime where the paper found SPANN+ ≈ SPFresh).
* :func:`make_spacev_like` — skewed cluster masses + a drifting component
  (the SPACEV regime where distribution shift breaks append-only updates).
* :class:`UpdateWorkload`  — workload A/B/C generator: a base set, an
  update-candidate pool, and per-epoch 1% delete + 1% insert batches
  ("1% daily update rate over N days").
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _clustered(
    rng: np.random.Generator,
    n: int,
    dim: int,
    n_clusters: int,
    *,
    weights: np.ndarray | None = None,
    spread: float = 0.08,
    drift: float = 0.0,
) -> np.ndarray:
    centers = rng.normal(size=(n_clusters, dim)).astype(np.float32)
    p = weights / weights.sum() if weights is not None else None
    assign = rng.choice(n_clusters, size=n, p=p)
    x = centers[assign] + spread * rng.normal(size=(n, dim)).astype(np.float32)
    if drift > 0:
        # a time-ordered drift: later vectors migrate toward a new region
        t = np.linspace(0, 1, n)[:, None].astype(np.float32)
        direction = rng.normal(size=(1, dim)).astype(np.float32)
        x = x + drift * t * direction
    return x.astype(np.float32)


def make_sift_like(n: int, dim: int = 16, seed: int = 0) -> np.ndarray:
    """Near-uniform cluster masses (the 'uniform' dataset of Fig. 9)."""
    rng = np.random.default_rng(seed)
    return _clustered(rng, n, dim, n_clusters=max(8, n // 500))


def make_spacev_like(n: int, dim: int = 16, seed: int = 0) -> np.ndarray:
    """Skewed cluster masses (Zipf) — 'data distribution shifts over time'."""
    rng = np.random.default_rng(seed)
    k = max(8, n // 500)
    w = 1.0 / np.arange(1, k + 1) ** 1.2
    return _clustered(rng, n, dim, n_clusters=k, weights=w, drift=0.5)


def make_shifting_stream(
    n: int, dim: int = 16, seed: int = 0, hot_fraction: float = 0.7
) -> np.ndarray:
    """Insert stream concentrated in a few hot regions (the shift
    micro-benchmark of paper Fig. 2/10)."""
    rng = np.random.default_rng(seed)
    k = 16
    w = np.full(k, (1 - hot_fraction) / (k - 2))
    w[:2] = hot_fraction / 2
    return _clustered(rng, n, dim, n_clusters=k, weights=w, spread=0.05)


@dataclasses.dataclass
class UpdateWorkload:
    """Paper §5.1: base set + disjoint update pool; each epoch deletes
    ``rate`` of the index and inserts ``rate`` fresh vectors."""

    base: np.ndarray          # (n_base, d) initial index contents
    pool: np.ndarray          # (n_pool, d) update candidates (disjoint)
    rate: float = 0.01
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._live = dict(enumerate(range(len(self.base))))  # vid -> row
        self._next_vid = len(self.base)
        self._pool_pos = 0

    @classmethod
    def spacev(cls, n: int = 20000, dim: int = 16, rate: float = 0.01,
               seed: int = 0) -> "UpdateWorkload":
        data = make_spacev_like(2 * n, dim, seed)
        return cls(base=data[:n], pool=data[n:], rate=rate, seed=seed)

    @classmethod
    def sift(cls, n: int = 20000, dim: int = 16, rate: float = 0.01,
             seed: int = 0) -> "UpdateWorkload":
        data = make_sift_like(2 * n, dim, seed)
        return cls(base=data[:n], pool=data[n:], rate=rate, seed=seed)

    @property
    def dim(self) -> int:
        return self.base.shape[1]

    def live_ids(self) -> np.ndarray:
        return np.fromiter(self._live.keys(), dtype=np.int64)

    def live_vectors(self) -> tuple[np.ndarray, np.ndarray]:
        ids = self.live_ids()
        all_data = np.concatenate([self.base, self.pool])
        rows = np.asarray([self._live[i] for i in ids])
        return all_data[rows], ids

    def epoch(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One update epoch → (delete_vids, insert_vecs, insert_vids)."""
        n_upd = max(1, int(self.rate * len(self._live)))
        live = self.live_ids()
        del_vids = self._rng.choice(live, size=min(n_upd, len(live)),
                                    replace=False)
        for v in del_vids:
            del self._live[int(v)]
        take = min(n_upd, len(self.pool) - self._pool_pos)
        rows = np.arange(self._pool_pos, self._pool_pos + take)
        self._pool_pos += take
        ins_vecs = self.pool[rows]
        ins_vids = np.arange(self._next_vid, self._next_vid + take)
        self._next_vid += take
        for v, r in zip(ins_vids, rows):
            self._live[int(v)] = len(self.base) + int(r)
        return del_vids.astype(np.int64), ins_vecs, ins_vids.astype(np.int64)

    def queries(self, n_queries: int, noise: float = 0.01) -> tuple[np.ndarray, np.ndarray]:
        """Queries near live vectors + brute-force ground truth (k=10)."""
        vecs, ids = self.live_vectors()
        sel = self._rng.integers(0, len(vecs), size=n_queries)
        q = vecs[sel] + noise * self._rng.normal(size=(n_queries, self.dim)).astype(np.float32)
        d = ((q[:, None, :] - vecs[None]) ** 2).sum(-1)
        gt = ids[np.argsort(d, axis=1)[:, :10]]
        return q.astype(np.float32), gt
