"""Data substrate: synthetic vector streams with distribution shift
(SPACEV-like skew / SIFT-like uniform), the paper's update workloads
(A/B/C), LM token pipeline, and the GNN neighbor sampler."""
from repro.data.vectors import (  # noqa: F401
    UpdateWorkload,
    make_shifting_stream,
    make_sift_like,
    make_spacev_like,
)
