"""Unified service API: ``spfresh.open(ServiceSpec) -> Service``.

One frozen spec describes the whole service (index geometry, scan data
path, micro-batching, maintenance, durability, sharding); ``open``
compiles it into a durable serving handle over the single-host or the
N-shard backend.  `import spfresh` re-exports this module.
"""
from repro.api.service import Service, open  # noqa: F401
from repro.api.spec import (  # noqa: F401
    DurabilitySpec,
    IndexSpec,
    MaintenanceSpec,
    ScanSpec,
    ServeSpec,
    ServiceSpec,
    ShardSpec,
)

__all__ = [
    "DurabilitySpec", "IndexSpec", "MaintenanceSpec", "ScanSpec",
    "ServeSpec", "Service", "ServiceSpec", "ShardSpec", "open",
]
