"""ServiceSpec — the one declarative description of a SPFresh service.

Every knob the repo grew across `LireConfig`, `EngineConfig`,
`ShardedIndex.__init__` kwargs, and `launch.serve` flags lives in exactly
one frozen sub-spec here; `spfresh.open(spec)` compiles the spec into a
running :class:`~repro.api.service.Service` over either backend.  Adding a
knob is now a one-file change: extend the sub-spec, consume it in
``lire_config()`` / ``engine_config()`` — nothing else threads it.

Sub-specs (all frozen dataclasses, composable with ``dataclasses.replace``):

  * :class:`IndexSpec`       — the LIRE protocol + storage geometry
                               (wraps :class:`~repro.core.types.LireConfig`)
  * :class:`ScanSpec`        — the Pallas posting-scan data path flags
  * :class:`ServeSpec`       — micro-batching + maintenance policy
                               (compiles to ``EngineConfig``)
  * :class:`MaintenanceSpec` — Local-Rebuilder round shape / budget
  * :class:`DurabilitySpec`  — WAL dir, snapshot dir, checkpoint cadence
  * :class:`ShardSpec`       — mesh geometry for the sharded backend
"""
from __future__ import annotations

import dataclasses
import os

from repro.core.types import LireConfig


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Index geometry + LIRE protocol parameters.

    ``config`` is the full :class:`LireConfig`; ``seed`` seeds the offline
    SPANN build.  Scan/maintenance fields of the config are *defaults* —
    the sibling :class:`ScanSpec` / :class:`MaintenanceSpec` override them
    (``ServiceSpec.lire_config()`` folds everything into one config).
    """

    config: LireConfig = LireConfig()
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ScanSpec:
    """Posting-scan data path (PR 2's flags, spec-ified).

    ``None`` means "defer to ``IndexSpec.config``" for the tri-state
    flags; ``probe_chunk`` is an engine-side knob (oracle path only).
    """

    probe_chunk: int = 0
    use_pallas_scan: bool | None = None
    scan_schedule: str | None = None       # "per_query" | "batched" | None
    scan_page_budget: int | None = None
    pallas_interpret: bool | None = None
    # Posting payload codec (storage/codec.py): "fp32" | "bf16" | "int8";
    # None defers to IndexSpec.config.  Lossy codecs over-fetch
    # rerank_factor×k quantized candidates and rerank them against the
    # exact tier (see LireConfig.codec / .rerank_factor).
    codec: str | None = None
    rerank_factor: int | None = None


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Micro-batching + maintenance scheduling (compiles to EngineConfig)."""

    search_k: int = 10
    nprobe: int | None = None
    max_batch: int = 256
    min_bucket: int = 8
    policy: str = "ratio"                  # "ratio" | "backlog"
    fg_bg_ratio: int = 2
    backlog_threshold: int = 1
    max_insert_retries: int = 4
    # --- async serving (background pump thread; see serve/engine.py) ---
    # async_serve=True: the engine owns a dedicated pump thread; callers
    # only enqueue and block on per-ticket events, maintenance runs in
    # queue-idle gaps, and durable update tickets ack after the WAL
    # fsync.  max_wait_ms is the batch-formation window: an unfenced
    # head run is held up to this long so micro-batches fill toward the
    # top bucket instead of dispatching immediately (async mode only).
    async_serve: bool = False
    max_wait_ms: float = 0.0
    # --- read replicas (serve/engine.py + distributed/replication.py) ---
    # With ShardSpec.n_replicas > 1 the pump routes search batches to
    # replica workers round-robin; max_lag is the freshness bound (a
    # replica more than max_lag WAL seqnos behind the primary is skipped
    # and the batch falls back to the primary), replica_inflight caps the
    # routed-but-unfinished batches a single replica may hold.
    max_lag: int = 64
    replica_inflight: int = 2


@dataclasses.dataclass(frozen=True)
class MaintenanceSpec:
    """Local-Rebuilder round shape.  ``None`` defers to IndexSpec.config."""

    jobs_per_round: int | None = None      # split/merge jobs per fused round
    merge_fanout: int | None = None
    reassign_budget: int | None = None
    maintain_budget: int | None = None     # jobs per background SLOT
                                           # (None -> jobs_per_round)
    # Job selection: "size" (top-K longest / bottom-K shortest — the
    # parity baseline) or "drift" (Ada-IVF-style cost model over the
    # per-posting access/update/drift telemetry).  None defers to
    # IndexSpec.config; alpha/beta weigh the access-rate and drift terms.
    policy: str | None = None              # "size" | "drift"
    alpha: float | None = None
    beta: float | None = None


@dataclasses.dataclass(frozen=True)
class DurabilitySpec:
    """Crash-recovery lifecycle: per-shard WAL + snapshot checkpoints.

    ``root=None`` disables durability (an ephemeral service).  With a
    root, every update dispatch is WAL-appended (fsync'd) before it runs,
    ``checkpoint()`` writes an atomic snapshot stamping each shard's
    applied WAL seqno and truncates the logs, and ``spfresh.open`` replays
    snapshot + WAL tails.  ``checkpoint_every=N`` auto-checkpoints (full
    base snapshot) after every N update rows (0 = manual/close only).

    The durability **fast path** (paper §4.4's block-granular
    copy-on-write):

    * ``delta_every=N`` — every N update rows, auto-checkpoint as a
      **delta** snapshot: only the blocks the pool's dirty bitmap marked
      since the last unit, one file per shard, chained to the base.
      Checkpoint bytes scale with churn, not index size.
    * ``compact_every=M`` — once M deltas stack on the base, the next
      delta-cadence checkpoint is promoted to a compaction: a fresh full
      base folds the chain and prunes it (0 = never auto-compact).
    * ``group_commit=N`` (+ ``group_commit_ms``) — batch up to N update
      dispatches per WAL fsync.  The ack point does not move: the service
      forces a sync before an update call returns, so one fsync covers
      every dispatch that ran inside the call (retries, interleaved
      maintenance, ``insert_bulk`` chunks).
    * ``compact_wal=True`` — on recovery, mask insert rows whose vids
      were later deleted before replaying (local backend; preserves the
      live set and version map, not the physical block layout — see
      ``storage.wal.compact_wal_records``).
    """

    root: str | None = None
    wal_dir: str | None = None             # default: <root>/wal
    snapshot_dir: str | None = None        # default: <root>/snapshot
    checkpoint_every: int = 0
    snapshot_on_open: bool = True          # durability point for the build
    checkpoint_on_close: bool = True
    # --- durability fast path ---
    delta_every: int = 0                   # rows per auto DELTA checkpoint
    compact_every: int = 16                # deltas per chain before re-base
    group_commit: int = 0                  # dispatches per WAL fsync window
    group_commit_ms: float = 0.0           # window age-out (0 = count only)
    compact_wal: bool = False              # replay-side WAL compaction

    @property
    def enabled(self) -> bool:
        return bool(self.root or (self.wal_dir and self.snapshot_dir))

    def resolved_wal_dir(self) -> str:
        assert self.enabled
        return self.wal_dir or os.path.join(self.root, "wal")

    def resolved_snapshot_dir(self) -> str:
        assert self.enabled
        return self.snapshot_dir or os.path.join(self.root, "snapshot")


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Mesh geometry.  ``n_shards=1`` selects the single-host backend.

    ``n_replicas > 1`` adds a leading **data** axis holding N full copies
    of the index: the primary (replica 0) alone runs the WAL-append +
    dispatch order, and every logged dispatch is streamed to the other
    replicas through a bounded async queue replayed in seqno order (see
    ``distributed/replication.py``).  The model axis continues to shard
    postings exactly as before — replication composes with sharding, so
    ``n_replicas=2, n_shards=2`` needs a 4-device (data, model) mesh.
    """

    n_shards: int = 1
    shard_axes: tuple[str, ...] = ("model",)
    n_replicas: int = 1
    replica_axis: str = "data"


@dataclasses.dataclass(frozen=True)
class ServiceSpec:
    """The whole service, declaratively.  See ``spfresh.open``."""

    index: IndexSpec = IndexSpec()
    serve: ServeSpec = ServeSpec()
    scan: ScanSpec = ScanSpec()
    maintenance: MaintenanceSpec = MaintenanceSpec()
    durability: DurabilitySpec = DurabilitySpec()
    shards: ShardSpec = ShardSpec()

    # ------------------------------------------------------------------
    @property
    def sharded(self) -> bool:
        return self.shards.n_shards > 1

    @property
    def replicated(self) -> bool:
        return self.shards.n_replicas > 1

    def lire_config(self) -> LireConfig:
        """IndexSpec.config with the scan/maintenance overrides folded in —
        the ONE config both backends and every jitted step see."""
        over: dict = {}
        s, m = self.scan, self.maintenance
        for field, value in (
            ("use_pallas_scan", s.use_pallas_scan),
            ("scan_schedule", s.scan_schedule),
            ("scan_page_budget", s.scan_page_budget),
            ("pallas_interpret", s.pallas_interpret),
            ("codec", s.codec),
            ("rerank_factor", s.rerank_factor),
            ("jobs_per_round", m.jobs_per_round),
            ("merge_fanout", m.merge_fanout),
            ("reassign_budget", m.reassign_budget),
            ("maintain_policy", m.policy),
            ("maintain_alpha", m.alpha),
            ("maintain_beta", m.beta),
        ):
            if value is not None:
                over[field] = value
        cfg = dataclasses.replace(self.index.config, **over) if over \
            else self.index.config
        cfg.validate()
        return cfg

    def engine_config(self):
        """Compile serve+scan+maintenance into the pipeline's EngineConfig."""
        from repro.serve.engine import EngineConfig

        cfg = self.lire_config()
        sv, sc, mt = self.serve, self.scan, self.maintenance
        return EngineConfig(
            search_k=sv.search_k,
            nprobe=sv.nprobe,
            probe_chunk=sc.probe_chunk,
            use_pallas_scan=sc.use_pallas_scan,
            scan_schedule=sc.scan_schedule,
            max_batch=sv.max_batch,
            min_bucket=sv.min_bucket,
            policy=sv.policy,
            fg_bg_ratio=sv.fg_bg_ratio,
            maintain_budget=(
                mt.maintain_budget
                if mt.maintain_budget is not None
                else cfg.jobs_per_round
            ),
            backlog_threshold=sv.backlog_threshold,
            max_insert_retries=sv.max_insert_retries,
            async_serve=sv.async_serve,
            max_wait_ms=sv.max_wait_ms,
            max_lag=sv.max_lag,
            replica_inflight=sv.replica_inflight,
        )

    def validate(self) -> None:
        self.lire_config()  # folds + validates
        assert self.shards.n_shards >= 1
        assert self.shards.n_replicas >= 1
        assert self.serve.policy in ("ratio", "backlog"), self.serve.policy
        assert self.serve.max_wait_ms >= 0
        assert self.serve.max_lag >= 0
        assert self.serve.replica_inflight >= 1
        assert self.durability.checkpoint_every >= 0
        dur = self.durability
        assert dur.delta_every >= 0 and dur.compact_every >= 0
        assert dur.group_commit >= 0 and dur.group_commit_ms >= 0
        if dur.root is None and (dur.wal_dir is None) != (
                dur.snapshot_dir is None):
            # Half-configured durability would silently run ephemeral.
            raise ValueError(
                "DurabilitySpec needs BOTH wal_dir and snapshot_dir (or "
                "just root); only one of them configures nothing"
            )
        if self.scan.scan_schedule is not None:
            assert self.scan.scan_schedule in ("per_query", "batched")
        if self.scan.codec is not None:
            assert self.scan.codec in ("fp32", "bf16", "int8"), self.scan.codec
        if self.scan.rerank_factor is not None:
            assert self.scan.rerank_factor >= 1

    # ------------------------------------------------------------------
    def with_durability(self, root: str, **kw) -> "ServiceSpec":
        """Convenience: the same service, durably rooted at ``root``."""
        return dataclasses.replace(
            self, durability=dataclasses.replace(
                self.durability, root=root, **kw
            )
        )

    def with_shards(self, n_shards: int, **kw) -> "ServiceSpec":
        """Convenience: the same service over an ``n_shards`` mesh."""
        return dataclasses.replace(
            self, shards=dataclasses.replace(
                self.shards, n_shards=n_shards, **kw
            )
        )

    def with_replicas(self, n_replicas: int, *, max_lag: int | None = None,
                      ) -> "ServiceSpec":
        """Convenience: the same service with ``n_replicas`` read replicas."""
        serve = self.serve if max_lag is None else dataclasses.replace(
            self.serve, max_lag=max_lag
        )
        return dataclasses.replace(
            self,
            serve=serve,
            shards=dataclasses.replace(self.shards, n_replicas=n_replicas),
        )
