"""`spfresh.open(spec)` — one durable serving lifecycle over both backends.

``open`` compiles a :class:`~repro.api.spec.ServiceSpec` into a running
:class:`Service`: it builds (or crash-recovers) the index, stands the
micro-batched ServeEngine in front of it, and wires the durability
lifecycle (per-shard WAL + snapshot checkpoints) into the backend.

Lifecycle::

    open(spec, vectors=...)           # fresh build; durable roots get an
                                      #   open-time snapshot (the build's
                                      #   durability point) + empty WALs
    svc.search / insert / delete      # updates are WAL-appended per
                                      #   dispatch before they run; under
                                      #   group_commit the fsync is forced
                                      #   before the call returns (ack)
    svc.insert_bulk(...)              # many dispatches, ONE fsync
    svc.checkpoint()                  # flush + atomic snapshot unit
                                      #   (delta when the spec enables
                                      #   them, else full base) stamping
                                      #   per-shard wal_seqnos + WAL trunc
    svc.close()                       # flush (+ final checkpoint)

    open(spec)                        # after a crash: latest snapshot +
                                      #   per-shard WAL replay through the
                                      #   backend's own jitted dispatches

Replay is bit-deterministic: the WAL records *dispatches* (padded arrays,
masks, maintenance rounds) rather than requests, and every dispatch is a
pure function of (state, batch) — so a recovered service answers queries
exactly like the uncrashed one, on the single-host backend and the
N-shard mesh alike.
"""
from __future__ import annotations

import numpy as np

from repro.api.spec import ServiceSpec
from repro.core.index import SPFreshIndex
from repro.core.types import make_empty_state
from repro.serve.engine import LocalBackend, ServeEngine
from repro.storage.durability import check_replay_config
from repro.storage.snapshot import SnapshotStore
from repro.storage.wal import WalSet, compact_wal_records


class Service:
    """A running SPFresh service: the stable serving surface.

    Thin by design — all state transitions live in the backend's jitted
    dispatches; the service owns the lifecycle (queue flush, checkpoint
    cadence, close) and the spec that created it.
    """

    def __init__(
        self,
        spec: ServiceSpec,
        engine: ServeEngine,
        *,
        initial_handles: np.ndarray | None = None,
        recovered: bool = False,
    ):
        self.spec = spec
        self.engine = engine
        self.initial_handles = initial_handles
        self.recovered = recovered
        self._updates_since_ckpt = 0
        self._updates_since_delta = 0
        self._closed = False
        self._store = (
            SnapshotStore(spec.durability.resolved_snapshot_dir())
            if spec.durability.enabled else None
        )

    # ------------------------------ serving ----------------------------
    @property
    def backend(self):
        return self.engine.backend

    @property
    def index(self) -> SPFreshIndex | None:
        """The single-host index (None on the sharded backend)."""
        return self.engine.index

    @property
    def replicas(self):
        """The bound ReplicaSet (None when ``n_replicas == 1``)."""
        return self.engine.replicas

    def search(
        self, queries: np.ndarray, *, k: int | None = None,
        nprobe: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        return self.engine.search(queries, k=k, nprobe=nprobe)

    def insert(
        self, vecs: np.ndarray, vids: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Returns ``(ids, landed)``.  The sharded backend assigns its own
        (shard, slot) handles — pass ``vids=None`` there; the local
        backend keys the version map by caller vids, so they're required."""
        vecs = np.asarray(vecs, np.float32)
        vids = self._resolve_vids(vecs, vids)
        ids, landed = self.engine.submit_insert(vecs, vids).result()
        self._wal_ack()
        self._note_updates(len(vecs))
        return ids, landed

    def insert_bulk(
        self, vecs: np.ndarray, vids: np.ndarray | None = None,
        *, chunk: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Group-commit fast path: submit every ``chunk``-row micro-batch,
        pump them all, then cross ONE fsync before collecting results —
        many update dispatches share a single durability point while the
        ack-after-fsync contract holds (nothing is returned pre-sync)."""
        vecs = np.asarray(vecs, np.float32)
        vids = self._resolve_vids(vecs, vids)
        chunk = chunk or self.spec.serve.max_batch
        tickets = [
            self.engine.submit_insert(vecs[s:s + chunk], vids[s:s + chunk])
            for s in range(0, len(vecs), chunk)
        ]
        self.engine.pump()
        self._wal_ack()
        outs = [t.result() for t in tickets]
        ids = (np.concatenate([o[0] for o in outs])
               if outs else np.zeros((0,), np.int32))
        landed = (np.concatenate([o[1] for o in outs])
                  if outs else np.zeros((0,), bool))
        self._note_updates(len(vecs))
        return ids, landed

    def _resolve_vids(self, vecs, vids):
        """The sharded backend assigns its own (shard, slot) handles —
        ``vids=None`` there; the local backend requires caller vids."""
        if vids is None:
            if not self.spec.sharded:
                raise ValueError("the local backend requires caller vids")
            return np.full(len(vecs), -1, np.int32)
        return np.asarray(vids, np.int32)

    def delete(self, vids: np.ndarray) -> None:
        vids = np.asarray(vids, np.int32)
        self.engine.delete(vids)
        self._wal_ack()
        self._note_updates(len(vids))

    def maintain(self, jobs: int | None = None) -> int:
        """One explicit Local-Rebuilder round (background slots also run
        under the engine's MaintenancePolicy).  Runs under the engine's
        exclusive lock so it serializes against the async pump thread's
        dispatches (one WAL append + dispatch order)."""
        self.flush()
        with self.engine.exclusive():
            jobs_done = self.backend.maintain(
                jobs or self.engine.policy.budget
            )
            self._wal_ack_locked()
        return jobs_done

    def drain(self) -> int:
        """Flush the queue and run the rebuilder to quiescence."""
        jobs = self.engine.drain()
        self._wal_ack()
        return jobs

    # ----------------------------- lifecycle ---------------------------
    @property
    def durable(self) -> bool:
        return self.spec.durability.enabled

    def flush(self) -> int:
        """Process every queued micro-batch; returns batches pumped.
        Crosses the group-commit ack point: every ticket resolvable
        after a flush is backed by fsync'd WAL records."""
        n = self.engine.pump()
        self._wal_ack()
        return n

    def checkpoint(self, delta: bool | None = None) -> None:
        """Flush, then commit an atomic snapshot unit stamping each
        shard's applied WAL seqno; the WALs restart empty after the
        commit.

        ``delta=None`` (default) picks the cheapest correct unit: a delta
        when the spec enables them (``delta_every > 0``), a base exists,
        and the chain is shorter than ``compact_every`` — otherwise a
        full base, which also folds + prunes the chain (compaction).
        ``delta=True``/``False`` force the choice (a forced delta still
        promotes to a base over an empty store)."""
        if not self.durable:
            raise RuntimeError("checkpoint() on a service with no "
                               "DurabilitySpec root")
        self.flush()
        dur = self.spec.durability
        store = self._store
        if delta is None:
            # Cadence POLICY lives here (the spec's knobs); the backend's
            # checkpoint() owns only the mechanics, incl. demoting a
            # forced delta over an empty store to a base.
            delta = (
                dur.delta_every > 0
                and store.has_base()
                and (dur.compact_every == 0
                     or store.chain_len() < dur.compact_every)
            )
        with self.engine.exclusive():
            self.backend.checkpoint(
                dur.resolved_snapshot_dir(), delta=bool(delta)
            )
        self._updates_since_ckpt = 0
        self._updates_since_delta = 0

    def _wal_ack(self) -> None:
        """Ack point under group commit: updates return only after their
        WAL records (and everything before them) are fsync'd."""
        if self.durable:
            with self.engine.exclusive():
                self.backend.wal_sync()

    def _wal_ack_locked(self) -> None:
        """`_wal_ack` for callers already inside ``engine.exclusive()``."""
        if self.durable:
            self.backend.wal_sync()

    def _note_updates(self, rows: int) -> None:
        self._updates_since_ckpt += rows
        self._updates_since_delta += rows
        if not self.durable:
            return
        dur = self.spec.durability
        if (dur.checkpoint_every > 0
                and self._updates_since_ckpt >= dur.checkpoint_every):
            self.checkpoint(delta=False)       # scheduled full re-base
        elif (dur.delta_every > 0
                and self._updates_since_delta >= dur.delta_every):
            self.checkpoint()                  # delta (or due compaction)

    def close(self) -> None:
        """Flush, optionally checkpoint (DurabilitySpec.checkpoint_on_close),
        and release the WAL file handles.  Idempotent."""
        if self._closed:
            return
        self.flush()
        # stop the pump thread BEFORE the final checkpoint/close so no
        # dispatch races the snapshot or lands on a closed WAL
        self.engine.shutdown()
        if self.durable and self.spec.durability.checkpoint_on_close:
            self.checkpoint()
        self.backend.close()
        self._closed = True

    def __enter__(self) -> "Service":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------- observability ------------------------
    def report(self) -> dict:
        rep = self.engine.report()
        rep["durability"] = {
            "durable": self.durable,
            "recovered": self.recovered,
            "wal_seqnos": (
                self.backend.wal_seqnos() if self.durable else None
            ),
            "updates_since_checkpoint": self._updates_since_ckpt,
        }
        if self.durable:
            if self.backend.wal_set is not None:
                rep["durability"]["wal"] = self.backend.wal_set.stats()
            rep["durability"]["snapshot_chain_len"] = self._store.chain_len()
        return rep

    def stats(self) -> dict:
        return self.engine.stats()

    def backlog(self) -> int:
        return self.backend.backlog()


# ---------------------------------------------------------------------------
# open()
# ---------------------------------------------------------------------------

def _make_mesh(spec: ServiceSpec):
    import jax

    n = spec.shards.n_shards
    if len(spec.shards.shard_axes) != 1:
        raise ValueError(
            "spfresh.open builds single-axis meshes; pass mesh= for "
            f"multi-axis shard_axes {spec.shards.shard_axes}"
        )
    return jax.make_mesh((n,), spec.shards.shard_axes)


def _make_meshes(spec: ServiceSpec, mesh):
    """(primary_mesh, replica_meshes) for the sharded backend.

    With ``n_replicas > 1`` and no explicit mesh, build the 2-axis
    (data, model) mesh — the data axis holds the N full copies — and
    split it into one single-axis row submesh per copy: row 0 is the
    primary, the rest are read replicas.  Each copy's shard_map'd steps
    compile on its own row, so the per-shard step code is exactly the
    unreplicated path.  An explicit ``mesh`` hosts every copy (useful
    when devices are scarce — e.g. local CPU tests)."""
    n_rep = spec.shards.n_replicas
    if mesh is not None:
        return mesh, [mesh] * (n_rep - 1)
    if n_rep == 1:
        return _make_mesh(spec), []
    from repro.distributed.sharding import (
        make_replicated_mesh, replica_submeshes,
    )

    if len(spec.shards.shard_axes) != 1:
        raise ValueError(
            "replicated meshes support a single shard axis; got "
            f"{spec.shards.shard_axes}"
        )
    full = make_replicated_mesh(
        n_rep, spec.shards.n_shards,
        (spec.shards.replica_axis, spec.shards.shard_axes[0]),
    )
    rows = replica_submeshes(full, spec.shards.replica_axis)
    return rows[0], rows[1:]


def _local_backend(spec: ServiceSpec, index: SPFreshIndex) -> LocalBackend:
    return LocalBackend(
        index,
        probe_chunk=spec.scan.probe_chunk,
        use_pallas_scan=spec.scan.use_pallas_scan,
        scan_schedule=spec.scan.scan_schedule,
    )


def open(
    spec: ServiceSpec,
    *,
    vectors: np.ndarray | None = None,
    mesh=None,
    fresh: bool = False,
) -> Service:
    """Open a SPFresh service described by ``spec``.

    * With a durable root whose snapshot exists: **recover** — load the
      snapshot, replay each shard's WAL tail through the backend, and
      resume serving (``vectors`` is ignored; the snapshot is truth).
    * Otherwise **build** from ``vectors`` (required); durable roots get
      an open-time checkpoint so the offline build itself survives a
      crash before the first explicit ``checkpoint()``.

    ``fresh=True`` forces the build path even when a snapshot exists —
    the durable root's previous contents are superseded by the new
    open-time checkpoint (a rebuild, not a recovery).

    The same spec (modulo :class:`ShardSpec`) opens a local service or an
    N-shard mesh service; ``mesh`` overrides the auto-built single-axis
    mesh (it must match ``spec.shards``).
    """
    spec.validate()
    cfg = spec.lire_config()
    dur = spec.durability
    n_shards = spec.shards.n_shards
    store = SnapshotStore(dur.resolved_snapshot_dir()) if dur.enabled else None
    can_recover = dur.enabled and not fresh and store.exists()
    if fresh and vectors is None:
        raise ValueError("fresh=True requires vectors to build from")
    if can_recover:
        # Validate the stamped config BEFORE building templates: a
        # geometry drift (e.g. the launcher re-run with different sizing
        # flags) must fail with field names, not a leaf-shape mismatch.
        check_replay_config(store.read_manifest(), cfg, n_shards=n_shards)

    initial_handles: np.ndarray | None = None
    recovered = False
    if not can_recover and vectors is None:
        raise FileNotFoundError(
            "no snapshot to recover and no vectors to build"
        )

    replica_meshes: list = []
    if spec.sharded:
        from repro.distributed.sharded_index import ShardedIndex

        mesh, replica_meshes = _make_meshes(spec, mesh)
        kwargs = dict(
            shard_axes=spec.shards.shard_axes,
            probe_chunk=spec.scan.probe_chunk,
            use_pallas_scan=spec.scan.use_pallas_scan,
            scan_schedule=spec.scan.scan_schedule,
            jobs_per_round=cfg.jobs_per_round,
        )
        if can_recover:
            backend, manifest = ShardedIndex.restore(
                mesh, cfg, dur.resolved_snapshot_dir(), n_shards, **kwargs
            )
            recovered = True
        else:
            backend, initial_handles = ShardedIndex.build(
                mesh, cfg, np.asarray(vectors, np.float32), n_shards,
                seed=spec.index.seed, **kwargs
            )
    else:
        if can_recover:
            template = make_empty_state(cfg)
            state, manifest = store.load(template)
            backend = _local_backend(spec, SPFreshIndex(state))
            recovered = True
        else:
            index = SPFreshIndex.build(
                cfg, np.asarray(vectors, np.float32), seed=spec.index.seed
            )
            initial_handles = np.arange(len(vectors), dtype=np.int64)
            backend = _local_backend(spec, index)

    if dur.enabled:
        wal_set = WalSet(dur.resolved_wal_dir(), n_shards)
        if dur.group_commit > 1:
            wal_set.set_group_commit(dur.group_commit, dur.group_commit_ms)
        if recovered:
            records = wal_set.recover_records()
            if dur.compact_wal and not spec.sharded:
                # Replay-speed knob: dead insert rows (vid deleted later
                # in the log) never re-land.  Local backend only — the
                # sharded stream's handle assignment is positional.
                records, _dropped = compact_wal_records(records)
            after = min(manifest.get("extra", {}).get("wal_seqnos", [-1]))
            # The checkpoint truncated the logs: seqno numbering must
            # resume ABOVE the manifest stamp, or the next recovery would
            # skip fresh acknowledged records as already-applied.
            wal_set.ensure_seqno_floor(after)
            backend.attach_durability(wal_set, applied_seqno=after)
            backend.replay(records, after_seqno=after)
        else:
            # Fresh build over a durable root.  Leftover WAL records from
            # a previous incarnation are NOT truncated here: the open-time
            # checkpoint below drops them only AFTER its snapshot commits,
            # so a crash anywhere in this window still recovers the
            # previous incarnation intact (old snapshot + old WAL).
            backend.attach_durability(wal_set)
            if not dur.snapshot_on_open and (
                store.exists()
                or any(s >= 0 for s in wal_set.last_seqnos())
            ):
                raise ValueError(
                    "refusing to rebuild over a non-empty durable root "
                    "with snapshot_on_open=False: the old snapshot/WAL "
                    "would later recover mixed with the new build's "
                    "records (use fresh=True with snapshot_on_open=True, "
                    "or point DurabilitySpec at a clean root)"
                )

    replicas = None
    if spec.replicated:
        # Clone the read replicas AFTER durability attach + replay so a
        # recovered service's replicas start bit-identical to the
        # recovered primary at its applied seqno; attach the publish
        # sink before the engine exists so no logged dispatch can slip
        # past the stream.  Workers start only after bind() (catch-up
        # needs the engine's exclusive lock).
        from repro.distributed.replication import ReplicaSet

        if spec.sharded:
            clones = [backend.clone(m) for m in replica_meshes]
        else:
            clones = [
                backend.clone() for _ in range(spec.shards.n_replicas - 1)
            ]
        replicas = ReplicaSet(
            backend, clones,
            max_lag=spec.serve.max_lag,
            inflight=spec.serve.replica_inflight,
        )
        backend.attach_replication(replicas)

    engine = ServeEngine(backend, spec.engine_config(), replicas=replicas)
    if replicas is not None:
        replicas.bind(engine)
        replicas.start()
    svc = Service(
        spec, engine, initial_handles=initial_handles, recovered=recovered
    )
    if dur.enabled and not recovered and dur.snapshot_on_open:
        # The offline build is not in the WAL; snapshot it so a crash
        # before the first checkpoint still recovers to a served state
        # (checkpoint also truncates any previous incarnation's WAL —
        # strictly after the new snapshot commits).  Always a FULL base:
        # a fresh rebuild must supersede — never chain onto — whatever
        # delta chain a previous incarnation left in the store.
        svc.checkpoint(delta=False)
    return svc
