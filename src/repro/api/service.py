"""`spfresh.open(spec)` — one durable serving lifecycle over both backends.

``open`` compiles a :class:`~repro.api.spec.ServiceSpec` into a running
:class:`Service`: it builds (or crash-recovers) the index, stands the
micro-batched ServeEngine in front of it, and wires the durability
lifecycle (per-shard WAL + snapshot checkpoints) into the backend.

Lifecycle::

    open(spec, vectors=...)           # fresh build; durable roots get an
                                      #   open-time snapshot (the build's
                                      #   durability point) + empty WALs
    svc.search / insert / delete      # updates are WAL-appended per
                                      #   dispatch before they run
    svc.checkpoint()                  # flush + atomic snapshot stamping
                                      #   per-shard wal_seqnos + WAL trunc
    svc.close()                       # flush (+ final checkpoint)

    open(spec)                        # after a crash: latest snapshot +
                                      #   per-shard WAL replay through the
                                      #   backend's own jitted dispatches

Replay is bit-deterministic: the WAL records *dispatches* (padded arrays,
masks, maintenance rounds) rather than requests, and every dispatch is a
pure function of (state, batch) — so a recovered service answers queries
exactly like the uncrashed one, on the single-host backend and the
N-shard mesh alike.
"""
from __future__ import annotations

import numpy as np

from repro.api.spec import ServiceSpec
from repro.core.index import SPFreshIndex
from repro.core.types import make_empty_state
from repro.serve.engine import LocalBackend, ServeEngine
from repro.storage.durability import check_replay_config
from repro.storage.snapshot import (
    load_snapshot, read_manifest, snapshot_exists,
)
from repro.storage.wal import WalSet


class Service:
    """A running SPFresh service: the stable serving surface.

    Thin by design — all state transitions live in the backend's jitted
    dispatches; the service owns the lifecycle (queue flush, checkpoint
    cadence, close) and the spec that created it.
    """

    def __init__(
        self,
        spec: ServiceSpec,
        engine: ServeEngine,
        *,
        initial_handles: np.ndarray | None = None,
        recovered: bool = False,
    ):
        self.spec = spec
        self.engine = engine
        self.initial_handles = initial_handles
        self.recovered = recovered
        self._updates_since_ckpt = 0
        self._closed = False

    # ------------------------------ serving ----------------------------
    @property
    def backend(self):
        return self.engine.backend

    @property
    def index(self) -> SPFreshIndex | None:
        """The single-host index (None on the sharded backend)."""
        return self.engine.index

    def search(
        self, queries: np.ndarray, *, k: int | None = None,
        nprobe: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        return self.engine.search(queries, k=k, nprobe=nprobe)

    def insert(
        self, vecs: np.ndarray, vids: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Returns ``(ids, landed)``.  The sharded backend assigns its own
        (shard, slot) handles — pass ``vids=None`` there; the local
        backend keys the version map by caller vids, so they're required."""
        vecs = np.asarray(vecs, np.float32)
        if vids is None:
            if not self.spec.sharded:
                raise ValueError("the local backend requires caller vids")
            vids = np.full(len(vecs), -1, np.int32)
        ids, landed = self.engine.submit_insert(vecs, vids).result()
        self._note_updates(len(vecs))
        return ids, landed

    def delete(self, vids: np.ndarray) -> None:
        vids = np.asarray(vids, np.int32)
        self.engine.delete(vids)
        self._note_updates(len(vids))

    def maintain(self, jobs: int | None = None) -> int:
        """One explicit Local-Rebuilder round (background slots also run
        under the engine's MaintenancePolicy)."""
        self.flush()
        return self.backend.maintain(jobs or self.engine.policy.budget)

    def drain(self) -> int:
        """Flush the queue and run the rebuilder to quiescence."""
        return self.engine.drain()

    # ----------------------------- lifecycle ---------------------------
    @property
    def durable(self) -> bool:
        return self.spec.durability.enabled

    def flush(self) -> int:
        """Process every queued micro-batch; returns batches pumped."""
        return self.engine.pump()

    def checkpoint(self) -> None:
        """Flush, then commit an atomic snapshot stamping each shard's
        applied WAL seqno; the WALs restart empty after the commit."""
        if not self.durable:
            raise RuntimeError("checkpoint() on a service with no "
                               "DurabilitySpec root")
        self.flush()
        self.backend.checkpoint(self.spec.durability.resolved_snapshot_dir())
        self._updates_since_ckpt = 0

    def _note_updates(self, rows: int) -> None:
        self._updates_since_ckpt += rows
        every = self.spec.durability.checkpoint_every
        if self.durable and every > 0 and self._updates_since_ckpt >= every:
            self.checkpoint()

    def close(self) -> None:
        """Flush, optionally checkpoint (DurabilitySpec.checkpoint_on_close),
        and release the WAL file handles.  Idempotent."""
        if self._closed:
            return
        self.flush()
        if self.durable and self.spec.durability.checkpoint_on_close:
            self.checkpoint()
        self.backend.close()
        self._closed = True

    def __enter__(self) -> "Service":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------- observability ------------------------
    def report(self) -> dict:
        rep = self.engine.report()
        rep["durability"] = {
            "durable": self.durable,
            "recovered": self.recovered,
            "wal_seqnos": (
                self.backend.wal_seqnos() if self.durable else None
            ),
            "updates_since_checkpoint": self._updates_since_ckpt,
        }
        return rep

    def stats(self) -> dict:
        return self.engine.stats()

    def backlog(self) -> int:
        return self.backend.backlog()


# ---------------------------------------------------------------------------
# open()
# ---------------------------------------------------------------------------

def _make_mesh(spec: ServiceSpec):
    import jax

    n = spec.shards.n_shards
    if len(spec.shards.shard_axes) != 1:
        raise ValueError(
            "spfresh.open builds single-axis meshes; pass mesh= for "
            f"multi-axis shard_axes {spec.shards.shard_axes}"
        )
    return jax.make_mesh((n,), spec.shards.shard_axes)


def _local_backend(spec: ServiceSpec, index: SPFreshIndex) -> LocalBackend:
    return LocalBackend(
        index,
        probe_chunk=spec.scan.probe_chunk,
        use_pallas_scan=spec.scan.use_pallas_scan,
        scan_schedule=spec.scan.scan_schedule,
    )


def open(
    spec: ServiceSpec,
    *,
    vectors: np.ndarray | None = None,
    mesh=None,
    fresh: bool = False,
) -> Service:
    """Open a SPFresh service described by ``spec``.

    * With a durable root whose snapshot exists: **recover** — load the
      snapshot, replay each shard's WAL tail through the backend, and
      resume serving (``vectors`` is ignored; the snapshot is truth).
    * Otherwise **build** from ``vectors`` (required); durable roots get
      an open-time checkpoint so the offline build itself survives a
      crash before the first explicit ``checkpoint()``.

    ``fresh=True`` forces the build path even when a snapshot exists —
    the durable root's previous contents are superseded by the new
    open-time checkpoint (a rebuild, not a recovery).

    The same spec (modulo :class:`ShardSpec`) opens a local service or an
    N-shard mesh service; ``mesh`` overrides the auto-built single-axis
    mesh (it must match ``spec.shards``).
    """
    spec.validate()
    cfg = spec.lire_config()
    dur = spec.durability
    n_shards = spec.shards.n_shards
    can_recover = (dur.enabled and not fresh
                   and snapshot_exists(dur.resolved_snapshot_dir()))
    if fresh and vectors is None:
        raise ValueError("fresh=True requires vectors to build from")
    if can_recover:
        # Validate the stamped config BEFORE building templates: a
        # geometry drift (e.g. the launcher re-run with different sizing
        # flags) must fail with field names, not a leaf-shape mismatch.
        check_replay_config(
            read_manifest(dur.resolved_snapshot_dir()), cfg,
            n_shards=n_shards,
        )

    initial_handles: np.ndarray | None = None
    recovered = False
    if not can_recover and vectors is None:
        raise FileNotFoundError(
            "no snapshot to recover and no vectors to build"
        )

    if spec.sharded:
        from repro.distributed.sharded_index import ShardedIndex

        mesh = mesh or _make_mesh(spec)
        kwargs = dict(
            shard_axes=spec.shards.shard_axes,
            probe_chunk=spec.scan.probe_chunk,
            use_pallas_scan=spec.scan.use_pallas_scan,
            scan_schedule=spec.scan.scan_schedule,
            jobs_per_round=cfg.jobs_per_round,
        )
        if can_recover:
            backend, manifest = ShardedIndex.restore(
                mesh, cfg, dur.resolved_snapshot_dir(), n_shards, **kwargs
            )
            recovered = True
        else:
            backend, initial_handles = ShardedIndex.build(
                mesh, cfg, np.asarray(vectors, np.float32), n_shards,
                seed=spec.index.seed, **kwargs
            )
    else:
        if can_recover:
            template = make_empty_state(cfg)
            state, manifest = load_snapshot(
                dur.resolved_snapshot_dir(), template
            )
            backend = _local_backend(spec, SPFreshIndex(state))
            recovered = True
        else:
            index = SPFreshIndex.build(
                cfg, np.asarray(vectors, np.float32), seed=spec.index.seed
            )
            initial_handles = np.arange(len(vectors), dtype=np.int64)
            backend = _local_backend(spec, index)

    if dur.enabled:
        wal_set = WalSet(dur.resolved_wal_dir(), n_shards)
        if recovered:
            records = wal_set.recover_records()
            after = min(manifest.get("extra", {}).get("wal_seqnos", [-1]))
            # The checkpoint truncated the logs: seqno numbering must
            # resume ABOVE the manifest stamp, or the next recovery would
            # skip fresh acknowledged records as already-applied.
            wal_set.ensure_seqno_floor(after)
            backend.attach_durability(wal_set, applied_seqno=after)
            backend.replay(records, after_seqno=after)
        else:
            # Fresh build over a durable root.  Leftover WAL records from
            # a previous incarnation are NOT truncated here: the open-time
            # checkpoint below drops them only AFTER its snapshot commits,
            # so a crash anywhere in this window still recovers the
            # previous incarnation intact (old snapshot + old WAL).
            backend.attach_durability(wal_set)
            if not dur.snapshot_on_open and (
                snapshot_exists(dur.resolved_snapshot_dir())
                or any(s >= 0 for s in wal_set.last_seqnos())
            ):
                raise ValueError(
                    "refusing to rebuild over a non-empty durable root "
                    "with snapshot_on_open=False: the old snapshot/WAL "
                    "would later recover mixed with the new build's "
                    "records (use fresh=True with snapshot_on_open=True, "
                    "or point DurabilitySpec at a clean root)"
                )

    engine = ServeEngine(backend, spec.engine_config())
    svc = Service(
        spec, engine, initial_handles=initial_handles, recovered=recovered
    )
    if dur.enabled and not recovered and dur.snapshot_on_open:
        # The offline build is not in the WAL; snapshot it so a crash
        # before the first checkpoint still recovers to a served state
        # (checkpoint also truncates any previous incarnation's WAL —
        # strictly after the new snapshot commits).
        svc.checkpoint()
    return svc
