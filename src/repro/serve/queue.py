"""Micro-batching request queue for the serving pipeline.

The engine's front door: callers ``submit`` search / insert / delete
requests of arbitrary size; the queue coalesces *contiguous runs of
same-kind requests* (order across kinds is preserved, so an insert
followed by a delete of the same id never reorders) and emits
fixed-shape **padded micro-batches**.

Padding is *pad-to-bucket*: batch rows are rounded up to the nearest
bucket in a small geometric ladder (default powers of two, e.g.
``8, 16, 32, 64, 128, 256``).  Under jit every distinct array shape is a
distinct compiled executable, so free-form batch sizes would thrash the
compile cache; a fixed bucket ladder keeps the cache warm at the cost of
a measurable amount of padding waste — which the queue accounts for
(``padded_rows`` vs ``real_rows``) so the trade-off shows up in the
engine's metrics instead of being invisible.

Large requests are split into parts of at most the largest bucket; a
:class:`Ticket` tracks all parts of one request and reassembles per-row
results in submission order.  Queue depth (in rows and requests) is
tracked continuously for the engine's depth metrics.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import numpy as np

SEARCH, INSERT, DELETE = "search", "insert", "delete"
_PAD_FILL = {"queries": 0.0, "vecs": 0.0, "vids": -1}


def default_buckets(min_bucket: int = 8, max_batch: int = 256) -> tuple[int, ...]:
    """Geometric (×2) bucket ladder from ``min_bucket`` to ``max_batch``."""
    assert min_bucket >= 1 and max_batch >= min_bucket
    out = []
    b = min_bucket
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


class Ticket:
    """Handle for one submitted request (possibly split into parts).

    ``result()`` blocks by pumping the owning engine until every part of
    the request has been processed, then returns the assembled per-row
    result (op-dependent; see :class:`ServeEngine`).
    """

    def __init__(self, op: str, n: int, key: tuple, engine: Any = None):
        self.op = op
        self.n = n
        self.key = key                    # (k, nprobe) for search, () else
        self.t_submit = time.perf_counter()
        self.t_done: float | None = None
        self._engine = engine
        self._pending = 0                 # parts not yet processed
        self._buffers: dict[str, np.ndarray] = {}

    @property
    def done(self) -> bool:
        return self._pending == 0

    def _complete_part(self, start: int, n: int, arrays: dict[str, np.ndarray]):
        for name, arr in arrays.items():
            if name not in self._buffers:
                shape = (self.n,) + arr.shape[1:]
                self._buffers[name] = np.zeros(shape, arr.dtype)
            self._buffers[name][start : start + n] = arr[:n]
        self._pending -= 1
        if self._pending == 0:
            self.t_done = time.perf_counter()

    def result(self):
        if not self.done:
            if self._engine is None:
                raise RuntimeError("ticket not done and no engine attached")
            self._engine._pump_until(self)
        return self._assemble()

    def _assemble(self):
        if self.op == SEARCH:
            return self._buffers["dists"], self._buffers["ids"]
        if self.op == INSERT:
            return self._buffers["ids"], self._buffers["landed"]
        return None

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit


@dataclasses.dataclass
class _Part:
    """A contiguous slice of one ticket's rows, at most one bucket wide."""

    ticket: Ticket
    arrays: dict[str, np.ndarray]   # unpadded row arrays for this part
    start: int                      # row offset inside the ticket
    n: int


@dataclasses.dataclass
class MicroBatch:
    """A padded, fixed-shape batch of same-kind parts ready for one jit call."""

    op: str
    key: tuple                      # per-op static params (k, nprobe)
    parts: list[_Part]
    arrays: dict[str, np.ndarray]   # padded to ``bucket`` rows
    n_valid: int
    bucket: int

    @property
    def valid(self) -> np.ndarray:
        return np.arange(self.bucket) < self.n_valid

    def scatter(self, results: dict[str, np.ndarray]) -> None:
        """Write per-row results back into the owning tickets."""
        off = 0
        for part in self.parts:
            sliced = {k: v[off : off + part.n] for k, v in results.items()}
            part.ticket._complete_part(part.start, part.n, sliced)
            off += part.n


class RequestQueue:
    """FIFO of request parts + the batching/padding policy described above."""

    def __init__(self, buckets: tuple[int, ...] | None = None):
        self.buckets = tuple(sorted(buckets or default_buckets()))
        self.max_batch = self.buckets[-1]
        self._fifo: deque[_Part] = deque()
        self._depth_rows = 0
        # cumulative accounting (engine metrics read these)
        self.real_rows = 0
        self.padded_rows = 0
        self.batches = 0
        self.max_depth_rows = 0
        self._depth_sum = 0.0
        self._depth_samples = 0

    # ------------------------------------------------------------- submit
    def submit(self, ticket: Ticket, arrays: dict[str, np.ndarray]) -> Ticket:
        """Split a request into ≤ max_batch parts and enqueue them in order."""
        n = ticket.n
        assert n >= 1, "empty request"
        for start in range(0, n, self.max_batch):
            stop = min(start + self.max_batch, n)
            part = _Part(
                ticket=ticket,
                arrays={k: v[start:stop] for k, v in arrays.items()},
                start=start,
                n=stop - start,
            )
            ticket._pending += 1
            self._fifo.append(part)
            self._depth_rows += part.n
        self.max_depth_rows = max(self.max_depth_rows, self._depth_rows)
        return ticket

    # -------------------------------------------------------------- state
    def __len__(self) -> int:
        return len(self._fifo)

    @property
    def depth_rows(self) -> int:
        return self._depth_rows

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_batch

    # ----------------------------------------------------------- batching
    def pop_batch(self) -> MicroBatch | None:
        """Coalesce the head run of same-kind/same-key parts into one
        padded batch.  Returns None when the queue is empty."""
        if not self._fifo:
            return None
        self._depth_sum += self._depth_rows
        self._depth_samples += 1

        head = self._fifo[0]
        op, key = head.ticket.op, head.ticket.key
        parts: list[_Part] = []
        rows = 0
        while self._fifo:
            p = self._fifo[0]
            if p.ticket.op != op or p.ticket.key != key:
                break
            if rows + p.n > self.max_batch:
                break
            parts.append(self._fifo.popleft())
            rows += p.n
        bucket = self.bucket_for(rows)
        self._depth_rows -= rows
        self.real_rows += rows
        self.padded_rows += bucket - rows
        self.batches += 1

        arrays: dict[str, np.ndarray] = {}
        for name in parts[0].arrays:
            chunks = [p.arrays[name] for p in parts]
            cat = np.concatenate(chunks) if len(chunks) > 1 else chunks[0]
            pad = bucket - rows
            if pad:
                width = [(0, pad)] + [(0, 0)] * (cat.ndim - 1)
                cat = np.pad(cat, width, constant_values=_PAD_FILL.get(name, 0))
            arrays[name] = cat
        return MicroBatch(
            op=op, key=key, parts=parts, arrays=arrays,
            n_valid=rows, bucket=bucket,
        )

    # ------------------------------------------------------------ metrics
    def accounting(self) -> dict:
        total = self.real_rows + self.padded_rows
        return {
            "batches": self.batches,
            "rows": self.real_rows,
            "padded_rows": self.padded_rows,
            "padding_waste_frac": self.padded_rows / total if total else 0.0,
            "depth_rows_now": self._depth_rows,
            "depth_rows_max": self.max_depth_rows,
            "depth_rows_avg": (
                self._depth_sum / self._depth_samples
                if self._depth_samples else 0.0
            ),
        }
