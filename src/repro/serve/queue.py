"""Micro-batching request queue for the serving pipeline.

The engine's front door: callers ``submit`` search / insert / delete
requests of arbitrary size; the queue coalesces *contiguous runs of
same-kind requests* (order across kinds is preserved, so an insert
followed by a delete of the same id never reorders) and emits
fixed-shape **padded micro-batches**.

Padding is *pad-to-bucket*: batch rows are rounded up to the nearest
bucket in a small geometric ladder (default powers of two, e.g.
``8, 16, 32, 64, 128, 256``).  Under jit every distinct array shape is a
distinct compiled executable, so free-form batch sizes would thrash the
compile cache; a fixed bucket ladder keeps the cache warm at the cost of
a measurable amount of padding waste — which the queue accounts for
(``padded_rows`` vs ``real_rows``) so the trade-off shows up in the
engine's metrics instead of being invisible.

Threading: the queue is safe for many producer threads and ONE consumer
(the engine's pump thread).  ``submit`` enqueues all parts of a request
atomically under the queue lock; ``pop_batch(block=True)`` waits on a
condition variable.  With ``max_wait_ms > 0`` the consumer additionally
holds a *batch-formation window*: a head run smaller than the top bucket
is kept on the queue until either the window since its first part
expires, the run fills ``max_batch``, or a different-kind part fences it
— so under open-loop load micro-batches fill toward the top bucket
instead of dispatching the head run immediately (less padding waste,
fewer dispatches).

Large requests are split into parts of at most the largest bucket; a
:class:`Ticket` tracks all parts of one request and reassembles per-row
results in submission order.  Queue depth (in rows and requests) is
tracked continuously for the engine's depth metrics.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any

import numpy as np

SEARCH, INSERT, DELETE = "search", "insert", "delete"
_PAD_FILL = {"queries": 0.0, "vecs": 0.0, "vids": -1}


def default_buckets(min_bucket: int = 8, max_batch: int = 256) -> tuple[int, ...]:
    """Geometric (×2) bucket ladder from ``min_bucket`` to ``max_batch``."""
    assert min_bucket >= 1 and max_batch >= min_bucket
    out = []
    b = min_bucket
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


class Ticket:
    """Handle for one submitted request (possibly split into parts).

    ``result()`` blocks until every part of the request has been
    processed, then returns the assembled per-row result (op-dependent;
    see :class:`ServeEngine`).  In cooperative (sync) mode the caller
    thread pumps the engine itself; with a background pump thread
    (``async_serve``) the caller waits on the ticket's event, which the
    engine sets after the batch is processed — and, for durable update
    tickets, only after the covering WAL fsync (the group-commit ack).
    """

    def __init__(self, op: str, n: int, key: tuple, engine: Any = None):
        self.op = op
        self.n = n
        self.key = key                    # (k, nprobe) for search, () else
        self.t_submit = time.perf_counter()
        self.t_done: float | None = None
        self.dropped = 0                  # insert rows lost to backpressure
        self._engine = engine
        self._pending = 0                 # parts not yet processed
        self._buffers: dict[str, np.ndarray] = {}
        self._event = threading.Event()
        # Parts of one ticket may complete from different threads (the
        # pump and replica workers both scatter results), so the pending
        # count and buffer creation are guarded.
        self._lock = threading.Lock()

    @property
    def done(self) -> bool:
        return self._pending == 0

    def _complete_part(self, start: int, n: int, arrays: dict[str, np.ndarray]):
        with self._lock:
            for name, arr in arrays.items():
                if name not in self._buffers:
                    shape = (self.n,) + arr.shape[1:]
                    self._buffers[name] = np.zeros(shape, arr.dtype)
                self._buffers[name][start : start + n] = arr[:n]
            self._pending -= 1
            if self._pending == 0:
                self.t_done = time.perf_counter()

    def _signal(self) -> None:
        """Release waiters (engine-owned: the pump thread calls this after
        processing — or after the WAL ack for durable updates)."""
        self._event.set()

    def result(self, timeout: float | None = None):
        eng = self._engine
        if eng is not None and getattr(eng, "is_async", False):
            deadline = None if timeout is None else time.monotonic() + timeout
            # Poll in short slices so a dead pump thread surfaces as an
            # exception here instead of a silent hang.
            while not self._event.wait(0.2):
                err = getattr(eng, "_pump_error", None)
                if err is not None:
                    raise RuntimeError("serve pump thread died") from err
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"{self.op} ticket ({self.n} rows) not done "
                        f"after {timeout}s"
                    )
            return self._assemble()
        if not self.done:
            if eng is None:
                raise RuntimeError("ticket not done and no engine attached")
            eng._pump_until(self)
        return self._assemble()

    def _assemble(self):
        if self.op == SEARCH:
            return self._buffers["dists"], self._buffers["ids"]
        if self.op == INSERT:
            return self._buffers["ids"], self._buffers["landed"]
        return None

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit


@dataclasses.dataclass
class _Part:
    """A contiguous slice of one ticket's rows, at most one bucket wide."""

    ticket: Ticket
    arrays: dict[str, np.ndarray]   # unpadded row arrays for this part
    start: int                      # row offset inside the ticket
    n: int
    t_enq: float = 0.0              # enqueue time (batch-formation window)


@dataclasses.dataclass
class MicroBatch:
    """A padded, fixed-shape batch of same-kind parts ready for one jit call."""

    op: str
    key: tuple                      # per-op static params (k, nprobe)
    parts: list[_Part]
    arrays: dict[str, np.ndarray]   # padded to ``bucket`` rows
    n_valid: int
    bucket: int

    @property
    def valid(self) -> np.ndarray:
        return np.arange(self.bucket) < self.n_valid

    def scatter(self, results: dict[str, np.ndarray]) -> None:
        """Write per-row results back into the owning tickets."""
        off = 0
        for part in self.parts:
            sliced = {k: v[off : off + part.n] for k, v in results.items()}
            part.ticket._complete_part(part.start, part.n, sliced)
            off += part.n


class RequestQueue:
    """FIFO of request parts + the batching/padding policy described above.

    Thread-safe for N producers × 1 consumer.  ``max_wait_ms`` is the
    batch-formation window (0 = dispatch the head run immediately, the
    pre-async behavior).  Batch staging buffers are cached per
    (op, bucket, dtype/shape) and reused across pops: the jit entry
    points copy host arrays onto the device at dispatch time, so the
    staging memory is dead the moment the dispatch is issued — reusing
    it cuts two allocations (concatenate + pad) per batch.
    """

    def __init__(self, buckets: tuple[int, ...] | None = None,
                 *, max_wait_ms: float = 0.0, reuse_staging: bool = True):
        self.buckets = tuple(sorted(buckets or default_buckets()))
        self.max_batch = self.buckets[-1]
        self.max_wait_ms = max_wait_ms
        self.reuse_staging = reuse_staging
        self._fifo: deque[_Part] = deque()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._staging: dict[tuple, dict[str, np.ndarray]] = {}
        self._depth_rows = 0
        # cumulative accounting (engine metrics read these)
        self.real_rows = 0
        self.padded_rows = 0
        self.batches = 0
        self.window_waits = 0           # pops that held the formation window
        self.max_depth_rows = 0
        self._depth_sum = 0.0
        self._depth_samples = 0

    # ------------------------------------------------------------- submit
    def submit(self, ticket: Ticket, arrays: dict[str, np.ndarray]) -> Ticket:
        """Split a request into ≤ max_batch parts and enqueue them in order.
        All parts land atomically: the consumer can never observe (and
        complete) a prefix of a request whose tail is still being split,
        so ``ticket.done`` only flips once every row is accounted for."""
        n = ticket.n
        assert n >= 1, "empty request"
        parts = []
        now = time.monotonic()
        for start in range(0, n, self.max_batch):
            stop = min(start + self.max_batch, n)
            parts.append(_Part(
                ticket=ticket,
                arrays={k: v[start:stop] for k, v in arrays.items()},
                start=start,
                n=stop - start,
                t_enq=now,
            ))
        with self._cond:
            with ticket._lock:
                ticket._pending += len(parts)
            self._fifo.extend(parts)
            self._depth_rows += n
            self.max_depth_rows = max(self.max_depth_rows, self._depth_rows)
            self._cond.notify_all()
        return ticket

    # -------------------------------------------------------------- state
    def __len__(self) -> int:
        return len(self._fifo)

    @property
    def depth_rows(self) -> int:
        return self._depth_rows

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_batch

    def wake(self) -> None:
        """Wake a consumer blocked in ``pop_batch`` (e.g. for shutdown)."""
        with self._cond:
            self._cond.notify_all()

    def requeue(self, parts: list[_Part]) -> None:
        """Push already-submitted parts back onto the HEAD of the queue
        (a failed replica hands its routed batches back this way).  The
        owning tickets' pending counts still include these parts, so no
        re-accounting — they simply get popped and served again."""
        if not parts:
            return
        with self._cond:
            self._fifo.extendleft(reversed(parts))
            self._depth_rows += sum(p.n for p in parts)
            self.max_depth_rows = max(self.max_depth_rows, self._depth_rows)
            self._cond.notify_all()

    def wait_nonempty(self, timeout: float | None = None) -> bool:
        """Block until at least one part is queued (or timeout)."""
        with self._cond:
            if self._fifo:
                return True
            self._cond.wait(timeout)
            return bool(self._fifo)

    # ----------------------------------------------------------- batching
    def _head_run(self) -> tuple[int, bool]:
        """Rows in the coalescible head run and whether the run is fenced
        (a different-kind part queued behind it, or max_batch reached) —
        a fenced run cannot grow, so the window must not hold it."""
        head = self._fifo[0]
        op, key = head.ticket.op, head.ticket.key
        rows = 0
        for p in self._fifo:
            if p.ticket.op != op or p.ticket.key != key:
                return rows, True
            if rows + p.n > self.max_batch:
                return rows, True
            rows += p.n
        return rows, rows >= self.max_batch

    def pop_batch(self, *, block: bool = False, timeout: float | None = None,
                  force: bool = False) -> MicroBatch | None:
        """Coalesce the head run of same-kind/same-key parts into one
        padded batch.  Returns None when the queue is empty (after
        waiting up to ``timeout`` if ``block``).  With ``max_wait_ms``
        set, an unfenced head run that hasn't filled the top bucket is
        held until the window since its first part's enqueue expires —
        ``force=True`` skips the hold (flush/shutdown)."""
        deadline = (
            time.monotonic() + timeout
            if (block and timeout is not None) else None
        )
        with self._cond:
            while True:
                if self._fifo:
                    rows, fenced = self._head_run()
                    if force or self.max_wait_ms <= 0 or fenced:
                        return self._form_batch()
                    window_end = (
                        self._fifo[0].t_enq + self.max_wait_ms / 1e3
                    )
                    wait = window_end - time.monotonic()
                    if wait <= 0:
                        return self._form_batch()
                    self.window_waits += 1
                    self._cond.wait(wait)
                    continue
                if not block:
                    return None
                if deadline is None:
                    self._cond.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                    self._cond.wait(remaining)

    def _form_batch(self) -> MicroBatch:
        """Pop + pad the head run.  Caller holds the lock."""
        self._depth_sum += self._depth_rows
        self._depth_samples += 1

        head = self._fifo[0]
        op, key = head.ticket.op, head.ticket.key
        parts: list[_Part] = []
        rows = 0
        while self._fifo:
            p = self._fifo[0]
            if p.ticket.op != op or p.ticket.key != key:
                break
            if rows + p.n > self.max_batch:
                break
            parts.append(self._fifo.popleft())
            rows += p.n
        bucket = self.bucket_for(rows)
        self._depth_rows -= rows
        self.real_rows += rows
        self.padded_rows += bucket - rows
        self.batches += 1

        arrays: dict[str, np.ndarray] = {}
        if not self.reuse_staging:
            # legacy path: one concatenate + one pad allocation per batch
            for name in parts[0].arrays:
                chunks = [p.arrays[name] for p in parts]
                cat = np.concatenate(chunks) if len(chunks) > 1 else chunks[0]
                pad = bucket - rows
                if pad:
                    width = [(0, pad)] + [(0, 0)] * (cat.ndim - 1)
                    cat = np.pad(
                        cat, width, constant_values=_PAD_FILL.get(name, 0)
                    )
                arrays[name] = cat
            return MicroBatch(
                op=op, key=key, parts=parts, arrays=arrays,
                n_valid=rows, bucket=bucket,
            )
        staging = self._staging.setdefault((op, key, bucket), {})
        for name in parts[0].arrays:
            first = parts[0].arrays[name]
            shape = (bucket,) + first.shape[1:]
            buf = staging.get(name)
            if buf is None or buf.shape != shape or buf.dtype != first.dtype:
                buf = np.empty(shape, first.dtype)
                staging[name] = buf
            off = 0
            for p in parts:
                buf[off : off + p.n] = p.arrays[name]
                off += p.n
            if rows < bucket:
                buf[rows:] = _PAD_FILL.get(name, 0)
            arrays[name] = buf
        return MicroBatch(
            op=op, key=key, parts=parts, arrays=arrays,
            n_valid=rows, bucket=bucket,
        )

    # ------------------------------------------------------------ metrics
    def accounting(self) -> dict:
        total = self.real_rows + self.padded_rows
        return {
            "batches": self.batches,
            "rows": self.real_rows,
            "padded_rows": self.padded_rows,
            "padding_waste_frac": self.padded_rows / total if total else 0.0,
            "window_waits": self.window_waits,
            "depth_rows_now": self._depth_rows,
            "depth_rows_max": self.max_depth_rows,
            "depth_rows_avg": (
                self._depth_sum / self._depth_samples
                if self._depth_samples else 0.0
            ),
        }
