"""Serving engine: the paper's online loop (§5.2/§5.3) as a host driver.

Search / insert / delete requests are micro-batched; the background Local
Rebuilder is interleaved at a configurable fg:bg ratio (the paper's 2:1
feed-forward pipeline, Fig. 12).  The latency budget is a candidate budget
(nprobe), the jit-world analogue of the paper's 10 ms hard cut.

Metrics: per-request latency percentiles, throughput, rebalancing stats —
everything Fig. 7/9 plots.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import lire
from repro.core.index import SPFreshIndex


@dataclasses.dataclass
class EngineConfig:
    search_k: int = 10
    nprobe: int | None = None
    fg_bg_ratio: int = 2        # foreground batches per background step (2:1)
    maintain_budget: int = 8    # max rebuild steps per background slot


class ServeEngine:
    def __init__(self, index: SPFreshIndex, cfg: EngineConfig | None = None):
        self.index = index
        self.cfg = cfg or EngineConfig()
        self.search_lat: list[float] = []
        self.insert_lat: list[float] = []
        self._fg_since_bg = 0

    # ------------------------------------------------------------------
    def search(self, queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        t0 = time.time()
        d, v = self.index.search(
            queries, self.cfg.search_k, nprobe=self.cfg.nprobe
        )
        self.search_lat.append(time.time() - t0)
        return d, v

    def insert(self, vecs: np.ndarray, vids: np.ndarray) -> None:
        t0 = time.time()
        self.index.insert(vecs, vids)
        self.insert_lat.append(time.time() - t0)
        self._tick_background()

    def delete(self, vids: np.ndarray) -> None:
        self.index.delete(vids)
        self._tick_background()

    def _tick_background(self) -> None:
        """Feed-forward pipeline: every fg_bg_ratio foreground batches, give
        the Local Rebuilder one slot of maintain_budget steps."""
        self._fg_since_bg += 1
        if self._fg_since_bg >= self.cfg.fg_bg_ratio:
            self._fg_since_bg = 0
            self.index.maintain(max_steps=self.cfg.maintain_budget)

    def drain(self) -> int:
        return self.index.maintain()

    # ------------------------------------------------------------------
    def latency_percentiles(self, which: str = "search") -> dict:
        lat = self.search_lat if which == "search" else self.insert_lat
        if not lat:
            return {}
        arr = np.asarray(lat) * 1e3
        return {
            "p50_ms": float(np.percentile(arr, 50)),
            "p90_ms": float(np.percentile(arr, 90)),
            "p99_ms": float(np.percentile(arr, 99)),
            "p999_ms": float(np.percentile(arr, 99.9)),
            "mean_ms": float(arr.mean()),
            "n": len(arr),
        }

    def stats(self) -> dict:
        return self.index.stats()
