"""Serving engine: the paper's online loop (§5.2/§5.3) as a batched
async pipeline.

Requests enter through a :class:`~repro.serve.queue.RequestQueue` that
micro-batches them into fixed-shape padded buckets (so the jit compile
cache stays warm); each micro-batch is ONE dispatch into a cached,
state-donating executable — `core.index.search_step` /
`insert_step` / `delete_step` for a single-host index, or the
shard_map'd steps of `distributed.sharded_index.ShardedIndex` for an
N-shard mesh.  The same engine serves both: backends implement the
small protocol below.

Two serving modes share the pipeline:

* **Cooperative (default)** — callers pump the queue themselves
  (``ticket.result()`` → ``_pump_until``); simple and deterministic,
  but every maintenance slot and every other caller's batch sits on
  each request's critical path.
* **Async (``EngineConfig.async_serve``)** — a dedicated background
  pump thread owns ALL backend dispatches; callers only enqueue and
  block on a per-ticket event.  The pump exploits JAX async dispatch
  (search readbacks are deferred so the device overlaps the next
  batch's work), schedules maintenance slots in queue-idle gaps with a
  backlog-pressure override, and acks durable update tickets only
  after the covering WAL fsync.  WAL appends and state-mutating
  dispatches stay in ONE serialized order on the pump thread, so
  crash replay is exactly as bit-deterministic as in sync mode.

Background maintenance (the Local Rebuilder) is scheduled by a
pluggable :class:`~repro.serve.policy.MaintenancePolicy` — the paper's
2:1 feed-forward pipeline (Fig. 12) is ``RatioPolicy(2)``; a reactive
``BacklogPolicy`` fires only when oversized postings actually exist.

Metrics: per-op latency percentiles (bounded reservoir), queue depth,
padding waste, and maintenance throughput/overlap — everything
Fig. 7/9/12 plot, per policy.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import threading
import time
from collections import deque
from typing import Callable, Protocol

import numpy as np

from repro.core.index import SPFreshIndex
from repro.serve.ownership import (
    GUARDED, INIT, LIFECYCLE, PUMP, holds_work, install_lock_check,
)
from repro.serve.policy import BacklogPolicy, MaintenancePolicy, RatioPolicy
from repro.storage.durability import DurableBackend
from repro.serve.queue import (
    DELETE, INSERT, SEARCH, MicroBatch, RequestQueue, Ticket, default_buckets,
)

log = logging.getLogger("repro.serve")


# ---------------------------------------------------------------------------
# Backend protocol + the single-host backend
# ---------------------------------------------------------------------------

class IndexBackend(Protocol):
    """What the engine needs from an index: fixed-shape batched ops, plus
    the durable lifecycle (`spfresh.open` drives the last four — every
    update dispatch is WAL-appended before it runs, `checkpoint` commits
    an atomic snapshot stamping per-shard WAL seqnos, and `replay`
    re-applies a WAL tail through the same jitted dispatches)."""

    def search(self, queries: np.ndarray, k: int, nprobe: int | None,
               valid: np.ndarray | None = None,
               ) -> tuple[np.ndarray, np.ndarray]: ...

    def search_begin(self, queries: np.ndarray, k: int, nprobe: int | None,
                     valid: np.ndarray | None = None,
                     ) -> Callable[[], tuple[np.ndarray, np.ndarray]]: ...

    def insert(self, vecs: np.ndarray, vids: np.ndarray, valid: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]: ...

    def delete(self, vids: np.ndarray, valid: np.ndarray) -> None: ...

    def log_update(self, op: str, payload: dict) -> None: ...

    def maintain(self, jobs: int) -> int: ...

    def drain(self) -> tuple[int, int]: ...

    def backlog(self) -> int: ...

    def stats(self) -> dict: ...

    # --- durability lifecycle (paper §4.4, promoted into the protocol) ---

    def attach_durability(self, wal_set) -> None: ...

    def checkpoint(self, snapshot_dir: str, *, delta: bool = False) -> None: ...

    def wal_sync(self) -> None: ...

    def replay(self, records, after_seqno: int = -1) -> int: ...

    def close(self) -> None: ...


class LocalBackend(DurableBackend):
    """Single-host SPFreshIndex behind the batched entry points.

    ``probe_chunk`` / ``use_pallas_scan`` / ``scan_schedule`` select the
    posting-scan data path for every search dispatch (engine knobs; the
    scan flags default to the index config when None).

    With a :class:`~repro.storage.wal.WalSet` attached
    (``attach_durability`` — `spfresh.open` does this), every update
    DISPATCH (insert/delete/maintain/drain, with its padded arrays and
    masks) is WAL-appended before it runs.  Because the jitted steps are
    deterministic functions of (state, batch), replaying the dispatch
    stream on top of a snapshot reproduces the index bit-for-bit —
    including the engine's backpressure retries, whose interleaved
    maintenance slots appear in the log at their true positions.
    """

    def __init__(
        self,
        index: SPFreshIndex,
        *,
        probe_chunk: int = 0,
        use_pallas_scan: bool | None = None,
        scan_schedule: str | None = None,
        track_access: bool = True,
    ):
        self.index = index
        self.probe_chunk = probe_chunk
        self.use_pallas_scan = use_pallas_scan
        self.scan_schedule = scan_schedule
        self.track_access = track_access
        # Per-posting probe counts accumulated since the last maintenance
        # dispatch.  Searches are NOT WAL-logged, so this buffer must never
        # touch the index state directly: it is drained into the payload of
        # the next logged maintain/drain dispatch and folded inside that
        # jitted round — live and on replay alike (bit-exact recovery).
        self._pending_access = np.zeros(
            (index.state.cfg.num_postings_cap,), np.int64
        )

    def search(self, queries, k, nprobe, valid=None):
        return self.search_begin(queries, k, nprobe, valid)()

    def search_begin(self, queries, k, nprobe, valid=None):
        """Issue ONE search dispatch and return a zero-arg ``finalize``
        that materializes ``(dists, ids)`` on the host.  The dispatch is
        in flight the moment this returns (JAX async dispatch) — the
        engine's pump thread defers ``finalize`` to scatter time so the
        device overlaps it with the next batch's work.  Access telemetry
        is folded into ``_pending_access`` at finalize time, always
        before the next maintenance dispatch drains it."""
        if not self.track_access:
            out = self.index.search_padded(
                queries, k, nprobe=nprobe, probe_chunk=self.probe_chunk,
                use_pallas_scan=self.use_pallas_scan,
                scan_schedule=self.scan_schedule, as_jax=True,
            )

            def finalize():
                return np.asarray(out[0]), np.asarray(out[1])
            return finalize
        out = self.index.search_padded(
            queries, k, nprobe=nprobe, probe_chunk=self.probe_chunk,
            use_pallas_scan=self.use_pallas_scan,
            scan_schedule=self.scan_schedule,
            with_access=True, qvalid=valid, as_jax=True,
        )

        def finalize():
            d, v, hist = (np.asarray(x) for x in out)
            self._pending_access += hist
            return d, v
        return finalize

    def _take_access(self) -> np.ndarray:
        """Drain the pending probe counts for a maintenance dispatch.
        Access accumulated after the LAST logged dispatch is lost on a
        crash (it never entered the WAL) — deterministically so: the
        recovered twin replays exactly the folds the WAL saw."""
        acc = np.minimum(
            self._pending_access, np.iinfo(np.int32).max
        ).astype(np.int32)
        self._pending_access[:] = 0
        return acc

    def insert(self, vecs, vids, valid):
        self._log("insert", {
            "vecs": np.asarray(vecs, np.float32),
            "vids": np.asarray(vids, np.int32),
            "valid": np.asarray(valid, bool),
        })
        landed = self.index.insert_padded(vecs, vids, valid)
        return np.asarray(vids), landed

    def delete(self, vids, valid):
        self._log("delete", {
            "vids": np.asarray(vids, np.int32),
            "valid": np.asarray(valid, bool),
        })
        self.index.delete_padded(vids, valid)

    def log_update(self, op, payload):
        """WAL-log a pipeline update batch (crash recovery, §4.4): the
        padded jit entry points bypass SPFreshIndex.insert/delete, so the
        engine logs here — once per batch, before the first dispatch.
        Legacy request-level path (SPFreshIndex built with ``wal_path``);
        the dispatch-level ``WalSet`` log supersedes it under
        `spfresh.open`."""
        if self.index.wal is not None:
            self.index._wal_applied = self.index.wal.append(op, payload)

    def maintain(self, jobs):
        access = self._take_access()
        self._log("maintain", {
            "jobs": np.asarray(jobs, np.int32), "access": access,
        })
        return self.index.maintain_round(jobs, access=access)

    def drain(self):
        # The record carries the jobs-per-round it drained with: replay
        # must re-run the same round shapes even if the index was
        # reopened under a different cfg.jobs_per_round (that field is
        # serving-side, not snapshot-stamped).
        access = self._take_access()
        jpr = int(self.index.state.cfg.jobs_per_round)
        self._log("drain", {
            "jobs": np.asarray(jpr, np.int32), "access": access,
        })
        jobs = self.index.maintain(jobs_per_round=jpr, access=access)
        return jobs, self.index.last_drain_rounds

    def backlog(self):
        return self.index.backlog()

    def stats(self):
        return self.index.stats()

    # ---------------- replication hooks (replica cloning) ---------------
    def fork_state(self):
        """Deep copy of the index state.  The padded update entry points
        donate their state buffers, so a replica sharing references with
        the primary would be invalidated by the next update dispatch."""
        import jax

        return jax.tree_util.tree_map(lambda x: x.copy(), self.index.state)

    def adopt_state(self, state) -> None:
        self.index.state = state

    def clone(self) -> "LocalBackend":
        """A read replica of this backend: same scan config, its own
        deep-copied state, no access telemetry of its own (replayed
        ``maintain`` records carry the primary's logged access counts —
        folding replica-local counts on top would break bit-parity)."""
        twin = LocalBackend(
            SPFreshIndex(self.fork_state()),
            probe_chunk=self.probe_chunk,
            use_pallas_scan=self.use_pallas_scan,
            scan_schedule=self.scan_schedule,
            track_access=False,
        )
        twin._wal_applied = self._wal_applied
        return twin

    # --------------- durability hooks (DurableBackend) -----------------
    def _snapshot_state(self):
        return self.index.state

    def _set_snapshot_state(self, state):
        self.index.state = state

    def _snapshot_extra(self):
        return {"backend": "local"}

    def _lire_config(self):
        return self.index.state.cfg

    def _apply_record(self, rec) -> None:
        p = rec.payload
        if rec.op == "insert":
            self.index.insert_padded(p["vecs"], p["vids"], p["valid"])
        elif rec.op == "delete":
            self.index.delete_padded(p["vids"], p["valid"])
        elif rec.op == "maintain":
            # Old records (pre-telemetry) carry no "access" — .get(None)
            # folds zeros, tracing the same graph those dispatches ran.
            self.index.maintain_round(int(p["jobs"]), access=p.get("access"))
        elif rec.op == "drain":
            # Pre-fix records carry no "jobs" — fall back to the config
            # default those drains actually ran with.
            self.index.maintain(
                jobs_per_round=int(p["jobs"]) if "jobs" in p else None,
                access=p.get("access"),
            )
        else:
            raise ValueError(f"unknown WAL op {rec.op!r}")

    def close(self) -> None:
        super().close()
        if self.index.wal is not None:
            self.index.wal.close()


# ---------------------------------------------------------------------------
# Config + metrics
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineConfig:
    """Pipeline knobs.  Deprecated as a user-facing surface: prefer
    declaring a :class:`repro.api.ServiceSpec` (its serve/scan/
    maintenance sub-specs compile to this via ``engine_config()``);
    direct construction remains for the engine internals and one
    release of back-compat."""

    search_k: int = 10
    nprobe: int | None = None
    # --- search data path (threaded into every search dispatch) ---
    probe_chunk: int = 0                  # oracle-path streaming chunk (0 = off)
    use_pallas_scan: bool | None = None   # None = defer to LireConfig
    scan_schedule: str | None = None      # "per_query" | "batched" | None
    # --- micro-batching ---
    max_batch: int = 256         # largest bucket (rows per dispatch)
    min_bucket: int = 8          # smallest bucket
    # --- maintenance scheduling (used when no policy object is given) ---
    policy: str = "ratio"        # "ratio" | "backlog"
    fg_bg_ratio: int = 2         # foreground update batches per bg slot (2:1)
    # Jobs per background ROUND: each slot is ONE fused dispatch splitting
    # the top-`maintain_budget` oversized postings and merging the bottom-
    # `maintain_budget` undersized, with one fused reassign pass (the
    # pre-round semantics were sequential steps per slot).
    maintain_budget: int = 8
    backlog_threshold: int = 1   # BacklogPolicy firing threshold
    # --- insert backpressure ---
    max_insert_retries: int = 4
    # --- async serving (background pump thread) ---
    async_serve: bool = False
    max_wait_ms: float = 0.0     # batch-formation window (async queue)
    max_inflight: int = 2        # deferred search readbacks in flight
    # --- read replicas (distributed/replication.py) ---
    max_lag: int = 64            # replica freshness bound (WAL seqnos)
    replica_inflight: int = 2    # routed batches per replica in flight
    # Deferred background slots tolerated before one runs inline even
    # under load — keeps the steady-state slot rate equal to sync mode's
    # when the queue never goes idle.
    maint_pressure: int = 8
    ack_batch: int = 32          # unacked update tickets per forced fsync
    lat_reservoir: int = 4096    # bounded latency sample size per op
    # Debug: enforce the engine's FIELD_OWNERSHIP map at runtime (owner-
    # tracking lock + checking __setattr__, serve/ownership.py).  The
    # async stress tests run under this; off in production (costs a dict
    # lookup per attribute write).
    lock_check: bool = False

    def buckets(self) -> tuple[int, ...]:
        return default_buckets(self.min_bucket, self.max_batch)

    def make_policy(self) -> MaintenancePolicy:
        if self.policy == "backlog":
            return BacklogPolicy(self.backlog_threshold, self.maintain_budget)
        return RatioPolicy(self.fg_bg_ratio, self.maintain_budget)


class _LatReservoir:
    """Uniform bounded sample of a latency stream (Vitter's algorithm R).
    A long-running service observes unbounded tickets; percentiles only
    need a uniform sample, so memory stays O(cap) forever."""

    __slots__ = ("cap", "n", "_buf", "_rng")

    def __init__(self, cap: int = 4096, seed: int = 0):
        self.cap = int(cap)
        self.n = 0
        self._buf: list[float] = []
        self._rng = np.random.default_rng(seed)

    def add(self, x: float) -> None:
        self.n += 1
        if len(self._buf) < self.cap:
            self._buf.append(x)
        else:
            j = int(self._rng.integers(0, self.n))
            if j < self.cap:
                self._buf[j] = x

    def values(self) -> list[float]:
        return self._buf

    def __len__(self) -> int:
        return self.n


class ServeMetrics:
    """Aggregated pipeline observability (read via ``ServeEngine.report``)."""

    def __init__(self, reservoir: int = 4096):
        self.lat: dict[str, _LatReservoir] = {
            op: _LatReservoir(reservoir, seed=i)
            for i, op in enumerate((SEARCH, INSERT, DELETE))
        }
        # tickets complete from the pump AND from replica worker threads
        self._note_lock = threading.Lock()
        self.maint_slots = 0
        self.maint_rounds = 0
        self.maint_steps = 0
        self.maint_time_s = 0.0
        # async-mode split: slots run in queue-idle gaps (overlapped with
        # nothing on the serve path) vs deferred/forced under pressure
        self.maint_idle_slots = 0
        self.maint_idle_time_s = 0.0
        self.maint_deferred = 0
        self.maint_forced = 0
        self.insert_retries = 0
        self.insert_stall_s = 0.0
        self.insert_dropped = 0

    def note_ticket(self, ticket: Ticket) -> None:
        if ticket.latency_s is not None:
            with self._note_lock:
                self.lat[ticket.op].add(ticket.latency_s)

    def note_maintenance(self, steps: int, dt: float, rounds: int = 1,
                         idle: bool = False) -> None:
        self.maint_slots += 1
        self.maint_rounds += rounds
        self.maint_steps += steps
        self.maint_time_s += dt
        if idle:
            self.maint_idle_slots += 1
            self.maint_idle_time_s += dt

    def percentiles(self, op: str) -> dict:
        res = self.lat.get(op)
        if res is None or not res.values():
            return {}
        with self._note_lock:
            arr = np.asarray(res.values()) * 1e3
        return {
            "p50_ms": float(np.percentile(arr, 50)),
            "p90_ms": float(np.percentile(arr, 90)),
            "p99_ms": float(np.percentile(arr, 99)),
            "p999_ms": float(np.percentile(arr, 99.9)),
            "mean_ms": float(arr.mean()),
            "n": res.n,
        }


class ServeEngine:
    """Batched async serving pipeline over a local or sharded index.

    Async API: ``submit_search`` / ``submit_insert`` / ``submit_delete``
    return a :class:`Ticket`; ``ticket.result()`` blocks until that
    request completes.  In cooperative mode (default) the caller thread
    pumps the queue itself; with ``EngineConfig.async_serve`` a
    background pump thread owns all dispatches and ``pump()`` becomes a
    flush barrier.  The synchronous ``search`` / ``insert`` / ``delete``
    methods are submit-then-wait conveniences (and the pre-pipeline API).

    Threading invariants (async mode):

    * ONLY the pump thread calls into the backend for serving work —
      WAL appends and state-mutating dispatches form one serialized
      order, so replay determinism is identical to sync mode.
    * External backend work (maintain/checkpoint/drain from the caller
      thread) must run under ``exclusive()``.
    * Durable update tickets are signaled only after the covering WAL
      fsync (group-commit ack); search tickets signal at readback.

    The map below is the machine-checked form of those invariants: the
    spflint lock pass (SPF20x) verifies every ``self.<field>`` access
    site against it, and ``EngineConfig.lock_check`` enforces it at
    runtime (serve/ownership.py).
    """

    LOCK_FIELD = "_work"
    PUMP_METHODS = ("_pump_loop",)
    LIFECYCLE_METHODS = ("start", "shutdown")
    FIELD_OWNERSHIP = {
        # bound once in __init__, immutable after
        "cfg": INIT, "backend": INIT, "policy": INIT, "queue": INIT,
        "metrics": INIT, "_work": INIT, "_stop": INIT, "replicas": INIT,
        # shared mutable pipeline state: only under _work
        "_inflight": GUARDED, "_unacked": GUARDED, "_maint_due": GUARDED,
        # pump-thread-only writes; racy reads are benign by design
        "_busy": PUMP, "_pump_error": PUMP,
        # written by start()/shutdown(), which run strictly outside the
        # pump thread's lifetime
        "_pump_thread": LIFECYCLE,
    }

    def __init__(
        self,
        backend: IndexBackend | SPFreshIndex,
        cfg: EngineConfig | None = None,
        policy: MaintenancePolicy | None = None,
        replicas=None,
    ):
        self.cfg = cfg or EngineConfig()
        if isinstance(backend, SPFreshIndex):
            backend = LocalBackend(
                backend,
                probe_chunk=self.cfg.probe_chunk,
                use_pallas_scan=self.cfg.use_pallas_scan,
                scan_schedule=self.cfg.scan_schedule,
            )
        self.backend = backend
        self.policy = policy or self.cfg.make_policy()
        # the batch-formation window only makes sense with a dedicated
        # consumer: in cooperative mode it would stall the caller itself
        self.queue = RequestQueue(
            self.cfg.buckets(),
            max_wait_ms=self.cfg.max_wait_ms if self.cfg.async_serve else 0.0,
        )
        self.metrics = ServeMetrics(self.cfg.lat_reservoir)
        # read replicas (a bound ReplicaSet, distributed/replication.py):
        # the pump offers every SEARCH batch to replicas.route() first
        self.replicas = replicas
        # --- async pump state (all mutated under _work on the pump) ---
        self._work = threading.RLock()   # serializes WAL append + dispatch
        self._inflight: deque[tuple[MicroBatch, Callable]] = deque()
        self._unacked: list[Ticket] = []
        self._maint_due = 0
        self._busy = False               # pump holds a popped batch
        self._stop = threading.Event()
        self._pump_error: BaseException | None = None
        self._pump_thread: threading.Thread | None = None
        if self.cfg.lock_check:
            install_lock_check(self)   # before the pump thread exists
        if self.cfg.async_serve:
            self.start()

    @property
    def index(self) -> SPFreshIndex | None:
        """The underlying single-host index (None for sharded backends)."""
        return getattr(self.backend, "index", None)

    # ------------------------- pump thread lifecycle --------------------
    @property
    def is_async(self) -> bool:
        return self._pump_thread is not None

    def start(self) -> None:
        """Start the background pump thread (idempotent)."""
        if self._pump_thread is not None:
            return
        self._stop.clear()
        self._pump_error = None
        t = threading.Thread(
            target=self._pump_loop, name="spfresh-pump", daemon=True
        )
        self._pump_thread = t
        t.start()

    def shutdown(self, timeout: float = 60.0) -> None:
        """Stop the pump thread (and any replica workers).  Queued
        batches, in-flight readbacks and unacked tickets are drained
        first, so no waiter is stranded."""
        t = self._pump_thread
        if t is not None:
            self._stop.set()
            self.queue.wake()
            t.join(timeout)
            if t.is_alive():
                raise RuntimeError("serve pump thread failed to stop")
            self._pump_thread = None
        if self.replicas is not None:
            # after the pump: replica workers first finish any batch the
            # pump's shutdown drain routed to them
            self.replicas.stop(timeout)

    @contextlib.contextmanager
    def exclusive(self):
        """Serialize external backend work (maintain / checkpoint / drain
        / wal_sync from the caller thread) against the pump thread's
        dispatches.  Uncontended no-op in cooperative mode."""
        with self._work:
            yield

    def _check_alive(self) -> None:
        if self._pump_error is not None:
            raise RuntimeError(
                "serve pump thread died"
            ) from self._pump_error

    def _pump_loop(self) -> None:
        try:
            while not self._stop.is_set():
                if len(self.queue):
                    self._busy = True
                    # may hold the batch-formation window (max_wait_ms);
                    # deliberately outside _work so external callers are
                    # not blocked behind the window
                    batch = self.queue.pop_batch()
                    if batch is not None:
                        with self._work:
                            self._process_async(batch)
                    continue
                # queue idle: land deferred readbacks, cross the ack
                # point, then give the rebuilder ONE slot (re-checking
                # for arrivals between slots keeps bursts unblocked)
                with self._work:
                    self._drain_inflight()
                    self._ack_updates()
                    if self._idle_maintenance():
                        continue
                self._busy = False
                self.queue.wait_nonempty(0.05)
            # shutdown drain: nothing may be stranded behind the stop
            with self._work:
                while True:
                    batch = self.queue.pop_batch(force=True)
                    if batch is None:
                        break
                    self._process_async(batch)
                self._drain_inflight()
                self._ack_updates()
                self._busy = False
        except BaseException as e:  # noqa: BLE001 — surfaced to waiters
            self._pump_error = e
            self._busy = False
            log.exception(
                "serve pump thread died; pending tickets will raise"
            )

    @holds_work
    def _process_async(self, batch: MicroBatch) -> None:
        """One pump iteration's processing."""
        # updates are ordered before any later search: ack them before
        # the search dispatch so insert latency is bounded by the next
        # batch boundary, not the next idle gap
        if batch.op == SEARCH and self._unacked:
            self._ack_updates()
        self._process(batch)
        while len(self._inflight) > max(0, self.cfg.max_inflight):
            self._finish_one_inflight()
        if len(self._unacked) >= max(1, self.cfg.ack_batch):
            self._ack_updates()

    # ----------------------------- submit ------------------------------
    def _empty_ticket(self, op: str, key: tuple,
                      buffers: dict[str, np.ndarray]) -> Ticket:
        """Zero-row requests complete immediately (a no-op, not an error)."""
        t = Ticket(op, 0, key, engine=self)
        t._buffers = buffers
        t.t_done = t.t_submit
        t._signal()
        return t

    def submit_search(
        self, queries: np.ndarray, *, k: int | None = None,
        nprobe: int | None = None,
    ) -> Ticket:
        self._check_alive()
        q = np.ascontiguousarray(np.asarray(queries, np.float32))
        # `is None` (not falsiness): an explicit k=0 / nprobe=0 must not
        # silently become the config default
        kk = self.cfg.search_k if k is None else k
        key = (kk, self.cfg.nprobe if nprobe is None else nprobe)
        if len(q) == 0:
            return self._empty_ticket(SEARCH, key, {
                "dists": np.zeros((0, kk), np.float32),
                "ids": np.full((0, kk), -1, np.int32),
            })
        t = Ticket(SEARCH, len(q), key, engine=self)
        return self.queue.submit(t, {"queries": q})

    def submit_insert(self, vecs: np.ndarray, vids: np.ndarray) -> Ticket:
        self._check_alive()
        vecs = np.asarray(vecs, np.float32)
        vids = np.asarray(vids, np.int32)
        assert len(vecs) == len(vids)
        if len(vids) == 0:
            return self._empty_ticket(INSERT, (), {
                "ids": np.zeros((0,), np.int32),
                "landed": np.zeros((0,), bool),
            })
        t = Ticket(INSERT, len(vids), (), engine=self)
        return self.queue.submit(t, {"vecs": vecs, "vids": vids})

    def submit_delete(self, vids: np.ndarray) -> Ticket:
        self._check_alive()
        vids = np.asarray(vids, np.int32)
        if len(vids) == 0:
            return self._empty_ticket(DELETE, (), {})
        t = Ticket(DELETE, len(vids), (), engine=self)
        return self.queue.submit(t, {"vids": vids})

    # ------------------------------ pump -------------------------------
    def pump(self, max_batches: int | None = None) -> int:
        """Cooperative mode: process queued micro-batches; returns how
        many were processed.  Async mode: a flush barrier — returns 0
        after every queued batch is processed, every deferred readback
        has landed, every update ticket is acked, and due background
        slots have run."""
        if self.is_async:
            self.barrier()
            return 0
        n = 0
        while max_batches is None or n < max_batches:
            batch = self.queue.pop_batch()
            if batch is None:
                break
            # Cooperative pumping can race with another caller thread's
            # drain()/exclusive(); dispatch under _work like every other
            # path (uncontended re-entrant acquire when single-threaded).
            with self._work:
                self._process(batch)
            n += 1
        return n

    def barrier(self, timeout: float = 600.0) -> None:
        """Wait for pipeline quiescence (async mode's flush point)."""
        deadline = time.monotonic() + timeout
        while True:
            self._check_alive()
            if not self.is_async:
                return
            with self._work:
                idle = (
                    len(self.queue) == 0 and not self._busy
                    and not self._inflight and not self._unacked
                    and self._maint_due <= 0
                    and (self.replicas is None or self.replicas.idle())
                )
            if idle:
                return
            if time.monotonic() >= deadline:
                raise TimeoutError("serve pipeline barrier timed out")
            time.sleep(0.001)

    def _pump_until(self, ticket: Ticket) -> None:
        while not ticket.done:
            if self.pump(max_batches=1) == 0:
                if self.replicas is not None:
                    # the batch was routed: wait for the replica worker's
                    # signal instead of spinning on an empty queue
                    if ticket._event.wait(timeout=60.0) or ticket.done:
                        continue
                raise RuntimeError("ticket still pending on an empty queue")

    @holds_work
    def _process(self, batch: MicroBatch) -> None:
        if batch.op == SEARCH:
            if self.replicas is not None and self.replicas.route(batch):
                # served on a replica worker thread (which scatters,
                # notes metrics and signals) — nothing more to do here
                return
            k, nprobe = batch.key
            # batch.valid masks padded rows out of the access telemetry
            # (their result rows are computed and discarded, as before).
            if self.is_async:
                begin = getattr(self.backend, "search_begin", None)
                if begin is not None:
                    # dispatch now, read back at scatter time: the device
                    # overlaps this batch with whatever the pump does next
                    fin = begin(batch.arrays["queries"], k, nprobe,
                                batch.valid)
                    self._inflight.append((batch, fin))
                    return
            d, v = self.backend.search(
                batch.arrays["queries"], k, nprobe, batch.valid
            )
            batch.scatter({"dists": d, "ids": v})
        elif batch.op == INSERT:
            self._process_insert(batch)
            self._tick_background()
        else:
            vids, valid = batch.arrays["vids"], batch.valid
            self.backend.log_update("delete", {"vids": vids[valid]})
            self.backend.delete(vids, valid)
            batch.scatter({})
            self._tick_background()
        self._note_done(batch)

    @holds_work
    def _note_done(self, batch: MicroBatch) -> None:
        """Record + release finished tickets.  Durable update tickets in
        async mode are held back until the WAL ack covers them."""
        hold = (
            self.is_async and batch.op != SEARCH
            and getattr(self.backend, "wal_set", None) is not None
        )
        for part in batch.parts:
            t = part.ticket
            if not t.done:
                continue
            if hold:
                self._unacked.append(t)
            else:
                self.metrics.note_ticket(t)
                t._signal()

    @holds_work
    def _ack_updates(self) -> None:
        """Group-commit ack point: fsync the WAL, then signal every held
        update ticket (latency includes the fsync wait)."""
        if not self._unacked:
            return
        self.backend.wal_sync()
        now = time.perf_counter()
        for t in self._unacked:
            t.t_done = now
            self.metrics.note_ticket(t)
            t._signal()
        self._unacked.clear()

    @holds_work
    def _finish_one_inflight(self) -> None:
        batch, finalize = self._inflight.popleft()
        d, v = finalize()
        batch.scatter({"dists": d, "ids": v})
        for part in batch.parts:
            if part.ticket.done:
                self.metrics.note_ticket(part.ticket)
                part.ticket._signal()

    @holds_work
    def _drain_inflight(self) -> None:
        while self._inflight:
            self._finish_one_inflight()

    @holds_work
    def _process_insert(self, batch: MicroBatch) -> None:
        """Insert with pipeline backpressure: when primary appends hit a
        posting at hard capacity, give the rebuilder a slot (it splits the
        oversized posting) and retry the unlanded rows — the explicit
        backpressure form of the paper's Updater→Rebuilder pipeline."""
        vecs, vids = batch.arrays["vecs"], batch.arrays["vids"]
        valid = batch.valid
        # logged ONCE per batch (not per retry): replay re-runs the full
        # backpressure loop through SPFreshIndex.insert
        self.backend.log_update(
            "insert", {"vecs": vecs[valid], "vids": vids[valid]}
        )
        ids = np.asarray(vids).copy()
        landed_all = np.zeros(batch.bucket, bool)
        pending = valid.copy()
        for attempt in range(self.cfg.max_insert_retries + 1):
            if not pending.any():
                break
            if attempt > 0:
                t0 = time.perf_counter()
                self._run_maintenance()      # backpressure slot
                # stall: serve-path time burned waiting on the rebuilder
                self.metrics.insert_stall_s += time.perf_counter() - t0
                self.metrics.insert_retries += 1
            got_ids, landed = self.backend.insert(vecs, vids, pending)
            newly = pending & landed
            ids[newly] = got_ids[newly]
            landed_all |= newly
            pending = pending & ~landed
        n_dropped = int(pending.sum())
        if n_dropped:
            self.metrics.insert_dropped += n_dropped
            off = 0
            for part in batch.parts:
                d = int(pending[off : off + part.n].sum())
                if d:
                    part.ticket.dropped += d
                off += part.n
            log.warning(
                "insert backpressure exhausted after %d retries: "
                "%d/%d row(s) dropped",
                self.cfg.max_insert_retries, n_dropped, batch.n_valid,
            )
        batch.scatter({"ids": ids, "landed": landed_all})

    # ------------------------ background pipeline -----------------------
    @holds_work
    def _tick_background(self) -> None:
        self.policy.note_foreground()
        if not self.policy.want_maintenance(self.backend.backlog):
            return
        if self.is_async:
            # Defer the slot to a queue-idle gap — unless enough slots
            # have piled up that the rebuilder would fall behind under
            # sustained load (the pressure override keeps the steady-
            # state slot rate equal to the sync engine's).
            self._maint_due += 1
            self.metrics.maint_deferred += 1
            if self._maint_due >= max(1, self.cfg.maint_pressure):
                self._maint_due -= 1
                self.metrics.maint_forced += 1
                self._run_maintenance()
        else:
            self._run_maintenance()

    @holds_work
    def _idle_maintenance(self) -> bool:
        """Run ONE deferred slot in a queue-idle gap; returns whether a
        slot ran."""
        if self._maint_due <= 0:
            return False
        self._maint_due -= 1
        self._run_maintenance(idle=True)
        return True

    @holds_work
    def _run_maintenance(self, idle: bool = False) -> int:
        """One maintenance slot = ONE fused round of ``policy.budget`` jobs
        (a single dispatch; the host reads back one did-work scalar)."""
        # deferred search readbacks fold access telemetry at finalize —
        # land them before the maintain dispatch drains that buffer
        self._drain_inflight()
        t0 = time.perf_counter()
        jobs = self.backend.maintain(self.policy.budget)
        self.policy.note_maintenance(jobs)
        self.metrics.note_maintenance(
            jobs, time.perf_counter() - t0, idle=idle
        )
        return jobs

    def drain(self) -> int:
        """Flush the queue, then run the rebuilder to quiescence (batched
        rounds, one readback per round); returns jobs executed."""
        self.pump()
        with self._work:
            self._drain_inflight()
            self._maint_due = 0    # quiescence supersedes deferred slots
            t0 = time.perf_counter()
            jobs, rounds = self.backend.drain()
            self.metrics.note_maintenance(
                jobs, time.perf_counter() - t0, rounds=rounds
            )
        return jobs

    # ------------------------- sync conveniences ------------------------
    def search(
        self, queries: np.ndarray, *, k: int | None = None,
        nprobe: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        t = self.submit_search(queries, k=k, nprobe=nprobe)
        return t.result()

    def insert(self, vecs: np.ndarray, vids: np.ndarray) -> None:
        t = self.submit_insert(vecs, vids)
        t.result()

    def delete(self, vids: np.ndarray) -> None:
        t = self.submit_delete(vids)
        t.result()

    # ----------------------------- metrics ------------------------------
    def latency_percentiles(self, which: str = SEARCH) -> dict:
        return self.metrics.percentiles(which)

    def report(self) -> dict:
        m = self.metrics
        mt = m.maint_time_s
        return {
            "search": m.percentiles(SEARCH),
            "insert": m.percentiles(INSERT),
            "delete": m.percentiles(DELETE),
            "queue": self.queue.accounting(),
            "maintenance": {
                "policy": self.policy.describe(),
                "slots": m.maint_slots,
                "rounds": m.maint_rounds,
                "steps": m.maint_steps,   # jobs that acted (pre-round name)
                "time_s": mt,
                "steps_per_s": m.maint_steps / mt if mt > 0 else 0.0,
                # async-mode overlap: fraction of rebuilder time spent in
                # queue-idle gaps (off the serve path) vs inline
                "idle_slots": m.maint_idle_slots,
                "idle_time_s": m.maint_idle_time_s,
                "overlap_frac": m.maint_idle_time_s / mt if mt > 0 else 0.0,
                "deferred": m.maint_deferred,
                "forced": m.maint_forced,
            },
            "async": self.is_async,
            "insert_retries": m.insert_retries,
            "insert_stall_s": m.insert_stall_s,
            "insert_dropped": m.insert_dropped,
            "backlog": self.backend.backlog(),
            "replicas": (
                self.replicas.report() if self.replicas is not None else None
            ),
        }

    def stats(self) -> dict:
        return self.backend.stats()
