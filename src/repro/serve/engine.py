"""Serving engine: the paper's online loop (§5.2/§5.3) as a batched
async pipeline.

Requests enter through a :class:`~repro.serve.queue.RequestQueue` that
micro-batches them into fixed-shape padded buckets (so the jit compile
cache stays warm); each micro-batch is ONE dispatch into a cached,
state-donating executable — `core.index.search_step` /
`insert_step` / `delete_step` for a single-host index, or the
shard_map'd steps of `distributed.sharded_index.ShardedIndex` for an
N-shard mesh.  The same engine serves both: backends implement the
small protocol below.

Background maintenance (the Local Rebuilder) is scheduled by a
pluggable :class:`~repro.serve.policy.MaintenancePolicy` — the paper's
2:1 feed-forward pipeline (Fig. 12) is ``RatioPolicy(2)``; a reactive
``BacklogPolicy`` fires only when oversized postings actually exist.

Metrics: per-op latency percentiles, queue depth, padding waste, and
maintenance throughput — everything Fig. 7/9/12 plot, per policy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Protocol

import numpy as np

from repro.core.index import SPFreshIndex
from repro.serve.policy import BacklogPolicy, MaintenancePolicy, RatioPolicy
from repro.storage.durability import DurableBackend
from repro.serve.queue import (
    DELETE, INSERT, SEARCH, MicroBatch, RequestQueue, Ticket, default_buckets,
)


# ---------------------------------------------------------------------------
# Backend protocol + the single-host backend
# ---------------------------------------------------------------------------

class IndexBackend(Protocol):
    """What the engine needs from an index: fixed-shape batched ops, plus
    the durable lifecycle (`spfresh.open` drives the last four — every
    update dispatch is WAL-appended before it runs, `checkpoint` commits
    an atomic snapshot stamping per-shard WAL seqnos, and `replay`
    re-applies a WAL tail through the same jitted dispatches)."""

    def search(self, queries: np.ndarray, k: int, nprobe: int | None,
               valid: np.ndarray | None = None,
               ) -> tuple[np.ndarray, np.ndarray]: ...

    def insert(self, vecs: np.ndarray, vids: np.ndarray, valid: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]: ...

    def delete(self, vids: np.ndarray, valid: np.ndarray) -> None: ...

    def log_update(self, op: str, payload: dict) -> None: ...

    def maintain(self, jobs: int) -> int: ...

    def drain(self) -> tuple[int, int]: ...

    def backlog(self) -> int: ...

    def stats(self) -> dict: ...

    # --- durability lifecycle (paper §4.4, promoted into the protocol) ---

    def attach_durability(self, wal_set) -> None: ...

    def checkpoint(self, snapshot_dir: str, *, delta: bool = False) -> None: ...

    def wal_sync(self) -> None: ...

    def replay(self, records, after_seqno: int = -1) -> int: ...

    def close(self) -> None: ...


class LocalBackend(DurableBackend):
    """Single-host SPFreshIndex behind the batched entry points.

    ``probe_chunk`` / ``use_pallas_scan`` / ``scan_schedule`` select the
    posting-scan data path for every search dispatch (engine knobs; the
    scan flags default to the index config when None).

    With a :class:`~repro.storage.wal.WalSet` attached
    (``attach_durability`` — `spfresh.open` does this), every update
    DISPATCH (insert/delete/maintain/drain, with its padded arrays and
    masks) is WAL-appended before it runs.  Because the jitted steps are
    deterministic functions of (state, batch), replaying the dispatch
    stream on top of a snapshot reproduces the index bit-for-bit —
    including the engine's backpressure retries, whose interleaved
    maintenance slots appear in the log at their true positions.
    """

    def __init__(
        self,
        index: SPFreshIndex,
        *,
        probe_chunk: int = 0,
        use_pallas_scan: bool | None = None,
        scan_schedule: str | None = None,
        track_access: bool = True,
    ):
        self.index = index
        self.probe_chunk = probe_chunk
        self.use_pallas_scan = use_pallas_scan
        self.scan_schedule = scan_schedule
        self.track_access = track_access
        # Per-posting probe counts accumulated since the last maintenance
        # dispatch.  Searches are NOT WAL-logged, so this buffer must never
        # touch the index state directly: it is drained into the payload of
        # the next logged maintain/drain dispatch and folded inside that
        # jitted round — live and on replay alike (bit-exact recovery).
        self._pending_access = np.zeros(
            (index.state.cfg.num_postings_cap,), np.int64
        )

    def search(self, queries, k, nprobe, valid=None):
        if not self.track_access:
            return self.index.search_padded(
                queries, k, nprobe=nprobe, probe_chunk=self.probe_chunk,
                use_pallas_scan=self.use_pallas_scan,
                scan_schedule=self.scan_schedule,
            )
        d, v, hist = self.index.search_padded(
            queries, k, nprobe=nprobe, probe_chunk=self.probe_chunk,
            use_pallas_scan=self.use_pallas_scan,
            scan_schedule=self.scan_schedule,
            with_access=True, qvalid=valid,
        )
        self._pending_access += hist
        return d, v

    def _take_access(self) -> np.ndarray:
        """Drain the pending probe counts for a maintenance dispatch.
        Access accumulated after the LAST logged dispatch is lost on a
        crash (it never entered the WAL) — deterministically so: the
        recovered twin replays exactly the folds the WAL saw."""
        acc = np.minimum(
            self._pending_access, np.iinfo(np.int32).max
        ).astype(np.int32)
        self._pending_access[:] = 0
        return acc

    def insert(self, vecs, vids, valid):
        self._log("insert", {
            "vecs": np.asarray(vecs, np.float32),
            "vids": np.asarray(vids, np.int32),
            "valid": np.asarray(valid, bool),
        })
        landed = self.index.insert_padded(vecs, vids, valid)
        return np.asarray(vids), landed

    def delete(self, vids, valid):
        self._log("delete", {
            "vids": np.asarray(vids, np.int32),
            "valid": np.asarray(valid, bool),
        })
        self.index.delete_padded(vids, valid)

    def log_update(self, op, payload):
        """WAL-log a pipeline update batch (crash recovery, §4.4): the
        padded jit entry points bypass SPFreshIndex.insert/delete, so the
        engine logs here — once per batch, before the first dispatch.
        Legacy request-level path (SPFreshIndex built with ``wal_path``);
        the dispatch-level ``WalSet`` log supersedes it under
        `spfresh.open`."""
        if self.index.wal is not None:
            self.index._wal_applied = self.index.wal.append(op, payload)

    def maintain(self, jobs):
        access = self._take_access()
        self._log("maintain", {
            "jobs": np.asarray(jobs, np.int32), "access": access,
        })
        return self.index.maintain_round(jobs, access=access)

    def drain(self):
        access = self._take_access()
        self._log("drain", {"access": access})
        jobs = self.index.maintain(access=access)
        return jobs, self.index.last_drain_rounds

    def backlog(self):
        return self.index.backlog()

    def stats(self):
        return self.index.stats()

    # --------------- durability hooks (DurableBackend) -----------------
    def _snapshot_state(self):
        return self.index.state

    def _set_snapshot_state(self, state):
        self.index.state = state

    def _snapshot_extra(self):
        return {"backend": "local"}

    def _lire_config(self):
        return self.index.state.cfg

    def _apply_record(self, rec) -> None:
        p = rec.payload
        if rec.op == "insert":
            self.index.insert_padded(p["vecs"], p["vids"], p["valid"])
        elif rec.op == "delete":
            self.index.delete_padded(p["vids"], p["valid"])
        elif rec.op == "maintain":
            # Old records (pre-telemetry) carry no "access" — .get(None)
            # folds zeros, tracing the same graph those dispatches ran.
            self.index.maintain_round(int(p["jobs"]), access=p.get("access"))
        elif rec.op == "drain":
            self.index.maintain(access=p.get("access"))
        else:
            raise ValueError(f"unknown WAL op {rec.op!r}")

    def close(self) -> None:
        super().close()
        if self.index.wal is not None:
            self.index.wal.close()


# ---------------------------------------------------------------------------
# Config + metrics
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineConfig:
    """Pipeline knobs.  Deprecated as a user-facing surface: prefer
    declaring a :class:`repro.api.ServiceSpec` (its serve/scan/
    maintenance sub-specs compile to this via ``engine_config()``);
    direct construction remains for the engine internals and one
    release of back-compat."""

    search_k: int = 10
    nprobe: int | None = None
    # --- search data path (threaded into every search dispatch) ---
    probe_chunk: int = 0                  # oracle-path streaming chunk (0 = off)
    use_pallas_scan: bool | None = None   # None = defer to LireConfig
    scan_schedule: str | None = None      # "per_query" | "batched" | None
    # --- micro-batching ---
    max_batch: int = 256         # largest bucket (rows per dispatch)
    min_bucket: int = 8          # smallest bucket
    # --- maintenance scheduling (used when no policy object is given) ---
    policy: str = "ratio"        # "ratio" | "backlog"
    fg_bg_ratio: int = 2         # foreground update batches per bg slot (2:1)
    # Jobs per background ROUND: each slot is ONE fused dispatch splitting
    # the top-`maintain_budget` oversized postings and merging the bottom-
    # `maintain_budget` undersized, with one fused reassign pass (the
    # pre-round semantics were sequential steps per slot).
    maintain_budget: int = 8
    backlog_threshold: int = 1   # BacklogPolicy firing threshold
    # --- insert backpressure ---
    max_insert_retries: int = 4

    def buckets(self) -> tuple[int, ...]:
        return default_buckets(self.min_bucket, self.max_batch)

    def make_policy(self) -> MaintenancePolicy:
        if self.policy == "backlog":
            return BacklogPolicy(self.backlog_threshold, self.maintain_budget)
        return RatioPolicy(self.fg_bg_ratio, self.maintain_budget)


class ServeMetrics:
    """Aggregated pipeline observability (read via ``ServeEngine.report``)."""

    def __init__(self):
        self.lat: dict[str, list[float]] = {SEARCH: [], INSERT: [], DELETE: []}
        self.maint_slots = 0
        self.maint_rounds = 0
        self.maint_steps = 0
        self.maint_time_s = 0.0
        self.insert_retries = 0
        self.insert_stall_s = 0.0
        self.insert_dropped = 0

    def note_ticket(self, ticket: Ticket) -> None:
        if ticket.latency_s is not None:
            self.lat[ticket.op].append(ticket.latency_s)

    def note_maintenance(self, steps: int, dt: float, rounds: int = 1) -> None:
        self.maint_slots += 1
        self.maint_rounds += rounds
        self.maint_steps += steps
        self.maint_time_s += dt

    def percentiles(self, op: str) -> dict:
        lat = self.lat.get(op, [])
        if not lat:
            return {}
        arr = np.asarray(lat) * 1e3
        return {
            "p50_ms": float(np.percentile(arr, 50)),
            "p90_ms": float(np.percentile(arr, 90)),
            "p99_ms": float(np.percentile(arr, 99)),
            "p999_ms": float(np.percentile(arr, 99.9)),
            "mean_ms": float(arr.mean()),
            "n": len(arr),
        }


class ServeEngine:
    """Batched async serving pipeline over a local or sharded index.

    Async API: ``submit_search`` / ``submit_insert`` / ``submit_delete``
    return a :class:`Ticket`; ``pump()`` processes queued micro-batches;
    ``ticket.result()`` pumps until that request completes.  The
    synchronous ``search`` / ``insert`` / ``delete`` methods are
    submit-then-pump conveniences (and the pre-pipeline API).
    """

    def __init__(
        self,
        backend: IndexBackend | SPFreshIndex,
        cfg: EngineConfig | None = None,
        policy: MaintenancePolicy | None = None,
    ):
        self.cfg = cfg or EngineConfig()
        if isinstance(backend, SPFreshIndex):
            backend = LocalBackend(
                backend,
                probe_chunk=self.cfg.probe_chunk,
                use_pallas_scan=self.cfg.use_pallas_scan,
                scan_schedule=self.cfg.scan_schedule,
            )
        self.backend = backend
        self.policy = policy or self.cfg.make_policy()
        self.queue = RequestQueue(self.cfg.buckets())
        self.metrics = ServeMetrics()

    @property
    def index(self) -> SPFreshIndex | None:
        """The underlying single-host index (None for sharded backends)."""
        return getattr(self.backend, "index", None)

    # ----------------------------- submit ------------------------------
    def _empty_ticket(self, op: str, key: tuple,
                      buffers: dict[str, np.ndarray]) -> Ticket:
        """Zero-row requests complete immediately (a no-op, not an error)."""
        t = Ticket(op, 0, key, engine=self)
        t._buffers = buffers
        t.t_done = t.t_submit
        return t

    def submit_search(
        self, queries: np.ndarray, *, k: int | None = None,
        nprobe: int | None = None,
    ) -> Ticket:
        q = np.ascontiguousarray(np.asarray(queries, np.float32))
        kk = k or self.cfg.search_k
        key = (kk, nprobe or self.cfg.nprobe)
        if len(q) == 0:
            return self._empty_ticket(SEARCH, key, {
                "dists": np.zeros((0, kk), np.float32),
                "ids": np.full((0, kk), -1, np.int32),
            })
        t = Ticket(SEARCH, len(q), key, engine=self)
        return self.queue.submit(t, {"queries": q})

    def submit_insert(self, vecs: np.ndarray, vids: np.ndarray) -> Ticket:
        vecs = np.asarray(vecs, np.float32)
        vids = np.asarray(vids, np.int32)
        assert len(vecs) == len(vids)
        if len(vids) == 0:
            return self._empty_ticket(INSERT, (), {
                "ids": np.zeros((0,), np.int32),
                "landed": np.zeros((0,), bool),
            })
        t = Ticket(INSERT, len(vids), (), engine=self)
        return self.queue.submit(t, {"vecs": vecs, "vids": vids})

    def submit_delete(self, vids: np.ndarray) -> Ticket:
        vids = np.asarray(vids, np.int32)
        if len(vids) == 0:
            return self._empty_ticket(DELETE, (), {})
        t = Ticket(DELETE, len(vids), (), engine=self)
        return self.queue.submit(t, {"vids": vids})

    # ------------------------------ pump -------------------------------
    def pump(self, max_batches: int | None = None) -> int:
        """Process queued micro-batches; returns how many were processed."""
        n = 0
        while max_batches is None or n < max_batches:
            batch = self.queue.pop_batch()
            if batch is None:
                break
            self._process(batch)
            n += 1
        return n

    def _pump_until(self, ticket: Ticket) -> None:
        while not ticket.done:
            if self.pump(max_batches=1) == 0:
                raise RuntimeError("ticket still pending on an empty queue")

    def _process(self, batch: MicroBatch) -> None:
        if batch.op == SEARCH:
            k, nprobe = batch.key
            # batch.valid masks padded rows out of the access telemetry
            # (their result rows are computed and discarded, as before).
            d, v = self.backend.search(
                batch.arrays["queries"], k, nprobe, batch.valid
            )
            batch.scatter({"dists": d, "ids": v})
        elif batch.op == INSERT:
            self._process_insert(batch)
            self._tick_background()
        else:
            vids, valid = batch.arrays["vids"], batch.valid
            self.backend.log_update("delete", {"vids": vids[valid]})
            self.backend.delete(vids, valid)
            batch.scatter({})
            self._tick_background()
        for part in batch.parts:
            if part.ticket.done:
                self.metrics.note_ticket(part.ticket)

    def _process_insert(self, batch: MicroBatch) -> None:
        """Insert with pipeline backpressure: when primary appends hit a
        posting at hard capacity, give the rebuilder a slot (it splits the
        oversized posting) and retry the unlanded rows — the explicit
        backpressure form of the paper's Updater→Rebuilder pipeline."""
        vecs, vids = batch.arrays["vecs"], batch.arrays["vids"]
        valid = batch.valid
        # logged ONCE per batch (not per retry): replay re-runs the full
        # backpressure loop through SPFreshIndex.insert
        self.backend.log_update(
            "insert", {"vecs": vecs[valid], "vids": vids[valid]}
        )
        ids = np.asarray(vids).copy()
        landed_all = np.zeros(batch.bucket, bool)
        pending = valid.copy()
        for attempt in range(self.cfg.max_insert_retries + 1):
            if not pending.any():
                break
            if attempt > 0:
                t0 = time.perf_counter()
                self._run_maintenance()      # backpressure slot
                # stall: serve-path time burned waiting on the rebuilder
                self.metrics.insert_stall_s += time.perf_counter() - t0
                self.metrics.insert_retries += 1
            got_ids, landed = self.backend.insert(vecs, vids, pending)
            newly = pending & landed
            ids[newly] = got_ids[newly]
            landed_all |= newly
            pending = pending & ~landed
        self.metrics.insert_dropped += int(pending.sum())
        batch.scatter({"ids": ids, "landed": landed_all})

    # ------------------------ background pipeline -----------------------
    def _tick_background(self) -> None:
        self.policy.note_foreground()
        if self.policy.want_maintenance(self.backend.backlog):
            self._run_maintenance()

    def _run_maintenance(self) -> int:
        """One maintenance slot = ONE fused round of ``policy.budget`` jobs
        (a single dispatch; the host reads back one did-work scalar)."""
        t0 = time.perf_counter()
        jobs = self.backend.maintain(self.policy.budget)
        self.policy.note_maintenance(jobs)
        self.metrics.note_maintenance(jobs, time.perf_counter() - t0)
        return jobs

    def drain(self) -> int:
        """Flush the queue, then run the rebuilder to quiescence (batched
        rounds, one readback per round); returns jobs executed."""
        self.pump()
        t0 = time.perf_counter()
        jobs, rounds = self.backend.drain()
        self.metrics.note_maintenance(
            jobs, time.perf_counter() - t0, rounds=rounds
        )
        return jobs

    # ------------------------- sync conveniences ------------------------
    def search(
        self, queries: np.ndarray, *, k: int | None = None,
        nprobe: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        t = self.submit_search(queries, k=k, nprobe=nprobe)
        return t.result()

    def insert(self, vecs: np.ndarray, vids: np.ndarray) -> None:
        t = self.submit_insert(vecs, vids)
        t.result()

    def delete(self, vids: np.ndarray) -> None:
        t = self.submit_delete(vids)
        t.result()

    # ----------------------------- metrics ------------------------------
    def latency_percentiles(self, which: str = SEARCH) -> dict:
        return self.metrics.percentiles(which)

    def report(self) -> dict:
        m = self.metrics
        mt = m.maint_time_s
        return {
            "search": m.percentiles(SEARCH),
            "insert": m.percentiles(INSERT),
            "delete": m.percentiles(DELETE),
            "queue": self.queue.accounting(),
            "maintenance": {
                "policy": self.policy.describe(),
                "slots": m.maint_slots,
                "rounds": m.maint_rounds,
                "steps": m.maint_steps,   # jobs that acted (pre-round name)
                "time_s": mt,
                "steps_per_s": m.maint_steps / mt if mt > 0 else 0.0,
            },
            "insert_retries": m.insert_retries,
            "insert_stall_s": m.insert_stall_s,
            "insert_dropped": m.insert_dropped,
            "backlog": self.backend.backlog(),
        }

    def stats(self) -> dict:
        return self.backend.stats()
