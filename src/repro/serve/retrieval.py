"""Two-tower retrieval served by the SPFresh index — the paper's technique
as a first-class feature of the framework (DESIGN.md §5, flagship arch).

The item corpus lives in a SPFreshIndex built over item-tower embeddings;
``retrieve`` runs the user tower and answers top-k by ANN search instead of
the brute-force 1M-candidate GEMM.  Streaming catalog churn (new/removed
items) goes through LIRE insert/delete — no index rebuilds.

``attach_engine`` puts the serving pipeline in front of the index: lookups
and churn then flow through the micro-batched ServeEngine, and background
maintenance is scheduled by its MaintenancePolicy instead of the fixed
``maintain(32)`` slot.
"""
from __future__ import annotations

import numpy as np

from repro.core.index import SPFreshIndex
from repro.core.types import LireConfig
from repro.models import recsys as R


class IndexedRetriever:
    def __init__(self, params: dict, model_cfg: R.TwoTowerConfig,
                 index_cfg: LireConfig):
        assert index_cfg.dim == model_cfg.tower_dims[-1]
        self.params = params
        self.model_cfg = model_cfg
        self.index_cfg = index_cfg
        self.index: SPFreshIndex | None = None
        self.engine = None

    # ------------------------------------------------------------------
    def attach_engine(self, cfg=None, policy=None):
        """Serve this corpus through the batched pipeline; returns the
        :class:`~repro.serve.engine.ServeEngine` (also kept on ``self``).

        ``cfg`` may be an ``EngineConfig`` or a
        :class:`~repro.api.ServiceSpec` — the spec is the preferred
        surface (its serve/scan/maintenance sub-specs compile to the
        engine config; the policy comes from the spec unless overridden).
        """
        from repro.api.spec import ServiceSpec
        from repro.serve.engine import EngineConfig, ServeEngine

        assert self.index is not None, "build_corpus first"
        if isinstance(cfg, ServiceSpec):
            cfg = cfg.engine_config()
        self.engine = ServeEngine(
            self.index, cfg or EngineConfig(), policy=policy
        )
        return self.engine

    # ------------------------------------------------------------------
    def build_corpus(self, item_ids: np.ndarray, batch: int = 4096) -> None:
        embs = self.embed_items(item_ids, batch)
        self.index = SPFreshIndex.build(self.index_cfg, embs)
        self._id_map = np.asarray(item_ids)

    def embed_items(self, item_ids: np.ndarray, batch: int = 4096) -> np.ndarray:
        import jax.numpy as jnp

        out = []
        for s in range(0, len(item_ids), batch):
            e = R.item_tower(
                self.params, jnp.asarray(item_ids[s:s + batch]), self.model_cfg
            )
            out.append(np.asarray(e, np.float32))
        return np.concatenate(out)

    # ------------------------------------------------------------------
    def add_items(self, item_ids: np.ndarray) -> None:
        """Catalog churn: embed fresh items and LIRE-insert them."""
        embs = self.embed_items(item_ids)
        base = len(self._id_map)
        vids = np.arange(base, base + len(item_ids))
        self._id_map = np.concatenate([self._id_map, np.asarray(item_ids)])
        if self.engine is not None:
            self.engine.insert(embs, vids.astype(np.int32))
        else:
            self.index.insert(embs, vids.astype(np.int32))
            self.index.maintain(max_steps=32)

    def remove_items(self, vids: np.ndarray) -> None:
        vids = np.asarray(vids, np.int32)
        if self.engine is not None:
            self.engine.delete(vids)
        else:
            self.index.delete(vids)

    # ------------------------------------------------------------------
    def retrieve(self, user_fields: np.ndarray, k: int = 10,
                 nprobe: int | None = None) -> tuple[np.ndarray, np.ndarray]:
        """(scores, item_ids): ANN path for retrieval_cand."""
        import jax.numpy as jnp

        u = np.asarray(
            R.user_tower(self.params, jnp.asarray(user_fields), self.model_cfg),
            np.float32,
        )
        if self.engine is not None:
            d, v = self.engine.search(u, k=k, nprobe=nprobe)
        else:
            d, v = self.index.search(u, k, nprobe=nprobe)
        safe = np.maximum(v, 0)
        ids = np.where(v >= 0, self._id_map[safe], -1)
        # squared-L2 on unit vectors ⇒ dot = 1 - d/2
        scores = np.where(v >= 0, 1.0 - d / 2.0, -np.inf)
        return scores, ids

    def retrieve_bruteforce(self, user_fields: np.ndarray, k: int = 10
                            ) -> tuple[np.ndarray, np.ndarray]:
        """Exact GEMM baseline over the whole corpus (the retrieval_cand
        brute-force path) for recall accounting."""
        import jax.numpy as jnp

        u = np.asarray(
            R.user_tower(self.params, jnp.asarray(user_fields), self.model_cfg),
            np.float32,
        )
        embs = self.embed_items(self._id_map)
        scores = u @ embs.T
        idx = np.argsort(-scores, axis=1)[:, :k]
        return np.take_along_axis(scores, idx, axis=1), self._id_map[idx]
