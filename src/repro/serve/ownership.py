"""Explicit ownership annotations for the serve engine's shared state,
plus the debug-flag runtime checker.

The engine's threading discipline used to live in docstrings ("caller
holds ``_work``", "all mutated under ``_work`` on the pump").  This
module makes it machine-readable in both directions:

* **statically** — `repro.analysis.locks` (spflint SPF20x) reads the
  ``FIELD_OWNERSHIP`` / ``PUMP_METHODS`` / ``LIFECYCLE_METHODS`` class
  attributes and the ``@holds_work`` decorators and verifies every
  ``self.<field>`` access site in ``serve/``;
* **at runtime** — ``install_lock_check(engine)``
  (``EngineConfig.lock_check``) swaps in an owner-tracking lock and a
  checking ``__setattr__`` so the async stress tests catch what a
  lexical pass can't (calls that arrive on the wrong thread).

Ownership categories:

* ``GUARDED``   — read/written only while holding ``_work``;
* ``PUMP``      — written only by the pump thread (or by lifecycle
                  methods, which run strictly before the pump thread
                  starts / after it joins); reads are unrestricted;
* ``INIT``      — bound once in ``__init__``, immutable after;
* ``LIFECYCLE`` — written only by the declared lifecycle methods.
"""
from __future__ import annotations

import threading
from typing import Callable, TypeVar

GUARDED = "guarded"
PUMP = "pump"
INIT = "init"
LIFECYCLE = "lifecycle"

F = TypeVar("F", bound=Callable)


def holds_work(fn: F) -> F:
    """Declare that every caller of ``fn`` holds the engine's ``_work``
    lock.  The static lock pass (a) treats the body as locked and
    (b) verifies every internal call site actually holds the lock
    (SPF207); the runtime checker relies on ``_work`` being re-entrant,
    so the annotation adds no runtime cost."""
    fn.__holds_work__ = True
    return fn


class CheckedRLock:
    """An RLock that knows which thread owns it — the instrumented lock
    the runtime checker consults.  Drop-in for ``threading.RLock()``."""

    __slots__ = ("_lock", "_owner", "_count")

    def __init__(self):
        self._lock = threading.RLock()
        self._owner: int | None = None
        self._count = 0

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._lock.acquire(blocking, timeout)
        if got:
            self._owner = threading.get_ident()
            self._count += 1
        return got

    def release(self) -> None:
        self._count -= 1
        if self._count == 0:
            self._owner = None
        self._lock.release()

    __enter__ = acquire

    def __exit__(self, *exc) -> None:
        self.release()

    @property
    def held_by_me(self) -> bool:
        return self._owner == threading.get_ident()


class LockDisciplineError(AssertionError):
    """A shared-field write violated the declared ownership map."""


def _checking_setattr(self, name: str, value) -> None:
    cat = type(self).FIELD_OWNERSHIP.get(name)
    if cat == GUARDED:
        work = object.__getattribute__(self, "_work")
        if isinstance(work, CheckedRLock) and not work.held_by_me:
            raise LockDisciplineError(
                f"write to guarded field {name!r} without holding _work "
                f"(thread {threading.current_thread().name})"
            )
    elif cat == PUMP:
        pump = object.__getattribute__(self, "_pump_thread")
        if (
            pump is not None and pump.is_alive()
            and threading.current_thread() is not pump
        ):
            raise LockDisciplineError(
                f"write to pump-thread-only field {name!r} from "
                f"non-pump thread {threading.current_thread().name}"
            )
    elif cat == INIT:
        raise LockDisciplineError(
            f"write to init-only field {name!r} after construction"
        )
    elif cat == LIFECYCLE:
        pump = object.__getattribute__(self, "_pump_thread")
        if pump is not None and threading.current_thread() is pump:
            raise LockDisciplineError(
                f"write to lifecycle field {name!r} from the pump thread"
            )
    object.__setattr__(self, name, value)


def install_lock_check(engine) -> None:
    """Instrument ``engine`` (in place) to enforce its FIELD_OWNERSHIP
    map on every subsequent attribute write.  Must run after ``__init__``
    has bound all fields and BEFORE the pump thread starts.  Idempotent.

    Tests that intentionally poke internals (e.g. clearing a simulated
    pump error) bypass the check with ``object.__setattr__``.
    """
    if getattr(type(engine), "__lock_checked__", False):
        return
    if not isinstance(engine._work, CheckedRLock):
        object.__setattr__(engine, "_work", CheckedRLock())
    cls = type(engine)
    checked = type(
        cls.__name__ + "LockChecked", (cls,),
        {"__setattr__": _checking_setattr, "__lock_checked__": True},
    )
    object.__setattr__(engine, "__class__", checked)
