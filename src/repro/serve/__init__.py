"""Serving layer: batched search/update engine over the SPFresh index +
the two-tower retrieval integration (the paper technique as a feature)."""
