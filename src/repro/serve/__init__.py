"""Serving layer: the batched async pipeline over the SPFresh index.

``RequestQueue`` micro-batches requests into padded fixed-shape buckets,
``ServeEngine`` dispatches them into cached jit steps (single-host or
sharded backends), and ``MaintenancePolicy`` schedules the background
Local Rebuilder.  ``IndexedRetriever`` is the two-tower retrieval
integration (the paper technique as a framework feature).
"""
from repro.serve.engine import (  # noqa: F401
    EngineConfig, IndexBackend, LocalBackend, ServeEngine,
)
from repro.serve.policy import (  # noqa: F401
    BacklogPolicy, MaintenancePolicy, RatioPolicy,
)
from repro.serve.queue import RequestQueue, Ticket, default_buckets  # noqa: F401
