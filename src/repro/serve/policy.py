"""Maintenance scheduling policies for the serving pipeline.

SPFresh overlaps the foreground Updater with the background Local
Rebuilder; *when* the rebuilder gets a slot is the pipeline-balance knob
the paper tunes in Fig. 12 (2 foreground threads : 1 background thread
is their optimum).  In the jit world there are no threads — the engine
interleaves maintenance *slots* between foreground update batches — so
the knob becomes a scheduling policy object.  A slot is ONE fused
``maintenance_round`` dispatch: ``budget`` is the round's
jobs-per-round count (top-``budget`` splits + bottom-``budget`` merges
+ one fused reassign pass), not a sequential step count.

Two concrete policies ship:

* :class:`RatioPolicy` — the paper's feed-forward pipeline: one
  maintenance slot every ``ratio`` foreground update batches,
  unconditionally.  ``ratio <= 0`` disables background maintenance
  entirely (the SPANN+ ablation).
* :class:`BacklogPolicy` — reactive scheduling in the spirit of
  incremental-IVF merge policies (arXiv 2411.00970): a slot fires only
  when the measured rebuild backlog (number of oversized postings
  waiting for a split) reaches a threshold.  Idle workloads pay zero
  maintenance cost; bursty ones get slots exactly when the backlog
  appears.

The engine calls ``note_foreground`` after every update batch, then
``want_maintenance(backlog_fn)``; ``backlog_fn`` is a callable so that
policies that don't need the backlog (ratio) never pay the device
read-back that computing it costs.
"""
from __future__ import annotations


class MaintenancePolicy:
    """Decides when the engine gives the Local Rebuilder a slot.

    Subclasses override :meth:`want_maintenance`; ``budget`` is the
    jobs-per-round of the fused maintenance round each slot dispatches.
    """

    def __init__(self, budget: int = 8):
        self.budget = budget
        self.fg_batches = 0
        self.slots_fired = 0

    def note_foreground(self) -> None:
        """Called once per processed foreground *update* batch."""
        self.fg_batches += 1

    def want_maintenance(self, backlog_fn) -> bool:
        raise NotImplementedError

    def note_maintenance(self, jobs: int) -> None:
        self.slots_fired += 1

    def describe(self) -> str:
        return type(self).__name__


class RatioPolicy(MaintenancePolicy):
    """Fixed fg:bg interleave — the paper's 2:1 pipeline (Fig. 12)."""

    def __init__(self, ratio: int = 2, budget: int = 8):
        super().__init__(budget)
        self.ratio = ratio
        self._since_slot = 0

    def note_foreground(self) -> None:
        super().note_foreground()
        self._since_slot += 1

    def want_maintenance(self, backlog_fn) -> bool:
        if self.ratio <= 0:
            return False
        if self._since_slot >= self.ratio:
            self._since_slot = 0
            return True
        return False

    def describe(self) -> str:
        if self.ratio <= 0:
            return "ratio:off"
        return f"ratio:{self.ratio}to1/b{self.budget}"


class BacklogPolicy(MaintenancePolicy):
    """Fire a slot when the rebuild backlog reaches ``threshold``.

    ``check_every`` rate-limits how often the (host-synchronising)
    backlog probe runs: the backlog is only measured every that many
    foreground batches.
    """

    def __init__(self, threshold: int = 1, budget: int = 16,
                 check_every: int = 1):
        super().__init__(budget)
        assert threshold >= 1 and check_every >= 1
        self.threshold = threshold
        self.check_every = check_every
        self._since_check = 0
        self.probes = 0

    def note_foreground(self) -> None:
        super().note_foreground()
        self._since_check += 1

    def want_maintenance(self, backlog_fn) -> bool:
        if self._since_check < self.check_every:
            return False
        self._since_check = 0
        self.probes += 1
        return backlog_fn() >= self.threshold

    def describe(self) -> str:
        return f"backlog:t{self.threshold}/b{self.budget}"
