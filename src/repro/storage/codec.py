"""Posting payload codecs: pluggable hot-tier dtype for BlockPool.

The pool's vector payload (``pool.blocks``) can be stored at full
precision (``fp32``), half precision (``bf16``), or as asymmetric
per-posting int8 (``int8``).  The codec is a *static* property of the
pool; the quantization parameters (one scale and one zero-point per
posting) are ordinary pytree leaves that ride through jit, snapshots,
and delta-checkpoints like any other state.

Quantization scheme (``int8``)
------------------------------
Per posting, over its live rows::

    zero  = (min + max) / 2
    scale = (max - min) / 254        (1.0 when the range collapses)
    q     = clip(round((x - zero) / scale), -127, 127)  -> int8
    x'    = q * scale + zero

The symmetric code range [-127, 127] keeps the reconstruction error
bounded by ``scale / 2`` per dimension, and degenerate postings
(all-zero, single-vector, constant) round-trip exactly because the
range collapses to scale=1 / zero=x.

Vectors appended to an *existing* posting reuse the posting's current
scale/zero (values outside the trained range clip); the exact fp32
cold tier plus the rerank pass bound the damage, and the next
split/merge rewrite re-trains the parameters from scratch.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

CODECS = ("fp32", "bf16", "int8")

# Quantized code range: symmetric about the zero-point so the error
# bound is scale/2 on both sides.
_QMAX = 127.0
_QLEVELS = 254.0


def payload_dtype(codec: str, vector_dtype) -> jnp.dtype:
    """Storage dtype of ``pool.blocks`` for a codec.

    ``fp32`` passes the configured vector dtype through unchanged so
    pre-codec configs keep byte-identical pools.
    """
    if codec == "fp32":
        return jnp.dtype(vector_dtype)
    if codec == "bf16":
        return jnp.dtype(jnp.bfloat16)
    if codec == "int8":
        return jnp.dtype(jnp.int8)
    raise ValueError(f"unknown codec {codec!r} (choose from {CODECS})")


def is_quantized(codec: str) -> bool:
    """True when the codec needs per-posting scale/zero to decode."""
    return codec == "int8"


def has_exact_tier(codec: str) -> bool:
    """True when the pool keeps a cold exact-fp32 copy alongside.

    bf16 round-trips well enough for maintenance math, but the rerank
    contract ("exact fp32 rerank") wants true fp32 distances, so both
    lossy codecs carry the cold tier.
    """
    return codec in ("bf16", "int8")


# ---------------------------------------------------------------------------
# jnp (traced) helpers
# ---------------------------------------------------------------------------


def train_scale_zero(vecs, valid):
    """Per-posting scale/zero from the valid rows of ``vecs``.

    vecs:  (..., n, d) float
    valid: (..., n) bool
    returns (scale, zero), each (...,) float32.  Postings with no valid
    rows (or a collapsed range) get scale=1, zero=0 / midpoint.
    """
    v = vecs.astype(jnp.float32)
    m = valid[..., None]
    hi = jnp.max(jnp.where(m, v, -jnp.inf), axis=(-2, -1))
    lo = jnp.min(jnp.where(m, v, jnp.inf), axis=(-2, -1))
    any_valid = jnp.any(valid, axis=-1)
    hi = jnp.where(any_valid, hi, 0.0)
    lo = jnp.where(any_valid, lo, 0.0)
    zero = (hi + lo) * 0.5
    rng = hi - lo
    scale = jnp.where(rng > 0, rng / _QLEVELS, 1.0).astype(jnp.float32)
    return scale, zero.astype(jnp.float32)


def encode(vecs, scale, zero):
    """fp32 rows -> int8 codes under a posting's (scale, zero).

    ``scale``/``zero`` broadcast against ``vecs[..., :-1]`` — pass
    scalars for one posting or ``scale[..., None, None]``-shaped arrays
    for batched rows.
    """
    q = jnp.round((vecs.astype(jnp.float32) - zero) / scale)
    return jnp.clip(q, -_QMAX, _QMAX).astype(jnp.int8)


def decode(codes, scale, zero):
    """int8 codes -> fp32 under (scale, zero); broadcasting as encode."""
    return codes.astype(jnp.float32) * scale + zero


def encode_payload(codec: str, vecs, scale, zero, out_dtype):
    """Encode fp32 rows into the hot-tier payload dtype for ``codec``.

    For fp32/bf16 this is a plain astype (scale/zero unused); for int8
    it quantizes under the supplied per-posting parameters.
    """
    if codec == "int8":
        return encode(vecs, scale, zero)
    return vecs.astype(out_dtype)


def decode_payload(codec: str, payload, scale, zero):
    """Hot-tier payload -> fp32 rows (inverse of encode_payload)."""
    if codec == "int8":
        return decode(payload, scale, zero)
    return payload.astype(jnp.float32)


# ---------------------------------------------------------------------------
# numpy helpers (host-side build path)
# ---------------------------------------------------------------------------


def np_train_scale_zero(rows: np.ndarray) -> tuple[np.float32, np.float32]:
    """(scale, zero) for one posting's rows (n, d) on host."""
    if rows.size == 0:
        return np.float32(1.0), np.float32(0.0)
    hi = float(rows.max())
    lo = float(rows.min())
    zero = (hi + lo) * 0.5
    rng = hi - lo
    scale = rng / _QLEVELS if rng > 0 else 1.0
    return np.float32(scale), np.float32(zero)


def np_encode(rows: np.ndarray, scale, zero) -> np.ndarray:
    q = np.round((rows.astype(np.float32) - zero) / scale)
    return np.clip(q, -_QMAX, _QMAX).astype(np.int8)


def np_decode(codes: np.ndarray, scale, zero) -> np.ndarray:
    return codes.astype(np.float32) * scale + zero
