"""Snapshot store — paper §4.4 crash recovery (snapshot half).

A snapshot captures the full functional index state (centroid index, version
map, block mapping, block pool — everything is one pytree here).  Writing is
atomic: we write to a temp dir and rename.  Restore needs a *template* state
(built from the config) to recover the treedef; leaves are loaded by position.

The paper's block-level copy-on-write + pre-release buffer exists to keep
*on-disk* blocks rollback-consistent between snapshots; in the functional
design every step already produces a fresh state, so the snapshot is simply
the latest state — we keep the pre-release semantics at the WAL level
(truncate only after the snapshot rename commits).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, TypeVar

import jax
import numpy as np

T = TypeVar("T")

_MANIFEST = "manifest.json"
_LEAVES = "leaves.npz"


def save_snapshot(path: str, state: Any, *, step: int = 0, extra: dict | None = None) -> None:
    leaves = jax.tree_util.tree_leaves(state)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    parent = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=parent, prefix=".snap_tmp_")
    try:
        np.savez(os.path.join(tmp, _LEAVES), **arrays)
        manifest = {
            "n_leaves": len(leaves),
            "step": step,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as fh:
            json.dump(manifest, fh)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.replace(tmp, path)  # atomic commit
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)


def load_snapshot(path: str, template: T) -> tuple[T, dict]:
    """Restore a state with the same structure as ``template``."""
    with open(os.path.join(path, _MANIFEST)) as fh:
        manifest = json.load(fh)
    data = np.load(os.path.join(path, _LEAVES))
    leaves, treedef = jax.tree_util.tree_flatten(template)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"snapshot has {manifest['n_leaves']} leaves, template has {len(leaves)}"
        )
    new_leaves = []
    for i, tmpl in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        want = np.asarray(tmpl)
        if arr.shape != want.shape:
            raise ValueError(
                f"leaf {i}: snapshot shape {arr.shape} != template {want.shape}"
            )
        new_leaves.append(jax.numpy.asarray(arr, dtype=want.dtype))
    return treedef.unflatten(new_leaves), manifest


def snapshot_exists(path: str) -> bool:
    return os.path.exists(os.path.join(path, _MANIFEST))
