"""Snapshot store — paper §4.4 crash recovery (snapshot half).

Two on-disk formats live here:

* **Legacy full snapshots** (``save_snapshot``/``load_snapshot``): one dir
  with ``manifest.json`` + ``leaves.npz`` holding every pytree leaf,
  committed by atomic rename with a ``path.old`` rotation fallback.  Still
  used by the training checkpointer and ``SPFreshIndex.snapshot``.

* **Chained incremental snapshots** (:class:`SnapshotStore`): the paper's
  block-level copy-on-write made durable.  A store directory holds *units*
  — ``base-<id>`` dirs (a full snapshot) and ``delta-<id>`` dirs (only the
  blocks the pool's dirty bitmap marked since the previous unit, plus the
  small non-block leaves, as one file per shard) — chained by parent links
  in their manifests.  A ``CURRENT`` pointer file names the head unit and
  is the commit point: it is replaced atomically only after the new unit
  dir has fully landed, so at EVERY crash point the store resolves a
  complete recovery chain.  Restore = base + ordered deltas; compaction
  folds the chain back into a fresh base and only then prunes the old
  units.

Manifest format 2 adds ``kind``/``unit``/``parent``/``chain_len``/
``n_shards``; format-1 snapshots (and states saved before the pool grew
its ``dirty`` leaf) load through an explicit migration path: the missing
dirty-bitmap leaf is reconstructed as all-clean from the template.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Callable, TypeVar

import jax
import numpy as np

T = TypeVar("T")

_MANIFEST = "manifest.json"
_LEAVES = "leaves.npz"
_CURRENT = "CURRENT"
_FORMAT = 2

# Test seam: called with a named step label at every crash point of a
# unit commit / compaction prune so tests can kill the process (raise) at
# each step and assert the store still resolves a complete chain.
_crash_hook: Callable[[str], None] | None = None


def _crash_point(label: str) -> None:
    if _crash_hook is not None:
        _crash_hook(label)


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    """Durably commit a directory's entries (renames live here) — the WAL
    is truncated right after a checkpoint, so the snapshot must reach the
    platter first or power loss could destroy acknowledged updates."""
    fd = os.open(path, getattr(os, "O_DIRECTORY", os.O_RDONLY))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_tree(d: str) -> None:
    for name in os.listdir(d):
        _fsync_file(os.path.join(d, name))
    _fsync_dir(d)


# ---------------------------------------------------------------------------
# Pytree helpers shared by both formats
# ---------------------------------------------------------------------------

def _dirty_leaf_index(template: Any) -> int | None:
    """Leaf index of ``pool.dirty`` in ``template``'s flatten order (None
    when the template has no block pool — e.g. a train-state dict)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(template)
    for i, (path, _leaf) in enumerate(flat):
        names = [k.name for k in path
                 if isinstance(k, jax.tree_util.GetAttrKey)]
        if names[-2:] == ["pool", "dirty"]:
            return i
    return None


def _telemetry_leaf_indices(template: Any) -> list[int]:
    """Leaf indices of the ``state.telemetry`` sub-tree (empty when the
    template has no telemetry — e.g. a train-state dict).  ``telemetry``
    is the LAST IndexState field, so these are trailing in flatten order;
    snapshots written before it existed reconstruct them as zeros."""
    out: list[int] = []
    flat, _ = jax.tree_util.tree_flatten_with_path(template)
    for i, (path, _leaf) in enumerate(flat):
        names = [k.name for k in path
                 if isinstance(k, jax.tree_util.GetAttrKey)]
        if len(names) >= 2 and names[-2] == "telemetry":
            out.append(i)
    return out


def _codec_leaf_indices(template: Any) -> dict[str, int]:
    """Leaf indices of the pool's per-posting codec params
    (``post_scale`` / ``post_zero``) — reconstructed for snapshots
    written before the payload codec existed.  ``blocks_exact`` is NOT
    here: a pre-codec snapshot can only be opened under the fp32 codec
    (replay-critical drift check), whose pool has no exact-tier leaf."""
    out: dict[str, int] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(template)
    for i, (path, _leaf) in enumerate(flat):
        names = [k.name for k in path
                 if isinstance(k, jax.tree_util.GetAttrKey)]
        if len(names) >= 2 and names[-2] == "pool" \
                and names[-1] in ("post_scale", "post_zero"):
            out[names[-1]] = i
    return out


def _block_leaf_indices(template: Any) -> dict[str, int] | None:
    """Leaf indices of the per-block pool arrays (``pool.blocks`` /
    ``block_vid`` / ``block_ver`` / ``dirty``, plus the optional cold
    exact tier ``blocks_exact`` when the codec keeps one) — the leaves a
    delta snapshot stores at block granularity instead of in full."""
    want = ("blocks", "block_vid", "block_ver", "dirty")
    opt = ("blocks_exact",)
    out: dict[str, int] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(template)
    for i, (path, _leaf) in enumerate(flat):
        names = [k.name for k in path
                 if isinstance(k, jax.tree_util.GetAttrKey)]
        if len(names) >= 2 and names[-2] == "pool" \
                and names[-1] in want + opt:
            out[names[-1]] = i
    return out if all(n in out for n in want) else None


def _assemble(template: T, leaves_np: list[np.ndarray]) -> T:
    tmpl_leaves, treedef = jax.tree_util.tree_flatten(template)
    out = []
    for arr, tmpl in zip(leaves_np, tmpl_leaves):
        want = np.asarray(tmpl)
        if arr.shape != want.shape:
            raise ValueError(
                f"snapshot leaf shape {arr.shape} != template {want.shape}"
            )
        out.append(jax.numpy.asarray(arr, dtype=want.dtype))
    return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# Legacy full snapshots (format 1)
# ---------------------------------------------------------------------------

def save_snapshot(path: str, state: Any, *, step: int = 0, extra: dict | None = None) -> None:
    """Crash-safe commit: write to a temp dir, rotate the previous
    snapshot aside (``path + ".old"``), rename the new one in, then drop
    the old.  At EVERY intermediate crash point either ``path`` or
    ``path.old`` holds a complete snapshot — ``load_snapshot`` /
    ``snapshot_exists`` resolve the fallback — so a checkpoint can never
    destroy the only recovery point (the WAL is truncated strictly after
    this function returns)."""
    leaves = jax.tree_util.tree_leaves(state)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    parent = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=parent, prefix=".snap_tmp_")
    old = path + ".old"
    try:
        np.savez(os.path.join(tmp, _LEAVES), **arrays)
        manifest = {
            "format": _FORMAT,
            "kind": "base",
            "n_leaves": len(leaves),
            "step": step,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as fh:
            json.dump(manifest, fh)
        _fsync_tree(tmp)       # data on the platter before the renames
        if os.path.exists(path):
            # Only rotate when a live primary exists: if a prior crash
            # left the .old fallback as the ONLY snapshot, deleting it
            # before the new commit would violate the invariant above.
            shutil.rmtree(old, ignore_errors=True)
            os.replace(path, old)
        os.replace(tmp, path)  # commit
        _fsync_dir(parent)     # ...and the renames before WAL truncation
        shutil.rmtree(old, ignore_errors=True)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)


def _resolve(path: str) -> str:
    """The live snapshot dir: ``path``, or the rotated-aside ``path.old``
    if a crash hit save_snapshot between its two renames."""
    if os.path.exists(os.path.join(path, _MANIFEST)):
        return path
    if os.path.exists(os.path.join(path + ".old", _MANIFEST)):
        return path + ".old"
    return path


def read_manifest(path: str) -> dict:
    """The snapshot manifest alone (cheap: no leaf arrays loaded)."""
    with open(os.path.join(_resolve(path), _MANIFEST)) as fh:
        return json.load(fh)


def _load_leaves_npz(path: str, template: Any, n_leaves: int) -> list[np.ndarray]:
    """Positional ``leaf_i`` arrays with the older-format migrations: a
    snapshot written before the pool grew its ``dirty`` leaf, the state
    grew its ``telemetry`` sub-tree, and/or the pool grew its codec
    params (``post_scale``/``post_zero``) is short those leaves; each
    missing leaf is reconstructed from the template at its flatten
    position (all-clean bitmap, zeroed counters, identity codec —
    scale 1, zero 0).  The leaf groups landed in a fixed order
    (dirty → telemetry → codec), so every historical generation maps to
    a distinct deficit: 1 (dirty), 2 (codec), 3 (telemetry),
    4 (dirty+tel), 5 (tel+codec), or 6 (dirty+tel+codec)."""
    data = np.load(path)
    return _migrate_leaves(
        [data[f"leaf_{i}"] for i in range(n_leaves)], template
    )


def _migrate_leaves(raw: list[np.ndarray], template: Any) -> list[np.ndarray]:
    """Insert reconstructed leaves into a positionally-loaded older-format
    leaf list (see ``_load_leaves_npz``).  Split out so a delta CHAIN can
    fold in its own (old) leaf coordinates first and migrate once at the
    end — the stamped per-unit leaf indices predate the new leaves."""
    tmpl_leaves = jax.tree_util.tree_leaves(template)
    n_leaves = len(raw)
    if n_leaves == len(tmpl_leaves):
        return raw
    dirty_at = _dirty_leaf_index(template)
    tel_at = _telemetry_leaf_indices(template)
    codec_at = _codec_leaf_indices(template)
    missing = len(tmpl_leaves) - n_leaves
    # index -> fill value factory for each reconstructible leaf group
    dirty_g = {dirty_at: np.zeros_like} if dirty_at is not None else None
    tel_g = {i: np.zeros_like for i in tel_at} if tel_at else None
    codec_g = (
        {codec_at["post_scale"]: np.ones_like,
         codec_at["post_zero"]: np.zeros_like}
        if len(codec_at) == 2 else None
    )
    reconstruct: dict[int, Any] = {}
    for groups in (
        (dirty_g,), (codec_g,), (tel_g,), (dirty_g, tel_g),
        (tel_g, codec_g), (dirty_g, tel_g, codec_g),
    ):
        if all(g is not None for g in groups) \
                and missing == sum(len(g) for g in groups):
            for g in groups:
                reconstruct.update(g)
            break
    if reconstruct:
        out, src = [], 0
        for i, tmpl in enumerate(tmpl_leaves):
            if i in reconstruct:
                out.append(reconstruct[i](np.asarray(tmpl)))
            else:
                out.append(raw[src])
                src += 1
        return out
    raise ValueError(
        f"snapshot has {n_leaves} leaves, template has {len(tmpl_leaves)}"
    )


def load_snapshot(path: str, template: T) -> tuple[T, dict]:
    """Restore a state with the same structure as ``template``."""
    path = _resolve(path)
    with open(os.path.join(path, _MANIFEST)) as fh:
        manifest = json.load(fh)
    leaves = _load_leaves_npz(
        os.path.join(path, _LEAVES), template, manifest["n_leaves"]
    )
    return _assemble(template, leaves), manifest


def snapshot_exists(path: str) -> bool:
    return os.path.exists(os.path.join(_resolve(path), _MANIFEST))


# ---------------------------------------------------------------------------
# SnapshotStore — chained base + delta units (format 2)
# ---------------------------------------------------------------------------

_UNIT_RE = re.compile(r"^(base|delta)-(\d{10})$")


class SnapshotChainError(RuntimeError):
    """The store's head chain references a unit that no longer resolves."""


class SnapshotStore:
    """Base + delta snapshot chain under one directory (see module doc).

    The store is format-compatible with a legacy full-snapshot dir: a
    root that holds only ``manifest.json``/``leaves.npz`` (or its
    ``.old`` rotation) loads as an implicit base, and the first
    ``save_base`` converts the root to the chained layout (pruning the
    legacy files only after the new unit commits).
    """

    def __init__(self, path: str):
        self.path = path

    # ----------------------------- resolve -----------------------------
    def _units(self) -> list[str]:
        if not os.path.isdir(self.path):
            return []
        return sorted(
            d for d in os.listdir(self.path)
            if _UNIT_RE.match(d)
            and os.path.exists(os.path.join(self.path, d, _MANIFEST))
        )

    def _unit_manifest(self, unit: str) -> dict:
        with open(os.path.join(self.path, unit, _MANIFEST)) as fh:
            return json.load(fh)

    def _chain(self, head: str) -> list[str]:
        """``[base, delta, ..., head]`` oldest-first; raises
        :class:`SnapshotChainError` on a broken parent link."""
        chain = []
        unit: str | None = head
        while unit is not None:
            if not os.path.exists(os.path.join(self.path, unit, _MANIFEST)):
                raise SnapshotChainError(
                    f"{self.path}: chain references missing unit {unit!r}"
                )
            chain.append(unit)
            unit = self._unit_manifest(unit).get("parent")
        if not chain or not chain[-1].startswith("base-"):
            raise SnapshotChainError(
                f"{self.path}: chain from {head!r} has no base"
            )
        return chain[::-1]

    def _head(self) -> str | None:
        """The committed head unit: ``CURRENT`` when it resolves, else the
        newest unit with a complete chain (crash between unit rename and
        the CURRENT update — both states are consistent recovery points
        because the WAL is truncated strictly after the commit)."""
        cur = os.path.join(self.path, _CURRENT)
        if os.path.exists(cur):
            with open(cur) as fh:
                head = fh.read().strip()
            try:
                self._chain(head)
                return head
            except SnapshotChainError:
                pass
        for unit in reversed(self._units()):
            try:
                self._chain(unit)
                return unit
            except SnapshotChainError:
                continue
        return None

    def _legacy_exists(self) -> bool:
        return os.path.exists(os.path.join(_resolve(self.path), _MANIFEST))

    def exists(self) -> bool:
        return self._head() is not None or self._legacy_exists()

    def has_base(self) -> bool:
        """True when a chained-layout head exists to hang a delta on (a
        legacy-layout root must be rebased by a full save first)."""
        return self._head() is not None

    def read_manifest(self) -> dict:
        head = self._head()
        if head is not None:
            return self._unit_manifest(head)
        return read_manifest(self.path)

    def chain_len(self) -> int:
        """Deltas stacked on the current base (0 = head is a base)."""
        head = self._head()
        if head is None:
            return 0
        return int(self._unit_manifest(head).get("chain_len", 0))

    # ------------------------------ write ------------------------------
    def _next_unit(self, kind: str) -> str:
        ids = [int(_UNIT_RE.match(u).group(2)) for u in self._units()]
        return f"{kind}-{(max(ids) + 1 if ids else 1):010d}"

    def _commit_unit(self, tmp: str, unit: str) -> None:
        """tmp dir → unit dir → CURRENT, with crash points between; every
        data file, the unit dir, and the store dir are fsync'd so the
        commit is on the platter BEFORE the caller truncates the WAL."""
        _fsync_tree(tmp)
        _crash_point("pre_commit")
        os.replace(tmp, os.path.join(self.path, unit))
        _fsync_dir(self.path)
        _crash_point("post_commit")
        cur_tmp = os.path.join(self.path, f".current_tmp_{unit}")
        with open(cur_tmp, "w") as fh:
            fh.write(unit)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(cur_tmp, os.path.join(self.path, _CURRENT))
        _fsync_dir(self.path)
        _crash_point("post_current")

    def _prune(self, keep: set[str]) -> None:
        """Drop every unit outside ``keep`` plus any legacy files — only
        reachable after the new head committed, so each deletion is safe
        at every crash point."""
        for unit in self._units():
            if unit not in keep:
                _crash_point(f"prune:{unit}")
                shutil.rmtree(os.path.join(self.path, unit),
                              ignore_errors=True)
        for legacy in (_MANIFEST, _LEAVES):
            p = os.path.join(self.path, legacy)
            if os.path.exists(p):
                _crash_point(f"prune:{legacy}")
                os.remove(p)
        old = self.path + ".old"
        if os.path.exists(old):
            _crash_point("prune:old")
            shutil.rmtree(old, ignore_errors=True)

    def save_base(self, state: Any, *, step: int = 0,
                  extra: dict | None = None) -> str:
        """Full snapshot as a new base unit; prunes the entire previous
        chain (and any legacy-layout files) after the commit — this IS
        the chain compaction: the in-memory state already equals
        base + deltas + dirty tail, so folding is a fresh full write."""
        os.makedirs(self.path, exist_ok=True)
        unit = self._next_unit("base")
        leaves = jax.tree_util.tree_leaves(state)
        tmp = tempfile.mkdtemp(dir=self.path, prefix=".unit_tmp_")
        try:
            np.savez(
                os.path.join(tmp, _LEAVES),
                **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)},
            )
            manifest = {
                "format": _FORMAT,
                "kind": "base",
                "unit": unit,
                "parent": None,
                "chain_len": 0,
                "n_leaves": len(leaves),
                "step": step,
                "extra": extra or {},
            }
            with open(os.path.join(tmp, _MANIFEST), "w") as fh:
                json.dump(manifest, fh)
            self._commit_unit(tmp, unit)
        finally:
            if os.path.exists(tmp):
                shutil.rmtree(tmp, ignore_errors=True)
        self._prune(keep={unit})
        return unit

    def save_delta(self, state: Any, *, n_shards: int = 1, step: int = 0,
                   extra: dict | None = None) -> str:
        """Delta unit: per shard, only the blocks marked dirty in
        ``state.pool.dirty`` (payload + slot metadata) plus every
        non-block leaf in full.  Chained onto the current head; restore
        applies the chain oldest-first.  Requires an existing head (the
        first checkpoint of a durable root is always a base)."""
        head = self._head()
        if head is None:
            raise SnapshotChainError(
                f"{self.path}: save_delta with no base snapshot to chain to"
            )
        blk = _block_leaf_indices(state)
        if blk is None:
            raise ValueError("save_delta needs a state with a block pool")
        head_m = self._unit_manifest(head)
        unit = self._next_unit("delta")
        leaves = jax.tree_util.tree_leaves(state)
        if head_m["n_leaves"] != len(leaves):
            raise ValueError(
                f"delta over a {head_m['n_leaves']}-leaf chain, state has "
                f"{len(leaves)} (mixed-format chain?)"
            )
        # One device→host conversion per leaf, OUTSIDE the shard loop —
        # re-materializing the stacked block arrays per shard would make
        # the delta cost O(n_shards × full state) in transfers.
        dirty = np.asarray(leaves[blk["dirty"]])
        blk_np = {
            name: np.asarray(leaves[blk[name]])
            for name in blk if name != "dirty"
        }
        dense_np = {
            j: np.asarray(leaf) for j, leaf in enumerate(leaves)
            if j not in blk.values()
        }
        tmp = tempfile.mkdtemp(dir=self.path, prefix=".unit_tmp_")
        try:
            for s in range(n_shards):
                sl = (lambda x: x[s]) if n_shards > 1 else (lambda x: x)
                idx = np.flatnonzero(sl(dirty)).astype(np.int32)
                arrays: dict[str, np.ndarray] = {"dirty_idx": idx}
                for name, whole in blk_np.items():
                    arrays[f"blk_{name}"] = sl(whole)[idx]
                for j, whole in dense_np.items():
                    arrays[f"leaf_{j}"] = sl(whole)
                np.savez(os.path.join(tmp, f"shard_{s:03d}.npz"), **arrays)
            manifest = {
                "format": _FORMAT,
                "kind": "delta",
                "unit": unit,
                "parent": head,
                "chain_len": int(head_m.get("chain_len", 0)) + 1,
                "n_leaves": len(leaves),
                "n_shards": n_shards,
                "block_leaves": blk,
                "step": step,
                "extra": extra or {},
            }
            with open(os.path.join(tmp, _MANIFEST), "w") as fh:
                json.dump(manifest, fh)
            self._commit_unit(tmp, unit)
        finally:
            if os.path.exists(tmp):
                shutil.rmtree(tmp, ignore_errors=True)
        return unit

    # ------------------------------ read -------------------------------
    def _apply_delta(self, leaves: list[np.ndarray], unit: str,
                     manifest: dict) -> None:
        blk = manifest["block_leaves"]
        n_shards = int(manifest.get("n_shards", 1))
        blk_idx = set(blk.values())
        for s in range(n_shards):
            data = np.load(os.path.join(self.path, unit, f"shard_{s:03d}.npz"))
            idx = data["dirty_idx"]
            for name in blk:
                if name == "dirty":
                    continue
                tgt = leaves[blk[name]]
                if n_shards > 1:
                    tgt[s][idx] = data[f"blk_{name}"]
                else:
                    tgt[idx] = data[f"blk_{name}"]
            for j in range(len(leaves)):
                if j in blk_idx:
                    continue
                arr = data[f"leaf_{j}"]
                if n_shards > 1:
                    leaves[j][s] = arr
                else:
                    leaves[j] = arr

    def load(self, template: T) -> tuple[T, dict]:
        """Resolve the head, walk to its base, and fold the deltas in
        order.  The head unit's manifest (whose ``extra`` stamps the WAL
        seqnos of the LAST checkpoint) is returned.  Falls back to the
        legacy full-snapshot layout."""
        head = self._head()
        if head is None:
            if self._legacy_exists():
                return load_snapshot(self.path, template)
            raise FileNotFoundError(f"{self.path}: no snapshot to load")
        chain = self._chain(head)
        base_m = self._unit_manifest(chain[0])
        data = np.load(os.path.join(self.path, chain[0], _LEAVES))
        # fold the chain in ITS OWN leaf coordinates (every unit of a
        # chain has the same n_leaves — save_delta enforces it), THEN
        # migrate: each delta's stamped block/dense leaf indices predate
        # any leaves the template has since grown.
        leaves = [np.array(data[f"leaf_{i}"])
                  for i in range(base_m["n_leaves"])]
        for unit in chain[1:]:
            self._apply_delta(leaves, unit, self._unit_manifest(unit))
        leaves = _migrate_leaves(leaves, template)
        dirty_at = _dirty_leaf_index(template)
        if dirty_at is not None:
            # post-restore the state is by definition in sync with the
            # chain head: nothing is dirty until the next update lands
            leaves[dirty_at] = np.zeros_like(leaves[dirty_at])
        return _assemble(template, leaves), self._unit_manifest(head)

    # --------------------------- accounting ----------------------------
    def unit_bytes(self, unit: str | None = None) -> int:
        """On-disk bytes of one unit (default: head) — the benchmark's
        checkpoint-cost metric."""
        unit = unit or self._head()
        if unit is None:
            return 0
        d = os.path.join(self.path, unit)
        return sum(
            os.path.getsize(os.path.join(d, f)) for f in os.listdir(d)
        )
