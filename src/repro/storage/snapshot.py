"""Snapshot store — paper §4.4 crash recovery (snapshot half).

A snapshot captures the full functional index state (centroid index, version
map, block mapping, block pool — everything is one pytree here).  Writing is
atomic: we write to a temp dir and rename.  Restore needs a *template* state
(built from the config) to recover the treedef; leaves are loaded by position.

The paper's block-level copy-on-write + pre-release buffer exists to keep
*on-disk* blocks rollback-consistent between snapshots; in the functional
design every step already produces a fresh state, so the snapshot is simply
the latest state — we keep the pre-release semantics at the WAL level
(truncate only after the snapshot rename commits).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, TypeVar

import jax
import numpy as np

T = TypeVar("T")

_MANIFEST = "manifest.json"
_LEAVES = "leaves.npz"


def save_snapshot(path: str, state: Any, *, step: int = 0, extra: dict | None = None) -> None:
    """Crash-safe commit: write to a temp dir, rotate the previous
    snapshot aside (``path + ".old"``), rename the new one in, then drop
    the old.  At EVERY intermediate crash point either ``path`` or
    ``path.old`` holds a complete snapshot — ``load_snapshot`` /
    ``snapshot_exists`` resolve the fallback — so a checkpoint can never
    destroy the only recovery point (the WAL is truncated strictly after
    this function returns)."""
    leaves = jax.tree_util.tree_leaves(state)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    parent = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=parent, prefix=".snap_tmp_")
    old = path + ".old"
    try:
        np.savez(os.path.join(tmp, _LEAVES), **arrays)
        manifest = {
            "n_leaves": len(leaves),
            "step": step,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as fh:
            json.dump(manifest, fh)
        if os.path.exists(path):
            # Only rotate when a live primary exists: if a prior crash
            # left the .old fallback as the ONLY snapshot, deleting it
            # before the new commit would violate the invariant above.
            shutil.rmtree(old, ignore_errors=True)
            os.replace(path, old)
        os.replace(tmp, path)  # commit
        shutil.rmtree(old, ignore_errors=True)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)


def _resolve(path: str) -> str:
    """The live snapshot dir: ``path``, or the rotated-aside ``path.old``
    if a crash hit save_snapshot between its two renames."""
    if os.path.exists(os.path.join(path, _MANIFEST)):
        return path
    if os.path.exists(os.path.join(path + ".old", _MANIFEST)):
        return path + ".old"
    return path


def read_manifest(path: str) -> dict:
    """The snapshot manifest alone (cheap: no leaf arrays loaded)."""
    with open(os.path.join(_resolve(path), _MANIFEST)) as fh:
        return json.load(fh)


def load_snapshot(path: str, template: T) -> tuple[T, dict]:
    """Restore a state with the same structure as ``template``."""
    path = _resolve(path)
    with open(os.path.join(path, _MANIFEST)) as fh:
        manifest = json.load(fh)
    data = np.load(os.path.join(path, _LEAVES))
    leaves, treedef = jax.tree_util.tree_flatten(template)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"snapshot has {manifest['n_leaves']} leaves, template has {len(leaves)}"
        )
    new_leaves = []
    for i, tmpl in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        want = np.asarray(tmpl)
        if arr.shape != want.shape:
            raise ValueError(
                f"leaf {i}: snapshot shape {arr.shape} != template {want.shape}"
            )
        new_leaves.append(jax.numpy.asarray(arr, dtype=want.dtype))
    return treedef.unflatten(new_leaves), manifest


def snapshot_exists(path: str) -> bool:
    return os.path.exists(os.path.join(_resolve(path), _MANIFEST))
