"""Paged block pool — the TPU-native analogue of SPFresh's Block Controller.

Paper §4.3: postings live on raw SSD blocks; an in-memory *Block Mapping*
maps posting id → block offsets; a *Free Block Pool* recycles blocks; APPEND
touches only the posting's tail block; PUT bulk-writes a posting.

Here the "SSD" is a fixed-capacity HBM array ``blocks[B_cap, BS, d]`` and the
block mapping is ``posting_blocks[P_cap, MB]`` (int32 block ids, -1 unused).
GET is a block-table gather (the same indirection as paged-attention KV);
APPEND is a dynamic-update of a single (block, slot); the free pool is an
int32 stack.  Everything is functional: each op returns a new pool pytree.

Blocks carry payload + metadata per slot, mirroring the paper's on-disk tuple
``<vector id, version number, raw vector>``.

Dirty tracking (paper §4.4, the block controller's copy-on-write ledger):
``dirty[B_cap]`` marks every block whose payload or slot metadata changed
since the last checkpoint cleared it.  All write paths set it — APPEND
tail writes, PUT rewrites, GC write-backs, and block frees (a freed
block's cleared ``block_vid`` must reach the next delta snapshot too).
``storage.snapshot`` serializes only dirty blocks into delta snapshots,
making checkpoint bytes proportional to churn instead of capacity.

Tiered payload (``storage.codec``): the hot tier ``blocks`` stores the
scan payload in the codec's dtype (fp32 passthrough / bf16 / int8 with
per-posting ``post_scale``/``post_zero``); lossy codecs additionally
carry a cold exact-fp32 tier ``blocks_exact`` (same geometry, same dirty
bitmap) that serves maintenance reads and the search rerank.  Every
write path encodes into the hot tier and mirrors raw fp32 into the cold
tier; PUT retrains the posting's scale/zero from the rows it writes,
APPEND reuses the posting's current parameters (first-ever append
trains them from that row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.storage import codec as pc
from repro.utils.tree import field, pytree_dataclass

Array = jax.Array


@pytree_dataclass
class BlockPool:
    # --- static geometry ---
    block_size: int = field(static=True)           # BS vectors per block
    max_blocks_per_posting: int = field(static=True)  # MB
    codec: str = field(static=True)                # fp32 | bf16 | int8
    # --- device state ---
    blocks: Array        # (B_cap, BS, d) hot-tier payload (codec dtype)
    block_vid: Array     # (B_cap, BS) i32 vector ids, -1 empty
    block_ver: Array     # (B_cap, BS) u8 version written with the data
    posting_blocks: Array  # (P_cap, MB) i32 block ids, -1 unused
    posting_len: Array     # (P_cap,) i32 vectors in posting
    free_stack: Array      # (B_cap,) i32 free block ids (top at index free_top-1)
    free_top: Array        # () i32 number of free blocks
    dirty: Array           # (B_cap,) bool — block changed since last checkpoint
    post_scale: Array      # (P_cap,) f32 per-posting quant scale (1 untrained)
    post_zero: Array       # (P_cap,) f32 per-posting quant zero-point
    blocks_exact: Array | None  # (B_cap, BS, d) f32 cold tier (lossy codecs)

    @property
    def posting_capacity(self) -> int:
        return self.block_size * self.max_blocks_per_posting

    @property
    def num_postings_cap(self) -> int:
        return self.posting_blocks.shape[0]

    @property
    def num_blocks_cap(self) -> int:
        return self.blocks.shape[0]

    @property
    def dim(self) -> int:
        return self.blocks.shape[-1]


def make_block_pool(
    *,
    num_blocks: int,
    block_size: int,
    dim: int,
    num_postings_cap: int,
    max_blocks_per_posting: int,
    dtype=jnp.float32,
    codec: str = "fp32",
) -> BlockPool:
    """Fresh, empty pool: every block free, every posting empty.

    ``dtype`` is the *configured* vector dtype; the hot-tier payload is
    stored at ``codec.payload_dtype(codec, dtype)`` and lossy codecs get
    a cold exact-fp32 tier alongside.
    """
    pay = pc.payload_dtype(codec, dtype)
    return BlockPool(
        block_size=block_size,
        max_blocks_per_posting=max_blocks_per_posting,
        codec=codec,
        blocks=jnp.zeros((num_blocks, block_size, dim), pay),
        block_vid=jnp.full((num_blocks, block_size), -1, jnp.int32),
        block_ver=jnp.zeros((num_blocks, block_size), jnp.uint8),
        posting_blocks=jnp.full(
            (num_postings_cap, max_blocks_per_posting), -1, jnp.int32
        ),
        posting_len=jnp.zeros((num_postings_cap,), jnp.int32),
        free_stack=jnp.arange(num_blocks, dtype=jnp.int32),
        free_top=jnp.asarray(num_blocks, jnp.int32),
        dirty=jnp.zeros((num_blocks,), bool),
        post_scale=jnp.ones((num_postings_cap,), jnp.float32),
        post_zero=jnp.zeros((num_postings_cap,), jnp.float32),
        blocks_exact=(
            jnp.zeros((num_blocks, block_size, dim), jnp.float32)
            if pc.has_exact_tier(codec)
            else None
        ),
    )


def _encode_rows(pool: BlockPool, vecs: Array, scale, zero) -> Array:
    """fp32 rows -> hot-tier payload under (scale, zero) (broadcasting)."""
    return pc.encode_payload(pool.codec, vecs, scale, zero, pool.blocks.dtype)


def clear_dirty(pool: BlockPool) -> BlockPool:
    """All blocks clean — called after a checkpoint serializes the pool."""
    return pool.replace(dirty=jnp.zeros_like(pool.dirty))


# ---------------------------------------------------------------------------
# Block allocation
# ---------------------------------------------------------------------------

def _alloc_block(pool: BlockPool) -> tuple[BlockPool, Array]:
    """Pop a free block; returns (pool, block_id) with block_id = -1 on OOM."""
    has = pool.free_top > 0
    top = jnp.maximum(pool.free_top - 1, 0)
    bid = jnp.where(has, pool.free_stack[top], -1)
    pool = pool.replace(free_top=jnp.where(has, top, pool.free_top))
    return pool, bid


def _free_block(pool: BlockPool, bid: Array) -> BlockPool:
    """Push a block back (no-op for bid < 0). Clears slot metadata."""
    do = bid >= 0
    safe = jnp.maximum(bid, 0)
    free_stack = jnp.where(
        do,
        pool.free_stack.at[pool.free_top].set(bid.astype(jnp.int32)),
        pool.free_stack,
    )
    block_vid = jnp.where(
        do, pool.block_vid.at[safe].set(-1), pool.block_vid
    )
    dirty = jnp.where(do, pool.dirty.at[safe].set(True), pool.dirty)
    return pool.replace(
        free_stack=free_stack,
        free_top=jnp.where(do, pool.free_top + 1, pool.free_top),
        block_vid=block_vid,
        dirty=dirty,
    )


# ---------------------------------------------------------------------------
# APPEND — tail-block read-modify-write (paper §4.3)
# ---------------------------------------------------------------------------

def append_one(
    pool: BlockPool, pid: Array, vec: Array, vid: Array, ver: Array, enable: Array
) -> tuple[BlockPool, Array]:
    """Append one vector to posting ``pid``. Returns (pool, ok).

    ok=False when the posting is at capacity or the pool is out of blocks;
    the caller (Updater) counts drops — in production the shard would spill
    to a sibling replica, here we surface it as a statistic.
    """
    length = pool.posting_len[pid]
    slot = jnp.remainder(length, pool.block_size)
    blk_idx = length // pool.block_size
    need_new = (slot == 0)
    full = blk_idx >= pool.max_blocks_per_posting
    can = enable & (~full)

    # Allocate only when needed; otherwise keep pool untouched.
    def with_alloc(pool):
        pool2, bid = _alloc_block(pool)
        return pool2, bid

    def no_alloc(pool):
        safe_idx = jnp.minimum(blk_idx, pool.max_blocks_per_posting - 1)
        return pool, pool.posting_blocks[pid, safe_idx]

    pool, bid = jax.lax.cond(can & need_new, with_alloc, no_alloc, pool)
    ok = can & (bid >= 0)
    safe_bid = jnp.maximum(bid, 0)
    safe_idx = jnp.minimum(blk_idx, pool.max_blocks_per_posting - 1)

    posting_blocks = jnp.where(
        ok & need_new,
        pool.posting_blocks.at[pid, safe_idx].set(bid.astype(jnp.int32)),
        pool.posting_blocks,
    )
    # First-ever append trains the posting's quant params from this row;
    # later appends reuse them (out-of-range values clip — the exact tier
    # plus rerank bound the damage until the next PUT retrains).
    fresh = ok & (length == 0)
    scale0, zero0 = pc.train_scale_zero(vec[None, :], jnp.ones((1,), bool))
    scale = jnp.where(fresh, scale0, pool.post_scale[pid])
    zero = jnp.where(fresh, zero0, pool.post_zero[pid])
    post_scale = jnp.where(
        fresh, pool.post_scale.at[pid].set(scale0), pool.post_scale
    )
    post_zero = jnp.where(
        fresh, pool.post_zero.at[pid].set(zero0), pool.post_zero
    )
    blocks = jnp.where(
        ok,
        pool.blocks.at[safe_bid, slot].set(_encode_rows(pool, vec, scale, zero)),
        pool.blocks,
    )
    blocks_exact = pool.blocks_exact
    if blocks_exact is not None:
        blocks_exact = jnp.where(
            ok,
            blocks_exact.at[safe_bid, slot].set(vec.astype(jnp.float32)),
            blocks_exact,
        )
    block_vid = jnp.where(
        ok, pool.block_vid.at[safe_bid, slot].set(vid.astype(jnp.int32)),
        pool.block_vid,
    )
    block_ver = jnp.where(
        ok, pool.block_ver.at[safe_bid, slot].set(ver.astype(jnp.uint8)),
        pool.block_ver,
    )
    posting_len = jnp.where(
        ok, pool.posting_len.at[pid].add(1), pool.posting_len
    )
    dirty = jnp.where(ok, pool.dirty.at[safe_bid].set(True), pool.dirty)
    return (
        pool.replace(
            blocks=blocks,
            blocks_exact=blocks_exact,
            block_vid=block_vid,
            block_ver=block_ver,
            posting_blocks=posting_blocks,
            posting_len=posting_len,
            dirty=dirty,
            post_scale=post_scale,
            post_zero=post_zero,
        ),
        ok,
    )


@jax.jit
def append_batch(
    pool: BlockPool,
    pids: Array,
    vecs: Array,
    vids: Array,
    vers: Array,
    enable: Array,
) -> tuple[BlockPool, Array]:
    """Sequential batched append (appends can collide on a posting's tail).

    ``lax.scan`` over the batch; each step is O(1) state surgery, mirroring
    the paper's per-request APPEND path.  Returns (pool, ok_mask).
    """

    def step(pool, args):
        pid, vec, vid, ver, en = args
        pool, ok = append_one(pool, pid, vec, vid, ver, en)
        return pool, ok

    pool, oks = jax.lax.scan(step, pool, (pids, vecs, vids, vers, enable))
    return pool, oks


@jax.jit
def append_scatter(
    pool: BlockPool,
    pids: Array,
    vecs: Array,
    vids: Array,
    vers: Array,
    enable: Array,
) -> tuple[BlockPool, Array]:
    """Vectorized batched APPEND: n rows land in ONE scatter instead of an
    n-step ``lax.scan`` — the fused-reassignment append of the maintenance
    round (and its merge moves), where the scan's per-row sequential cost
    would swamp the batching win.

    Rows targeting the same posting are ranked in row order (earlier rows
    win tail slots — the same landed set as `append_batch`); a row fails
    (``ok=False``) when its posting is at capacity.  Tail blocks for every
    boundary-crossing posting are allocated in one cumsum-indexed pop;
    under pool OOM the rows needing fresh blocks fail as a group, so each
    posting still lands a contiguous rank prefix (`append_batch` fails
    them one by one — the failure set can differ only when the free pool
    runs dry mid-batch).
    """
    n = pids.shape[0]
    bs = pool.block_size
    cap = pool.posting_capacity
    mb = pool.max_blocks_per_posting
    nb_cap = pool.num_blocks_cap
    p_cap = pool.num_postings_cap
    en = enable & (pids >= 0)
    safe = jnp.maximum(pids, 0).astype(jnp.int32)

    # Rank of each enabled row within its posting, preserving row order:
    # stable group-by-pid sort, then position minus group start.
    row = jnp.arange(n, dtype=jnp.int32)
    spid_key = jnp.where(en, safe, p_cap)
    order = jnp.lexsort((row, spid_key))
    sp = spid_key[order]
    pos = jnp.arange(n, dtype=jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool), sp[1:] != sp[:-1]])
    start = jax.lax.cummax(jnp.where(first, pos, 0))
    rank = jnp.zeros((n,), jnp.int32).at[order].set(pos - start)

    slot_g = pool.posting_len[safe] + rank
    ok_cap = en & (slot_g < cap)
    blk = slot_g // bs
    slot = slot_g % bs
    safe_blk = jnp.minimum(blk, mb - 1)
    existing = pool.posting_blocks[safe, safe_blk]       # (n,)

    # One leader row per absent tail block (ranks are contiguous, so every
    # block boundary has a slot==0 row); allocate all leaders at once.
    leader = ok_cap & (slot == 0) & (existing < 0)
    n_new = jnp.sum(leader)
    have = n_new <= pool.free_top
    lrank = jnp.cumsum(leader.astype(jnp.int32)) - 1
    lpos = pool.free_top - 1 - lrank
    new_bid = jnp.where(
        leader & have, pool.free_stack[jnp.clip(lpos, 0, nb_cap - 1)], -1
    )
    posting_blocks = pool.posting_blocks.at[
        jnp.where(leader & have, safe, p_cap), safe_blk
    ].set(new_bid, mode="drop")

    bid = jnp.where(existing >= 0, existing, posting_blocks[safe, safe_blk])
    ok = ok_cap & (bid >= 0)

    tb = jnp.where(ok, bid, nb_cap)
    # Rows landing in a previously-empty posting (global slot 0) train its
    # quant params from their own row; later ranks of the same posting in
    # this batch read the freshly scattered value.
    fresh = ok & (slot_g == 0)
    rs, rz = pc.train_scale_zero(
        vecs[:, None, :], jnp.ones((n, 1), bool)
    )                                                    # (n,) per-row
    post_scale = pool.post_scale.at[
        jnp.where(fresh, safe, p_cap)
    ].set(rs, mode="drop")
    post_zero = pool.post_zero.at[
        jnp.where(fresh, safe, p_cap)
    ].set(rz, mode="drop")
    blocks = pool.blocks.at[tb, slot].set(
        _encode_rows(
            pool, vecs, post_scale[safe][:, None], post_zero[safe][:, None]
        ),
        mode="drop",
    )
    blocks_exact = pool.blocks_exact
    if blocks_exact is not None:
        blocks_exact = blocks_exact.at[tb, slot].set(
            vecs.astype(jnp.float32), mode="drop"
        )
    block_vid = pool.block_vid.at[tb, slot].set(
        vids.astype(jnp.int32), mode="drop"
    )
    block_ver = pool.block_ver.at[tb, slot].set(
        vers.astype(jnp.uint8), mode="drop"
    )
    posting_len = pool.posting_len.at[jnp.where(ok, safe, p_cap)].add(
        1, mode="drop"
    )
    dirty = pool.dirty.at[tb].set(True, mode="drop")
    return (
        pool.replace(
            blocks=blocks,
            blocks_exact=blocks_exact,
            block_vid=block_vid,
            block_ver=block_ver,
            posting_blocks=posting_blocks,
            posting_len=posting_len,
            free_top=pool.free_top - jnp.where(have, n_new, 0),
            dirty=dirty,
            post_scale=post_scale,
            post_zero=post_zero,
        ),
        ok,
    )


# ---------------------------------------------------------------------------
# GET — block-table gather (ParallelGET is vmap of this)
# ---------------------------------------------------------------------------

def gather_posting(
    pool: BlockPool, pid: Array
) -> tuple[Array, Array, Array, Array]:
    """Read a whole posting into fixed-capacity buffers.

    Returns ``(vecs (MB*BS, d), vids (MB*BS,), vers (MB*BS,), valid (MB*BS,))``.
    Slots past ``posting_len`` are masked invalid.  Lossy codecs serve
    the cold exact tier so maintenance rewrites never accumulate
    requantization error.
    """
    bids = pool.posting_blocks[pid]  # (MB,)
    safe = jnp.maximum(bids, 0)
    payload = pool.blocks_exact if pool.blocks_exact is not None else pool.blocks
    vecs = payload[safe]             # (MB, BS, d)
    vids = pool.block_vid[safe]
    vers = pool.block_ver[safe]
    cap = pool.posting_capacity
    d = pool.dim
    vecs = vecs.reshape(cap, d)
    vids = vids.reshape(cap)
    vers = vers.reshape(cap)
    idx = jnp.arange(cap, dtype=jnp.int32)
    valid = (idx < pool.posting_len[pid]) & (vids >= 0)
    return vecs, vids, vers, valid


def gather_posting_hot(
    pool: BlockPool, pid: Array
) -> tuple[Array, Array, Array, Array]:
    """`gather_posting`, but decoding the HOT tier (codec payload).

    The oracle search path uses this so its distances match what the
    dequant-fused Pallas scan computes — bit-for-bit the same decoded
    values, never the exact tier (which only the rerank reads).
    """
    bids = pool.posting_blocks[pid]  # (MB,)
    safe = jnp.maximum(bids, 0)
    vecs = pc.decode_payload(
        pool.codec, pool.blocks[safe], pool.post_scale[pid], pool.post_zero[pid]
    )
    vids = pool.block_vid[safe]
    vers = pool.block_ver[safe]
    cap = pool.posting_capacity
    d = pool.dim
    vecs = vecs.reshape(cap, d)
    vids = vids.reshape(cap)
    vers = vers.reshape(cap)
    idx = jnp.arange(cap, dtype=jnp.int32)
    valid = (idx < pool.posting_len[pid]) & (vids >= 0)
    return vecs, vids, vers, valid


def parallel_get(
    pool: BlockPool, pids: Array
) -> tuple[Array, Array, Array, Array]:
    """Paper's ParallelGET: batched posting fetch, ``pids (m,)`` →
    ``(m, MB*BS, ...)`` buffers."""
    return jax.vmap(lambda p: gather_posting(pool, p))(pids)


def parallel_get_hot(
    pool: BlockPool, pids: Array
) -> tuple[Array, Array, Array, Array]:
    """Batched `gather_posting_hot` — the oracle search path's fetch."""
    return jax.vmap(lambda p: gather_posting_hot(pool, p))(pids)


def gather_postings(
    pool: BlockPool, pids: Array
) -> tuple[Array, Array, Array, Array]:
    """Multi-pid bulk GET for the maintenance round: ``pids (k,)`` →
    ``(vecs (k, MB*BS, d), vids, vers, valid)``.  Negative pids read
    posting 0 but the caller's enable masks make those rows inert."""
    return parallel_get(pool, jnp.maximum(pids, 0))


def gather_posting_ids(
    pool: BlockPool, pid: Array
) -> tuple[Array, Array, Array]:
    """Metadata-only posting read: ``(vids, vers, valid)`` without payload.

    Used by the reassign NPA re-check (does a live replica already exist in
    the target posting?) where fetching vector payloads would be wasted HBM
    traffic.
    """
    bids = pool.posting_blocks[pid]
    safe = jnp.maximum(bids, 0)
    vids = pool.block_vid[safe].reshape(-1)
    vers = pool.block_ver[safe].reshape(-1)
    idx = jnp.arange(pool.posting_capacity, dtype=jnp.int32)
    valid = (idx < pool.posting_len[pid]) & (vids >= 0)
    return vids, vers, valid


# ---------------------------------------------------------------------------
# PUT / DELETE — bulk posting rewrite and free
# ---------------------------------------------------------------------------

def free_posting(pool: BlockPool, pid: Array, enable: Array) -> BlockPool:
    """Release all blocks of ``pid`` to the free pool and empty it."""
    bids = pool.posting_blocks[pid]  # (MB,)

    def step(pool, bid):
        pool = jax.lax.cond(
            enable & (bid >= 0), lambda p: _free_block(p, bid), lambda p: p, pool
        )
        return pool, ()

    pool, _ = jax.lax.scan(step, pool, bids)
    posting_blocks = jnp.where(
        enable, pool.posting_blocks.at[pid].set(-1), pool.posting_blocks
    )
    posting_len = jnp.where(
        enable, pool.posting_len.at[pid].set(0), pool.posting_len
    )
    post_scale = jnp.where(
        enable, pool.post_scale.at[pid].set(1.0), pool.post_scale
    )
    post_zero = jnp.where(
        enable, pool.post_zero.at[pid].set(0.0), pool.post_zero
    )
    return pool.replace(
        posting_blocks=posting_blocks,
        posting_len=posting_len,
        post_scale=post_scale,
        post_zero=post_zero,
    )


def free_postings(pool: BlockPool, pids: Array, enable: Array) -> BlockPool:
    """Batched `free_posting`: release all blocks of ``k`` DISTINCT postings
    in ONE scatter (the maintenance round's retire/GC path).

    The per-block ``lax.scan`` of `free_posting` becomes a cumsum-indexed
    push: every freed block id lands in ``free_stack[free_top + i]`` where
    ``i`` is its rank among the round's freed blocks; disabled rows and
    absent blocks scatter out of bounds and are dropped.
    """
    enable = enable & (pids >= 0)
    safe = jnp.maximum(pids, 0)
    bids = pool.posting_blocks[safe]                     # (k, MB)
    do = enable[:, None] & (bids >= 0)
    flat_bids = bids.reshape(-1)
    flat_do = do.reshape(-1)
    nb_cap = pool.num_blocks_cap

    pos = pool.free_top + jnp.cumsum(flat_do.astype(jnp.int32)) - 1
    free_stack = pool.free_stack.at[jnp.where(flat_do, pos, nb_cap)].set(
        flat_bids, mode="drop"
    )
    block_vid = pool.block_vid.at[
        jnp.where(flat_do, flat_bids, nb_cap)
    ].set(-1, mode="drop")
    dirty = pool.dirty.at[
        jnp.where(flat_do, flat_bids, nb_cap)
    ].set(True, mode="drop")
    row = jnp.where(enable, safe, pool.num_postings_cap)
    posting_blocks = pool.posting_blocks.at[row].set(-1, mode="drop")
    posting_len = pool.posting_len.at[row].set(0, mode="drop")
    post_scale = pool.post_scale.at[row].set(1.0, mode="drop")
    post_zero = pool.post_zero.at[row].set(0.0, mode="drop")
    return pool.replace(
        free_stack=free_stack,
        free_top=pool.free_top + jnp.sum(flat_do),
        block_vid=block_vid,
        posting_blocks=posting_blocks,
        posting_len=posting_len,
        dirty=dirty,
        post_scale=post_scale,
        post_zero=post_zero,
    )


def put_postings(
    pool: BlockPool,
    pids: Array,
    vecs: Array,
    vids: Array,
    vers: Array,
    ns: Array,
    enable: Array,
) -> tuple[BlockPool, Array]:
    """Batched `put_posting`: bulk-write ``k`` DISTINCT postings in ONE
    scatter — the maintenance round's half-writes and GC write-backs.

    ``vecs (k, cap, d)`` / ``vids`` / ``vers (k, cap)`` are fixed-capacity
    buffers; row ``j`` writes its first ``ns[j]`` entries.  Per row the
    semantics match `put_posting`: old blocks freed first, ``ceil(n/BS)``
    fresh blocks allocated (LIFO from the shared stack), payload written,
    length set.  Allocation is first-come: once cumulative demand exceeds
    the free pool, that row and all later enabled rows fail (``ok=False``,
    posting left empty — same observable outcome as `put_posting` under
    pool OOM; the drain loop retries next round).
    """
    k, cap, _ = vecs.shape
    assert cap == pool.posting_capacity, (cap, pool.posting_capacity)
    mb, bs = pool.max_blocks_per_posting, pool.block_size
    nb_cap = pool.num_blocks_cap

    enable = enable & (pids >= 0)
    safe = jnp.maximum(pids, 0)
    pool = free_postings(pool, pids, enable)

    need = jnp.where(enable, (ns + bs - 1) // bs, 0)     # (k,)
    ok = enable & (jnp.cumsum(need) <= pool.free_top)
    used = jnp.where(ok, need, 0)
    off = jnp.cumsum(used) - used                        # exclusive

    i_idx = jnp.arange(mb, dtype=jnp.int32)[None, :]     # (1, MB)
    in_use = ok[:, None] & (i_idx < need[:, None])       # (k, MB)
    pos = pool.free_top - 1 - (off[:, None] + i_idx)     # LIFO pop order
    bids = jnp.where(
        in_use, pool.free_stack[jnp.clip(pos, 0, nb_cap - 1)], -1
    )

    # PUT retrains each posting's quant params from the rows it writes.
    row_valid = (
        jnp.arange(cap, dtype=jnp.int32)[None, :] < ns[:, None]
    )                                                    # (k, cap)
    scale, zero = pc.train_scale_zero(vecs, row_valid)   # (k,)
    enc = _encode_rows(pool, vecs, scale[:, None, None], zero[:, None, None])
    vecs_b = enc.reshape(k, mb, bs, -1)
    vids_b = vids.reshape(k, mb, bs)
    vers_b = vers.reshape(k, mb, bs)
    in_range = (
        i_idx[..., None] * bs + jnp.arange(bs, dtype=jnp.int32)[None, None, :]
    ) < ns[:, None, None]                                # (k, MB, BS)
    tgt = jnp.where(in_use, bids, nb_cap).reshape(-1)
    blocks = pool.blocks.at[tgt].set(
        vecs_b.reshape(k * mb, bs, -1), mode="drop"
    )
    blocks_exact = pool.blocks_exact
    if blocks_exact is not None:
        blocks_exact = blocks_exact.at[tgt].set(
            vecs.astype(jnp.float32).reshape(k * mb, bs, -1), mode="drop"
        )
    block_vid = pool.block_vid.at[tgt].set(
        jnp.where(in_range, vids_b, -1).reshape(k * mb, bs), mode="drop"
    )
    block_ver = pool.block_ver.at[tgt].set(
        jnp.where(in_range, vers_b, jnp.uint8(0)).reshape(k * mb, bs),
        mode="drop",
    )

    row = jnp.where(ok, safe, pool.num_postings_cap)
    posting_blocks = pool.posting_blocks.at[
        jnp.broadcast_to(row[:, None], (k, mb)),
        jnp.broadcast_to(i_idx, (k, mb)),
    ].set(bids, mode="drop")
    posting_len = pool.posting_len.at[row].set(
        ns.astype(jnp.int32), mode="drop"
    )
    post_scale = pool.post_scale.at[row].set(scale, mode="drop")
    post_zero = pool.post_zero.at[row].set(zero, mode="drop")
    dirty = pool.dirty.at[tgt].set(True, mode="drop")
    return (
        pool.replace(
            blocks=blocks,
            blocks_exact=blocks_exact,
            block_vid=block_vid,
            block_ver=block_ver,
            posting_blocks=posting_blocks,
            posting_len=posting_len,
            free_top=pool.free_top - jnp.sum(used),
            dirty=dirty,
            post_scale=post_scale,
            post_zero=post_zero,
        ),
        ok,
    )


def put_posting(
    pool: BlockPool,
    pid: Array,
    vecs: Array,
    vids: Array,
    vers: Array,
    n: Array,
    enable: Array,
) -> tuple[BlockPool, Array]:
    """Bulk-write a posting (paper PUT): free old blocks, allocate
    ``ceil(n/BS)`` fresh ones, write payload, set length.

    ``vecs (cap, d)`` etc. are fixed-capacity buffers; only the first ``n``
    entries are meaningful.  Returns (pool, ok).
    """
    cap = vecs.shape[0]
    assert cap == pool.posting_capacity, (cap, pool.posting_capacity)
    pool = free_posting(pool, pid, enable)
    n_blocks_needed = (n + pool.block_size - 1) // pool.block_size
    have = pool.free_top >= n_blocks_needed
    ok = enable & have

    bs = pool.block_size
    row_valid = jnp.arange(cap, dtype=jnp.int32) < n
    scale, zero = pc.train_scale_zero(vecs, row_valid)
    enc = _encode_rows(pool, vecs, scale, zero)
    exact = vecs.astype(jnp.float32)
    enc = enc.reshape(pool.max_blocks_per_posting, bs, -1)
    exact = exact.reshape(pool.max_blocks_per_posting, bs, -1)
    vids = vids.reshape(pool.max_blocks_per_posting, bs)
    vers = vers.reshape(pool.max_blocks_per_posting, bs)

    def step(carry, i):
        pool = carry

        def write(pool):
            pool2, bid = _alloc_block(pool)
            safe = jnp.maximum(bid, 0)
            slot_idx = jnp.arange(bs, dtype=jnp.int32)
            in_range = (i * bs + slot_idx) < n
            blocks = pool2.blocks.at[safe].set(
                jnp.where(in_range[:, None], enc[i], pool2.blocks[safe])
            )
            blocks_exact = pool2.blocks_exact
            if blocks_exact is not None:
                blocks_exact = blocks_exact.at[safe].set(
                    jnp.where(in_range[:, None], exact[i], blocks_exact[safe])
                )
            block_vid = pool2.block_vid.at[safe].set(
                jnp.where(in_range, vids[i], -1)
            )
            block_ver = pool2.block_ver.at[safe].set(
                jnp.where(in_range, vers[i], 0)
            )
            posting_blocks = pool2.posting_blocks.at[pid, i].set(bid)
            return pool2.replace(
                blocks=blocks,
                blocks_exact=blocks_exact,
                block_vid=block_vid,
                block_ver=block_ver,
                posting_blocks=posting_blocks,
                dirty=pool2.dirty.at[safe].set(True),
            )

        pool = jax.lax.cond(ok & (i < n_blocks_needed), write, lambda p: p, pool)
        return pool, ()

    pool, _ = jax.lax.scan(
        step, pool, jnp.arange(pool.max_blocks_per_posting, dtype=jnp.int32)
    )
    posting_len = jnp.where(
        ok, pool.posting_len.at[pid].set(n.astype(jnp.int32)), pool.posting_len
    )
    post_scale = jnp.where(
        ok, pool.post_scale.at[pid].set(scale), pool.post_scale
    )
    post_zero = jnp.where(
        ok, pool.post_zero.at[pid].set(zero), pool.post_zero
    )
    return (
        pool.replace(
            posting_len=posting_len,
            post_scale=post_scale,
            post_zero=post_zero,
        ),
        ok,
    )


def used_blocks(pool: BlockPool) -> Array:
    """Number of allocated blocks (for resource accounting, paper Fig. 7d)."""
    return pool.num_blocks_cap - pool.free_top
