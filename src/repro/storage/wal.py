"""Write-ahead log — paper §4.4 crash recovery (WAL half).

All update requests between two snapshots are appended to the WAL; recovery
replays the WAL on top of the latest snapshot.  Records are length-prefixed
msgpack blobs with numpy payloads, fsync'd per batch (the paper's durability
point is the SSD write; ours is the fsync).
"""
from __future__ import annotations

import io
import os
import struct
from dataclasses import dataclass
from typing import Iterator

import msgpack
import numpy as np

_MAGIC = b"SPFW"
_HEADER = struct.Struct("<4sI")  # magic, payload length


@dataclass
class WalRecord:
    op: str                      # "insert" | "delete"
    payload: dict[str, np.ndarray]
    seqno: int


def _encode(rec: WalRecord) -> bytes:
    arrays = {}
    for k, v in rec.payload.items():
        buf = io.BytesIO()
        np.save(buf, np.asarray(v), allow_pickle=False)
        arrays[k] = buf.getvalue()
    body = msgpack.packb(
        {"op": rec.op, "seqno": rec.seqno, "arrays": arrays},
        use_bin_type=True,
    )
    return _HEADER.pack(_MAGIC, len(body)) + body


def _decode(body: bytes) -> WalRecord:
    obj = msgpack.unpackb(body, raw=False)
    payload = {
        k: np.load(io.BytesIO(v), allow_pickle=False)
        for k, v in obj["arrays"].items()
    }
    return WalRecord(op=obj["op"], payload=payload, seqno=obj["seqno"])


class WriteAheadLog:
    """Append-only log; one per index shard."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._fh = open(path, "ab")
        self._seqno = self._scan_last_seqno()

    def _scan_last_seqno(self) -> int:
        last = -1
        for rec in iter_wal(self.path):
            last = rec.seqno
        return last

    @property
    def next_seqno(self) -> int:
        return self._seqno + 1

    def append(self, op: str, payload: dict[str, np.ndarray]) -> int:
        self._seqno += 1
        rec = WalRecord(op=op, payload=payload, seqno=self._seqno)
        self._fh.write(_encode(rec))
        self._fh.flush()
        os.fsync(self._fh.fileno())
        return self._seqno

    def truncate(self) -> None:
        """Called after a successful snapshot: the log restarts empty."""
        self._fh.close()
        self._fh = open(self.path, "wb")
        self._fh.flush()

    def close(self) -> None:
        self._fh.close()


def iter_wal(path: str, after_seqno: int = -1) -> Iterator[WalRecord]:
    """Replay iterator.  Tolerates a torn tail record (crash mid-append)."""
    if not os.path.exists(path):
        return
    with open(path, "rb") as fh:
        while True:
            head = fh.read(_HEADER.size)
            if len(head) < _HEADER.size:
                return
            magic, length = _HEADER.unpack(head)
            if magic != _MAGIC:
                return  # corrupt tail
            body = fh.read(length)
            if len(body) < length:
                return  # torn write
            rec = _decode(body)
            if rec.seqno > after_seqno:
                yield rec
