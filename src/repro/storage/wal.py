"""Write-ahead log — paper §4.4 crash recovery (WAL half).

All update requests between two snapshots are appended to the WAL; recovery
replays the WAL on top of the latest snapshot.  Records are length-prefixed
msgpack blobs with numpy payloads, fsync'd on every ``append`` (the paper's
durability point is the SSD write; ours is the fsync — a record is
acknowledged only after ``os.fsync`` returns).

Group commit relaxes the per-append fsync without moving the ack point:
with a ``(group_commit_n, group_commit_ms)`` window set, ``append`` only
buffers (write + flush) and the fsync fires when the window fills, ages
out, or a caller forces ``sync()``.  Because the log is append-only, one
fsync covers every buffered record before it — a crash can only lose a
contiguous UNSYNCED tail, so the service acks a dispatch after the next
``sync()`` and replay determinism is preserved (the durable stream is
always a prefix of the dispatched stream).

``compact_wal_records`` is the replay-side compaction: insert rows whose
vids are deleted later in the same stream are masked out (and fully-dead
dispatch records dropped) before replay — the deletes themselves are kept
because they must still kill snapshot-resident versions.

Corruption policy: a *torn tail* (crash mid-append: short header, short
body, or garbage bytes where the final record should be — a multi-page
append may persist later pages without the first) is tolerated and treated
as "the last op was never acknowledged".  A bad-magic header FOLLOWED by a
complete decodable record is mid-file corruption of acknowledged data and
raises :class:`WalCorruptionError` instead of silently truncating the log
there.

``WalSet`` is the sharded form: one log file per index shard (in a real
deployment each shard node fsyncs its own device).  Updates in this repro
are deterministically replicated to every shard, so the per-shard logs are
replicas of one global dispatch stream; recovery takes the longest cleanly-
readable log as authoritative and re-syncs the laggards.
"""
from __future__ import annotations

import io
import os
import struct
import time
from dataclasses import dataclass
from typing import Iterator

import msgpack
import numpy as np

_MAGIC = b"SPFW"
_HEADER = struct.Struct("<4sI")  # magic, payload length


class WalCorruptionError(RuntimeError):
    """Mid-file WAL corruption (bad magic on a fully-written header)."""


@dataclass
class WalRecord:
    op: str                      # "insert" | "delete" | "maintain" | "drain"
    payload: dict[str, np.ndarray]
    seqno: int


def _encode(rec: WalRecord) -> bytes:
    arrays = {}
    for k, v in rec.payload.items():
        buf = io.BytesIO()
        np.save(buf, np.asarray(v), allow_pickle=False)
        arrays[k] = buf.getvalue()
    body = msgpack.packb(
        {"op": rec.op, "seqno": rec.seqno, "arrays": arrays},
        use_bin_type=True,
    )
    return _HEADER.pack(_MAGIC, len(body)) + body


def _decode(body: bytes) -> WalRecord:
    obj = msgpack.unpackb(body, raw=False)
    payload = {
        k: np.load(io.BytesIO(v), allow_pickle=False)
        for k, v in obj["arrays"].items()
    }
    return WalRecord(op=obj["op"], payload=payload, seqno=obj["seqno"])


class WriteAheadLog:
    """Append-only log; one per index shard."""

    def __init__(self, path: str, tail: tuple[int, int] | None = None):
        """``tail`` = precomputed ``(last seqno, clean end offset)`` from
        a caller that already scanned the file (WalSet's salvage pass) —
        skips the open-time rescan."""
        self.path = path
        self.n_fsyncs = 0
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._seqno, clean_end = tail if tail is not None else self._scan_tail()
        if os.path.exists(path) and os.path.getsize(path) > clean_end:
            # Trim a torn tail so new appends don't land after garbage
            # (the reader stops at the tear and would lose them).
            with open(path, "r+b") as fh:
                fh.truncate(clean_end)
                fh.flush()
                os.fsync(fh.fileno())
        self._fh = open(path, "ab")

    def _scan_tail(self) -> tuple[int, int]:
        """(last seqno, byte offset of the end of the last clean record)."""
        last, end = -1, 0
        for rec, rec_end in _scan_records(self.path):
            last, end = rec.seqno, rec_end
        return last, end

    @property
    def next_seqno(self) -> int:
        return self._seqno + 1

    def append(self, op: str, payload: dict[str, np.ndarray]) -> int:
        self._seqno += 1
        rec = WalRecord(op=op, payload=payload, seqno=self._seqno)
        self.append_encoded(_encode(rec))
        return self._seqno

    def append_encoded(self, blob: bytes, *, sync: bool = True) -> None:
        """Durability point: the append is acknowledged only post-fsync.
        ``sync=False`` (group commit) defers the fsync to a later
        ``sync()`` — the record is written + flushed but NOT durable yet."""
        self._fh.write(blob)
        self._fh.flush()
        if sync:
            self.sync()

    def sync(self) -> None:
        """fsync the log file (the group-commit window boundary)."""
        os.fsync(self._fh.fileno())
        self.n_fsyncs += 1

    def truncate(self) -> None:
        """Called after a successful snapshot: the log restarts empty.
        Seqnos keep counting (they are global, not per-file offsets)."""
        self._fh.close()
        self._fh = open(self.path, "wb")
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def rewrite(self, records: list[WalRecord]) -> None:
        """Replace the file contents with ``records`` (recovery re-sync of
        a lagging shard log to the authoritative stream)."""
        self._fh.close()
        _rewrite_log_file(self.path, records)
        self._seqno = records[-1].seqno if records else -1
        self._fh = open(self.path, "ab")

    def close(self) -> None:
        self._fh.close()


def _rest_holds_complete_record(blob: bytes) -> bool:
    """True if ``blob`` (bytes from a bad header onward) contains at
    least one complete, decodable record — i.e. the damage sits in FRONT
    of acknowledged data (corruption), not at the tail (a torn append)."""
    idx = blob.find(_MAGIC, 1)
    while idx != -1:
        if idx + _HEADER.size <= len(blob):
            _, length = _HEADER.unpack_from(blob, idx)
            if idx + _HEADER.size + length <= len(blob):
                try:
                    _decode(blob[idx + _HEADER.size:
                                 idx + _HEADER.size + length])
                    return True
                except Exception:
                    pass
        idx = blob.find(_MAGIC, idx + 1)
    return False


def _scan_records(path: str) -> Iterator[tuple[WalRecord, int]]:
    """Yield ``(record, end_offset)`` up to the first tear.  Raises
    :class:`WalCorruptionError` only when damage precedes a complete
    record (see module docstring for the torn-tail/corruption policy)."""
    if not os.path.exists(path):
        return
    with open(path, "rb") as fh:
        while True:
            head = fh.read(_HEADER.size)
            if len(head) < _HEADER.size:
                return  # EOF or torn header
            magic, length = _HEADER.unpack(head)
            if magic != _MAGIC:
                pos = fh.tell() - _HEADER.size
                if _rest_holds_complete_record(head + fh.read()):
                    raise WalCorruptionError(
                        f"{path}: bad record magic {magic!r} at offset "
                        f"{pos} with intact records after it"
                    )
                return  # garbage at the tail: a torn multi-page append
            body = fh.read(length)
            if len(body) < length:
                return  # torn write
            yield _decode(body), fh.tell()


def iter_wal(path: str, after_seqno: int = -1) -> Iterator[WalRecord]:
    """Replay iterator.  Tolerates a torn tail record (crash mid-append);
    raises :class:`WalCorruptionError` on mid-file damage."""
    for rec, _end in _scan_records(path):
        if rec.seqno > after_seqno:
            yield rec


def _salvage_scan(path: str) -> tuple[list[WalRecord], int, bool]:
    """``(records, clean end offset, corrupt)`` up to the first tear OR
    corruption; the flag is True only for mid-file corruption (a torn
    tail is normal crash debris)."""
    recs: list[WalRecord] = []
    end = 0
    try:
        for rec, rec_end in _scan_records(path):
            recs.append(rec)
            end = rec_end
        return recs, end, False
    except WalCorruptionError:
        return recs, end, True


def _rewrite_log_file(path: str, records: list[WalRecord]) -> None:
    with open(path, "wb") as fh:
        for rec in records:
            fh.write(_encode(rec))
        fh.flush()
        os.fsync(fh.fileno())


class WalSet:
    """Per-shard WALs behind one append/replay surface.

    ``append`` encodes the record once and fsyncs it into every shard's
    log (this repro's sharded backend replicates every update dispatch to
    all shards, so each shard's log is exactly what that shard needs to
    replay).  ``recover_records`` scans all logs, takes the one with the
    longest cleanly-readable prefix as authoritative (a crash can tear
    different logs at different records), re-syncs the laggards, and
    returns the authoritative record list.

    ``set_group_commit(n, ms)`` arms the group-commit window: appends
    buffer (write + flush, no fsync) until ``n`` records are pending or
    the oldest pending record is ``ms`` old, then one ``sync()`` round
    fsyncs every shard log.  ``pending`` counts buffered-but-not-durable
    records; the service forces ``sync()`` before acknowledging updates.
    """

    def __init__(self, wal_dir: str, n_shards: int):
        self.wal_dir = wal_dir
        self.n_shards = n_shards
        self.n_appends = 0
        self.group_n = 0            # 0/1 = fsync every append (legacy)
        self.group_ms = 0.0         # 0 = no age-out, count/force only
        self._pending = 0
        self._pending_since = 0.0
        os.makedirs(wal_dir, exist_ok=True)
        # Salvage pass: a mid-file-corrupt shard log is repaired from the
        # longest readable stream (the logs are replicas) instead of
        # bricking recovery.  Only if EVERY log is corrupt do we raise —
        # and then before rewriting anything, so the evidence survives.
        streams: list[list[WalRecord]] = []
        ends: list[int] = []
        corrupt: list[int] = []
        for i in range(n_shards):
            recs, end, bad = _salvage_scan(self.shard_path(i))
            streams.append(recs)
            ends.append(end)
            if bad:
                corrupt.append(i)
        if corrupt and len(corrupt) == n_shards:
            raise WalCorruptionError(
                f"{wal_dir}: all {n_shards} shard logs are corrupt "
                "(no clean replica to resync from)"
            )
        if corrupt:
            best = max(streams,
                       key=lambda recs: recs[-1].seqno if recs else -1)
            for i in corrupt:
                _rewrite_log_file(self.shard_path(i), best)
                streams[i] = list(best)
                ends[i] = os.path.getsize(self.shard_path(i))
        self.logs = [
            # the salvage pass already found each tail: no rescan
            WriteAheadLog(
                self.shard_path(i),
                tail=(streams[i][-1].seqno if streams[i] else -1, ends[i]),
            )
            for i in range(n_shards)
        ]
        # recover_records reuses this boot-time scan (one decode pass
        # over the recovery-critical path); invalidated by any append.
        self._boot_streams: list[list[WalRecord]] | None = streams

    def shard_path(self, shard: int) -> str:
        return os.path.join(self.wal_dir, f"shard_{shard:03d}.wal")

    @property
    def next_seqno(self) -> int:
        return max(log.next_seqno for log in self.logs)

    def last_seqnos(self) -> list[int]:
        """Last durable seqno per shard log (the snapshot manifest entry)."""
        return [log.next_seqno - 1 for log in self.logs]

    def set_group_commit(self, n: int, ms: float = 0.0) -> None:
        """Arm (n>1) or disarm (n<=1) the group-commit window."""
        self.group_n = int(n)
        self.group_ms = float(ms)

    @property
    def grouped(self) -> bool:
        return self.group_n > 1

    @property
    def pending(self) -> int:
        """Records written but not yet covered by an fsync."""
        return self._pending

    @property
    def n_fsyncs(self) -> int:
        """Total os.fsync calls across the shard logs' append/sync path."""
        return sum(log.n_fsyncs for log in self.logs)

    def append(self, op: str, payload: dict[str, np.ndarray]) -> int:
        seqno = self.next_seqno
        blob = _encode(WalRecord(op=op, payload=payload, seqno=seqno))
        self._boot_streams = None
        self.n_appends += 1
        for log in self.logs:
            log._seqno = seqno
            log.append_encoded(blob, sync=not self.grouped)
        if self.grouped:
            if self._pending == 0:
                self._pending_since = time.monotonic()
            self._pending += 1
            aged = (
                self.group_ms > 0
                and (time.monotonic() - self._pending_since) * 1e3
                >= self.group_ms
            )
            if self._pending >= self.group_n or aged:
                self.sync()
        return seqno

    def sync(self) -> None:
        """Force the group-commit window closed: one fsync round over all
        shard logs; every previously buffered record becomes durable (the
        ack point for the dispatches it covers).  No-op when clean."""
        if self._pending == 0:
            return
        for log in self.logs:
            log.sync()
        self._pending = 0

    def recover_records(self) -> list[WalRecord]:
        """Authoritative post-crash record stream (see class docstring)."""
        if self._boot_streams is not None:
            per_shard = self._boot_streams
        else:
            per_shard = [
                list(iter_wal(self.shard_path(i)))
                for i in range(self.n_shards)
            ]
        best = max(per_shard, key=lambda recs: recs[-1].seqno if recs else -1)
        for i, recs in enumerate(per_shard):
            have = recs[-1].seqno if recs else -1
            want = best[-1].seqno if best else -1
            if have != want:
                self.logs[i].rewrite(best)
        for log in self.logs:
            log._seqno = best[-1].seqno if best else -1
        return best

    def stats(self) -> dict:
        return {
            "appends": self.n_appends,
            "fsyncs": self.n_fsyncs,
            "pending": self._pending,
            "fsyncs_per_append": (
                self.n_fsyncs / self.n_appends if self.n_appends else 0.0
            ),
        }

    def ensure_seqno_floor(self, seqno: int) -> None:
        """Never hand out a seqno ≤ ``seqno`` again.  Recovery calls this
        with the snapshot's stamped seqno: the checkpoint truncated the
        logs, so a post-crash scan alone would restart numbering below
        the manifest and the NEXT recovery would skip those acknowledged
        records as already-applied."""
        for log in self.logs:
            log._seqno = max(log._seqno, seqno)

    def truncate(self) -> None:
        self._boot_streams = None
        self._pending = 0          # truncation supersedes buffered records
        for log in self.logs:
            log.truncate()

    def close(self) -> None:
        self.sync()                # buffered records stay durable
        for log in self.logs:
            log.close()


# ---------------------------------------------------------------------------
# Replay-side compaction
# ---------------------------------------------------------------------------

def compact_wal_records(
    records: list[WalRecord],
) -> tuple[list[WalRecord], int]:
    """Mask insert rows whose vid is deleted later in ``records`` (and
    drop dispatch records with no surviving rows); returns the compacted
    stream and the number of rows dropped.

    Only dispatch-level LOCAL records participate (insert payloads with
    caller ``vids`` + ``valid`` masks); delete records are always kept —
    they must still kill versions resident in the snapshot the stream
    replays over.  Sharded streams (handle-assigning inserts without
    ``vids``) pass through untouched.

    Compaction preserves the recovered LIVE SET and the version map of
    every surviving vid exactly; it does NOT preserve the physical block
    layout bit-for-bit (a netted insert+delete pair's stale rows never
    land), so it is an opt-in recovery-speed knob
    (``DurabilitySpec.compact_wal``) rather than the default path.
    """
    last_del: dict[int, int] = {}
    for t, rec in enumerate(records):
        if rec.op == "delete" and "vids" in rec.payload:
            vids = np.asarray(rec.payload["vids"]).reshape(-1)
            valid = rec.payload.get("valid")
            mask = (np.ones(vids.shape[0], bool) if valid is None
                    else np.asarray(valid, bool).reshape(-1))
            for v in vids[mask & (vids >= 0)].tolist():
                last_del[int(v)] = t
    if not last_del:
        return list(records), 0
    out: list[WalRecord] = []
    dropped = 0
    for t, rec in enumerate(records):
        if (rec.op == "insert" and "vids" in rec.payload
                and "valid" in rec.payload):
            vids = np.asarray(rec.payload["vids"]).reshape(-1)
            mask = np.asarray(rec.payload["valid"], bool).reshape(-1)
            dead = mask & np.asarray(
                [last_del.get(int(v), -1) > t for v in vids]
            )
            if dead.any():
                dropped += int(dead.sum())
                mask = mask & ~dead
                if not mask.any():
                    continue           # the whole dispatch is dead rows
                payload = dict(rec.payload)
                payload["valid"] = mask
                rec = WalRecord(op=rec.op, payload=payload, seqno=rec.seqno)
        out.append(rec)
    return out, dropped
