"""DurableBackend — the one durability lifecycle both index backends mix
in (paper §4.4 promoted into the `IndexBackend` protocol).

The lifecycle invariants live HERE exactly once: the not-while-replaying
WAL logging guard, applied-seqno bookkeeping, checkpoint = snapshot
(stamping per-shard ``wal_seqnos`` + the replay-critical ``lire_config``)
then WAL truncate, and the replay loop that re-applies a dispatch stream
through the subclass's ``_apply_record``.  Backends supply only what
differs: the state pytree to snapshot, manifest extras, the per-op
dispatch arms, and the shard count.

Checkpoints go through :class:`~repro.storage.snapshot.SnapshotStore`:
``checkpoint(dir)`` writes a full **base** unit (which is also the chain
compaction — the in-memory state already equals base + deltas + dirty
tail, so folding is a fresh full write that prunes the old chain), while
``checkpoint(dir, delta=True)`` writes a **delta** unit holding only the
blocks the pool's dirty bitmap marked since the previous unit, one file
per shard.  Either way the backend's in-memory state is swapped for the
dirty-cleared twin afterwards, so the next delta starts from a clean
ledger, and the WALs restart empty only after the unit commits.
"""
from __future__ import annotations

import dataclasses

from repro.storage.blockpool import clear_dirty
from repro.storage.snapshot import SnapshotStore


class DurableBackend:
    """Mixin for backends with dispatch-level WAL + snapshot recovery.

    Subclass hooks:
      * ``_snapshot_state()``  — the pytree the checkpoint serializes
      * ``_set_snapshot_state(state)`` — install the dirty-cleared state
      * ``_snapshot_extra()``  — backend-specific manifest fields
      * ``_apply_record(rec)`` — re-run one WAL dispatch (replay arms)
      * ``_wal_shards``        — logs in the WalSet (1 for local)
      * ``_lire_config()``     — config stamped into the manifest
    """

    wal_set = None
    _wal_applied = -1
    _replaying = False
    _repl_sink = None

    # ------------------------- subclass hooks --------------------------
    def _snapshot_state(self):
        raise NotImplementedError

    def _set_snapshot_state(self, state) -> None:
        raise NotImplementedError

    def _snapshot_extra(self) -> dict:
        return {}

    def _apply_record(self, rec) -> None:
        raise NotImplementedError

    def _lire_config(self):
        raise NotImplementedError

    @property
    def _wal_shards(self) -> int:
        return 1

    # ------------------------- the lifecycle ---------------------------
    def _log(self, op: str, payload: dict) -> None:
        if self._replaying:
            return
        if self.wal_set is not None:
            self._wal_applied = self.wal_set.append(op, payload)
        if self._repl_sink is not None:
            if self.wal_set is None:
                # Ephemeral service: no durable log, but replicas still
                # need a contiguous dispatch stream — mint local seqnos.
                self._wal_applied += 1
            self._repl_sink.publish(self._wal_applied, op, payload)

    def attach_replication(self, sink) -> None:
        """``sink.publish(seqno, op, payload)`` is called for every logged
        update dispatch, AFTER the WAL append assigns its seqno (so a
        published record is already durable when durability is on).  The
        sink must be cheap and non-blocking: it runs on the serialized
        pump thread, upstream of the ack point."""
        self._repl_sink = sink

    def attach_durability(self, wal_set, applied_seqno: int | None = None,
                          ) -> None:
        """``applied_seqno`` is the seqno this backend's state already
        reflects — the snapshot manifest stamp on recovery.  The default
        (last durable record) is ONLY correct when the state genuinely
        includes everything on disk (a fresh build about to checkpoint);
        recovery paths must pass the stamp or a later checkpoint would
        mark the unreplayed tail as applied."""
        assert wal_set.n_shards == self._wal_shards, (
            wal_set.n_shards, self._wal_shards,
        )
        self.wal_set = wal_set
        self._wal_applied = (
            applied_seqno if applied_seqno is not None
            else wal_set.next_seqno - 1
        )

    def wal_seqnos(self) -> list[int]:
        """Applied WAL seqno per shard (the snapshot manifest entry).
        The snapshot is one atomic commit, so shards advance together."""
        return [self._wal_applied] * self._wal_shards

    def wal_sync(self) -> None:
        """Force any group-commit-buffered WAL records durable — the ack
        point the service crosses before returning an update."""
        if self.wal_set is not None:
            self.wal_set.sync()

    def checkpoint(self, snapshot_dir: str, *, delta: bool = False) -> None:
        """Atomic snapshot unit stamping the applied WAL seqnos and the
        replay-critical config; the WALs restart empty only after the
        unit commit.  ``delta=True`` writes an incremental unit (dirty
        blocks + non-block leaves, per shard) chained onto the store's
        head; it silently promotes to a full base when no chain exists
        yet.  Afterwards the in-memory state is the dirty-cleared twin."""
        if self.wal_set is not None:
            self.wal_set.sync()    # buffered records precede the stamp
        store = SnapshotStore(snapshot_dir)
        state = self._snapshot_state()
        cleared = state.replace(pool=clear_dirty(state.pool))
        extra = {
            "wal_seqnos": self.wal_seqnos(),
            "lire_config": dataclasses.asdict(self._lire_config()),
            **self._snapshot_extra(),
        }
        if delta and store.has_base():
            store.save_delta(state, n_shards=self._wal_shards, extra=extra)
        else:
            store.save_base(cleared, extra=extra)
        self._set_snapshot_state(cleared)
        if self.wal_set is not None:
            self.wal_set.truncate()

    def replay(self, records, after_seqno: int = -1) -> int:
        """Re-apply a WAL dispatch stream through the backend's own
        jitted entry points; returns how many records were applied."""
        n = 0
        self._replaying = True
        try:
            for rec in records:
                if rec.seqno <= after_seqno:
                    continue
                self._apply_record(rec)
                self._wal_applied = rec.seqno
                n += 1
        finally:
            self._replaying = False
        return n

    def close(self) -> None:
        if self.wal_set is not None:
            self.wal_set.close()


# Geometry/protocol fields that must match between a snapshot and the
# opening spec: they shape the state pytree or change update-dispatch
# semantics, so replay under a different value is undefined.  Every
# LireConfig field is classified here or in REPLAY_EXEMPT_FIELDS below —
# the spflint replay pass (SPF104/105) cross-checks both lists against
# the config class and against every field read reachable from the
# jit-step builders, so a new field cannot ship unclassified.
REPLAY_CRITICAL_FIELDS = (
    "dim", "block_size", "max_blocks_per_posting", "num_blocks",
    "num_postings_cap", "num_vectors_cap", "vector_dtype",
    "split_limit", "merge_limit", "merge_fanout",
    "reassign_range", "reassign_budget", "replica_count", "replica_rng",
    "kmeans_iters", "enable_split", "enable_merge", "enable_reassign",
    # Job SELECTION shapes which postings every logged maintenance round
    # touches, so replaying under a different policy/weighting diverges.
    "maintain_policy", "maintain_alpha", "maintain_beta",
    # The payload codec changes the hot-tier dtype/leaf structure and the
    # rerank factor changes which candidates a logged search would have
    # returned; both are stamped by name so pre-codec snapshots (which
    # never stamped them) still pass.
    "codec", "rerank_factor",
    # Insert/reassign ROUTING runs through `lire.navigate`, whose kernel
    # path (Pallas nav vs XLA oracle, compiled vs interpret) these two
    # select.  The paths are numerically equivalent only up to top-k
    # tie-breaking on equal distances — enough to route a vector to a
    # different posting on replay — so they must match the snapshot.
    # Stamped by name: snapshots from before this stamp never recorded
    # them and still pass.
    "use_pallas_nav", "pallas_interpret",
)

# Serving-side fields a reopened index may change freely: they only
# shape dispatches that are never WAL-logged (searches) or whose logged
# records carry the value they ran with.  Each entry needs a reason —
# the replay pass treats this list as load-bearing, not a dumping
# ground.
REPLAY_EXEMPT_FIELDS = (
    # Search-path only; search dispatches are not WAL-logged.
    "nprobe", "scan_dtype", "use_pallas_scan", "scan_schedule",
    "scan_page_budget",
    # Logged "maintain"/"drain" records carry their own job counts, so
    # replay re-runs the original round shapes regardless of the
    # reopened config's default.
    "jobs_per_round",
)


def check_replay_config(manifest: dict, cfg, *, n_shards: int | None = None,
                        ) -> None:
    """Raise a clear error when a snapshot was written under a different
    replay-critical config than the spec now opening it (e.g. the serve
    launcher re-run with different sizing flags or a different
    ``--shards``) — BEFORE template construction turns the drift into a
    cryptic leaf-shape mismatch."""
    extra = manifest.get("extra", {})
    diffs = []
    if n_shards is not None:
        stamped_shards = extra.get("n_shards", 1)
        if stamped_shards != n_shards:
            diffs.append(
                f"n_shards: snapshot={stamped_shards!r} spec={n_shards!r}"
            )
    stamped = extra.get("lire_config")
    if stamped is None and not diffs:
        return  # pre-stamp snapshot: nothing to validate against
    if stamped is not None:
        now = dataclasses.asdict(cfg)
        diffs += [
            f"{f}: snapshot={stamped[f]!r} spec={now[f]!r}"
            for f in REPLAY_CRITICAL_FIELDS
            if f in stamped and stamped[f] != now[f]
        ]
    if diffs:
        raise ValueError(
            "snapshot was written under a different index config; "
            "recovery must reuse the original geometry/protocol "
            "parameters (re-run with the original sizing flags or point "
            "DurabilitySpec at a fresh root).  Mismatched fields:\n  "
            + "\n  ".join(diffs)
        )
