"""Version map — paper §4.2.1.

One byte per vector id: low 7 bits = reassign version (wraps mod 128), high
bit = deletion label.  A stored replica is *stale* when its written version
differs from the map's current version, or the vector is deleted.  Reassign
bumps the version and appends a fresh replica; stale replicas are filtered at
search time and garbage-collected during splits.

The paper's CAS-on-version concurrency control degenerates to functional
updates here (each jitted step owns the state), but the version semantics —
defer/batch deletes, cheap invalidation of all old replicas — are identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

VERSION_MASK = jnp.uint8(0x7F)
DELETED_BIT = jnp.uint8(0x80)

# The version array reserves its LAST slot as a scratch target: disabled rows
# in a batched update scatter there.  Routing disabled rows to a live index
# (e.g. clip-to-0) is a correctness hazard — XLA scatter with duplicate
# indices has unspecified order, so a disabled row's stale write could
# clobber a real update to vid 0.


def scratch_index(versions: Array) -> int:
    return versions.shape[0] - 1


def _targets(versions: Array, vids: Array, enable: Array | None) -> Array:
    scratch = scratch_index(versions)
    safe = jnp.clip(vids, 0, scratch - 1)
    if enable is None:
        return jnp.where(vids >= 0, safe, scratch)
    return jnp.where(enable & (vids >= 0), safe, scratch)


def current_version(versions: Array, vids: Array) -> Array:
    """Low-7-bit current version for each vid."""
    return versions[jnp.clip(vids, 0, scratch_index(versions) - 1)] & VERSION_MASK


def bump_version(versions: Array, vids: Array, enable: Array | None = None) -> Array:
    """Increment the 7-bit reassign version (mod 128), preserving the
    deletion bit.  Disabled rows write to the scratch slot."""
    idx = _targets(versions, vids, enable)
    cur = versions[idx]
    new = (cur & DELETED_BIT) | ((cur + 1) & VERSION_MASK)
    return versions.at[idx].set(new)


def mark_deleted(versions: Array, vids: Array, enable: Array | None = None) -> Array:
    idx = _targets(versions, vids, enable)
    return versions.at[idx].set(versions[idx] | DELETED_BIT)


def clear(versions: Array, vids: Array, enable: Array | None = None) -> Array:
    """Reset a vid's byte (used when a deleted id slot is recycled)."""
    idx = _targets(versions, vids, enable)
    return versions.at[idx].set(jnp.zeros_like(versions[idx]))


def is_deleted(versions: Array, vids: Array) -> Array:
    return (versions[vids] & DELETED_BIT) != 0


def is_stale(versions: Array, vids: Array, stored_ver: Array) -> Array:
    """True when a stored replica must be ignored (filtered at search)."""
    safe = jnp.maximum(vids, 0)
    cur = versions[safe]
    stale = ((cur & VERSION_MASK) != (stored_ver & VERSION_MASK)) | (
        (cur & DELETED_BIT) != 0
    )
    return stale | (vids < 0)
