"""Storage engine: paged block pool (Block Controller analogue), version map,
write-ahead log, and snapshot/restore (crash recovery, paper §4.3-4.4)."""
from repro.storage.blockpool import BlockPool, make_block_pool  # noqa: F401
from repro.storage.versionmap import (  # noqa: F401
    DELETED_BIT,
    VERSION_MASK,
    bump_version,
    is_deleted,
    is_stale,
    mark_deleted,
)
