"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run process sets
``--xla_force_host_platform_device_count=512`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over the locally available devices (tests/examples)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
