import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell on the production mesh, record memory/cost/collective analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --list
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-20b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --driver --out results/dryrun
        (driver: one subprocess per remaining cell; resumable)

The very first lines above set the 512-device host platform BEFORE any jax
import — jax locks the device count on first init.  Nothing else in the
repo sets this flag (tests and benchmarks see 1 device).
"""
import argparse
import json
import subprocess
import sys
import time
import traceback


def _cell_key(arch: str, shape: str, mesh_kind: str) -> str:
    return f"{arch}__{shape}__{mesh_kind}".replace("/", "_")


def list_cells():
    from repro.configs import all_cells

    rows = []
    for c in all_cells():
        rows.append((c.arch, c.shape, c.family, c.kind, c.skip_reason))
    return rows


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str) -> dict:
    import jax

    from repro.configs import get_cell
    from repro.distributed.sharding import mesh_context
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import collective_bytes, model_flops, roofline_terms

    multi_pod = mesh_kind == "multi"
    cell = get_cell(arch, shape)
    rec: dict = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "family": cell.family, "kind": cell.kind,
        "n_devices": 512 if multi_pod else 256,
        "time": time.strftime("%Y-%m-%d %H:%M:%S"),
    }
    if cell.skip_reason is not None:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell.skip_reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    if multi_pod:
        n_mesh_devices = 512
    else:
        n_mesh_devices = 256
    t0 = time.time()

    def to_shardings(tree):
        from jax.sharding import NamedSharding, PartitionSpec

        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            tree,
            is_leaf=lambda x: isinstance(x, PartitionSpec),
        )

    with mesh_context(mesh):
        if cell.make_mesh_step is not None:
            step, args = cell.make_mesh_step(mesh, multi_pod)
            lowered = step.lower(*args)
        else:
            args = cell.input_specs()
            in_shardings = to_shardings(cell.in_shardings(multi_pod))
            kwargs = {}
            if cell.out_shardings is not None:
                kwargs["out_shardings"] = to_shardings(
                    cell.out_shardings(multi_pod)
                )
            step = jax.jit(cell.step_fn, in_shardings=in_shardings, **kwargs)
            lowered = step.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    # ---- memory analysis (proves it fits) ----
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(ma, "generated_code_size_in_bytes", 0)
            ),
        }
        ma_total = (
            rec["memory_analysis"]["argument_bytes"]
            + rec["memory_analysis"]["output_bytes"]
            + rec["memory_analysis"]["temp_bytes"]
        )
        rec["memory_analysis"]["total_bytes"] = ma_total
        rec["bytes_per_device"] = ma_total  # partitioned module = per-device
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = repr(e)

    # ---- cost analysis (FLOPs / bytes for the roofline) ----
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        flops = float(ca.get("flops", 0.0))
        bytes_accessed = float(ca.get("bytes accessed", 0.0))
        rec["cost_analysis"] = {
            "flops": flops,
            "bytes_accessed": bytes_accessed,
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = repr(e)
        flops, bytes_accessed = 0.0, 0.0

    # ---- collective bytes from the partitioned HLO ----
    try:
        hlo = compiled.as_text()
        cb = collective_bytes(hlo)
        rec["collective_bytes"] = cb
        rec["hlo_collective_counts"] = {
            k: hlo.count(f" {k}(") for k in
            ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
        }
    except Exception as e:  # pragma: no cover
        rec["collective_error"] = repr(e)
        cb = {"total": 0}

    # ---- two-point loop-analysis correction (LM cells) ----
    # XLA's cost analysis counts a lax.scan body ONCE; the layer stack runs
    # n_layers times.  We compile two small UNROLLED variants (L=2, L=4,
    # inner attention un-chunked) of the same cell and extrapolate:
    #   body = (m4 - m2) / 2 ;  outside = m2 - 2*body ;
    #   corrected_L = outside + L * body
    # Validated against a fully-unrolled granite-20b compile (ratio within
    # a few %).  GNN/recsys/index cells have no layer scan → no correction.
    corrected = None
    if cell.family == "lm" and cell.make_for_cfg is not None:
        import dataclasses as _dc

        from repro.configs.common import LM_SHAPES

        seq = LM_SHAPES[cell.shape]["seq"]
        probe_metrics = {}
        for l_probe in (2, 4):
            vcfg = _dc.replace(
                cell.model_cfg, n_layers=l_probe, scan_unroll=l_probe,
                kv_chunk=max(seq, cell.model_cfg.kv_chunk),
            )
            vstep, vspecs, vshard, _, _, vouts = cell.make_for_cfg(vcfg)
            vkwargs = {}
            if vouts is not None:
                vkwargs["out_shardings"] = to_shardings(vouts(multi_pod))
            with mesh_context(mesh):
                vlow = jax.jit(
                    vstep, in_shardings=to_shardings(vshard(multi_pod)),
                    **vkwargs,
                ).lower(*vspecs())
                vcomp = vlow.compile()
            vca = vcomp.cost_analysis()
            if isinstance(vca, (list, tuple)):
                vca = vca[0]
            vcb = collective_bytes(vcomp.as_text())
            probe_metrics[l_probe] = {
                "flops": float(vca.get("flops", 0.0)),
                "bytes": float(vca.get("bytes accessed", 0.0)),
                "coll": float(vcb.get("total", 0)),
            }
        l_full = cell.model_cfg.n_layers
        corrected = {}
        for name, key in (("flops", "flops"), ("bytes", "bytes"),
                          ("coll", "coll")):
            m2 = probe_metrics[2][key]
            m4 = probe_metrics[4][key]
            body = (m4 - m2) / 2.0
            outside = m2 - 2.0 * body
            corrected[name] = max(outside + l_full * body, 0.0)
        rec["analysis_correction"] = {
            "probe_L2": probe_metrics[2], "probe_L4": probe_metrics[4],
            "corrected": corrected,
        }
        flops = max(flops, corrected["flops"])
        bytes_accessed = max(bytes_accessed, corrected["bytes"])
        cb = dict(cb)
        cb["total"] = max(float(cb.get("total", 0)), corrected["coll"])

    # ---- roofline ----
    terms = roofline_terms(
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        collective_bytes_per_device=float(cb.get("total", 0)),
    )
    rec["roofline"] = terms
    mf = model_flops(cell)
    if mf is not None:
        rec["model_flops_global"] = mf
        hlo_flops_global = flops * n_mesh_devices
        rec["model_to_hlo_flops"] = (
            mf / hlo_flops_global if hlo_flops_global else None
        )
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--driver", action="store_true",
                    help="subprocess per remaining cell (resumable)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    if args.list:
        for arch, shape, family, kind, skip in list_cells():
            flag = f"SKIP({skip})" if skip else ""
            print(f"{arch:28s} {shape:16s} {family:8s} {kind:8s} {flag}")
        return

    os.makedirs(args.out, exist_ok=True)

    if args.driver:
        from repro.configs import all_cells

        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        todo = []
        for c in all_cells():
            for mk in meshes:
                key = _cell_key(c.arch, c.shape, mk)
                path = os.path.join(args.out, key + ".json")
                if os.path.exists(path) and not args.force:
                    continue
                todo.append((c.arch, c.shape, mk))
        print(f"driver: {len(todo)} cells to run")
        for i, (arch, shape, mk) in enumerate(todo):
            print(f"[{i + 1}/{len(todo)}] {arch}/{shape} mesh={mk}",
                  flush=True)
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", mk,
                "--out", args.out,
            ]
            try:
                proc = subprocess.run(
                    cmd, timeout=args.timeout, capture_output=True, text=True
                )
                if proc.returncode != 0:
                    key = _cell_key(arch, shape, mk)
                    with open(os.path.join(args.out, key + ".json"), "w") as fh:
                        json.dump({
                            "arch": arch, "shape": shape, "mesh": mk,
                            "status": "error",
                            "stderr": proc.stderr[-4000:],
                        }, fh, indent=2)
                    print(f"   ERROR (recorded): {proc.stderr[-400:]}")
                else:
                    print("   ok")
            except subprocess.TimeoutExpired:
                key = _cell_key(arch, shape, mk)
                with open(os.path.join(args.out, key + ".json"), "w") as fh:
                    json.dump({
                        "arch": arch, "shape": shape, "mesh": mk,
                        "status": "timeout",
                    }, fh, indent=2)
                print("   TIMEOUT (recorded)")
        return

    assert args.arch and args.shape, "--arch and --shape required"
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mk in meshes:
        key = _cell_key(args.arch, args.shape, mk)
        path = os.path.join(args.out, key + ".json")
        if os.path.exists(path) and not args.force:
            print(f"skip existing {path}")
            continue
        try:
            rec = run_cell(args.arch, args.shape, mk, args.out)
        except Exception:
            rec = {
                "arch": args.arch, "shape": args.shape, "mesh": mk,
                "status": "error", "traceback": traceback.format_exc(),
            }
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=2)
        status = rec.get("status")
        print(f"{key}: {status}")
        if status == "ok":
            r = rec["roofline"]
            print(
                f"  compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                f"collective={r['collective_s']:.3e}s dominant={r['dominant']}"
            )
        elif status == "error":
            print(rec.get("traceback", "")[-2000:])
            sys.exit(1)


if __name__ == "__main__":
    main()
