"""Serving launcher: stand up a SPFresh *service* and run a mixed
search/update stream through it (the paper's §5.2 loop).

Everything is driven through the unified service API: the flags compile
into ONE :class:`~repro.api.ServiceSpec` and ``spfresh.open(spec)``
serves a single-host index or an N-shard mesh (fake CPU devices) behind
the same handle — with the durable lifecycle attached when ``--durable``
is set:

    PYTHONPATH=src python -m repro.launch.serve --n 8000 --epochs 10 \
        --dataset spacev --rate 0.01 --policy ratio --ratio 2
    PYTHONPATH=src python -m repro.launch.serve --n 4000 --shards 4
    # durable service: WAL every update, checkpoint every 2000 rows,
    # then kill it and recover:
    PYTHONPATH=src python -m repro.launch.serve --durable /tmp/svc \
        --checkpoint-every 2000
    PYTHONPATH=src python -m repro.launch.serve --durable /tmp/svc --recover
"""
from __future__ import annotations

import argparse
import os

import numpy as np


def _print_report(service) -> None:
    rep = service.report()
    q, m, d = rep["queue"], rep["maintenance"], rep["durability"]
    print(f"policy={m['policy']} maint_slots={m['slots']} "
          f"maint_rounds={m['rounds']} maint_jobs={m['steps']} "
          f"maint_jps={m['steps_per_s']:.1f} "
          f"insert_stall={rep['insert_stall_s'] * 1e3:.0f}ms")
    if rep.get("async"):
        print(f"async: overlap_frac={m.get('overlap_frac', 0.0):.2f} "
              f"idle_slots={m.get('idle_slots', 0)} "
              f"forced={m.get('forced', 0)} "
              f"window_waits={q.get('window_waits', 0)}")
    print(f"queue: batches={q['batches']} rows={q['rows']} "
          f"pad_waste={q['padding_waste_frac']:.3f} "
          f"depth_avg={q['depth_rows_avg']:.0f} depth_max={q['depth_rows_max']}")
    r = rep.get("replicas")
    if r:
        lags = [x["lag"] for x in r["per_replica"]]
        print(f"replicas: n={r['n_replicas']} "
              f"routed={r['routed_batches']} "
              f"fallback={r['fallback_primary']} "
              f"published={r['published']} "
              f"max_lag_seen={max(lags) if lags else 0} "
              f"catchups={sum(x['catchups'] for x in r['per_replica'])}")
    if d["durable"]:
        wal = d.get("wal", {})
        print(f"durability: recovered={d['recovered']} "
              f"wal_seqnos={d['wal_seqnos']} "
              f"since_ckpt={d['updates_since_checkpoint']} "
              f"chain_len={d.get('snapshot_chain_len', 0)} "
              f"fsyncs/dispatch={wal.get('fsyncs_per_append', 1):.2f}")
    for op in ("search", "insert", "delete"):
        p = rep[op]
        if p:
            print(f"{op}: p50={p['p50_ms']:.1f}ms p99={p['p99_ms']:.1f}ms "
                  f"n={p['n']}")


def build_spec(args):
    """Compile the CLI flags into the ONE ServiceSpec (the old launcher
    threaded each knob positionally through LireConfig → EngineConfig →
    backend ctor; every knob now has exactly one home)."""
    import spfresh
    from repro.core.types import LireConfig

    jobs = args.maintain_jobs or args.budget
    cfg = LireConfig(
        dim=args.dim, block_size=8, max_blocks_per_posting=8,
        num_blocks=max(8192, args.n // 2),
        num_postings_cap=max(1024, args.n // 20),
        num_vectors_cap=4 * args.n, split_limit=48, merge_limit=6,
        reassign_range=8, replica_count=2, nprobe=args.nprobe,
    )
    return spfresh.ServiceSpec(
        index=spfresh.IndexSpec(config=cfg),
        serve=spfresh.ServeSpec(
            search_k=10, nprobe=args.nprobe, policy=args.policy,
            fg_bg_ratio=args.ratio, backlog_threshold=args.threshold,
            async_serve=args.async_serve, max_wait_ms=args.max_wait_ms,
            max_lag=args.max_lag,
        ),
        scan=spfresh.ScanSpec(
            probe_chunk=args.probe_chunk,
            use_pallas_scan=None if args.scan == "oracle" else True,
            scan_schedule=None if args.scan == "oracle" else args.scan,
            codec=args.codec,
            rerank_factor=args.rerank_factor,
        ),
        maintenance=spfresh.MaintenanceSpec(
            jobs_per_round=jobs, policy=args.maintain_policy,
        ),
        durability=spfresh.DurabilitySpec(
            root=args.durable, checkpoint_every=args.checkpoint_every,
            delta_every=args.delta_every, compact_every=args.compact_every,
            group_commit=args.group_commit,
            group_commit_ms=args.group_commit_ms,
            compact_wal=args.compact_wal,
        ),
        shards=spfresh.ShardSpec(n_shards=args.shards,
                                 n_replicas=args.replicas),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--rate", type=float, default=0.01)
    ap.add_argument("--dataset", choices=["spacev", "sift"], default="spacev")
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--durable", default=None, metavar="DIR",
                    help="service root: per-shard WAL + snapshot "
                         "checkpoints live under DIR (DurabilitySpec)")
    ap.add_argument("--snapshot", default=None,
                    help="legacy alias of --durable")
    ap.add_argument("--recover", action="store_true",
                    help="open-time recovery: restore the latest snapshot "
                         "under --durable and replay the per-shard WALs "
                         "instead of rebuilding")
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="N",
                    help="auto-checkpoint (FULL snapshot + WAL truncate) "
                         "every N update rows (0 = only at exit)")
    ap.add_argument("--delta-every", type=int, default=0, metavar="N",
                    help="auto-checkpoint a DELTA snapshot (only blocks "
                         "dirtied since the last unit, per shard) every "
                         "N update rows (0 = full snapshots only)")
    ap.add_argument("--compact-every", type=int, default=16, metavar="M",
                    help="fold the delta chain into a fresh base once M "
                         "deltas stack on it (0 = never auto-compact)")
    ap.add_argument("--group-commit", type=int, default=0, metavar="N",
                    help="batch up to N update dispatches per WAL fsync "
                         "(ack still waits for the fsync; 0 = fsync "
                         "every dispatch)")
    ap.add_argument("--group-commit-ms", type=float, default=0.0,
                    help="group-commit window age-out in ms (0 = close "
                         "on count/ack only)")
    ap.add_argument("--compact-wal", action="store_true",
                    help="on --recover, drop insert rows whose vids were "
                         "later deleted before replaying (faster replay; "
                         "local backend)")
    ap.add_argument("--async", dest="async_serve", action="store_true",
                    help="async serving: a dedicated background pump "
                         "thread owns all dispatches; callers enqueue "
                         "and block on per-ticket events, maintenance "
                         "runs in queue-idle gaps, durable updates ack "
                         "after the WAL fsync")
    ap.add_argument("--max-wait-ms", type=float, default=0.0,
                    help="batch-formation window: hold an unfenced head "
                         "run up to this long so micro-batches fill "
                         "toward the top bucket (async mode only; "
                         "0 = dispatch immediately)")
    ap.add_argument("--policy", choices=["ratio", "backlog"], default="ratio")
    ap.add_argument("--ratio", type=int, default=2,
                    help="fg update batches per bg slot (0 disables)")
    ap.add_argument("--budget", type=int, default=8,
                    help="rebuild jobs per bg slot (legacy alias of "
                         "--maintain-jobs)")
    ap.add_argument("--maintain-jobs", type=int, default=None,
                    help="jobs per fused maintenance round (top-K splits "
                         "+ bottom-K merges per slot, one dispatch); "
                         "overrides --budget")
    ap.add_argument("--maintain-policy", choices=["size", "drift"],
                    default=None,
                    help="maintenance job selection: 'size' ranks by "
                         "posting length alone; 'drift' ranks by the "
                         "Ada-IVF-style cost model over per-posting "
                         "access/update/drift telemetry (default: the "
                         "LireConfig default, 'size')")
    ap.add_argument("--threshold", type=int, default=1,
                    help="BacklogPolicy firing threshold")
    ap.add_argument("--shards", type=int, default=1,
                    help=">1: serve an N-shard mesh on fake CPU devices")
    ap.add_argument("--replicas", type=int, default=1,
                    help="total index copies including the primary (>1: "
                         "read replicas fed by the async WAL replication "
                         "stream serve searches; sharded mode needs "
                         "shards*replicas fake devices)")
    ap.add_argument("--max-lag", type=int, default=64,
                    help="replica freshness bound in WAL seqnos: a search "
                         "falls back to the primary rather than land on a "
                         "replica lagging more than this")
    ap.add_argument("--probe-chunk", type=int, default=0,
                    help="oracle scan path: stream probes in chunks")
    ap.add_argument("--scan", choices=["oracle", "per_query", "batched"],
                    default="oracle",
                    help="posting-scan data path (per_query/batched = "
                         "Pallas paged kernels, interpret mode on CPU)")
    ap.add_argument("--codec", choices=["fp32", "bf16", "int8"],
                    default=None,
                    help="hot-tier posting payload codec: int8 stores "
                         "per-posting scale/zero-point and dequantizes "
                         "inside the page scan (~4x fewer scan bytes); "
                         "bf16 halves them; lossy codecs keep a cold "
                         "exact fp32 tier for maintenance + rerank "
                         "(default: the LireConfig default, fp32)")
    ap.add_argument("--rerank-factor", type=int, default=None,
                    help="with a lossy codec: over-fetch N*k candidates "
                         "from the quantized scan and rerank them against "
                         "the exact fp32 tier before the final top-k "
                         "(1 = no rerank; default: LireConfig default)")
    args = ap.parse_args()
    args.durable = args.durable or args.snapshot

    if args.shards > 1:
        # a replicated sharded service lives on a (data=replicas,
        # model=shards) mesh — one fake device per index copy per shard
        n_dev = args.shards * max(args.replicas, 1)
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_dev} "
            + os.environ.get("XLA_FLAGS", "")
        )
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
    if args.recover and not args.durable:
        raise SystemExit("--recover needs --durable DIR")

    import spfresh
    from repro.data import UpdateWorkload

    spec = build_spec(args)
    maker = (UpdateWorkload.spacev if args.dataset == "spacev"
             else UpdateWorkload.sift)
    wl = maker(n=args.n, dim=args.dim, rate=args.rate, seed=0)

    if args.recover:
        service = spfresh.open(spec)
        print(f"recovered service from {args.durable} "
              f"(wal_seqnos={service.backend.wal_seqnos()})")
    else:
        # fresh=True: without --recover the launcher always builds from
        # the workload — an existing durable root is superseded, never
        # silently recovered with the freshly built vectors discarded.
        vecs, _ = wl.live_vectors()
        service = spfresh.open(spec, vectors=vecs, fresh=True)
        if service.durable:
            print(f"durable service at {args.durable} "
                  f"(checkpoint_every={args.checkpoint_every or 'exit-only'})")

    if args.shards > 1:
        # workload vid -> global (shard, slot) handle, kept current so
        # epoch deletes translate into sharded deletes.  After --recover
        # the pre-crash handle map is gone: epoch deletes are skipped and
        # the stream degrades to insert+search traffic.
        vid2h = {}
        if service.initial_handles is not None:
            _, base_ids = wl.live_vectors()
            vid2h = dict(zip(base_ids.tolist(),
                             service.initial_handles.tolist()))
        print(f"serving {args.n} vectors over {args.shards} shards")
        print("epoch  p99_ms postings splits deletes")
        for epoch in range(args.epochs):
            dv, iv, ii = wl.epoch()
            dh = [vid2h.pop(int(v)) for v in dv if int(v) in vid2h]
            service.delete(np.asarray(dh, np.int32))
            # sharded service assigns its own handles
            new_h, landed = service.insert(iv)
            vid2h.update(
                (int(v), int(h))
                for v, h, ok in zip(ii, new_h, landed) if ok
            )
            q, _gt = wl.queries(64)
            service.search(q)
            lat = service.engine.latency_percentiles("search")
            st = service.stats()
            print(f"{epoch:5d} {lat.get('p99_ms', 0):7.1f} "
                  f"{st['n_postings']:8d} {st['n_splits']:6d} "
                  f"{len(dh):7d}")
        service.drain()
        _print_report(service)
        service.close()
        return

    print("epoch recall@10 p99_ms postings splits reassigned")
    for epoch in range(args.epochs):
        dv, iv, ii = wl.epoch()
        service.delete(dv.astype(np.int32))
        service.insert(iv, ii.astype(np.int32))
        q, gt = wl.queries(64)
        _, got = service.search(q)
        hits = sum(len(set(g.tolist()) & set(o.tolist()))
                   for g, o in zip(gt, got))
        lat = service.engine.latency_percentiles("search")
        st = service.stats()
        print(f"{epoch:5d} {hits / (len(q) * 10):9.3f} "
              f"{lat.get('p99_ms', 0):6.1f} {st['n_postings']:8d} "
              f"{st['n_splits']:6d} {st['n_reassigned']:10d}")
    service.drain()
    _print_report(service)
    service.close()
    if service.durable:
        print(f"service checkpointed under {args.durable}")


if __name__ == "__main__":
    main()
