"""Serving launcher: stand up a SPFresh index and run a mixed
search/update stream through the batched ServeEngine pipeline (the
paper's §5.2 loop).  The same engine drives a single-host index or an
N-shard mesh (fake CPU devices) — the tentpole claim, runnable:

    PYTHONPATH=src python -m repro.launch.serve --n 8000 --epochs 10 \
        --dataset spacev --rate 0.01 --policy ratio --ratio 2
    PYTHONPATH=src python -m repro.launch.serve --n 4000 --shards 4
"""
from __future__ import annotations

import argparse
import os

import numpy as np


def _make_policy(args):
    from repro.serve.policy import BacklogPolicy, RatioPolicy

    jobs = args.maintain_jobs or args.budget
    if args.policy == "backlog":
        return BacklogPolicy(threshold=args.threshold, budget=jobs)
    return RatioPolicy(ratio=args.ratio, budget=jobs)


def _print_report(engine) -> None:
    rep = engine.report()
    q, m = rep["queue"], rep["maintenance"]
    print(f"policy={m['policy']} maint_slots={m['slots']} "
          f"maint_rounds={m['rounds']} maint_jobs={m['steps']} "
          f"maint_jps={m['steps_per_s']:.1f} "
          f"insert_stall={rep['insert_stall_s'] * 1e3:.0f}ms")
    print(f"queue: batches={q['batches']} rows={q['rows']} "
          f"pad_waste={q['padding_waste_frac']:.3f} "
          f"depth_avg={q['depth_rows_avg']:.0f} depth_max={q['depth_rows_max']}")
    for op in ("search", "insert", "delete"):
        p = rep[op]
        if p:
            print(f"{op}: p50={p['p50_ms']:.1f}ms p99={p['p99_ms']:.1f}ms "
                  f"n={p['n']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--rate", type=float, default=0.01)
    ap.add_argument("--dataset", choices=["spacev", "sift"], default="spacev")
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--snapshot", default=None)
    ap.add_argument("--policy", choices=["ratio", "backlog"], default="ratio")
    ap.add_argument("--ratio", type=int, default=2,
                    help="fg update batches per bg slot (0 disables)")
    ap.add_argument("--budget", type=int, default=8,
                    help="rebuild jobs per bg slot (legacy alias of "
                         "--maintain-jobs)")
    ap.add_argument("--maintain-jobs", type=int, default=None,
                    help="jobs per fused maintenance round (top-K splits "
                         "+ bottom-K merges per slot, one dispatch); "
                         "overrides --budget")
    ap.add_argument("--threshold", type=int, default=1,
                    help="BacklogPolicy firing threshold")
    ap.add_argument("--shards", type=int, default=1,
                    help=">1: serve an N-shard mesh on fake CPU devices")
    ap.add_argument("--probe-chunk", type=int, default=0,
                    help="oracle scan path: stream probes in chunks")
    ap.add_argument("--scan", choices=["oracle", "per_query", "batched"],
                    default="oracle",
                    help="posting-scan data path (per_query/batched = "
                         "Pallas paged kernels, interpret mode on CPU)")
    args = ap.parse_args()

    if args.shards > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.shards} "
            + os.environ.get("XLA_FLAGS", "")
        )
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro.core import LireConfig, SPFreshIndex
    from repro.data import UpdateWorkload
    from repro.serve.engine import EngineConfig, ServeEngine

    maker = UpdateWorkload.spacev if args.dataset == "spacev" else UpdateWorkload.sift
    wl = maker(n=args.n, dim=args.dim, rate=args.rate, seed=0)
    jobs = args.maintain_jobs or args.budget
    cfg = LireConfig(
        dim=args.dim, block_size=8, max_blocks_per_posting=8,
        num_blocks=max(8192, args.n // 2), num_postings_cap=max(1024, args.n // 20),
        num_vectors_cap=4 * args.n, split_limit=48, merge_limit=6,
        reassign_range=8, replica_count=2, nprobe=args.nprobe,
        jobs_per_round=jobs,
    )
    ecfg = EngineConfig(
        search_k=10, nprobe=args.nprobe, probe_chunk=args.probe_chunk,
        use_pallas_scan=None if args.scan == "oracle" else True,
        scan_schedule=None if args.scan == "oracle" else args.scan,
        maintain_budget=jobs,
    )
    vecs, _ = wl.live_vectors()

    if args.shards > 1:
        import jax

        from repro.distributed.sharded_index import ShardedIndex

        mesh = jax.make_mesh((args.shards,), ("model",))
        backend, handles = ShardedIndex.build(
            mesh, cfg, vecs, args.shards, probe_chunk=args.probe_chunk,
            use_pallas_scan=ecfg.use_pallas_scan,
            scan_schedule=ecfg.scan_schedule,
        )
        engine = ServeEngine(backend, ecfg, policy=_make_policy(args))
        # workload vid -> global (shard, slot) handle, kept current so
        # epoch deletes translate into sharded deletes
        _, base_ids = wl.live_vectors()
        vid2h = dict(zip(base_ids.tolist(), handles.tolist()))
        print(f"serving {args.n} vectors over {args.shards} shards")
        print("epoch  p99_ms postings splits deletes")
        for epoch in range(args.epochs):
            dv, iv, ii = wl.epoch()
            dh = [vid2h.pop(int(v)) for v in dv if int(v) in vid2h]
            engine.delete(np.asarray(dh, np.int32))
            # sharded index assigns its own handles; vids are placeholders
            t = engine.submit_insert(iv, np.full(len(iv), -1, np.int32))
            new_h, landed = t.result()
            vid2h.update(
                (int(v), int(h))
                for v, h, ok in zip(ii, new_h, landed) if ok
            )
            q, _gt = wl.queries(64)
            engine.search(q)
            lat = engine.latency_percentiles("search")
            st = engine.stats()
            print(f"{epoch:5d} {lat.get('p99_ms', 0):7.1f} "
                  f"{st['n_postings']:8d} {st['n_splits']:6d} "
                  f"{len(dh):7d}")
        engine.drain()
        _print_report(engine)
        return

    engine = ServeEngine(
        SPFreshIndex.build(cfg, vecs), ecfg, policy=_make_policy(args)
    )
    print("epoch recall@10 p99_ms postings splits reassigned")
    for epoch in range(args.epochs):
        dv, iv, ii = wl.epoch()
        engine.delete(dv.astype(np.int32))
        engine.insert(iv, ii.astype(np.int32))
        q, gt = wl.queries(64)
        _, got = engine.search(q)
        hits = sum(len(set(g.tolist()) & set(o.tolist()))
                   for g, o in zip(gt, got))
        lat = engine.latency_percentiles("search")
        st = engine.stats()
        print(f"{epoch:5d} {hits / (len(q) * 10):9.3f} "
              f"{lat.get('p99_ms', 0):6.1f} {st['n_postings']:8d} "
              f"{st['n_splits']:6d} {st['n_reassigned']:10d}")
    engine.drain()
    _print_report(engine)
    if args.snapshot:
        engine.index.snapshot(args.snapshot)
        print(f"snapshot written to {args.snapshot}")


if __name__ == "__main__":
    main()
