"""Serving launcher: stand up a SPFresh index and run a mixed
search/update stream through the ServeEngine (the paper's §5.2 loop).

    PYTHONPATH=src python -m repro.launch.serve --n 8000 --epochs 10 \
        --dataset spacev --rate 0.01
"""
from __future__ import annotations

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--rate", type=float, default=0.01)
    ap.add_argument("--dataset", choices=["spacev", "sift"], default="spacev")
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--snapshot", default=None)
    args = ap.parse_args()

    from repro.core import LireConfig, SPFreshIndex
    from repro.data import UpdateWorkload
    from repro.serve.engine import EngineConfig, ServeEngine

    maker = UpdateWorkload.spacev if args.dataset == "spacev" else UpdateWorkload.sift
    wl = maker(n=args.n, dim=args.dim, rate=args.rate, seed=0)
    cfg = LireConfig(
        dim=args.dim, block_size=8, max_blocks_per_posting=8,
        num_blocks=max(8192, args.n // 2), num_postings_cap=max(1024, args.n // 20),
        num_vectors_cap=4 * args.n, split_limit=48, merge_limit=6,
        reassign_range=8, replica_count=2, nprobe=args.nprobe,
    )
    vecs, _ = wl.live_vectors()
    engine = ServeEngine(SPFreshIndex.build(cfg, vecs), EngineConfig())
    print("epoch recall@10 p99_ms postings splits reassigned")
    for epoch in range(args.epochs):
        dv, iv, ii = wl.epoch()
        engine.delete(dv.astype(np.int32))
        engine.insert(iv, ii.astype(np.int32))
        q, gt = wl.queries(64)
        _, got = engine.search(q)
        hits = sum(len(set(g.tolist()) & set(o.tolist()))
                   for g, o in zip(gt, got))
        lat = engine.latency_percentiles("search")
        st = engine.stats()
        print(f"{epoch:5d} {hits / (len(q) * 10):9.3f} "
              f"{lat.get('p99_ms', 0):6.1f} {st['n_postings']:8d} "
              f"{st['n_splits']:6d} {st['n_reassigned']:10d}")
    engine.drain()
    if args.snapshot:
        engine.index.snapshot(args.snapshot)
        print(f"snapshot written to {args.snapshot}")


if __name__ == "__main__":
    main()
