"""Training launcher: ``--arch`` selects any assigned architecture's
training cell and runs the fault-tolerant Trainer on its smoke-scale config
(CPU) or, with ``--mesh``, lowers the full-scale step on the production
mesh first (sanity) before training the reduced config.

    PYTHONPATH=src python -m repro.launch.train --arch deepfm --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch gat-cora --shape minibatch_lg
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None,
                    help="training shape cell (default: the arch's train cell)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs import get_cells

    cells = [c for c in get_cells(args.arch) if c.kind == "train"]
    if args.shape:
        cells = [c for c in cells if c.shape == args.shape]
    if not cells:
        raise SystemExit(f"no train cell for {args.arch}/{args.shape}")
    cell = cells[0]
    print(f"training {cell.name} (smoke-scale config on CPU)")

    rng = np.random.default_rng(0)
    step_fn = jax.jit(cell.smoke_step_fn, donate_argnums=cell.donate_argnums)
    params, opt, batch0 = cell.make_smoke_inputs(cell.smoke_cfg, rng)

    import time

    from repro.train.checkpoint import CheckpointStore

    store = CheckpointStore(args.ckpt) if args.ckpt else None
    start = 0
    if store is not None:
        restored = store.restore_latest((params, opt))
        if restored is not None:
            (params, opt), start, _ = restored
            print(f"resumed from step {start}")

    for step in range(start, args.steps):
        batch = cell.make_smoke_inputs(
            cell.smoke_cfg, np.random.default_rng(step)
        )[-1]
        t0 = time.time()
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {loss:.4f}  {1e3 * (time.time() - t0):.0f} ms")
        if store is not None and (step + 1) % 25 == 0:
            store.save(step + 1, (params, opt))
    print("done")


if __name__ == "__main__":
    main()
