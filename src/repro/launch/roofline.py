"""Roofline accounting from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh), in seconds:
    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` on the partitioned module reports PER-DEVICE flops and
bytes, so per-device values are divided by per-chip peaks (equivalent to the
global formula).  collective_bytes is parsed from the partitioned HLO text —
we sum the RESULT shape bytes of every collective op (local, per-device
view).
"""
from __future__ import annotations

import re

# TPU v5e hardware constants (assignment).
PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind from partitioned HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["total"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result shapes appear between '=' and the op name
        for kind in _COLLECTIVES:
            # match ` = <shape or tuple> <kind>(` — start instruction only
            marker = f" {kind}("
            if marker not in stripped or " = " not in stripped:
                continue
            lhs = stripped.split(marker)[0]
            rhs = lhs.split(" = ")
            if len(rhs) != 2:
                continue
            shapes = _SHAPE_RE.findall(rhs[1])
            nbytes = sum(_shape_bytes(d, s) for d, s in shapes)
            out[kind] += nbytes
            out["total"] += nbytes
            break
    return out


def roofline_terms(
    *,
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
) -> dict[str, float]:
    compute = flops_per_device / PEAK_FLOPS_BF16
    memory = bytes_per_device / HBM_BW
    coll = collective_bytes_per_device / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    bound = max(compute, memory, coll)
    terms["roofline_fraction_compute"] = compute / bound if bound > 0 else 0.0
    return terms


def model_flops(cell, *, tokens: int | None = None) -> float | None:
    """6·N·D (dense) / 6·N_active·D (MoE) model FLOPs for LM cells; None
    for families without a standard counting rule."""
    if cell.family == "lm":
        from repro.configs.common import LM_SHAPES

        sh = LM_SHAPES[cell.shape]
        cfg = cell.model_cfg
        n = cfg.n_active_params if cfg.moe else cfg.n_params
        if cell.kind == "train":
            d = sh["seq"] * sh["batch"]
            return 6.0 * n * d
        if cell.kind == "prefill":
            d = sh["seq"] * sh["batch"]
            return 2.0 * n * d
        # decode: one token per sequence
        return 2.0 * n * sh["batch"]
    return None
