"""spfresh-1b — the paper's own architecture at billion scale.

Document-sharded SPFresh: one LIRE shard per device (256 on the single-pod
16×16 mesh, 512 on the 2×16×16 multi-pod mesh).  Per-shard geometry below
holds ~2M live vectors (≈8M replica slots): 256 shards ≈ 0.5B, 512 shards
≈ 1.1B vectors — the paper's SPACEV1B/SIFT1B regime with int8 payloads.

Cells (serving steps, the paper's §5 workloads):
  * serve_search — Q=1024 queries, k=10, nprobe=64 (paper's search setting)
  * serve_update — B=4096 inserts routed + appended (Updater)
  * maintain     — one Local-Rebuilder round on every shard in parallel
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.common import Cell, _sds
from repro.core.types import LireConfig, make_empty_state
from repro.distributed import sharded_index as D

# Per-shard geometry (per device).
CONFIG = LireConfig(
    dim=100,                      # SPACEV byte vectors
    block_size=32,
    # §Perf iter 1: capacity 256→160 (MB 8→5).  The scan gathers FULL
    # posting buffers; steady-state live length sits between merge_limit
    # and split_limit, so capacity slack is pure HBM waste.  160 keeps
    # split_limit+GC headroom while cutting scan traffic 1.6×.
    max_blocks_per_posting=4,     # posting capacity 128
    num_blocks=262_144,           # 838 MB int8 payload / device
    num_postings_cap=65_536,
    num_vectors_cap=4_194_304,    # 4M handles / shard
    vector_dtype="int8",
    scan_dtype="bfloat16",        # §Perf iter 2: halve upcast traffic in the scan
    split_limit=96,
    merge_limit=12,
    merge_fanout=4,
    reassign_range=64,            # paper default (Fig. 11)
    reassign_budget=256,
    replica_count=4,
    replica_rng=1.15,
    nprobe=64,                    # paper: search nearest 64 postings
    # Batched Local-Rebuilder rounds: 8 splits + 8 merges per shard per
    # round, one fused reassign GEMM (1% daily churn on 2M live vectors
    # per shard ≈ a handful of oversized postings per serving slot).
    jobs_per_round=8,
    # Drift-aware job selection: at 8 jobs over 65k postings the round
    # budget is scarce, so rank by access rate × imbalance + centroid
    # drift instead of size alone (BENCH_scenarios.json shift cell).
    maintain_policy="drift",
    maintain_alpha=4.0,
    maintain_beta=1.0,
)

SMOKE = LireConfig(
    dim=16, block_size=8, max_blocks_per_posting=8, num_blocks=1024,
    num_postings_cap=128, num_vectors_cap=4096, split_limit=48,
    merge_limit=6, merge_fanout=4, reassign_range=8, reassign_budget=128,
    replica_count=2, nprobe=8, jobs_per_round=4,
)

SEARCH_Q = 1024
UPDATE_B = 4096
# probe_chunk=0: the probe-chunk lax.scan would be counted once by XLA's
# cost analysis; unchunked gives exact FLOP/byte counts for the roofline
# (the Pallas posting_scan kernel bounds real VMEM use on hardware).
PROBE_CHUNK = 0

# Paged-scan production path (serve_search_paged): the batch-dedup Pallas
# schedule with a static page budget.  SEARCH_Q·nprobe probes touch at most
# num_blocks distinct pages; 32768 (= num_blocks/8) caps the kernel grid
# while staying above the unique-page count of real probe distributions
# (overflow drops the highest-numbered pages, counted by dedup_pages).
# pallas_interpret stays True so the cell lowers everywhere; flip it off on
# real TPU hardware.
CONFIG_PAGED = dataclasses.replace(
    CONFIG,
    use_pallas_scan=True,
    scan_schedule="batched",
    scan_page_budget=32_768,
)


# ---------------------------------------------------------------------------
# Service specs — the deployable description of this architecture for
# `spfresh.open` (the serving knobs that used to be hand-threaded through
# EngineConfig/backend ctors live here, next to the geometry they tune).
# ---------------------------------------------------------------------------

def service_spec(*, paged: bool = True, smoke: bool = False,
                 n_shards: int = 1, durable_root: str | None = None,
                 n_replicas: int = 1, max_lag: int = 64):
    """The production ServiceSpec for spfresh-1b (or its smoke twin).

    ``spfresh.open(service_spec(smoke=True), vectors=...)`` stands up a
    runnable miniature of the billion-scale deployment; on real hardware
    pass ``n_shards=256`` (single-pod) and a durable root per node.
    ``n_replicas > 1`` adds data-axis read replicas fed by the async WAL
    replication stream (distributed/replication.py); ``max_lag`` is the
    freshness bound in WAL seqnos before a search falls back to the
    primary.
    """
    import spfresh

    base = SMOKE if smoke else (CONFIG_PAGED if paged else CONFIG)
    return spfresh.ServiceSpec(
        index=spfresh.IndexSpec(config=base),
        serve=spfresh.ServeSpec(
            search_k=10, nprobe=base.nprobe, max_batch=SEARCH_Q,
            max_lag=max_lag,
        ),
        scan=spfresh.ScanSpec(probe_chunk=PROBE_CHUNK),
        maintenance=spfresh.MaintenanceSpec(
            jobs_per_round=base.jobs_per_round,
            policy=base.maintain_policy,
            alpha=base.maintain_alpha,
            beta=base.maintain_beta,
        ),
        durability=spfresh.DurabilitySpec(root=durable_root),
        shards=spfresh.ShardSpec(n_shards=n_shards, n_replicas=n_replicas),
    )


def _shard_axes(multi_pod: bool):
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def _n_shards(multi_pod: bool):
    return 512 if multi_pod else 256


def _stacked_state_specs(n_shards: int):
    abstract = jax.eval_shape(lambda: make_empty_state(CONFIG))
    return jax.tree_util.tree_map(
        lambda x: _sds((n_shards, *x.shape), x.dtype), abstract
    )


# two-level router geometry (§Perf Cell A iter 4): 512 groups of ≤256
# centroids per shard; queries probe the 32 nearest groups
N_GROUPS = 512
GROUP_CAP = 256
GPROBE = 32


def _make_mesh_step(shape: str):
    def make(mesh, multi_pod: bool):
        axes = _shard_axes(multi_pod)
        n = _n_shards(multi_pod)
        state_specs = _stacked_state_specs(n)
        if shape == "serve_search":
            fn = D.make_search_step(
                mesh, CONFIG, k=10, shard_axes=axes, probe_chunk=PROBE_CHUNK
            )
            args = (
                state_specs,
                _sds((SEARCH_Q, CONFIG.dim), jnp.float32),
                _sds((n,), jnp.bool_),
            )
            return fn, args
        if shape == "serve_search_paged":
            fn = D.make_search_step(
                mesh, CONFIG_PAGED, k=10, shard_axes=axes,
                probe_chunk=PROBE_CHUNK, use_pallas_scan=True,
                scan_schedule="batched",
            )
            paged_specs = jax.tree_util.tree_map(
                lambda x: _sds((n, *x.shape), x.dtype),
                jax.eval_shape(lambda: make_empty_state(CONFIG_PAGED)),
            )
            args = (
                paged_specs,
                _sds((SEARCH_Q, CONFIG.dim), jnp.float32),
                _sds((n,), jnp.bool_),
            )
            return fn, args
        if shape == "serve_search_grouped":
            from repro.core.grouping import GroupIndex

            fn = D.make_search_step(
                mesh, CONFIG, k=10, shard_axes=axes,
                probe_chunk=PROBE_CHUNK, gprobe=GPROBE,
            )
            gi = GroupIndex(
                group_centroids=_sds((n, N_GROUPS, CONFIG.dim), jnp.float32),
                group_sqn=_sds((n, N_GROUPS), jnp.float32),
                members=_sds((n, N_GROUPS, GROUP_CAP), jnp.int32),
                member_valid=_sds((n, N_GROUPS, GROUP_CAP), jnp.bool_),
            )
            args = (
                state_specs,
                _sds((SEARCH_Q, CONFIG.dim), jnp.float32),
                _sds((n,), jnp.bool_),
                gi,
            )
            return fn, args
        if shape == "serve_update":
            fn = D.make_insert_step(mesh, CONFIG, shard_axes=axes)
            args = (state_specs, _sds((UPDATE_B, CONFIG.dim), jnp.float32))
            return fn, args
        if shape == "maintain":
            fn = D.make_maintenance_round(
                mesh, CONFIG, shard_axes=axes,
                jobs_per_round=CONFIG.jobs_per_round,
            )
            return fn, (state_specs,)
        raise KeyError(shape)
    return make


def cells() -> list[Cell]:
    out = []
    for shape in ("serve_search", "serve_search_paged",
                  "serve_search_grouped", "serve_update", "maintain"):
        c = Cell(
            arch="spfresh-1b", shape=shape, family="index",
            kind="serve", model_cfg=CONFIG, smoke_cfg=SMOKE,
            step_fn=None, input_specs=None, in_shardings=None,
            make_smoke_inputs=None,
        )
        c.make_mesh_step = _make_mesh_step(shape)
        out.append(c)
    return out
